"""Daemon quickstart: the durable control plane, crash included.

    PYTHONPATH=src python examples/daemon_quickstart.py       # seconds on CPU

Runs the full ISSUE 6 story in-process (docs/control_plane.md):

  1. boot a ``SchedulerService`` on the calibrated hetero cluster with a
     journal, submit a small workload, advance simulated time,
  2. "crash" — throw the service away, truncate the journal mid-record
     the way a SIGKILL tears it,
  3. boot a fresh service on the torn journal: it replays the inputs,
     verifies the journaled transitions, repairs the tail, and resumes,
  4. finish the workload and show the recovered schedule is identical
     to an uninterrupted run.

The real subprocess version (boot ``python -m repro.cli daemon``, submit
over the unix socket, ``kill -9``, reboot) is one command:

    PYTHONPATH=src python -m benchmarks.bench_service --smoke
"""
import os
import sys
import tempfile

sys.path.insert(0, "src")

from repro.cli import make_backend_factory
from repro.core import SchedulerService

WORKLOAD = [
    ("j0", "bert", 10.0),
    ("j1", "lbm", 10.0),
    ("j2", "resnet50", 45.0),
    ("j3", "gpt2", 900.0),
]


def fingerprint(svc):
    res = svc.result()
    return sorted(map(tuple, res["records"])), res["makespan"], res["edp"]


def main():
    factory = make_backend_factory("hetero")
    jnl = os.path.join(tempfile.mkdtemp(prefix="eco-"), "sched.jnl")

    # -- uninterrupted golden run ------------------------------------------
    golden = SchedulerService(factory)
    for name, app, t in WORKLOAD:
        golden.submit(name, app, t)
    golden.advance(None)  # drain
    g_records, g_makespan, g_edp = fingerprint(golden)

    # -- the same workload, journaled, with a crash in the middle ----------
    svc = SchedulerService(factory, journal_path=jnl)
    for name, app, t in WORKLOAD[:3]:
        print(svc.submit(name, app, t)["job"]["state"], name)
    svc.advance(400.0)
    for name in ("j0", "j1", "j2"):
        print(f"  t=400: {name} is {svc.jobs[name].state}")
    svc.close()

    size = os.path.getsize(jnl)
    with open(jnl, "r+b") as f:  # SIGKILL tears the record being written
        f.truncate(size - 17)
    print(f"\ncrash: journal torn at byte {size - 17} of {size}")

    # -- recovery: replay, verify, repair, resume --------------------------
    back = SchedulerService(factory, journal_path=jnl)
    print(
        f"recovered {len(back.jobs)} jobs, "
        f"{back.replay_divergences} divergences, t={back.backend.now:.0f}"
    )
    for name, app, t in WORKLOAD:  # idempotent re-drive + the straggler
        back.submit(name, app, t)
    back.advance(None)

    records, makespan, edp = fingerprint(back)
    assert (records, makespan, edp) == (g_records, g_makespan, g_edp)
    print(f"\nschedule after crash+recovery (== uninterrupted run):")
    for job, node, g, start, end in records:
        print(f"  {job:4s} {node:8s} g={g}  [{start:8.1f}, {end:8.1f}]")
    print(f"makespan {makespan:.1f} s, EDP {edp:.3e}")


if __name__ == "__main__":
    main()

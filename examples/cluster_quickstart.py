"""Cluster quickstart: online jobs over a heterogeneous H100/A100/V100 cluster.

    PYTHONPATH=src python examples/cluster_quickstart.py          # seconds on CPU

Generates a seeded Poisson arrival stream over the paper's 17-app mix,
round-trips it through a replayable trace file, then runs two cluster
stacks over the *same* stream:

  * energy-aware dispatcher + per-node EcoSched (the paper's policy,
    now behind a cluster-level router),
  * round-robin dispatcher + per-node max-GPU FCFS (FIFO-max baseline),

and prints the energy / makespan / EDP / wait comparison plus where each
job ran.
"""
import sys
import tempfile

sys.path.insert(0, "src")

from repro.core import calibration as C
from repro.core import load_trace, poisson_stream, save_trace

sys.path.insert(0, ".")
from benchmarks.common import run_cluster  # noqa: E402  (reuses the locked hyperparams)


def main():
    stream = poisson_stream(C.APP_ORDER, rate=1 / 1000, n=16, seed=11)
    with tempfile.NamedTemporaryFile(mode="w", suffix=".csv", delete=False) as f:
        trace_path = f.name
    save_trace(trace_path, stream)
    replay = load_trace(trace_path)
    assert replay == stream, "trace round-trip must be exact"
    print(f"{len(stream)} arrivals over {stream[-1].t:.0f}s (trace: {trace_path})")

    res = run_cluster(replay)
    fifo, eco = res["fifo_max"], res["ecosched"]
    for name, r in (("fifo_max", fifo), ("ecosched", eco)):
        placed = {nm: len(pr.records) for nm, pr in r.per_node.items()}
        print(
            f"  {name:9s} [{r.policy:13s}]: energy {r.total_energy/1e6:6.1f} MJ  "
            f"makespan {r.makespan:7.0f} s  EDP {r.edp:.3e}  "
            f"mean wait {r.mean_wait:6.0f} s  jobs/node {placed}"
        )
    print(
        f"\nEcoSched cluster vs FIFO-max: energy -{(1-eco.total_energy/fifo.total_energy)*100:.1f}%  "
        f"makespan -{(1-eco.makespan/fifo.makespan)*100:.1f}%  "
        f"EDP -{(1-eco.edp/fifo.edp)*100:.1f}%"
    )
    print("cluster quickstart OK")


if __name__ == "__main__":
    main()

"""Fault-tolerance demo: device failure mid-run → elastic recovery.

    REPRO_HOST_DEVICES=4 PYTHONPATH=src python examples/elastic_failover.py

Trains on 4 (emulated) devices, kills one at step 12, and shows the
Trainer rebuilding a 3-device mesh, restoring the last checkpoint with
re-sharding, and finishing the run.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import logging
import shutil
import sys

sys.path.insert(0, "src")

from repro.configs import get_config, reduced
from repro.data import SyntheticLM
from repro.distributed.fault import FailureInjector
from repro.models import Runtime, build_model
from repro.optim import AdamW, AdamWConfig, WarmupCosine
from repro.train.loop import Trainer, TrainerConfig


def main():
    logging.basicConfig(level=logging.INFO, format="%(levelname)s %(message)s")
    ckpt_dir = "/tmp/repro_failover"
    shutil.rmtree(ckpt_dir, ignore_errors=True)
    cfg = reduced(get_config("phi4-mini-3.8b")).replace(vocab_size=512)
    model = build_model(cfg, Runtime(remat="none"))
    data = SyntheticLM(cfg, batch=8, seq_len=64)
    trainer = Trainer(
        cfg, model, AdamW(AdamWConfig()),
        WarmupCosine(peak_lr=2e-3, warmup_steps=5, decay_steps=40),
        data,
        TrainerConfig(total_steps=40, ckpt_every=10, ckpt_dir=ckpt_dir, log_every=5),
        failure_injector=FailureInjector(schedule={12: 1}),
    )
    out = trainer.run()
    print(f"\nfinished despite failure: step={out['final_step']} "
          f"loss={out['final_loss']:.3f} recoveries={out['recoveries']}")
    assert out["recoveries"] == 1
    assert out["final_step"] == 40
    print("elastic_failover OK")


if __name__ == "__main__":
    main()

"""Quickstart: train a small LM end-to-end + schedule a workload with EcoSched.

    PYTHONPATH=src python examples/quickstart.py            # ~2 min on CPU
    PYTHONPATH=src python examples/quickstart.py --large    # ~100M-param model

Part 1 trains a granite-family model on the synthetic Markov stream and
prints the loss curve (it should fall well below ln(vocab) ≈ 5.5).
Part 2 runs the paper's scheduler on the calibrated H100 workload and
prints the headline comparison.
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.configs import get_config, reduced
from repro.data import SyntheticLM
from repro.models import Runtime, build_model
from repro.optim import AdamW, AdamWConfig, WarmupCosine
from repro.train.loop import Trainer, TrainerConfig


def train_part(large: bool, steps: int):
    cfg = get_config("granite-8b")
    if large:
        # ~100M-param member of the same family
        cfg = cfg.replace(
            name="granite-100m", num_layers=8, d_model=768, num_heads=12,
            num_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=8192,
            attn_q_chunk=256, attn_kv_chunk=256,
        )
    else:
        cfg = reduced(cfg).replace(vocab_size=512)
    model = build_model(cfg, Runtime(remat="none"))
    data = SyntheticLM(cfg, batch=8, seq_len=128)
    trainer = Trainer(
        cfg, model, AdamW(AdamWConfig()),
        WarmupCosine(peak_lr=3e-3, warmup_steps=10, decay_steps=steps),
        data,
        TrainerConfig(total_steps=steps, ckpt_every=max(steps // 2, 1),
                      ckpt_dir="/tmp/repro_quickstart", log_every=10),
    )
    out = trainer.run()
    hist = out["history"]
    print(f"\ntrained {cfg.name}: loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
          f"over {out['final_step']} steps")
    assert hist[-1]["loss"] < hist[0]["loss"], "loss did not improve"


def schedule_part():
    from repro.core import (
        EcoSched, Marble, Node, ProfiledPerfModel, SequentialOptimal,
        simulate, summarize,
    )
    from repro.core import calibration as C

    truth = C.build_system("h100")
    node = Node(units=4, domains=2, idle_power_per_unit=C.idle_power("h100"))
    pm = ProfiledPerfModel(truth, noise=0.02, seed=1)
    res = {}
    for pol in [SequentialOptimal(truth), Marble(truth), EcoSched(pm, lam=0.35, tau=0.45)]:
        r = simulate(pol, node, truth, queue=list(C.APP_ORDER),
                     charge_profiling=pol.name() == "ecosched",
                     slowdown_model=C.cross_numa_slowdown if pol.name() != "sequential_optimal_gpu" else None)
        res[r.policy] = r
    base = res["sequential_optimal_gpu"]
    print("\nEcoSched on the calibrated H100 node (17-app window):")
    for n in ("marble", "ecosched"):
        s = summarize(base, res[n])
        print(f"  {n:9s}: energy -{s['energy_saving']*100:.1f}%  "
              f"makespan -{s['makespan_improvement']*100:.1f}%  EDP -{s['edp_saving']*100:.1f}%")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--large", action="store_true")
    ap.add_argument("--steps", type=int, default=120)
    args = ap.parse_args()
    train_part(args.large, args.steps)
    schedule_part()
    print("\nquickstart OK")

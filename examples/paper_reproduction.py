"""Full paper reproduction driver: Figs. 1/2/6/8/9 + Tables II + overhead.

    PYTHONPATH=src python examples/paper_reproduction.py [--with-oracle]

Runs every benchmark tied to a paper artifact and prints ours-vs-paper
side by side, including the §V-B six-application case study (Figs. 7–8):
EcoSched downsizes pot3d/resnet50/gpt2 and cuts makespan ~30% and energy
~17% relative to Marble.
"""
import argparse
import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")


def case_study(verbose=True):
    from repro.core import (
        EcoSched, Marble, Node, ProfiledPerfModel, simulate, summarize,
    )
    from repro.core import calibration as C

    truth_all = C.build_system("h100")
    six = ["pot3d", "simpleP2P", "minisweep", "gpt2", "vgg16", "resnet50"]
    truth = {k: truth_all[k] for k in six}
    node = Node(units=4, domains=2, idle_power_per_unit=C.idle_power("h100"))
    pm = ProfiledPerfModel(truth, noise=0.02, seed=1)
    res = {}
    for pol in [Marble(truth), EcoSched(pm, lam=0.35, tau=0.45)]:
        r = simulate(pol, node, truth, queue=six,
                     slowdown_model=C.cross_numa_slowdown)
        res[r.policy] = r
    s = summarize(res["marble"], res["ecosched"])
    if verbose:
        print("\n== §V-B case study (6 apps, System 1) — EcoSched vs Marble ==")
        print(f"  makespan improvement {s['makespan_improvement']*100:5.1f}%   (paper ≈ 30%)")
        print(f"  energy reduction     {s['energy_saving']*100:5.1f}%   (paper ≈ 17%)")
        chosen = {r.job: r.g for r in res["ecosched"].records}
        print(f"  downsizing: pot3d→{chosen['pot3d']} (paper 2), "
              f"resnet50→{chosen['resnet50']} (paper 3), gpt2→{chosen['gpt2']} (paper 2)")
    return s


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--with-oracle", action="store_true")
    args = ap.parse_args()

    from benchmarks import (
        bench_fig1_scaling, bench_fig2_tradeoff, bench_fig6_end2end,
        bench_fig9_perf_loss, bench_overhead, bench_table2_choices,
    )
    from benchmarks.common import Csv

    csv = Csv()
    print("== Fig.1 scaling ==")
    bench_fig1_scaling.run(csv)
    print("\n== Fig.2 tradeoff ==")
    bench_fig2_tradeoff.run(csv)
    print("\n== Fig.6 end-to-end ==")
    bench_fig6_end2end.run(csv, with_oracle=args.with_oracle)
    print("\n== Table II ==")
    bench_table2_choices.run(csv)
    print("\n== Fig.9 perf loss ==")
    bench_fig9_perf_loss.run(csv)
    print("\n== Overhead (§V-C) ==")
    bench_overhead.run(csv)
    case_study()
    print("\npaper_reproduction OK")


if __name__ == "__main__":
    main()

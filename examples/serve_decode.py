"""Serving example: prefill a batch of prompts, then batched decode.

    PYTHONPATH=src python examples/serve_decode.py --arch gemma3-4b --tokens 32

Uses the reduced config of any assigned arch (SSM/hybrid archs exercise
the recurrent cache; gemma3 exercises the sliding-window layers).
Prints per-step latency and tokens/s for the batched decode loop.
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.models import Runtime, build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-4b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    model = build_model(cfg, Runtime(remat="none"))
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)

    B, P = args.batch, args.prompt_len
    cap = P + args.tokens
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, P)), jnp.int32)}
    if cfg.frontend == "patch_stub":
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.num_frontend_tokens, cfg.d_model)), jnp.bfloat16)
    if cfg.is_encoder_decoder:
        batch["src_embeds"] = jnp.asarray(rng.normal(size=(B, P, cfg.d_model)), jnp.bfloat16)

    t0 = time.perf_counter()
    logits, cache = jax.jit(model.prefill)(params, batch)
    logits.block_until_ready()
    print(f"prefill {B}x{P}: {time.perf_counter()-t0:.2f}s (incl. compile)")

    # grow attention caches to capacity
    cache = {
        k: (jnp.pad(v, [(0, 0), (0, 0), (0, cap - v.shape[2]), (0, 0), (0, 0)])
            if k in ("k", "v") else v)
        for k, v in cache.items()
    }
    decode = jax.jit(model.decode_step, donate_argnums=1)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    outs = [tok]
    t0 = time.perf_counter()
    for i in range(args.tokens):
        logits, cache = decode(params, cache, tok, jnp.int32(P + i))
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        outs.append(tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    toks = args.tokens * B
    print(f"decoded {args.tokens} steps x {B} seqs: {dt:.2f}s "
          f"({1e3*dt/args.tokens:.1f} ms/step, {toks/dt:.1f} tok/s)")
    gen = jnp.concatenate(outs, axis=1)
    print("sample token ids:", np.asarray(gen[0])[:16].tolist())
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    print("serve_decode OK")


if __name__ == "__main__":
    main()

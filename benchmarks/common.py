"""Shared benchmark helpers: runners, timing, CSV emission."""
from __future__ import annotations

import glob
import json
import os
import time
from typing import Dict, List, Optional

from repro.core import (
    Cluster,
    EcoSched,
    EnergyAwareDispatcher,
    Marble,
    Node,
    NodeSpec,
    OraclePerfModel,
    OracleSolver,
    ProfiledPerfModel,
    RoundRobinDispatcher,
    SequentialMax,
    SequentialOptimal,
    simulate,
    summarize,
)
from repro.core import calibration as C
from repro.roofline.hw import CHIPS

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")
DRYRUN_DIR = os.path.join(RESULTS_DIR, "dryrun")

# Locked reproduction hyperparameters (EXPERIMENTS.md §Reproduction setup).
LAM = 0.35
TAU = 0.45
NOISE = 0.02
SEED = 1


def run_system(
    system: str,
    *,
    queue=None,
    lam: float = LAM,
    tau: float = TAU,
    noise: float = NOISE,
    seed: int = SEED,
    with_oracle: bool = False,
    oracle_budget_s: float = 25.0,
    lookahead: float = 0.0,
):
    """All policies on one calibrated system; returns {name: ScheduleResult}."""
    truth = C.build_system(system)
    node = Node(units=4, domains=2, idle_power_per_unit=C.idle_power(system))
    queue = list(queue if queue is not None else C.APP_ORDER)
    pm = ProfiledPerfModel(truth, noise=noise, seed=seed)
    out = {}
    policies = [
        SequentialMax(truth),
        SequentialOptimal(truth),
        Marble(truth),
        EcoSched(pm, lam=lam, tau=tau, lookahead=lookahead),
    ]
    for pol in policies:
        r = simulate(
            pol, node, truth, queue=queue,
            charge_profiling=pol.name().startswith("ecosched"),
            slowdown_model=(
                C.cross_numa_slowdown
                if pol.name().startswith(("ecosched", "marble"))
                else None
            ),
        )
        out[r.policy] = r
    if with_oracle:
        solver = OracleSolver(node, truth, time_budget_s=oracle_budget_s)
        orr, exact = solver.solve(queue)
        orr.policy = "oracle" + ("" if exact else "~")
        out["oracle"] = orr
    return out, truth


def hetero_specs(systems=("h100", "a100", "v100")) -> List[NodeSpec]:
    """One 4-GPU/2-domain node per entry — the paper's three evaluation
    platforms joined into a single heterogeneous cluster.  Repeated systems
    get distinct node names (``v100-0``, ``v100-1``, ...)."""
    seen: Dict[str, int] = {}
    out = []
    for s in systems:
        idx = seen.get(s, 0)
        seen[s] = idx + 1
        out.append(NodeSpec(name=f"{s}-{idx}", chip=CHIPS[s]))
    return out


def run_cluster(
    stream,
    *,
    specs=None,
    lam: float = LAM,
    tau: float = TAU,
    noise: float = NOISE,
    seed: int = SEED,
):
    """EcoSched cluster vs FIFO-max cluster on one arrival stream.

    ``ecosched``: energy-aware dispatcher + per-node EcoSched (co-scheduling
    under the NUMA slowdown model, as in the single-node reproduction).
    ``fifo_max``: round-robin dispatcher + per-node sequential max-GPU FCFS
    (every job alone on all 4 units) — the paper's worst baseline, online.
    Returns {name: ClusterResult}.
    """
    specs = specs if specs is not None else hetero_specs()

    def truth_for(spec):
        return C.build_system(spec.chip.name)

    def eco_policy(spec, truth):
        return EcoSched(
            ProfiledPerfModel(truth, noise=noise, seed=seed), lam=lam, tau=tau
        )

    eco = Cluster(
        specs,
        truth_for=truth_for,
        policy_for=eco_policy,
        dispatcher=EnergyAwareDispatcher(),
        slowdown_for=lambda spec: C.cross_numa_slowdown,
        label="eco+ecosched",
    )
    fifo = Cluster(
        specs,
        truth_for=truth_for,
        policy_for=lambda spec, truth: SequentialMax(truth),
        dispatcher=RoundRobinDispatcher(),
        label="rr+fifo_max",
    )
    return {
        "ecosched": eco.simulate(stream),
        "fifo_max": fifo.simulate(stream),
    }


def load_dryrun(pattern: str = "*.json") -> List[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, pattern))):
        try:
            with open(path) as f:
                out.append(json.load(f))
        except Exception:
            pass
    return out


class Csv:
    """Collects `name,us_per_call,derived` rows for benchmarks/run.py."""

    def __init__(self):
        self.rows: List[str] = []

    def add(self, name: str, us_per_call: float, derived: str):
        self.rows.append(f"{name},{us_per_call:.3f},{derived}")

    def emit(self):
        for r in self.rows:
            print(r)

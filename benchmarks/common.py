"""Shared benchmark helpers: runners, timing, CSV emission."""
from __future__ import annotations

import glob
import json
import os
import time
from typing import Dict, List, Optional

from repro.core import (
    EcoSched,
    Marble,
    Node,
    OraclePerfModel,
    OracleSolver,
    ProfiledPerfModel,
    SequentialMax,
    SequentialOptimal,
    simulate,
    summarize,
)
from repro.core import calibration as C

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")
DRYRUN_DIR = os.path.join(RESULTS_DIR, "dryrun")

# Locked reproduction hyperparameters (EXPERIMENTS.md §Reproduction setup).
LAM = 0.35
TAU = 0.45
NOISE = 0.02
SEED = 1


def run_system(
    system: str,
    *,
    queue=None,
    lam: float = LAM,
    tau: float = TAU,
    noise: float = NOISE,
    seed: int = SEED,
    with_oracle: bool = False,
    oracle_budget_s: float = 25.0,
    lookahead: float = 0.0,
):
    """All policies on one calibrated system; returns {name: ScheduleResult}."""
    truth = C.build_system(system)
    node = Node(units=4, domains=2, idle_power_per_unit=C.idle_power(system))
    queue = list(queue if queue is not None else C.APP_ORDER)
    pm = ProfiledPerfModel(truth, noise=noise, seed=seed)
    out = {}
    policies = [
        SequentialMax(truth),
        SequentialOptimal(truth),
        Marble(truth),
        EcoSched(pm, lam=lam, tau=tau, lookahead=lookahead),
    ]
    for pol in policies:
        r = simulate(
            pol, node, truth, queue=queue,
            charge_profiling=pol.name().startswith("ecosched"),
            slowdown_model=(
                C.cross_numa_slowdown
                if pol.name().startswith(("ecosched", "marble"))
                else None
            ),
        )
        out[r.policy] = r
    if with_oracle:
        solver = OracleSolver(node, truth, time_budget_s=oracle_budget_s)
        orr, exact = solver.solve(queue)
        orr.policy = "oracle" + ("" if exact else "~")
        out["oracle"] = orr
    return out, truth


def load_dryrun(pattern: str = "*.json") -> List[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, pattern))):
        try:
            with open(path) as f:
                out.append(json.load(f))
        except Exception:
            pass
    return out


class Csv:
    """Collects `name,us_per_call,derived` rows for benchmarks/run.py."""

    def __init__(self):
        self.rows: List[str] = []

    def add(self, name: str, us_per_call: float, derived: str):
        self.rows.append(f"{name},{us_per_call:.3f},{derived}")

    def emit(self):
        for r in self.rows:
            print(r)

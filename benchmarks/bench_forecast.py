"""Forecast-driven control plane vs PR 4 eager elastic (ISSUE 5).

PR 4's elastic substrate beats static EcoSched on bursty arrivals, but its
*eager* point-in-time heuristics lose on some seeds: a drained node pulls
a waiting job an instant before work it should have absorbed arrives, or
pulls a job whose best mode on the drained (slower) hardware runs
thousands of seconds longer than staying put.  The forecast plane
(``repro.core.forecast``) replaces those point-in-time tests with online
forecasts: queueing-aware wait estimates (drain proxy × the sustained
arrival-rate EWMA), a per-job completion forecast in the migration gate,
and a hysteretic burst-risk margin on elastic actions.

Three bursty rows (the bench_elastic rates), each **averaged over 8
seeds** — the plane's value is robustness across arrival shapes, so a
single-seed comparison would be exactly the cherry-picking this PR
fixes — comparing:

  * ``static``  — EcoSched, no elasticity (PR 4 baseline),
  * ``eager``   — PR 4 elastic (resize + migrate, eco dispatcher, raw
    drain-proxy gap tests),
  * ``predictive`` — the same elastic knobs behind the forecast plane:
    ``PredictiveDispatcher`` routing on forecasted wait + energy, the
    forecasted per-job migration gate, pressure-conditioned resize bias,
    online perf-model refinement.

Gates (full mode):
  * predictive ≤ eager on mean EDP on ≥ 2/3 rows,
  * the committed **adversarial seed** (``ADVERSARIAL``: rate 1/900,
    seed 7 — found by sweeping PR 4: static beats eager there by ~31%
    EDP) must *flip*: predictive beats static AND eager.

``--smoke`` (CI): forecast-off parity (an all-off ``ForecastConfig`` and
an unattached ``PredictiveDispatcher`` are bit-identical to the PR 4
paths) + a no-regression tripwire on one small row.

Writes ``benchmarks/results/forecast.csv``; ``run.py`` snapshots the row
means into the committed ``benchmarks/BENCH_forecast.json``.
"""
from __future__ import annotations

import os
import time

from benchmarks.common import LAM, NOISE, SEED, TAU, RESULTS_DIR, Csv, hetero_specs
from repro.core import (
    Cluster,
    EcoSched,
    ElasticConfig,
    EnergyAwareDispatcher,
    ForecastConfig,
    PredictiveDispatcher,
    ProfiledPerfModel,
    bursty_stream,
)
from repro.core import calibration as C

# the bench_elastic bursty shapes: sparse -> overlapping -> saturated
RATES = (1 / 2000, 1 / 900, 1 / 450)
SEEDS = tuple(range(8))
N, BURST = 24, 5

# the committed PR 4 "eager migration loses" seed: static beats eager
# elastic by ~31% EDP (the drained a100 pulls a job whose g=1 runtime
# there is ~4300 s longer than on its donor; the job-blind wait-gap test
# cannot see that).  Deterministic regression case — also locked in
# tests/test_forecast.py.
ADVERSARIAL = (1 / 900, 7)

# PR 4 elastic knobs, unchanged (benchmarks/bench_elastic.py)
ELASTIC = ElasticConfig(
    resize=True,
    migrate=True,
    ckpt_time=30.0,
    restart_time=15.0,
    migration_delay=10.0,
    min_gain_s=120.0,
    max_preempts=2,
    switch_cost=0.05,
)

FORECAST = ForecastConfig()  # the documented defaults are the bench config


def make_cluster(dispatcher, label: str = "") -> Cluster:
    return Cluster(
        hetero_specs(),
        truth_for=lambda s: C.build_system(s.chip.name),
        policy_for=lambda s, t: EcoSched(
            ProfiledPerfModel(t, noise=NOISE, seed=SEED), lam=LAM, tau=TAU
        ),
        dispatcher=dispatcher,
        slowdown_for=lambda s: C.cross_numa_slowdown,
        label=label,
    )


def _triple(stream):
    """(static, eager-elastic, predictive) ClusterResults for one stream."""
    static = make_cluster(EnergyAwareDispatcher(), "eco+ecosched-static").simulate(
        stream
    )
    eager = make_cluster(EnergyAwareDispatcher(), "eco+ecosched-elastic").simulate(
        stream, elastic=ELASTIC
    )
    pred = make_cluster(PredictiveDispatcher(), "predictive+ecosched").simulate(
        stream, elastic=ELASTIC, forecast=FORECAST
    )
    return static, eager, pred


def run(csv: Csv, verbose: bool = True, smoke: bool = False):
    if smoke:
        return _smoke(csv, verbose)
    os.makedirs(RESULTS_DIR, exist_ok=True)
    rows = [
        "row,policy,mean_edp_Js,mean_energy_J,mean_makespan_s,"
        "migrations,vetoed,refinements"
    ]
    snapshot = {"rows": [], "adversarial": {}}
    wins = 0
    for rate in RATES:
        t0 = time.perf_counter()
        acc = {"static": [], "eager": [], "predictive": []}
        stats = {"vetoed": 0.0, "refinements": 0.0}
        for seed in SEEDS:
            stream = bursty_stream(
                C.APP_ORDER, rate=rate, n=N, burst=BURST, seed=seed
            )
            static, eager, pred = _triple(stream)
            for k, r in (("static", static), ("eager", eager), ("predictive", pred)):
                acc[k].append(r)
            stats["vetoed"] += pred.forecast["migrations_vetoed"]
            stats["refinements"] += pred.forecast["refinements"]
        us = (time.perf_counter() - t0) * 1e6
        tag = f"bursty_{rate:.5f}"
        means = {}
        for k, rs in acc.items():
            edp = sum(r.edp for r in rs) / len(rs)
            energy = sum(r.total_energy for r in rs) / len(rs)
            mk_ = sum(r.makespan for r in rs) / len(rs)
            means[k] = edp
            mig = sum(r.migrations for r in rs)
            rows.append(
                f"{tag},{k},{edp:.6e},{energy:.1f},{mk_:.1f},{mig},"
                f"{stats['vetoed'] if k == 'predictive' else 0:.0f},"
                f"{stats['refinements'] if k == 'predictive' else 0:.0f}"
            )
        win = means["predictive"] <= means["eager"]
        wins += win
        snapshot["rows"].append(
            {
                "rate": rate,
                "seeds": len(SEEDS),
                "static_edp": means["static"],
                "eager_edp": means["eager"],
                "predictive_edp": means["predictive"],
                "win": bool(win),
            }
        )
        if verbose:
            print(
                f"forecast {tag} ({len(SEEDS)} seeds): "
                f"static EDP={means['static']:.3e} | "
                f"eager {means['eager']:.3e} | "
                f"predictive {means['predictive']:.3e} "
                f"({100 * (means['predictive'] / means['eager'] - 1):+.2f}% vs eager, "
                f"veto={stats['vetoed']:.0f}) | {'WIN' if win else 'no win'}"
            )
        csv.add(
            f"forecast_{tag}", us,
            f"edp_vs_eager={100 * (means['predictive'] / means['eager'] - 1):+.2f}%",
        )
    # the committed adversarial seed: eager loses to static; the plane flips it
    rate, seed = ADVERSARIAL
    stream = bursty_stream(C.APP_ORDER, rate=rate, n=N, burst=BURST, seed=seed)
    static, eager, pred = _triple(stream)
    for k, r in (("static", static), ("eager", eager), ("predictive", pred)):
        rows.append(
            f"adversarial_s{seed},{k},{r.edp:.6e},{r.total_energy:.1f},"
            f"{r.makespan:.1f},{r.migrations},"
            f"{r.forecast.get('migrations_vetoed', 0):.0f},"
            f"{r.forecast.get('refinements', 0):.0f}"
        )
    snapshot["adversarial"] = {
        "rate": rate,
        "seed": seed,
        "static_edp": static.edp,
        "eager_edp": eager.edp,
        "predictive_edp": pred.edp,
        "vetoed": pred.forecast["migrations_vetoed"],
    }
    if verbose:
        print(
            f"forecast adversarial (rate=1/{round(1 / rate)}, seed={seed}): "
            f"static {static.edp:.3e} < eager {eager.edp:.3e} (the PR 4 loss) "
            f"| predictive {pred.edp:.3e} "
            f"({'FLIPPED' if pred.edp < static.edp else 'NOT flipped'}, "
            f"veto={pred.forecast['migrations_vetoed']:.0f})"
        )
    out_path = os.path.join(RESULTS_DIR, "forecast.csv")
    with open(out_path, "w") as f:
        f.write("\n".join(rows) + "\n")
    if verbose:
        print(f"forecast CSV -> {out_path}")
    assert static.edp < eager.edp, (
        "the committed adversarial seed must reproduce the PR 4 loss "
        f"(static {static.edp:.3e} vs eager {eager.edp:.3e})"
    )
    assert pred.edp < static.edp and pred.edp < eager.edp, (
        f"predictive must flip the adversarial seed: {pred.edp:.3e} vs "
        f"static {static.edp:.3e} / eager {eager.edp:.3e}"
    )
    assert wins >= 2, (
        f"predictive must be >= PR 4 elastic on mean EDP on >=2/3 bursty "
        f"rows, got {wins}"
    )
    return snapshot


def write_json(path: str, snapshot: dict) -> None:
    """Committed perf-trajectory snapshot (run.py, full runs only)."""
    import json

    with open(path, "w") as f:
        json.dump(snapshot, f, indent=2, sort_keys=True)
        f.write("\n")


def _smoke(csv: Csv, verbose: bool) -> int:
    """CI tripwire: forecast-off parity + no-regression, one small row."""
    stream = bursty_stream(C.APP_ORDER, rate=1 / 900, n=12, burst=4, seed=13)
    t0 = time.perf_counter()
    base = make_cluster(EnergyAwareDispatcher()).simulate(stream, elastic=ELASTIC)
    # an all-off ForecastConfig never builds a plane: bit-identical
    off = make_cluster(EnergyAwareDispatcher()).simulate(
        stream,
        elastic=ELASTIC,
        forecast=ForecastConfig(refine=False, queueing=False, burst_gate=False),
    )
    key = lambda r: [(x.job, x.node, x.g, x.start) for x in r.records]  # noqa: E731
    assert key(base) == key(off) and base.total_energy == off.total_energy, (
        "all-off ForecastConfig must be bit-identical to forecast=None"
    )
    assert off.forecast == {}, "no plane -> no forecast summary"
    # an unattached PredictiveDispatcher routes exactly like EnergyAware
    pred_off = make_cluster(PredictiveDispatcher()).simulate(
        stream, elastic=ELASTIC
    )
    assert key(base) == key(pred_off), (
        "PredictiveDispatcher without a plane must match EnergyAwareDispatcher"
    )
    # enabled plane: completes every job, regresses nowhere near the gate
    pred = make_cluster(PredictiveDispatcher()).simulate(
        stream, elastic=ELASTIC, forecast=FORECAST
    )
    assert {r.job for r in pred.records} == {a.name for a in stream}
    assert pred.forecast["refinements"] > 0, "COMPLETE events must feed the posterior"
    assert pred.edp <= base.edp * 1.02, (
        f"predictive regressed EDP: {pred.edp:.3e} vs {base.edp:.3e}"
    )
    us = (time.perf_counter() - t0) * 1e6
    if verbose:
        print(
            f"forecast --smoke: parity OK, predictive EDP {pred.edp:.3e} vs "
            f"eager {base.edp:.3e}"
        )
    csv.add("forecast_smoke", us, "parity+no-regression OK")
    return 0


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--json", help="also write the BENCH_forecast.json snapshot")
    args = ap.parse_args()
    c = Csv()
    snap = run(c, smoke=args.smoke)
    if args.json and not args.smoke:
        write_json(args.json, snap)
        print(f"forecast snapshot -> {args.json}")
    c.emit()

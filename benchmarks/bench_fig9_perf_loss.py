"""Fig. 9 — per-application performance loss under EcoSched vs solo
execution at the performance-optimal count, across all systems.

Paper anchors: moderate losses for downsized apps (gpt2/pot3d/resnet101 on
H100); miniweather on V100 ≈ 40% (4→1, traded for ~20% energy saving).
"""
from __future__ import annotations

import time

from benchmarks.common import Csv, run_system
from repro.core import perf_loss


def run(csv: Csv, verbose: bool = True):
    t0 = time.perf_counter()
    worst = {}
    for system in ("h100", "a100", "v100"):
        res, truth = run_system(system)
        losses = perf_loss(res["ecosched"], truth)
        mean_loss = sum(losses.values()) / len(losses)
        w = max(losses.items(), key=lambda kv: kv[1])
        worst[system] = w
        if verbose:
            print(f"fig9 {system}: mean loss {mean_loss*100:.1f}%, worst {w[0]} {w[1]*100:.1f}%")
            for app, l in sorted(losses.items(), key=lambda kv: -kv[1])[:6]:
                print(f"    {app:24s} {l*100:6.1f}%")
    # paper: miniweather V100 ~40%
    res_v, truth_v = run_system("v100")
    l_v = perf_loss(res_v["ecosched"], truth_v)
    assert 0.30 < l_v["miniweather"] < 0.50, l_v["miniweather"]
    us = (time.perf_counter() - t0) * 1e6
    csv.add(
        "fig9_perf_loss", us,
        ";".join(f"{s}:worst={a}@{l*100:.0f}%" for s, (a, l) in worst.items()),
    )


if __name__ == "__main__":
    c = Csv()
    run(c)
    c.emit()

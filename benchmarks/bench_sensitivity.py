"""Robustness ablations the paper leaves implicit (§III-C, §VI).

* λ / τ sensitivity — the headline H100 result across the hyperparameter
  grid (the paper gives no values; we verify the result is a plateau, not
  a cherry-picked point),
* Phase-I noise sweep — how much profiling error EcoSched tolerates
  before Table II choices and energy savings degrade,
* bounded-window sweep — §VI's streaming setting: EcoSched restricted to
  the first W waiting jobs,
* queue-shuffle robustness — mean ± spread over 10 random arrival orders,
* lookahead ablation (beyond-paper) — completion-alignment penalty.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Csv
from repro.core import (
    EcoSched, Node, ProfiledPerfModel, SequentialOptimal, simulate, summarize,
)
from repro.core import calibration as C


def _run(lam=0.35, tau=0.45, noise=0.02, seed=1, window=None, lookahead=0.0, queue=None):
    truth = C.build_system("h100")
    node = Node(units=4, domains=2, idle_power_per_unit=C.idle_power("h100"))
    q = list(queue if queue is not None else C.APP_ORDER)
    base = simulate(SequentialOptimal(truth), node, truth, queue=q)
    pm = ProfiledPerfModel(truth, noise=noise, seed=seed)
    eco = simulate(
        EcoSched(pm, lam=lam, tau=tau, window=window, lookahead=lookahead),
        node, truth, queue=q,
        charge_profiling=True, slowdown_model=C.cross_numa_slowdown,
    )
    return summarize(base, eco)


def run(csv: Csv, verbose: bool = True):
    t0 = time.perf_counter()

    if verbose:
        print("sensitivity λ×τ grid (H100 energy/makespan/EDP savings %):")
    grid_vals = []
    for lam in (0.15, 0.35, 0.7):
        for tau in (0.25, 0.45, 0.7):
            s = _run(lam=lam, tau=tau)
            grid_vals.append(s["edp_saving"])
            if verbose:
                print(
                    f"  λ={lam:4.2f} τ={tau:4.2f}: "
                    f"e={s['energy_saving']*100:5.1f} m={s['makespan_improvement']*100:5.1f} "
                    f"d={s['edp_saving']*100:5.1f}"
                )
    plateau = min(grid_vals) > 0.25  # every grid point keeps >25% EDP saving

    if verbose:
        print("sensitivity Phase-I noise sweep:")
    noise_last = None
    for noise in (0.0, 0.02, 0.05, 0.10, 0.20):
        s = _run(noise=noise)
        noise_last = s
        if verbose:
            print(f"  σ={noise:4.2f}: e={s['energy_saving']*100:5.1f} d={s['edp_saving']*100:5.1f}")

    if verbose:
        print("sensitivity window sweep (§VI streaming):")
    for w in (4, 8, 12, None):
        s = _run(window=w)
        if verbose:
            print(f"  W={str(w):>4s}: e={s['energy_saving']*100:5.1f} d={s['edp_saving']*100:5.1f}")

    rng = np.random.default_rng(0)
    shuf = []
    for i in range(10):
        q = list(C.APP_ORDER)
        rng.shuffle(q)
        shuf.append(_run(queue=q, seed=i)["edp_saving"])
    if verbose:
        print(
            f"sensitivity shuffle robustness: EDP saving {np.mean(shuf)*100:.1f}% "
            f"± {np.std(shuf)*100:.1f}% over 10 arrival orders"
        )

    s_base = _run()
    s_look = _run(lookahead=0.3)
    if verbose:
        print(
            f"sensitivity lookahead ablation: EDP {s_base['edp_saving']*100:.1f}% -> "
            f"{s_look['edp_saving']*100:.1f}% (beyond-paper, §Perf)"
        )

    us = (time.perf_counter() - t0) * 1e6
    csv.add(
        "sensitivity", us,
        f"plateau={plateau};shuffle_edp={np.mean(shuf)*100:.1f}±{np.std(shuf)*100:.1f}%",
    )


if __name__ == "__main__":
    c = Csv()
    run(c)
    c.emit()

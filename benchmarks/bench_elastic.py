"""Elastic vs static EcoSched on bursty heterogeneous arrivals (ISSUE 4).

The paper's headline is that *jointly* choosing GPU counts and
co-scheduling wins — but a static scheduler commits each count at launch.
Under bursty arrivals that commitment is exactly wrong: during a burst
EcoSched packs jobs at modest counts, and when the burst drains the
stragglers keep their launch-time counts while units idle.  The elastic
substrate (``repro.core.events``) fixes both ends:

  * **resizing**  — on completions EcoSched preempt-and-relaunches a
    running job at its now-better count (checkpoint + restart charged),
  * **migration** — a node that drains early pulls waiting jobs from the
    most backlogged node when the wait gap beats the move cost.

This bench sweeps three bursty rates over the heterogeneous
H100/A100/V100 cluster and compares ``ecosched-static`` (elastic off)
against ``ecosched-elastic`` (resize + migrate), with the cluster-level
greedy oracle bound (``repro.core.oracle.cluster_oracle_bound``) reported
alongside.  Gate (full mode): elastic beats static on *both* makespan and
EDP on ≥ 2 of the 3 rows.  A fourth ungated row replays the committed
datacenter sample trace (``benchmarks/data/datacenter_sample.csv``)
through ``from_datacenter_csv`` — real arrival shapes, same comparison.

``--smoke`` (CI): asserts the all-off ``ElasticConfig()`` is bit-identical
to ``elastic=None`` (substrate parity) and that enabling elasticity does
not regress EDP on one small bursty row (no-regression gate).

``--ablate-resize-order`` (ISSUE 5 satellite): the PR 4 caveat was that
resizes fire mostly at drain tails because the backfill scheduling pass
soaks freed units before ``propose_resizes`` sees them.
``ElasticConfig(resize_before_backfill=True)`` flips that order on
COMPLETE events; the ablation reruns the three bursty rows under both
orders and prints one summary line per config (mean EDP / makespan /
resize count across the rows).

Writes ``benchmarks/results/elastic.csv``.  Runs in seconds on CPU.
"""
from __future__ import annotations

import os
import time

from benchmarks.common import LAM, NOISE, SEED, TAU, RESULTS_DIR, Csv, hetero_specs
from repro.core import (
    Cluster,
    EcoSched,
    ElasticConfig,
    EnergyAwareDispatcher,
    ProfiledPerfModel,
    bursty_stream,
    cluster_oracle_bound,
    from_datacenter_csv,
)
from repro.core import calibration as C

# three bursty rows: sparse -> overlapping -> saturated (jobs/s over the
# long-running calibrated mix, bursts of up to 5 correlated submissions)
ROWS = (
    (1 / 2000, 5, 24, 3),
    (1 / 900, 5, 24, 3),
    (1 / 450, 5, 24, 3),
)

# checkpoint/restart costs are tens of seconds against multi-thousand-second
# jobs — the regime where elastic reallocation pays (arXiv:2304.06381)
ELASTIC = ElasticConfig(
    resize=True,
    migrate=True,
    ckpt_time=30.0,
    restart_time=15.0,
    migration_delay=10.0,
    min_gain_s=120.0,
    max_preempts=2,
    switch_cost=0.05,
)

SAMPLE_TRACE = os.path.join(os.path.dirname(__file__), "data", "datacenter_sample.csv")


def make_cluster(elastic_label: str = "") -> Cluster:
    return Cluster(
        hetero_specs(),
        truth_for=lambda s: C.build_system(s.chip.name),
        policy_for=lambda s, t: EcoSched(
            ProfiledPerfModel(t, noise=NOISE, seed=SEED), lam=LAM, tau=TAU
        ),
        dispatcher=EnergyAwareDispatcher(),
        slowdown_for=lambda s: C.cross_numa_slowdown,
        label=elastic_label,
    )


def bound_for(stream):
    return cluster_oracle_bound(
        hetero_specs(), lambda s: C.build_system(s.chip.name), stream
    )


def sample_stream(time_scale: float = 4.0):
    """The committed datacenter sample, times stretched so the ~3 h log
    spans the calibrated multi-thousand-second runtimes."""
    return from_datacenter_csv(
        SAMPLE_TRACE,
        app_map=lambda a: a if a in C.APP_ORDER else None,
        time_scale=time_scale,
    )


def _run_pair(stream):
    static = make_cluster("eco+ecosched-static").simulate(stream)
    elastic = make_cluster("eco+ecosched-elastic").simulate(
        stream, elastic=ELASTIC
    )
    return static, elastic


def run(csv: Csv, verbose: bool = True, smoke: bool = False):
    if smoke:
        return _smoke(csv, verbose)
    os.makedirs(RESULTS_DIR, exist_ok=True)
    rows = [
        "stream,policy,total_energy_J,makespan_s,edp_Js,mean_wait_s,"
        "preemptions,migrations,resizes,oracle_energy_lb_J,oracle_makespan_lb_s"
    ]
    wins = 0
    for rate, burst, n, seed in ROWS:
        stream = bursty_stream(C.APP_ORDER, rate=rate, n=n, burst=burst, seed=seed)
        t0 = time.perf_counter()
        static, elastic = _run_pair(stream)
        us = (time.perf_counter() - t0) * 1e6
        lb = bound_for(stream)
        tag = f"bursty_{rate:.5f}"
        for r in (static, elastic):
            rows.append(
                f"{tag},{r.policy},{r.total_energy:.1f},{r.makespan:.1f},"
                f"{r.edp:.6e},{r.mean_wait:.1f},{r.preemptions},"
                f"{r.migrations},{r.resizes},"
                f"{lb['energy_lb']:.1f},{lb['makespan_lb']:.1f}"
            )
        win = elastic.makespan < static.makespan and elastic.edp < static.edp
        wins += win
        if verbose:
            print(
                f"elastic {tag} ({n} jobs, burst≤{burst}): "
                f"static T={static.makespan:.0f}s EDP={static.edp:.3e} | "
                f"elastic T={elastic.makespan:.0f}s EDP={elastic.edp:.3e} "
                f"(pre={elastic.preemptions} mig={elastic.migrations} "
                f"rsz={elastic.resizes}) | "
                f"oracle LB T={lb['makespan_lb']:.0f}s E={lb['energy_lb']/1e6:.1f}MJ"
                f" | {'WIN' if win else 'no win'}"
            )
        csv.add(
            f"elastic_{tag}", us,
            f"edp_save={100 * (1 - elastic.edp / static.edp):.1f}%",
        )
    # ungated: real arrival shapes from the committed datacenter sample
    stream = sample_stream()
    static, elastic = _run_pair(stream)
    lb = bound_for(stream)
    for r in (static, elastic):
        rows.append(
            f"datacenter_sample,{r.policy},{r.total_energy:.1f},{r.makespan:.1f},"
            f"{r.edp:.6e},{r.mean_wait:.1f},{r.preemptions},{r.migrations},"
            f"{r.resizes},{lb['energy_lb']:.1f},{lb['makespan_lb']:.1f}"
        )
    if verbose:
        print(
            f"elastic datacenter_sample ({len(stream)} jobs): "
            f"static EDP={static.edp:.3e} | elastic EDP={elastic.edp:.3e} "
            f"(pre={elastic.preemptions} mig={elastic.migrations} "
            f"rsz={elastic.resizes})"
        )
    out_path = os.path.join(RESULTS_DIR, "elastic.csv")
    with open(out_path, "w") as f:
        f.write("\n".join(rows) + "\n")
    if verbose:
        print(f"elastic CSV -> {out_path}")
    assert wins >= 2, (
        f"elastic EcoSched must beat static on makespan AND EDP on >=2/3 "
        f"bursty rows, got {wins}"
    )
    return wins


def run_ablate_resize_order(csv: Csv, verbose: bool = True):
    """Resize-before-backfill vs the default resize-after order, one
    summary line per config over the three bursty rows."""
    import dataclasses

    configs = {
        "resize-after-backfill (default)": ELASTIC,
        "resize-before-backfill": dataclasses.replace(
            ELASTIC, resize_before_backfill=True
        ),
    }
    streams = [
        bursty_stream(C.APP_ORDER, rate=rate, n=n, burst=burst, seed=seed)
        for rate, burst, n, seed in ROWS
    ]
    for name, cfg in configs.items():
        t0 = time.perf_counter()
        results = [
            make_cluster("eco+ecosched-elastic").simulate(s, elastic=cfg)
            for s in streams
        ]
        us = (time.perf_counter() - t0) * 1e6
        edp = sum(r.edp for r in results) / len(results)
        mk = sum(r.makespan for r in results) / len(results)
        rsz = sum(r.resizes for r in results)
        pre = sum(r.preemptions for r in results)
        if verbose:
            print(
                f"ablate-resize-order {name}: mean EDP={edp:.3e} "
                f"mean T={mk:.0f}s resizes={rsz} preemptions={pre}"
            )
        csv.add(
            f"ablate_{'before' if cfg.resize_before_backfill else 'after'}",
            us,
            f"mean_edp={edp:.3e};resizes={rsz}",
        )


def _smoke(csv: Csv, verbose: bool) -> int:
    """CI tripwire: substrate parity + elastic no-regression, one tiny row."""
    stream = bursty_stream(C.APP_ORDER, rate=1 / 900, n=12, burst=4, seed=13)
    t0 = time.perf_counter()
    static = make_cluster().simulate(stream)
    # an all-off ElasticConfig must ride the identical code path
    off = make_cluster().simulate(stream, elastic=ElasticConfig())
    assert [(r.job, r.node, r.g, r.start) for r in static.records] == [
        (r.job, r.node, r.g, r.start) for r in off.records
    ], "ElasticConfig() with every switch off must be bit-identical"
    assert static.total_energy == off.total_energy
    elastic = make_cluster().simulate(stream, elastic=ELASTIC)
    # set-compare: a preempted job legitimately emits several records
    assert {r.job for r in elastic.records} == {a.name for a in stream}, (
        "elastic run must complete every job"
    )
    assert elastic.edp <= static.edp * 1.02, (
        f"elastic regressed EDP: {elastic.edp:.3e} vs {static.edp:.3e}"
    )
    lb = bound_for(stream)
    assert lb["energy_lb"] <= min(static.total_energy, elastic.total_energy)
    assert lb["makespan_lb"] <= min(static.makespan, elastic.makespan)
    us = (time.perf_counter() - t0) * 1e6
    if verbose:
        print(
            f"elastic --smoke: parity OK, EDP {elastic.edp:.3e} vs "
            f"{static.edp:.3e} (static), oracle LB holds"
        )
    csv.add("elastic_smoke", us, "parity+no-regression OK")
    return 0


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ablate-resize-order", action="store_true")
    args = ap.parse_args()
    c = Csv()
    if args.ablate_resize_order:
        run_ablate_resize_order(c)
    else:
        run(c, smoke=args.smoke)
    c.emit()

"""Fig. 1 — application performance across GPU counts (3 systems).

Emits per-(system, app) normalized runtime at g ∈ {1..4} and the
performance-optimal count, demonstrating heterogeneous / non-monotonic
scaling (miniweather optimal at 1 on H100 vs 4 on V100 etc.).
"""
from __future__ import annotations

import time

from benchmarks.common import Csv
from repro.core import calibration as C

REPRESENTATIVE = (
    "miniweather", "gpt2", "pot3d", "resnet50", "lbm", "vgg16", "MonteCarlo",
)


def run(csv: Csv, verbose: bool = True):
    t0 = time.perf_counter()
    opt_counts = {}
    for system in ("h100", "a100", "v100"):
        truth = C.build_system(system)
        for app in REPRESENTATIVE:
            prof = truth[app]
            t1 = prof.runtime[1]
            curve = [round(prof.runtime[g] / t1, 3) for g in (1, 2, 3, 4)]
            opt_counts[(system, app)] = prof.optimal_count()
            if verbose:
                print(f"fig1 {system:5s} {app:14s} t(g)/t(1)={curve} optimal={prof.optimal_count()}")
    # headline checks from Fig. 1: miniweather optimal 1 on H100, 4 on V100
    assert opt_counts[("h100", "miniweather")] == 1
    assert opt_counts[("v100", "miniweather")] == 4
    us = (time.perf_counter() - t0) * 1e6
    csv.add("fig1_scaling", us, "miniweather_opt_h100=1;v100=4")


if __name__ == "__main__":
    c = Csv()
    run(c)
    c.emit()

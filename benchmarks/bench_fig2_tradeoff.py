"""Fig. 2 — per-application perf/energy tradeoff of one-fewer-GPU (H100).

For gpt2 (3→2), pot3d (4→3), resnet50 (4→3): performance loss, active
energy saving, and EDP change between the performance-optimal count and
one fewer GPU.  Paper anchor: gpt2 ≈ 3–8% perf loss for ~24% energy
saving.
"""
from __future__ import annotations

import time

from benchmarks.common import Csv
from repro.core import calibration as C

CASES = [("gpt2", 3, 2), ("pot3d", 4, 3), ("resnet50", 4, 3)]


def run(csv: Csv, verbose: bool = True):
    t0 = time.perf_counter()
    truth = C.build_system("h100")
    derived = []
    for app, g_opt, g_less in CASES:
        prof = truth[app]
        perf_loss = prof.runtime[g_less] / prof.runtime[g_opt] - 1.0
        e_opt = prof.energy(g_opt)
        e_less = prof.energy(g_less)
        saving = 1.0 - e_less / e_opt
        edp_opt = e_opt * prof.runtime[g_opt]
        edp_less = e_less * prof.runtime[g_less]
        edp_save = 1.0 - edp_less / edp_opt
        if verbose:
            print(
                f"fig2 {app:9s} {g_opt}→{g_less}: perf_loss={perf_loss*100:5.1f}% "
                f"energy_saving={saving*100:5.1f}% edp_saving={edp_save*100:5.1f}%"
            )
        derived.append(f"{app}:{perf_loss*100:.0f}%loss/{saving*100:.0f}%save")
    gpt2 = truth["gpt2"]
    assert 0.02 < gpt2.runtime[2] / gpt2.runtime[3] - 1 < 0.12  # 3–8% band
    assert 1 - gpt2.energy(2) / gpt2.energy(3) > 0.15  # ~24% band
    us = (time.perf_counter() - t0) * 1e6
    csv.add("fig2_tradeoff", us, ";".join(derived))


if __name__ == "__main__":
    c = Csv()
    run(c)
    c.emit()

"""§V-C — scheduling + profiling overhead.

* decision latency per scheduling event (paper: < 0.5 ms in C; ours is
  pure Python — reported honestly),
* profiling energy per app and amortization time: the minutes of
  execution after which the one-time profiling cost is repaid by the
  lower-power mode EcoSched selected (paper: gpt2 3.13 min, vgg16 via
  idle-GPU reuse 2.70 min).
"""
from __future__ import annotations

import time

from benchmarks.common import Csv, run_system
from repro.core import calibration as C


def run(csv: Csv, verbose: bool = True):
    t0 = time.perf_counter()
    res, truth = run_system("h100")
    eco = res["ecosched"]
    per_event_ms = 1e3 * eco.decision_time_s / max(eco.decision_events, 1)

    # gpt2 amortization: power delta between fastest profiled mode (3) and
    # EcoSched's choice (2) repays the 64 kJ profiling cost
    gpt2 = truth["gpt2"]
    chosen = {r.job: r.g for r in eco.records}
    g_fast, g_pick = gpt2.optimal_count(), chosen["gpt2"]
    dp = gpt2.busy_power[g_fast] - gpt2.busy_power[g_pick]
    amort_min = gpt2.profiling_energy / dp / 60.0 if dp > 0 else float("inf")

    # vgg16 amortization via idle-GPU reuse: choosing 1 GPU frees 3 that
    # co-runners keep busy, avoiding 3×idle power
    vgg = truth["vgg16"]
    idle = C.idle_power("h100")
    freed = 4 - chosen["vgg16"]
    amort_vgg_min = vgg.profiling_energy / (freed * idle) / 60.0

    total_prof_kj = sum(p.profiling_energy for p in truth.values()) / 1e3
    frac = eco.profiling_energy / eco.total_energy

    if verbose:
        print(f"overhead decision latency: {per_event_ms:.2f} ms/event over {eco.decision_events} events (paper <0.5ms, C impl)")
        print(f"overhead gpt2 profiling 64kJ repaid in {amort_min:.2f} min by ΔP={dp:.0f}W (paper: 3.13 min / 341W)")
        print(f"overhead vgg16 profiling 34kJ repaid in {amort_vgg_min:.2f} min via {freed}x{idle:.0f}W idle reuse (paper: 2.70 min)")
        print(f"overhead total profiling {total_prof_kj:.0f} kJ = {frac*100:.2f}% of EcoSched total energy")
    us = (time.perf_counter() - t0) * 1e6
    csv.add(
        "overhead", us,
        f"decision={per_event_ms:.2f}ms;gpt2_amort={amort_min:.2f}min;vgg16_amort={amort_vgg_min:.2f}min",
    )


if __name__ == "__main__":
    c = Csv()
    run(c)
    c.emit()

"""Cluster sweep — heterogeneous H100/A100/V100 nodes, online Poisson jobs.

The paper evaluates each system in isolation with a static 17-app window;
this benchmark joins the three calibrated systems into one cluster and
sweeps the *arrival rate* of an online job stream (the regime of
arXiv:2412.17484 / arXiv:2304.06381, where routing + co-scheduling
decisions dominate).  For each rate it compares

  * ``eco+ecosched``  — energy-aware dispatcher, per-node EcoSched,
  * ``rr+fifo_max``   — round-robin dispatcher, per-node max-GPU FCFS,

and writes energy/makespan/EDP/mean-wait rows to
``benchmarks/results/cluster.csv``.  Runs in seconds on CPU.
"""
from __future__ import annotations

import os
import time

from benchmarks.common import RESULTS_DIR, Csv, run_cluster
from repro.core import calibration as C
from repro.core import poisson_stream

# jobs/s over the long-running calibrated workload (mean solo runtimes are
# thousands of seconds): sparse -> overlapping -> saturated
RATES = (1 / 2000, 1 / 1000, 1 / 400)
N_JOBS = 24
SEED = 7


def run(csv: Csv, verbose: bool = True, rates=RATES, n_jobs: int = N_JOBS):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    out_path = os.path.join(RESULTS_DIR, "cluster.csv")
    rows = ["rate_jobs_per_s,policy,total_energy_J,makespan_s,edp_Js,mean_wait_s"]
    for rate in rates:
        stream = poisson_stream(C.APP_ORDER, rate=rate, n=n_jobs, seed=SEED)
        t0 = time.perf_counter()
        res = run_cluster(stream)
        us = (time.perf_counter() - t0) * 1e6
        for name in ("fifo_max", "ecosched"):
            r = res[name]
            rows.append(
                f"{rate:.6f},{r.policy},{r.total_energy:.1f},"
                f"{r.makespan:.1f},{r.edp:.6e},{r.mean_wait:.1f}"
            )
        eco, fifo = res["ecosched"], res["fifo_max"]
        edp_save = 1.0 - eco.edp / fifo.edp
        if verbose:
            print(
                f"cluster rate={rate:.5f}/s ({n_jobs} jobs): "
                f"eco E={eco.total_energy/1e6:.1f}MJ T={eco.makespan:.0f}s | "
                f"fifo E={fifo.total_energy/1e6:.1f}MJ T={fifo.makespan:.0f}s | "
                f"EDP saving {edp_save*100:.1f}%"
            )
        csv.add(f"cluster_rate_{rate:.5f}", us, f"edp_save={edp_save*100:.1f}%")
    with open(out_path, "w") as f:
        f.write("\n".join(rows) + "\n")
    if verbose:
        print(f"cluster CSV -> {out_path}")


if __name__ == "__main__":
    c = Csv()
    run(c)
    c.emit()

"""Table II — EcoSched's GPU-count choices across platforms vs the paper."""
from __future__ import annotations

import time

from benchmarks.common import Csv, run_system
from repro.core import calibration as C


def run(csv: Csv, verbose: bool = True):
    t0 = time.perf_counter()
    matches = {}
    for system in ("h100", "a100", "v100"):
        res, truth = run_system(system)
        chosen = {rec.job: rec.g for rec in res["ecosched"].records}
        ok = sum(1 for a, t in C.TABLE_II.items() if chosen.get(a) == t[system])
        matches[system] = ok
        if verbose:
            print(f"table2 {system}: {ok}/17 choices match the paper")
            for app in sorted(C.TABLE_II):
                want = C.TABLE_II[app][system]
                got = chosen.get(app)
                flag = "" if got == want else "  <-- MISMATCH"
                print(f"    {app:24s} ours={got} paper={want}{flag}")
    us = (time.perf_counter() - t0) * 1e6
    csv.add(
        "table2_choices", us,
        ";".join(f"{s}:{m}/17" for s, m in matches.items()),
    )
    return matches


if __name__ == "__main__":
    c = Csv()
    run(c)
    c.emit()

"""Beyond-paper deployment: EcoSched on a TPU v5e pod (DESIGN.md §2).

The workload pool is the 10 assigned architectures; each job's scaling
curve across sub-slice sizes comes from its dry-run roofline cell
(RooflinePerfModel — ONE compiled profile per job instead of the paper's
per-count profiling).  Node: 256-chip pod = 16 allocation units of 16
chips, K = 4 host-group isolation domains, sub-slices ICI-contiguous.

Ground truth = roofline scaling × a per-arch perturbation the scheduler
does not see (collective-growth exponent mismatch), so Phase I is
genuinely approximate.
"""
from __future__ import annotations

import time

from benchmarks.common import Csv, load_dryrun
from repro.configs import SHAPES, get_config
from repro.roofline import analysis as RA
from repro.core import (
    EcoSched,
    JobProfile,
    Marble,
    Node,
    ProfiledPerfModel,
    RooflinePerfModel,
    SequentialMax,
    SequentialOptimal,
    simulate,
    summarize,
)
from repro.roofline.hw import TPU_V5E

UNITS = 16  # 16 units x 16 chips = 256-chip pod
CHIPS_PER_UNIT = 16
DOMAINS = 4
COUNTS = (2, 4, 8, 16)  # units -> 32..256 chips
STEPS = {  # steps per job: sized for ~1-3h at 256 chips
    "train_4k": 2000,
    "prefill_32k": 20_000,
    "decode_32k": 500_000,
    "long_500k": 200_000,
}


def build_cells():
    """name -> roofline reference terms from the single-pod dry-run."""
    cells = {}
    for rec in load_dryrun("*__16x16.json"):
        if not rec.get("applicable") or "roofline" not in rec:
            continue
        name = f"{rec['arch']}@{rec['shape']}"
        r = RA.derive_terms(rec, get_config(rec["arch"]), SHAPES[rec["shape"]], TPU_V5E)
        cells[name] = {
            "chips_ref": rec["chips"],
            "t_compute": r["t_compute"],
            "t_memory": r["t_memory"],
            "t_collective": r["t_collective"],
            "steps": STEPS[rec["shape"]],
            "shape": rec["shape"],
            "hbm_ref": rec["hbm_per_device_tpu_model"],
        }
    return cells


def feasible_counts(cell) -> tuple:
    """Sub-slice sizes whose per-chip HBM stays under capacity (state
    shards with the chips: hbm(g) ≈ hbm_ref · chips_ref / chips)."""
    out = []
    for g in COUNTS:
        chips = g * CHIPS_PER_UNIT
        if cell["hbm_ref"] * cell["chips_ref"] / chips <= TPU_V5E.hbm_bytes:
            out.append(g)
    return tuple(out)


def build_truth(cells, pm: RooflinePerfModel):
    """Ground truth: model curves with a hidden per-arch perturbation."""
    truth = {}
    for i, (name, cell) in enumerate(sorted(cells.items())):
        # scheduler assumes alpha_coll=0.3; reality varies by arch
        real = dict(cell)
        real["alpha_coll"] = 0.2 + 0.05 * (i % 5)
        runtime, power = {}, {}
        for g in feasible_counts(cell):
            chips = g * CHIPS_PER_UNIT
            tc, tm, tl = RooflinePerfModel(
                {name: real}, counts=COUNTS, chip=TPU_V5E,
                units_to_chips=CHIPS_PER_UNIT,
            )._terms_at(real, chips)
            step_t = max(tc, tm, tl)
            runtime[g] = step_t * cell["steps"]
            util = tc / step_t
            per_chip = TPU_V5E.power_idle + (TPU_V5E.power_peak - TPU_V5E.power_idle) * (
                0.3 + 0.7 * util
            )
            power[g] = per_chip * chips
        truth[name] = JobProfile(name=name, runtime=runtime, busy_power=power)
    return truth


def run(csv: Csv, verbose: bool = True, workload: str = "train_4k"):
    t0 = time.perf_counter()
    cells = build_cells()
    picked = {n: c for n, c in cells.items() if c["shape"] == workload}
    # add the sub-quadratic long-context serving jobs for diversity
    picked.update({n: c for n, c in cells.items() if c["shape"] == "long_500k"})
    if len(picked) < 4:
        print("bench_tpu_pod: dry-run results not available yet — skipping")
        csv.add("tpu_pod_end2end", 0.0, "skipped_no_dryrun")
        return
    infeasible = {n: c for n, c in picked.items() if not feasible_counts(c)}
    for n in infeasible:
        del picked[n]
    if infeasible and verbose:
        print(f"tpu_pod: {sorted(infeasible)} exceed single-pod HBM at every "
              f"sub-slice size -> scheduled on the multi-pod tier (excluded here)")
    pm = RooflinePerfModel(
        picked, counts=COUNTS, chip=TPU_V5E, units_to_chips=CHIPS_PER_UNIT
    )
    pm.counts_for = {n: feasible_counts(c) for n, c in picked.items()}
    truth = build_truth(picked, pm)
    node = Node(
        units=UNITS, domains=DOMAINS,
        idle_power_per_unit=TPU_V5E.power_idle * CHIPS_PER_UNIT,
    )
    queue = sorted(truth)
    res = {}
    for pol in [
        SequentialMax(truth),
        SequentialOptimal(truth),
        Marble(truth),
        EcoSched(pm, lam=0.35, tau=0.45),
    ]:
        r = simulate(pol, node, truth, queue=queue)
        res[r.policy] = r
    base = res["sequential_optimal_gpu"]
    derived = []
    for n in ("marble", "ecosched"):
        s = summarize(base, res[n])
        if verbose:
            print(
                f"tpu_pod {n:9s} vs seq_opt ({len(queue)} jobs, {UNITS}x{CHIPS_PER_UNIT} chips): "
                f"energy {s['energy_saving']*100:5.1f}%  makespan {s['makespan_improvement']*100:5.1f}%  "
                f"EDP {s['edp_saving']*100:5.1f}%"
            )
        derived.append(f"{n}:e{s['energy_saving']*100:.1f}/m{s['makespan_improvement']*100:.1f}")
    if verbose:
        chosen = {r.job: r.g for r in res["ecosched"].records}
        print("tpu_pod EcoSched sub-slice choices (units of 16 chips):")
        for j, g in sorted(chosen.items()):
            print(f"    {j:34s} {g:2d} units = {g*CHIPS_PER_UNIT} chips")
    us = (time.perf_counter() - t0) * 1e6
    csv.add("tpu_pod_end2end", us, ";".join(derived))


if __name__ == "__main__":
    c = Csv()
    run(c)
    c.emit()

"""Fig. 6 — end-to-end policy comparison on H100 / A100 / V100.

Energy saving, makespan improvement and EDP saving for Marble, EcoSched
and the Oracle, relative to BOTH sequential baselines
(sequential_optimal_gpu and sequential_max_gpu), plus the paper's
reported numbers side by side.
"""
from __future__ import annotations

import time

from benchmarks.common import Csv, run_system
from repro.core import calibration as C
from repro.core import summarize


def run(csv: Csv, verbose: bool = True, with_oracle: bool = True, oracle_budget_s: float = 25.0):
    derived = []
    for system in ("h100", "a100", "v100"):
        t0 = time.perf_counter()
        res, truth = run_system(system, with_oracle=with_oracle, oracle_budget_s=oracle_budget_s)
        for base_name in ("sequential_optimal_gpu", "sequential_max_gpu"):
            base = res[base_name]
            for pol in ("marble", "ecosched", "oracle"):
                if pol not in res:
                    continue
                s = summarize(base, res[pol])
                paper = C.PAPER_HEADLINE.get(system, {}).get(pol.rstrip("~"), {})
                ref = ""
                if base_name == "sequential_optimal_gpu" and paper:
                    ref = (
                        f"  [paper: e={paper.get('energy', float('nan'))*100:.1f}%"
                        f" m={paper.get('makespan', float('nan'))*100 if 'makespan' in paper else float('nan'):.1f}%"
                        f" edp={paper.get('edp', float('nan'))*100 if 'edp' in paper else float('nan'):.1f}%]"
                    )
                if verbose:
                    print(
                        f"fig6 {system:5s} {res[pol].policy:10s} vs {base_name:22s}: "
                        f"energy {s['energy_saving']*100:5.1f}%  "
                        f"makespan {s['makespan_improvement']*100:5.1f}%  "
                        f"EDP {s['edp_saving']*100:5.1f}%{ref}"
                    )
                if base_name == "sequential_optimal_gpu" and pol == "ecosched":
                    derived.append(
                        f"{system}:e{s['energy_saving']*100:.1f}/m{s['makespan_improvement']*100:.1f}/d{s['edp_saving']*100:.1f}"
                    )
        us = (time.perf_counter() - t0) * 1e6
        csv.add(f"fig6_end2end_{system}", us, derived[-1] if derived else "")


if __name__ == "__main__":
    c = Csv()
    run(c)
    c.emit()

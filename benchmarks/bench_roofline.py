"""§Roofline — the three-term table for every dry-run cell.

Reads ``benchmarks/results/dryrun/*.json`` (produced by
``python -m repro.launch.dryrun --all --both-meshes``) and prints, per
(arch × shape × mesh): compute / memory / collective terms in seconds,
the dominant term, MODEL_FLOPS/HLO ratio, HBM fit, and a one-line
bottleneck note.
"""
from __future__ import annotations

import time

from benchmarks.common import Csv, load_dryrun
from repro.configs import SHAPES, get_config
from repro.roofline import analysis as RA
from repro.roofline.hw import TPU_V5E

NOTES = {
    "compute": "compute-bound: raise MXU efficiency (remat policy, fusion)",
    "memory": "HBM-bound: shrink activation traffic (microbatch, dtype, fusion)",
    "collective": "ICI-bound: reshard (reduce-scatter, EP locality, overlap)",
}


def run(csv: Csv, verbose: bool = True, mesh: str = "16x16"):
    t0 = time.perf_counter()
    recs = [r for r in load_dryrun(f"*__{mesh}.json") if not r.get("tag")]
    recs.sort(key=lambda r: (r["arch"], r["shape"]))
    n_ok = n_skip = 0
    dominants = {"compute": 0, "memory": 0, "collective": 0}
    if verbose:
        print(f"roofline table ({mesh} mesh, {len(recs)} cells): terms in ms/step")
        print(f"{'arch':18s} {'shape':12s} {'compute':>9s} {'memory':>9s} {'collectv':>9s} {'dom':>10s} {'MF/HLO':>7s} {'frac':>6s} {'fitsHBM':>7s}")
    for r in recs:
        if not r.get("applicable"):
            n_skip += 1
            if verbose:
                print(f"{r['arch']:18s} {r['shape']:12s} {'SKIP: ' + r['skip_reason']}")
            continue
        n_ok += 1
        t = RA.derive_terms(r, get_config(r["arch"]), SHAPES[r["shape"]], TPU_V5E)
        dominants[t["dominant"]] += 1
        if verbose:
            print(
                f"{r['arch']:18s} {r['shape']:12s} "
                f"{t['t_compute']*1e3:9.2f} {t['t_memory']*1e3:9.2f} {t['t_collective']*1e3:9.2f} "
                f"{t['dominant']:>10s} {t['useful_flops_ratio']:7.2f} {t['roofline_fraction']:6.2f} "
                f"{str(r['fits_hbm']):>7s}"
            )
    if verbose:
        print(f"roofline dominant-term census: {dominants}  ({n_ok} cells, {n_skip} skipped)")
    us = (time.perf_counter() - t0) * 1e6
    csv.add(
        "roofline_table", us,
        f"cells={n_ok};skipped={n_skip};" + ";".join(f"{k}={v}" for k, v in dominants.items()),
    )


if __name__ == "__main__":
    c = Csv()
    run(c)
    c.emit()

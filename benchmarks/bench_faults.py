"""Graceful degradation under node failures (ISSUE 8).

A scheduler's fault story only matters under load: when nodes die
mid-run, a static FIFO-max scheduler (SequentialMax + round-robin,
no elasticity) strands work — jobs pinned to a failing node burn their
retry budget waiting out repairs and are eventually dropped — while the
elastic EcoSched stack (resize + migrate) reroutes both waiting and
killed jobs to live nodes and finishes everything.

This bench replays one bursty heterogeneous stream (H100/A100/V100,
18 jobs) under three calibrated node-failure rates (MTBF 40000 / 15000
/ 6000 s against a ~17-25 ks fault-free makespan, MTTR 1500 s) with the
same seeded ``FaultConfig`` for both schedulers, so the fault process
is identical — only the response differs.

Gates (full mode):

  * faults-off parity — a disabled ``FaultConfig()`` is bit-identical
    to ``faults=None`` for both schedulers (the fault plane is inert
    when off),
  * zero lost jobs for elastic EcoSched at every calibrated rate,
  * static FIFO-max strands at least one job at the harshest rate,
  * elastic EDP <= static EDP on >= 2 of the 3 rates,
  * bounded degradation — the harshest rate costs elastic at most 3x
    fault-free makespan and 6x fault-free EDP (the graceful envelope).

``--smoke`` (CI): the parity check plus one small faulty row asserting
determinism (two runs bit-identical) and zero elastic losses.

Full mode writes ``benchmarks/results/faults.csv`` and returns the
trajectory snapshot committed to ``benchmarks/BENCH_faults.json``.
Runs in seconds on CPU.
"""
from __future__ import annotations

import os
import time

from benchmarks.common import LAM, NOISE, SEED, TAU, RESULTS_DIR, Csv, hetero_specs
from repro.core import (
    Cluster,
    EcoSched,
    ElasticConfig,
    EnergyAwareDispatcher,
    FaultConfig,
    ProfiledPerfModel,
    RoundRobinDispatcher,
    SequentialMax,
    bursty_stream,
)
from repro.core import calibration as C

# node MTBFs calibrated against the stream's fault-free makespan:
# rare -> recurring -> harsh (where static FIFO-max strands work)
MTBF_ROWS = (40000.0, 15000.0, 6000.0)
MTTR_S = 1500.0
FAULT_SEED = 2

ELASTIC = ElasticConfig(
    resize=True,
    migrate=True,
    ckpt_time=30.0,
    restart_time=15.0,
    migration_delay=10.0,
    min_gain_s=120.0,
    max_preempts=2,
    switch_cost=0.05,
)


def _stream(n: int = 18, seed: int = 7):
    return bursty_stream(C.APP_ORDER, rate=1 / 900, n=n, burst=4, seed=seed)


def static_cluster() -> Cluster:
    return Cluster(
        hetero_specs(),
        truth_for=lambda s: C.build_system(s.chip.name),
        policy_for=lambda s, t: SequentialMax(t),
        dispatcher=RoundRobinDispatcher(),
        label="static-fifo-max",
    )


def elastic_cluster() -> Cluster:
    return Cluster(
        hetero_specs(),
        truth_for=lambda s: C.build_system(s.chip.name),
        policy_for=lambda s, t: EcoSched(
            ProfiledPerfModel(t, noise=NOISE, seed=SEED), lam=LAM, tau=TAU
        ),
        dispatcher=EnergyAwareDispatcher(),
        slowdown_for=lambda s: C.cross_numa_slowdown,
        label="elastic-eco",
    )


def _fingerprint(res):
    return [
        (r.job, r.node, r.g, r.kind, r.start, r.end) for r in res.records
    ]


def _assert_parity(stream) -> None:
    """A disabled FaultConfig must ride the identical code path."""
    off = FaultConfig()
    assert not off.enabled
    for make, elastic in (
        (static_cluster, None),
        (elastic_cluster, ELASTIC),
    ):
        base = make().simulate(stream, elastic=elastic)
        gated = make().simulate(stream, elastic=elastic, faults=off)
        assert _fingerprint(base) == _fingerprint(gated), (
            f"{base.policy}: disabled faults must be bit-identical to none"
        )
        assert base.total_energy == gated.total_energy


def run(csv: Csv, verbose: bool = True, smoke: bool = False):
    if smoke:
        return _smoke(csv, verbose)
    os.makedirs(RESULTS_DIR, exist_ok=True)
    stream = _stream()
    _assert_parity(stream)
    s_free = static_cluster().simulate(stream)
    e_free = elastic_cluster().simulate(stream, elastic=ELASTIC)
    rows = [
        "node_mtbf_s,policy,total_energy_J,makespan_s,edp_Js,node_failures,"
        "fault_kills,fault_retries,migrations,lost_jobs"
    ]
    for r, tag in ((s_free, "inf"), (e_free, "inf")):
        rows.append(
            f"{tag},{r.policy},{r.total_energy:.1f},{r.makespan:.1f},"
            f"{r.edp:.6e},0,0,0,{r.migrations},0"
        )
    snapshot = {"rows": []}
    edp_wins = 0
    for mtbf in MTBF_ROWS:
        fc = FaultConfig(
            seed=FAULT_SEED, node_mtbf_s=mtbf, node_mttr_s=MTTR_S
        )
        t0 = time.perf_counter()
        s = static_cluster().simulate(stream, faults=fc)
        e = elastic_cluster().simulate(stream, elastic=ELASTIC, faults=fc)
        us = (time.perf_counter() - t0) * 1e6
        for r in (s, e):
            rows.append(
                f"{mtbf:.0f},{r.policy},{r.total_energy:.1f},{r.makespan:.1f},"
                f"{r.edp:.6e},{r.node_failures},{r.fault_kills},"
                f"{r.fault_retries},{r.migrations},{len(r.lost_jobs)}"
            )
        win = e.edp <= s.edp
        edp_wins += win
        snapshot["rows"].append(
            {
                "node_mtbf_s": mtbf,
                "static_edp": s.edp,
                "static_makespan_s": s.makespan,
                "static_lost": len(s.lost_jobs),
                "elastic_edp": e.edp,
                "elastic_makespan_s": e.makespan,
                "elastic_lost": len(e.lost_jobs),
                "node_failures": e.node_failures,
                "migrations": e.migrations,
                "edp_win": bool(win),
            }
        )
        if verbose:
            print(
                f"faults mtbf={mtbf:.0f}s: "
                f"static T={s.makespan:.0f}s EDP={s.edp:.3e} "
                f"lost={len(s.lost_jobs)} | "
                f"elastic T={e.makespan:.0f}s EDP={e.edp:.3e} "
                f"lost={len(e.lost_jobs)} "
                f"(nf={e.node_failures} mig={e.migrations}) | "
                f"{'WIN' if win else 'no win'}"
            )
        csv.add(
            f"faults_mtbf_{mtbf:.0f}", us,
            f"elastic_lost={len(e.lost_jobs)};static_lost={len(s.lost_jobs)}",
        )
        # graceful-degradation gates
        assert not e.lost_jobs, (
            f"elastic EcoSched lost jobs at mtbf={mtbf}: {e.lost_jobs}"
        )
        if mtbf == min(MTBF_ROWS):
            assert s.lost_jobs, (
                "calibration drift: static FIFO-max no longer strands work "
                f"at mtbf={mtbf}"
            )
            assert e.makespan <= 3.0 * e_free.makespan, (
                f"unbounded makespan degradation: {e.makespan:.0f}s vs "
                f"{e_free.makespan:.0f}s fault-free"
            )
            assert e.edp <= 6.0 * e_free.edp, (
                f"unbounded EDP degradation: {e.edp:.3e} vs "
                f"{e_free.edp:.3e} fault-free"
            )
    assert edp_wins >= 2, (
        f"elastic EcoSched must match-or-beat static EDP on >=2/3 fault "
        f"rates, got {edp_wins}"
    )
    out_path = os.path.join(RESULTS_DIR, "faults.csv")
    with open(out_path, "w") as f:
        f.write("\n".join(rows) + "\n")
    if verbose:
        print(f"faults CSV -> {out_path}")
    return snapshot


def write_json(path: str, snapshot: dict) -> None:
    """Committed fault-trajectory snapshot (run.py, full runs only)."""
    import json

    with open(path, "w") as f:
        json.dump(snapshot, f, indent=2, sort_keys=True)
        f.write("\n")


def _smoke(csv: Csv, verbose: bool) -> int:
    """CI tripwire: faults-off parity + one deterministic faulty row."""
    stream = _stream(n=10, seed=13)
    t0 = time.perf_counter()
    _assert_parity(stream)
    fc = FaultConfig(seed=0, node_mtbf_s=8000.0, node_mttr_s=MTTR_S)
    a = elastic_cluster().simulate(stream, elastic=ELASTIC, faults=fc)
    b = elastic_cluster().simulate(stream, elastic=ELASTIC, faults=fc)
    assert _fingerprint(a) == _fingerprint(b), (
        "seeded fault trace must be deterministic"
    )
    assert a.node_failures >= 1, "the smoke row must actually inject faults"
    assert not a.lost_jobs, f"elastic EcoSched lost jobs: {a.lost_jobs}"
    us = (time.perf_counter() - t0) * 1e6
    if verbose:
        print(
            f"faults --smoke: parity OK, {a.node_failures} failures, "
            f"{a.migrations} migrations, 0 lost"
        )
    csv.add("faults_smoke", us, "parity+deterministic+0 lost OK")
    return 0


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    c = Csv()
    run(c, smoke=args.smoke)
    c.emit()

"""Scheduler-daemon end-to-end smoke + control-plane overhead (ISSUE 6).

Boots the real daemon (``python -m repro.cli daemon``) on the simulation
backend, drives it over the unix socket exactly the way a user would
(submit / cancel / advance), then SIGKILLs it mid-workload, reboots it on
the same journal, drains, and asserts the recovered schedule is
**bit-identical** to an uninterrupted in-process run of the same ops —
the ISSUE 6 durability contract, exercised through every layer (CLI
wiring, socket protocol, journal, replay) rather than in-process only
(tests/test_service.py covers that).

``--smoke`` (CI) runs the small fixed workload above.  Full mode adds a
bursty 32-job workload measuring per-request round-trip latency and
journal-replay time — the control plane's overhead budget: a scheduler
tick is microseconds, so the daemon wrapper must stay in the tens of
microseconds per RPC.
"""
from __future__ import annotations

import os
import signal
import subprocess
import sys
import tempfile
import time

from benchmarks.common import Csv
from repro.core.arrivals import bursty_stream
from repro.core.service import SchedulerService, request

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")

# the fixed smoke workload: every record kind, a same-instant pair, a
# cancel, a bounded advance, a post-advance straggler.  The SIGKILL lands
# after KILL_AFTER ops; the reboot re-applies the rest (idempotent).
SMOKE_OPS = [
    {"op": "submit", "name": "s0", "app": "bert", "t": 10.0},
    {"op": "submit", "name": "s1", "app": "lbm", "t": 10.0},
    {"op": "submit", "name": "s2", "app": "resnet50", "t": 45.0},
    {"op": "cancel", "name": "s2"},
    {"op": "advance", "until": 400.0},
    {"op": "submit", "name": "s3", "app": "gpt2", "t": 900.0},
]
KILL_AFTER = 5  # SIGKILL lands between the advance and the straggler


def _fingerprint(res: dict):
    assert res.get("ok"), res
    return (
        tuple(tuple(r) for r in sorted(res["records"])),
        res["makespan"],
        res["total_energy"],
    )


def _boot(sock: str, jnl: str, preset: str = "hetero") -> subprocess.Popen:
    env = dict(os.environ, PYTHONPATH=SRC)
    proc = subprocess.Popen(
        [
            sys.executable, "-m", "repro.cli", "daemon",
            "--socket", sock, "--journal", jnl, "--preset", preset,
        ],
        env=env, cwd=REPO,
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
    )
    deadline = time.time() + 30.0
    while time.time() < deadline:
        if proc.poll() is not None:
            out = proc.stdout.read().decode()
            raise RuntimeError(f"daemon died on boot:\n{out}")
        try:
            if request(sock, {"op": "ping"}, timeout=5.0).get("pong"):
                return proc
        except (OSError, ValueError):
            time.sleep(0.05)
    proc.kill()
    raise RuntimeError("daemon never answered ping")


def _golden(ops) -> tuple:
    """Uninterrupted in-process run of the same op sequence (the same
    backend factory the daemon preset builds)."""
    from repro.cli import make_backend_factory

    svc = SchedulerService(make_backend_factory("hetero"))
    for req in ops:
        resp = svc.handle(req)
        assert "error" not in resp, resp
    svc.advance(None)
    return _fingerprint(svc.result())


def _kill_restart_cycle(ops, kill_after: int, verbose: bool):
    """Drive ``ops`` through a live daemon with a SIGKILL after
    ``kill_after`` ops, reboot on the same journal, re-apply the rest,
    drain; returns (fingerprint, per-RPC latencies, replay seconds)."""
    tmp = tempfile.mkdtemp(prefix="ecosvc-")
    sock, jnl = os.path.join(tmp, "d.sock"), os.path.join(tmp, "d.jnl")
    lat = []
    proc = _boot(sock, jnl)
    try:
        for req in ops[:kill_after]:
            t0 = time.perf_counter()
            resp = request(sock, req)
            lat.append(time.perf_counter() - t0)
            assert resp.get("ok") or "reason" in resp, resp
        os.kill(proc.pid, signal.SIGKILL)
        proc.wait(timeout=10)
        if verbose:
            print(
                f"service: SIGKILLed daemon after {kill_after} ops "
                f"(journal {os.path.getsize(jnl)} bytes), rebooting"
            )
        t0 = time.perf_counter()
        proc = _boot(sock, jnl)  # recovery = journal replay
        replay_s = time.perf_counter() - t0
        for req in ops[kill_after:]:
            t0 = time.perf_counter()
            resp = request(sock, req)
            lat.append(time.perf_counter() - t0)
            assert resp.get("ok") or "reason" in resp, resp
        assert request(sock, {"op": "drain"})["ok"]
        stats = request(sock, {"op": "stats"})
        assert stats["replay_divergences"] == 0, stats
        fp = _fingerprint(request(sock, {"op": "result"}))
        assert request(sock, {"op": "shutdown"})["ok"]
        proc.wait(timeout=10)
    finally:
        if proc.poll() is None:
            proc.kill()
    return fp, lat, replay_s


def run(csv: Csv, verbose: bool = True, smoke: bool = False):
    golden = _golden(SMOKE_OPS)
    fp, lat, replay_s = _kill_restart_cycle(SMOKE_OPS, KILL_AFTER, verbose)
    assert fp == golden, (
        "recovered daemon schedule diverged from the uninterrupted run:\n"
        f"  daemon: {fp}\n  golden: {golden}"
    )
    rpc_us = 1e6 * sum(lat) / len(lat)
    if verbose:
        print(
            f"service --smoke: kill+replay bit-identical "
            f"({len(golden[0])} records, makespan {golden[1]:.1f} s), "
            f"replay {replay_s * 1e3:.0f} ms, mean RPC {rpc_us:.0f} us"
        )
    csv.add("service_smoke", rpc_us, "SIGKILL+replay bit-identical")
    if smoke:
        return 0

    # full mode: a bursty 32-job workload through the daemon, killed
    # mid-stream — overhead numbers at a realistic op count
    stream = bursty_stream(
        ("bert", "lbm", "resnet50", "gpt2"), rate=1 / 300, n=32, burst=4,
        seed=3,
    )
    ops = [
        {"op": "submit", "name": a.name, "app": a.app, "t": a.t}
        for a in sorted(stream, key=lambda a: a.t)
    ]
    golden = _golden(ops)
    fp, lat, replay_s = _kill_restart_cycle(ops, len(ops) // 2, verbose)
    assert fp == golden, "bursty daemon run diverged after SIGKILL+replay"
    rpc_us = 1e6 * sum(lat) / len(lat)
    if verbose:
        print(
            f"service full: 32-job bursty kill+replay bit-identical, "
            f"replay {replay_s * 1e3:.0f} ms, mean RPC {rpc_us:.0f} us"
        )
    csv.add("service_rpc", rpc_us, "mean submit RPC round-trip")
    csv.add("service_replay", replay_s * 1e6, "journal replay, 16 ops")
    return 0


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    c = Csv()
    run(c, smoke=args.smoke)
    print("\nname,us_per_call,derived")
    c.emit()

"""Decision-overhead sweep — vectorized engine vs pure-Python reference.

The paper's §V-C argues the online decision must stay lightweight
(< 0.5 ms/event in their C implementation).  Our reference enumeration
(`core.actions`) is pure Python and dominates decision time at pod scale;
the vectorized engine (`core.engine`) batches Eq. (1) scoring and
placement feasibility.  This benchmark sweeps node size M, domains K and
scheduling-window size over seeded synthetic windows and reports the
per-event decision latency of both backends plus the speedup
(ISSUE 2 target: ≥10× at M=16, K=4, window=17).

Every measured case also argmin-parity-checks the two backends — a perf
number from a diverged scorer would be meaningless.

    PYTHONPATH=src python -m benchmarks.bench_decision_overhead [--smoke]
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List, Tuple

import numpy as np

from benchmarks.common import Csv
from repro.core.actions import enumerate_actions
from repro.core.engine import enumerate_scored
from repro.core.perfmodel import _mk_spec
from repro.core.types import NodeView

FULL_SWEEP = [
    (M, K, W)
    for M in (4, 8, 16)
    for K in (2, 4)
    for W in (4, 8, 17)
    if K <= M
]
SMOKE_SWEEP = [(4, 2, 4), (8, 2, 8), (16, 4, 8)]
TARGET = (16, 4, 17)  # the pod-scale acceptance case (full sweep, >=10x)
SMOKE_TARGET = (16, 4, 8)  # largest smoke case; relaxed gate for CI jitter
SMOKE_MIN_SPEEDUP = 3.0  # measured ~16x; trips on real regressions only
SEED = 7
LAM = 0.35


def synth_window(window: int, M: int, seed: int):
    """Seeded synthetic scheduling window: sublinear speedup curves and
    power-law busy power, the same shape the calibrated workload has."""
    rng = np.random.default_rng(seed)
    counts = [g for g in (1, 2, 3, 4, 6, 8, 12, 16) if g <= M]
    specs = []
    for i in range(window):
        t_hat = {g: 100.0 / g ** float(rng.uniform(0.35, 0.95)) for g in counts}
        p_hat = {g: 300.0 * g ** float(rng.uniform(0.6, 0.9)) for g in counts}
        specs.append(_mk_spec(f"job{i}", t_hat, p_hat))
    return specs


def empty_view(M: int, K: int) -> NodeView:
    # an idle node maximizes the feasible action space — the worst case
    return NodeView(
        t=0.0, total_units=M, domains=K, free_units=M,
        running=[], free_map=[True] * M, domain_jobs=[0] * K,
    )


def _best_python(scored):
    scored = sorted(scored, key=lambda kv: (kv[0], -sum(m.g for _, m in kv[1])))
    return scored[0]


def _time(fn, repeats: int) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def measure_case(
    M: int, K: int, W: int, *, repeats: int, beam: int = 64
) -> Dict[str, float]:
    specs = synth_window(W, M, seed=SEED)
    view = empty_view(M, K)
    free = list(view.free_map)

    def run_python():
        return _best_python(
            enumerate_actions(specs, view, list(free), lam=LAM, beam=beam)
        )

    def run_vector():
        batch = enumerate_scored(specs, view, list(free), lam=LAM, beam=beam)
        i = batch.best_index()
        return batch.scores[i], batch.action(i)

    # parity gate: a fast-but-wrong argmin is not a result
    s_py, a_py = run_python()
    s_vec, a_vec = run_vector()
    assert abs(s_py - float(s_vec)) <= 1e-9, (M, K, W, s_py, s_vec)
    assert [(sp.name, m.g) for sp, m in a_py] == [
        (sp.name, m.g) for sp, m in a_vec
    ], (M, K, W)

    t_py = _time(run_python, repeats)
    t_vec = _time(run_vector, repeats)
    n_actions = len(enumerate_scored(specs, view, list(free), lam=LAM, beam=beam))
    return {
        "python_ms": t_py * 1e3,
        "vector_ms": t_vec * 1e3,
        "speedup": t_py / t_vec if t_vec > 0 else float("inf"),
        "actions": n_actions,
    }


def run(csv: Csv, verbose: bool = True, smoke: bool = False) -> Dict[Tuple, Dict]:
    sweep = SMOKE_SWEEP if smoke else FULL_SWEEP
    repeats = 3 if smoke else 7
    results: Dict[Tuple, Dict] = {}
    for M, K, W in sweep:
        r = measure_case(M, K, W, repeats=repeats)
        results[(M, K, W)] = r
        if verbose:
            print(
                f"decision M={M:2d} K={K} window={W:2d}: "
                f"python {r['python_ms']:8.2f} ms  vector {r['vector_ms']:7.2f} ms  "
                f"speedup {r['speedup']:6.1f}x  ({r['actions']} scored actions)"
            )
        csv.add(
            f"decision_M{M}_K{K}_W{W}",
            r["vector_ms"] * 1e3,
            f"python_ms={r['python_ms']:.3f};speedup={r['speedup']:.1f}x",
        )
    if TARGET in results and verbose:
        sp = results[TARGET]["speedup"]
        M, K, W = TARGET
        verdict = "MET" if sp >= 10 else "MISSED"
        print(f"target M={M} K={K} window={W}: {sp:.1f}x (>=10x {verdict})")
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--smoke", action="store_true",
        help="tiny sweep + parity gate only (CI perf tripwire)",
    )
    args = ap.parse_args()
    c = Csv()
    res = run(c, smoke=args.smoke)
    c.emit()
    if args.smoke:
        sp = res[SMOKE_TARGET]["speedup"]
        if sp < SMOKE_MIN_SPEEDUP:
            raise SystemExit(
                f"smoke perf tripwire: {sp:.1f}x < {SMOKE_MIN_SPEEDUP:.0f}x "
                f"at M={SMOKE_TARGET[0]} K={SMOKE_TARGET[1]} W={SMOKE_TARGET[2]}"
            )
    else:
        sp = res[TARGET]["speedup"]
        if sp < 10:
            raise SystemExit(f"pod-scale speedup target missed: {sp:.1f}x < 10x")

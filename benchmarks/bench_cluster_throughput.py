"""Cluster decision-throughput sweep — nodes × arrival rate × window.

The regime of arXiv:2412.17484 / arXiv:2304.06381: an online scheduler at
datacenter scale is judged by how many scheduling events per second it
sustains end-to-end, not by one decision's latency.  This benchmark drives
pod-scale nodes (M=16 units, K=4 domains) behind the energy-aware
dispatcher and measures ``Cluster.simulate`` wall time in three modes:

  * ``pr2``    — the PR 2 baseline: per-event enumeration from scratch
                 (``EcoSched(cache=False)``) + the per-arrival Python
                 status scan (``fast_status=False``),
  * ``cached`` — ISSUE 3: incremental ``DecisionCache`` + vectorized
                 ``ClusterState`` dispatch,
  * ``jax``    — ``cached`` with the Eq. (1) score reduction offloaded to
                 ``kernels/score_reduce`` (ref backend on CPU CI; pallas
                 on TPU).

Phase-I noise is 0, so instances of one application share their mode
structure and repeated decisions hit the cache's name-free keys — the
recurrent regime the cache targets (with noise > 0 only same-window hits
remain).  Every measured case first asserts the cached schedule is
bit-identical to the baseline schedule: a fast-but-diverged cluster run
would be meaningless.

    PYTHONPATH=src python -m benchmarks.bench_cluster_throughput [--smoke]

Acceptance gate (full mode): >= 3x end-to-end speedup at the pod-scale
config (M=16, K=4, 8 nodes) vs the PR 2 baseline.
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from benchmarks.common import RESULTS_DIR, Csv
from repro.core import (
    Cluster,
    EcoSched,
    EnergyAwareDispatcher,
    JobProfile,
    NodeSpec,
    ProfiledPerfModel,
    poisson_stream,
)
from repro.roofline.hw import H100

M, K = 16, 4  # pod-scale node geometry (ISSUE 2/3 target)
N_APPS = 10
COUNTS = (1, 2, 3, 4, 6, 8, 12, 16)
SEED = 3
LAM, TAU = 0.35, 0.45

# (nodes, rate jobs/s, window cap, jobs): sparse -> steady-state backlogs
FULL_SWEEP = [
    (2, 0.05, 4, 200),
    (2, 0.20, 8, 200),
    (8, 0.20, 4, 800),
    (8, 0.20, 8, 800),
]
POD = (8, 0.20, 8, 800)  # the acceptance config: 8 pod-scale nodes
SMOKE_SWEEP = [(2, 0.20, 4, 60)]
MIN_SPEEDUP = 3.0  # full-mode gate vs the PR 2 baseline at POD


def synth_apps(n_apps: int = N_APPS, seed: int = SEED) -> Dict[str, JobProfile]:
    """Seeded app mix with sublinear speedup and power-law busy power —
    the calibrated workload's shape, scaled out to 16-unit modes."""
    rng = np.random.default_rng(seed)
    counts = [g for g in COUNTS if g <= M]
    out = {}
    for i in range(n_apps):
        t1 = float(rng.uniform(60.0, 240.0))
        alpha = float(rng.uniform(0.35, 0.95))
        beta = float(rng.uniform(0.6, 0.9))
        p0 = float(rng.uniform(250.0, 400.0))
        out[f"app{i}"] = JobProfile(
            name=f"app{i}",
            runtime={g: t1 / g ** alpha for g in counts},
            busy_power={g: p0 * g ** beta for g in counts},
        )
    return out


def pod_cluster(
    n_nodes: int, window: int, *, engine: str, cache: bool,
    policies: Optional[List[EcoSched]] = None,
) -> Cluster:
    apps = synth_apps()

    def policy_for(spec, truth):
        pol = EcoSched(
            ProfiledPerfModel(truth, noise=0.0, seed=1),
            lam=LAM, tau=TAU, window=window, engine=engine, cache=cache,
        )
        if policies is not None:
            policies.append(pol)
        return pol

    return Cluster(
        [NodeSpec(f"pod-{i}", H100, units=M, domains=K) for i in range(n_nodes)],
        truth_for=lambda spec: apps,
        policy_for=policy_for,
        dispatcher=EnergyAwareDispatcher(),
        label=f"eco+ecosched[{engine}]",
    )


def _stream(rate: float, n_jobs: int):
    return poisson_stream([f"app{i}" for i in range(N_APPS)],
                          rate=rate, n=n_jobs, seed=SEED)


def _run_once(n_nodes, rate, window, n_jobs, *, engine, cache, fast_status):
    stream = _stream(rate, n_jobs)
    policies: List[EcoSched] = []
    cl = pod_cluster(n_nodes, window, engine=engine, cache=cache,
                     policies=policies)
    t0 = time.perf_counter()
    res = cl.simulate(stream, fast_status=fast_status)
    elapsed = time.perf_counter() - t0
    stats = [p.cache_stats() for p in policies if p.cache_stats()]
    agg = {}
    for layer in ("decision", "table", "oracle"):
        h = sum(s[f"{layer}_hits"] for s in stats)
        if layer == "decision":  # launch-memo hits serve events too
            h += sum(s["launch_hits"] for s in stats)
        m = sum(s[f"{layer}_misses"] for s in stats)
        agg[f"{layer}_hit_rate"] = h / (h + m) if h + m else 0.0
    return res, elapsed, agg


def _schedule_of(res) -> List[Tuple]:
    return [(r.job, r.node, r.g, r.start) for r in res.records]


def measure_case(
    n_nodes: int, rate: float, window: int, n_jobs: int,
    *, repeats: int = 3, with_jax: bool = True,
) -> Dict[str, float]:
    modes = {
        "pr2": dict(engine="vector", cache=False, fast_status=False),
        "cached": dict(engine="vector", cache=True, fast_status=True),
    }
    if with_jax:
        modes["jax"] = dict(engine="jax", cache=True, fast_status=True)
    out: Dict[str, float] = {"nodes": n_nodes, "rate": rate,
                             "window": window, "jobs": n_jobs}
    schedules = {}
    # interleave the repeats so a noisy-neighbor slowdown hits every mode
    # equally instead of biasing whichever ran during the bad window
    best: Dict[str, Tuple] = {name: (float("inf"), None, {}) for name in modes}
    for _ in range(repeats):
        for name, kw in modes.items():
            r, elapsed, a = _run_once(n_nodes, rate, window, n_jobs, **kw)
            if elapsed < best[name][0]:
                best[name] = (elapsed, r, a)
    for name in modes:
        t_best, res, agg = best[name]
        schedules[name] = _schedule_of(res)
        out[f"{name}_s"] = t_best
        out[f"{name}_events_per_s"] = res.decision_events / t_best
        out[f"{name}_decision_ms"] = (
            1e3 * res.decision_time_s / res.decision_events
        )
        if name != "pr2":
            out[f"{name}_hit_rate"] = agg["decision_hit_rate"]
            out[f"{name}_oracle_hit_rate"] = agg["oracle_hit_rate"]
            out[f"{name}_energy_J"] = res.total_energy
    # parity gate: under the same load formula, the decision cache must not
    # change the schedule, bit for bit (deterministic — hard assert)
    r_pure, _, _ = _run_once(
        n_nodes, rate, window, n_jobs,
        engine="vector", cache=False, fast_status=True,
    )
    assert schedules["cached"] == _schedule_of(r_pure), (
        "decision cache changed the schedule"
    )
    # the PR 2 status scan aggregates outstanding work in a different float
    # association; ClusterState snaps drained accumulators to exact zero so
    # routing ties agree in practice, but a last-ulp flip on a genuinely
    # tied pair is possible — report it rather than flake the gate
    out["pr2_schedule_identical"] = schedules["cached"] == schedules["pr2"]
    if not out["pr2_schedule_identical"]:
        print(
            f"  note: nodes={n_nodes} rate={rate} window={window}: PR 2 "
            "status-scan run routed a float-ulp tie differently"
        )
    out["speedup"] = out["pr2_s"] / out["cached_s"]
    if with_jax:
        out["jax_speedup"] = out["pr2_s"] / out["jax_s"]
    return out


def run(csv: Csv, verbose: bool = True, smoke: bool = False,
        with_jax: Optional[bool] = None) -> Dict[Tuple, Dict]:
    sweep = SMOKE_SWEEP if smoke else FULL_SWEEP
    if with_jax is None:
        with_jax = not smoke  # jit warmup noise has no place in CI smoke
    results: Dict[Tuple, Dict] = {}
    for n_nodes, rate, window, n_jobs in sweep:
        r = measure_case(n_nodes, rate, window, n_jobs,
                         repeats=2 if smoke else 3, with_jax=with_jax)
        results[(n_nodes, rate, window)] = r
        if verbose:
            jax_part = (
                f"  jax {r['jax_events_per_s']:7.0f} ev/s" if with_jax else ""
            )
            print(
                f"throughput nodes={n_nodes} rate={rate:.2f}/s window={window}: "
                f"pr2 {r['pr2_events_per_s']:7.0f} ev/s  "
                f"cached {r['cached_events_per_s']:7.0f} ev/s "
                f"({r['speedup']:4.1f}x, hit {r['cached_hit_rate']*100:4.1f}%)"
                f"{jax_part}"
            )
        csv.add(
            f"cluster_throughput_n{n_nodes}_r{rate:.2f}_w{window}",
            1e6 / r["cached_events_per_s"],
            f"speedup={r['speedup']:.1f}x;hit={r['cached_hit_rate']*100:.0f}%",
        )
    pod_key = POD[:3]
    if pod_key in results and verbose:
        sp = results[pod_key]["speedup"]
        verdict = "MET" if sp >= MIN_SPEEDUP else "MISSED"
        print(f"pod-scale target (M={M} K={K} nodes={POD[0]}): "
              f"{sp:.1f}x (>= {MIN_SPEEDUP:.0f}x {verdict})")
    return results


def write_json(path: str, decision: Dict, throughput: Dict) -> None:
    """Baseline perf snapshot (BENCH_decision.json) — the tracked trajectory
    starts here; future PRs diff against these numbers."""

    def tidy(d):
        return {
            "_".join(str(p) for p in k) if isinstance(k, tuple) else k: v
            for k, v in d.items()
        }

    payload = {
        "schema": "bench_decision/v1",
        "pod_config": {"M": M, "K": K, "nodes": POD[0], "rate": POD[1],
                       "window": POD[2], "jobs": POD[3]},
        "decision_overhead": tidy(decision),
        "cluster_throughput": tidy(throughput),
    }
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--smoke", action="store_true",
        help="tiny sweep + cache parity gate only (CI tripwire)",
    )
    ap.add_argument(
        "--json", metavar="PATH",
        help="also write a BENCH_decision.json baseline snapshot "
             "(runs the decision-overhead smoke sweep for the other half)",
    )
    args = ap.parse_args()
    c = Csv()
    res = run(c, smoke=args.smoke)
    c.emit()
    if args.json:
        from benchmarks import bench_decision_overhead

        dec = bench_decision_overhead.run(Csv(), verbose=False, smoke=args.smoke)
        write_json(args.json, dec, res)
        print(f"baseline JSON -> {args.json}")
    if not args.smoke:
        sp = res[POD[:3]]["speedup"]
        if sp < MIN_SPEEDUP:
            raise SystemExit(
                f"pod-scale throughput target missed: {sp:.1f}x < "
                f"{MIN_SPEEDUP:.0f}x vs the PR 2 baseline"
            )

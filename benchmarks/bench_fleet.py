"""Fleet scale-out sweep — hierarchical dispatch at 64/256/1024 nodes.

ISSUE 9's regime: one region-scale fleet of rack-homogeneous pods (16
nodes per pod, chips cycling H100/A100/V100 across pods) under bursty
arrivals heavy enough to keep per-node queues nonempty.  Each case runs
the same stream through

  * ``flat`` — ``EnergyAwareDispatcher`` scanning every node per arrival
    (the PR 3 reference path, kept as the parity oracle), and
  * ``hier`` — ``HierarchicalDispatcher(EnergyAwareDispatcher())``
    pruning region -> pod -> node via the ``FleetIndex`` pod summary
    tables (admissible bounds, so pruning is exact).

and hard-asserts the two schedules are bit-identical before reporting
events/s (events = routing decisions + per-job launch/complete pairs).
The workload mixes elastic apps with rigid {8}- and {1,2}-mode apps so
the fragmentation gauge (``ClusterResult.fragmentation``, Lettich-style
unusable-GPU fraction over the pending mix) has signal; its rollup is
reported per case.

Full mode also runs a cross-node batched-kernel parity case: a jax-engine
fleet where same-instant bursts are scored in one ``score_reduce_batch``
launch (``stage_served > 0`` asserted) against the staging-disabled solo
path — schedules must match bitwise.

ISSUE 10 adds the COMPLETE-path sweep: an anchor+grow elastic workload
(short rigid 4-unit anchors whose completions free half a node next to a
long strong-scaling {4,8} job — every anchor completion is a resize
opportunity, and burst arrivals align those completions into same-instant
COMPLETE bursts across nodes) run on a jax-engine fleet twice:

  * ``batched`` — the full fast COMPLETE path: one
    ``score_reduce_multi`` launch per resize table, cross-node
    COMPLETE-burst staging, and a fleet-shared ``DecisionCache``,
  * ``solo``    — the pre-batching reference exactly as it shipped:
    ``resize_batch=False`` (one kernel launch per running job per
    completion), the ``prepare_complete`` hook detached, and private
    per-node caches.

Schedules must match bit for bit (records, energy); the batched leg
must beat the solo leg by ``MIN_ELASTIC_SPEEDUP`` in events/s at the
gate scale.  Per-phase decision-time breakdowns
(dispatch/launch/resize/migrate/stage) are reported for both legs.

    PYTHONPATH=src python -m benchmarks.bench_fleet [--smoke]

Acceptance gates (full mode): >= 10k events/s at 256 nodes on the best
dispatcher with flat/hier schedule parity at every scale, and >= 2x
batched-vs-solo events/s on the elastic-on case at 256 nodes with
batched/solo schedule parity at every elastic scale.
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from benchmarks.common import Csv
from repro.core import (
    Cluster,
    DecisionCache,
    EcoSched,
    ElasticConfig,
    EnergyAwareDispatcher,
    HierarchicalDispatcher,
    JobProfile,
    NodeSpec,
    ProfiledPerfModel,
    bursty_stream,
)
from repro.roofline.hw import A100, H100, V100

M, K = 8, 2  # per-node geometry: 8 units, 2 NUMA domains
N_APPS = 8
APP_SEED = 3
STREAM_SEED = 7
POD_SIZE = 16
PODS_PER_REGION = 8
LAM, TAU = 0.35, 0.45
CHIP_CYCLE = [H100, A100, V100]  # rack-homogeneous: one chip per pod
# relative service speed per chip — older racks run the same app slower
# (and at worse unit-energy), so pod lower bounds actually discriminate
CHIP_SLOW = {"h100": 1.0, "a100": 1.6, "v100": 2.6}

# (nodes, rate jobs/s, jobs): load scales with fleet size so queues stay
# bursty-nonempty — the regime where dispatch cost dominates
FULL_SWEEP = [
    (64, 1.2, 512),
    (256, 4.8, 2048),
    (1024, 19.2, 4096),
]
SMOKE_SWEEP = [(40, 1.2, 160)]  # 2.5 pods: exercises ragged geometry
GATE_NODES = 256
MIN_EVENTS_PER_S = 10_000.0  # full-mode gate at GATE_NODES

# COMPLETE-path sweep (ISSUE 10): rate scales with fleet size like the
# arrival sweep, but slower apps (hours, not minutes) so mid-flight
# resizes clear the min-gain guard
ELASTIC_APP_SEED = 5
ELASTIC_SWEEP = [(64, 0.6, 512), (256, 2.4, 2048)]
ELASTIC_SMOKE = [(40, 0.6, 160)]
MIN_ELASTIC_SPEEDUP = 2.0  # batched vs pre-PR per-job events/s at gate


def synth_apps(chip, n_apps: int = N_APPS, seed: int = APP_SEED) -> Dict[str, JobProfile]:
    """Seeded app mix with three mode families: elastic {2,4,8}, rigid
    {8}, and small {1,2}.  Rigid apps strand sub-8 free levels behind
    small-app launches — that is what the fragmentation gauge measures."""
    s = CHIP_SLOW[chip.name]
    rng = np.random.default_rng(seed)
    out = {}
    for i in range(n_apps):
        counts = (1, 2) if i % 3 == 0 else ((8,) if i % 3 == 1 else (2, 4, 8))
        t1 = float(rng.uniform(60.0, 240.0))
        alpha = float(rng.uniform(0.35, 0.95))
        beta = float(rng.uniform(0.6, 0.9))
        p0 = float(rng.uniform(250.0, 400.0))
        out[f"app{i}"] = JobProfile(
            name=f"app{i}",
            runtime={g: s * t1 / g ** alpha for g in counts},
            busy_power={g: (p0 / s ** 0.5) * g ** beta for g in counts},
        )
    return out


TRUTH = {chip.name: synth_apps(chip) for chip in CHIP_CYCLE}


def fleet(n_nodes: int, dispatcher) -> Cluster:
    def policy_for(spec, truth):
        return EcoSched(
            ProfiledPerfModel(truth, noise=0.0, seed=1),
            lam=LAM, tau=TAU, window=8, engine="vector", cache=True,
        )

    return Cluster(
        [
            NodeSpec(
                f"n{i:04d}",
                CHIP_CYCLE[(i // POD_SIZE) % len(CHIP_CYCLE)],
                units=M,
                domains=K,
            )
            for i in range(n_nodes)
        ],
        truth_for=lambda spec: TRUTH[spec.chip.name],
        policy_for=policy_for,
        dispatcher=dispatcher,
    )


def _stream(rate: float, n_jobs: int):
    return bursty_stream(
        [f"app{i}" for i in range(N_APPS)],
        rate=rate, n=n_jobs, seed=STREAM_SEED, burst=16,
    )


def _dispatchers() -> Dict[str, object]:
    return {
        "flat": EnergyAwareDispatcher(),
        "hier": HierarchicalDispatcher(
            EnergyAwareDispatcher(),
            pod_size=POD_SIZE,
            pods_per_region=PODS_PER_REGION,
        ),
    }


def _schedule_of(res) -> List[Tuple]:
    return [(r.job, r.node, r.g, r.start) for r in res.records]


def measure_case(
    n_nodes: int, rate: float, n_jobs: int, *, repeats: int = 2
) -> Dict[str, float]:
    out: Dict[str, float] = {"nodes": n_nodes, "rate": rate, "jobs": n_jobs}
    schedules = {}
    # interleave the repeats so a noisy-neighbor slowdown hits both
    # dispatchers equally instead of biasing whichever ran during it
    best: Dict[str, Tuple] = {
        name: (float("inf"), None) for name in _dispatchers()
    }
    for _ in range(repeats):
        for name, disp in _dispatchers().items():
            stream = _stream(rate, n_jobs)
            cl = fleet(n_nodes, disp)
            t0 = time.perf_counter()
            res = cl.simulate(stream)
            elapsed = time.perf_counter() - t0
            if elapsed < best[name][0]:
                best[name] = (elapsed, res)
    for name, (t_best, res) in best.items():
        schedules[name] = _schedule_of(res)
        # launches + completions are fleet work too: each job's lifecycle
        # transits the event loop twice beyond its routing decision
        events = res.decision_events + 2 * n_jobs
        out[f"{name}_s"] = t_best
        out[f"{name}_events_per_s"] = events / t_best
        out[f"{name}_energy_J"] = res.total_energy
    out["frag_time_avg"] = best["flat"][1].fragmentation["time_avg"]
    out["frag_peak"] = best["flat"][1].fragmentation["peak"]
    # parity gate: pod/region pruning uses admissible lower bounds, so the
    # hierarchical route must equal the flat scan, bit for bit (hard assert
    # — a fast-but-diverged dispatcher would be meaningless)
    assert schedules["hier"] == schedules["flat"], (
        f"hierarchical dispatch diverged from flat at {n_nodes} nodes"
    )
    out["speedup"] = out["flat_s"] / out["hier_s"]
    return out


def synth_elastic_apps(
    chip, n_apps: int = N_APPS, seed: int = ELASTIC_APP_SEED
) -> Dict[str, JobProfile]:
    """Anchor+grow mix for the COMPLETE-path sweep: even apps are long
    strong-scaling {4,8} jobs worth preempt-resizing to the full node
    mid-flight; odd apps are short rigid 4-unit anchors.  An anchor
    completion frees the other half of a node hosting a grow job — every
    such completion is a resize opportunity, and burst arrivals align
    anchor completions into same-instant COMPLETE bursts across nodes."""
    s = CHIP_SLOW[chip.name]
    rng = np.random.default_rng(seed)
    out = {}
    for i in range(n_apps):
        if i % 2 == 0:  # grow app: near-linear scaling, cheap extra units
            counts = (4, 8)
            t1 = float(rng.uniform(3600.0, 10800.0))
            alpha = float(rng.uniform(0.42, 0.52))
            beta = alpha - float(rng.uniform(0.10, 0.20))
            p0 = float(rng.uniform(250.0, 400.0))
            rt = {g: s * t1 / g ** alpha for g in counts}
            bp = {g: (p0 / s ** 0.5) * g ** beta for g in counts}
        else:  # anchor app: short, rigid half-node filler
            t4 = float(rng.uniform(600.0, 1800.0))
            p0 = float(rng.uniform(250.0, 400.0))
            rt = {4: s * t4}
            bp = {4: (p0 / s ** 0.5) * 4 ** 0.7}
        out[f"app{i}"] = JobProfile(name=f"app{i}", runtime=rt, busy_power=bp)
    return out


ELASTIC_TRUTH = {chip.name: synth_elastic_apps(chip) for chip in CHIP_CYCLE}


def elastic_fleet(
    n_nodes: int,
    dispatcher,
    *,
    resize_batch: bool = True,
    shared_cache: bool = True,
    launch_share: bool = True,
) -> Cluster:
    """jax-engine fleet over the anchor+grow mix — the engine whose
    per-job resize loop pays one kernel launch per candidate, i.e. the
    path the batched plane exists to collapse.  By default all policies
    pool one ``DecisionCache``: keys are name-free, so
    identically-shaped nodes serve each other's first-sight
    enumerations (a private cache never warms when each node only hosts
    a handful of jobs).  ``shared_cache=False`` reverts to private
    per-node caches and ``launch_share=False`` disables the tie-frontier
    launch memo — together the pre-PR configuration the solo leg
    measures."""
    cache = DecisionCache() if shared_cache else True

    def policy_for(spec, truth):
        return EcoSched(
            ProfiledPerfModel(truth, noise=0.0, seed=1),
            lam=LAM, tau=TAU, window=8, engine="jax", cache=cache,
            resize_batch=resize_batch, launch_share=launch_share,
        )

    return Cluster(
        [
            NodeSpec(
                f"n{i:04d}",
                CHIP_CYCLE[(i // POD_SIZE) % len(CHIP_CYCLE)],
                units=M,
                domains=K,
            )
            for i in range(n_nodes)
        ],
        truth_for=lambda spec: ELASTIC_TRUTH[spec.chip.name],
        policy_for=policy_for,
        dispatcher=dispatcher,
    )


def _elastic_schedule_of(res) -> List[Tuple]:
    return [
        (r.job, r.node, r.g, r.f, r.start, r.end, r.kind, r.segment)
        for r in res.records
    ]


def _run_elastic(
    n_nodes: int,
    rate: float,
    n_jobs: int,
    *,
    resize_batch: bool,
    staged: bool,
    shared_cache: bool,
    launch_share: bool = True,
):
    """One elastic leg; returns (result, elapsed_s, resize_stage_served)."""
    from repro.core.events import EVT_ARRIVAL

    arrivals = sorted(_stream(rate, n_jobs), key=lambda a: a.t)
    cl = elastic_fleet(
        n_nodes,
        _dispatchers()["hier"],
        resize_batch=resize_batch,
        shared_cache=shared_cache,
        launch_share=launch_share,
    )
    run = cl.open_run(
        apps=[f"app{i}" for i in range(N_APPS)],
        jobs=[(a.name, a.app) for a in arrivals],
        elastic=ElasticConfig(resize=True, resize_before_backfill=True),
    )
    if not staged:
        run.loop.prepare_complete = None
    t0 = time.perf_counter()
    for a in arrivals:
        if a.t <= 0.0:
            run.route(a, 0.0)
        else:
            run.loop.queue.push(a.t, EVT_ARRIVAL, a)
    run.loop.run()
    res = run.finalize()
    elapsed = time.perf_counter() - t0
    served = sum(
        getattr(s.policy, "resize_stage_served", 0)
        for s in run.sims.values()
    )
    return res, elapsed, served


def elastic_case(
    n_nodes: int, rate: float, n_jobs: int, *, repeats: int = 2
) -> Dict[str, float]:
    """Batched vs per-job COMPLETE path on the same workload: hard
    schedule parity, then the end-to-end events/s speedup.  The solo
    leg is the pre-PR configuration in full (per-job resize loop, no
    COMPLETE staging, private caches, no tie-frontier launch sharing);
    the batched leg is everything this PR's fast path adds.  None of
    those knobs can move a schedule (every key is name-free and each
    decision is a pure function of its key), and the parity asserts
    below re-prove that on this workload."""
    out: Dict[str, float] = {"nodes": n_nodes, "rate": rate, "jobs": n_jobs}
    legs = {
        "batched": dict(resize_batch=True, staged=True, shared_cache=True),
        "solo": dict(
            resize_batch=False, staged=False, shared_cache=False,
            launch_share=False,
        ),
    }
    best = {name: (float("inf"), None, 0) for name in legs}
    for _ in range(repeats):
        for name, kw in legs.items():
            res, elapsed, served = _run_elastic(n_nodes, rate, n_jobs, **kw)
            if elapsed < best[name][0]:
                best[name] = (elapsed, res, served)
    assert _elastic_schedule_of(best["batched"][1]) == _elastic_schedule_of(
        best["solo"][1]
    ), f"batched COMPLETE path diverged from per-job loop at {n_nodes} nodes"
    assert best["batched"][1].total_energy == best["solo"][1].total_energy
    events = best["batched"][1].decision_events + 2 * n_jobs
    for name, (t_best, res, served) in best.items():
        out[f"{name}_s"] = t_best
        out[f"{name}_events_per_s"] = events / t_best
        for k, v in res.decision_phases.items():
            out[f"{name}_phase_{k}_s"] = v
    out["resizes"] = best["batched"][1].resizes
    out["resize_stage_served"] = best["batched"][2]
    # the headline: the fast COMPLETE path (batched resize plane +
    # burst staging + shared cache) vs the pre-PR per-job loop, end to
    # end — phase columns above show where the time moved
    out["speedup"] = out["solo_s"] / out["batched_s"]
    return out


def jax_parity_case(n_jobs: int = 48) -> Dict[str, float]:
    """Cross-node batched scoring vs the solo per-node kernel path: same
    4-node jax-engine fleet, same bursty stream, staging on vs off."""
    from repro.core import calibration as C
    from repro.core.events import EVT_ARRIVAL

    apps = C.build_system("h100")

    def make(policies):
        def policy_for(spec, truth):
            pol = EcoSched(
                ProfiledPerfModel(truth, noise=0.0, seed=1),
                lam=LAM, tau=TAU, engine="jax",
            )
            policies.append(pol)
            return pol

        return Cluster(
            [NodeSpec(f"n{i:03d}", H100, units=8, domains=2) for i in range(4)],
            truth_for=lambda spec: apps,
            policy_for=policy_for,
            dispatcher=EnergyAwareDispatcher(),
        )

    stream = bursty_stream(list(C.APP_ORDER), rate=0.25, n=n_jobs, seed=11, burst=6)
    pols: List[EcoSched] = []
    t0 = time.perf_counter()
    batched = make(pols).simulate(stream)
    t_batched = time.perf_counter() - t0
    served = sum(p.stage_served for p in pols)
    assert served > 0, "no decision was served from the cross-node batch"
    # solo: same fleet with the staging hook detached before the run
    solo_cl = make([])
    arrivals = sorted(stream, key=lambda a: a.t)
    run = solo_cl.open_run(
        apps=sorted({a.app for a in arrivals}),
        jobs=[(a.name, a.app) for a in arrivals],
    )
    run.loop.prepare_batch = None
    t0 = time.perf_counter()
    for a in arrivals:
        if a.t <= 0.0:
            run.route(a, 0.0)
        else:
            run.loop.queue.push(a.t, EVT_ARRIVAL, a)
    run.loop.run()
    solo = run.finalize()
    t_solo = time.perf_counter() - t0
    assert _schedule_of(batched) == _schedule_of(solo), (
        "cross-node batched scoring changed the schedule"
    )
    assert batched.total_energy == solo.total_energy
    return {
        "jobs": n_jobs,
        "stage_served": served,
        "batched_s": t_batched,
        "solo_s": t_solo,
        "schedule_identical": True,
    }


def run(csv: Csv, verbose: bool = True, smoke: bool = False) -> Dict:
    sweep = SMOKE_SWEEP if smoke else FULL_SWEEP
    results: Dict = {"cases": {}}
    for n_nodes, rate, n_jobs in sweep:
        r = measure_case(n_nodes, rate, n_jobs, repeats=1 if smoke else 2)
        results["cases"][n_nodes] = r
        if verbose:
            print(
                f"fleet nodes={n_nodes:4d} rate={rate:5.2f}/s jobs={n_jobs}: "
                f"flat {r['flat_events_per_s']:7.0f} ev/s  "
                f"hier {r['hier_events_per_s']:7.0f} ev/s "
                f"({r['speedup']:4.2f}x)  frag avg {r['frag_time_avg']:.3f} "
                f"peak {r['frag_peak']:.2f}  parity OK"
            )
        csv.add(
            f"fleet_n{n_nodes}",
            1e6 / r["hier_events_per_s"],
            f"speedup={r['speedup']:.2f}x;frag={r['frag_time_avg']:.3f}",
        )
    esweep = ELASTIC_SMOKE if smoke else ELASTIC_SWEEP
    results["elastic"] = {}
    for n_nodes, rate, n_jobs in esweep:
        er = elastic_case(n_nodes, rate, n_jobs, repeats=1 if smoke else 2)
        results["elastic"][n_nodes] = er
        if verbose:
            print(
                f"fleet elastic nodes={n_nodes:4d} rate={rate:5.2f}/s "
                f"jobs={n_jobs}: batched {er['batched_events_per_s']:7.0f} "
                f"ev/s  solo {er['solo_events_per_s']:7.0f} ev/s "
                f"({er['speedup']:4.2f}x)  "
                f"(resizes={er['resizes']}, "
                f"staged={er['resize_stage_served']})  parity OK"
            )
        csv.add(
            f"fleet_elastic_n{n_nodes}",
            1e6 / er["batched_events_per_s"],
            f"speedup={er['speedup']:.2f}x;"
            f"resizes={er['resizes']}",
        )
    if not smoke:
        jp = jax_parity_case()
        results["jax_parity"] = jp
        if verbose:
            print(
                f"fleet jax batch: {jp['stage_served']} decisions served "
                f"from cross-node launches, schedule identical to solo"
            )
    return results


def write_json(path: str, results: Dict) -> None:
    """Fleet-scale perf snapshot (BENCH_fleet.json) — committed trajectory;
    future PRs diff against these numbers."""
    payload = {
        "schema": "bench_fleet/v1",
        "geometry": {
            "M": M,
            "K": K,
            "pod_size": POD_SIZE,
            "pods_per_region": PODS_PER_REGION,
            "chips": [c.name for c in CHIP_CYCLE],
        },
        "gate": {
            "nodes": GATE_NODES,
            "min_events_per_s": MIN_EVENTS_PER_S,
            "min_elastic_speedup": MIN_ELASTIC_SPEEDUP,
        },
        "cases": {str(k): v for k, v in results["cases"].items()},
        "elastic": {
            str(k): v for k, v in results.get("elastic", {}).items()
        },
    }
    if "jax_parity" in results:
        payload["jax_parity"] = results["jax_parity"]
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--smoke", action="store_true",
        help="one small ragged-pod case + parity gate only (CI tripwire)",
    )
    ap.add_argument(
        "--json", metavar="PATH",
        help="also write a BENCH_fleet.json baseline snapshot",
    )
    args = ap.parse_args()
    c = Csv()
    res = run(c, smoke=args.smoke)
    c.emit()
    if args.json:
        write_json(args.json, res)
        print(f"baseline JSON -> {args.json}")
    if not args.smoke:
        gate = res["cases"][GATE_NODES]
        ev = max(gate["flat_events_per_s"], gate["hier_events_per_s"])
        if ev < MIN_EVENTS_PER_S:
            raise SystemExit(
                f"fleet throughput target missed: {ev:.0f} ev/s < "
                f"{MIN_EVENTS_PER_S:.0f} at {GATE_NODES} nodes"
            )
        egate = res["elastic"][GATE_NODES]
        if egate["speedup"] < MIN_ELASTIC_SPEEDUP:
            raise SystemExit(
                f"fast COMPLETE path target missed: "
                f"{egate['speedup']:.2f}x < "
                f"{MIN_ELASTIC_SPEEDUP:.1f}x at {GATE_NODES} nodes"
            )

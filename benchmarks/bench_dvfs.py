"""DVFS as the third decision axis: joint (count, frequency) EcoSched
vs the count-only PR 6 policy (ISSUE 7).

Each calibrated system (the paper's H100/A100/V100 platforms) runs the
single-node golden workload twice under identical hyperparameters
(``LAM/TAU/NOISE/SEED``):

  * ``count_only`` — ``build_system(sys)``: base clock only, the exact
    PR 6 decision space,
  * ``joint``      — ``build_system(sys, freq_levels=<full ladder>)``:
    every app carries per-frequency runtime/power curves from the
    sweet-spot model (``ChipSpec.freq_time_multiplier`` /
    ``freq_power_multiplier``), and EcoSched argmins over the joint
    (count, frequency) candidate set.

The sweet-spot model makes this a real trade, not a free win:
downclocking saves energy everywhere (cubic power in the clock ratio)
but stretches compute-bound apps near-linearly, so EDP only improves
where the mix is memory-bound enough — the paper's central DVFS claim.

Gates (full mode):
  * joint EDP <= count-only EDP on >= 2/3 calibrated systems,
  * joint total energy strictly below count-only on *all* systems,
  * frequency-off parity: an explicit ``freq_levels=1`` H100 run is
    bit-identical to the default build AND still matches the PR 6
    golden schedule fingerprint.

``--smoke`` (CI): the frequency-off parity + golden-fingerprint lock,
plus the deterministic H100 joint EDP win as a regression tripwire.

Writes ``benchmarks/results/dvfs.csv``; ``run.py`` snapshots the rows
into the committed ``benchmarks/BENCH_dvfs.json``.
"""
from __future__ import annotations

import hashlib
import os
import time

from benchmarks.common import LAM, NOISE, SEED, TAU, RESULTS_DIR, Csv
from repro.core import EcoSched, Node, ProfiledPerfModel, simulate
from repro.core import calibration as C
from repro.roofline.hw import CHIPS

SYSTEMS = ("h100", "a100", "v100")

# the PR 6 single-node golden schedule (tests/test_events.py /
# tests/test_dvfs.py) — frequency-off runs must still produce it
GOLDEN_H100_FP = "4e5acdeeb3914722311e6f77658684e6"


def fp_records(records) -> str:
    s = ";".join(
        f"{r.job}|{r.g}|{r.start!r}|{r.end!r}|{r.node}|{r.domain}"
        for r in records
    )
    return hashlib.md5(s.encode()).hexdigest()


def _run(system: str, freq_levels: int | None = None, lam_f: float = 0.0):
    """The golden single-node workload on one calibrated system."""
    truth = (
        C.build_system(system)
        if freq_levels is None
        else C.build_system(system, freq_levels=freq_levels)
    )
    node = Node(4, 2, C.idle_power(system))
    pol = EcoSched(
        ProfiledPerfModel(truth, noise=NOISE, seed=SEED),
        lam=LAM, tau=TAU, lam_f=lam_f,
    )
    return simulate(
        pol,
        node,
        truth,
        arrivals=[(120.0 * i, a) for i, a in enumerate(C.APP_ORDER)],
        slowdown_model=C.cross_numa_slowdown,
    )


def _parity(csv: Csv, verbose: bool) -> None:
    """freq_levels=1 is bit-identical to count-only — the PR 6 lock."""
    t0 = time.perf_counter()
    base = _run("h100")
    one = _run("h100", freq_levels=1)
    assert fp_records(one.records) == fp_records(base.records), (
        "freq_levels=1 must reproduce the count-only schedule bit-identically"
    )
    assert one.total_energy == base.total_energy
    assert all(r.f == 0 for r in one.records)
    assert fp_records(base.records) == GOLDEN_H100_FP, (
        f"count-only H100 schedule drifted from the PR 6 golden lock: "
        f"{fp_records(base.records)}"
    )
    us = (time.perf_counter() - t0) * 1e6
    if verbose:
        print("dvfs parity: freq_levels=1 == count-only == PR 6 golden")
    csv.add("dvfs_parity", us, "freq-off bit-identical to PR 6")


# λ_f sensitivity sweep (ISSUE 9 satellite): how hard the DVFS
# conservatism weight pushes the joint argmin back toward base clock.
# 0.0 is the purely energy-driven default the gates above run at.
LAM_F_VALUES = (0.0, 0.1, 0.3)


def lam_f_sweep(csv: Csv, verbose: bool = True, values=LAM_F_VALUES):
    """EDP/energy deltas vs the ``lam_f=0.0`` joint baseline, per system.

    A positive λ_f penalizes the mean frequency level of an action, so
    rising values monotonically shrink the downclocked-launch count; the
    sweep records what that conservatism costs (or buys) in EDP."""
    rows = []
    for system in SYSTEMS:
        levels = len(CHIPS[system].freq_ratios)
        t0 = time.perf_counter()
        runs = {v: _run(system, freq_levels=levels, lam_f=v) for v in values}
        us = (time.perf_counter() - t0) * 1e6
        base = runs[values[0]]
        for v in values:
            r = runs[v]
            down = int(sum(rec.f > 0 for rec in r.records))
            rows.append(
                {
                    "system": system,
                    "lam_f": v,
                    "edp": r.edp,
                    "edp_delta_pct": 100.0 * (r.edp / base.edp - 1.0),
                    "energy": r.total_energy,
                    "energy_delta_pct": 100.0
                    * (r.total_energy / base.total_energy - 1.0),
                    "downclocked_launches": down,
                }
            )
        if verbose:
            parts = ", ".join(
                f"lam_f={v}: EDP{100 * (runs[v].edp / base.edp - 1):+.2f}% "
                f"down={int(sum(rec.f > 0 for rec in runs[v].records))}"
                for v in values
            )
            print(f"dvfs lam_f sweep {system}: {parts}")
        csv.add(
            f"dvfs_lamf_{system}", us,
            ";".join(
                f"lamf{v}={100 * (runs[v].edp / base.edp - 1):+.2f}%"
                for v in values
            ),
        )
    return rows


def run(csv: Csv, verbose: bool = True, smoke: bool = False):
    if smoke:
        return _smoke(csv, verbose)
    os.makedirs(RESULTS_DIR, exist_ok=True)
    _parity(csv, verbose)
    rows = [
        "system,levels,count_only_edp_Js,joint_edp_Js,"
        "count_only_energy_J,joint_energy_J,count_only_makespan_s,"
        "joint_makespan_s,downclocked_launches,win"
    ]
    snapshot = {"rows": []}
    wins = 0
    for system in SYSTEMS:
        t0 = time.perf_counter()
        levels = len(CHIPS[system].freq_ratios)
        base = _run(system)
        joint = _run(system, freq_levels=levels)
        us = (time.perf_counter() - t0) * 1e6
        down = sum(r.f > 0 for r in joint.records)
        win = joint.edp <= base.edp
        wins += win
        rows.append(
            f"{system},{levels},{base.edp:.6e},{joint.edp:.6e},"
            f"{base.total_energy:.1f},{joint.total_energy:.1f},"
            f"{base.makespan:.1f},{joint.makespan:.1f},{down},{int(win)}"
        )
        snapshot["rows"].append(
            {
                "system": system,
                "levels": levels,
                "count_only_edp": base.edp,
                "joint_edp": joint.edp,
                "count_only_energy": base.total_energy,
                "joint_energy": joint.total_energy,
                "downclocked_launches": int(down),
                "win": bool(win),
            }
        )
        assert joint.total_energy < base.total_energy, (
            f"{system}: joint DVFS must save energy "
            f"({joint.total_energy:.3e} vs {base.total_energy:.3e})"
        )
        if verbose:
            print(
                f"dvfs {system} ({levels} levels): "
                f"count-only EDP={base.edp:.3e} | joint {joint.edp:.3e} "
                f"({100 * (joint.edp / base.edp - 1):+.2f}%), "
                f"energy {100 * (joint.total_energy / base.total_energy - 1):+.1f}%, "
                f"{down} downclocked launches | {'WIN' if win else 'no win'}"
            )
        csv.add(
            f"dvfs_{system}", us,
            f"edp_vs_count_only={100 * (joint.edp / base.edp - 1):+.2f}%",
        )
    out_path = os.path.join(RESULTS_DIR, "dvfs.csv")
    with open(out_path, "w") as f:
        f.write("\n".join(rows) + "\n")
    if verbose:
        print(f"dvfs CSV -> {out_path}")
    assert wins >= 2, (
        f"joint (count, frequency) EcoSched must match or beat count-only "
        f"EDP on >= 2/3 calibrated systems, got {wins}"
    )
    snapshot["lam_f_sweep"] = lam_f_sweep(csv, verbose)
    return snapshot


def write_json(path: str, snapshot: dict) -> None:
    """Committed DVFS-trajectory snapshot (run.py, full runs only)."""
    import json

    with open(path, "w") as f:
        json.dump(snapshot, f, indent=2, sort_keys=True)
        f.write("\n")


def _smoke(csv: Csv, verbose: bool) -> int:
    """CI tripwire: frequency-off parity + the deterministic H100 win."""
    _parity(csv, verbose)
    t0 = time.perf_counter()
    base = _run("h100")
    joint = _run("h100", freq_levels=len(CHIPS["h100"].freq_ratios))
    assert any(r.f > 0 for r in joint.records), (
        "the joint run must actually exercise the frequency axis"
    )
    assert joint.total_energy < base.total_energy
    assert joint.edp <= base.edp, (
        f"H100 joint EDP win regressed: {joint.edp:.3e} vs {base.edp:.3e}"
    )
    us = (time.perf_counter() - t0) * 1e6
    if verbose:
        print(
            f"dvfs --smoke: h100 joint EDP {joint.edp:.3e} vs "
            f"count-only {base.edp:.3e}"
        )
    csv.add("dvfs_smoke", us, "parity+h100 EDP win OK")
    return 0


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument(
        "--lam-f-sweep", action="store_true",
        help="run only the λ_f sensitivity sweep",
    )
    ap.add_argument("--json", help="also write the BENCH_dvfs.json snapshot")
    args = ap.parse_args()
    c = Csv()
    if args.lam_f_sweep:
        lam_f_sweep(c)
        c.emit()
        raise SystemExit(0)
    snap = run(c, smoke=args.smoke)
    if args.json and not args.smoke:
        write_json(args.json, snap)
        print(f"dvfs snapshot -> {args.json}")
    c.emit()

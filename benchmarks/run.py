"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV at the end (scaffold contract).
Individual benchmarks are importable and runnable standalone:
    PYTHONPATH=src python -m benchmarks.bench_fig6_end2end
"""
from __future__ import annotations

import argparse
import os


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="skip the Oracle search")
    ap.add_argument("--quiet", action="store_true")
    args, _ = ap.parse_known_args()

    from benchmarks import (
        bench_cluster,
        bench_cluster_throughput,
        bench_decision_overhead,
        bench_dvfs,
        bench_elastic,
        bench_faults,
        bench_fleet,
        bench_forecast,
        bench_fig1_scaling,
        bench_fig2_tradeoff,
        bench_fig6_end2end,
        bench_fig9_perf_loss,
        bench_overhead,
        bench_roofline,
        bench_sensitivity,
        bench_service,
        bench_table2_choices,
        bench_tpu_pod,
    )
    from benchmarks.common import Csv

    csv = Csv()
    verbose = not args.quiet
    bench_fig1_scaling.run(csv, verbose=verbose)
    bench_fig2_tradeoff.run(csv, verbose=verbose)
    bench_fig6_end2end.run(
        csv, verbose=verbose, with_oracle=not args.quick, oracle_budget_s=20.0
    )
    bench_table2_choices.run(csv, verbose=verbose)
    bench_fig9_perf_loss.run(csv, verbose=verbose)
    bench_overhead.run(csv, verbose=verbose)
    decision = bench_decision_overhead.run(csv, verbose=verbose, smoke=args.quick)
    bench_roofline.run(csv, verbose=verbose)
    bench_tpu_pod.run(csv, verbose=verbose)
    bench_sensitivity.run(csv, verbose=verbose)
    bench_cluster.run(csv, verbose=verbose)
    bench_elastic.run(csv, verbose=verbose, smoke=args.quick)
    faults = bench_faults.run(csv, verbose=verbose, smoke=args.quick)
    forecast = bench_forecast.run(csv, verbose=verbose, smoke=args.quick)
    dvfs = bench_dvfs.run(csv, verbose=verbose, smoke=args.quick)
    throughput = bench_cluster_throughput.run(csv, verbose=verbose, smoke=args.quick)
    fleet = bench_fleet.run(csv, verbose=verbose, smoke=args.quick)
    bench_service.run(csv, verbose=verbose, smoke=args.quick)

    # perf-trajectory snapshots (ISSUE 3/5): decision overhead + throughput,
    # and the forecast-vs-eager EDP rows.  Only full runs refresh the
    # committed baselines (benchmarks/, not the gitignored results/) —
    # smoke numbers are a tripwire, not a trajectory.
    if not args.quick:
        json_path = os.path.join(
            os.path.dirname(__file__), "BENCH_decision.json"
        )
        bench_cluster_throughput.write_json(json_path, decision, throughput)
        forecast_path = os.path.join(
            os.path.dirname(__file__), "BENCH_forecast.json"
        )
        bench_forecast.write_json(forecast_path, forecast)
        dvfs_path = os.path.join(os.path.dirname(__file__), "BENCH_dvfs.json")
        bench_dvfs.write_json(dvfs_path, dvfs)
        faults_path = os.path.join(
            os.path.dirname(__file__), "BENCH_faults.json"
        )
        bench_faults.write_json(faults_path, faults)
        fleet_path = os.path.join(os.path.dirname(__file__), "BENCH_fleet.json")
        bench_fleet.write_json(fleet_path, fleet)
        if verbose:
            print(
                f"perf baselines -> {json_path}, {forecast_path}, "
                f"{dvfs_path}, {faults_path}, {fleet_path}"
            )

    print("\nname,us_per_call,derived")
    csv.emit()


if __name__ == "__main__":
    main()

# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
#
# score_reduce.py — batched Eq. (1) scoring + masked argmin for the
# scheduler's candidate blocks (EcoSched engine="jax"); pallas on TPU,
# interpret/ref fallbacks on CPU, selected like ops.py.

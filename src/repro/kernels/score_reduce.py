"""Batched Eq. (1) score reduction + masked argmin (JAX/Pallas).

The engine's candidate set for one scheduling event is a padded matrix of
per-slot energy deviations, unit counts (``ScoredBatch.padded_cols``) and
DVFS frequency levels (``ScoredBatch.padded_f``).  Scoring it is a row
reduction

    S[b] = Σ_s dev[b, s] / max(n[b], 1) + λ·(G_free − Σ_s g[b, s]) / M
           + λ_f·Σ_s f[b, s] / max(n[b], 1) + bias[b]

followed by a masked argmin under EcoSched's tie-break (lowest score, then
largest total unit count, then earliest row).  At pod scale the candidate
space exceeds 10^5 rows per event — and the joint (count × frequency) mode
set is 4–8× larger still; this module reduces it in one fused kernel
instead of a chain of numpy temporaries.

Backend selection mirrors ``kernels/ops.py``: on TPU the Pallas kernel
runs compiled (Mosaic); everywhere else ``REPRO_KERNELS`` picks
``interpret`` (kernel body op-by-op on CPU — the validation target) or
``ref`` (pure jnp, fast enough for CI; the default off-TPU).  The Pallas
grid tiles rows into blocks; each grid step writes its block's scores and
a per-block (min score, best count, best row) triple, and a tiny jnp
combine selects the global winner across blocks — so the reduction never
materializes on the host.

λ, G_free, M and λ_f ride in an SMEM params row (traced, not static):
sweeping node fill levels or frequency-conservatism weights does not
recompile.  Rows are padded to a power of two
and slots to a multiple of 8, so the jit cache stays small.  Scores are
float32 — parity vs the float64 numpy engine is ≤1e-6 over seeded random
windows (tests/test_score_reduce.py).
"""
from __future__ import annotations

import functools
import os
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

_BLOCK_B = 256  # candidate rows per grid step
_SLOT_PAD = 8  # slot (action-size) axis padded to a multiple of this


def _backend_mode() -> str:
    forced = os.environ.get("REPRO_KERNELS", "")
    if forced:
        return forced  # "pallas" | "interpret" | "ref"
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def _row_scores(dev, g, f, n, bias, mask, lam, g_free, M, lam_f):
    """(B, 1) masked Eq. (1) scores from (B, S)/(B, 1) blocks.  The
    frequency term is λ_f·mean(f); at λ_f = 0 (or an all-zero f plane —
    single-frequency windows) it contributes exactly +0.0, keeping scores
    bit-identical to the count-only kernel."""
    tot = jnp.sum(g, axis=1, keepdims=True)
    n_eff = jnp.maximum(n, 1.0)
    s = (
        jnp.sum(dev, axis=1, keepdims=True) / n_eff
        + lam * (g_free - tot) / M
        + lam_f * jnp.sum(f, axis=1, keepdims=True) / n_eff
        + bias
    )
    return jnp.where(mask > 0, s, jnp.inf), tot


def _pick(scores, tot, idx, idx_cap):
    """Tie-broken argmin: min score, then max total count, then min index.
    Returns (min score, winning count, winning index)."""
    m = jnp.min(scores)
    tie = scores == m
    t_best = jnp.max(jnp.where(tie, tot, -1.0))
    cand = tie & (tot == t_best)
    i = jnp.min(jnp.where(cand, idx, idx_cap))
    return m, t_best, i


def _kernel(params_ref, dev_ref, g_ref, f_ref, n_ref, bias_ref, mask_ref,
            scores_ref, bmin_ref, btot_ref, bidx_ref):
    lam = params_ref[0, 0]
    g_free = params_ref[0, 1]
    M = params_ref[0, 2]
    lam_f = params_ref[0, 3]
    scores, tot = _row_scores(
        dev_ref[:], g_ref[:], f_ref[:], n_ref[:], bias_ref[:], mask_ref[:],
        lam, g_free, M, lam_f,
    )
    scores_ref[:] = scores
    bb = scores.shape[0]
    ridx = jax.lax.broadcasted_iota(jnp.int32, (bb, 1), 0)
    m, t_best, r = _pick(scores, tot, ridx, jnp.int32(bb))
    bmin_ref[0, 0] = m
    btot_ref[0, 0] = t_best
    bidx_ref[0, 0] = pl.program_id(0) * bb + r


def _combine(scores, bmin, btot, bidx, b_pad):
    """Global winner across per-block (min, count, index) triples."""
    mg = jnp.min(bmin)
    tie = bmin == mg
    t_best = jnp.max(jnp.where(tie, btot, -1.0))
    cand = tie & (btot == t_best)
    idx = jnp.min(jnp.where(cand, bidx, jnp.int32(b_pad)))
    best = jnp.where(jnp.isinf(mg), jnp.int32(-1), idx)
    return scores[:, 0], best


def _node_reduce(params, dev, g, f, n, bias, mask):
    """Single-node Eq. (1) reduction in pure jnp.  ``params`` is one (4,)
    [λ, G_free, M, λ_f] row; vmapping this over a leading node axis is the
    batched ref path, so per-node results are the same elementwise ops as
    the solo ref path."""
    b_pad = dev.shape[0]
    scores, tot = _row_scores(
        dev, g, f, n, bias, mask, params[0], params[1], params[2], params[3]
    )
    ridx = jax.lax.broadcasted_iota(jnp.int32, (b_pad, 1), 0)
    m, t_best, i = _pick(scores, tot, ridx, jnp.int32(b_pad))
    best = jnp.where(jnp.isinf(m), jnp.int32(-1), i)
    return scores[:, 0], best


@functools.partial(jax.jit, static_argnames=("mode",))
def _reduce_jit(params, dev, g, f, n, bias, mask, *, mode: str):
    b_pad, s_pad = dev.shape
    if mode == "ref":
        return _node_reduce(params[0], dev, g, f, n, bias, mask)
    nb = b_pad // _BLOCK_B
    col = pl.BlockSpec((_BLOCK_B, 1), lambda i: (i, 0))
    blk = pl.BlockSpec((1, 1), lambda i: (i, 0))
    plane = pl.BlockSpec((_BLOCK_B, s_pad), lambda i: (i, 0))
    scores, bmin, btot, bidx = pl.pallas_call(
        _kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, 4), lambda i: (0, 0), memory_space=pltpu.SMEM),
            plane, plane, plane,
            col, col, col,
        ],
        out_specs=[col, blk, blk, blk],
        out_shape=[
            jax.ShapeDtypeStruct((b_pad, 1), jnp.float32),
            jax.ShapeDtypeStruct((nb, 1), jnp.float32),
            jax.ShapeDtypeStruct((nb, 1), jnp.float32),
            jax.ShapeDtypeStruct((nb, 1), jnp.int32),
        ],
        interpret=(mode == "interpret"),
    )(params, dev, g, f, n, bias, mask)
    return _combine(scores, bmin, btot, bidx, b_pad)


def _pad_rows(a: np.ndarray, b_pad: int) -> np.ndarray:
    out = np.zeros((b_pad,) + a.shape[1:], dtype=a.dtype)
    out[: len(a)] = a
    return out


def score_reduce(
    dev: np.ndarray,
    g: np.ndarray,
    n: np.ndarray,
    *,
    lam: float,
    g_free: int,
    M: int,
    f: Optional[np.ndarray] = None,
    lam_f: float = 0.0,
    bias: Optional[np.ndarray] = None,
    mask: Optional[np.ndarray] = None,
    mode: Optional[str] = None,
) -> Tuple[np.ndarray, int]:
    """Scores + tie-broken argmin for a (B, S) candidate block.

    ``dev``/``g`` are per-slot deviation/count columns (zero-padded past
    each action's size ``n``); ``f`` is the optional per-slot DVFS
    frequency-level plane (``None`` ≡ all base clock) weighted by
    ``lam_f``; ``bias`` is an optional per-candidate additive term
    (EcoSched's lookahead spread penalty); ``mask`` marks feasible
    candidates (default: all).  Returns (float32 scores (B,), winning row
    index) — the index is -1 when no candidate is feasible.
    """
    B, S = dev.shape
    b_pad = max(_BLOCK_B, 1 << max(B - 1, 0).bit_length())
    s_pad = max(_SLOT_PAD, -(-S // _SLOT_PAD) * _SLOT_PAD)
    dev_p = np.zeros((b_pad, s_pad), dtype=np.float32)
    g_p = np.zeros((b_pad, s_pad), dtype=np.float32)
    f_p = np.zeros((b_pad, s_pad), dtype=np.float32)
    dev_p[:B, :S] = dev
    g_p[:B, :S] = g
    if f is not None:
        f_p[:B, :S] = f
    n_p = _pad_rows(np.asarray(n, dtype=np.float32).reshape(B, 1), b_pad)
    bias_p = (
        _pad_rows(np.asarray(bias, dtype=np.float32).reshape(B, 1), b_pad)
        if bias is not None
        else np.zeros((b_pad, 1), dtype=np.float32)
    )
    feasible = (
        np.asarray(mask, dtype=np.float32).reshape(B, 1)
        if mask is not None
        else np.ones((B, 1), dtype=np.float32)
    )
    mask_p = _pad_rows(feasible, b_pad)  # padding rows stay masked out
    params = np.array([[lam, g_free, M, lam_f]], dtype=np.float32)
    scores, best = _reduce_jit(
        params, dev_p, g_p, f_p, n_p, bias_p, mask_p,
        mode=mode or _backend_mode(),
    )
    return np.asarray(scores)[:B], int(best)


# ---------------------------------------------------------------------------
# Cross-node batched reduction: one launch serves a pod's worth of
# simultaneous per-node decisions (ISSUE 9 tentpole).
# ---------------------------------------------------------------------------


def _kernel_batch(params_ref, dev_ref, g_ref, f_ref, n_ref, bias_ref,
                  mask_ref, scores_ref, bmin_ref, btot_ref, bidx_ref):
    """Grid step (d, i): row-block i of node d.  Each node's [λ, G_free,
    M, λ_f] row rides in SMEM, selected by the node grid axis — per-node
    free-unit/alive-unit scalars without recompiles or plane broadcasts."""
    lam = params_ref[0, 0]
    g_free = params_ref[0, 1]
    M = params_ref[0, 2]
    lam_f = params_ref[0, 3]
    scores, tot = _row_scores(
        dev_ref[0], g_ref[0], f_ref[0], n_ref[0], bias_ref[0], mask_ref[0],
        lam, g_free, M, lam_f,
    )
    scores_ref[0] = scores
    bb = scores.shape[0]
    ridx = jax.lax.broadcasted_iota(jnp.int32, (bb, 1), 0)
    m, t_best, r = _pick(scores, tot, ridx, jnp.int32(bb))
    bmin_ref[0, 0, 0] = m
    btot_ref[0, 0, 0] = t_best
    bidx_ref[0, 0, 0] = pl.program_id(1) * bb + r


@functools.partial(jax.jit, static_argnames=("mode",))
def _reduce_batch_jit(params, dev, g, f, n, bias, mask, *, mode: str):
    d_pad, b_pad, s_pad = dev.shape
    if mode == "ref":
        return jax.vmap(_node_reduce)(params, dev, g, f, n, bias, mask)
    nb = b_pad // _BLOCK_B
    col = pl.BlockSpec((1, _BLOCK_B, 1), lambda d, i: (d, i, 0))
    blk = pl.BlockSpec((1, 1, 1), lambda d, i: (d, i, 0))
    plane = pl.BlockSpec((1, _BLOCK_B, s_pad), lambda d, i: (d, i, 0))
    scores, bmin, btot, bidx = pl.pallas_call(
        _kernel_batch,
        grid=(d_pad, nb),
        in_specs=[
            pl.BlockSpec((1, 4), lambda d, i: (d, 0),
                         memory_space=pltpu.SMEM),
            plane, plane, plane,
            col, col, col,
        ],
        out_specs=[col, blk, blk, blk],
        out_shape=[
            jax.ShapeDtypeStruct((d_pad, b_pad, 1), jnp.float32),
            jax.ShapeDtypeStruct((d_pad, nb, 1), jnp.float32),
            jax.ShapeDtypeStruct((d_pad, nb, 1), jnp.float32),
            jax.ShapeDtypeStruct((d_pad, nb, 1), jnp.int32),
        ],
        interpret=(mode == "interpret"),
    )(params, dev, g, f, n, bias, mask)
    combine = jax.vmap(lambda s, m, t, i: _combine(s, m, t, i, b_pad))
    return combine(scores, bmin, btot, bidx)


def score_reduce_batch(
    reqs: Sequence[Dict[str, Any]],
    *,
    mode: Optional[str] = None,
) -> List[Tuple[np.ndarray, int]]:
    """Reduce many nodes' candidate blocks in one kernel launch.

    Each request is a dict with the per-node arguments of
    :func:`score_reduce`: required ``dev``/``g``/``n`` (the (B, S) padded
    columns and per-row action sizes) and ``lam``/``g_free``/``M``
    scalars; optional ``f``/``lam_f``/``bias``/``mask``.  Blocks are
    zero-padded to the common (b_pad, s_pad) and stacked on a leading
    node axis (itself padded to a power of two with fully-masked rows),
    so appended zeros contribute exactly +0.0 at every reduction combine
    and per-node results match the solo path.  Returns one
    (scores (B_k,), best index) pair per request, in order; ``best`` is
    -1 when that node has no feasible candidate (including B_k == 0).
    """
    if not reqs:
        return []
    sizes = [r["dev"].shape for r in reqs]
    b_max = max(b for b, _ in sizes)
    s_max = max(s for _, s in sizes)
    b_pad = max(_BLOCK_B, 1 << max(b_max - 1, 0).bit_length())
    s_pad = max(_SLOT_PAD, -(-s_max // _SLOT_PAD) * _SLOT_PAD)
    D = len(reqs)
    d_pad = 1 << max(D - 1, 0).bit_length()
    dev = np.zeros((d_pad, b_pad, s_pad), dtype=np.float32)
    g = np.zeros((d_pad, b_pad, s_pad), dtype=np.float32)
    f = np.zeros((d_pad, b_pad, s_pad), dtype=np.float32)
    n = np.zeros((d_pad, b_pad, 1), dtype=np.float32)
    bias = np.zeros((d_pad, b_pad, 1), dtype=np.float32)
    mask = np.zeros((d_pad, b_pad, 1), dtype=np.float32)
    params = np.zeros((d_pad, 4), dtype=np.float32)
    params[:, 2] = 1.0  # benign M for the masked pad nodes (no 0/0)
    for k, r in enumerate(reqs):
        B, S = sizes[k]
        dev[k, :B, :S] = r["dev"]
        g[k, :B, :S] = r["g"]
        rf = r.get("f")
        if rf is not None:
            f[k, :B, :S] = rf
        n[k, :B, 0] = np.asarray(r["n"], dtype=np.float32).reshape(B)
        rb = r.get("bias")
        if rb is not None:
            bias[k, :B, 0] = np.asarray(rb, dtype=np.float32).reshape(B)
        rm = r.get("mask")
        if rm is None:
            mask[k, :B, 0] = 1.0
        else:
            mask[k, :B, 0] = np.asarray(rm, dtype=np.float32).reshape(B)
        params[k] = [r["lam"], r["g_free"], r["M"], r.get("lam_f", 0.0)]
    scores, best = _reduce_batch_jit(
        params, dev, g, f, n, bias, mask, mode=mode or _backend_mode()
    )
    scores = np.asarray(scores)
    best = np.asarray(best)
    return [(scores[k, : sizes[k][0]], int(best[k])) for k in range(D)]


# ---------------------------------------------------------------------------
# Multi-window reduction: many variable-size windows share one launch by
# packing rows, not by padding every window to the widest (ISSUE 10
# tentpole).  The COMPLETE path's windows are tiny-but-many (one per
# eligible resize candidate, one per backfilling node); stacking them on a
# node axis like ``score_reduce_batch`` would pad each to _BLOCK_B rows,
# so instead the rows concatenate into one block and the per-window
# [λ, G_free, M, λ_f] scalars ride as per-row columns.
# ---------------------------------------------------------------------------


def _kernel_multi(dev_ref, g_ref, f_ref, n_ref, bias_ref, mask_ref,
                  lam_ref, gfree_ref, m_ref, lamf_ref,
                  scores_ref, tot_ref):
    """Grid step i: row-block i of the packed multi-window table.  Eq. (1)
    params are per-row columns (windows straddle block boundaries freely);
    the per-window argmin is a segmented combine outside the kernel."""
    scores, tot = _row_scores(
        dev_ref[:], g_ref[:], f_ref[:], n_ref[:], bias_ref[:], mask_ref[:],
        lam_ref[:], gfree_ref[:], m_ref[:], lamf_ref[:],
    )
    scores_ref[:] = scores
    tot_ref[:] = tot


@functools.partial(jax.jit, static_argnames=("n_windows", "mode"))
def _reduce_multi_jit(lam, gfree, m, lamf, dev, g, f, n, bias, mask,
                      wid, starts, *, n_windows: int, mode: str):
    b_pad, s_pad = dev.shape
    if mode == "ref":
        scores2, tot2 = _row_scores(
            dev, g, f, n, bias, mask, lam, gfree, m, lamf
        )
    else:
        nb = b_pad // _BLOCK_B
        col = pl.BlockSpec((_BLOCK_B, 1), lambda i: (i, 0))
        plane = pl.BlockSpec((_BLOCK_B, s_pad), lambda i: (i, 0))
        scores2, tot2 = pl.pallas_call(
            _kernel_multi,
            grid=(nb,),
            in_specs=[plane, plane, plane, col, col, col, col, col, col, col],
            out_specs=[col, col],
            out_shape=[
                jax.ShapeDtypeStruct((b_pad, 1), jnp.float32),
                jax.ShapeDtypeStruct((b_pad, 1), jnp.float32),
            ],
            interpret=(mode == "interpret"),
        )(dev, g, f, n, bias, mask, lam, gfree, m, lamf)
    scores = scores2[:, 0]
    tot = tot2[:, 0]
    # segmented tie-broken argmin — the same (min score, max count, min
    # row) combine as _pick, scatter-reduced per window id.  Pad rows
    # belong to a dummy window (their masked inf scores never matter).
    seg_min = jnp.full((n_windows,), jnp.inf, dtype=scores.dtype)
    m_w = seg_min.at[wid].min(scores)
    tie = scores == m_w[wid]
    seg_tot = jnp.full((n_windows,), -1.0, dtype=tot.dtype)
    t_w = seg_tot.at[wid].max(jnp.where(tie, tot, -1.0))
    cand = tie & (tot == t_w[wid])
    ridx = jax.lax.iota(jnp.int32, b_pad)
    seg_idx = jnp.full((n_windows,), b_pad, dtype=jnp.int32)
    i_w = seg_idx.at[wid].min(jnp.where(cand, ridx, jnp.int32(b_pad)))
    best = jnp.where(jnp.isinf(m_w), jnp.int32(-1), i_w - starts)
    return scores, best


def score_reduce_multi(
    reqs: Sequence[Dict[str, Any]],
    *,
    mode: Optional[str] = None,
) -> List[Tuple[np.ndarray, int]]:
    """Reduce many independent candidate windows in one kernel launch.

    Same request dicts as :func:`score_reduce_batch` (required
    ``dev``/``g``/``n``/``lam``/``g_free``/``M``, optional
    ``f``/``lam_f``/``bias``/``mask``), but the windows concatenate on the
    row axis instead of stacking on a padded node axis — the right shape
    when windows are many and small (the COMPLETE path: one window per
    elastic resize candidate plus one per backfilling node).  Per-row
    scores are the identical elementwise Eq. (1) ops as the solo kernel
    (params broadcast per row instead of per launch), and the per-window
    argmin applies the same tie-break, so each window's (scores, best)
    pair is bit-identical to a solo :func:`score_reduce` call on it.
    ``best`` is -1 for a window with no feasible candidate (including an
    empty window).
    """
    if not reqs:
        return []
    sizes = [r["dev"].shape for r in reqs]
    total = sum(b for b, _ in sizes)
    s_max = max(s for _, s in sizes)
    b_pad = max(_BLOCK_B, 1 << max(total - 1, 0).bit_length())
    s_pad = max(_SLOT_PAD, -(-s_max // _SLOT_PAD) * _SLOT_PAD)
    W = len(reqs)
    # power-of-two window count strictly greater than W: the jit cache
    # stays small and the last segment is always the pad rows' dummy
    n_windows = 1 << max(W, 1).bit_length()
    dev = np.zeros((b_pad, s_pad), dtype=np.float32)
    g = np.zeros((b_pad, s_pad), dtype=np.float32)
    f = np.zeros((b_pad, s_pad), dtype=np.float32)
    n = np.zeros((b_pad, 1), dtype=np.float32)
    bias = np.zeros((b_pad, 1), dtype=np.float32)
    mask = np.zeros((b_pad, 1), dtype=np.float32)
    lam = np.zeros((b_pad, 1), dtype=np.float32)
    gfree = np.zeros((b_pad, 1), dtype=np.float32)
    m = np.ones((b_pad, 1), dtype=np.float32)  # benign M for pad rows
    lamf = np.zeros((b_pad, 1), dtype=np.float32)
    wid = np.full(b_pad, n_windows - 1, dtype=np.int32)
    starts = np.zeros(n_windows, dtype=np.int32)
    off = 0
    for k, r in enumerate(reqs):
        B, S = sizes[k]
        starts[k] = off
        if B == 0:
            continue  # empty window: stays all-inf, best = -1
        rows = slice(off, off + B)
        dev[rows, :S] = r["dev"]
        g[rows, :S] = r["g"]
        rf = r.get("f")
        if rf is not None:
            f[rows, :S] = rf
        n[rows, 0] = np.asarray(r["n"], dtype=np.float32).reshape(B)
        rb = r.get("bias")
        if rb is not None:
            bias[rows, 0] = np.asarray(rb, dtype=np.float32).reshape(B)
        rm = r.get("mask")
        if rm is None:
            mask[rows, 0] = 1.0
        else:
            mask[rows, 0] = np.asarray(rm, dtype=np.float32).reshape(B)
        lam[rows, 0] = r["lam"]
        gfree[rows, 0] = r["g_free"]
        m[rows, 0] = r["M"]
        lamf[rows, 0] = r.get("lam_f", 0.0)
        wid[rows] = k
        off += B
    scores, best = _reduce_multi_jit(
        lam, gfree, m, lamf, dev, g, f, n, bias, mask, wid, starts,
        n_windows=n_windows, mode=mode or _backend_mode(),
    )
    scores = np.asarray(scores)
    best = np.asarray(best)
    return [
        (scores[int(starts[k]): int(starts[k]) + sizes[k][0]], int(best[k]))
        for k in range(W)
    ]

"""Batched Eq. (1) score reduction + masked argmin (JAX/Pallas).

The engine's candidate set for one scheduling event is a padded matrix of
per-slot energy deviations, unit counts (``ScoredBatch.padded_cols``) and
DVFS frequency levels (``ScoredBatch.padded_f``).  Scoring it is a row
reduction

    S[b] = Σ_s dev[b, s] / max(n[b], 1) + λ·(G_free − Σ_s g[b, s]) / M
           + λ_f·Σ_s f[b, s] / max(n[b], 1) + bias[b]

followed by a masked argmin under EcoSched's tie-break (lowest score, then
largest total unit count, then earliest row).  At pod scale the candidate
space exceeds 10^5 rows per event — and the joint (count × frequency) mode
set is 4–8× larger still; this module reduces it in one fused kernel
instead of a chain of numpy temporaries.

Backend selection mirrors ``kernels/ops.py``: on TPU the Pallas kernel
runs compiled (Mosaic); everywhere else ``REPRO_KERNELS`` picks
``interpret`` (kernel body op-by-op on CPU — the validation target) or
``ref`` (pure jnp, fast enough for CI; the default off-TPU).  The Pallas
grid tiles rows into blocks; each grid step writes its block's scores and
a per-block (min score, best count, best row) triple, and a tiny jnp
combine selects the global winner across blocks — so the reduction never
materializes on the host.

λ, G_free, M and λ_f ride in an SMEM params row (traced, not static):
sweeping node fill levels or frequency-conservatism weights does not
recompile.  Rows are padded to a power of two
and slots to a multiple of 8, so the jit cache stays small.  Scores are
float32 — parity vs the float64 numpy engine is ≤1e-6 over seeded random
windows (tests/test_score_reduce.py).
"""
from __future__ import annotations

import functools
import os
from typing import Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

_BLOCK_B = 256  # candidate rows per grid step
_SLOT_PAD = 8  # slot (action-size) axis padded to a multiple of this


def _backend_mode() -> str:
    forced = os.environ.get("REPRO_KERNELS", "")
    if forced:
        return forced  # "pallas" | "interpret" | "ref"
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def _row_scores(dev, g, f, n, bias, mask, lam, g_free, M, lam_f):
    """(B, 1) masked Eq. (1) scores from (B, S)/(B, 1) blocks.  The
    frequency term is λ_f·mean(f); at λ_f = 0 (or an all-zero f plane —
    single-frequency windows) it contributes exactly +0.0, keeping scores
    bit-identical to the count-only kernel."""
    tot = jnp.sum(g, axis=1, keepdims=True)
    n_eff = jnp.maximum(n, 1.0)
    s = (
        jnp.sum(dev, axis=1, keepdims=True) / n_eff
        + lam * (g_free - tot) / M
        + lam_f * jnp.sum(f, axis=1, keepdims=True) / n_eff
        + bias
    )
    return jnp.where(mask > 0, s, jnp.inf), tot


def _pick(scores, tot, idx, idx_cap):
    """Tie-broken argmin: min score, then max total count, then min index.
    Returns (min score, winning count, winning index)."""
    m = jnp.min(scores)
    tie = scores == m
    t_best = jnp.max(jnp.where(tie, tot, -1.0))
    cand = tie & (tot == t_best)
    i = jnp.min(jnp.where(cand, idx, idx_cap))
    return m, t_best, i


def _kernel(params_ref, dev_ref, g_ref, f_ref, n_ref, bias_ref, mask_ref,
            scores_ref, bmin_ref, btot_ref, bidx_ref):
    lam = params_ref[0, 0]
    g_free = params_ref[0, 1]
    M = params_ref[0, 2]
    lam_f = params_ref[0, 3]
    scores, tot = _row_scores(
        dev_ref[:], g_ref[:], f_ref[:], n_ref[:], bias_ref[:], mask_ref[:],
        lam, g_free, M, lam_f,
    )
    scores_ref[:] = scores
    bb = scores.shape[0]
    ridx = jax.lax.broadcasted_iota(jnp.int32, (bb, 1), 0)
    m, t_best, r = _pick(scores, tot, ridx, jnp.int32(bb))
    bmin_ref[0, 0] = m
    btot_ref[0, 0] = t_best
    bidx_ref[0, 0] = pl.program_id(0) * bb + r


def _combine(scores, bmin, btot, bidx, b_pad):
    """Global winner across per-block (min, count, index) triples."""
    mg = jnp.min(bmin)
    tie = bmin == mg
    t_best = jnp.max(jnp.where(tie, btot, -1.0))
    cand = tie & (btot == t_best)
    idx = jnp.min(jnp.where(cand, bidx, jnp.int32(b_pad)))
    best = jnp.where(jnp.isinf(mg), jnp.int32(-1), idx)
    return scores[:, 0], best


@functools.partial(jax.jit, static_argnames=("mode",))
def _reduce_jit(params, dev, g, f, n, bias, mask, *, mode: str):
    b_pad, s_pad = dev.shape
    if mode == "ref":
        scores, tot = _row_scores(
            dev, g, f, n, bias, mask,
            params[0, 0], params[0, 1], params[0, 2], params[0, 3],
        )
        ridx = jax.lax.broadcasted_iota(jnp.int32, (b_pad, 1), 0)
        m, t_best, i = _pick(scores, tot, ridx, jnp.int32(b_pad))
        best = jnp.where(jnp.isinf(m), jnp.int32(-1), i)
        return scores[:, 0], best
    nb = b_pad // _BLOCK_B
    col = pl.BlockSpec((_BLOCK_B, 1), lambda i: (i, 0))
    blk = pl.BlockSpec((1, 1), lambda i: (i, 0))
    plane = pl.BlockSpec((_BLOCK_B, s_pad), lambda i: (i, 0))
    scores, bmin, btot, bidx = pl.pallas_call(
        _kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, 4), lambda i: (0, 0), memory_space=pltpu.SMEM),
            plane, plane, plane,
            col, col, col,
        ],
        out_specs=[col, blk, blk, blk],
        out_shape=[
            jax.ShapeDtypeStruct((b_pad, 1), jnp.float32),
            jax.ShapeDtypeStruct((nb, 1), jnp.float32),
            jax.ShapeDtypeStruct((nb, 1), jnp.float32),
            jax.ShapeDtypeStruct((nb, 1), jnp.int32),
        ],
        interpret=(mode == "interpret"),
    )(params, dev, g, f, n, bias, mask)
    return _combine(scores, bmin, btot, bidx, b_pad)


def _pad_rows(a: np.ndarray, b_pad: int) -> np.ndarray:
    out = np.zeros((b_pad,) + a.shape[1:], dtype=a.dtype)
    out[: len(a)] = a
    return out


def score_reduce(
    dev: np.ndarray,
    g: np.ndarray,
    n: np.ndarray,
    *,
    lam: float,
    g_free: int,
    M: int,
    f: Optional[np.ndarray] = None,
    lam_f: float = 0.0,
    bias: Optional[np.ndarray] = None,
    mask: Optional[np.ndarray] = None,
    mode: Optional[str] = None,
) -> Tuple[np.ndarray, int]:
    """Scores + tie-broken argmin for a (B, S) candidate block.

    ``dev``/``g`` are per-slot deviation/count columns (zero-padded past
    each action's size ``n``); ``f`` is the optional per-slot DVFS
    frequency-level plane (``None`` ≡ all base clock) weighted by
    ``lam_f``; ``bias`` is an optional per-candidate additive term
    (EcoSched's lookahead spread penalty); ``mask`` marks feasible
    candidates (default: all).  Returns (float32 scores (B,), winning row
    index) — the index is -1 when no candidate is feasible.
    """
    B, S = dev.shape
    b_pad = max(_BLOCK_B, 1 << max(B - 1, 0).bit_length())
    s_pad = max(_SLOT_PAD, -(-S // _SLOT_PAD) * _SLOT_PAD)
    dev_p = np.zeros((b_pad, s_pad), dtype=np.float32)
    g_p = np.zeros((b_pad, s_pad), dtype=np.float32)
    f_p = np.zeros((b_pad, s_pad), dtype=np.float32)
    dev_p[:B, :S] = dev
    g_p[:B, :S] = g
    if f is not None:
        f_p[:B, :S] = f
    n_p = _pad_rows(np.asarray(n, dtype=np.float32).reshape(B, 1), b_pad)
    bias_p = (
        _pad_rows(np.asarray(bias, dtype=np.float32).reshape(B, 1), b_pad)
        if bias is not None
        else np.zeros((b_pad, 1), dtype=np.float32)
    )
    feasible = (
        np.asarray(mask, dtype=np.float32).reshape(B, 1)
        if mask is not None
        else np.ones((B, 1), dtype=np.float32)
    )
    mask_p = _pad_rows(feasible, b_pad)  # padding rows stay masked out
    params = np.array([[lam, g_free, M, lam_f]], dtype=np.float32)
    scores, best = _reduce_jit(
        params, dev_p, g_p, f_p, n_p, bias_p, mask_p,
        mode=mode or _backend_mode(),
    )
    return np.asarray(scores)[:B], int(best)

"""Pure-jnp oracles for every Pallas kernel.

These are the *definitional* implementations — materialized score tensors,
step-by-step recurrences — used by the kernel test sweeps
(``assert_allclose`` against interpret-mode Pallas) and as the CPU
fallback inside ``ops.py``.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(
    q: jax.Array,  # (B, Sq, H, hd)
    k: jax.Array,  # (B, Skv, KVH, hd)
    v: jax.Array,  # (B, Skv, KVH, hd)
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    scale: Optional[float] = None,
) -> jax.Array:
    B, Sq, H, hd = q.shape
    _, Skv, KVH, _ = k.shape
    G = H // KVH
    scale = scale or 1.0 / math.sqrt(hd)
    qg = q.reshape(B, Sq, KVH, G, hd)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32), k.astype(jnp.float32))
    s = s * scale
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    qp = jnp.arange(Sq)
    kp = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kp[None, :] <= qp[:, None]
    if window > 0:
        mask &= kp[None, :] > qp[:, None] - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, hd).astype(q.dtype)


def ssd_ref(
    xh: jax.Array,  # (B, S, nh, hp)
    dt: jax.Array,  # (B, S, nh) positive
    A: jax.Array,  # (nh,) negative
    Bm: jax.Array,  # (B, S, N)
    Cm: jax.Array,  # (B, S, N)
    *,
    h0: Optional[jax.Array] = None,  # (B, nh, hp, N)
):
    """Definitional SSD recurrence, one step at a time.

    h_t = exp(A·Δ_t)·h_{t-1} + Δ_t · x_t ⊗ B_t ;  y_t = h_t · C_t
    Returns (y (B,S,nh,hp) fp32, final state (B,nh,hp,N) fp32).
    """
    B, S, nh, hp = xh.shape
    N = Bm.shape[-1]
    xh = xh.astype(jnp.float32)
    dt = dt.astype(jnp.float32)
    Bm = Bm.astype(jnp.float32)
    Cm = Cm.astype(jnp.float32)
    h = jnp.zeros((B, nh, hp, N), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, t):
        x_t, dt_t, B_t, C_t = xh[:, t], dt[:, t], Bm[:, t], Cm[:, t]
        dA = jnp.exp(dt_t * A[None, :])  # (B, nh)
        h = h * dA[..., None, None] + jnp.einsum("bh,bhp,bn->bhpn", dt_t, x_t, B_t)
        y = jnp.einsum("bn,bhpn->bhp", C_t, h)
        return h, y

    h, ys = jax.lax.scan(step, h, jnp.arange(S))
    return ys.transpose(1, 0, 2, 3), h  # (B,S,nh,hp), (B,nh,hp,N)

"""jit'd public wrappers around the Pallas kernels.

On TPU the kernels run compiled (Mosaic); everywhere else they run in
``interpret=True`` mode, or the pure-jnp reference when ``REPRO_KERNELS=ref``
— the interpret path executes the kernel body op-by-op on CPU and is the
validation target, while the ref path is fast enough for CI.
"""
from __future__ import annotations

import functools
import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.flash_attention import flash_attention as _flash_pallas
from repro.kernels.ssd_scan import ssd_scan as _ssd_pallas


def _backend_mode() -> str:
    forced = os.environ.get("REPRO_KERNELS", "")
    if forced:
        return forced  # "pallas" | "interpret" | "ref"
    return "pallas" if jax.default_backend() == "tpu" else "ref"


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "softcap", "block_q", "block_k", "mode")
)
def _flash_jit(q, k, v, *, causal, window, softcap, block_q, block_k, mode):
    if mode == "ref":
        return _ref.flash_attention_ref(q, k, v, causal=causal, window=window, softcap=softcap)
    return _flash_pallas(
        q, k, v,
        causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_k=block_k,
        interpret=(mode == "interpret"),
    )


def flash_attention(
    q: jax.Array, k: jax.Array, v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    block_q: int = 512,
    block_k: int = 512,
    mode: Optional[str] = None,
) -> jax.Array:
    return _flash_jit(
        q, k, v,
        causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_k=block_k,
        mode=mode or _backend_mode(),
    )


@functools.partial(jax.jit, static_argnames=("chunk", "mode"))
def _ssd_jit(xh, dt, A, Bm, Cm, *, chunk, mode):
    if mode == "ref":
        return _ref.ssd_ref(xh, dt, A, Bm, Cm)
    return _ssd_pallas(xh, dt, A, Bm, Cm, chunk=chunk, interpret=(mode == "interpret"))


def ssd_scan(
    xh: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array, Cm: jax.Array,
    *,
    chunk: int = 256,
    mode: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array]:
    return _ssd_jit(xh, dt, A, Bm, Cm, chunk=chunk, mode=mode or _backend_mode())

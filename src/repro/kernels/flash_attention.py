"""Pallas TPU flash attention (causal / sliding-window / GQA / softcap).

TPU-native design (not a CUDA port): the grid is
``(batch, kv_head, q_group, q_blocks, kv_blocks)`` with the kv-block axis
sequential ("arbitrary") and everything else parallel.  Running max / sum /
accumulator live in VMEM scratch and persist across the kv-block axis —
the online-softmax state never leaves VMEM, and each (bq×hd) output tile is
written exactly once on the last kv step.  Block shapes are BlockSpec-tiled
so the (bq×bk) score tile and the (bk×hd) K/V tiles sit in VMEM with
MXU-aligned (multiple-of-128) matmul dims.

GQA: queries carry H = KVH·G heads; K/V carry KVH.  The q-group axis of the
grid indexes the G query heads sharing one kv head, so K/V tiles are
fetched once per group from HBM.

Validated on CPU via ``interpret=True`` against ``ref.flash_attention_ref``
(tests/test_kernels_flash.py sweeps shapes × dtypes × flags).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro import compat

NEG_INF = -1e30


def _kernel(
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, scale: float, causal: bool, window: int, softcap: float,
    block_q: int, block_k: int,
):
    iq = pl.program_id(3)
    ik = pl.program_id(4)
    nk = pl.num_programs(4)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * block_q
    k_start = ik * block_k

    should_run = jnp.bool_(True)
    if causal:
        should_run &= k_start <= q_start + block_q - 1
    if window > 0:
        should_run &= k_start + block_k - 1 > q_start - window

    @pl.when(should_run)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale  # (bq, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # (bk, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (bq, bk)
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), bool)
        if causal:
            mask &= k_pos <= q_pos
        if window > 0:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, 0]
        l_prev = l_ref[:, 0]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l_prev * alpha + p.sum(axis=-1)
        v = v_ref[0, :, 0, :].astype(jnp.float32)  # (bk, hd)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_ref[...] = acc_ref[...] * alpha[:, None] + pv
        m_ref[:, 0] = m_new
        l_ref[:, 0] = l_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,  # (B, Sq, H, hd)
    k: jax.Array,  # (B, Skv, KVH, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    scale: Optional[float] = None,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, Sq, H, hd = q.shape
    _, Skv, KVH, _ = k.shape
    assert H % KVH == 0, (H, KVH)
    G = H // KVH
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    assert Sq % block_q == 0 and Skv % block_k == 0, (Sq, block_q, Skv, block_k)
    nq, nk = Sq // block_q, Skv // block_k
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)

    grid = (B, KVH, G, nq, nk)
    kernel = functools.partial(
        _kernel,
        scale=scale, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_k=block_k,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(
                (1, block_q, 1, hd), lambda b, h, g, iq, ik: (b, iq, h * G + g, 0)
            ),
            pl.BlockSpec((1, block_k, 1, hd), lambda b, h, g, iq, ik: (b, ik, h, 0)),
            pl.BlockSpec((1, block_k, 1, hd), lambda b, h, g, iq, ik: (b, ik, h, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, block_q, 1, hd), lambda b, h, g, iq, ik: (b, iq, h * G + g, 0)
        ),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)

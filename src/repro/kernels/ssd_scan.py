"""Pallas TPU kernel for the Mamba2 SSD chunked scan.

TPU adaptation of the SSD "dual form": the sequence is tiled into chunks
of ``Q`` tokens; per (batch, head) the chunk axis runs sequentially
("arbitrary" grid dim) while batch and heads parallelize.  The (hp × N)
recurrent state lives in VMEM scratch and never round-trips to HBM between
chunks — the HBM traffic is exactly one read of x/Δ/B/C and one write of y
per token.  The intra-chunk quadratic form is two MXU matmuls
((Q×N)·(N×Q) and (Q×Q)·(Q×hp)); Q and N default to 256/128 so every
matmul dim is 128-aligned.

Returns y **without** the D·x skip term and gating — those are
elementwise and stay in the XLA layer where they fuse with the
surrounding ops.

Validated on CPU via ``interpret=True`` against ``ref.ssd_ref``
(tests/test_kernels_ssd.py sweeps shapes/dtypes/chunk sizes).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro import compat


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hout_ref, h_ref, *, chunk: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)  # (Q, hp)
    dt = dt_ref[0, :, 0].astype(jnp.float32)  # (Q,)
    A = a_ref[0]  # scalar (negative)
    Bm = b_ref[0].astype(jnp.float32)  # (Q, N)
    Cm = c_ref[0].astype(jnp.float32)  # (Q, N)

    a = dt * A  # (Q,) negative log-decay
    La = jnp.cumsum(a)  # inclusive
    Ltot = La[-1]

    # intra-chunk quadratic form
    cb = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (Q, Q) = C_i · B_j
    decay = jnp.exp(La[:, None] - La[None, :])
    qi = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    kj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    scores = jnp.where(qi >= kj, cb * decay, 0.0) * dt[None, :]
    y_intra = jax.lax.dot_general(
        scores, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (Q, hp)

    # inter-chunk: contribution of the carried state
    h_prev = h_ref[...]  # (hp, N)
    y_inter = jax.lax.dot_general(
        Cm, h_prev, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * jnp.exp(La)[:, None]  # (Q, hp)

    # state update: deposits surviving to end of chunk
    w = jnp.exp(Ltot - La) * dt  # (Q,)
    s_chunk = jax.lax.dot_general(
        x, Bm * w[:, None], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (hp, N)
    h_ref[...] = jnp.exp(Ltot) * h_prev + s_chunk

    y_ref[0, :, 0, :] = (y_intra + y_inter).astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _finish():
        hout_ref[0, 0] = h_ref[...].astype(hout_ref.dtype)


def ssd_scan(
    xh: jax.Array,  # (B, S, nh, hp)
    dt: jax.Array,  # (B, S, nh) positive
    A: jax.Array,  # (nh,) negative
    Bm: jax.Array,  # (B, S, N)
    Cm: jax.Array,  # (B, S, N)
    *,
    chunk: int = 256,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,nh,hp) fp32, final state (B,nh,hp,N) fp32)."""
    B, S, nh, hp = xh.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk

    grid = (B, nh, nc)
    kernel = functools.partial(_kernel, chunk=chunk)
    y, hout = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, hp), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, hp), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, hp, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, nh, hp), jnp.float32),
            jax.ShapeDtypeStruct((B, nh, hp, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hp, N), jnp.float32)],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(xh, dt, A, Bm, Cm)
    return y, hout

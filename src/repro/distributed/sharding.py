"""Sharding rules: params / optimizer / batches / caches per architecture.

Baseline layout (2-D ``(data, model)`` mesh, optionally with a leading
``pod`` axis that joins the data axes):

* Megatron-style TP on the ``model`` axis: attention heads, FFN hidden,
  MoE experts (EP) or expert-hidden (when E doesn't divide), SSM heads;
  vocab-sharded embedding/head.
* DP over ``(pod, data)`` for activations; ZeRO-style optimizer-state
  sharding adds the data axes to the first evenly-divisible unsharded dim.
* K/V that don't divide the model axis stay replicated (GQA kv<TP), which
  is the standard Megatron fallback.

``shardable(cfg, model_par)`` pads head/expert/vocab counts to the mesh
where the published numbers don't divide (phi4 24H→32H, arctic 56H→64H,
gemma3 8H→16H, hymba 25H/5KV/50ssmH→32/8/64, qwen2-moe 60E→64E,
whisper 8H→16H, mamba2 vocab→%16) — a *documented* TP-divisibility
variant: FLOP/byte structure preserved, dead-row waste is visible in the
roofline's MODEL_FLOPS/HLO ratio (DESIGN.md §2, §5).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeCell


# ---------------------------------------------------------------------------
# Mesh introspection
# ---------------------------------------------------------------------------


def mesh_dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def mesh_dp_size(mesh: Mesh) -> int:
    n = 1
    for a in mesh_dp_axes(mesh):
        n *= mesh.shape[a]
    return n


def mesh_model_size(mesh: Mesh) -> int:
    return mesh.shape.get("model", 1)


# ---------------------------------------------------------------------------
# TP-divisibility padding
# ---------------------------------------------------------------------------


def _pad_to(n: int, mult: int) -> int:
    return ((n + mult - 1) // mult) * mult


def shardable(cfg: ModelConfig, model_par: int) -> Tuple[ModelConfig, Dict[str, Any]]:
    """Pad the config so TP on ``model_par`` partitions divides evenly."""
    changes: Dict[str, Any] = {}
    kw: Dict[str, Any] = {}

    if cfg.uses_attention and cfg.num_heads % model_par:
        new_h = _pad_to(cfg.num_heads, model_par)
        # keep GQA grouping integral
        kv = cfg.num_kv_heads
        while new_h % kv:
            kv += 1
        if kv != cfg.num_kv_heads:
            kw["num_kv_heads"] = kv
            changes["num_kv_heads"] = (cfg.num_kv_heads, kv)
        kw["num_heads"] = new_h
        changes["num_heads"] = (cfg.num_heads, new_h)

    if cfg.uses_moe and cfg.num_experts % model_par and cfg.num_experts > model_par:
        new_e = _pad_to(cfg.num_experts, model_par)
        kw["num_experts"] = new_e
        changes["num_experts"] = (cfg.num_experts, new_e)

    if cfg.uses_ssm:
        nh = cfg.ssm_heads
        if nh % model_par:
            new_nh = _pad_to(nh, model_par)
            kw["d_inner_override"] = new_nh * cfg.ssm_head_dim
            changes["ssm_heads"] = (nh, new_nh)

    if cfg.vocab_size % model_par:
        new_v = _pad_to(cfg.vocab_size, model_par)
        kw["vocab_size"] = new_v
        kw["vocab_size_real"] = cfg.vocab_size
        changes["vocab_size"] = (cfg.vocab_size, new_v)

    return (cfg.replace(**kw) if kw else cfg), changes


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def param_spec_for(cfg: ModelConfig, mesh: Mesh, path: str, shape: Tuple[int, ...]) -> P:
    """PartitionSpec for one parameter leaf (shape includes any leading L)."""
    m = mesh_model_size(mesh)
    stacked = ("blocks" in path) or ("enc_blocks" in path)
    core = shape[1:] if stacked else shape
    name = path.split("/")[-1]

    attn_tp = cfg.uses_attention and cfg.num_heads % m == 0
    kv_tp = cfg.uses_attention and cfg.num_kv_heads % m == 0
    ff = cfg.d_ff
    moe_ep = cfg.uses_moe and cfg.num_experts % m == 0
    moe_ff = cfg.moe_d_ff or cfg.d_ff
    ssm_tp = cfg.uses_ssm and cfg.ssm_heads % m == 0 and cfg.d_inner % m == 0

    def spec(*core_spec):
        return P(*((None,) + core_spec if stacked else core_spec))

    # --- embeddings / head ------------------------------------------------
    if name == "embed":
        return P("model", None) if cfg.vocab_size % m == 0 else P(None, None)
    if name == "lm_head":
        return P(None, "model") if cfg.vocab_size % m == 0 else P(None, None)
    if name in ("final_norm", "enc_norm"):
        return P(None)

    # --- attention ----------------------------------------------------------
    if name in ("wq",) and ("attn" in path or "cross" in path):
        return spec(None, "model") if attn_tp else spec(None, None)
    if name in ("wk", "wv") and ("attn" in path or "cross" in path):
        return spec(None, "model") if kv_tp else spec(None, None)
    if name == "wo":
        return spec("model", None) if attn_tp else spec(None, None)

    # --- MoE -----------------------------------------------------------------
    if "experts" in path and name in ("gate", "up"):
        if moe_ep:
            return spec("model", None, None)
        return spec(None, None, "model") if moe_ff % m == 0 else spec(None, None, None)
    if "experts" in path and name == "down":
        if moe_ep:
            return spec("model", None, None)
        return spec(None, "model", None) if moe_ff % m == 0 else spec(None, None, None)
    if name == "router":
        return spec(None, None)
    if "shared" in path and name in ("gate", "up"):
        shared_ff = cfg.num_shared_experts * moe_ff
        return spec(None, "model") if shared_ff % m == 0 else spec(None, None)
    if "shared" in path and name == "down":
        shared_ff = cfg.num_shared_experts * moe_ff
        return spec("model", None) if shared_ff % m == 0 else spec(None, None)
    if name == "shared_gate":
        return spec(None, None)

    # --- dense FFN (mlp / arctic dense residual) ------------------------------
    if ("mlp" in path or "dense_ffn" in path) and name in ("gate", "up"):
        ffd = cfg.d_ff
        return spec(None, "model") if ffd % m == 0 else spec(None, None)
    if ("mlp" in path or "dense_ffn" in path) and name == "down":
        ffd = cfg.d_ff
        return spec("model", None) if ffd % m == 0 else spec(None, None)

    # --- SSM ------------------------------------------------------------------
    if name in ("wz", "wx"):
        return spec(None, "model") if ssm_tp else spec(None, None)
    if name == "conv_x":
        return spec(None, "model") if ssm_tp else spec(None, None)
    if name in ("conv_bx", "norm") and "ssm" in path:
        return spec("model") if ssm_tp else spec(None)
    if name == "out_proj":
        return spec("model", None) if ssm_tp else spec(None, None)
    if name in ("A_log", "D", "dt_bias"):
        return spec("model") if ssm_tp else spec(None)
    if name == "wdt":
        return spec(None, "model") if ssm_tp else spec(None, None)
    if name in ("wbc", "conv_bc", "conv_bbc"):
        return spec(*([None] * len(core)))

    # --- norms / scalars / anything else: replicated ---------------------------
    return spec(*([None] * len(core)))


def param_specs(cfg: ModelConfig, mesh: Mesh, params_shape) -> Any:
    """Pytree of PartitionSpec matching a params template (eval_shape ok)."""

    def one(path, leaf):
        return param_spec_for(cfg, mesh, _path_str(path), tuple(leaf.shape))

    return jax.tree_util.tree_map_with_path(one, params_shape)


def named(mesh: Mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


# ---------------------------------------------------------------------------
# ZeRO-style optimizer-state specs
# ---------------------------------------------------------------------------


def zero_extend(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Add the data axes to the first evenly-divisible unsharded dim."""
    dp = mesh_dp_axes(mesh)
    dp_size = mesh_dp_size(mesh)
    if not dp or dp_size == 1:
        return spec
    parts = list(spec) + [None] * (len(shape) - len(spec))
    for i, (s, dim) in enumerate(zip(parts, shape)):
        if s is None and dim % dp_size == 0 and dim >= dp_size:
            parts[i] = dp if len(dp) > 1 else dp[0]
            return P(*parts)
    return spec


def opt_state_specs(cfg: ModelConfig, mesh: Mesh, opt_shape, *, zero: bool = True):
    """Specs for AdamW state {m, v, count}.

    fp32/bf16 moments mirror the param layout (+ZeRO extension over the
    data axes); int8 moments ({"q": (nb, BLOCK), "scale": (nb, 1)}) shard
    the block dim over data.
    """
    dp = mesh_dp_axes(mesh)
    dp_size = mesh_dp_size(mesh)
    dp_spec = dp if len(dp) > 1 else (dp[0] if dp else None)

    def one(path, leaf):
        if _path_str(path) == "count":
            return P()
        inner = path[1:]  # drop the leading "m"/"v" key
        name = _path_str(inner)
        if name.split("/")[-1] in ("q", "scale"):  # int8 block layout
            # shape = param.shape[:-1] + (nb, BLOCK|1): inherit the param's
            # leading-dim sharding, block dims unsharded
            pname = "/".join(name.split("/")[:-1])
            lead = tuple(leaf.shape[:-2])
            base = param_spec_for(cfg, mesh, pname, lead + (leaf.shape[-2] * 256,))
            parts = (list(base) + [None] * len(leaf.shape))[: max(len(lead), 0)]
            spec = P(*(tuple(parts) + (None, None)))
            if zero:
                return zero_extend(spec, tuple(leaf.shape), mesh)
            return spec
        base = param_spec_for(cfg, mesh, name, tuple(leaf.shape))
        if zero:
            return zero_extend(base, tuple(leaf.shape), mesh)
        return base

    return jax.tree_util.tree_map_with_path(one, opt_shape)


# ---------------------------------------------------------------------------
# Batch / cache / activation specs
# ---------------------------------------------------------------------------


def _dp_spec_or_none(mesh: Mesh, batch: int):
    dp = mesh_dp_axes(mesh)
    n = mesh_dp_size(mesh)
    if n > 1 and batch % n == 0:
        return dp if len(dp) > 1 else dp[0]
    return None


def batch_specs(cfg: ModelConfig, mesh: Mesh, batch_shapes: Dict[str, Tuple[int, ...]]):
    out = {}
    for k, shp in batch_shapes.items():
        b = _dp_spec_or_none(mesh, shp[0])
        out[k] = P(*((b,) + (None,) * (len(shp) - 1)))
    return out


def cache_specs(cfg: ModelConfig, mesh: Mesh, cache_shapes: Dict[str, Tuple[int, ...]]):
    """Decode-cache layout: batch over data; KV sequence over model."""
    m = mesh_model_size(mesh)
    out = {}
    for k, shp in cache_shapes.items():
        b = _dp_spec_or_none(mesh, shp[1])
        if k in ("k", "v") and len(shp) == 6 and shp[3] % m == 0:
            # striped layout (L,B,nblk,w,KVH,hd): shard the window offset —
            # any window read stays local and balanced (§Perf G2)
            out[k] = P(None, b, None, "model", None, None)
        elif k in ("k", "v") and shp[2] % m == 0:
            out[k] = P(None, b, "model", None, None)
        elif k == "h" and cfg.uses_ssm and cfg.ssm_heads % m == 0:
            out[k] = P(None, b, "model", None, None)
        elif k == "conv" and cfg.uses_ssm and cfg.d_inner % m == 0:
            # channels = [x (di, sharded) | bc (2N, replicated)] — keep whole
            out[k] = P(None, b, None, None)
        else:
            out[k] = P(*((None, b) + (None,) * (len(shp) - 2)))
    return out


def activation_rules(cfg: ModelConfig, mesh: Mesh, batch: int) -> Dict[str, NamedSharding]:
    """Residual stream: batch over data, replicated over model (Megatron).

    Deliberately NO constraint on "attn_out": the head-sharded attention
    output must flow *sharded* into the row-parallel wo matmul, whose
    partial sums all-reduce once.  Constraining it replicated forced an
    all-gather + 16x-redundant wo compute (§Perf iteration Q1 — found via
    the dry-run collective breakdown: 460 GB/chip of spurious all-gathers
    on qwen3 train_4k).
    """
    b = _dp_spec_or_none(mesh, batch)
    res = NamedSharding(mesh, P(b, None, None))
    return {"embed": res, "residual": res}

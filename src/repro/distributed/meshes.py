"""Mesh construction, sub-mesh carving, and the pod topology abstraction.

Sub-mesh carving is the mechanical substrate of EcoSched's co-scheduling:
a job assigned ``g`` allocation units gets a ``jax.sharding.Mesh`` over a
*contiguous* slice of the pod's devices (ICI contiguity — the analogue of
the paper's NUMA-domain constraint), and jobs on disjoint sub-meshes run
concurrently with zero JAX-level interaction, exactly like
``CUDA_VISIBLE_DEVICES`` partitions on a GPU node.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import numpy as np

import jax
from jax.sharding import Mesh

from repro import compat


def make_mesh(shape: Sequence[int], axes: Sequence[str], devices=None) -> Mesh:
    """jax.make_mesh wrapper pinning Auto axis types (pjit-style propagation)."""
    if devices is None:
        return jax.make_mesh(
            tuple(shape), tuple(axes), **compat.auto_axis_types(len(axes))
        )
    arr = np.asarray(devices).reshape(tuple(shape))
    return Mesh(arr, tuple(axes), **compat.auto_axis_types(len(axes)))


def carve_submesh(
    devices: Sequence, start: int, count: int, *, model_axis: int = 0
) -> Mesh:
    """A (data, model) mesh over devices[start:start+count].

    ``model_axis``: requested model-parallel width (defaults to everything
    on one axis).  Used by the co-scheduled launcher: each job gets its own
    contiguous device block.
    """
    block = list(devices[start : start + count])
    assert len(block) == count, (start, count, len(devices))
    model = model_axis or count
    assert count % model == 0, (count, model)
    return make_mesh((count // model, model), ("data", "model"), devices=block)


# ---------------------------------------------------------------------------
# Pod topology: the scheduler-facing resource model
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PodTopology:
    """A multi-accelerator node/pod as EcoSched sees it.

    ``units``            M allocation units (the paper's "GPUs")
    ``chips_per_unit``   chips behind one unit (1 for a GPU node)
    ``domains``          K isolation domains (paper: NUMA sockets); at most
                         K jobs co-run, and a job's units live in
                         contiguous positions (ICI contiguity)
    """

    name: str = "tpu-v5e-pod"
    units: int = 4
    chips_per_unit: int = 64
    domains: int = 2

    @property
    def total_chips(self) -> int:
        return self.units * self.chips_per_unit

    def unit_slice(self, first_unit: int, num_units: int) -> Tuple[int, int]:
        """(device start index, device count) for a contiguous unit range."""
        return first_unit * self.chips_per_unit, num_units * self.chips_per_unit


GPU_NODE_4X = PodTopology(name="gpu-node-4x", units=4, chips_per_unit=1, domains=2)
V5E_POD_256 = PodTopology(name="v5e-pod-256", units=16, chips_per_unit=16, domains=4)

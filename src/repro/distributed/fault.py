"""Fault tolerance: failure injection, straggler detection, elastic rescale.

On a real pod these signals come from the runtime (missing heartbeats,
slow all-reduce participants); here they are injectable so the recovery
paths are *testable on CPU*:

* ``FailureInjector``   — raises ``DeviceFailure`` at a chosen step; the
  Trainer catches it, shrinks the mesh to the surviving devices, restores
  the last checkpoint with the new shardings, and resumes (checkpoints
  are mesh-elastic by construction — checkpoint/ckpt.py).
* ``StragglerMonitor``  — EMA step-time watchdog; sustained deviation
  triggers a re-schedule callback (EcoSched re-invokes its window and can
  rescale the job at the next checkpoint boundary).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional


class DeviceFailure(RuntimeError):
    def __init__(self, message: str, failed_devices: Optional[List[int]] = None):
        super().__init__(message)
        self.failed_devices = failed_devices or []


@dataclass
class FailureInjector:
    """Deterministic failure schedule: {step: number of devices lost}."""

    schedule: dict = field(default_factory=dict)
    fired: set = field(default_factory=set)

    def check(self, step: int):
        if step in self.schedule and step not in self.fired:
            self.fired.add(step)
            n = self.schedule[step]
            raise DeviceFailure(f"injected failure at step {step}: lost {n} device(s)",
                                failed_devices=list(range(n)))


@dataclass
class StragglerMonitor:
    """EMA step-time watchdog (straggler mitigation hook).

    ``on_straggle(step, ratio)`` fires when a step exceeds ``threshold`` ×
    the EMA for ``patience`` consecutive steps.
    """

    alpha: float = 0.1
    threshold: float = 1.8
    patience: int = 3
    on_straggle: Optional[Callable[[int, float], None]] = None

    _ema: float = 0.0
    _strikes: int = 0
    events: List[int] = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        if self._ema == 0.0:
            self._ema = dt
            return False
        ratio = dt / self._ema
        slow = ratio > self.threshold
        self._strikes = self._strikes + 1 if slow else 0
        # slow steps should not drag the EMA up (they are anomalies)
        if not slow:
            self._ema = (1 - self.alpha) * self._ema + self.alpha * dt
        if self._strikes >= self.patience:
            self._strikes = 0
            self.events.append(step)
            if self.on_straggle is not None:
                self.on_straggle(step, ratio)
            return True
        return False

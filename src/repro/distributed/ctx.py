"""Activation-sharding context.

Model code stays mesh-agnostic: it calls ``constrain(x, tag)`` at a few
canonical points ("embed", "residual", "attn_out", ...).  Launchers that
want explicit activation shardings install a rule table (tag →
``NamedSharding``) around tracing; with no rules installed the call is a
no-op, so CPU smoke tests and single-device runs never touch device state.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional

import jax

_tls = threading.local()


def current_rules() -> Optional[Dict[str, object]]:
    return getattr(_tls, "rules", None)


@contextlib.contextmanager
def sharding_rules(rules: Optional[Dict[str, object]]):
    """Install tag → NamedSharding constraints for the enclosed trace."""
    old = getattr(_tls, "rules", None)
    _tls.rules = rules
    try:
        yield
    finally:
        _tls.rules = old


def constrain(x: jax.Array, tag: str) -> jax.Array:
    rules = getattr(_tls, "rules", None)
    if not rules:
        return x
    sharding = rules.get(tag)
    if sharding is None:
        return x
    return jax.lax.with_sharding_constraint(x, sharding)


@contextlib.contextmanager
def mesh_context(mesh):
    """Install the active mesh for modules that need explicit collectives
    (e.g. the expert-parallel MoE shard_map path)."""
    old = getattr(_tls, "mesh", None)
    _tls.mesh = mesh
    try:
        yield
    finally:
        _tls.mesh = old


def current_mesh():
    return getattr(_tls, "mesh", None)

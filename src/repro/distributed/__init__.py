from repro.distributed.ctx import constrain, sharding_rules

__all__ = ["constrain", "sharding_rules"]

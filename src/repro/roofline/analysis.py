"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds (assignment §Roofline):

    compute    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory     = HLO_bytes / (chips × HBM_bw)
    collective = collective_bytes / (chips × link_bw)

``cost_analysis()`` reports the *per-device* partitioned module, and counts
a ``lax.scan`` (while-loop) body **once** — so totals are reconstructed by
compiling three module variants (0 layers / 1 period / full) and
extrapolating:  total = C0 + (L / period) · (C1 − C0)   (DESIGN.md §4).

Collective bytes are parsed from the compiled HLO text: every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
op contributes its result-shape bytes (``-start`` counted, ``-done``
skipped).  This is a per-device byte count, matching the per-chip link
bandwidth in the denominator.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

from repro.roofline.hw import ChipSpec

COLLECTIVE_KINDS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

# result types of an HLO op: "f32[16,64]{1,0}" possibly inside a tuple
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(?P<result>.*?)\s+"
    r"(?P<op>all-gather-start|all-gather|all-reduce-start|all-reduce|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)\s*\(",
)


def _shape_bytes(result: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(result):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-kind result bytes of every collective op in an HLO module."""
    out: Dict[str, int] = {k: 0 for k in COLLECTIVE_KINDS}
    counts: Dict[str, int] = {k: 0 for k in COLLECTIVE_KINDS}
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        op = m.group("op").replace("-start", "")
        out[op] += _shape_bytes(m.group("result"))
        counts[op] += 1
    out["_counts"] = counts  # type: ignore[assignment]
    return out


def cost_summary(cost) -> Dict[str, float]:
    if isinstance(cost, (list, tuple)):  # jax 0.4: one dict per computation
        cost = cost[0] if cost else {}
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    if byts == 0.0:
        byts = sum(
            float(v) for k, v in cost.items() if k.startswith("bytes accessed")
        )
    return {"flops": flops, "bytes": byts, "transcendentals": float(cost.get("transcendentals", 0.0))}


@dataclass
class CellCost:
    """Extrapolated per-device totals for one dry-run cell."""

    flops: float
    bytes: float
    coll_bytes: float
    coll_by_kind: Dict[str, float]
    coll_counts: Dict[str, int]


def extrapolate(
    c0: Dict[str, float],
    c1: Dict[str, float],
    cfull: Dict[str, float],
    *,
    periods_total: int,
) -> Dict[str, float]:
    """total = C0 + periods_total · (C1 − C0), with a floor at Cfull."""
    out = {}
    keys = set(c0) | set(c1) | set(cfull)
    for k in keys:
        a, b, f = c0.get(k, 0.0), c1.get(k, 0.0), cfull.get(k, 0.0)
        per_period = max(b - a, 0.0)
        out[k] = max(a + periods_total * per_period, f)
    return out


def roofline_terms(
    flops: float, byts: float, coll: float, *, chips: int, chip: ChipSpec,
    per_device: bool = True,
) -> Dict[str, float]:
    """Terms in seconds.  ``per_device=True``: inputs are per-device already
    (the partitioned module), so the chips factor is dropped."""
    div = 1 if per_device else chips
    t_compute = flops / (div * chip.peak_flops_bf16)
    t_memory = byts / (div * chip.hbm_bw)
    t_coll = coll / (div * chip.ici_bw)
    dominant = max(
        ("compute", t_compute), ("memory", t_memory), ("collective", t_coll),
        key=lambda kv: kv[1],
    )[0]
    bound = max(t_compute, t_memory, t_coll)
    return {
        "t_compute": t_compute,
        "t_memory": t_memory,
        "t_collective": t_coll,
        "t_bound": bound,
        "dominant": dominant,
    }


def model_flops(cfg, cell, *, original_cfg=None) -> float:
    """MODEL_FLOPS = 6·N·D (train) or 2·N·D (fwd), N = active params.

    Attention score/value FLOPs are added explicitly (they are not in N·D):
    12·L·hd·H·S per token causal-halved for train/prefill; 4·L·H·hd·S_cache
    per decoded token (2 matmuls × 2 flops, GQA on the query side).
    """
    c = original_cfg or cfg
    n_active = c.active_param_count()
    tokens = cell.tokens_per_step
    if cell.kind == "train":
        base = 6.0 * n_active * tokens
    else:
        base = 2.0 * n_active * tokens
    attn = 0.0
    if c.uses_attention:
        H, hd, L = c.num_heads, c.resolved_head_dim, c.num_layers
        if cell.kind in ("train", "prefill"):
            per_tok = 2 * 2 * H * hd * (cell.seq_len / 2)  # causal half
            if c.attention_pattern == "local_global":
                period_ = c.local_global_ratio + 1
                frac_g = 1.0 / period_
                w = min(c.sliding_window, cell.seq_len)
                per_tok = 2 * 2 * H * hd * (
                    frac_g * cell.seq_len / 2 + (1 - frac_g) * w
                )
            attn = L * per_tok * tokens
            if cell.kind == "train":
                attn *= 3  # fwd + 2x bwd
        else:
            per_tok = 2 * 2 * H * hd * cell.seq_len
            if c.attention_pattern == "local_global":
                period_ = c.local_global_ratio + 1
                frac_g = 1.0 / period_
                w = min(c.sliding_window, cell.seq_len)
                per_tok = 2 * 2 * H * hd * (frac_g * cell.seq_len + (1 - frac_g) * w)
            attn = L * per_tok * tokens
    return base + attn


# ---------------------------------------------------------------------------
# Post-hoc term derivation from a dry-run record (bench_roofline / tpu_pod).
#
# The CPU backend legalizes bf16 compute to f32 and fuses far less than the
# TPU backend, so raw HLO "bytes accessed" overstates TPU HBM traffic by a
# large, workload-dependent factor (verified by HLO inspection,
# EXPERIMENTS.md §Dry-run caveats).  The *memory term* therefore uses an
# analytic HBM-traffic model — the bytes that MUST move:
#   decode   : all arguments once (params + KV cache) + cache append
#   prefill  : params + 2 residual passes/layer + KV-cache write
#   train    : params+opt once + residual stream passes/layer
#              (4 = fwd in/out + bwd in/out; +2 with full remat recompute)
# The raw HLO bytes stay in every record ("t_memory_hlo") as the
# pessimistic bound, and hillclimb iterations report both.
# ---------------------------------------------------------------------------


def hbm_floor_bytes(record: dict, cfg, cell, *, dp: int, mp: int) -> float:
    args = float(record["memory"]["argument_bytes"])
    opts = record.get("opts", {})
    accum = max(int(opts.get("grad_accum", 1)), 1)
    remat = opts.get("remat", "full")
    if cell.kind == "decode":
        touched = args
        b_chip = (
            cell.global_batch / dp if cell.global_batch % max(dp, 1) == 0 else cell.global_batch
        )
        if opts.get("window_slice") and cfg.sliding_window and cfg.uses_attention:
            # local layers read only the window, not the whole cache
            period = (cfg.local_global_ratio + 1) if cfg.attention_pattern == "local_global" else 1
            n_global = (
                cfg.num_layers // period if cfg.attention_pattern == "local_global"
                else (0 if cfg.attention_pattern == "local" else cfg.num_layers)
            )
            n_local = cfg.num_layers - n_global
            kv_tok = 2 * max(cfg.num_kv_heads, 1) * cfg.resolved_head_dim * 2  # bytes
            full_cache = cfg.num_layers * b_chip * cell.seq_len * kv_tok / mp
            kept = (
                n_global * b_chip * cell.seq_len * kv_tok / mp
                + n_local * b_chip * min(cfg.sliding_window, cell.seq_len) * kv_tok / mp
            )
            touched = args - full_cache + kept
        return touched + 4 * b_chip * cfg.d_model * 2
    tokens_chip = cell.tokens_per_step / max(dp, 1)
    if cell.kind == "prefill":
        passes = 2
        kv_write = (
            cfg.num_layers * tokens_chip * 2 * max(cfg.num_kv_heads, 1)
            * cfg.resolved_head_dim * 2 / mp
        )
        return args + passes * 2 * tokens_chip * cfg.d_model * cfg.num_layers + kv_write
    passes = {"none": 4, "dots": 5, "full": 6}.get(remat, 6)
    act = passes * 2 * tokens_chip * cfg.d_model * max(cfg.num_layers, 1)
    logits = 2 * tokens_chip * (cfg.vocab_size / mp) * 4  # fwd+bwd, f32
    return args + act + logits


def derive_terms(record: dict, cfg, cell, chip) -> dict:
    """Roofline terms for one dry-run record, memory from the HBM floor."""
    mesh = record["mesh"]
    dims = [int(x) for x in mesh.split("x")]
    mp = dims[-1]
    dp = 1
    for d in dims[:-1]:
        dp *= d
    totals = record["cost_totals"]
    t_compute = totals["flops"] / chip.peak_flops_bf16
    t_mem_hlo = totals["bytes"] / chip.hbm_bw
    floor = hbm_floor_bytes(record, cfg, cell, dp=dp, mp=mp)
    t_memory = floor / chip.hbm_bw
    t_coll = totals["coll_bytes"] / chip.ici_bw
    t_bound = max(t_compute, t_memory, t_coll)
    dominant = max(
        ("compute", t_compute), ("memory", t_memory), ("collective", t_coll),
        key=lambda kv: kv[1],
    )[0]
    mf_chip = record["model_flops_total"] / record["chips"]
    ideal = mf_chip / chip.peak_flops_bf16
    # memory-side ideal: for decode the floor IS the ideal; roofline
    # fraction = ideal-time / bound where ideal includes mandatory bytes
    ideal_mem = floor / chip.hbm_bw if cell.kind == "decode" else 0.0
    frac = max(ideal, ideal_mem) / t_bound if t_bound else 0.0
    return {
        "t_compute": t_compute,
        "t_memory": t_memory,
        "t_memory_hlo": t_mem_hlo,
        "t_collective": t_coll,
        "t_bound": t_bound,
        "dominant": dominant,
        "useful_flops_ratio": (mf_chip / totals["flops"]) if totals["flops"] else 0.0,
        "roofline_fraction": frac,
    }

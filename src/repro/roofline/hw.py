"""Hardware constants for roofline terms and power models.

TPU v5e numbers are the assignment's constants; GPU entries calibrate the
paper-reproduction workload (idle power 70 W/GPU is from the paper §V-C).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class ChipSpec:
    """Per-chip constants.  ``freq_ratios``/``power_floor`` parameterize the
    DVFS sweet-spot model (core/calibration.py): level ``f`` clocks the chip
    at ``freq_ratios[f]`` × base, dynamic power scales ~cubically with the
    ratio above a ``power_floor`` static fraction, and per-app slowdown is
    sub-linear in the clock drop (memory-bound work barely slows).  A
    single-entry ratio tuple means the chip exposes no DVFS levels."""

    name: str
    peak_flops_bf16: float  # FLOP/s
    hbm_bw: float  # bytes/s
    ici_bw: float  # bytes/s per link (all links combined per chip ~ 2-3x)
    hbm_bytes: float
    power_peak: float  # W, busy at full utilization
    power_idle: float  # W
    freq_ratios: Tuple[float, ...] = (1.0,)  # level f -> clock / base clock
    power_floor: float = 0.30  # static fraction of busy power (no f scaling)

    def freq_time_multiplier(self, f: int, mu: float) -> float:
        """Runtime multiplier at level ``f`` for a workload whose
        memory-bound fraction is ``mu``: compute time stretches as 1/ratio,
        the memory-bound fraction not at all — the classic sub-linear
        slowdown that creates below-base sweet spots."""
        r = self.freq_ratios[f]
        return mu + (1.0 - mu) / r

    def freq_power_multiplier(self, f: int) -> float:
        """Busy-power multiplier at level ``f``: static floor plus a
        cubic-ish dynamic term (P_dyn ∝ V²f with voltage tracking f)."""
        r = self.freq_ratios[f]
        return self.power_floor + (1.0 - self.power_floor) * r**3


TPU_V5E = ChipSpec(
    name="tpu-v5e",
    peak_flops_bf16=197e12,
    hbm_bw=819e9,
    ici_bw=50e9,
    hbm_bytes=16e9,
    power_peak=220.0,
    power_idle=60.0,
)

# GPU specs for the paper-calibrated systems (F32/TF32 class numbers are not
# needed — the scheduler only uses power and relative-runtime curves).
# DVFS ratio ladders follow the published core-clock ranges (Afzal et al.:
# sweet spots sit well below max clocks on all three generations); level 0
# is always the base clock so count-only callers never see the ladder.
H100 = ChipSpec("h100", 989e12, 3350e9, 450e9, 80e9, 700.0, 70.0,
                freq_ratios=(1.0, 0.86, 0.72, 0.58), power_floor=0.32)
A100 = ChipSpec("a100", 312e12, 2039e9, 300e9, 80e9, 400.0, 55.0,
                freq_ratios=(1.0, 0.84, 0.70, 0.56), power_floor=0.30)
V100 = ChipSpec("v100", 125e12, 900e9, 150e9, 32e9, 300.0, 40.0,
                freq_ratios=(1.0, 0.82, 0.66), power_floor=0.28)

CHIPS = {c.name: c for c in (TPU_V5E, H100, A100, V100)}

"""Hardware constants for roofline terms and power models.

TPU v5e numbers are the assignment's constants; GPU entries calibrate the
paper-reproduction workload (idle power 70 W/GPU is from the paper §V-C).
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ChipSpec:
    name: str
    peak_flops_bf16: float  # FLOP/s
    hbm_bw: float  # bytes/s
    ici_bw: float  # bytes/s per link (all links combined per chip ~ 2-3x)
    hbm_bytes: float
    power_peak: float  # W, busy at full utilization
    power_idle: float  # W


TPU_V5E = ChipSpec(
    name="tpu-v5e",
    peak_flops_bf16=197e12,
    hbm_bw=819e9,
    ici_bw=50e9,
    hbm_bytes=16e9,
    power_peak=220.0,
    power_idle=60.0,
)

# GPU specs for the paper-calibrated systems (F32/TF32 class numbers are not
# needed — the scheduler only uses power and relative-runtime curves).
H100 = ChipSpec("h100", 989e12, 3350e9, 450e9, 80e9, 700.0, 70.0)
A100 = ChipSpec("a100", 312e12, 2039e9, 300e9, 80e9, 400.0, 55.0)
V100 = ChipSpec("v100", 125e12, 900e9, 150e9, 32e9, 300.0, 40.0)

CHIPS = {c.name: c for c in (TPU_V5E, H100, A100, V100)}

from repro.roofline import analysis, hw

__all__ = ["analysis", "hw"]

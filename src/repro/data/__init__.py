from repro.data.synthetic import DataConfig, SyntheticLM, make_dataset

__all__ = ["DataConfig", "SyntheticLM", "make_dataset"]

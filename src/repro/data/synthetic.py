"""Deterministic synthetic token pipeline.

Every batch is a pure function of (seed, step, shard) — reproducible across
restarts and elastic re-sharding, with no host-to-host coordination: each
data-parallel host slices its rows of the global batch by index
(``host_slice``).  The stream has learnable structure (an affine
token-chain corrupted with Zipf noise) so the end-to-end training examples
show a real loss curve, and a known floor: CE can approach
``-(1-p)·log(1-p)...`` of the mixture rather than ``log V``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import numpy as np

from repro.configs.base import ModelConfig, ShapeCell


@dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    noise_p: float = 0.2  # fraction of tokens drawn from a Zipf tail
    chain_mult: int = 3
    chain_add: int = 7


class SyntheticLM:
    """Markov-chain token stream: t+1 = (a·t + b) mod V, with Zipf noise."""

    def __init__(self, cfg: ModelConfig, batch: int, seq_len: int, dcfg: DataConfig = DataConfig()):
        self.cfg = cfg
        self.batch = batch
        self.seq_len = seq_len
        self.dcfg = dcfg
        # Zipf weights over a 1024-token "head" of the vocab
        head = min(1024, cfg.vocab_size)
        w = 1.0 / np.arange(1, head + 1, dtype=np.float64)
        self._zipf_head = head
        self._zipf_cdf = np.cumsum(w / w.sum())

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.dcfg.seed, step, self.cfg.vocab_size])
        )

    def global_batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg, B, S = self.cfg, self.batch, self.seq_len
        rng = self._rng(step)
        V = cfg.vocab_size
        t0 = rng.integers(0, V, (B, 1), dtype=np.int64)
        toks = np.empty((B, S), np.int64)
        toks[:, 0] = t0[:, 0]
        noise_mask = rng.random((B, S)) < self.dcfg.noise_p
        zipf_draws = np.searchsorted(self._zipf_cdf, rng.random((B, S)))
        for i in range(1, S):
            nxt = (toks[:, i - 1] * self.dcfg.chain_mult + self.dcfg.chain_add) % V
            toks[:, i] = np.where(noise_mask[:, i], zipf_draws[:, i], nxt)
        batch: Dict[str, np.ndarray] = {"tokens": toks.astype(np.int32)}
        if cfg.frontend == "patch_stub":
            batch["patch_embeds"] = rng.standard_normal(
                (B, cfg.num_frontend_tokens, cfg.d_model), np.float32
            )
        if cfg.is_encoder_decoder:
            batch["src_embeds"] = rng.standard_normal((B, S, cfg.d_model), np.float32)
        return batch

    def host_slice(self, step: int, host_idx: int, num_hosts: int) -> Dict[str, np.ndarray]:
        """The rows of the global batch owned by this host (no comm)."""
        assert self.batch % num_hosts == 0, (self.batch, num_hosts)
        per = self.batch // num_hosts
        g = self.global_batch(step)
        return {k: v[host_idx * per : (host_idx + 1) * per] for k, v in g.items()}


def make_dataset(cfg: ModelConfig, cell: ShapeCell, seed: int = 0) -> SyntheticLM:
    return SyntheticLM(cfg, cell.global_batch, cell.seq_len, DataConfig(seed=seed))

"""JAX version-compatibility shims.

The code targets the current pallas/sharding APIs, but several of them were
renamed across JAX 0.4 -> 0.5 and the container pins 0.4.x:

  * ``pltpu.CompilerParams``        is ``TPUCompilerParams`` on 0.4,
  * ``jax.sharding.AxisType`` and the mesh ``axis_types=`` kwarg do not
    exist on 0.4 (Auto propagation is the only — and default — behavior),
  * ``AbstractMesh`` takes ``(sizes, names)`` on 0.5+ but a single
    ``shape_tuple`` of (name, size) pairs on 0.4.

Everything version-dependent goes through this module so call sites stay
written against the modern API.
"""
from __future__ import annotations

import jax
import jax.experimental.pallas.tpu as pltpu

# Renamed CompilerParams (0.5+) <- TPUCompilerParams (0.4).
CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def shard_map(*args, **kwargs):
    """``jax.shard_map`` (0.5+) <- ``jax.experimental.shard_map`` (0.4).

    The replication-check kwarg was renamed ``check_rep`` -> ``check_vma``
    in a different release than the promotion to ``jax.shard_map``, so the
    translation keys on the resolved function's actual signature.
    """
    import inspect

    fn = getattr(jax, "shard_map", None)
    if fn is None:
        from jax.experimental.shard_map import shard_map as fn
    if "check_vma" in kwargs:
        params = inspect.signature(fn).parameters
        if "check_vma" not in params:
            val = kwargs.pop("check_vma")
            if "check_rep" in params:
                kwargs["check_rep"] = val
    return fn(*args, **kwargs)


def auto_axis_types(n_axes: int) -> dict:
    """``{"axis_types": (Auto,) * n}`` where supported, else ``{}``.

    On JAX 0.4 meshes have no axis_types and behave as all-Auto, so
    omitting the kwarg is semantically identical.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def abstract_mesh(axis_sizes, axis_names) -> "jax.sharding.AbstractMesh":
    """AbstractMesh across the 0.4/0.5 constructor signatures."""
    from jax.sharding import AbstractMesh

    try:
        return AbstractMesh(tuple(axis_sizes), tuple(axis_names))
    except TypeError:
        return AbstractMesh(tuple(zip(axis_names, axis_sizes)))

"""Production mesh definition (functions only — importing this module never
touches jax device state)."""
from __future__ import annotations

import jax

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 multi-pod (512 chips) mesh.

    Axes: ``data`` = batch/DP (+ZeRO), ``model`` = TP/EP, ``pod`` = DP
    across pods (gradient all-reduce crosses the inter-pod links only on
    this axis; TP stays inside a pod).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **compat.auto_axis_types(len(axes)))

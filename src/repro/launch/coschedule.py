import os
if os.environ.get("REPRO_HOST_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={os.environ['REPRO_HOST_DEVICES']}"
    )

"""EcoSched-driven co-scheduled launcher — the paper's loop driving REAL
JAX jobs on carved sub-meshes.

    REPRO_HOST_DEVICES=8 PYTHONPATH=src python -m repro.launch.coschedule \
        --jobs granite-8b,mamba2-2.7b,qwen3-32b --steps 30

Each job is a reduced-config training run.  Phase I profiles every job
briefly (a few measured steps per feasible unit count — the real
measurement analogue of the paper's debug-node profiling), Phase II picks
the joint action with Eq. (1), and launched jobs train concurrently in
threads, each on its own contiguous device block (the
``CUDA_VISIBLE_DEVICES`` analogue).  Completions re-invoke the scheduler,
exactly as in core/ecosched.py — this is the same policy object, driven
by wall-clock events instead of the simulator.
"""

import argparse
import threading
import time
from typing import Dict, List

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.core.ecosched import EcoSched
from repro.core.perfmodel import _mk_spec
from repro.core.placement import PlacementState
from repro.core.types import JobSpec, Launch, NodeView, RunningJob
from repro.data import SyntheticLM
from repro.models import Runtime, build_model
from repro.optim import AdamW, AdamWConfig, WarmupCosine
from repro.train.loop import Trainer, TrainerConfig


class MeasuredPerfModel:
    """Phase I by real measurement: time a few steps per unit count."""

    def __init__(self, jobs: Dict[str, dict], devices, profile_steps: int = 3):
        self.jobs = jobs
        self.devices = devices
        self.profile_steps = profile_steps
        self._cache: Dict[str, JobSpec] = {}

    def spec(self, name: str) -> JobSpec:
        if name in self._cache:
            return self._cache[name]
        job = self.jobs[name]
        t_hat, p_hat = {}, {}
        for g in job["counts"]:
            devs = self.devices[: g]
            trainer = _make_trainer(job, devs, steps=self.profile_steps, tag=f"prof{g}")
            t0 = time.perf_counter()
            trainer.run()
            dt = (time.perf_counter() - t0) / self.profile_steps
            t_hat[g] = dt
            p_hat[g] = 60.0 + 140.0 * g  # CPU power model stand-in
        self._cache[name] = _mk_spec(name, t_hat, p_hat)
        return self._cache[name]

    def profiling_energy(self, name: str) -> float:
        return 0.0


def _make_trainer(job: dict, devices, steps: int, tag: str) -> Trainer:
    cfg = job["cfg"]
    model = build_model(cfg, Runtime(remat="none"))
    opt = AdamW(AdamWConfig())
    sched = WarmupCosine(peak_lr=1e-3, warmup_steps=2, decay_steps=steps)
    data = SyntheticLM(cfg, job["batch"], job["seq"])
    return Trainer(
        cfg, model, opt, sched, data,
        TrainerConfig(
            total_steps=steps, ckpt_every=10**9, log_every=10**9,
            ckpt_dir=f"/tmp/repro_cosched/{job['name']}_{tag}",
        ),
        devices=list(devices),
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--jobs", default="granite-8b,mamba2-2.7b,phi4-mini-3.8b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--domains", type=int, default=2)
    ap.add_argument("--lam", type=float, default=0.35)
    ap.add_argument("--tau", type=float, default=0.45)
    args = ap.parse_args()

    devices = jax.devices()
    M = len(devices)
    counts = tuple(g for g in (1, 2, 4, 8) if g <= M)
    jobs = {}
    for name in args.jobs.split(","):
        cfg = reduced(get_config(name.strip()))
        jobs[cfg.name] = {
            "name": cfg.name, "cfg": cfg, "batch": args.batch,
            "seq": args.seq, "counts": counts, "steps": args.steps,
        }

    print(f"coschedule: {len(jobs)} jobs on {M} devices, K={args.domains}")
    pm = MeasuredPerfModel(jobs, devices)
    t_prof = time.perf_counter()
    for name in jobs:
        spec = pm.spec(name)
        print(f"  profiled {name}: " + " ".join(
            f"g={m.g}:t̂={m.t_norm:.2f}/ê={m.e_norm:.2f}" for m in spec.modes))
    print(f"  (Phase I took {time.perf_counter()-t_prof:.1f}s)")

    policy = EcoSched(pm, lam=args.lam, tau=args.tau)
    placement = PlacementState(M, args.domains)
    waiting = list(jobs)
    running: Dict[str, dict] = {}
    lock = threading.Condition()
    t_start = time.perf_counter()
    timeline: List[str] = []

    def job_thread(name: str, g: int, units):
        trainer = _make_trainer(jobs[name], [devices[u] for u in units], steps=args.steps, tag="run")
        out = trainer.run()
        with lock:
            timeline.append(
                f"t={time.perf_counter()-t_start:6.1f}s  finish {name} (loss {out['final_loss']:.3f})"
            )
            placement.release(units, running[name]["domain"])
            del running[name]
            lock.notify_all()

    with lock:
        while waiting or running:
            view = NodeView(
                t=time.perf_counter() - t_start, total_units=M, domains=args.domains,
                free_units=placement.free_count(),
                running=[RunningJob(n, r["g"], r["units"], r["domain"], 0, 0, 0) for n, r in running.items()],
                free_map=list(placement.free),
                domain_jobs=list(placement.domain_jobs),
            )
            launches = policy.on_event(view, list(waiting)) if waiting else []
            for ln in launches:
                units, dom = placement.allocate(ln.g)
                waiting.remove(ln.job)
                running[ln.job] = {"g": ln.g, "units": units, "domain": dom}
                timeline.append(
                    f"t={time.perf_counter()-t_start:6.1f}s  launch {ln.job} on units {units}"
                )
                th = threading.Thread(target=job_thread, args=(ln.job, ln.g, units), daemon=True)
                th.start()
            if running:
                lock.wait(timeout=1.0)
            elif waiting:
                raise RuntimeError("deadlock: nothing running, queue non-empty")

    print("timeline:")
    for line in timeline:
        print("  " + line)
    print(f"makespan {time.perf_counter()-t_start:.1f}s")


if __name__ == "__main__":
    main()

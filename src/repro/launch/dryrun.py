import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver (assignment deliverable e).

For every (architecture × input-shape × mesh) cell this:
  1. pads the config to TP divisibility (``sharding.shardable``),
  2. builds the real step (train / prefill / decode) with full sharding
     specs, ``.lower().compile()``s it against ShapeDtypeStruct stand-ins —
     no allocation — and records ``memory_analysis()`` (proof it fits) and
     the collective schedule,
  3. compiles two reduced-layer variants (0 layers, 1 period) to undo
     XLA's count-while-body-once accounting and extrapolate true per-device
     FLOPs / bytes / collective bytes (DESIGN.md §4),
  4. derives the three roofline terms vs TPU v5e constants and writes one
     JSON per cell under ``benchmarks/results/dryrun/``.

The 512-device XLA_FLAGS override above MUST precede every other import —
jax locks the device count at first init.  Do not set it anywhere global.

Usage:
  python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--skip-existing]
"""

import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, cell_applicable, get_config, list_archs
from repro.configs.base import ModelConfig, ShapeCell
from repro.distributed import sharding as shd
from repro.distributed.ctx import mesh_context, sharding_rules
from repro.launch.mesh import make_production_mesh
from repro.models import Runtime, build_model
from repro.optim import AdamW, AdamWConfig
from repro.optim.schedule import WarmupCosine
from repro.roofline import analysis as RA
from repro.roofline.hw import TPU_V5E
from repro.train import init_state, make_decode_step, make_prefill, make_train_step

DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "..", "..", "..", "benchmarks", "results", "dryrun")


# ---------------------------------------------------------------------------
# Input stand-ins (ShapeDtypeStruct; weak-type-correct, shardable, no alloc)
# ---------------------------------------------------------------------------


def batch_shapes(cfg: ModelConfig, cell: ShapeCell) -> Dict[str, Tuple[tuple, Any]]:
    """Model-input shapes for a cell: {name: (shape, dtype)}."""
    B, S = cell.global_batch, cell.seq_len
    if cell.kind in ("train", "prefill"):
        tgt = S // 8 if cfg.is_encoder_decoder else S
        out = {"tokens": ((B, tgt), jnp.int32)}
        if cfg.frontend == "patch_stub":
            out["patch_embeds"] = ((B, cfg.num_frontend_tokens, cfg.d_model), jnp.bfloat16)
        if cfg.is_encoder_decoder:
            out["src_embeds"] = ((B, S, cfg.d_model), jnp.bfloat16)
        return out
    return {"token": ((B, 1), jnp.int32)}


def input_specs(cfg: ModelConfig, cell: ShapeCell, mesh=None) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    out = {}
    for name, (shape, dtype) in batch_shapes(cfg, cell).items():
        if mesh is not None:
            spec = shd.batch_specs(cfg, mesh, {name: shape})[name]
            out[name] = jax.ShapeDtypeStruct(shape, dtype, sharding=NamedSharding(mesh, spec))
        else:
            out[name] = jax.ShapeDtypeStruct(shape, dtype)
    return out


def _attach(tree_shapes, spec_tree, mesh):
    return jax.tree_util.tree_map(
        lambda s, p: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=NamedSharding(mesh, p)),
        tree_shapes,
        spec_tree,
        is_leaf=lambda x: isinstance(x, (jax.ShapeDtypeStruct, P)),
    )


# ---------------------------------------------------------------------------
# Per-cell lowering
# ---------------------------------------------------------------------------


def _variant_cfg(cfg: ModelConfig, model, n_periods: int) -> ModelConfig:
    L = n_periods * model.period
    kw = {"num_layers": L}
    if cfg.is_encoder_decoder:
        kw["num_encoder_layers"] = max(
            0, cfg.num_encoder_layers * L // max(cfg.num_layers, 1)
        ) if cfg.num_layers else 0
        if n_periods:
            kw["num_encoder_layers"] = max(1, kw["num_encoder_layers"])
    return cfg.replace(**kw)


def lower_cell(
    cfg: ModelConfig,
    cell: ShapeCell,
    mesh,
    rt: Runtime,
    *,
    opt_dtype: str = "float32",
    zero: bool = True,
    compress: bool = False,
    grad_accum: int = 1,
    lr_peak: float = 3e-4,
):
    """Lower+compile one (cfg × cell) on ``mesh``.  Returns (compiled, lowered)."""
    model = build_model(cfg, rt)
    rules = shd.activation_rules(cfg, mesh, cell.global_batch)

    if cell.kind == "train":
        opt = AdamW(AdamWConfig(state_dtype=opt_dtype, master_weights=zero))
        sched = WarmupCosine(peak_lr=lr_peak)
        state_shape = jax.eval_shape(
            lambda: init_state(model, opt, jax.random.key(0), compress=compress)
        )
        pspecs = shd.param_specs(cfg, mesh, state_shape["params"])
        ospecs = shd.opt_state_specs(cfg, mesh, state_shape["opt"], zero=zero)
        gshards = None
        if zero:
            gshards = jax.tree_util.tree_map(
                lambda sp, leaf: NamedSharding(
                    mesh, shd.zero_extend(sp, tuple(leaf.shape), mesh)
                ),
                pspecs, state_shape["params"],
                is_leaf=lambda x: isinstance(x, P),
            )
        step_fn = make_train_step(
            model, opt, sched, compress=compress, grad_accum=grad_accum,
            grad_shardings=gshards,
        )
        state_specs = {"params": pspecs, "opt": ospecs, "step": P()}
        if compress:
            state_specs["residuals"] = jax.tree_util.tree_map(
                lambda s: shd.zero_extend(s, None, mesh) if False else s, pspecs,
                is_leaf=lambda x: isinstance(x, P),
            )
        state_in = _attach(state_shape, state_specs, mesh)
        batch_in = input_specs(cfg, cell, mesh)
        with mesh_context(mesh):
            metrics_shape = jax.eval_shape(step_fn, state_shape, {
                k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in batch_in.items()
            })[1]
        metric_specs = jax.tree_util.tree_map(lambda _: P(), metrics_shape)
        with mesh, sharding_rules(rules), mesh_context(mesh):
            lowered = jax.jit(
                step_fn,
                out_shardings=(
                    jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), state_specs,
                                           is_leaf=lambda x: isinstance(x, P)),
                    jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), metric_specs,
                                           is_leaf=lambda x: isinstance(x, P)),
                ),
                donate_argnums=(0,),
            ).lower(state_in, batch_in)

    elif cell.kind == "prefill":
        step_fn = make_prefill(model)
        params_shape = jax.eval_shape(lambda: model.init(jax.random.key(0)))
        pspecs = shd.param_specs(cfg, mesh, params_shape)
        params_in = _attach(params_shape, pspecs, mesh)
        batch_in = input_specs(cfg, cell, mesh)
        with mesh, sharding_rules(rules), mesh_context(mesh):
            lowered = jax.jit(step_fn).lower(params_in, batch_in)

    else:  # decode
        step_fn = make_decode_step(model)
        params_shape = jax.eval_shape(lambda: model.init(jax.random.key(0)))
        pspecs = shd.param_specs(cfg, mesh, params_shape)
        params_in = _attach(params_shape, pspecs, mesh)
        cache_shape = jax.eval_shape(
            lambda: model.init_cache(cell.global_batch, cell.seq_len)
        )
        cspecs = shd.cache_specs(
            cfg, mesh, {k: tuple(v.shape) for k, v in cache_shape.items()}
        )
        cache_in = _attach(cache_shape, cspecs, mesh)
        tok = jax.ShapeDtypeStruct(
            (cell.global_batch, 1), jnp.int32,
            sharding=NamedSharding(mesh, shd.batch_specs(cfg, mesh, {"t": (cell.global_batch, 1)})["t"]),
        )
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        with mesh, sharding_rules(rules), mesh_context(mesh):
            lowered = jax.jit(step_fn, donate_argnums=(1,)).lower(
                params_in, cache_in, tok, pos
            )

    compiled = lowered.compile()
    return compiled, lowered


# ---------------------------------------------------------------------------
# Cost extraction with scan-body correction
# ---------------------------------------------------------------------------


def _costs_of(compiled) -> Dict[str, float]:
    cs = RA.cost_summary(compiled.cost_analysis())
    coll = RA.collective_bytes(compiled.as_text())
    counts = coll.pop("_counts")
    cs["coll_bytes"] = float(sum(coll.values()))
    for k, v in coll.items():
        cs[f"coll_{k}"] = float(v)
    cs["_counts"] = counts  # not extrapolated
    return cs


def dryrun_cell(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    rt: Optional[Runtime] = None,
    opt_dtype: Optional[str] = None,
    zero: bool = True,
    compress: bool = False,
    grad_accum: int = 0,
    skip_variants: bool = False,
) -> Dict[str, Any]:
    cell = SHAPES[shape_name]
    cfg0 = get_config(arch)
    ok, why = cell_applicable(cfg0, cell)
    mesh = make_production_mesh(multi_pod=multi_pod)
    model_par = mesh.shape["model"]
    chips = 1
    for v in mesh.shape.values():
        chips *= v
    result: Dict[str, Any] = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "x".join(str(mesh.shape[a]) for a in mesh.axis_names),
        "chips": chips,
        "applicable": ok,
        "skip_reason": why,
    }
    if not ok:
        return result

    cfg, changes = shd.shardable(cfg0, model_par)
    result["pad_changes"] = {k: list(v) for k, v in changes.items()}
    rt = rt or Runtime(remat="full", attn_impl="auto")
    if opt_dtype is None:
        # int8 moments for the MoE monsters, fp32 elsewhere (fits-HBM default)
        opt_dtype = "int8" if cfg.param_count() > 100e9 else "float32"
    if cell.kind == "train" and grad_accum == 0:
        # auto: keep the per-microbatch rows per chip small enough that the
        # scan carry (B/dp × S × d per layer) stays well inside HBM
        rows = cell.global_batch // shd.mesh_dp_size(mesh)
        grad_accum = max(1, min(8, rows // 2))
    elif grad_accum == 0:
        grad_accum = 1
    result["opts"] = {
        "remat": rt.remat, "attn_impl": rt.attn_impl, "opt_dtype": opt_dtype,
        "zero": zero, "compress": compress, "grad_accum": grad_accum,
        "window_slice": rt.decode_window_slice, "moe_impl": rt.moe_impl,
    }

    model = build_model(cfg, rt)
    t0 = time.time()
    compiled, lowered = lower_cell(
        cfg, cell, mesh, rt, opt_dtype=opt_dtype, zero=zero, compress=compress,
        grad_accum=grad_accum,
    )
    result["compile_s_full"] = round(time.time() - t0, 2)

    ma = compiled.memory_analysis()
    result["memory"] = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "code_bytes": int(ma.generated_code_size_in_bytes),
    }
    result["hbm_per_device"] = int(
        ma.argument_size_in_bytes + ma.temp_size_in_bytes
        + ma.output_size_in_bytes - ma.alias_size_in_bytes
    )
    # CPU-backend memory_analysis is systematically pessimistic for the
    # TPU target: bf16 buffers are legalized to f32 copies (2x) and the CPU
    # scheduler does not minimize liveness across microbatches (verified by
    # HLO/buffer inspection — EXPERIMENTS.md §Dry-run caveats).  We
    # therefore also report a first-principles TPU HBM model:
    #   args (exact, from memory_analysis — params/opt/cache shards)
    # + remat carry stack  L x (microbatch tokens/chip) x d x 2B
    # + working activations ~6 live residual-sized tensors (fp32)
    # + logits microbatch buffer (fp32, vocab/model sharded)
    # all x1.3 headroom.
    args_b = float(ma.argument_size_in_bytes)
    extra = 0.0
    mp = shd.mesh_model_size(mesh)
    dp = shd.mesh_dp_size(mesh)
    if cell.kind == "train":
        tokens_chip = cell.tokens_per_step / dp / max(grad_accum, 1)
        extra += cfg.num_layers * tokens_chip * cfg.d_model * 2.0  # bf16 carries
        extra += 6 * tokens_chip * cfg.d_model * 4.0
        extra += tokens_chip * (cfg.vocab_size / mp) * 4.0
    elif cell.kind == "prefill":
        tokens_chip = cell.tokens_per_step / dp
        kvh = max(cfg.num_kv_heads, 1)
        extra += (
            cfg.num_layers * tokens_chip * 2 * kvh * cfg.resolved_head_dim * 2.0 / mp
        )  # kv cache output (seq or head sharded over model)
        extra += 6 * tokens_chip * cfg.d_model * 2.0
    else:
        extra += 4 * (cell.global_batch / max(dp, 1)) * cfg.d_model * 4.0
    result["hbm_per_device_tpu_model"] = int((args_b + extra) * 1.3)
    result["fits_hbm_raw"] = bool(result["hbm_per_device"] <= TPU_V5E.hbm_bytes)
    result["fits_hbm"] = bool(result["hbm_per_device_tpu_model"] <= TPU_V5E.hbm_bytes)

    c_full = _costs_of(compiled)
    result["counts_full"] = c_full.pop("_counts")

    if skip_variants:
        totals = c_full
    else:
        # reduced-layer variants for while-body cost correction
        cfg1 = _variant_cfg(cfg, model, 1)
        cfg0L = _variant_cfg(cfg, model, 0)
        t0 = time.time()
        comp1, _ = lower_cell(cfg1, cell, mesh, rt, opt_dtype=opt_dtype, zero=zero, compress=compress, grad_accum=grad_accum)
        comp0, _ = lower_cell(cfg0L, cell, mesh, rt, opt_dtype=opt_dtype, zero=zero, compress=compress, grad_accum=grad_accum)
        result["compile_s_variants"] = round(time.time() - t0, 2)
        c1 = _costs_of(comp1)
        c0 = _costs_of(comp0)
        c1.pop("_counts")
        c0.pop("_counts")
        totals = RA.extrapolate(c0, c1, c_full, periods_total=model.n_scan + (1 if model.n_tail else 0))
        # exact period count: layers / period
        totals = RA.extrapolate(c0, c1, c_full, periods_total=cfg.num_layers / model.period)
        result["cost_L0"] = c0
        result["cost_L1"] = c1
    result["cost_full_module"] = {k: v for k, v in c_full.items()}
    result["cost_totals"] = totals

    mf = RA.model_flops(cfg, cell, original_cfg=cfg0)
    result["model_flops_total"] = mf
    result["model_flops_per_chip"] = mf / chips
    terms = RA.roofline_terms(
        totals["flops"], totals["bytes"], totals["coll_bytes"],
        chips=chips, chip=TPU_V5E, per_device=True,
    )
    # analytic memory floor: params/opt touched once + residual stream
    min_bytes = float(ma.argument_size_in_bytes + ma.output_size_in_bytes)
    if cell.kind != "decode":
        tokens_chip = cell.tokens_per_step / max(shd.mesh_dp_size(mesh), 1)
        min_bytes += 2 * 2 * tokens_chip * cfg.d_model * max(cfg.num_layers, 1)
    result["t_memory_min"] = min_bytes / TPU_V5E.hbm_bw
    result["bw_utilization_vs_min"] = (
        result["t_memory_min"] / terms["t_memory"] if terms["t_memory"] else 0.0
    )
    result["roofline"] = terms
    result["useful_flops_ratio"] = (
        (mf / chips) / totals["flops"] if totals["flops"] else 0.0
    )
    # fraction of the bound the useful model flops could ideally take
    ideal = (mf / chips) / TPU_V5E.peak_flops_bf16
    result["roofline_fraction"] = ideal / terms["t_bound"] if terms["t_bound"] else 0.0
    return result


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=os.environ.get("DRYRUN_OUT", "benchmarks/results/dryrun"))
    ap.add_argument("--tag", default="", help="suffix for result filenames (hillclimb variants)")
    ap.add_argument("--remat", default="full", choices=["none", "full", "dots"])
    ap.add_argument("--attn-impl", default="auto")
    ap.add_argument("--window-slice", action="store_true")
    ap.add_argument("--moe-impl", default="dense", choices=["dense", "ep", "auto"])
    ap.add_argument("--opt-dtype", default=None, choices=[None, "float32", "bfloat16", "int8"])
    ap.add_argument("--no-zero", action="store_true")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--grad-accum", type=int, default=0, help="0 = auto")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--skip-variants", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    rt = Runtime(remat=args.remat, attn_impl=args.attn_impl, decode_window_slice=args.window_slice, moe_impl=args.moe_impl)

    cells = []
    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                cells.append((a, s, mp))

    failures = []
    for arch, shape, mp in cells:
        mesh_tag = "2x16x16" if mp else "16x16"
        name = f"{arch}__{shape}__{mesh_tag}{('__' + args.tag) if args.tag else ''}"
        path = os.path.join(args.out, name + ".json")
        if args.skip_existing and os.path.exists(path):
            print(f"[skip] {name}")
            continue
        print(f"[dryrun] {name} ...", flush=True)
        t0 = time.time()
        try:
            res = dryrun_cell(
                arch, shape,
                multi_pod=mp, rt=rt,
                opt_dtype=args.opt_dtype,
                zero=not args.no_zero,
                compress=args.compress_grads,
                grad_accum=args.grad_accum,
                skip_variants=args.skip_variants,
            )
            res["tag"] = args.tag
            with open(path, "w") as f:
                json.dump(res, f, indent=1)
            if res.get("applicable"):
                r = res["roofline"]
                print(
                    f"  ok in {time.time()-t0:.1f}s  bound={r['t_bound']*1e3:.2f}ms "
                    f"dominant={r['dominant']} frac={res['roofline_fraction']:.2f} "
                    f"hbm_raw={res['hbm_per_device']/1e9:.2f}GB "
                    f"hbm_tpu={res['hbm_per_device_tpu_model']/1e9:.2f}GB fits={res['fits_hbm']}"
                )
            else:
                print(f"  skipped: {res['skip_reason']}")
        except Exception as e:
            failures.append((name, repr(e)))
            print(f"  FAIL {e!r}")
            traceback.print_exc()
    if failures:
        print(f"{len(failures)} FAILURES:")
        for n, e in failures:
            print(" ", n, e)
        raise SystemExit(1)
    print("dry-run complete")


if __name__ == "__main__":
    main()

import os
if os.environ.get("REPRO_HOST_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={os.environ['REPRO_HOST_DEVICES']}"
    )

"""Training launcher.

    REPRO_HOST_DEVICES=8 PYTHONPATH=src python -m repro.launch.train \
        --arch granite-8b --smoke --steps 50 --batch 8 --seq 128 \
        --model-par 2 --fail-at 25

``--smoke`` swaps in the reduced config (CPU-runnable).  ``--fail-at``
injects a device failure to exercise checkpoint/restart + elastic
recovery.  All substrate features are reachable from here: ZeRO, grad
accumulation, int8 optimizer state, gradient compression.
"""

import argparse
import logging

import jax

from repro.configs import get_config, reduced
from repro.data import DataConfig, SyntheticLM
from repro.distributed.fault import FailureInjector
from repro.models import Runtime, build_model
from repro.optim import AdamW, AdamWConfig, WarmupCosine
from repro.train.loop import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--smoke", action="store_true", help="use the reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--model-par", type=int, default=1)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--opt-dtype", default="float32", choices=["float32", "bfloat16", "int8"])
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--remat", default="none", choices=["none", "full", "dots"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--fail-at", type=int, default=0, help="inject device failure at this step")
    ap.add_argument("--fail-devices", type=int, default=1)
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO, format="%(asctime)s %(name)s %(message)s")
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    model = build_model(cfg, Runtime(remat=args.remat))
    opt = AdamW(AdamWConfig(state_dtype=args.opt_dtype))
    sched = WarmupCosine(peak_lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                         decay_steps=args.steps)
    data = SyntheticLM(cfg, args.batch, args.seq, DataConfig(seed=0))
    injector = None
    if args.fail_at:
        injector = FailureInjector(schedule={args.fail_at: args.fail_devices})
    trainer = Trainer(
        cfg, model, opt, sched, data,
        TrainerConfig(
            total_steps=args.steps, ckpt_every=args.ckpt_every,
            ckpt_dir=args.ckpt_dir, grad_accum=args.grad_accum,
            compress=args.compress_grads,
        ),
        model_par=args.model_par,
        failure_injector=injector,
    )
    out = trainer.run()
    print(
        f"done: step={out['final_step']} loss={out['final_loss']:.4f} "
        f"recoveries={out['recoveries']} stragglers={out['straggler_events']}"
    )


if __name__ == "__main__":
    main()

"""Evaluation metrics (paper §IV): energy saving, makespan improvement,
EDP saving, per-application performance loss."""
from __future__ import annotations

from typing import Dict

from repro.core.types import JobProfile, ScheduleResult


def energy_saving(base: ScheduleResult, x: ScheduleResult) -> float:
    return 1.0 - x.total_energy / base.total_energy


def makespan_improvement(base: ScheduleResult, x: ScheduleResult) -> float:
    return 1.0 - x.makespan / base.makespan


def edp_saving(base: ScheduleResult, x: ScheduleResult) -> float:
    return 1.0 - x.edp / base.edp


def perf_loss(result: ScheduleResult, truth: Dict[str, JobProfile]) -> Dict[str, float]:
    """Per-job runtime increase vs. solo execution at the performance-optimal
    count (the paper's Fig. 9 metric).  Preempted jobs have several run
    segments (repro.core.events); their occupied time is summed, so the
    checkpoint/restart overhead shows up as performance loss."""
    occupied: Dict[str, float] = {}
    for r in result.records:
        occupied[r.job] = occupied.get(r.job, 0.0) + (r.end - r.start)
    out = {}
    for job, busy in occupied.items():
        prof = truth[job]
        best = prof.runtime[prof.optimal_count()]
        out[job] = busy / best - 1.0
    return out


def elastic_summary(result) -> Dict[str, float]:
    """Elastic-substrate counters for a ``ScheduleResult`` or
    ``ClusterResult``: checkpoints taken, completed migrations, count
    resizes, and the checkpoint-write energy (already inside busy energy)."""
    migrations = getattr(result, "migrations", None)
    if migrations is None:
        migrations = result.migrations_in
    return {
        "preemptions": result.preemptions,
        "migrations": migrations,
        "resizes": result.resizes,
        "ckpt_energy": result.ckpt_energy,
    }


def summarize(base: ScheduleResult, x: ScheduleResult) -> Dict[str, float]:
    return {
        "energy_saving": energy_saving(base, x),
        "makespan_improvement": makespan_improvement(base, x),
        "edp_saving": edp_saving(base, x),
    }

"""Evaluation metrics (paper §IV): energy saving, makespan improvement,
EDP saving, per-application performance loss."""
from __future__ import annotations

from typing import Dict

from repro.core.types import JobProfile, ScheduleResult


def energy_saving(base: ScheduleResult, x: ScheduleResult) -> float:
    return 1.0 - x.total_energy / base.total_energy


def makespan_improvement(base: ScheduleResult, x: ScheduleResult) -> float:
    return 1.0 - x.makespan / base.makespan


def edp_saving(base: ScheduleResult, x: ScheduleResult) -> float:
    return 1.0 - x.edp / base.edp


def perf_loss(result: ScheduleResult, truth: Dict[str, JobProfile]) -> Dict[str, float]:
    """Per-job runtime increase vs. solo execution at the performance-optimal
    count (the paper's Fig. 9 metric)."""
    out = {}
    for r in result.records:
        prof = truth[r.job]
        best = prof.runtime[prof.optimal_count()]
        out[r.job] = (r.end - r.start) / best - 1.0
    return out


def summarize(base: ScheduleResult, x: ScheduleResult) -> Dict[str, float]:
    return {
        "energy_saving": energy_saving(base, x),
        "makespan_improvement": makespan_improvement(base, x),
        "edp_saving": edp_saving(base, x),
    }

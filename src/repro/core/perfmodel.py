"""Phase I — lightweight online performance modeling (paper §III-B).

The paper profiles each queued application *briefly* at every feasible GPU
count on debug nodes, recording GPU DRAM utilization and power, then maps
utilization to **normalized** runtime — never absolute runtime.

``ProfiledPerfModel`` reproduces that faithfully in simulation: the only
ground-truth it reads is the profiling *signal* (``dram_util`` and busy
power, both measurable in seconds of profiling), plus multiplicative
measurement noise.  The runtime estimator inverts the bandwidth identity

    runtime(g) ∝ mem_work / (util(g) · g · BW_unit)

whose unknown per-app constant cancels under normalization — exactly why
the paper's relative-not-absolute modeling works.  Estimates are computed
once per job and cached (paper: "this profiling stage only needs to be
performed once").

``RooflinePerfModel`` is the beyond-paper TPU variant (DESIGN.md §2): one
compiled dry-run gives the three roofline terms, and scaling a job from g
to g′ sub-slices rescales the terms analytically — one profile instead of
one per count.  Same JobSpec interface, so every policy runs on either.

Any of these can additionally be wrapped by
``repro.core.forecast.RefinedPerfModel`` (ISSUE 5): the Phase-I estimates
become priors that shrink toward observed segment runtimes as jobs
complete — the estimates stay static only on the default (forecast-off)
path.  ``_mk_spec`` is the shared spec constructor all of them (and the
refinement layer) normalize through.
"""
from __future__ import annotations

import math
from typing import Dict, Optional

import numpy as np

from repro.core.types import JobProfile, JobSpec, ModeEstimate

def _stable_seed(*parts) -> int:
    import hashlib

    h = hashlib.md5("|".join(str(p) for p in parts).encode()).digest()
    return int.from_bytes(h[:4], "little")



def _key_gf(k) -> tuple:
    """Normalize a mode key: a bare count ``g`` means (g, base clock);
    a ``(g, f)`` tuple names the joint (count, frequency-level) mode."""
    if isinstance(k, tuple):
        return int(k[0]), int(k[1])
    return int(k), 0


def _mk_spec(name: str, t_hat: Dict, p_hat: Dict) -> JobSpec:
    """Shared spec constructor over the joint mode set.  Keys are bare
    counts (single-frequency — today's behavior, bit-identical) or
    ``(g, f)`` tuples; sorted key order puts modes in (g, f) order, which
    collapses to the historical g order when every key is a bare count."""
    t_min = min(t_hat.values())
    e_raw = {k: p_hat[k] * (t_hat[k] / t_min) for k in t_hat}
    e_min = min(e_raw.values())
    modes = []
    for k in sorted(t_hat):
        g, f = _key_gf(k)
        modes.append(
            ModeEstimate(
                g=g,
                t_norm=t_hat[k] / t_min,
                p_bar=p_hat[k],
                e_norm=e_raw[k] / e_min,
                f=f,
            )
        )
    return JobSpec(name=name, modes=tuple(modes))


class DomainInterferenceModel:
    """Residual-interference slowdown keyed on *actual* domain co-residency
    (ISSUE 4 satellite; PR 2 recorded ``JobRecord.domain`` for this).

    The count-only proxy (``calibration.cross_numa_slowdown``) charges a
    flat penalty whenever *anything* co-runs and a fixed cross-domain
    penalty for g=3 — it cannot distinguish a clean one-job-per-domain
    placement from two jobs squeezed into one domain.  This model reads
    the real placement the simulator just made (``domain_aware = True``
    makes ``NodeSim`` pass it) and composes three effects:

      * ``shared``   — the launched job's home domain already hosts
        another job's home (CPU-side resources genuinely contended),
      * ``span``     — the job's contiguous unit range crosses a domain
        boundary while anything co-runs (remote-domain traffic; the
        paper's 3-GPU case),
      * ``residual`` — co-running in fully disjoint domains (shared
        fabric/power residuals; near 1 with NUMA-aware placement).

    Factors compose multiplicatively; a solo job is always 1.0.
    """

    domain_aware = True

    def __init__(
        self,
        *,
        shared: float = 1.08,
        span: float = 1.05,
        residual: float = 1.02,
    ):
        assert min(shared, span, residual) >= 1.0
        self.shared = shared
        self.span = span
        self.residual = residual

    def __call__(
        self,
        job: str,
        g: int,
        co_running,
        *,
        units=None,
        domain=None,
        running=None,
        total_units=None,
        domains=None,
    ) -> float:
        if not co_running:
            return 1.0
        if units is None or running is None:  # legacy call: count-only info
            return self.residual
        from repro.core.placement import domains_of_units

        factor = self.residual
        if any(r.domain == domain for r in running):
            factor *= self.shared
        if len(domains_of_units(units, total_units, domains)) > 1:
            factor *= self.span
        return factor


class ProfiledPerfModel:
    """Paper-faithful Phase I (simulated brief profiling)."""

    def __init__(
        self,
        truth: Dict[str, JobProfile],
        *,
        noise: float = 0.03,
        seed: int = 0,
    ):
        self.truth = truth
        self.noise = noise
        self.seed = seed
        self._cache: Dict[str, JobSpec] = {}
        # noise-free mode tuples shared per profile *object*: cluster truth
        # tables alias one JobProfile across every instance of an app, so
        # Phase I runs once per app, not once per arriving instance.  The
        # profile list pins the ids the dict is keyed on.
        self._noiseless: Dict[int, tuple] = {}
        self._noiseless_refs: list = []

    def spec(self, job: str) -> JobSpec:
        hit = self._cache.get(job)
        if hit is not None:
            return hit
        prof = self.truth[job]
        if self.noise == 0.0:
            modes = self._noiseless.get(id(prof))
            if modes is None:
                t_hat, p_hat = self._estimate(prof, None)
                modes = _mk_spec(job, t_hat, p_hat).modes
                self._noiseless[id(prof)] = modes
                self._noiseless_refs.append(prof)
            spec = JobSpec(name=job, modes=modes)
        else:
            rng = np.random.default_rng(
                np.random.SeedSequence([self.seed, _stable_seed(job)])
            )
            t_hat, p_hat = self._estimate(prof, rng)
            spec = _mk_spec(job, t_hat, p_hat)
        self._cache[job] = spec
        return spec

    def _estimate(self, prof: JobProfile, rng):
        t_hat, p_hat = {}, {}
        levels = prof.freq_levels
        multi = len(levels) > 1
        for g in prof.feasible_counts:
            util = prof.dram_util.get(g)
            if util:
                # bandwidth-identity estimator from the profiling signal
                t_rel = 1.0 / (util * g)
            else:
                t_rel = prof.runtime[g]  # degenerate fallback (tests)
            eps = 1.0 + (rng.normal(0.0, self.noise) if rng is not None else 0.0)
            p_eps = 1.0 + (
                rng.normal(0.0, self.noise / 2) if rng is not None else 0.0
            )
            if not multi:
                t_hat[g] = t_rel * max(eps, 0.5)
                p_hat[g] = prof.busy_power[g] * p_eps
            else:
                # the frequency response is the chip's analytic curve, so
                # one profiling draw per count fans out across its levels
                # (the noise models count-profiling error, not DVFS)
                for f in levels:
                    t_hat[(g, f)] = t_rel * prof.freq_time[f] * max(eps, 0.5)
                    p_hat[(g, f)] = prof.power_at(g, f) * p_eps
        return t_hat, p_hat

    def profiling_energy(self, job: str) -> float:
        return self.truth[job].profiling_energy


class OraclePerfModel:
    """Perfect-knowledge estimates (used by the Oracle and for ablations)."""

    def __init__(self, truth: Dict[str, JobProfile]):
        self.truth = truth
        self._cache: Dict[str, JobSpec] = {}

    def spec(self, job: str) -> JobSpec:
        if job not in self._cache:
            prof = self.truth[job]
            if len(prof.freq_levels) > 1:
                t_hat = {
                    (g, f): prof.runtime_at(g, f)
                    for g in prof.feasible_counts
                    for f in prof.freq_levels
                }
                p_hat = {
                    (g, f): prof.power_at(g, f)
                    for g in prof.feasible_counts
                    for f in prof.freq_levels
                }
                self._cache[job] = _mk_spec(job, t_hat, p_hat)
            else:
                self._cache[job] = _mk_spec(
                    job, dict(prof.runtime), dict(prof.busy_power)
                )
        return self._cache[job]

    def profiling_energy(self, job: str) -> float:
        return 0.0


class RooflinePerfModel:
    """TPU-mode Phase I: scaling curves from one dry-run roofline point.

    ``cells``: job name → dict with per-chip roofline terms at the
    reference chip count, plus power-model inputs:
        {"chips_ref", "t_compute", "t_memory", "t_collective",
         "alpha_coll" (collective growth exponent, default 0.3)}
    Scaling g_ref → g: compute and memory shard ~1/g; the collective term
    per chip *grows* mildly with participants (ring latency + smaller
    shards): t_coll(g) = t_coll_ref · (g/g_ref)^alpha.
    """

    def __init__(
        self,
        cells: Dict[str, dict],
        *,
        counts=(1, 2, 3, 4),
        chip,
        units_to_chips: int = 64,
    ):
        self.cells = cells
        self.counts = tuple(counts)
        self.counts_for: Dict[str, tuple] = {}  # optional per-job override
        self.chip = chip
        self.units_to_chips = units_to_chips
        self._cache: Dict[str, JobSpec] = {}

    def _terms_at(self, cell: dict, chips: int):
        ref = cell["chips_ref"]
        s = ref / chips  # per-chip work scale factor
        a = cell.get("alpha_coll", 0.3)
        tc = cell["t_compute"] * s
        tm = cell["t_memory"] * s
        tl = cell["t_collective"] * (chips / ref) ** a
        return tc, tm, tl

    def spec(self, job: str) -> JobSpec:
        if job in self._cache:
            return self._cache[job]
        cell = self.cells[job]
        t_hat, p_hat = {}, {}
        for g in self.counts_for.get(job, self.counts):
            chips = g * self.units_to_chips
            tc, tm, tl = self._terms_at(cell, chips)
            t_hat[g] = max(tc, tm, tl)
            util = tc / t_hat[g]
            per_chip = self.chip.power_idle + (
                self.chip.power_peak - self.chip.power_idle
            ) * (0.3 + 0.7 * util)
            p_hat[g] = per_chip * chips
        self._cache[job] = _mk_spec(job, t_hat, p_hat)
        return self._cache[job]

    def profiling_energy(self, job: str) -> float:
        return 0.0  # roofline profile costs one compile, no device energy

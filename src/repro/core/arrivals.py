"""Online arrival streams for the cluster simulator.

The paper evaluates a single static scheduling window; real GPU
datacenters see jobs *arrive over time* (the regime of arXiv:2412.17484 /
arXiv:2304.06381).  This module generates seeded, replayable arrival
streams over the calibrated application mix:

  * ``poisson_stream``  — exponential inter-arrival gaps (rate jobs/s),
  * ``bursty_stream``   — Poisson-spaced bursts of correlated submissions
    (one user submitting a sweep), the heavy-tail pattern trace studies
    report,
  * ``save_trace`` / ``load_trace`` — byte-stable CSV round-trip so a
    stream can be replayed across machines and compared across policies.

All randomness flows through ``np.random.default_rng(seed)``; a fixed
seed yields a byte-identical trace (regression-locked in
tests/test_cluster.py).
"""
from __future__ import annotations

import csv
import io
from dataclasses import dataclass
from typing import List, Sequence

import numpy as np


@dataclass(frozen=True)
class Arrival:
    """One job submission: unique instance ``name`` of application ``app``."""

    t: float
    name: str
    app: str


def _instance(app: str, idx: int) -> str:
    return f"{app}#{idx}"


def poisson_stream(
    apps: Sequence[str],
    *,
    rate: float,
    n: int,
    seed: int = 0,
    start: float = 0.0,
) -> List[Arrival]:
    """``n`` arrivals, exponential gaps with mean ``1/rate`` seconds, app
    drawn uniformly from ``apps``."""
    assert rate > 0 and n >= 0
    rng = np.random.default_rng(seed)
    t = start
    out: List[Arrival] = []
    for i in range(n):
        t += float(rng.exponential(1.0 / rate))
        app = str(apps[int(rng.integers(len(apps)))])
        out.append(Arrival(t=round(t, 6), name=_instance(app, i), app=app))
    return out


def bursty_stream(
    apps: Sequence[str],
    *,
    rate: float,
    n: int,
    burst: int = 4,
    seed: int = 0,
    start: float = 0.0,
) -> List[Arrival]:
    """~``n`` arrivals in bursts of 1..``burst`` jobs submitted together.

    Burst *starts* are Poisson with the given overall job rate scaled by
    the mean burst size, so the long-run job rate still ≈ ``rate``.
    """
    assert rate > 0 and n >= 0 and burst >= 1
    rng = np.random.default_rng(seed)
    mean_burst = (1 + burst) / 2.0
    t = start
    out: List[Arrival] = []
    i = 0
    while i < n:
        t += float(rng.exponential(mean_burst / rate))
        size = min(int(rng.integers(1, burst + 1)), n - i)
        app = str(apps[int(rng.integers(len(apps)))])  # a burst repeats one app
        for _ in range(size):
            out.append(Arrival(t=round(t, 6), name=_instance(app, i), app=app))
            i += 1
    return out


# ---------------------------------------------------------------------------
# Replayable trace files
# ---------------------------------------------------------------------------


def dumps_trace(stream: Sequence[Arrival]) -> str:
    """Canonical CSV serialization (header + ``t,name,app`` rows).

    Times use ``repr`` (shortest exact float form) so the round-trip is
    lossless for *any* stream, not just the 6-decimal generator output.
    Names and apps go through ``csv`` quoting, so adversarial values
    (commas, quotes, even newlines) survive the round-trip instead of
    corrupting neighbouring fields; plain names serialize byte-identically
    to the unquoted legacy format.
    """
    buf = io.StringIO()
    w = csv.writer(buf, lineterminator="\n")
    w.writerow(["t", "name", "app"])
    for a in stream:
        if not a.name or not a.app:
            raise ValueError(f"arrival at t={a.t} has an empty name/app")
        w.writerow([repr(a.t), a.name, a.app])
    return buf.getvalue()


def loads_trace(text: str) -> List[Arrival]:
    rows = csv.reader(io.StringIO(text))
    header = next(rows, None)
    if header is not None and header[:1] != ["t"]:
        raise ValueError(f"not a trace file (header {header!r})")
    out: List[Arrival] = []
    for row in rows:
        if not row:
            continue
        if len(row) != 3:
            raise ValueError(f"malformed trace row {row!r}")
        t, name, app = row
        out.append(Arrival(t=float(t), name=name, app=app))
    return out


def save_trace(path: str, stream: Sequence[Arrival]) -> None:
    with open(path, "w") as f:
        f.write(dumps_trace(stream))


def load_trace(path: str) -> List[Arrival]:
    with open(path) as f:
        return loads_trace(f.read())

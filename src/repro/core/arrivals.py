"""Online arrival streams for the cluster simulator.

The paper evaluates a single static scheduling window; real GPU
datacenters see jobs *arrive over time* (the regime of arXiv:2412.17484 /
arXiv:2304.06381).  This module generates seeded, replayable arrival
streams over the calibrated application mix:

  * ``poisson_stream``  — exponential inter-arrival gaps (rate jobs/s),
  * ``bursty_stream``   — Poisson-spaced bursts of correlated submissions
    (one user submitting a sweep), the heavy-tail pattern trace studies
    report,
  * ``save_trace`` / ``load_trace`` — byte-stable CSV round-trip so a
    stream can be replayed across machines and compared across policies.

All randomness flows through ``np.random.default_rng(seed)``; a fixed
seed yields a byte-identical trace (regression-locked in
tests/test_cluster.py).
"""
from __future__ import annotations

import csv
import datetime as _dt
import io
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Union

import numpy as np


@dataclass(frozen=True)
class Arrival:
    """One job submission: unique instance ``name`` of application ``app``."""

    t: float
    name: str
    app: str


def _instance(app: str, idx: int) -> str:
    return f"{app}#{idx}"


def poisson_stream(
    apps: Sequence[str],
    *,
    rate: float,
    n: int,
    seed: int = 0,
    start: float = 0.0,
) -> List[Arrival]:
    """``n`` arrivals, exponential gaps with mean ``1/rate`` seconds, app
    drawn uniformly from ``apps``."""
    assert rate > 0 and n >= 0
    rng = np.random.default_rng(seed)
    t = start
    out: List[Arrival] = []
    for i in range(n):
        t += float(rng.exponential(1.0 / rate))
        app = str(apps[int(rng.integers(len(apps)))])
        out.append(Arrival(t=round(t, 6), name=_instance(app, i), app=app))
    return out


def bursty_stream(
    apps: Sequence[str],
    *,
    rate: float,
    n: int,
    burst: int = 4,
    seed: int = 0,
    start: float = 0.0,
) -> List[Arrival]:
    """~``n`` arrivals in bursts of 1..``burst`` jobs submitted together.

    Burst *starts* are Poisson with the given overall job rate scaled by
    the mean burst size, so the long-run job rate still ≈ ``rate``.
    """
    assert rate > 0 and n >= 0 and burst >= 1
    rng = np.random.default_rng(seed)
    mean_burst = (1 + burst) / 2.0
    t = start
    out: List[Arrival] = []
    i = 0
    while i < n:
        t += float(rng.exponential(mean_burst / rate))
        size = min(int(rng.integers(1, burst + 1)), n - i)
        app = str(apps[int(rng.integers(len(apps)))])  # a burst repeats one app
        for _ in range(size):
            out.append(Arrival(t=round(t, 6), name=_instance(app, i), app=app))
            i += 1
    return out


# ---------------------------------------------------------------------------
# Replayable trace files
# ---------------------------------------------------------------------------


def dumps_trace(stream: Sequence[Arrival]) -> str:
    """Canonical CSV serialization (header + ``t,name,app`` rows).

    Times use ``repr`` (shortest exact float form) so the round-trip is
    lossless for *any* stream, not just the 6-decimal generator output.
    Names and apps go through ``csv`` quoting, so adversarial values
    (commas, quotes, even newlines) survive the round-trip instead of
    corrupting neighbouring fields; plain names serialize byte-identically
    to the unquoted legacy format.
    """
    buf = io.StringIO()
    w = csv.writer(buf, lineterminator="\n")
    w.writerow(["t", "name", "app"])
    for a in stream:
        if not a.name or not a.app:
            raise ValueError(f"arrival at t={a.t} has an empty name/app")
        w.writerow([repr(a.t), a.name, a.app])
    return buf.getvalue()


def loads_trace(text: str) -> List[Arrival]:
    rows = csv.reader(io.StringIO(text))
    header = next(rows, None)
    if header is not None and header[:1] != ["t"]:
        raise ValueError(f"not a trace file (header {header!r})")
    out: List[Arrival] = []
    for row in rows:
        if not row:
            continue
        if len(row) != 3:
            raise ValueError(f"malformed trace row {row!r}")
        t, name, app = row
        out.append(Arrival(t=float(t), name=name, app=app))
    return out


def save_trace(path: str, stream: Sequence[Arrival]) -> None:
    with open(path, "w") as f:
        f.write(dumps_trace(stream))


def load_trace(path: str) -> List[Arrival]:
    with open(path) as f:
        return loads_trace(f.read())


# ---------------------------------------------------------------------------
# Datacenter log replay (Philly / Helios-style submission CSVs)
# ---------------------------------------------------------------------------


def _parse_submit(raw: str) -> float:
    """Submission time as seconds: plain float, or an ISO-8601 timestamp
    (``2017-10-03 09:14:07``, the Philly/Helios log format).  Naive
    timestamps are pinned to UTC so the parse is machine-independent and
    inter-arrival gaps never pick up DST discontinuities."""
    raw = raw.strip()
    try:
        return float(raw)
    except ValueError:
        pass
    try:
        dt = _dt.datetime.fromisoformat(raw)
    except ValueError as e:
        raise ValueError(f"unparseable submit time {raw!r}") from e
    if dt.tzinfo is None:
        dt = dt.replace(tzinfo=_dt.timezone.utc)
    return dt.timestamp()


def from_datacenter_csv(
    source: str,
    *,
    t_col: str = "submit_time",
    name_col: str = "job_id",
    app_col: str = "app",
    app_map: Optional[Union[Dict[str, str], Callable[[str], Optional[str]]]] = None,
    rebase: bool = True,
    time_scale: float = 1.0,
) -> List[Arrival]:
    """Philly/Helios-style submission log -> replayable ``Arrival`` stream.

    Public GPU-datacenter traces (arXiv:2412.17484 / arXiv:2304.06381 use
    the same shape) are CSVs with one row per submitted job carrying a job
    id, a submission timestamp and some application/model tag.  This loader
    maps them onto the cluster simulator so benches can replay *real*
    arrival shapes (diurnal bursts, heavy-tailed sweeps) against the
    calibrated app mix:

      * ``source``   — a path, or the CSV text itself (anything containing
        a newline is treated as text),
      * ``t_col``    — submission time: float seconds or ISO-8601
        timestamps; with ``rebase`` (default) the earliest submission
        becomes t=0, and ``time_scale`` then compresses/stretches the
        stream (0.5 = replay twice as fast),
      * ``app_col``/``app_map`` — the application tag, optionally mapped
        onto calibrated app names (a dict or callable; rows mapping to
        ``None``/missing are dropped — real logs carry job types the
        calibration does not model),
      * duplicate job ids are uniquified with ``#k`` so the stream
        satisfies the simulator's unique-name contract.

    The result is sorted by time (stable, so same-instant rows keep log
    order) and round-trips byte-stably through ``save_trace``/``load_trace``
    like every generated stream.
    """
    if "\n" in source:
        text = source
    else:
        with open(source) as f:
            text = f.read()
    rows = list(csv.DictReader(io.StringIO(text)))
    if not rows:
        return []
    for col in (t_col, name_col, app_col):
        if col not in rows[0]:
            raise ValueError(
                f"column {col!r} not in trace header {sorted(rows[0])!r}"
            )
    parsed: List[Arrival] = []
    emitted: set = set()
    next_suffix: Dict[str, int] = {}
    for row in rows:
        raw_app = (row[app_col] or "").strip()
        if app_map is None:
            app = raw_app
        elif callable(app_map):
            app = app_map(raw_app)
        else:
            app = app_map.get(raw_app)
        if not app:
            continue  # unmodeled job type
        t = _parse_submit(row[t_col])
        name = (row[name_col] or "").strip()
        if not name:
            raise ValueError(f"row with empty {name_col!r}: {row!r}")
        if name in emitted:
            # synthesized names can collide with ids literally in the log
            # (j1, j1, "j1#1"), so probe until genuinely fresh
            k = next_suffix.get(name, 1)
            while f"{name}#{k}" in emitted:
                k += 1
            next_suffix[name] = k + 1
            name = f"{name}#{k}"
        emitted.add(name)
        parsed.append(Arrival(t=t, name=name, app=app))
    if not parsed:
        return []
    parsed.sort(key=lambda a: a.t)  # stable: same-instant rows keep log order
    t0 = parsed[0].t if rebase else 0.0
    return [
        Arrival(t=round((a.t - t0) * time_scale, 6), name=a.name, app=a.app)
        for a in parsed
    ]

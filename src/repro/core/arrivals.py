"""Online arrival streams for the cluster simulator.

The paper evaluates a single static scheduling window; real GPU
datacenters see jobs *arrive over time* (the regime of arXiv:2412.17484 /
arXiv:2304.06381).  This module generates seeded, replayable arrival
streams over the calibrated application mix:

  * ``poisson_stream``  — exponential inter-arrival gaps (rate jobs/s),
  * ``bursty_stream``   — Poisson-spaced bursts of correlated submissions
    (one user submitting a sweep), the heavy-tail pattern trace studies
    report,
  * ``save_trace`` / ``load_trace`` — byte-stable CSV round-trip so a
    stream can be replayed across machines and compared across policies.

All randomness flows through ``np.random.default_rng(seed)``; a fixed
seed yields a byte-identical trace (regression-locked in
tests/test_cluster.py).

``ArrivalRateEWMA`` is the online inter-arrival-rate estimator feeding
the forecast-driven control plane (``repro.core.forecast``, ISSUE 5): two
exponentially weighted means over recent inter-arrival gaps — a short
horizon that reacts to bursts and a long horizon that anchors the
baseline — whose ratio is the burst signal the plane's hysteresis gates
on.  The short estimate is *censored* at query time by the silence since
the last arrival, so a stale burst reading decays as soon as the stream
goes quiet.
"""
from __future__ import annotations

import csv
import datetime as _dt
import io
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Union

import numpy as np


@dataclass(frozen=True)
class Arrival:
    """One job submission: unique instance ``name`` of application ``app``."""

    t: float
    name: str
    app: str


def _instance(app: str, idx: int) -> str:
    return f"{app}#{idx}"


def poisson_stream(
    apps: Sequence[str],
    *,
    rate: float,
    n: int,
    seed: int = 0,
    start: float = 0.0,
) -> List[Arrival]:
    """``n`` arrivals, exponential gaps with mean ``1/rate`` seconds, app
    drawn uniformly from ``apps``."""
    assert rate > 0 and n >= 0
    rng = np.random.default_rng(seed)
    t = start
    out: List[Arrival] = []
    for i in range(n):
        t += float(rng.exponential(1.0 / rate))
        app = str(apps[int(rng.integers(len(apps)))])
        out.append(Arrival(t=round(t, 6), name=_instance(app, i), app=app))
    return out


def bursty_stream(
    apps: Sequence[str],
    *,
    rate: float,
    n: int,
    burst: int = 4,
    seed: int = 0,
    start: float = 0.0,
) -> List[Arrival]:
    """~``n`` arrivals in bursts of 1..``burst`` jobs submitted together.

    Burst *starts* are Poisson with the given overall job rate scaled by
    the mean burst size, so the long-run job rate still ≈ ``rate``.
    """
    assert rate > 0 and n >= 0 and burst >= 1
    rng = np.random.default_rng(seed)
    mean_burst = (1 + burst) / 2.0
    t = start
    out: List[Arrival] = []
    i = 0
    while i < n:
        t += float(rng.exponential(mean_burst / rate))
        size = min(int(rng.integers(1, burst + 1)), n - i)
        app = str(apps[int(rng.integers(len(apps)))])  # a burst repeats one app
        for _ in range(size):
            out.append(Arrival(t=round(t, 6), name=_instance(app, i), app=app))
            i += 1
    return out


# ---------------------------------------------------------------------------
# Online arrival-rate estimation (forecast plane input, ISSUE 5)
# ---------------------------------------------------------------------------


class ArrivalRateEWMA:
    """Two-horizon EWMA over inter-arrival gaps.

    ``observe(t)`` feeds each arrival instant (monotone non-decreasing;
    same-instant burst members contribute zero gaps, which is exactly the
    burst signature).  ``rate(now)`` inverts the short-horizon mean gap,
    censored by the silence since the last arrival — ``max(gap_ewma,
    now - last)`` — so the estimate cannot stay hot forever after the
    stream stops.  ``burst_factor(now)`` is short-rate / baseline-rate:
    ~1 in steady state, ≫1 while a burst lands, decaying back toward 1
    through the post-burst lull.

    ``horizon`` counts effective samples: the EWMA weight is
    ``2 / (horizon + 1)`` (the classic N-period convention), so
    ``horizon=8`` reacts within a burst or two while
    ``baseline_horizon=64`` smooths over the whole recent stream.  Below
    ``min_samples`` gaps the estimator reports no signal (rate 0, factor
    1) rather than extrapolating from nothing.
    """

    def __init__(
        self,
        horizon: int = 8,
        baseline_horizon: int = 64,
        *,
        min_samples: int = 3,
    ):
        if horizon < 1 or baseline_horizon < 1:
            raise ValueError("EWMA horizons must be >= 1")
        self.alpha_short = 2.0 / (horizon + 1)
        self.alpha_long = 2.0 / (baseline_horizon + 1)
        self.min_samples = min_samples
        self.gap_short: Optional[float] = None
        self.gap_long: Optional[float] = None
        self.last_t: Optional[float] = None
        self.n_gaps = 0

    def observe(self, t: float) -> None:
        if self.last_t is not None:
            gap = max(t - self.last_t, 0.0)
            if self.gap_short is None:
                self.gap_short = gap
                self.gap_long = gap
            else:
                self.gap_short += self.alpha_short * (gap - self.gap_short)
                self.gap_long += self.alpha_long * (gap - self.gap_long)
            self.n_gaps += 1
        self.last_t = max(t, self.last_t) if self.last_t is not None else t

    def _short_gap(self, now: Optional[float]) -> Optional[float]:
        if self.n_gaps < self.min_samples or self.gap_short is None:
            return None
        gap = self.gap_short
        if now is not None and self.last_t is not None:
            gap = max(gap, now - self.last_t)  # censor: silence decays the rate
        return gap

    def rate(self, now: Optional[float] = None) -> float:
        """Short-horizon arrival rate (jobs/s); 0 before warm-up."""
        gap = self._short_gap(now)
        return 0.0 if gap is None else 1.0 / max(gap, 1e-9)

    def baseline_rate(self) -> float:
        """Long-horizon anchor rate (jobs/s); 0 before warm-up."""
        if self.n_gaps < self.min_samples or not self.gap_long:
            return 0.0
        return 1.0 / max(self.gap_long, 1e-9)

    def burst_factor(self, now: Optional[float] = None) -> float:
        """short-rate / baseline-rate; 1.0 whenever either is unwarmed."""
        gap = self._short_gap(now)
        if gap is None or self.gap_long is None:
            return 1.0
        return max(self.gap_long, 1e-9) / max(gap, 1e-9)


# ---------------------------------------------------------------------------
# Replayable trace files
# ---------------------------------------------------------------------------


def dumps_trace(stream: Sequence[Arrival]) -> str:
    """Canonical CSV serialization (header + ``t,name,app`` rows).

    Times use ``repr`` (shortest exact float form) so the round-trip is
    lossless for *any* stream, not just the 6-decimal generator output.
    Names and apps go through ``csv`` quoting, so adversarial values
    (commas, quotes, even newlines) survive the round-trip instead of
    corrupting neighbouring fields; plain names serialize byte-identically
    to the unquoted legacy format.
    """
    buf = io.StringIO()
    w = csv.writer(buf, lineterminator="\n")
    w.writerow(["t", "name", "app"])
    for a in stream:
        if not a.name or not a.app:
            raise ValueError(f"arrival at t={a.t} has an empty name/app")
        w.writerow([repr(a.t), a.name, a.app])
    return buf.getvalue()


def loads_trace(text: str) -> List[Arrival]:
    rows = csv.reader(io.StringIO(text))
    header = next(rows, None)
    if header is not None and header[:1] != ["t"]:
        raise ValueError(f"not a trace file (header {header!r})")
    out: List[Arrival] = []
    for row in rows:
        if not row:
            continue
        if len(row) != 3:
            raise ValueError(f"malformed trace row {row!r}")
        t, name, app = row
        out.append(Arrival(t=float(t), name=name, app=app))
    return out


def save_trace(path: str, stream: Sequence[Arrival]) -> None:
    with open(path, "w") as f:
        f.write(dumps_trace(stream))


def load_trace(path: str) -> List[Arrival]:
    with open(path) as f:
        return loads_trace(f.read())


# ---------------------------------------------------------------------------
# Datacenter log replay (Philly / Helios-style submission CSVs)
# ---------------------------------------------------------------------------


def _parse_submit(raw: str) -> float:
    """Submission time as seconds: plain float, or an ISO-8601 timestamp
    (``2017-10-03 09:14:07``, the Philly/Helios log format).  Naive
    timestamps are pinned to UTC so the parse is machine-independent and
    inter-arrival gaps never pick up DST discontinuities."""
    raw = raw.strip()
    try:
        return float(raw)
    except ValueError:
        pass
    try:
        dt = _dt.datetime.fromisoformat(raw)
    except ValueError as e:
        raise ValueError(f"unparseable submit time {raw!r}") from e
    if dt.tzinfo is None:
        dt = dt.replace(tzinfo=_dt.timezone.utc)
    return dt.timestamp()


def from_datacenter_csv(
    source: str,
    *,
    t_col: str = "submit_time",
    name_col: str = "job_id",
    app_col: str = "app",
    app_map: Optional[Union[Dict[str, str], Callable[[str], Optional[str]]]] = None,
    rebase: bool = True,
    time_scale: float = 1.0,
    duration_col: Optional[str] = None,
    strict: bool = False,
) -> List[Arrival]:
    """Philly/Helios-style submission log -> replayable ``Arrival`` stream.

    Public GPU-datacenter traces (arXiv:2412.17484 / arXiv:2304.06381 use
    the same shape) are CSVs with one row per submitted job carrying a job
    id, a submission timestamp and some application/model tag.  This loader
    maps them onto the cluster simulator so benches can replay *real*
    arrival shapes (diurnal bursts, heavy-tailed sweeps) against the
    calibrated app mix:

      * ``source``   — a path, or the CSV text itself (anything containing
        a newline is treated as text),
      * ``t_col``    — submission time: float seconds or ISO-8601
        timestamps; with ``rebase`` (default) the earliest submission
        becomes t=0, and ``time_scale`` then compresses/stretches the
        stream (0.5 = replay twice as fast),
      * ``app_col``/``app_map`` — the application tag, optionally mapped
        onto calibrated app names (a dict or callable; rows mapping to
        ``None``/missing are dropped — real logs carry job types the
        calibration does not model),
      * duplicate job ids are uniquified with ``#k`` so the stream
        satisfies the simulator's unique-name contract,
      * ``duration_col`` — optional logged-runtime column, validated only:
        a malformed (unparseable, negative or zero) duration raises
        ``ValueError`` naming the row — corrupt rows must never silently
        shape a replay,
      * ``strict`` — promote the two silent normalizations to explicit
        errors: an app with no ``app_map`` entry raises instead of being
        dropped, and out-of-order submit times raise instead of being
        sorted.  Use it when the log is supposed to be clean and a
        surprise would mean the wrong file was loaded.

    The result is sorted by time (stable, so same-instant rows keep log
    order) and round-trips byte-stably through ``save_trace``/``load_trace``
    like every generated stream.
    """
    if "\n" in source:
        text = source
    else:
        with open(source) as f:
            text = f.read()
    rows = list(csv.DictReader(io.StringIO(text)))
    if not rows:
        return []
    for col in (t_col, name_col, app_col) + (
        (duration_col,) if duration_col is not None else ()
    ):
        if col not in rows[0]:
            raise ValueError(
                f"column {col!r} not in trace header {sorted(rows[0])!r}"
            )
    parsed: List[Arrival] = []
    emitted: set = set()
    next_suffix: Dict[str, int] = {}
    prev_t: Optional[float] = None
    for row in rows:
        if duration_col is not None:
            raw_dur = (row[duration_col] or "").strip()
            try:
                dur = float(raw_dur)
            except ValueError as e:
                raise ValueError(
                    f"unparseable {duration_col!r} {raw_dur!r} in row {row!r}"
                ) from e
            if not dur > 0.0:
                raise ValueError(
                    f"non-positive {duration_col!r} {dur!r} in row {row!r}"
                )
        raw_app = (row[app_col] or "").strip()
        if app_map is None:
            app = raw_app
        elif callable(app_map):
            app = app_map(raw_app)
        else:
            app = app_map.get(raw_app)
        if not app:
            if strict:
                raise ValueError(
                    f"app {raw_app!r} has no app_map entry (row {row!r}); "
                    "pass strict=False to drop unmodeled job types"
                )
            continue  # unmodeled job type
        t = _parse_submit(row[t_col])
        if strict and prev_t is not None and t < prev_t:
            raise ValueError(
                f"out-of-order submit time {row[t_col]!r} in row {row!r} "
                "(strict=True; pass strict=False to sort)"
            )
        prev_t = t
        name = (row[name_col] or "").strip()
        if not name:
            raise ValueError(f"row with empty {name_col!r}: {row!r}")
        if name in emitted:
            # synthesized names can collide with ids literally in the log
            # (j1, j1, "j1#1"), so probe until genuinely fresh
            k = next_suffix.get(name, 1)
            while f"{name}#{k}" in emitted:
                k += 1
            next_suffix[name] = k + 1
            name = f"{name}#{k}"
        emitted.add(name)
        parsed.append(Arrival(t=t, name=name, app=app))
    if not parsed:
        return []
    parsed.sort(key=lambda a: a.t)  # stable: same-instant rows keep log order
    t0 = parsed[0].t if rebase else 0.0
    return [
        Arrival(t=round((a.t - t0) * time_scale, 6), name=a.name, app=a.app)
        for a in parsed
    ]

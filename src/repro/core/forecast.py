"""Forecast-driven control plane (ISSUE 5).

PR 4 showed elastic actions (migration, resizing) are the dominant lever
under bursty arrivals — and that *eager* point-in-time heuristics lose on
some seeds: a drained node pulls a waiting job an instant before the next
burst lands on it.  This module centralizes the lightweight online
signals the paper's thesis calls for, so every decision layer conditions
on the same forecasts instead of its own point-in-time proxy:

  * **online perf-model refinement** (``RefinedPerfModel``) — Phase-I
    estimates become *priors* that shrink toward observed segment
    runtimes as jobs complete.  The posterior is keyed on the app's
    ground-truth profile object, so every instance of one application —
    across the whole stream — shares one posterior, exactly like the
    Phase-I sharing in ``ProfiledPerfModel``.
  * **queueing-aware wait forecasts** (``ForecastPlane.wait_forecast``) —
    the PR 3 drain proxy (committed busy unit-seconds per unit, from the
    ``ClusterState`` accumulators) inflated by the M/G/c heavy-traffic
    factor ``1 / (1 - rho)``: while a node drains its backlog, new work
    keeps arriving at rate ``lambda_node = lambda * share``, each job
    bringing ``E[unit-work]`` seconds — the *forecasted* wait, not the
    current one.  ``lambda`` comes from the arrival-rate EWMA
    (``repro.core.arrivals.ArrivalRateEWMA``).
  * **burst risk with hysteresis** (``ForecastPlane.burst_risk``) — the
    short/long rate ratio arms a gate at ``1 + hysteresis_margin`` times
    the baseline and releases it only below ``1 + hysteresis_margin/4``;
    while armed, elastic actions pay a risk penalty (migration demands a
    bigger forecasted-wait gap, resizes a bigger switch-cost margin).
    The hysteresis band is what keeps the gate from chattering between
    consecutive completions of one burst.

Consumers (all rewired through this plane):

  * ``PredictiveDispatcher`` (repro.core.cluster) routes arrivals on
    forecasted wait + energy instead of the raw drain proxy,
  * ``Cluster.simulate``'s default ``migrate_candidate`` replaces the raw
    wait-gap test with forecasted-wait-gap minus the burst-risk penalty
    (the fix for the PR 4 losing seeds — regression-locked in
    tests/test_forecast.py),
  * ``EcoSched.propose_resizes`` scales its switch-cost bias by the
    forecasted queue pressure (``resize_switch_cost``) — churn gets more
    expensive exactly when freed units are about to be needed.

Everything is **default-off**: ``forecast=None`` (or a ``ForecastConfig``
with every switch off) never builds a plane, so cluster and single-node
schedules stay bit-identical to the PR 4 substrate (parity-locked in
tests/test_forecast.py on top of the golden locks in tests/test_events.py).

Knobs (``ForecastConfig``): ``ewma_horizon`` / ``baseline_horizon`` set
the arrival-rate EWMA windows (effective sample counts),
``hysteresis_margin`` the burst-gate arming band, ``posterior_weight``
the prior strength of the Phase-I estimates in pseudo-segments.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.arrivals import ArrivalRateEWMA
from repro.core.perfmodel import _mk_spec
from repro.core.types import JobSpec, RunningJob


@dataclass(frozen=True)
class ForecastConfig:
    """Knobs for the forecast-driven control plane.  With every switch off
    (or ``forecast=None``) no plane is built and schedules are
    bit-identical to the forecast-free substrate.

    ``posterior_weight`` is the Phase-I prior strength in
    pseudo-segments: an observed segment runtime at count g moves the
    estimate to ``(w·prior + n·observed) / (w + n)`` — small w trusts
    observations quickly, large w keeps the profile-driven prior.

    ``hysteresis_margin`` m sets the burst gate band: arm when the short
    arrival rate exceeds ``(1+m)`` × baseline, release only below
    ``(1+m/4)`` × baseline.  ``risk_horizon_s`` converts armed risk into
    seconds of expected extra drain charged against elastic actions.
    """

    refine: bool = True  # online runtime-posterior refinement
    queueing: bool = True  # M/G/c wait inflation on the drain proxy
    burst_gate: bool = True  # hysteretic burst-risk gating of elastic acts
    # dispatch consumers of the refined posteriors (ISSUE 6 satellites;
    # both are no-ops unless ``refine`` built per-node models):
    # dispatch_refine — EnergyAware/Predictive dispatchers read
    # posterior-blended (E*, t*) tables instead of the static priors, so
    # dispatch and per-node placement see the *same* model;
    # migration_relief_weight — the migrate accept additionally credits
    # the freeing of the donor's queue (each remaining waiter's forecasted
    # wait drops by the moved job's drain seconds), weighted by this —
    # 0 restores the myopic single-job gain.
    dispatch_refine: bool = True
    migration_relief_weight: float = 1.0
    posterior_weight: float = 4.0  # Phase-I prior strength (pseudo-segments)
    ewma_horizon: int = 4  # short-horizon arrival-rate EWMA (samples)
    baseline_horizon: int = 64  # long-run baseline EWMA (samples)
    hysteresis_margin: float = 0.5  # burst gate arms at (1+m)×baseline rate
    risk_horizon_s: float = 600.0  # horizon burst work is charged over
    pressure_gain: float = 1.0  # switch-cost inflation per unit pressure
    rho_cap: float = 0.75  # forecasted-utilization clamp in out·(1+rho)
    # sustained-load clamp for the queueing forecast: rho uses
    # min(lambda_short, clamp × lambda_baseline).  Within a same-instant
    # burst the short rate spikes orders of magnitude above anything
    # sustainable — that spike is the *burst gate's* signal; feeding it to
    # the M/G/c term would double-count members already sitting in the
    # drain proxy and over-spread routing (measured in bench_forecast.py)
    lambda_clamp: float = 2.0

    @property
    def enabled(self) -> bool:
        return self.refine or self.queueing or self.burst_gate


class RefinedPerfModel:
    """Wraps a Phase-I perf model; observed segment runtimes shrink the
    prior toward the truth (tentpole part (a)).

    The base model's normalized estimates ``t_norm(g)`` are the prior
    *shape*; observations are absolute seconds.  The blend anchors the
    prior to the observed scale — ``s`` is the observation-weighted mean
    of ``observed(g) / t_norm(g)`` — then shrinks each observed count:

        t_post(g) = (w · s·t_norm(g) + n_g · mean_obs(g)) / (w + n_g)

    with ``w = posterior_weight`` pseudo-segments.  Unobserved counts
    keep the prior shape (scaled by ``s``, which cancels under
    ``_mk_spec``'s renormalization), so one observation at g=2 improves
    the *relative* estimate of every other count only through the ratios
    that were actually measured.  Power blends the same way from the
    observed draw.

    Posteriors are keyed on the app's ground-truth ``JobProfile`` object
    (the same aliasing ``ProfiledPerfModel`` uses for its noise-free mode
    sharing), so every instance of an application shares one posterior;
    a base model without a ``truth`` table falls back to per-job keys.

    ``version`` bumps on every accepted observation — policies that cache
    τ-filtered specs (EcoSched) invalidate on it.
    """

    def __init__(self, base, *, weight: float = 4.0):
        assert weight > 0.0
        self.base = base
        self.weight = weight
        self.version = 0
        self._truth = getattr(base, "truth", None)
        # profile-key -> {(g, f): (n_t, mean_t, n_p, mean_p)} — keyed on
        # the joint (count, frequency-level) mode so DVFS runs refine each
        # operating point separately; power keeps its own count so t-only
        # observations never dilute the power mean
        self._obs: Dict[
            object, Dict[Tuple[int, int], Tuple[int, float, int, float]]
        ] = {}
        self._ver_of: Dict[object, int] = {}
        self._profiles: List[object] = []  # pin ids while keyed on them
        self._spec_cache: Dict[str, Tuple[int, JobSpec]] = {}

    def _key(self, job: str):
        if self._truth is not None:
            prof = self._truth.get(job)
            if prof is not None:
                return id(prof)
        return job

    def observe(
        self, job: str, g: int, t_obs: float, p_obs: float = 0.0, f: int = 0
    ) -> None:
        """One completed segment: solo-equivalent full runtime ``t_obs``
        seconds at count ``g`` and frequency level ``f`` (and the observed
        busy power, if known)."""
        if t_obs <= 0.0:
            return
        key = self._key(job)
        if key not in self._obs and self._truth is not None:
            self._profiles.append(self._truth.get(job))
        d = self._obs.setdefault(key, {})
        n, mt, np_, mp = d.get((g, f), (0, 0.0, 0, 0.0))
        n += 1
        mt += (t_obs - mt) / n
        if p_obs > 0.0:
            np_ += 1
            mp += (p_obs - mp) / np_
        d[(g, f)] = (n, mt, np_, mp)
        self._ver_of[key] = self._ver_of.get(key, 0) + 1
        self.version += 1

    def spec(self, job: str) -> JobSpec:
        base_spec = self.base.spec(job)
        key = self._key(job)
        obs = self._obs.get(key)
        if not obs:
            return base_spec  # no observations: the prior passes through
        ver = self._ver_of[key]
        hit = self._spec_cache.get(job)
        if hit is not None and hit[0] == ver:
            return hit[1]
        prior_t = {(m.g, m.f): m.t_norm for m in base_spec.modes}
        prior_p = {(m.g, m.f): m.p_bar for m in base_spec.modes}
        seen = [(k, n, mt) for k, (n, mt, _, _) in obs.items() if k in prior_t]
        if not seen:
            return base_spec  # observed modes all fell outside the prior
        # anchor the relative prior to the observed absolute scale
        n_tot = sum(n for _, n, _ in seen)
        s = sum(n * (mt / prior_t[k]) for k, n, mt in seen) / n_tot
        w = self.weight
        t_post, p_post = {}, {}
        for m in base_spec.modes:
            k = (m.g, m.f)
            n, mt, np_, mp = obs.get(k, (0, 0.0, 0, 0.0))
            t_post[k] = (w * s * prior_t[k] + n * mt) / (w + n)
            p_post[k] = (
                (w * prior_p[k] + np_ * mp) / (w + np_)
                if np_
                else prior_p[k]
            )
        spec = _mk_spec(job, t_post, p_post)
        self._spec_cache[job] = (ver, spec)
        if len(self._spec_cache) > 100_000:
            self._spec_cache.clear()  # bound endless-stream growth
        return spec

    def profiling_energy(self, job: str) -> float:
        return self.base.profiling_energy(job)

    def posterior_curves(
        self, prof, *, limit: Optional[int] = None
    ) -> Optional[Dict[Tuple[int, int], Tuple[float, float]]]:
        """Posterior (runtime s, busy power W) per feasible (count,
        frequency-level) mode for the app whose ground-truth profile is
        ``prof``, blending the caller's absolute prior (the profile
        itself) toward this node's observed segments with the usual
        ``(w·prior + n·obs) / (w + n)`` shrink.  ``None`` when this node
        has no observations of the app — callers keep their static
        tables.  This is the dispatch-table feed
        (``ForecastPlane.dispatch_tables``): unlike ``spec()``, the prior
        here is the dispatcher's calibrated truth, not the Phase-I noisy
        estimate, because that is the table being corrected."""
        obs = self._obs.get(id(prof))
        if not obs:
            return None
        w = self.weight
        out: Dict[Tuple[int, int], Tuple[float, float]] = {}
        for g in prof.feasible_counts:
            if limit is not None and g > limit:
                continue
            for f in prof.freq_levels:
                n, mt, np_, mp = obs.get((g, f), (0, 0.0, 0, 0.0))
                t_post = (w * prof.runtime_at(g, f) + n * mt) / (w + n)
                p_post = (
                    (w * prof.power_at(g, f) + np_ * mp) / (w + np_)
                    if np_
                    else prof.power_at(g, f)
                )
                out[(g, f)] = (t_post, p_post)
        return out or None


class ForecastPlane:
    """The shared online-signal state for one simulation run.

    Owns the arrival-rate EWMA, per-node routing shares and service-work
    EWMAs, the hysteretic burst gate, and the per-node refined perf
    models.  The event substrate feeds it (``on_arrival`` /
    ``on_launch`` / ``on_complete``); dispatchers, the migration gate and
    EcoSched's resize bias read it.  Built by ``simulate`` /
    ``Cluster.simulate`` when ``forecast`` is enabled; never constructed
    on the default path.
    """

    def __init__(
        self,
        cfg: ForecastConfig,
        units: Dict[str, int],
        *,
        state=None,  # ClusterState (cluster runs) or None (single node)
        elastic=None,  # ElasticConfig, for checkpoint-segment accounting
    ):
        self.cfg = cfg
        self.units = {nm: float(u) for nm, u in units.items()}
        self.state = state
        self.elastic = elastic
        self.rate = ArrivalRateEWMA(cfg.ewma_horizon, cfg.baseline_horizon)
        self._alpha = 2.0 / (cfg.ewma_horizon + 1)
        self._work: Dict[str, float] = {}  # EWMA busy unit-s per launch
        self._routed: Dict[str, int] = {nm: 0 for nm in units}
        self._models: Dict[str, RefinedPerfModel] = {}
        self._armed = False
        # dispatch-table overlay state (bind_dispatch / dispatch_tables)
        self._dispatch_truth: Optional[Dict[str, Dict[str, object]]] = None
        self._tables: Optional[Tuple[np.ndarray, np.ndarray]] = None
        self._tables_ver: Optional[Tuple[int, ...]] = None
        # observability counters (surfaced via summary())
        self.gate_flips = 0
        self.migrations_vetoed = 0
        self.refinements = 0

    # -- wiring --------------------------------------------------------------

    def refined_model(self, nm: str, base):
        """Wrap one node policy's perf model; pass-through when refinement
        is off (so ``attach_forecast`` is always safe to call)."""
        if not self.cfg.refine:
            return base
        if isinstance(base, RefinedPerfModel):  # idempotent attach
            self._models[nm] = base
            return base
        model = RefinedPerfModel(base, weight=self.cfg.posterior_weight)
        self._models[nm] = model
        return model

    def bind_dispatch(self, app_truth: Dict[str, Dict[str, object]]) -> None:
        """Give the plane the dispatcher's per-node app->JobProfile tables
        so ``dispatch_tables`` can rebuild (E*, t*) cells from posteriors.
        Called by the cluster run when a plane exists; harmless otherwise."""
        self._dispatch_truth = app_truth
        self._tables = None
        self._tables_ver = None

    def dispatch_tables(self) -> Tuple[np.ndarray, np.ndarray]:
        """Per-(node, app) best-mode (energy, runtime) tables for the
        dispatchers, with every cell a node has *observed* re-derived from
        that node's refined posterior — dispatch and per-node placement see
        the same model (ISSUE 6 satellite).  Falls back to the static
        ``ClusterState`` priors when refinement (or ``dispatch_refine``)
        is off or nothing has been observed.  Rebuilds are cached keyed on
        the tuple of per-node model versions, so the arrays are only
        recomputed after an accepted observation."""
        st = self.state
        assert st is not None, "dispatch_tables needs a ClusterState"
        if (
            not (self.cfg.refine and self.cfg.dispatch_refine)
            or self._dispatch_truth is None
            or not self._models
        ):
            return st.e_best, st.t_best
        ver = tuple(m.version for m in self._models.values())
        if self._tables is not None and self._tables_ver == ver:
            return self._tables
        e = np.array(st.e_best)
        t = np.array(st.t_best)
        for nm, model in self._models.items():
            ni = st.index.get(nm)
            truth = self._dispatch_truth.get(nm)
            if ni is None or not truth:
                continue
            for app, ai in st.app_index.items():
                if not st.fits[ni, ai]:
                    continue
                prof = truth.get(app)
                if prof is None:
                    continue
                curves = model.posterior_curves(prof, limit=int(st.units[ni]))
                if curves is None:
                    continue
                eb, tb = min((tt * pp, tt) for tt, pp in curves.values())
                e[ni, ai] = eb
                t[ni, ai] = tb
        self._tables = (e, t)
        self._tables_ver = ver
        return self._tables

    # -- substrate feeds -----------------------------------------------------

    def on_arrival(self, t: float, nm: Optional[str] = None) -> None:
        self.rate.observe(t)
        if nm is not None and nm in self._routed:
            self._routed[nm] += 1
        # arm/release the burst gate at arrival instants with the raw
        # (uncensored) EWMA ratio: a burst is only *visible* while its
        # members land — a lazy decision-time check would consistently
        # sample the post-burst silence and never arm
        if self.cfg.burst_gate:
            self._update_gate(self.rate.burst_factor())

    def on_launch(self, nm: str, rj: RunningJob) -> None:
        w = (rj.end - rj.start) * rj.g  # committed busy unit-seconds
        prev = self._work.get(nm)
        self._work[nm] = w if prev is None else prev + self._alpha * (w - prev)

    def on_complete(self, nm: str, rj: RunningJob) -> None:
        """A segment finished (COMPLETE, or the PREEMPT checkpoint-write
        end): convert its wall time back to a solo-equivalent full runtime
        at its count and feed the posterior.  The launch-time interference
        factor is divided out — the simulator re-applies it to whatever
        the policy launches next, so leaving it in would double-count
        co-schedule slowdown for counts that co-run more often."""
        if not self.cfg.refine:
            return
        model = self._models.get(nm)
        if model is None:
            return
        if rj.preempted:
            if self.elastic is None:
                return
            # rj.end was retimed to the checkpoint-write end; the run
            # segment itself spans [start + restart, end - ckpt_time]
            useful = (rj.end - self.elastic.ckpt_time) - rj.start - rj.restart
            frac = rj.frac_ckpt - rj.frac0
        else:
            useful = rj.end - rj.start - rj.restart
            frac = 1.0 - rj.frac0
        if frac <= 1e-9 or useful <= 0.0:
            return
        solo = useful / frac / max(rj.factor, 1.0)
        model.observe(rj.job, rj.g, solo, rj.power, rj.f)
        self.refinements += 1

    # -- forecasts -----------------------------------------------------------

    def _rho(self, nm: str, now: float) -> float:
        """Forecasted utilization of node ``nm``: sustained incoming work
        rate (jobs/s × the node's routed share × E[unit-work]) per unit.
        The rate is the short-horizon EWMA clamped at ``lambda_clamp`` ×
        the baseline — reactive to regime shifts, blind to the
        within-burst spike (see ``ForecastConfig.lambda_clamp``)."""
        lam = self.rate.rate(now)
        base = self.rate.baseline_rate()
        if base > 0.0:
            lam = min(lam, self.cfg.lambda_clamp * base)
        if lam <= 0.0:
            return 0.0
        w = self._work.get(nm)
        if w is None:
            return 0.0  # no launches observed here yet: no inflation
        total = sum(self._routed.values())
        share = (self._routed[nm] + 1.0) / (total + len(self._routed))
        return min(lam * share * w / self.units[nm], self.cfg.rho_cap)

    def wait_forecast(self, now: float) -> np.ndarray:
        """Per-node forecasted wait (s): the ClusterState drain proxy
        inflated by the work expected to land while the backlog drains —
        ``out · (1 + rho)``, the first-order M/G/c heavy-traffic
        correction.  (The full ``1/(1-rho)`` geometric form over-commits
        here: same-instant burst members are already *in* the proxy as
        they route, so the resolvent double-counts exactly when rho
        spikes; the bounded first-order term measures better across the
        sparse-to-saturated sweep in benchmarks/bench_forecast.py.)
        Falls back to the raw proxy with ``queueing`` off (or before
        warm-up)."""
        assert self.state is not None, "wait_forecast needs a ClusterState"
        out = self.state.outstanding(now)
        if not self.cfg.queueing:
            return out
        fc = np.array(out, dtype=float)
        for i, nm in enumerate(self.state.names):
            rho = self._rho(nm, now)
            if rho > 0.0:
                fc[i] = out[i] * (1.0 + rho)
        return fc

    def _update_gate(self, f: float) -> None:
        """Hysteresis: arm above ``(1+m)`` × baseline, release only below
        ``(1+m/4)`` — the band keeps the gate from chattering between
        consecutive completions of one burst."""
        m = self.cfg.hysteresis_margin
        if self._armed:
            if f < 1.0 + 0.25 * m:
                self._armed = False
                self.gate_flips += 1
        elif f >= 1.0 + m:
            self._armed = True
            self.gate_flips += 1

    def burst_risk(self, now: float) -> float:
        """Hysteretic burst signal in [0, 1].  0 while the gate is
        released; while armed, scales with how far the *censored*
        short-horizon rate still sits above the release threshold — so
        an armed gate decays through post-burst silence instead of
        latching forever."""
        if not self.cfg.burst_gate:
            return 0.0
        f = self.rate.burst_factor(now)
        self._update_gate(f)
        if not self._armed:
            return 0.0
        m = self.cfg.hysteresis_margin
        lo = 1.0 + 0.25 * m
        hi = 1.0 + m
        return float(min(1.0, max(f - lo, 0.0) / max(hi - lo, 1e-9)))

    def migration_penalty_s(self, nm: str, now: float) -> float:
        """Extra forecasted-wait gap (s) a migration onto ``nm`` must
        clear while the burst gate is armed: the work a burst is expected
        to deliver to this node over ``risk_horizon_s``, in drain
        seconds.  0 when the gate is released."""
        risk = self.burst_risk(now)
        if risk <= 0.0:
            return 0.0
        lam = self.rate.rate(now)
        works = [w for w in self._work.values() if w > 0.0]
        if lam <= 0.0 or not works:
            return 0.0
        inflow = lam * (sum(works) / len(works)) / self.units[nm]
        return risk * min(inflow, 2.0) * self.cfg.risk_horizon_s

    def resize_switch_cost(self, nm: str, base: float, now: float) -> float:
        """Switch-cost bias conditioned on forecasted queue pressure:
        churn gets more expensive as burst risk and the node's forecasted
        utilization rise (tentpole consumer (c))."""
        pressure = self.burst_risk(now) + (
            self._rho(nm, now) if self.cfg.queueing else 0.0
        )
        return base * (1.0 + self.cfg.pressure_gain * pressure)

    # -- observability -------------------------------------------------------

    def summary(self) -> Dict[str, float]:
        """Forecast-state rollup attached to results (types.py)."""
        refined_apps = sum(len(m._obs) for m in self._models.values())
        return {
            "arrivals_observed": float(self.rate.n_gaps + 1 if self.rate.last_t is not None else 0),
            "rate_short": self.rate.rate(),
            "rate_baseline": self.rate.baseline_rate(),
            "burst_factor": self.rate.burst_factor(),
            "burst_armed": float(self._armed),
            "gate_flips": float(self.gate_flips),
            "migrations_vetoed": float(self.migrations_vetoed),
            "refinements": float(self.refinements),
            "refined_apps": float(refined_apps),
        }

"""Discrete-event node simulator with energy accounting.

Drives any ``Policy`` through a workload: at t=0 and at every job
completion it hands the policy the current ``NodeView`` + waiting queue and
launches whatever the policy returns (validating capacity, domain and
contiguity constraints — a policy bug raises, it never silently
oversubscribes).

Energy integration is exact piecewise-constant:
  busy  = Σ_jobs  P_busy(job, g) · runtime(job, g)
  idle  = Σ_segments  (idle units) · P_idle_unit · dt   until makespan.
Invariant (tested): Σ busy GPU-seconds + Σ idle GPU-seconds = M · makespan.
"""
from __future__ import annotations

import heapq
import time as _time
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.placement import PlacementState
from repro.core.types import (
    JobProfile,
    JobRecord,
    Launch,
    NodeView,
    RunningJob,
    ScheduleResult,
)


class Node:
    def __init__(self, units: int, domains: int, idle_power_per_unit: float):
        self.units = units
        self.domains = domains
        self.idle_power_per_unit = idle_power_per_unit


def simulate(
    policy,
    node: Node,
    truth: Dict[str, JobProfile],
    *,
    queue: Optional[Sequence[str]] = None,
    charge_profiling: bool = False,
    slowdown_model=None,
    max_events: int = 100_000,
) -> ScheduleResult:
    """Run ``policy`` over the workload; returns exact energy/makespan.

    ``slowdown_model(job, g, co_running) -> factor ≥ 1`` optionally models
    residual interference (NUMA-aware placement keeps it ≈ 1; §V-C's
    cross-domain GPU case can be modeled by the caller).
    """
    waiting: List[str] = list(queue if queue is not None else sorted(truth))
    placement = PlacementState(node.units, node.domains)
    running: List[RunningJob] = []
    heap: List[Tuple[float, int, RunningJob]] = []
    records: List[JobRecord] = []
    t = 0.0
    busy_energy = 0.0
    idle_unit_seconds = 0.0
    seq = 0
    decision_time = 0.0
    decision_events = 0

    def node_view() -> NodeView:
        return NodeView(
            t=t,
            total_units=node.units,
            domains=node.domains,
            free_units=placement.free_count(),
            running=list(running),
            free_map=list(placement.free),
        )

    def invoke_policy():
        nonlocal decision_time, decision_events, busy_energy, seq
        t0 = _time.perf_counter()
        launches: List[Launch] = policy.on_event(node_view(), list(waiting)) or []
        decision_time += _time.perf_counter() - t0
        decision_events += 1
        for ln in launches:
            if ln.job not in waiting:
                raise ValueError(f"{policy.name()} launched unknown/duplicate job {ln.job}")
            prof = truth[ln.job]
            if ln.g not in prof.runtime:
                raise ValueError(f"{ln.job}: infeasible unit count {ln.g}")
            if len(running) >= node.domains:
                raise ValueError(f"{policy.name()} exceeded domain cap K={node.domains}")
            units, domain = placement.allocate(ln.g)  # raises if impossible
            factor = 1.0
            if slowdown_model is not None:
                factor = float(
                    slowdown_model(ln.job, ln.g, [r.job for r in running])
                )
                assert factor >= 1.0
            dur = prof.runtime[ln.g] * factor
            power = prof.busy_power[ln.g]
            rj = RunningJob(
                job=ln.job, g=ln.g, units=units, domain=domain,
                start=t, end=t + dur, power=power,
            )
            waiting.remove(ln.job)
            running.append(rj)
            seq += 1
            heapq.heappush(heap, (rj.end, seq, rj))
            busy_energy += power * dur
            records.append(
                JobRecord(job=ln.job, g=ln.g, start=t, end=rj.end, busy_energy=power * dur)
            )

    events = 0
    invoke_policy()
    while heap:
        events += 1
        if events > max_events:
            raise RuntimeError("simulator event cap exceeded (policy deadlock?)")
        end_t, _, rj = heapq.heappop(heap)
        # integrate idle unit-seconds over [t, end_t)
        idle_unit_seconds += placement.free_count() * (end_t - t)
        t = end_t
        running.remove(rj)
        placement.release(rj.units)
        if waiting:
            invoke_policy()
        elif not running and waiting:
            raise RuntimeError("deadlock: queue non-empty, nothing running")

    if waiting:
        raise RuntimeError(f"policy {policy.name()} finished with waiting jobs {waiting}")

    prof_energy = 0.0
    if charge_profiling:
        prof_energy = sum(truth[r.job].profiling_energy for r in records)

    return ScheduleResult(
        policy=policy.name(),
        makespan=t,
        busy_energy=busy_energy,
        idle_energy=idle_unit_seconds * node.idle_power_per_unit,
        profiling_energy=prof_energy,
        records=records,
        decision_time_s=decision_time,
        decision_events=decision_events,
    )

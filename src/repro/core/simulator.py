"""Discrete-event node simulator with energy accounting.

Drives any ``Policy`` through a workload: at t=0, at every job completion
and at every job *arrival* it hands the policy the current ``NodeView`` +
waiting queue and launches whatever the policy returns (validating
capacity, domain and contiguity constraints — a policy bug raises, it
never silently oversubscribes).

Energy integration is exact piecewise-constant:
  busy  = Σ_jobs  P_busy(job, g) · runtime(job, g)
  idle  = Σ_segments  (idle units) · P_idle_unit · dt   until makespan.
Invariant (tested): Σ busy GPU-seconds + Σ idle GPU-seconds = M · makespan.

The per-node state machine lives in ``NodeSim``; the event loop itself is
the shared substrate in ``repro.core.events`` (ISSUE 4), so the
single-node ``simulate()`` entry point and the cluster-scale
``Cluster.simulate()`` drive the identical loop — a 1-node cluster
reproduces ``simulate()`` exactly (regression-locked).

With an ``ElasticConfig`` the same ``NodeSim`` supports
preemption/checkpoint-restart: a running job can be checkpointed (units
held for the write, energy charged), re-queued with its completed-work
fraction, and relaunched at any feasible count — the relaunch pays the
restart overhead and only the remaining work.  All of it is default-off
and adds nothing to the static path.
"""
from __future__ import annotations

import time as _time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.core.events import EVT_ARRIVAL, ElasticConfig, EventLoop
from repro.core.faults import FaultConfig, FaultInjector
from repro.core.placement import PlacementState
from repro.core.types import (
    JobProfile,
    JobRecord,
    Launch,
    NodeView,
    RunningJob,
    ScheduleResult,
)

# Pre-refactor aliases (the heap tuple kind slots); kept for callers that
# imported the private constants.
_ARRIVAL = EVT_ARRIVAL
_DONE = 1  # EVT_COMPLETE


class Node:
    def __init__(self, units: int, domains: int, idle_power_per_unit: float):
        self.units = units
        self.domains = domains
        self.idle_power_per_unit = idle_power_per_unit


@dataclass(frozen=True)
class MigrantState:
    """Everything a migrating job carries between nodes (MIGRATE payload):
    the original submission time, its completed-work fraction, whether the
    next launch owes a restart, and the per-job counters that must stay
    global across nodes."""

    arrival: float
    progress: float = 0.0
    restart: bool = False
    segment: int = 0
    preempts: int = 0  # checkpoint budget already spent (max_preempts)
    last_g: Optional[int] = None  # last launched count (resize history)
    last_f: Optional[int] = None  # last launched frequency level (retunes)
    queued_at: float = 0.0  # when it last entered a waiting queue (donor)


class NodeSim:
    """Single-node simulation state: placement, running set, waiting queue,
    and exact piecewise-constant energy integration.

    The owner (the ``EventLoop`` built by ``simulate`` or
    ``Cluster.simulate``) runs the event heap and calls
    ``advance``/``arrive``/``complete``/``invoke_policy`` (plus the
    preemption/migration hooks when elastic); this object never sees the
    heap, so the same accounting serves every entry point.
    """

    def __init__(
        self,
        node: Node,
        truth: Dict[str, JobProfile],
        policy,
        *,
        slowdown_model=None,
        name: str = "",
        elastic: Optional[ElasticConfig] = None,
        faults: Optional[FaultConfig] = None,
        fault_injector: Optional[FaultInjector] = None,
    ):
        self.node = node
        self.truth = truth
        self.policy = policy
        self.slowdown_model = slowdown_model
        self.name = name
        self.elastic = elastic
        self.faults = faults if (faults and faults.enabled) else None
        self.fault_injector = (
            fault_injector if self.faults is not None else None
        )
        # segment/progress tracking is needed by both planes; the restart
        # overhead after a kill comes from whichever config supplies one
        self._track = elastic is not None or self.faults is not None
        self._restart_time = (
            elastic.restart_time
            if elastic is not None
            else (self.faults.restart_time if self.faults is not None else 0.0)
        )
        self.placement = PlacementState(node.units, node.domains)
        self.waiting: List[str] = []
        self.running: List[RunningJob] = []
        self.records: List[JobRecord] = []
        self.arrival_of: Dict[str, float] = {}
        self.t = 0.0
        self.busy_energy = 0.0
        self.idle_unit_seconds = 0.0
        self.decision_time = 0.0
        self.decision_events = 0
        self.resize_time = 0.0  # wall-clock inside the resize phase
        self.migrate_time = 0.0  # wall-clock inside the migration phase
        # elastic bookkeeping (inert unless the substrate drives it)
        self.progress: Dict[str, float] = {}  # job -> completed-work fraction
        self.needs_restart: Set[str] = set()  # next launch pays restart_time
        self.preempt_count: Dict[str, int] = {}
        self.preemptions = 0
        self.ckpt_energy = 0.0
        self.migrations_in = 0
        self.migrations_out = 0
        self.resize_history: Dict[str, List[Tuple[float, int, int]]] = {}
        self.freq_history: Dict[str, List[Tuple[float, int, int]]] = {}
        self._last_g: Dict[str, int] = {}
        self._last_f: Dict[str, int] = {}
        self._segments: Dict[str, int] = {}
        self._queued_at: Dict[str, float] = {}  # last (re-)enqueue time
        # fault-plane accounting (inert unless the substrate drives it)
        self.job_crashes = 0
        self.node_failures = 0
        self.fault_kills = 0
        self.fault_retries = 0
        self.lost: List[str] = []

    def node_view(self) -> NodeView:
        return NodeView(
            t=self.t,
            total_units=self.node.units,
            domains=self.node.domains,
            free_units=self.placement.free_count(),
            running=list(self.running),
            free_map=list(self.placement.free),
            domain_jobs=list(self.placement.domain_jobs),
            dead_units=self.placement.dead_count(),
        )

    def advance(self, t: float) -> None:
        """Integrate idle unit-seconds over [self.t, t) and move the clock."""
        assert t >= self.t - 1e-12, (self.name, self.t, t)
        self.idle_unit_seconds += self.placement.free_count() * (t - self.t)
        self.t = t

    def arrive(self, job: str, t: float) -> None:
        self.advance(t)
        self.arrival_of[job] = t
        self._queued_at[job] = t
        self.waiting.append(job)

    def complete(self, rj: RunningJob) -> None:
        """Advance to the completion instant, then free the job's units."""
        self.advance(rj.end)
        self.running.remove(rj)
        self.placement.release(rj.units, rj.domain)

    def frac_of(self, rj: RunningJob) -> float:
        """Completed-work fraction of a running job at the node clock."""
        return rj.frac_at(self.t)

    def invoke_policy(self) -> List[RunningJob]:
        """One scheduling event; returns the newly launched jobs (the owner
        pushes their completion events)."""
        t0 = _time.perf_counter()
        launches: List[Launch] = (
            self.policy.on_event(self.node_view(), list(self.waiting)) or []
        )
        self.decision_time += _time.perf_counter() - t0
        self.decision_events += 1
        out: List[RunningJob] = []
        for ln in launches:
            if ln.job not in self.waiting:
                raise ValueError(
                    f"{self.policy.name()} launched unknown/duplicate job {ln.job}"
                )
            prof = self.truth[ln.job]
            if ln.g not in prof.runtime:
                raise ValueError(f"{ln.job}: infeasible unit count {ln.g}")
            if ln.f not in prof.freq_levels:
                raise ValueError(f"{ln.job}: infeasible frequency level {ln.f}")
            if self.placement.occupied_domains() >= self.node.domains:
                raise ValueError(
                    f"{self.policy.name()} exceeded domain cap K={self.node.domains}"
                )
            units, domain = self.placement.allocate(ln.g)  # raises if impossible
            factor = 1.0
            if self.slowdown_model is not None:
                # domain-aware models additionally see the real placement
                kw = (
                    dict(units=units, domain=domain, running=self.running,
                         total_units=self.node.units, domains=self.node.domains)
                    if getattr(self.slowdown_model, "domain_aware", False)
                    else {}
                )
                factor = float(
                    self.slowdown_model(
                        ln.job, ln.g, [r.job for r in self.running], **kw
                    )
                )
                assert factor >= 1.0
            frac0 = 0.0
            restart = 0.0
            segment = 0
            if self._track:
                frac0 = self.progress.pop(ln.job, 0.0)
                if ln.job in self.needs_restart:
                    self.needs_restart.discard(ln.job)
                    restart = self._restart_time
                segment = self._segments.get(ln.job, 0)
                self._segments[ln.job] = segment + 1
                last = self._last_g.get(ln.job)
                if last is not None and last != ln.g:
                    self.resize_history.setdefault(ln.job, []).append(
                        (self.t, last, ln.g)
                    )
                last_f = self._last_f.get(ln.job)
                if last_f is not None and last_f != ln.f and last == ln.g:
                    # pure frequency retune: the relaunch kept the count
                    # and only moved the DVFS level
                    self.freq_history.setdefault(ln.job, []).append(
                        (self.t, last_f, ln.f)
                    )
                self._last_g[ln.job] = ln.g
                self._last_f[ln.job] = ln.f
            if self.fault_injector is not None:
                # seeded per-(job, segment) straggler slowdown (>= 1.0)
                factor *= self.fault_injector.straggler(ln.job, segment)
            solo = prof.runtime_at(ln.g, ln.f)
            if frac0 == 0.0 and restart == 0.0:
                dur = solo * factor
            else:
                dur = restart + (1.0 - frac0) * solo * factor
            power = prof.power_at(ln.g, ln.f)
            rj = RunningJob(
                job=ln.job, g=ln.g, units=units, domain=domain,
                start=self.t, end=self.t + dur, power=power, f=ln.f,
                factor=factor, frac0=frac0, restart=restart,
            )
            self.waiting.remove(ln.job)
            self.running.append(rj)
            self.busy_energy += power * dur
            rec = JobRecord(
                job=ln.job, g=ln.g, start=self.t, end=rj.end,
                busy_energy=power * dur,
                arrival=self.arrival_of.get(ln.job, 0.0),
                node=self.name,
                domain=domain,
                segment=segment,
                queued=self._queued_at.get(ln.job, self.arrival_of.get(ln.job, 0.0)),
                f=ln.f,
            )
            rj.record = rec
            self.records.append(rec)
            out.append(rj)
        return out

    # -- elastic substrate hooks (repro.core.events) ------------------------

    def begin_preempt(self, rj: RunningJob, t: float, cfg: ElasticConfig) -> float:
        """Checkpoint a running job at decision time ``t``.  Its units stay
        held until the write finishes at ``t + ckpt_time``; the unrun tail
        of its pre-charged busy energy is returned and the write charged at
        ``ckpt_power_scale`` × busy power.  Returns the checkpoint end time
        (the owner pushes the PREEMPT event there)."""
        assert rj in self.running and not rj.preempted
        assert rj.end > t + cfg.ckpt_time, (rj.job, rj.end, t)
        frac = rj.frac_at(t)
        ck_end = t + cfg.ckpt_time
        ck_e = rj.power * cfg.ckpt_power_scale * cfg.ckpt_time
        self.busy_energy -= rj.power * (rj.end - t)  # un-charge the unrun tail
        self.busy_energy += ck_e
        self.ckpt_energy += ck_e
        rec = rj.record
        rec.end = ck_end
        rec.busy_energy = rj.power * (t - rj.start) + ck_e
        rec.kind = "ckpt"
        rec.ckpt_energy = ck_e
        rj.preempted = True
        rj.frac_ckpt = frac
        rj.end = ck_end
        self.preemptions += 1
        self.preempt_count[rj.job] = self.preempt_count.get(rj.job, 0) + 1
        return ck_end

    def finish_preempt(self, rj: RunningJob, t: float) -> None:
        """The checkpoint write finished: free the units and remember the
        completed-work fraction for the relaunch."""
        assert rj.preempted and abs(rj.end - t) < 1e-9
        self.advance(t)
        self.running.remove(rj)
        self.placement.release(rj.units, rj.domain)
        self.progress[rj.job] = rj.frac_ckpt
        self.needs_restart.add(rj.job)

    def requeue(self, job: str, t: float) -> None:
        """A preempted job re-enters this node's waiting queue (RESUME)."""
        self.advance(t)
        self._queued_at[job] = t
        self.waiting.append(job)

    # -- fault plane (repro.core.events / repro.core.faults) ----------------

    def fail_running(self, rj: RunningJob, t: float) -> None:
        """A crash or node failure kills a job mid-flight at ``t``: the
        pre-charged energy of the unrun tail is refunded (the burned
        segment stays charged — that work *was* done, then lost), its
        units free immediately, and the job rolls back to its last
        checkpoint (``frac0``) with a restart obligation.  The caller
        decides retry-or-lost and owns the clock advance ordering."""
        assert rj in self.running
        self.advance(t)
        rec = rj.record
        if rj.preempted:
            # killed mid-checkpoint-write: the partial write is useless,
            # so refund its unwritten tail and fall back to the fraction
            # at the segment start (the write's snapshot never landed)
            scale = self.elastic.ckpt_power_scale if self.elastic else 1.0
            refund = rj.power * scale * (rj.end - t)
            self.ckpt_energy -= refund
            rec.ckpt_energy -= refund
        else:
            refund = rj.power * (rj.end - t)
        self.busy_energy -= refund
        rec.busy_energy -= refund
        rec.end = t
        rec.kind = "fail"
        rj.failed = True
        rj.end = t
        self.running.remove(rj)
        self.placement.release(rj.units, rj.domain)
        self.progress[rj.job] = rj.frac0
        self.needs_restart.add(rj.job)
        self.fault_kills += 1

    def drop_lost(self, job: str) -> None:
        """Retries exhausted: the job leaves the system for good."""
        self.progress.pop(job, None)
        self.needs_restart.discard(job)
        self.lost.append(job)

    def cancel_waiting(self, job: str) -> None:
        """Drop a waiting job that has never launched (control-plane
        cancel, ISSUE 6).  The caller is responsible for refusing jobs
        that are running, checkpointed or carrying elastic state — this
        only erases the queue entry and its arrival bookkeeping."""
        if job in self.progress or job in self.needs_restart:
            raise ValueError(f"{job}: cannot cancel a checkpointed job")
        if self._segments.get(job, 0):
            raise ValueError(f"{job}: cannot cancel after it has launched")
        self.waiting.remove(job)  # raises if not waiting
        self.arrival_of.pop(job, None)
        self._queued_at.pop(job, None)

    def evict(self, job: str) -> "MigrantState":
        """Detach a waiting job for migration; returns everything that must
        travel with it — original arrival, completed-work fraction, the
        restart obligation, and the per-job counters (segment index,
        checkpoint budget spent, last launched count) so the
        ``max_preempts`` bound and the resize history stay global, not
        per-node."""
        self.waiting.remove(job)
        restart = job in self.needs_restart
        self.needs_restart.discard(job)
        arrival = self.arrival_of.pop(job, 0.0)
        state = MigrantState(
            arrival=arrival,
            progress=self.progress.pop(job, 0.0),
            restart=restart,
            segment=self._segments.pop(job, 0),
            preempts=self.preempt_count.pop(job, 0),
            last_g=self._last_g.pop(job, None),
            last_f=self._last_f.pop(job, None),
            queued_at=self._queued_at.pop(job, arrival),
        )
        self.migrations_out += 1
        return state

    def absorb(self, job: str, t: float, state: "MigrantState") -> None:
        """A migrated job lands here (MIGRATE): waiting time keeps counting
        from its original submission; segment numbering, the checkpoint
        budget and the resize history continue where they left off."""
        self.advance(t)
        self.arrival_of[job] = state.arrival
        # waiting keeps counting from the DONOR's enqueue: queueing time
        # spent there plus the transit is genuine waiting, unlike the
        # running time a preempted job's requeue excludes
        self._queued_at[job] = state.queued_at
        if state.progress:
            self.progress[job] = state.progress
        if state.restart:
            self.needs_restart.add(job)
        if state.segment:
            self._segments[job] = state.segment
        if state.preempts:
            self.preempt_count[job] = state.preempts
        if state.last_g is not None:
            self._last_g[job] = state.last_g
        if state.last_f is not None:
            self._last_f[job] = state.last_f
        self.waiting.append(job)
        self.migrations_in += 1

    def result(self, *, charge_profiling: bool = False) -> ScheduleResult:
        """Finalize. ``self.t`` is the node's last completion (its makespan)."""
        prof_energy = 0.0
        if charge_profiling:
            charged = set()
            for r in self.records:
                if r.job not in charged:  # once per job, not per segment
                    charged.add(r.job)
                    prof_energy += self.truth[r.job].profiling_energy
        return ScheduleResult(
            policy=self.policy.name(),
            makespan=self.t,
            busy_energy=self.busy_energy,
            idle_energy=self.idle_unit_seconds * self.node.idle_power_per_unit,
            profiling_energy=prof_energy,
            records=self.records,
            decision_time_s=self.decision_time,
            decision_events=self.decision_events,
            resize_time_s=self.resize_time,
            migrate_time_s=self.migrate_time,
            preemptions=self.preemptions,
            migrations_in=self.migrations_in,
            migrations_out=self.migrations_out,
            ckpt_energy=self.ckpt_energy,
            resize_history=self.resize_history,
            freq_history=self.freq_history,
            job_crashes=self.job_crashes,
            node_failures=self.node_failures,
            fault_kills=self.fault_kills,
            fault_retries=self.fault_retries,
            lost_jobs=list(self.lost),
        )


def _auto_max_events(n_stream: int, floor: int = 100_000) -> int:
    """Deadlock-guard cap that scales with workload size: every job costs a
    bounded number of events (preemption adds at most 3·max_preempts), so
    50·|stream| with a generous floor never false-trips on large sweeps
    while still catching true deadlocks."""
    return max(floor, 50 * n_stream)


def simulate(
    policy,
    node: Node,
    truth: Dict[str, JobProfile],
    *,
    queue: Optional[Sequence[str]] = None,
    arrivals: Optional[Sequence[Tuple[float, str]]] = None,
    charge_profiling: bool = False,
    slowdown_model=None,
    max_events: Optional[int] = None,
    elastic: Optional[ElasticConfig] = None,
    forecast=None,
    faults: Optional[FaultConfig] = None,
) -> ScheduleResult:
    """Run ``policy`` over the workload; returns exact energy/makespan.

    ``arrivals`` — optional online stream of ``(time, job)`` pairs; jobs
    with time ≤ 0 are waiting at t=0 (identical to passing them in
    ``queue``).  Without it every ``queue`` job waits at t=0, which is the
    paper's static single-window setup.

    ``slowdown_model(job, g, co_running) -> factor ≥ 1`` optionally models
    residual interference.  A model with ``domain_aware = True`` (e.g.
    ``repro.core.perfmodel.DomainInterferenceModel``) additionally receives
    the actual placement (units, home domain, running set) so the penalty
    keys on real domain co-residency instead of the co-runner count.

    ``elastic`` — optional ``ElasticConfig`` enabling preemption/
    checkpoint-restart and (with an elastic-aware policy) GPU resizing on
    completion events; ``None`` reproduces the static loop bit-exactly.

    ``forecast`` — optional ``ForecastConfig`` (repro.core.forecast): on a
    single node this wires online perf-model refinement (COMPLETE events
    feed the posterior, the policy's estimates shrink toward observed
    runtimes) and burst-conditioned resize bias; queueing wait forecasts
    and migration are cluster-level and stay inert here.  ``None`` (or an
    all-off config) never builds a plane — bit-identical schedules.

    ``faults`` — optional ``FaultConfig`` (repro.core.faults): seeded
    node failures, job crashes, and stragglers with checkpoint-rollback
    recovery and capped-backoff retries; ``None`` (or an all-off config)
    rides the exact pre-fault loop bit-identically.

    ``max_events`` defaults to ``max(100_000, 50·|stream|)`` so large
    sweeps never false-trip the deadlock guard.
    """
    if arrivals is None:
        stream = [(0.0, j) for j in (queue if queue is not None else sorted(truth))]
    else:
        if queue is not None:
            raise ValueError("pass either queue or arrivals, not both")
        stream = sorted(arrivals, key=lambda a: a[0])
    names = [j for _, j in stream]
    if len(set(names)) != len(names):
        raise ValueError("job names must be unique across the workload")
    if max_events is None:
        max_events = _auto_max_events(len(stream))

    injector = (
        FaultInjector(faults) if faults is not None and faults.enabled else None
    )
    sim = NodeSim(node, truth, policy, slowdown_model=slowdown_model,
                  elastic=elastic, faults=faults, fault_injector=injector)

    # forecast plane (ISSUE 5): never built on the default path, so
    # forecast=None rides the exact pre-forecast loop
    plane = None
    if forecast is not None and forecast.enabled:
        from repro.core.forecast import ForecastPlane

        plane = ForecastPlane(forecast, {"": node.units}, elastic=elastic)
        if hasattr(policy, "attach_forecast"):
            policy.attach_forecast(plane, "")

    def arrive(job: str, t: float) -> str:
        sim.arrive(job, t)
        if plane is not None:
            plane.on_arrival(t)
        return ""

    loop = EventLoop(
        {"": sim},
        arrive=arrive,
        max_events=max_events,
        cap_msg="simulator event cap exceeded (policy deadlock?)",
        elastic=elastic,
        faults=faults,
        fault_injector=injector,
        on_launch=(plane.on_launch if plane is not None else None),
        on_complete=(plane.on_complete if plane is not None else None),
    )
    for at, job in stream:
        if at <= 0.0:
            sim.arrival_of[job] = 0.0
            sim.waiting.append(job)
            if plane is not None:
                plane.on_arrival(0.0)
        else:
            loop.queue.push(at, EVT_ARRIVAL, job)
    loop.run()

    if sim.waiting:
        raise RuntimeError(
            f"policy {policy.name()} finished with waiting jobs {sim.waiting}"
        )
    result = sim.result(charge_profiling=charge_profiling)
    if plane is not None:
        result.forecast = plane.summary()
    return result

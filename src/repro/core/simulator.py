"""Discrete-event node simulator with energy accounting.

Drives any ``Policy`` through a workload: at t=0, at every job completion
and at every job *arrival* it hands the policy the current ``NodeView`` +
waiting queue and launches whatever the policy returns (validating
capacity, domain and contiguity constraints — a policy bug raises, it
never silently oversubscribes).

Energy integration is exact piecewise-constant:
  busy  = Σ_jobs  P_busy(job, g) · runtime(job, g)
  idle  = Σ_segments  (idle units) · P_idle_unit · dt   until makespan.
Invariant (tested): Σ busy GPU-seconds + Σ idle GPU-seconds = M · makespan.

The per-node state machine lives in ``NodeSim`` so that the single-node
``simulate()`` entry point and the cluster-scale event loop
(``repro.core.cluster``) share one accounting implementation — a 1-node
cluster reproduces ``simulate()`` exactly (regression-locked).
"""
from __future__ import annotations

import heapq
import time as _time
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.placement import PlacementState
from repro.core.types import (
    JobProfile,
    JobRecord,
    Launch,
    NodeView,
    RunningJob,
    ScheduleResult,
)


class Node:
    def __init__(self, units: int, domains: int, idle_power_per_unit: float):
        self.units = units
        self.domains = domains
        self.idle_power_per_unit = idle_power_per_unit


class NodeSim:
    """Single-node simulation state: placement, running set, waiting queue,
    and exact piecewise-constant energy integration.

    The owner (``simulate`` or ``Cluster.simulate``) runs the event loop and
    calls ``advance``/``arrive``/``complete``/``invoke_policy``; this object
    never sees the heap, so the same accounting serves both.
    """

    def __init__(
        self,
        node: Node,
        truth: Dict[str, JobProfile],
        policy,
        *,
        slowdown_model=None,
        name: str = "",
    ):
        self.node = node
        self.truth = truth
        self.policy = policy
        self.slowdown_model = slowdown_model
        self.name = name
        self.placement = PlacementState(node.units, node.domains)
        self.waiting: List[str] = []
        self.running: List[RunningJob] = []
        self.records: List[JobRecord] = []
        self.arrival_of: Dict[str, float] = {}
        self.t = 0.0
        self.busy_energy = 0.0
        self.idle_unit_seconds = 0.0
        self.decision_time = 0.0
        self.decision_events = 0

    def node_view(self) -> NodeView:
        return NodeView(
            t=self.t,
            total_units=self.node.units,
            domains=self.node.domains,
            free_units=self.placement.free_count(),
            running=list(self.running),
            free_map=list(self.placement.free),
            domain_jobs=list(self.placement.domain_jobs),
        )

    def advance(self, t: float) -> None:
        """Integrate idle unit-seconds over [self.t, t) and move the clock."""
        assert t >= self.t - 1e-12, (self.name, self.t, t)
        self.idle_unit_seconds += self.placement.free_count() * (t - self.t)
        self.t = t

    def arrive(self, job: str, t: float) -> None:
        self.advance(t)
        self.arrival_of[job] = t
        self.waiting.append(job)

    def complete(self, rj: RunningJob) -> None:
        """Advance to the completion instant, then free the job's units."""
        self.advance(rj.end)
        self.running.remove(rj)
        self.placement.release(rj.units, rj.domain)

    def invoke_policy(self) -> List[RunningJob]:
        """One scheduling event; returns the newly launched jobs (the owner
        pushes their completion events)."""
        t0 = _time.perf_counter()
        launches: List[Launch] = (
            self.policy.on_event(self.node_view(), list(self.waiting)) or []
        )
        self.decision_time += _time.perf_counter() - t0
        self.decision_events += 1
        out: List[RunningJob] = []
        for ln in launches:
            if ln.job not in self.waiting:
                raise ValueError(
                    f"{self.policy.name()} launched unknown/duplicate job {ln.job}"
                )
            prof = self.truth[ln.job]
            if ln.g not in prof.runtime:
                raise ValueError(f"{ln.job}: infeasible unit count {ln.g}")
            if self.placement.occupied_domains() >= self.node.domains:
                raise ValueError(
                    f"{self.policy.name()} exceeded domain cap K={self.node.domains}"
                )
            units, domain = self.placement.allocate(ln.g)  # raises if impossible
            factor = 1.0
            if self.slowdown_model is not None:
                factor = float(
                    self.slowdown_model(ln.job, ln.g, [r.job for r in self.running])
                )
                assert factor >= 1.0
            dur = prof.runtime[ln.g] * factor
            power = prof.busy_power[ln.g]
            rj = RunningJob(
                job=ln.job, g=ln.g, units=units, domain=domain,
                start=self.t, end=self.t + dur, power=power,
            )
            self.waiting.remove(ln.job)
            self.running.append(rj)
            self.busy_energy += power * dur
            self.records.append(
                JobRecord(
                    job=ln.job, g=ln.g, start=self.t, end=rj.end,
                    busy_energy=power * dur,
                    arrival=self.arrival_of.get(ln.job, 0.0),
                    node=self.name,
                    domain=domain,
                )
            )
            out.append(rj)
        return out

    def result(self, *, charge_profiling: bool = False) -> ScheduleResult:
        """Finalize. ``self.t`` is the node's last completion (its makespan)."""
        prof_energy = 0.0
        if charge_profiling:
            prof_energy = sum(
                self.truth[r.job].profiling_energy for r in self.records
            )
        return ScheduleResult(
            policy=self.policy.name(),
            makespan=self.t,
            busy_energy=self.busy_energy,
            idle_energy=self.idle_unit_seconds * self.node.idle_power_per_unit,
            profiling_energy=prof_energy,
            records=self.records,
            decision_time_s=self.decision_time,
            decision_events=self.decision_events,
        )


_ARRIVAL = 0  # event kinds; arrivals sort before same-time completions so a
_DONE = 1  # completion-triggered decision always sees the newcomers


def _auto_max_events(n_stream: int, floor: int = 100_000) -> int:
    """Deadlock-guard cap that scales with workload size: every job costs a
    bounded number of events, so 50·|stream| with a generous floor never
    false-trips on large sweeps while still catching true deadlocks."""
    return max(floor, 50 * n_stream)


def simulate(
    policy,
    node: Node,
    truth: Dict[str, JobProfile],
    *,
    queue: Optional[Sequence[str]] = None,
    arrivals: Optional[Sequence[Tuple[float, str]]] = None,
    charge_profiling: bool = False,
    slowdown_model=None,
    max_events: Optional[int] = None,
) -> ScheduleResult:
    """Run ``policy`` over the workload; returns exact energy/makespan.

    ``arrivals`` — optional online stream of ``(time, job)`` pairs; jobs
    with time ≤ 0 are waiting at t=0 (identical to passing them in
    ``queue``).  Without it every ``queue`` job waits at t=0, which is the
    paper's static single-window setup.

    ``slowdown_model(job, g, co_running) -> factor ≥ 1`` optionally models
    residual interference (NUMA-aware placement keeps it ≈ 1; §V-C's
    cross-domain GPU case can be modeled by the caller).

    ``max_events`` defaults to ``max(100_000, 50·|stream|)`` so large
    sweeps never false-trip the deadlock guard.
    """
    if arrivals is None:
        stream = [(0.0, j) for j in (queue if queue is not None else sorted(truth))]
    else:
        if queue is not None:
            raise ValueError("pass either queue or arrivals, not both")
        stream = sorted(arrivals, key=lambda a: a[0])
    names = [j for _, j in stream]
    if len(set(names)) != len(names):
        raise ValueError("job names must be unique across the workload")
    if max_events is None:
        max_events = _auto_max_events(len(stream))

    sim = NodeSim(node, truth, policy, slowdown_model=slowdown_model)
    heap: List[Tuple[float, int, int, object]] = []
    seq = 0
    for at, job in stream:
        if at <= 0.0:
            sim.arrival_of[job] = 0.0
            sim.waiting.append(job)
        else:
            heapq.heappush(heap, (at, _ARRIVAL, seq, job))
            seq += 1

    def push_launched(launched: List[RunningJob]) -> None:
        nonlocal seq
        for rj in launched:
            heapq.heappush(heap, (rj.end, _DONE, seq, rj))
            seq += 1

    push_launched(sim.invoke_policy())

    events = 0
    while heap:
        events += 1
        if events > max_events:
            raise RuntimeError("simulator event cap exceeded (policy deadlock?)")
        et, kind, _, payload = heapq.heappop(heap)
        if kind == _ARRIVAL:
            # batch all arrivals at this instant into one scheduling event
            sim.arrive(payload, et)
            while heap and heap[0][0] == et and heap[0][1] == _ARRIVAL:
                _, _, _, job = heapq.heappop(heap)
                sim.arrive(job, et)
            push_launched(sim.invoke_policy())
        else:
            sim.complete(payload)
            if sim.waiting:
                push_launched(sim.invoke_policy())

    if sim.waiting:
        raise RuntimeError(
            f"policy {policy.name()} finished with waiting jobs {sim.waiting}"
        )
    return sim.result(charge_profiling=charge_profiling)

"""NUMA/ICI-domain-aware placement (paper §III-C, DESIGN.md §2).

Constraints enforced:
  * at most K *occupied* isolation domains (each job is homed in exactly
    one domain; a domain only hosts a second job when no empty domain is
    reachable),
  * a job's units are **contiguous** (ICI torus contiguity on TPU; on a GPU
    node contiguity is vacuous but harmless),
  * unit counts need NOT align with domain boundaries (paper: a 3-GPU job
    + 1-GPU job share a 2-domain node).

Allocation is **domain-spreading first-fit**: among all feasible contiguous
starts, prefer the one whose *home domain* (the least-occupied domain the
range overlaps) currently hosts the fewest jobs, breaking ties toward the
lowest start.  On an empty node this degenerates to plain first-fit, but
once jobs are running it steers new jobs away from occupied domains —
two co-running jobs never share CPU-side domain resources while another
domain sits empty, which is what the paper's NUMA-aware placement means.

``domain_jobs`` tracks actual per-domain occupancy (jobs homed in each
domain); callers that care about the K co-run cap should count occupied
domains, not running jobs, via ``occupied_domains()``.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple


def domains_of_units(
    units: Sequence[int], total_units: int, domains: int
) -> Tuple[int, ...]:
    """Distinct isolation domains touched by a set of unit ids (ascending).

    A job homed in one domain can still *span* others when its contiguous
    range crosses a boundary (the paper's 3-GPU-on-a-2-domain-node case) —
    interference models key remote-traffic penalties on this.
    """
    return tuple(sorted({u * domains // total_units for u in units}))


class PlacementState:
    def __init__(self, units: int, domains: int):
        assert units >= 1 and domains >= 1
        self.units = units
        self.domains = domains
        self.free = [True] * units
        self.domain_jobs = [0] * domains  # jobs homed in each domain
        # fault plane (ISSUE 8): units lost to a node failure.  A dead
        # unit reads as occupied (free[u] = False), so allocation, the
        # contiguity scan, free_count() and therefore the idle-energy
        # integral all exclude it without touching any other code path.
        self.dead = [False] * units
        self._dead_n = 0

    def free_count(self) -> int:
        return sum(self.free)

    def dead_count(self) -> int:
        return self._dead_n

    def alive_units(self) -> int:
        return self.units - self._dead_n

    def mark_dead(self, ids) -> None:
        """Take failed units out of service.  The caller kills (and
        thereby frees) any job occupying them first."""
        for u in ids:
            assert self.free[u], f"unit {u} still occupied at failure"
            assert not self.dead[u], f"unit {u} already dead"
            self.free[u] = False
            self.dead[u] = True
            self._dead_n += 1

    def revive(self, ids) -> None:
        """Repaired units return to the free pool."""
        for u in ids:
            assert self.dead[u], f"unit {u} was not dead"
            self.dead[u] = False
            self.free[u] = True
            self._dead_n -= 1

    def occupied_domains(self) -> int:
        return sum(1 for c in self.domain_jobs if c)

    def domain_of_unit(self, u: int) -> int:
        return u * self.domains // self.units

    def _ranges(self) -> List[Tuple[int, int]]:
        """Maximal contiguous free (start, length) ranges."""
        out = []
        i = 0
        while i < self.units:
            if self.free[i]:
                j = i
                while j < self.units and self.free[j]:
                    j += 1
                out.append((i, j - i))
                i = j
            else:
                i += 1
        return out

    def can_allocate(self, g: int) -> bool:
        return any(length >= g for _, length in self._ranges())

    def max_contiguous(self) -> int:
        return max((length for _, length in self._ranges()), default=0)

    def _home_domain(self, start: int, g: int) -> int:
        """Least-occupied domain overlapped by [start, start+g)."""
        d_lo = self.domain_of_unit(start)
        d_hi = self.domain_of_unit(start + g - 1)
        return min(range(d_lo, d_hi + 1), key=lambda d: (self.domain_jobs[d], d))

    def allocate(self, g: int) -> Tuple[Tuple[int, ...], int]:
        """Domain-spreading first-fit contiguous allocation.

        Returns (unit ids, home domain).  The home domain's occupancy is
        incremented; pass it back to ``release`` when the job finishes.
        """
        best = None  # ((home occupancy, start), start, home)
        for start, length in self._ranges():
            for s in range(start, start + length - g + 1):
                home = self._home_domain(s, g)
                key = (self.domain_jobs[home], s)
                if best is None or key < best[0]:
                    best = (key, s, home)
                if self.domain_jobs[home] == 0:
                    break  # scanning right can't beat (0, s) within the range
            if best is not None and best[0][0] == 0:
                break  # later ranges have strictly larger starts
        if best is None:
            raise ValueError(f"cannot allocate {g} contiguous units (free={self.free})")
        _, s, home = best
        ids = tuple(range(s, s + g))
        for u in ids:
            self.free[u] = False
        self.domain_jobs[home] += 1
        return ids, home

    def release(self, ids, domain: Optional[int] = None) -> None:
        for u in ids:
            assert not self.free[u], f"double free of unit {u}"
            self.free[u] = True
        if domain is not None:
            assert self.domain_jobs[domain] > 0, f"release of empty domain {domain}"
            self.domain_jobs[domain] -= 1

"""NUMA/ICI-domain-aware placement (paper §III-C, DESIGN.md §2).

Constraints enforced:
  * at most K co-running jobs (one per isolation domain),
  * a job's units are **contiguous** (ICI torus contiguity on TPU; on a GPU
    node contiguity is vacuous but harmless),
  * unit counts need NOT align with domain boundaries (paper: a 3-GPU job
    + 1-GPU job share a 2-domain node).

Allocation is first-fit over contiguous free ranges; the domain label is
the index of the first unit's domain (CPU-side resources are partitioned
by domain in the real system; the simulator only needs the count cap).
"""
from __future__ import annotations

from typing import List, Tuple


class PlacementState:
    def __init__(self, units: int, domains: int):
        assert units >= 1 and domains >= 1
        self.units = units
        self.domains = domains
        self.free = [True] * units

    def free_count(self) -> int:
        return sum(self.free)

    def _ranges(self) -> List[Tuple[int, int]]:
        """Maximal contiguous free (start, length) ranges."""
        out = []
        i = 0
        while i < self.units:
            if self.free[i]:
                j = i
                while j < self.units and self.free[j]:
                    j += 1
                out.append((i, j - i))
                i = j
            else:
                i += 1
        return out

    def can_allocate(self, g: int) -> bool:
        return any(length >= g for _, length in self._ranges())

    def max_contiguous(self) -> int:
        return max((length for _, length in self._ranges()), default=0)

    def allocate(self, g: int) -> Tuple[Tuple[int, ...], int]:
        """First-fit contiguous allocation; returns (unit ids, domain)."""
        for start, length in self._ranges():
            if length >= g:
                ids = tuple(range(start, start + g))
                for u in ids:
                    self.free[u] = False
                domain = start * self.domains // self.units
                return ids, domain
        raise ValueError(f"cannot allocate {g} contiguous units (free={self.free})")

    def release(self, ids) -> None:
        for u in ids:
            assert not self.free[u], f"double free of unit {u}"
            self.free[u] = True

"""EcoSched — the paper's online energy-aware co-scheduler (§III).

Window-based event loop: at every scheduling event (t=0 and each job
completion), build the scheduling window, τ-filter each job's modes
(Phase I estimates, computed once per job), enumerate feasible joint
actions under GPU-capacity + domain constraints, score with Eq. (1), and
launch the argmin.  The empty action participates in scoring (its
R_energy is 0 and it pays the full idle term), which is exactly the λ
tradeoff: launching an energy-regretful mode must beat idling.  A
deadlock guard forces the best non-empty action when the node is
completely idle.

Scoring backends (``engine=``):
  * ``"vector"`` (default) — the batched numpy engine
    (``repro.core.engine``): one vector expression scores the whole
    candidate space, bitmask replay checks placement; the decision stays
    lightweight at pod scale (M=16, K=4, 17-job windows).
  * ``"python"`` — the pure-Python reference (``repro.core.actions``),
    parity-locked against the engine in tests/test_engine.py.

Launches are returned largest-count first — the same order the
feasibility replay allocated them — so the simulator's placement is
guaranteed to succeed and land on the checked units.

Beyond-paper options (all default-off; §Perf ablations):
  * ``lookahead``  — penalize actions whose predicted completion times
    diverge (tail fragmentation), a lightweight fix for the greedy
    policy's myopia.
  * ``elastic``    — see launch/coschedule.py: running jobs may be
    rescaled at checkpoint boundaries when the node drains.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

from repro.core.actions import enumerate_actions
from repro.core.engine import enumerate_scored
from repro.core.score import tau_filter
from repro.core.types import JobSpec, Launch, NodeView


class EcoSched:
    def __init__(
        self,
        perf_model,
        *,
        lam: float = 0.5,
        tau: float = 0.35,
        window: Optional[int] = None,
        exact_limit: int = 50_000,
        beam: int = 64,
        lookahead: float = 0.0,
        engine: str = "vector",
    ):
        if engine not in ("vector", "python"):
            raise ValueError(f"unknown scoring engine {engine!r}")
        self.perf_model = perf_model
        self.lam = lam
        self.tau = tau
        self.window = window
        self.exact_limit = exact_limit
        self.beam = beam
        self.lookahead = lookahead
        self.engine = engine

    def name(self) -> str:
        return "ecosched" if not self.lookahead else "ecosched+lookahead"

    def on_event(self, view: NodeView, waiting: Sequence[str]) -> List[Launch]:
        window_jobs = list(waiting[: self.window] if self.window else waiting)
        if not window_jobs or view.free_domains <= 0 or view.free_units <= 0:
            return []
        specs = [tau_filter(self.perf_model.spec(j), self.tau) for j in window_jobs]
        # a job whose mode list is empty (nothing feasible survives the
        # filter) can never launch; drop it rather than crash the scorer
        specs = [s for s in specs if s.modes]
        if not specs:
            return []
        if self.engine == "python":
            action = self._best_python(specs, view)
        else:
            action = self._best_vector(specs, view)
        launches = [Launch(job=sp.name, g=m.g) for sp, m in action]
        # descending count — the order the feasibility replay allocated
        launches.sort(key=lambda ln: -ln.g)
        return launches

    def _best_vector(self, specs, view: NodeView):
        try:
            batch = enumerate_scored(
                specs, view, list(view.free_map),
                lam=self.lam, exact_limit=self.exact_limit, beam=self.beam,
            )
        except OverflowError:
            # windows too wide for the engine's int64 action-set keys
            # (never the pod-scale target); the reference path has no limit
            return self._best_python(specs, view)
        scores = batch.scores
        if self.lookahead:
            scores = scores + self.lookahead * batch.spread
        i = batch.best_index(scores)
        if batch.n_jobs[i] == 0 and not view.running:
            j = batch.best_index(scores, nonempty=True)
            if j is not None:
                i = j
        return batch.action(i)

    def _best_python(self, specs, view: NodeView):
        scored = enumerate_actions(
            specs, view, list(view.free_map),
            lam=self.lam, exact_limit=self.exact_limit, beam=self.beam,
        )
        if self.lookahead:
            scored = [(s + self._lookahead_penalty(a, view), a) for s, a in scored]
        scored.sort(key=lambda kv: (kv[0], -sum(m.g for _, m in kv[1])))
        best_s, best_a = scored[0]
        if not best_a and not view.running:
            nonempty = [sa for sa in scored if sa[1]]
            if nonempty:
                best_s, best_a = nonempty[0]
        return best_a

    # -- beyond-paper: completion-alignment lookahead ----------------------
    def _lookahead_penalty(self, action, view: NodeView) -> float:
        if len(action) < 2:
            return 0.0
        # t_norm is relative within a job; as a *proxy* for alignment we
        # penalize spread of (t_norm · g) across co-launched jobs.
        loads = [m.t_norm * m.g for _, m in action]
        spread = (max(loads) - min(loads)) / max(max(loads), 1e-9)
        return self.lookahead * spread

"""EcoSched — the paper's online energy-aware co-scheduler (§III).

Window-based event loop: at every scheduling event (t=0 and each job
completion), build the scheduling window, τ-filter each job's modes
(Phase I estimates, computed once per job), enumerate feasible joint
actions under GPU-capacity + domain constraints, score with Eq. (1), and
launch the argmin.  The empty action participates in scoring (its
R_energy is 0 and it pays the full idle term), which is exactly the λ
tradeoff: launching an energy-regretful mode must beat idling.  A
deadlock guard forces the best non-empty action when the node is
completely idle.

Scoring backends (``engine=``):
  * ``"vector"`` (default) — the batched numpy engine
    (``repro.core.engine``): one vector expression scores the whole
    candidate space, bitmask replay checks placement; the decision stays
    lightweight at pod scale (M=16, K=4, 17-job windows).
  * ``"jax"`` — same cached enumeration, but the Eq. (1) score reduction
    and masked argmin run through the jitted JAX/Pallas kernel
    (``repro.kernels.score_reduce``); parity-locked to 1e-6 against the
    numpy path in tests/test_score_reduce.py.
  * ``"python"`` — the pure-Python reference (``repro.core.actions``),
    parity-locked against the engine in tests/test_engine.py.

Repeated decisions are incremental (``cache=True``, the default for the
array backends): τ-filtered specs are computed once per job, and a
``DecisionCache`` reuses spec tables, placement-oracle memos and whole
scored batches across events keyed on name-free window structure + the
placement bitmask — consecutive events that share a window, and instances
of the same application, skip enumeration entirely.  Caching is pure: the
schedule is bit-identical with the cache off (tests/test_decision_cache.py).

Launches are returned largest-count first — the same order the
feasibility replay allocated them — so the simulator's placement is
guaranteed to succeed and land on the checked units.

Beyond-paper options (all default-off; §Perf ablations):
  * ``lookahead``  — penalize actions whose predicted completion times
    diverge (tail fragmentation), a lightweight fix for the greedy
    policy's myopia.
  * elastic resizing — when the simulator runs with an ``ElasticConfig``
    (repro.core.events), the substrate calls ``propose_resizes`` on
    COMPLETE events: running jobs may be checkpointed and relaunched at a
    now-better count, with the candidates scored through the same batched
    Eq. (1) path plus a switch-cost bias.
  * forecast plane — with a ``ForecastConfig`` (repro.core.forecast) the
    entry points call ``attach_forecast``: the perf model becomes an
    online-refined posterior (τ-filtered specs re-derive when it bumps
    its ``version``) and the resize switch-cost bias scales with
    forecasted queue pressure.  Never attached on the default path.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.actions import enumerate_actions
from repro.core.engine import DecisionCache, _mask_of, enumerate_scored
from repro.core.score import tau_filter
from repro.core.types import JobSpec, Launch, NodeView, RunningJob


class EcoSched:
    def __init__(
        self,
        perf_model,
        *,
        lam: float = 0.5,
        tau: float = 0.35,
        lam_f: float = 0.0,
        window: Optional[int] = None,
        exact_limit: int = 50_000,
        beam: int = 64,
        lookahead: float = 0.0,
        engine: str = "vector",
        cache: bool = True,
    ):
        if engine not in ("vector", "python", "jax"):
            raise ValueError(f"unknown scoring engine {engine!r}")
        self.perf_model = perf_model
        self.lam = lam
        self.tau = tau
        # DVFS conservatism weight: λ_f penalizes (or, negative, rewards)
        # the mean frequency level of an action.  0.0 — the default — makes
        # the joint argmin purely energy-driven and keeps single-frequency
        # scores bit-identical to the count-only scorer.
        self.lam_f = lam_f
        self.window = window
        self.exact_limit = exact_limit
        self.beam = beam
        self.lookahead = lookahead
        self.engine = engine
        self._cache = DecisionCache() if (cache and engine != "python") else None
        self._filtered: Dict[str, JobSpec] = {}  # job -> τ-filtered spec
        # launch-level memo: decision state -> [(window position, g, f)].
        # The chosen action is a pure function of the (name-free) decision
        # state, so a repeated state skips scoring outright and only
        # rebinds window positions to the current job names.
        self._launch_memo: "OrderedDict[Tuple, Tuple]" = OrderedDict()
        self._launch_epoch = 0
        self.launch_hits = 0
        # fleet-batched decision staging (ISSUE 9): a coordinator
        # (repro.core.cluster.ClusterRun) may pre-run this node's Eq. (1)
        # reduction inside one cross-node kernel launch and park the
        # result here; ``_best_jax`` consumes it when the decision state
        # still matches, else recomputes solo.  ``stage_served`` counts
        # consumed stagings (observability + test hook).
        self._staged: Optional[dict] = None
        self.stage_served = 0
        # forecast plane (repro.core.forecast): attached by the simulation
        # entry points when a ForecastConfig is enabled; None otherwise
        self._plane = None
        self._node = ""
        self._pm_version = 0

    def name(self) -> str:
        return "ecosched" if not self.lookahead else "ecosched+lookahead"

    def cache_stats(self) -> Dict[str, float]:
        """Decision-cache hit/miss counters (empty when caching is off).
        ``event_hit_rate`` counts a scheduling event as a hit when either
        the launch memo or the scored-batch layer served it."""
        if self._cache is None:
            return {}
        s = self._cache.stats()
        s["launch_hits"] = self.launch_hits
        h = self.launch_hits + s["decision_hits"]
        m = s["decision_misses"]
        s["event_hit_rate"] = h / (h + m) if h + m else 0.0
        return s

    def attach_forecast(self, plane, node: str = "") -> None:
        """Wire the forecast plane (repro.core.forecast.ForecastPlane):
        wraps the perf model with the plane's refined posterior (online
        refinement, tentpole (a)) and conditions the resize switch-cost
        bias on forecasted queue pressure (tentpole (c)).  Called by the
        simulation entry points before any event fires."""
        self._plane = plane
        self._node = node
        self.perf_model = plane.refined_model(node, self.perf_model)

    def _spec(self, job: str) -> JobSpec:
        """τ-filtered Phase-I spec, computed once per job and reused across
        events (the estimates themselves are per-job constants, §III-B —
        unless an online-refined model bumps its ``version``, which drops
        the filtered cache so decisions see the posterior)."""
        v = getattr(self.perf_model, "version", 0)
        if v != self._pm_version:
            self._filtered.clear()
            self._pm_version = v
        s = self._filtered.get(job)
        if s is None:
            if len(self._filtered) >= 100_000:
                self._filtered.clear()  # bound endless-stream growth
            s = tau_filter(self.perf_model.spec(job), self.tau)
            self._filtered[job] = s
        return s

    def on_event(self, view: NodeView, waiting: Sequence[str]) -> List[Launch]:
        window_jobs = list(waiting[: self.window] if self.window else waiting)
        if not window_jobs or view.free_domains <= 0 or view.free_units <= 0:
            return []
        specs = [self._spec(j) for j in window_jobs]
        # a job whose mode list is empty (nothing feasible survives the
        # filter) can never launch; drop it rather than crash the scorer
        specs = [s for s in specs if s.modes]
        if not specs:
            return []
        key = None
        order = None
        if self._cache is not None and view.domain_jobs:
            if self._launch_epoch != self._cache.epoch:
                # token tables were reset; stale token keys could alias
                self._launch_memo.clear()
                self._launch_epoch = self._cache.epoch
            toks = tuple(self._cache.spec_token(s) for s in specs)
            # order-canonical memo key (stable sort): permuted windows with
            # the same structure multiset share one entry; stored pairs are
            # (canonical slot, g), mapped back through the current order
            order = DecisionCache.canonical_order(toks)
            ctoks = toks if order is None else tuple(toks[i] for i in order)
            key = (
                ctoks,
                _mask_of(view.free_map),
                tuple(view.domain_jobs),
                bool(view.running),  # the deadlock guard reads this
                view.total_units,
                view.dead_units,  # degraded capacity changes the argmin
                view.domains,
            )
            hit = self._launch_memo.get(key)
            if hit is not None:
                self._launch_memo.move_to_end(key)
                self.launch_hits += 1
                if order is None:
                    pairs = [(c, g, f) for c, g, f in hit]
                else:
                    pairs = [(order[c], g, f) for c, g, f in hit]
                # normalize equal-g ties to current-window position so a
                # permuted hit replays the order a cold evaluation of THIS
                # window would produce (cache purity)
                pairs.sort(key=lambda pg: (-pg[1], pg[0]))
                return [
                    Launch(job=specs[p].name, g=g, f=f) for p, g, f in pairs
                ]
        if self.engine == "python":
            action = self._best_python(specs, view)
        elif self.engine == "jax":
            action = self._best_jax(specs, view)
        else:
            action = self._best_vector(specs, view)
        # descending count — the order the feasibility replay allocated;
        # equal counts break toward the earlier window position, which is
        # exactly what the stable sort over ascending-position action
        # tuples produced, but stays well-defined when a cached action is
        # rebound to a permuted window
        pos_of = {id(sp): i for i, sp in enumerate(specs)}
        pairs = sorted(
            ((pos_of[id(sp)], m.g, m.f) for sp, m in action),
            key=lambda pg: (-pg[1], pg[0]),
        )
        if key is not None:
            if order is None:
                stored = tuple(pairs)
            else:  # window position -> canonical slot
                inv = [0] * len(specs)
                for c, p in enumerate(order):
                    inv[p] = c
                stored = tuple((inv[p], g, f) for p, g, f in pairs)
            self._launch_memo[key] = stored
            if len(self._launch_memo) > 8192:
                self._launch_memo.popitem(last=False)
        return [Launch(job=specs[p].name, g=g, f=f) for p, g, f in pairs]

    def _enumerate(self, specs, view: NodeView):
        # free_map is only read (mask/bitmask replay) — no defensive copy
        return enumerate_scored(
            specs, view, view.free_map,
            lam=self.lam, lam_f=self.lam_f,
            exact_limit=self.exact_limit, beam=self.beam,
            cache=self._cache,
        )

    def _best_vector(self, specs, view: NodeView):
        try:
            batch = self._enumerate(specs, view)
        except OverflowError:
            # windows too wide for the engine's int64 action-set keys
            # (never the pod-scale target); the reference path has no limit
            return self._best_python(specs, view)
        i = batch.best_cached(self.lookahead)
        # row 0 is always the empty action; any other row is non-empty
        if i == 0 and not view.running:
            j = batch.best_cached(self.lookahead, nonempty=True)
            if j is not None:
                i = j
        return batch.action(i)

    # -- fleet-batched decisions (ISSUE 9) ---------------------------------

    def _stage_sig(self, view: NodeView, specs) -> Tuple:
        """Everything the jax decision is a pure function of.  A staged
        result is only consumed when this matches at ``on_event`` time, so
        any drift between staging and consumption (a capacity event, a
        perf-model refinement, a reordered queue) falls back to the solo
        recomputation instead of serving a stale argmin."""
        return (
            tuple(s.name for s in specs),
            _mask_of(view.free_map),
            tuple(view.domain_jobs),
            bool(view.running),
            view.total_units,
            view.dead_units,
            view.domains,
            view.free_units,
            view.t,
            getattr(self.perf_model, "version", 0),
        )

    def stage_score(self, view: NodeView, waiting: Sequence[str]):
        """Phase 1 of a fleet-coordinated decision: replicate
        ``on_event``'s window/enumeration prefix (same caches, same spec
        tokens — so the imminent solo invocation behaves bit-identically
        whether or not staging happened) and return the kernel request
        dict for ``score_reduce_batch``.  Returns None when this event
        would not launch a solo kernel anyway (non-jax engine, empty or
        un-placeable window, launch-memo hit, overflow fallback)."""
        self._staged = None
        if self.engine != "jax":
            return None
        window_jobs = list(waiting[: self.window] if self.window else waiting)
        if not window_jobs or view.free_domains <= 0 or view.free_units <= 0:
            return None
        specs = [self._spec(j) for j in window_jobs]
        specs = [s for s in specs if s.modes]
        if not specs:
            return None
        if self._cache is not None and view.domain_jobs:
            if self._launch_epoch != self._cache.epoch:
                self._launch_memo.clear()
                self._launch_epoch = self._cache.epoch
            toks = tuple(self._cache.spec_token(s) for s in specs)
            order = DecisionCache.canonical_order(toks)
            ctoks = toks if order is None else tuple(toks[i] for i in order)
            key = (
                ctoks,
                _mask_of(view.free_map),
                tuple(view.domain_jobs),
                bool(view.running),
                view.total_units,
                view.dead_units,
                view.domains,
            )
            if key in self._launch_memo:
                return None  # on_event replays the memo; no kernel runs
        try:
            batch = self._enumerate(specs, view)
        except OverflowError:
            return None  # on_event falls back to the python reference
        dev, g, n = batch.padded_cols()
        fcol = batch.padded_f() if self.lam_f else None
        bias = (self.lookahead * batch.spread) if self.lookahead else None
        req = dict(
            dev=dev, g=g, n=n, lam=self.lam, g_free=view.free_units,
            M=view.alive_units, f=fcol, lam_f=self.lam_f, bias=bias,
        )
        self._staged = {
            "sig": self._stage_sig(view, specs),
            "batch": batch,
            "req": req,
            "guard": not view.running,
            "best": None,
        }
        return req

    def stage_round1(self, best: int):
        """Phase 2: record the batched round-1 argmin.  Returns the
        round-2 masked request when the idle-node deadlock guard needs one
        (the coordinator batches those too), else None."""
        st = self._staged
        if st is None:
            return None
        st["best"] = int(best)
        if best == 0 and st["guard"]:
            return dict(st["req"], mask=st["batch"].n_jobs > 0)
        return None

    def stage_round2(self, best: int) -> None:
        st = self._staged
        if st is not None and best >= 0:
            st["best"] = int(best)

    def stage_drop(self) -> None:
        self._staged = None

    def _best_jax(self, specs, view: NodeView):
        staged, self._staged = self._staged, None
        if (
            staged is not None
            and staged["best"] is not None
            and staged["sig"] == self._stage_sig(view, specs)
        ):
            self.stage_served += 1
            i = staged["best"]
            return staged["batch"].action(i) if i >= 0 else ()
        try:
            batch = self._enumerate(specs, view)
        except OverflowError:
            return self._best_python(specs, view)
        from repro.kernels.score_reduce import score_reduce

        dev, g, n = batch.padded_cols()
        # the f plane only shifts scores through λ_f; skip materializing it
        # when the weight is 0 (the kernel zero-fills it internally)
        fcol = batch.padded_f() if self.lam_f else None
        bias = (self.lookahead * batch.spread) if self.lookahead else None
        _, i = score_reduce(
            dev, g, n,
            lam=self.lam, g_free=view.free_units, M=view.alive_units,
            f=fcol, lam_f=self.lam_f, bias=bias,
        )
        if i < 0:  # unreachable: the empty action is always feasible
            return ()
        if i == 0 and not view.running:  # row 0 is the empty action
            _, j = score_reduce(
                dev, g, n,
                lam=self.lam, g_free=view.free_units, M=view.alive_units,
                f=fcol, lam_f=self.lam_f, bias=bias, mask=batch.n_jobs > 0,
            )
            if j >= 0:
                i = j
        return batch.action(i)

    def _best_python(self, specs, view: NodeView):
        scored = enumerate_actions(
            specs, view, list(view.free_map),
            lam=self.lam, lam_f=self.lam_f,
            exact_limit=self.exact_limit, beam=self.beam,
        )
        if self.lookahead:
            scored = [(s + self._lookahead_penalty(a, view), a) for s, a in scored]
        scored.sort(key=lambda kv: (kv[0], -sum(m.g for _, m in kv[1])))
        best_s, best_a = scored[0]
        if not best_a and not view.running:
            nonempty = [sa for sa in scored if sa[1]]
            if nonempty:
                best_s, best_a = nonempty[0]
        return best_a

    # -- elastic GPU resizing (ISSUE 4) ------------------------------------
    def propose_resizes(self, view: NodeView, *, frac_of, cfg) -> List[Launch]:
        """Substrate hook (``repro.core.events``): on a COMPLETE event,
        propose preempt-and-relaunch of one running job at a now-better
        (count, frequency) mode — a pure frequency retune rides the same
        checkpoint/relaunch mechanics as a count resize.

        Each running job's alternative (g, f) modes are scored through the
        same batched Eq. (1) path as launch decisions — a single-job window
        on the hypothetical node state with the job's units freed — with
        ``cfg.switch_cost`` added to every candidate that changes the
        joint mode, so a resize must beat staying put by the switch margin
        on the same scale the scheduler already optimizes.  On top of the
        score win, the predicted remaining-time saving (via the Phase-I
        t_norm ratio) must exceed the checkpoint + restart overhead by
        ``cfg.min_gain_s`` — energy-better-but-slower moves never degrade
        makespan.  Returns at most one proposal (the largest predicted
        gain); the substrate enforces its own guards on top.
        """
        if view.free_units <= 0 or not view.running:
            return []
        best: Optional[Tuple[float, Launch]] = None
        overhead = cfg.ckpt_time + cfg.restart_time
        # forecast-conditioned switch cost: under burst risk / queue
        # pressure the freed units are about to be needed, so changing a
        # count must clear a larger margin (identical to cfg.switch_cost
        # when no plane is attached)
        switch_cost = (
            cfg.switch_cost
            if self._plane is None
            else self._plane.resize_switch_cost(self._node, cfg.switch_cost, view.t)
        )
        for rj in view.running:
            if rj.preempted or frac_of(rj) >= 1.0:
                continue
            rem_t = rj.end - view.t  # wall time to completion as-is
            # only the useful-work tail scales with the count: a freshly
            # resumed job's restart head must not inflate the prediction
            useful_rem = rj.end - max(view.t, rj.start + rj.restart)
            if useful_rem <= overhead + cfg.min_gain_s:
                continue
            spec = self._spec(rj.job)
            if len(spec.modes) < 2:
                continue
            try:
                cur = spec.mode(rj.g, rj.f)
            except KeyError:
                continue  # current mode fell to the τ-filter; leave it be
            hypo = self._freed_view(view, rj)
            new = self._best_resize_mode(spec, hypo, switch_cost, rj.g, rj.f)
            if new is None or new == (rj.g, rj.f):
                continue
            g_new, f_new = new
            pred_rem = overhead + useful_rem * (
                spec.mode(g_new, f_new).t_norm / cur.t_norm
            )
            gain = rem_t - pred_rem
            if gain <= cfg.min_gain_s:
                continue
            if best is None or gain > best[0]:
                best = (gain, Launch(job=rj.job, g=g_new, f=f_new))
        return [best[1]] if best is not None else []

    @staticmethod
    def _freed_view(view: NodeView, rj: RunningJob) -> NodeView:
        """Hypothetical node state with ``rj``'s units and home domain
        freed — what the node looks like the instant the resize relaunches."""
        free_map = list(view.free_map)
        for u in rj.units:
            free_map[u] = True
        occ = list(view.domain_jobs) if view.domain_jobs else [0] * view.domains
        if occ and 0 <= rj.domain < len(occ) and occ[rj.domain] > 0:
            occ[rj.domain] -= 1
        return NodeView(
            t=view.t,
            total_units=view.total_units,
            domains=view.domains,
            free_units=view.free_units + rj.g,
            running=[r for r in view.running if r is not rj],
            free_map=free_map,
            domain_jobs=occ,
            dead_units=view.dead_units,
        )

    def _best_resize_mode(
        self,
        spec: JobSpec,
        hypo: NodeView,
        switch_cost: float,
        g_cur: int,
        f_cur: int,
    ) -> Optional[Tuple[int, int]]:
        """Best (count, frequency) mode for one job on the freed node
        state, switch-cost biased, scored through whichever backend the
        policy runs on.  "Staying put" is joint-mode identity: a candidate
        at the same count but a different DVFS level pays the switch cost
        too (it still costs a checkpoint/relaunch)."""
        if self.engine == "python":
            scored = enumerate_actions(
                [spec], hypo, list(hypo.free_map),
                lam=self.lam, lam_f=self.lam_f,
                exact_limit=self.exact_limit, beam=self.beam,
            )
            best = None
            for s, a in scored:
                if not a:
                    continue
                m = a[0][1]
                moved = m.g != g_cur or m.f != f_cur
                key = (s + (switch_cost if moved else 0.0), -m.g)
                if best is None or key < best[0]:
                    best = (key, (m.g, m.f))
            return best[1] if best else None
        try:
            batch = self._enumerate([spec], hypo)
        except OverflowError:  # pragma: no cover - single-job windows are tiny
            return None
        # single-job window: each non-empty row's total_g IS its count and
        # slot 0 of the padded f plane IS its frequency level
        moved = (batch.total_g != g_cur) | (
            batch.padded_f()[:, 0].astype(np.int64) != f_cur
        )
        bias = np.where(moved & (batch.n_jobs > 0), switch_cost, 0.0)
        if self.engine == "jax":
            from repro.kernels.score_reduce import score_reduce

            dev, g, n = batch.padded_cols()
            fcol = batch.padded_f() if self.lam_f else None
            _, i = score_reduce(
                dev, g, n,
                lam=self.lam, g_free=hypo.free_units, M=hypo.alive_units,
                f=fcol, lam_f=self.lam_f, bias=bias, mask=batch.n_jobs > 0,
            )
            if i < 0:
                return None
        else:
            i = batch.best_index(batch.scores + bias, nonempty=True)
            if i is None:
                return None
        action = batch.action(int(i))
        if not action:
            return None
        m = action[0][1]
        return (m.g, m.f)

    # -- beyond-paper: completion-alignment lookahead ----------------------
    def _lookahead_penalty(self, action, view: NodeView) -> float:
        if len(action) < 2:
            return 0.0
        # t_norm is relative within a job; as a *proxy* for alignment we
        # penalize spread of (t_norm · g) across co-launched jobs.
        loads = [m.t_norm * m.g for _, m in action]
        spread = (max(loads) - min(loads)) / max(max(loads), 1e-9)
        return self.lookahead * spread

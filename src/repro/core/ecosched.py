"""EcoSched — the paper's online energy-aware co-scheduler (§III).

Window-based event loop: at every scheduling event (t=0 and each job
completion), build the scheduling window, τ-filter each job's modes
(Phase I estimates, computed once per job), enumerate feasible joint
actions under GPU-capacity + domain constraints, score with Eq. (1), and
launch the argmin.  The empty action participates in scoring (its
R_energy is 0 and it pays the full idle term), which is exactly the λ
tradeoff: launching an energy-regretful mode must beat idling.  A
deadlock guard forces the best non-empty action when the node is
completely idle.

Beyond-paper options (all default-off; §Perf ablations):
  * ``lookahead``  — penalize actions whose predicted completion times
    diverge (tail fragmentation), a lightweight fix for the greedy
    policy's myopia.
  * ``elastic``    — see launch/coschedule.py: running jobs may be
    rescaled at checkpoint boundaries when the node drains.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

from repro.core.actions import enumerate_actions
from repro.core.score import tau_filter
from repro.core.types import JobSpec, Launch, NodeView


class EcoSched:
    def __init__(
        self,
        perf_model,
        *,
        lam: float = 0.5,
        tau: float = 0.35,
        window: Optional[int] = None,
        exact_limit: int = 50_000,
        beam: int = 64,
        lookahead: float = 0.0,
    ):
        self.perf_model = perf_model
        self.lam = lam
        self.tau = tau
        self.window = window
        self.exact_limit = exact_limit
        self.beam = beam
        self.lookahead = lookahead

    def name(self) -> str:
        return "ecosched" if not self.lookahead else "ecosched+lookahead"

    def on_event(self, view: NodeView, waiting: Sequence[str]) -> List[Launch]:
        window_jobs = list(waiting[: self.window] if self.window else waiting)
        if not window_jobs or view.free_domains <= 0 or view.free_units <= 0:
            return []
        specs = [tau_filter(self.perf_model.spec(j), self.tau) for j in window_jobs]
        scored = enumerate_actions(
            specs, view, list(view.free_map),
            lam=self.lam, exact_limit=self.exact_limit, beam=self.beam,
        )
        if self.lookahead:
            scored = [(s + self._lookahead_penalty(a, view), a) for s, a in scored]
        scored.sort(key=lambda kv: (kv[0], -sum(m.g for _, m in kv[1])))
        best_s, best_a = scored[0]
        if not best_a and not view.running:
            nonempty = [sa for sa in scored if sa[1]]
            if nonempty:
                best_s, best_a = nonempty[0]
        return [Launch(job=sp.name, g=m.g) for sp, m in best_a]

    # -- beyond-paper: completion-alignment lookahead ----------------------
    def _lookahead_penalty(self, action, view: NodeView) -> float:
        if len(action) < 2:
            return 0.0
        # t_norm is relative within a job; as a *proxy* for alignment we
        # penalize spread of (t_norm · g) across co-launched jobs.
        loads = [m.t_norm * m.g for _, m in action]
        spread = (max(loads) - min(loads)) / max(max(loads), 1e-9)
        return self.lookahead * spread

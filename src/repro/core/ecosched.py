"""EcoSched — the paper's online energy-aware co-scheduler (§III).

Window-based event loop: at every scheduling event (t=0 and each job
completion), build the scheduling window, τ-filter each job's modes
(Phase I estimates, computed once per job), enumerate feasible joint
actions under GPU-capacity + domain constraints, score with Eq. (1), and
launch the argmin.  The empty action participates in scoring (its
R_energy is 0 and it pays the full idle term), which is exactly the λ
tradeoff: launching an energy-regretful mode must beat idling.  A
deadlock guard forces the best non-empty action when the node is
completely idle.

Scoring backends (``engine=``):
  * ``"vector"`` (default) — the batched numpy engine
    (``repro.core.engine``): one vector expression scores the whole
    candidate space, bitmask replay checks placement; the decision stays
    lightweight at pod scale (M=16, K=4, 17-job windows).
  * ``"jax"`` — same cached enumeration, but the Eq. (1) score reduction
    and masked argmin run through the jitted JAX/Pallas kernel
    (``repro.kernels.score_reduce``); parity-locked to 1e-6 against the
    numpy path in tests/test_score_reduce.py.
  * ``"python"`` — the pure-Python reference (``repro.core.actions``),
    parity-locked against the engine in tests/test_engine.py.

Repeated decisions are incremental (``cache=True``, the default for the
array backends): τ-filtered specs are computed once per job, and a
``DecisionCache`` reuses spec tables, placement-oracle memos and whole
scored batches across events keyed on name-free window structure + the
placement bitmask — consecutive events that share a window, and instances
of the same application, skip enumeration entirely.  Caching is pure: the
schedule is bit-identical with the cache off (tests/test_decision_cache.py).

Launches are returned largest-count first — the same order the
feasibility replay allocated them — so the simulator's placement is
guaranteed to succeed and land on the checked units.

Beyond-paper options (all default-off; §Perf ablations):
  * ``lookahead``  — penalize actions whose predicted completion times
    diverge (tail fragmentation), a lightweight fix for the greedy
    policy's myopia.
  * ``elastic``    — see launch/coschedule.py: running jobs may be
    rescaled at checkpoint boundaries when the node drains.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.actions import enumerate_actions
from repro.core.engine import DecisionCache, _mask_of, enumerate_scored
from repro.core.score import tau_filter
from repro.core.types import JobSpec, Launch, NodeView


class EcoSched:
    def __init__(
        self,
        perf_model,
        *,
        lam: float = 0.5,
        tau: float = 0.35,
        window: Optional[int] = None,
        exact_limit: int = 50_000,
        beam: int = 64,
        lookahead: float = 0.0,
        engine: str = "vector",
        cache: bool = True,
    ):
        if engine not in ("vector", "python", "jax"):
            raise ValueError(f"unknown scoring engine {engine!r}")
        self.perf_model = perf_model
        self.lam = lam
        self.tau = tau
        self.window = window
        self.exact_limit = exact_limit
        self.beam = beam
        self.lookahead = lookahead
        self.engine = engine
        self._cache = DecisionCache() if (cache and engine != "python") else None
        self._filtered: Dict[str, JobSpec] = {}  # job -> τ-filtered spec
        # launch-level memo: decision state -> [(window position, g)].  The
        # chosen action is a pure function of the (name-free) decision
        # state, so a repeated state skips scoring outright and only
        # rebinds window positions to the current job names.
        self._launch_memo: "OrderedDict[Tuple, Tuple]" = OrderedDict()
        self._launch_epoch = 0
        self.launch_hits = 0

    def name(self) -> str:
        return "ecosched" if not self.lookahead else "ecosched+lookahead"

    def cache_stats(self) -> Dict[str, float]:
        """Decision-cache hit/miss counters (empty when caching is off).
        ``event_hit_rate`` counts a scheduling event as a hit when either
        the launch memo or the scored-batch layer served it."""
        if self._cache is None:
            return {}
        s = self._cache.stats()
        s["launch_hits"] = self.launch_hits
        h = self.launch_hits + s["decision_hits"]
        m = s["decision_misses"]
        s["event_hit_rate"] = h / (h + m) if h + m else 0.0
        return s

    def _spec(self, job: str) -> JobSpec:
        """τ-filtered Phase-I spec, computed once per job and reused across
        events (the estimates themselves are per-job constants, §III-B)."""
        s = self._filtered.get(job)
        if s is None:
            if len(self._filtered) >= 100_000:
                self._filtered.clear()  # bound endless-stream growth
            s = tau_filter(self.perf_model.spec(job), self.tau)
            self._filtered[job] = s
        return s

    def on_event(self, view: NodeView, waiting: Sequence[str]) -> List[Launch]:
        window_jobs = list(waiting[: self.window] if self.window else waiting)
        if not window_jobs or view.free_domains <= 0 or view.free_units <= 0:
            return []
        specs = [self._spec(j) for j in window_jobs]
        # a job whose mode list is empty (nothing feasible survives the
        # filter) can never launch; drop it rather than crash the scorer
        specs = [s for s in specs if s.modes]
        if not specs:
            return []
        key = None
        if self._cache is not None and view.domain_jobs:
            if self._launch_epoch != self._cache.epoch:
                # token tables were reset; stale token keys could alias
                self._launch_memo.clear()
                self._launch_epoch = self._cache.epoch
            key = (
                tuple(self._cache.spec_token(s) for s in specs),
                _mask_of(view.free_map),
                tuple(view.domain_jobs),
                bool(view.running),  # the deadlock guard reads this
                view.total_units,
                view.domains,
            )
            hit = self._launch_memo.get(key)
            if hit is not None:
                self._launch_memo.move_to_end(key)
                self.launch_hits += 1
                return [Launch(job=specs[p].name, g=g) for p, g in hit]
        if self.engine == "python":
            action = self._best_python(specs, view)
        elif self.engine == "jax":
            action = self._best_jax(specs, view)
        else:
            action = self._best_vector(specs, view)
        # descending count — the order the feasibility replay allocated
        pos_of = {id(sp): i for i, sp in enumerate(specs)}
        pairs = sorted(
            ((pos_of[id(sp)], m.g) for sp, m in action),
            key=lambda pg: -pg[1],
        )
        if key is not None:
            self._launch_memo[key] = tuple(pairs)
            if len(self._launch_memo) > 8192:
                self._launch_memo.popitem(last=False)
        return [Launch(job=specs[p].name, g=g) for p, g in pairs]

    def _enumerate(self, specs, view: NodeView):
        # free_map is only read (mask/bitmask replay) — no defensive copy
        return enumerate_scored(
            specs, view, view.free_map,
            lam=self.lam, exact_limit=self.exact_limit, beam=self.beam,
            cache=self._cache,
        )

    def _best_vector(self, specs, view: NodeView):
        try:
            batch = self._enumerate(specs, view)
        except OverflowError:
            # windows too wide for the engine's int64 action-set keys
            # (never the pod-scale target); the reference path has no limit
            return self._best_python(specs, view)
        i = batch.best_cached(self.lookahead)
        # row 0 is always the empty action; any other row is non-empty
        if i == 0 and not view.running:
            j = batch.best_cached(self.lookahead, nonempty=True)
            if j is not None:
                i = j
        return batch.action(i)

    def _best_jax(self, specs, view: NodeView):
        try:
            batch = self._enumerate(specs, view)
        except OverflowError:
            return self._best_python(specs, view)
        from repro.kernels.score_reduce import score_reduce

        dev, g, n = batch.padded_cols()
        bias = (self.lookahead * batch.spread) if self.lookahead else None
        _, i = score_reduce(
            dev, g, n,
            lam=self.lam, g_free=view.free_units, M=view.total_units, bias=bias,
        )
        if i < 0:  # unreachable: the empty action is always feasible
            return ()
        if i == 0 and not view.running:  # row 0 is the empty action
            _, j = score_reduce(
                dev, g, n,
                lam=self.lam, g_free=view.free_units, M=view.total_units,
                bias=bias, mask=batch.n_jobs > 0,
            )
            if j >= 0:
                i = j
        return batch.action(i)

    def _best_python(self, specs, view: NodeView):
        scored = enumerate_actions(
            specs, view, list(view.free_map),
            lam=self.lam, exact_limit=self.exact_limit, beam=self.beam,
        )
        if self.lookahead:
            scored = [(s + self._lookahead_penalty(a, view), a) for s, a in scored]
        scored.sort(key=lambda kv: (kv[0], -sum(m.g for _, m in kv[1])))
        best_s, best_a = scored[0]
        if not best_a and not view.running:
            nonempty = [sa for sa in scored if sa[1]]
            if nonempty:
                best_s, best_a = nonempty[0]
        return best_a

    # -- beyond-paper: completion-alignment lookahead ----------------------
    def _lookahead_penalty(self, action, view: NodeView) -> float:
        if len(action) < 2:
            return 0.0
        # t_norm is relative within a job; as a *proxy* for alignment we
        # penalize spread of (t_norm · g) across co-launched jobs.
        loads = [m.t_norm * m.g for _, m in action]
        spread = (max(loads) - min(loads)) / max(max(loads), 1e-9)
        return self.lookahead * spread

"""EcoSched — the paper's online energy-aware co-scheduler (§III).

Window-based event loop: at every scheduling event (t=0 and each job
completion), build the scheduling window, τ-filter each job's modes
(Phase I estimates, computed once per job), enumerate feasible joint
actions under GPU-capacity + domain constraints, score with Eq. (1), and
launch the argmin.  The empty action participates in scoring (its
R_energy is 0 and it pays the full idle term), which is exactly the λ
tradeoff: launching an energy-regretful mode must beat idling.  A
deadlock guard forces the best non-empty action when the node is
completely idle.

Scoring backends (``engine=``):
  * ``"vector"`` (default) — the batched numpy engine
    (``repro.core.engine``): one vector expression scores the whole
    candidate space, bitmask replay checks placement; the decision stays
    lightweight at pod scale (M=16, K=4, 17-job windows).
  * ``"jax"`` — same cached enumeration, but the Eq. (1) score reduction
    and masked argmin run through the jitted JAX/Pallas kernel
    (``repro.kernels.score_reduce``); parity-locked to 1e-6 against the
    numpy path in tests/test_score_reduce.py.
  * ``"python"`` — the pure-Python reference (``repro.core.actions``),
    parity-locked against the engine in tests/test_engine.py.

Repeated decisions are incremental (``cache=True``, the default for the
array backends): τ-filtered specs are computed once per job, and a
``DecisionCache`` reuses spec tables, placement-oracle memos and whole
scored batches across events keyed on name-free window structure + the
placement bitmask — consecutive events that share a window, and instances
of the same application, skip enumeration entirely.  Caching is pure: the
schedule is bit-identical with the cache off (tests/test_decision_cache.py).

Launches are returned largest-count first — the same order the
feasibility replay allocated them — so the simulator's placement is
guaranteed to succeed and land on the checked units.

Beyond-paper options (all default-off; §Perf ablations):
  * ``lookahead``  — penalize actions whose predicted completion times
    diverge (tail fragmentation), a lightweight fix for the greedy
    policy's myopia.
  * elastic resizing — when the simulator runs with an ``ElasticConfig``
    (repro.core.events), the substrate calls ``propose_resizes`` on
    COMPLETE events: running jobs may be checkpointed and relaunched at a
    now-better count, with the candidates scored through the same batched
    Eq. (1) path plus a switch-cost bias.
  * forecast plane — with a ``ForecastConfig`` (repro.core.forecast) the
    entry points call ``attach_forecast``: the perf model becomes an
    online-refined posterior (τ-filtered specs re-derive when it bumps
    its ``version``) and the resize switch-cost bias scales with
    forecasted queue pressure.  Never attached on the default path.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.actions import enumerate_actions
from repro.core.engine import DecisionCache, _mask_of, enumerate_scored
from repro.core.score import tau_filter
from repro.core.types import JobSpec, Launch, NodeView, RunningJob


class EcoSched:
    def __init__(
        self,
        perf_model,
        *,
        lam: float = 0.5,
        tau: float = 0.35,
        lam_f: float = 0.0,
        window: Optional[int] = None,
        exact_limit: int = 50_000,
        beam: int = 64,
        lookahead: float = 0.0,
        engine: str = "vector",
        cache=True,
        resize_batch: bool = True,
        launch_share: bool = True,
    ):
        if engine not in ("vector", "python", "jax"):
            raise ValueError(f"unknown scoring engine {engine!r}")
        self.perf_model = perf_model
        self.lam = lam
        self.tau = tau
        # DVFS conservatism weight: λ_f penalizes (or, negative, rewards)
        # the mean frequency level of an action.  0.0 — the default — makes
        # the joint argmin purely energy-driven and keeps single-frequency
        # scores bit-identical to the count-only scorer.
        self.lam_f = lam_f
        self.window = window
        self.exact_limit = exact_limit
        self.beam = beam
        self.lookahead = lookahead
        self.engine = engine
        # ``cache`` accepts a shared ``DecisionCache`` instance (ISSUE 10):
        # every cache key is name-free and structure-interned, so policies
        # on identically-shaped nodes can pool one cache and serve each
        # other's first-sight enumerations — at fleet scale each node sees
        # only a handful of jobs, so private caches never warm up.  The
        # decision is a pure function of the key either way: sharing
        # changes hit rates, never schedules.
        if isinstance(cache, DecisionCache):
            self._cache = cache if engine != "python" else None
        else:
            self._cache = (
                DecisionCache() if (cache and engine != "python") else None
            )
        self._filtered: Dict[str, JobSpec] = {}  # job -> τ-filtered spec
        # launch-level memo layers (stored *in* the DecisionCache, so fleet
        # peers pooling one cache replay each other's decisions too):
        #   * raw layer — exact decision state (token order included) ->
        #     final launch pairs; the chosen action is a pure function of
        #     the (name-free) state, so a repeat skips scoring outright.
        #   * tie-frontier layer (ISSUE 10 fast path, ``launch_share``) —
        #     *canonical* (token-sorted) state -> every argmin-optimal row
        #     (min score, max total count) in canonical slot form.  A
        #     permuted window re-breaks the tie in its own reference
        #     enumeration order (size, then ascending position tuple, then
        #     mode tuple) — exactly what its cold argmin would do — so the
        #     replay is bit-identical to scoring from scratch while
        #     skipping the enumeration *and* the kernel launch.  A
        #     single-winner canonical entry is unsound: exact
        #     cross-structure ties are structural here (normalized best
        #     modes all score dev=0) and the winner depends on window
        #     order.  ``launch_share=False`` disables the layer (the
        #     bench's pre-batching reference leg).
        self.launch_share = launch_share
        self.launch_hits = 0
        self.frontier_hits = 0
        # (batch, used_nonempty, chosen row) of the engine decision that
        # produced the current action — the frontier store reads it right
        # after engine dispatch; None when the python reference ran
        self._last_decision = None
        # fleet-batched decision staging (ISSUE 9): a coordinator
        # (repro.core.cluster.ClusterRun) may pre-run this node's Eq. (1)
        # reduction inside one cross-node kernel launch and park the
        # result here; ``_best_jax`` consumes it when the decision state
        # still matches, else recomputes solo.  ``stage_served`` counts
        # consumed stagings (observability + test hook).
        self._staged: Optional[dict] = None
        self.stage_served = 0
        # batched elastic resize scoring (ISSUE 10 tentpole): collect every
        # eligible running job's candidate window and score them through
        # one multi-window kernel launch instead of one launch per job.
        # ``resize_batch=False`` keeps the per-job loop (the measured
        # pre-batching baseline; schedules are bit-identical either way).
        self.resize_batch = resize_batch
        self._staged_resize: Optional[dict] = None
        self.resize_stage_served = 0
        # scratch free-unit mask for the resize hot path (_freed_view):
        # reused across candidates instead of allocating a fresh list +
        # per-unit Python loop per candidate per COMPLETE event
        self._free_scratch: Optional[np.ndarray] = None
        # forecast plane (repro.core.forecast): attached by the simulation
        # entry points when a ForecastConfig is enabled; None otherwise
        self._plane = None
        self._node = ""
        self._pm_version = 0

    def name(self) -> str:
        return "ecosched" if not self.lookahead else "ecosched+lookahead"

    def cache_stats(self) -> Dict[str, float]:
        """Decision-cache hit/miss counters (empty when caching is off).
        ``event_hit_rate`` counts a scheduling event as a hit when either
        the launch memo or the scored-batch layer served it."""
        if self._cache is None:
            return {}
        s = self._cache.stats()
        s["launch_hits"] = self.launch_hits
        s["frontier_hits"] = self.frontier_hits
        h = self.launch_hits + self.frontier_hits + s["decision_hits"]
        m = s["decision_misses"]
        s["event_hit_rate"] = h / (h + m) if h + m else 0.0
        return s

    def attach_forecast(self, plane, node: str = "") -> None:
        """Wire the forecast plane (repro.core.forecast.ForecastPlane):
        wraps the perf model with the plane's refined posterior (online
        refinement, tentpole (a)) and conditions the resize switch-cost
        bias on forecasted queue pressure (tentpole (c)).  Called by the
        simulation entry points before any event fires."""
        self._plane = plane
        self._node = node
        self.perf_model = plane.refined_model(node, self.perf_model)

    def _spec(self, job: str) -> JobSpec:
        """τ-filtered Phase-I spec, computed once per job and reused across
        events (the estimates themselves are per-job constants, §III-B —
        unless an online-refined model bumps its ``version``, which drops
        the filtered cache so decisions see the posterior)."""
        v = getattr(self.perf_model, "version", 0)
        if v != self._pm_version:
            self._filtered.clear()
            self._pm_version = v
        s = self._filtered.get(job)
        if s is None:
            if len(self._filtered) >= 100_000:
                self._filtered.clear()  # bound endless-stream growth
            s = tau_filter(self.perf_model.spec(job), self.tau)
            self._filtered[job] = s
        return s

    def on_event(self, view: NodeView, waiting: Sequence[str]) -> List[Launch]:
        window_jobs = list(waiting[: self.window] if self.window else waiting)
        if not window_jobs or view.free_domains <= 0 or view.free_units <= 0:
            return []
        specs = [self._spec(j) for j in window_jobs]
        # a job whose mode list is empty (nothing feasible survives the
        # filter) can never launch; drop it rather than crash the scorer
        specs = [s for s in specs if s.modes]
        if not specs:
            return []
        key = ckey = order = None
        if self._cache is not None and view.domain_jobs:
            toks = tuple(self._cache.spec_token(s) for s in specs)
            rest = (
                _mask_of(view.free_map),
                tuple(view.domain_jobs),
                bool(view.running),  # the deadlock guard reads this
                view.total_units,
                view.dead_units,  # degraded capacity changes the argmin
                view.domains,
            )
            # raw (order-sensitive) layer first: the chosen action breaks
            # exact score ties by window position, so a permuted window is
            # a *different* decision — a single-winner canonical key here
            # replayed the producer's tie order, which diverged from a cold
            # evaluation whenever two structures tied exactly
            key = (toks,) + rest
            hit = self._cache.launch(key)
            if hit is not None:
                self.launch_hits += 1
                return [
                    Launch(job=specs[p].name, g=g, f=f) for p, g, f in hit
                ]
            if self.launch_share:
                # canonical tie-frontier layer: permuted windows share the
                # full optimal set and re-break the tie in *this* window's
                # enumeration order — pure, unlike a single stored winner
                order = DecisionCache.canonical_order(toks)
                ckey = (
                    toks if order is None else tuple(toks[i] for i in order),
                ) + rest
                cands = self._cache.frontier(ckey)
                if cands is not None:
                    self.frontier_hits += 1
                    pairs = _replay_frontier(cands, order, specs)
                    self._cache.store_launch(key, pairs)
                    return [
                        Launch(job=specs[p].name, g=g, f=f)
                        for p, g, f in pairs
                    ]
        self._last_decision = None
        if self.engine == "python":
            action = self._best_python(specs, view)
        elif self.engine == "jax":
            action = self._best_jax(specs, view)
        else:
            action = self._best_vector(specs, view)
        # descending count — the order the feasibility replay allocated;
        # equal counts break toward the earlier window position
        pos_of = {id(sp): i for i, sp in enumerate(specs)}
        pairs = sorted(
            ((pos_of[id(sp)], m.g, m.f) for sp, m in action),
            key=lambda pg: (-pg[1], pg[0]),
        )
        if key is not None:
            self._cache.store_launch(key, tuple(pairs))
            if ckey is not None and self._last_decision is not None:
                self._store_frontier(ckey, order, *self._last_decision)
        return [Launch(job=specs[p].name, g=g, f=f) for p, g, f in pairs]

    def _store_frontier(self, ckey, order, batch, used_nonempty, chosen):
        """Store the decision's full argmin frontier — every row attaining
        (min biased score, max total count), restricted to non-empty rows
        when the idle-node guard re-scored — keyed on the canonical decision
        state.  Scores, totals and the frontier *set* are order-free; only
        the tie-break among members depends on window order, so the replay
        (`_replay_frontier`) re-breaks it per consumer.  Skipped for beam
        batches (their row *set* is window-order dependent) and when the
        engine's winner is not the frontier's producer-order minimum (a
        float32 kernel argmin diverging from the float64 frontier would
        make replay unsound — never observed, but cheap to guard)."""
        if not getattr(batch, "exact", False):
            return
        sc = batch.scores
        if self.lookahead:
            sc = sc + self.lookahead * batch.spread
        if used_nonempty:
            idxs = np.flatnonzero(batch.n_jobs > 0)
            if idxs.size == 0:
                return
            sub = sc[idxs]
            tie = idxs[sub == sub.min()]
        else:
            tie = np.flatnonzero(sc == sc.min())
        tot = batch.total_g[tie]
        frontier = tie[tot == tot.max()]
        if frontier.size > 64 or int(frontier[0]) != chosen:
            return
        J = len(batch.specs)
        slot_of = list(range(J))
        if order is not None:
            for c, p in enumerate(order):
                slot_of[p] = c
        cands = tuple(
            tuple(sorted((slot_of[p], m) for p, m in batch.row_pairs(int(r))))
            for r in frontier
        )
        self._cache.store_frontier(ckey, cands)

    def _enumerate(self, specs, view: NodeView):
        # free_map is only read (mask/bitmask replay) — no defensive copy
        return enumerate_scored(
            specs, view, view.free_map,
            lam=self.lam, lam_f=self.lam_f,
            exact_limit=self.exact_limit, beam=self.beam,
            cache=self._cache,
        )

    def _best_vector(self, specs, view: NodeView):
        try:
            batch = self._enumerate(specs, view)
        except OverflowError:
            # windows too wide for the engine's int64 action-set keys
            # (never the pod-scale target); the reference path has no limit
            return self._best_python(specs, view)
        used_nonempty = False
        i = batch.best_cached(self.lookahead)
        # row 0 is always the empty action; any other row is non-empty
        if i == 0 and not view.running:
            j = batch.best_cached(self.lookahead, nonempty=True)
            if j is not None:
                i = j
                used_nonempty = True
        self._last_decision = (batch, used_nonempty, int(i))
        return batch.action(i)

    # -- fleet-batched decisions (ISSUE 9) ---------------------------------

    def _stage_sig(self, view: NodeView, specs) -> Tuple:
        """Everything the jax decision is a pure function of.  A staged
        result is only consumed when this matches at ``on_event`` time, so
        any drift between staging and consumption (a capacity event, a
        perf-model refinement, a reordered queue) falls back to the solo
        recomputation instead of serving a stale argmin."""
        return (
            tuple(s.name for s in specs),
            _mask_of(view.free_map),
            tuple(view.domain_jobs),
            bool(view.running),
            view.total_units,
            view.dead_units,
            view.domains,
            view.free_units,
            view.t,
            getattr(self.perf_model, "version", 0),
        )

    def stage_score(self, view: NodeView, waiting: Sequence[str]):
        """Phase 1 of a fleet-coordinated decision: replicate
        ``on_event``'s window/enumeration prefix (same caches, same spec
        tokens — so the imminent solo invocation behaves bit-identically
        whether or not staging happened) and return the kernel request
        dict for ``score_reduce_batch``.  Returns None when this event
        would not launch a solo kernel anyway (non-jax engine, empty or
        un-placeable window, launch-memo hit, overflow fallback)."""
        self._staged = None
        if self.engine != "jax":
            return None
        window_jobs = list(waiting[: self.window] if self.window else waiting)
        if not window_jobs or view.free_domains <= 0 or view.free_units <= 0:
            return None
        specs = [self._spec(j) for j in window_jobs]
        specs = [s for s in specs if s.modes]
        if not specs:
            return None
        if self._cache is not None and view.domain_jobs:
            toks = tuple(self._cache.spec_token(s) for s in specs)
            rest = (
                _mask_of(view.free_map),
                tuple(view.domain_jobs),
                bool(view.running),
                view.total_units,
                view.dead_units,
                view.domains,
            )
            if self._cache.launch((toks,) + rest) is not None:
                return None  # on_event replays the memo; no kernel runs
            if self.launch_share:
                order = DecisionCache.canonical_order(toks)
                ckey = (
                    toks if order is None else tuple(toks[i] for i in order),
                ) + rest
                if self._cache.frontier(ckey) is not None:
                    return None  # on_event re-breaks the frontier tie
        try:
            batch = self._enumerate(specs, view)
        except OverflowError:
            return None  # on_event falls back to the python reference
        dev, g, n = batch.padded_cols()
        fcol = batch.padded_f() if self.lam_f else None
        bias = (self.lookahead * batch.spread) if self.lookahead else None
        req = dict(
            dev=dev, g=g, n=n, lam=self.lam, g_free=view.free_units,
            M=view.alive_units, f=fcol, lam_f=self.lam_f, bias=bias,
        )
        self._staged = {
            "sig": self._stage_sig(view, specs),
            "batch": batch,
            "req": req,
            "guard": not view.running,
            "best": None,
        }
        return req

    def stage_round1(self, best: int):
        """Phase 2: record the batched round-1 argmin.  Returns the
        round-2 masked request when the idle-node deadlock guard needs one
        (the coordinator batches those too), else None."""
        st = self._staged
        if st is None:
            return None
        st["best"] = int(best)
        if best == 0 and st["guard"]:
            return dict(st["req"], mask=st["batch"].n_jobs > 0)
        return None

    def stage_round2(self, best: int) -> None:
        st = self._staged
        if st is not None and best >= 0:
            st["best"] = int(best)
            st["nonempty"] = True  # guard re-score chose this row

    def stage_drop(self) -> None:
        self._staged = None

    def _best_jax(self, specs, view: NodeView):
        staged, self._staged = self._staged, None
        if (
            staged is not None
            and staged["best"] is not None
            and staged["sig"] == self._stage_sig(view, specs)
        ):
            self.stage_served += 1
            i = staged["best"]
            if i >= 0:
                self._last_decision = (
                    staged["batch"], staged.get("nonempty", False), int(i)
                )
                return staged["batch"].action(i)
            return ()
        try:
            batch = self._enumerate(specs, view)
        except OverflowError:
            return self._best_python(specs, view)
        from repro.kernels.score_reduce import score_reduce

        dev, g, n = batch.padded_cols()
        # the f plane only shifts scores through λ_f; skip materializing it
        # when the weight is 0 (the kernel zero-fills it internally)
        fcol = batch.padded_f() if self.lam_f else None
        bias = (self.lookahead * batch.spread) if self.lookahead else None
        _, i = score_reduce(
            dev, g, n,
            lam=self.lam, g_free=view.free_units, M=view.alive_units,
            f=fcol, lam_f=self.lam_f, bias=bias,
        )
        if i < 0:  # unreachable: the empty action is always feasible
            return ()
        used_nonempty = False
        if i == 0 and not view.running:  # row 0 is the empty action
            _, j = score_reduce(
                dev, g, n,
                lam=self.lam, g_free=view.free_units, M=view.alive_units,
                f=fcol, lam_f=self.lam_f, bias=bias, mask=batch.n_jobs > 0,
            )
            if j >= 0:
                i = j
                used_nonempty = True
        self._last_decision = (batch, used_nonempty, int(i))
        return batch.action(i)

    def _best_python(self, specs, view: NodeView):
        scored = enumerate_actions(
            specs, view, list(view.free_map),
            lam=self.lam, lam_f=self.lam_f,
            exact_limit=self.exact_limit, beam=self.beam,
        )
        if self.lookahead:
            scored = [(s + self._lookahead_penalty(a, view), a) for s, a in scored]
        scored.sort(key=lambda kv: (kv[0], -sum(m.g for _, m in kv[1])))
        best_s, best_a = scored[0]
        if not best_a and not view.running:
            nonempty = [sa for sa in scored if sa[1]]
            if nonempty:
                best_s, best_a = nonempty[0]
        return best_a

    # -- elastic GPU resizing (ISSUE 4; batched scoring ISSUE 10) ----------
    def propose_resizes(self, view: NodeView, *, frac_of, cfg) -> List[Launch]:
        """Substrate hook (``repro.core.events``): on a COMPLETE event,
        propose preempt-and-relaunch of one running job at a now-better
        (count, frequency) mode — a pure frequency retune rides the same
        checkpoint/relaunch mechanics as a count resize.

        Each running job's alternative (g, f) modes are scored through the
        same batched Eq. (1) path as launch decisions — a single-job window
        on the hypothetical node state with the job's units freed — with
        ``cfg.switch_cost`` added to every candidate that changes the
        joint mode, so a resize must beat staying put by the switch margin
        on the same scale the scheduler already optimizes.  On top of the
        score win, the predicted remaining-time saving (via the Phase-I
        t_norm ratio) must exceed the checkpoint + restart overhead by
        ``cfg.min_gain_s`` — energy-better-but-slower moves never degrade
        makespan.  Returns at most one proposal (the largest predicted
        gain); the substrate enforces its own guards on top.

        With ``resize_batch`` (the default for the array engines) every
        candidate window is scored in ONE kernel/vector reduction instead
        of one per running job, and a fleet coordinator may have pre-run
        the whole reduction inside a cross-node COMPLETE-burst launch
        (``stage_resize``) — consumed only on an exact decision-state
        signature match, so schedules are bit-identical either way.
        """
        staged, self._staged_resize = self._staged_resize, None
        if view.free_units <= 0 or not view.running:
            return []
        # forecast-conditioned switch cost: under burst risk / queue
        # pressure the freed units are about to be needed, so changing a
        # count must clear a larger margin (identical to cfg.switch_cost
        # when no plane is attached)
        switch_cost = (
            cfg.switch_cost
            if self._plane is None
            else self._plane.resize_switch_cost(self._node, cfg.switch_cost, view.t)
        )
        if (
            staged is not None
            and staged["bests"] is not None
            and staged["sig"] == self._resize_sig(view, switch_cost, cfg)
        ):
            self.resize_stage_served += 1
            return self._pick_resize(staged["cands"], staged["bests"], cfg)
        if not self.resize_batch or self.engine == "python":
            return self._propose_solo(view, frac_of, cfg, switch_cost)
        cands = self._resize_candidates(view, frac_of, cfg)
        if not cands:
            return []
        reqs = self._resize_requests(cands, switch_cost)
        if self.engine == "jax":
            from repro.kernels.score_reduce import score_reduce_multi

            bests = [b for _, b in score_reduce_multi(reqs)]
        else:  # vector: the same per-window argmin, batched numpy
            bests = [
                c["batch"].best_index(
                    c["batch"].scores + c["bias"], nonempty=True
                )
                for c in cands
            ]
        return self._pick_resize(cands, bests, cfg)

    def _propose_solo(
        self, view: NodeView, frac_of, cfg, switch_cost: float
    ) -> List[Launch]:
        """The pre-batching per-job loop: one enumeration + one scoring
        reduction per eligible running job (kept as the reference/baseline
        leg; also the ``python`` engine's path)."""
        best: Optional[Tuple[float, Launch]] = None
        overhead = cfg.ckpt_time + cfg.restart_time
        for rj in view.running:
            if rj.preempted or frac_of(rj) >= 1.0:
                continue
            rem_t = rj.end - view.t  # wall time to completion as-is
            # only the useful-work tail scales with the count: a freshly
            # resumed job's restart head must not inflate the prediction
            useful_rem = rj.end - max(view.t, rj.start + rj.restart)
            if useful_rem <= overhead + cfg.min_gain_s:
                continue
            spec = self._spec(rj.job)
            if len(spec.modes) < 2:
                continue
            try:
                cur = spec.mode(rj.g, rj.f)
            except KeyError:
                continue  # current mode fell to the τ-filter; leave it be
            hypo = self._freed_view(view, rj)
            new = self._best_resize_mode(spec, hypo, switch_cost, rj.g, rj.f)
            if new is None or new == (rj.g, rj.f):
                continue
            g_new, f_new = new
            pred_rem = overhead + useful_rem * (
                spec.mode(g_new, f_new).t_norm / cur.t_norm
            )
            gain = rem_t - pred_rem
            if gain <= cfg.min_gain_s:
                continue
            if best is None or gain > best[0]:
                best = (gain, Launch(job=rj.job, g=g_new, f=f_new))
        return [best[1]] if best is not None else []

    def _resize_candidates(self, view: NodeView, frac_of, cfg) -> List[dict]:
        """The guard prefix of the per-job loop, shared by the batched and
        staged paths: collect every eligible running job's candidate
        window (same guards, same order) with its enumeration done but the
        scoring deferred."""
        overhead = cfg.ckpt_time + cfg.restart_time
        cands: List[dict] = []
        for rj in view.running:
            if rj.preempted or frac_of(rj) >= 1.0:
                continue
            rem_t = rj.end - view.t
            useful_rem = rj.end - max(view.t, rj.start + rj.restart)
            if useful_rem <= overhead + cfg.min_gain_s:
                continue
            spec = self._spec(rj.job)
            if len(spec.modes) < 2:
                continue
            try:
                cur = spec.mode(rj.g, rj.f)
            except KeyError:
                continue
            hypo = self._freed_view(view, rj)
            try:
                batch = self._enumerate([spec], hypo)
            except OverflowError:  # pragma: no cover - single-job windows
                continue
            # single-job window: each non-empty row's total_g IS its count
            # and slot 0 of the padded f plane IS its frequency level
            moved = (batch.total_g != rj.g) | (
                batch.padded_f()[:, 0].astype(np.int64) != rj.f
            )
            cands.append(
                dict(
                    rj=rj, cur=cur, batch=batch, moved=moved,
                    rem_t=rem_t, useful_rem=useful_rem,
                    g_free=hypo.free_units, M=hypo.alive_units,
                )
            )
        return cands

    def _resize_requests(
        self, cands: List[dict], switch_cost: float
    ) -> List[dict]:
        """Kernel request dict per candidate window (the
        ``score_reduce_multi`` shape); also materializes each window's
        switch-cost bias on the candidate entry."""
        reqs = []
        for c in cands:
            batch = c["batch"]
            bias = np.where(
                c["moved"] & (batch.n_jobs > 0), switch_cost, 0.0
            )
            c["bias"] = bias
            dev, g, n = batch.padded_cols()
            reqs.append(
                dict(
                    dev=dev, g=g, n=n, lam=self.lam,
                    g_free=c["g_free"], M=c["M"],
                    f=batch.padded_f() if self.lam_f else None,
                    lam_f=self.lam_f, bias=bias, mask=batch.n_jobs > 0,
                )
            )
        return reqs

    def _pick_resize(
        self, cands: List[dict], bests: Sequence[Optional[int]], cfg
    ) -> List[Launch]:
        """Apply the post-score guards (joint-mode identity, predicted
        min-gain) to the per-window argmins and keep the largest-gain
        proposal — the exact tail of the per-job loop."""
        best: Optional[Tuple[float, Launch]] = None
        overhead = cfg.ckpt_time + cfg.restart_time
        for c, i in zip(cands, bests):
            if i is None or i < 0:
                continue
            action = c["batch"].action(int(i))
            if not action:
                continue
            m = action[0][1]
            rj = c["rj"]
            if (m.g, m.f) == (rj.g, rj.f):
                continue
            pred_rem = overhead + c["useful_rem"] * (
                m.t_norm / c["cur"].t_norm
            )
            gain = c["rem_t"] - pred_rem
            if gain <= cfg.min_gain_s:
                continue
            if best is None or gain > best[0]:
                best = (gain, Launch(job=rj.job, g=m.g, f=m.f))
        return [best[1]] if best is not None else []

    # -- COMPLETE-burst staging (ISSUE 10) ---------------------------------

    def _resize_sig(self, view: NodeView, switch_cost: float, cfg) -> Tuple:
        """Everything the resize decision is a pure function of: the node
        state the candidate windows were built from, every running job's
        mode/timing fields (candidacy guards and gain predictions read
        them), the effective switch cost (forecast planes condition it on
        mutable queue-pressure state), the cfg knobs, and the perf-model
        version (spec tables).  A staged result is consumed only on an
        exact match, so any drift between the predicted post-COMPLETE
        state and the real one falls back to the solo recomputation."""
        return (
            view.t,
            _mask_of(view.free_map),
            tuple(view.domain_jobs),
            view.total_units,
            view.dead_units,
            view.domains,
            view.free_units,
            tuple(
                (rj.job, rj.g, rj.f, rj.end, rj.start, rj.restart,
                 rj.frac0, rj.preempted, rj.failed, rj.domain,
                 tuple(rj.units))
                for rj in view.running
            ),
            switch_cost,
            (cfg.ckpt_time, cfg.restart_time, cfg.min_gain_s,
             cfg.switch_cost),
            getattr(self.perf_model, "version", 0),
        )

    def stage_resize(self, view: NodeView, *, frac_of, cfg):
        """Phase 1 of a fleet-coordinated COMPLETE burst: build this
        node's resize candidate windows against the *predicted*
        post-completion view and return their kernel requests for the
        coordinator's single cross-node ``score_reduce_multi`` launch.
        Returns None when the imminent solo pass would not launch kernels
        anyway (non-jax engine, batching off, no eligible candidates)."""
        self._staged_resize = None
        if self.engine != "jax" or not self.resize_batch:
            return None
        if view.free_units <= 0 or not view.running:
            return None
        switch_cost = (
            cfg.switch_cost
            if self._plane is None
            else self._plane.resize_switch_cost(self._node, cfg.switch_cost, view.t)
        )
        cands = self._resize_candidates(view, frac_of, cfg)
        if not cands:
            return None
        reqs = self._resize_requests(cands, switch_cost)
        self._staged_resize = {
            "sig": self._resize_sig(view, switch_cost, cfg),
            "cands": cands,
            "bests": None,
        }
        return reqs

    def stage_resize_results(self, bests: Sequence[int]) -> None:
        """Phase 2: park the batched per-window argmins for consumption
        by the next ``propose_resizes`` call (signature-guarded)."""
        st = self._staged_resize
        if st is not None:
            st["bests"] = [int(b) for b in bests]

    def stage_resize_drop(self) -> None:
        self._staged_resize = None

    def _freed_view(
        self, view: NodeView, rj: RunningJob, t: Optional[float] = None,
        scratch: bool = True,
    ) -> NodeView:
        """Hypothetical node state with ``rj``'s units and home domain
        freed — what the node looks like the instant the resize relaunches
        (or, with ``t``, the predicted post-COMPLETE state a burst
        coordinator stages against).  With ``scratch`` (the resize hot
        path) the returned ``free_map`` aliases a per-policy numpy buffer
        and is valid only until the next scratch call — candidates are
        built and enumerated one at a time; pass ``scratch=False`` for a
        view that must outlive the loop."""
        if scratch:
            nu = view.total_units
            buf = self._free_scratch
            if buf is None or buf.shape[0] < nu:
                buf = self._free_scratch = np.empty(nu, dtype=bool)
            free_map = buf[:nu]
            free_map[:] = view.free_map
            for u in rj.units:
                free_map[u] = True
        else:
            free_map = list(view.free_map)
            for u in rj.units:
                free_map[u] = True
        occ = list(view.domain_jobs) if view.domain_jobs else [0] * view.domains
        if occ and 0 <= rj.domain < len(occ) and occ[rj.domain] > 0:
            occ[rj.domain] -= 1
        return NodeView(
            t=view.t if t is None else t,
            total_units=view.total_units,
            domains=view.domains,
            free_units=view.free_units + rj.g,
            running=[r for r in view.running if r is not rj],
            free_map=free_map,
            domain_jobs=occ,
            dead_units=view.dead_units,
        )

    def _best_resize_mode(
        self,
        spec: JobSpec,
        hypo: NodeView,
        switch_cost: float,
        g_cur: int,
        f_cur: int,
    ) -> Optional[Tuple[int, int]]:
        """Best (count, frequency) mode for one job on the freed node
        state, switch-cost biased, scored through whichever backend the
        policy runs on.  "Staying put" is joint-mode identity: a candidate
        at the same count but a different DVFS level pays the switch cost
        too (it still costs a checkpoint/relaunch)."""
        if self.engine == "python":
            scored = enumerate_actions(
                [spec], hypo, list(hypo.free_map),
                lam=self.lam, lam_f=self.lam_f,
                exact_limit=self.exact_limit, beam=self.beam,
            )
            best = None
            for s, a in scored:
                if not a:
                    continue
                m = a[0][1]
                moved = m.g != g_cur or m.f != f_cur
                key = (s + (switch_cost if moved else 0.0), -m.g)
                if best is None or key < best[0]:
                    best = (key, (m.g, m.f))
            return best[1] if best else None
        try:
            batch = self._enumerate([spec], hypo)
        except OverflowError:  # pragma: no cover - single-job windows are tiny
            return None
        # single-job window: each non-empty row's total_g IS its count and
        # slot 0 of the padded f plane IS its frequency level
        moved = (batch.total_g != g_cur) | (
            batch.padded_f()[:, 0].astype(np.int64) != f_cur
        )
        bias = np.where(moved & (batch.n_jobs > 0), switch_cost, 0.0)
        if self.engine == "jax":
            from repro.kernels.score_reduce import score_reduce

            dev, g, n = batch.padded_cols()
            fcol = batch.padded_f() if self.lam_f else None
            _, i = score_reduce(
                dev, g, n,
                lam=self.lam, g_free=hypo.free_units, M=hypo.alive_units,
                f=fcol, lam_f=self.lam_f, bias=bias, mask=batch.n_jobs > 0,
            )
            if i < 0:
                return None
        else:
            i = batch.best_index(batch.scores + bias, nonempty=True)
            if i is None:
                return None
        action = batch.action(int(i))
        if not action:
            return None
        m = action[0][1]
        return (m.g, m.f)

    # -- beyond-paper: completion-alignment lookahead ----------------------
    def _lookahead_penalty(self, action, view: NodeView) -> float:
        if len(action) < 2:
            return 0.0
        # t_norm is relative within a job; as a *proxy* for alignment we
        # penalize spread of (t_norm · g) across co-launched jobs.
        loads = [m.t_norm * m.g for _, m in action]
        spread = (max(loads) - min(loads)) / max(max(loads), 1e-9)
        return self.lookahead * spread


def _replay_frontier(cands, order, specs) -> Tuple:
    """Re-break a stored tie frontier in the consumer window's order.

    ``cands`` holds every argmin-optimal action of the decision in
    canonical slot form; the cold argmin picks whichever of them the
    consumer's reference enumeration generates first — rows enumerate by
    ascending action size, then lexicographically by (ascending position
    tuple, mode tuple) — so mapping slots onto this window's positions
    (slot ``c`` holds position ``order[c]``) and taking the minimum of
    that key reproduces the cold choice exactly.  Returns the launch-memo
    pair tuple ((position, g, f), ...) sorted the way ``on_event`` emits
    launches (descending count, then position)."""
    best_key = best = None
    for cand in cands:
        mapped = sorted((c if order is None else order[c], m) for c, m in cand)
        k = (
            len(mapped),
            tuple(p for p, _ in mapped),
            tuple(m for _, m in mapped),
        )
        if best_key is None or k < best_key:
            best_key, best = k, mapped
    return tuple(
        sorted(
            ((p, specs[p].modes[m].g, specs[p].modes[m].f) for p, m in best),
            key=lambda pg: (-pg[1], pg[0]),
        )
    )

"""Paper-calibrated workload: 17 applications × {H100, A100, V100}.

The paper releases no raw runtimes ("link will be provided after
acceptance"), so the workload is reconstructed from every quantitative
anchor in the text (DESIGN.md §6):

  * Table II    — EcoSched's chosen GPU counts per app per system,
  * Fig. 2      — gpt2 3→2 ≈ 3–8% perf loss / ~24% energy saving;
                  pot3d 4→3; resnet50 4→3,
  * §V-B        — pot3d 4→2 (10%), resnet50 4→3 (5%), gpt2 3→2 (8%),
  * §V-C        — gpt2: 1287 W @3 GPUs vs 946 W @2 (⇒ P(g) = P0·g^0.757);
                  profiling energy gpt2 64 kJ, vgg16 34 kJ, ≤70 kJ each;
                  idle power 70 W/GPU; miniweather V100 4→1: 40% loss /
                  20% energy saving,
  * Fig. 1      — miniweather performance-optimal at 1 on H100, 4 on V100,
  * §V-A        — V100 is compute-bound: most apps scale to 4.

Runtime curves are expressed as speedup tuples (s1..s4), t(g) = t1/s_g;
busy power as P(g) = P0·g^β.  The DRAM-utilization profiling signal is
generated from the bandwidth identity util(g) ∝ 1/(t(g)·g) with a
per-app distortion so Phase I sees a realistic (imperfect) signal.
Free parameters (absolute t1 values) are fixed plausible magnitudes and
held constant across policies — all reported metrics are relative.
"""
from __future__ import annotations

import hashlib
import math
from typing import Dict, Tuple

import numpy as np

from repro.core.types import JobProfile

BETA_DEFAULT = 0.757  # from gpt2 power anchor: 1287/946 = (3/2)^β

# Table I order — the single scheduling window queue.
APP_ORDER = (
    "conjugateGradient", "MonteCarlo", "simpleP2P", "streamOrderedAllocation",
    "lbm", "cloverleaf", "tealeaf", "minisweep", "pot3d", "miniweather",
    "resnet101", "resnet152", "resnet50", "vgg19", "vgg16", "bert", "gpt2",
)

# solo 1-GPU runtime (s) on H100; A100/V100 scale by system factor.
# Long-running magnitudes (§VI: "ML training workloads commonly run for
# hours") so one-time profiling energy amortizes as in §V-C.
T1_H100 = {
    "conjugateGradient": 1260, "MonteCarlo": 900, "simpleP2P": 720,
    "streamOrderedAllocation": 720, "lbm": 5400, "cloverleaf": 4500,
    "tealeaf": 4200, "minisweep": 2700, "pot3d": 6000, "miniweather": 3200,
    "resnet101": 9000, "resnet152": 10800, "resnet50": 7200,
    "vgg19": 7200, "vgg16": 6300, "bert": 8100, "gpt2": 9000,
}

# 1-GPU busy power (W) on H100
P0_H100 = {
    "conjugateGradient": 380, "MonteCarlo": 310, "simpleP2P": 300,
    "streamOrderedAllocation": 305, "lbm": 430, "cloverleaf": 420,
    "tealeaf": 410, "minisweep": 390, "pot3d": 440, "miniweather": 370,
    "resnet101": 470, "resnet152": 480, "resnet50": 460,
    "vgg19": 450, "vgg16": 440, "bert": 490, "gpt2": 559,
}

PROFILING_KJ = {  # §V-C anchors + bounded ≤70 kJ
    "gpt2": 64.0, "vgg16": 34.0, "bert": 58.0, "resnet152": 52.0,
    "resnet101": 47.0, "resnet50": 41.0, "vgg19": 38.0, "pot3d": 55.0,
    "lbm": 49.0, "cloverleaf": 45.0, "tealeaf": 43.0, "minisweep": 33.0,
    "miniweather": 30.0, "conjugateGradient": 26.0, "MonteCarlo": 22.0,
    "simpleP2P": 20.0, "streamOrderedAllocation": 20.0,
}

# speedup tuples (s1, s2, s3, s4); β overrides in POWER_BETA
STRONG = (1.0, 1.90, 2.70, 3.50)  # compute-bound strong scaler
SPEEDUPS: Dict[str, Dict[str, Tuple[float, float, float, float]]] = {
    "h100": {
        "conjugateGradient": (1.0, 1.80, 2.50, 3.35),
        "MonteCarlo": (1.0, 0.95, 0.92, 0.90),
        "simpleP2P": (1.0, 1.60, 1.58, 1.55),
        "streamOrderedAllocation": (1.0, 1.60, 1.59, 1.56),
        "lbm": STRONG,
        "cloverleaf": (1.0, 1.88, 2.65, 3.46),
        "tealeaf": (1.0, 1.85, 2.60, 3.42),
        "minisweep": (1.0, 1.87, 2.62, 3.42),
        "pot3d": (1.0, 1.750, 1.880, 1.925),  # §V-B: 4→2 = +10%
        "miniweather": (1.0, 0.90, 0.85, 0.80),  # Fig.1: optimal at 1
        "resnet101": (1.0, 1.90, 2.72, 2.66),
        "resnet152": (1.0, 1.90, 2.70, 2.64),
        "resnet50": (1.0, 1.90, 2.67, 2.80),  # §V-B: 4→3 = +5%
        "vgg19": (1.0, 1.17, 1.19, 1.21),
        "vgg16": (1.0, 1.18, 1.20, 1.22),
        "bert": (1.0, 1.88, 2.68, 3.52),
        "gpt2": (1.0, 1.850, 2.000, 1.950),  # opt at 3; 3→2 = +8% (§V-B)
    },
    "a100": {
        "conjugateGradient": (1.0, 1.75, 1.80, 1.85),
        "MonteCarlo": (1.0, 0.96, 0.93, 0.91),
        "simpleP2P": (1.0, 1.62, 1.60, 1.57),
        "streamOrderedAllocation": (1.0, 1.62, 1.61, 1.58),
        "lbm": STRONG,
        "cloverleaf": STRONG,
        "tealeaf": (1.0, 1.88, 2.66, 3.46),
        "minisweep": (1.0, 1.88, 2.64, 3.44),
        "pot3d": (1.0, 1.90, 2.70, 3.50),
        "miniweather": (1.0, 0.92, 0.88, 0.85),
        "resnet101": (1.0, 1.80, 1.92, 1.88),
        "resnet152": (1.0, 1.80, 1.93, 1.89),
        "resnet50": (1.0, 1.90, 2.68, 3.50),
        "vgg19": (1.0, 1.15, 1.20, 1.25),
        "vgg16": (1.0, 1.70, 1.75, 1.80),
        "bert": (1.0, 1.89, 2.68, 3.52),
        "gpt2": (1.0, 1.90, 2.70, 3.50),
    },
    "v100": {
        "conjugateGradient": (1.0, 1.90, 2.65, 3.50),
        "MonteCarlo": (1.0, 0.97, 0.94, 0.92),
        "simpleP2P": (1.0, 1.65, 1.63, 1.60),
        "streamOrderedAllocation": (1.0, 1.65, 1.64, 1.61),
        "lbm": (1.0, 1.92, 2.75, 3.55),
        "cloverleaf": (1.0, 1.92, 2.74, 3.53),
        "tealeaf": (1.0, 1.91, 2.72, 3.52),
        "minisweep": (1.0, 1.90, 2.70, 3.50),
        "pot3d": (1.0, 1.91, 2.73, 3.52),
        "miniweather": (1.0, 1.22, 1.32, 1.40),  # §V-C: 4→1 = +40%
        "resnet101": (1.0, 1.90, 2.72, 2.80),
        "resnet152": (1.0, 1.91, 2.70, 3.50),
        "resnet50": (1.0, 1.90, 2.71, 3.50),
        "vgg19": (1.0, 1.90, 2.68, 3.50),
        "vgg16": (1.0, 1.88, 2.70, 2.78),
        "bert": (1.0, 1.88, 2.72, 2.80),
        "gpt2": (1.0, 1.90, 2.69, 3.50),
    },
}

# Per-app power exponents.  β reflects per-GPU utilization at higher
# counts: strong scalers keep every GPU busy (β ≈ 0.757, the gpt2 anchor);
# flat scalers leave added GPUs underutilized, so total power grows slowly.
BETA_FLAT = 0.45
POWER_BETA: Dict[Tuple[str, str], float] = {
    ("v100", "miniweather"): 0.40,  # §V-C: 4→1 saves ~20% energy
    ("h100", "miniweather"): 0.45,
    ("a100", "miniweather"): 0.45,
    ("h100", "MonteCarlo"): BETA_FLAT,
    ("a100", "MonteCarlo"): BETA_FLAT,
    ("v100", "MonteCarlo"): BETA_FLAT,
    ("h100", "vgg16"): BETA_FLAT,
    ("h100", "vgg19"): BETA_FLAT,
    ("a100", "vgg19"): BETA_FLAT,
    ("h100", "simpleP2P"): 0.55,
    ("h100", "streamOrderedAllocation"): 0.55,
    ("a100", "simpleP2P"): 0.55,
    ("a100", "streamOrderedAllocation"): 0.55,
    ("v100", "simpleP2P"): 0.55,
    ("v100", "streamOrderedAllocation"): 0.55,
}

SYSTEM_SCALE = {  # runtime ×, power ×, idle W/GPU
    "h100": (1.0, 1.00, 70.0),
    "a100": (1.6, 0.60, 55.0),
    "v100": (2.8, 0.45, 40.0),
}

# per-app distortion of the DRAM-util signal (Phase I never sees a perfect
# inverse-runtime signal; compute-bound apps deviate most — Fig. 5 scatter)
_SIGNAL_DISTORTION = {
    "MonteCarlo": 0.03, "miniweather": 0.02, "conjugateGradient": 0.02,
    "bert": 0.02, "gpt2": 0.015, "lbm": 0.01, "pot3d": 0.01,
}

# Memory-bound fraction μ per application — the DVFS slowdown shape
# (Afzal et al.: memory-bound kernels barely slow when the core clock
# drops, so their energy sweet spot sits well below base clock; compute-
# bound kernels slow ~linearly and stay at base).  Bandwidth-dominated
# stencil/streaming codes sit high, dense-GEMM training moderate,
# latency/compute-bound kernels low.
MEMORY_BOUND_MU: Dict[str, float] = {
    "conjugateGradient": 0.55, "MonteCarlo": 0.10, "simpleP2P": 0.70,
    "streamOrderedAllocation": 0.72, "lbm": 0.75, "cloverleaf": 0.62,
    "tealeaf": 0.65, "minisweep": 0.35, "pot3d": 0.68, "miniweather": 0.58,
    "resnet101": 0.30, "resnet152": 0.28, "resnet50": 0.33,
    "vgg19": 0.26, "vgg16": 0.27, "bert": 0.22, "gpt2": 0.20,
}


def freq_curves(
    system: str, app: str, levels: int
) -> Tuple[Dict[int, float], Dict[int, float]]:
    """Analytic DVFS sweet-spot curves for one (chip, app) pair: per-level
    (runtime multiplier, power multiplier) dicts, level 0 = base clock.

    Runtime stretches only in the compute-bound fraction (sub-linear
    slowdown), power falls with the chip's cubic-ish dynamic curve above a
    static floor — so E(f) = T(f)·P(f) has an interior minimum for
    memory-bound apps.  ``levels`` is clamped to the chip's ratio ladder.
    """
    from repro.roofline.hw import CHIPS

    chip = CHIPS[system.lower()]
    mu = MEMORY_BOUND_MU.get(app, 0.3)
    n = max(1, min(int(levels), len(chip.freq_ratios)))
    ft = {f: chip.freq_time_multiplier(f, mu) for f in range(n)}
    fp = {f: chip.freq_power_multiplier(f) for f in range(n)}
    return ft, fp


def build_system(system: str, freq_levels: int = 1) -> Dict[str, JobProfile]:
    """JobProfile table for one platform.  ``freq_levels=1`` (default)
    builds the count-only profiles — bit-identical to the pre-DVFS tables;
    ``freq_levels>1`` attaches the analytic sweet-spot frequency curves
    (clamped to the chip's ratio ladder)."""
    system = system.lower()
    t_scale, p_scale, _idle = SYSTEM_SCALE[system]
    out: Dict[str, JobProfile] = {}
    for app in APP_ORDER:
        s = SPEEDUPS[system][app]
        t1 = T1_H100[app] * t_scale
        runtime = {g: t1 / s[g - 1] for g in (1, 2, 3, 4)}
        beta = POWER_BETA.get((system, app), BETA_DEFAULT)
        p0 = P0_H100[app] * p_scale
        power = {g: p0 * g**beta for g in (1, 2, 3, 4)}
        # profiling signal with deterministic per-(app,g) distortion
        dis = _SIGNAL_DISTORTION.get(app, 0.0)
        seed = int.from_bytes(hashlib.md5(f"{system}|{app}".encode()).digest()[:4], "little")
        rng = np.random.default_rng(seed)
        util = {}
        for g in (1, 2, 3, 4):
            base = 1.0 / (runtime[g] * g)
            draw = float(np.clip(rng.standard_normal(), -1.5, 1.5))
            util[g] = base * (1.0 + dis * draw)
        ft: Dict[int, float] = {}
        fp: Dict[int, float] = {}
        if freq_levels > 1:
            ft, fp = freq_curves(system, app, freq_levels)
        out[app] = JobProfile(
            name=app,
            runtime=runtime,
            busy_power=power,
            dram_util=util,
            profiling_energy=PROFILING_KJ[app] * 1e3 * p_scale,
            profiling_time=60.0,
            freq_time=ft,
            freq_power=fp,
        )
    return out


def idle_power(system: str) -> float:
    return SYSTEM_SCALE[system.lower()][2]


def cross_numa_slowdown(job: str, g: int, co_running) -> float:
    """§V-C residual interference: a 3-unit job on a 2-domain node has one
    GPU in the remote domain (~5%); any co-running pair sees ~2% residual."""
    if g == 3 and co_running:
        return 1.05
    if co_running:
        return 1.02
    return 1.0


# Table II — the paper's reported EcoSched GPU-count choices (validation).
TABLE_II = {
    "bert": {"h100": 4, "a100": 4, "v100": 3},
    "cloverleaf": {"h100": 4, "a100": 4, "v100": 4},
    "conjugateGradient": {"h100": 4, "a100": 2, "v100": 4},
    "gpt2": {"h100": 2, "a100": 4, "v100": 4},
    "lbm": {"h100": 4, "a100": 4, "v100": 4},
    "minisweep": {"h100": 4, "a100": 4, "v100": 4},
    "miniweather": {"h100": 1, "a100": 1, "v100": 1},
    "MonteCarlo": {"h100": 1, "a100": 1, "v100": 1},
    "pot3d": {"h100": 2, "a100": 4, "v100": 4},
    "resnet101": {"h100": 3, "a100": 2, "v100": 3},
    "resnet152": {"h100": 3, "a100": 2, "v100": 4},
    "resnet50": {"h100": 3, "a100": 4, "v100": 4},
    "simpleP2P": {"h100": 2, "a100": 2, "v100": 2},
    "streamOrderedAllocation": {"h100": 2, "a100": 2, "v100": 2},
    "tealeaf": {"h100": 4, "a100": 4, "v100": 4},
    "vgg16": {"h100": 1, "a100": 2, "v100": 3},
    "vgg19": {"h100": 1, "a100": 1, "v100": 4},
}

# Headline results to validate against (paper §V-A).
PAPER_HEADLINE = {
    "h100": {
        "ecosched": {"energy": 0.148, "makespan": 0.301, "edp": 0.404},
        "marble": {"energy": 0.042, "makespan": 0.115},
        "oracle": {"energy": 0.179, "edp": 0.475},
    },
    "v100": {
        "ecosched": {"energy": 0.044, "makespan": 0.141, "edp": 0.179},
        "marble": {"energy": 0.016, "makespan": 0.070, "edp": 0.085},
        "oracle": {"energy": 0.045, "edp": 0.182},
    },
}

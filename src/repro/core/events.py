"""Unified event-queue substrate (ISSUE 4).

One typed event heap + one driver loop shared by the single-node
``simulate()`` (repro.core.simulator) and the cluster-scale
``Cluster.simulate()`` (repro.core.cluster).  Before this module the two
entry points carried divergent copies of the same loop; now both build an
``EventLoop`` over the same ``NodeSim`` accounting and differ only in the
hooks they plug in (arrival routing, array-state bookkeeping, migration
candidate selection).

Event kinds, in tie-break order at one instant:

  ARRIVAL  — a job enters the system (batched: all same-instant arrivals
             are absorbed before the policies run, so a completion-driven
             decision always sees the newcomers),
  COMPLETE — a running job finishes and frees its units,
  PREEMPT  — a checkpoint write finishes: the preempted job's units free
             and the job re-enters a queue with its remaining work,
  RESUME   — a preempted job re-enters its node's waiting queue,
  MIGRATE  — a waiting (possibly preempted) job lands on another node
             after the migration delay,
  NODE_FAIL / NODE_RECOVER / JOB_FAIL / RETRY — the fault plane
             (ISSUE 8): a node loses k of its GPUs (or all of them) and
             is repaired later; a running job crashes; a killed job
             re-enters a waiting queue after capped exponential backoff.

The ARRIVAL < COMPLETE ordering is exactly the pre-refactor contract, so
with the elastic machinery disabled (``elastic=None``) the substrate pops
the identical event sequence and produces bit-identical schedules — the
regression lock in tests/test_events.py pins golden fingerprints captured
from the pre-refactor loops.

Elastic capabilities (all default-off, ``ElasticConfig``):

  * **preemption / checkpoint-restart** — a running job can be
    checkpointed: its units stay held for ``ckpt_time`` (energy charged at
    ``ckpt_power_scale``·job power), then the job re-enters the waiting
    queue carrying its completed-work fraction; the next launch pays
    ``restart_time`` on top of the remaining work at the new count.
  * **elastic GPU resizing** — on COMPLETE events the node policy may
    propose preempt-and-relaunch of a running job at a now-better unit
    count (``propose_resizes`` hook; EcoSched scores the candidates
    through the batched Eq. (1) engine with a switch-cost bias).  The
    relaunch itself goes through the normal scheduling path, so the
    resized job re-enters the scored window like any other candidate.
  * **job migration** — after a COMPLETE event the cluster may requeue a
    waiting or preempted job from a backlogged node onto the completing
    node when the predicted wait beats the move cost (migration delay,
    plus the restart charge a preempted job will pay anyway).

Every elastic action is bounded: at most one resize and one migration per
COMPLETE event, ``max_preempts`` checkpoints per job, and a job within
``ckpt_time + restart_time`` of finishing is never preempted.

The fault plane (``FaultConfig``, default-off — ``faults=None`` rides
the exact pre-fault path) threads failures through the same heap:

  * a seeded per-node timeline pushes NODE_FAIL/NODE_RECOVER cycles;
    a failure kills every overlapping job (work since its last
    checkpoint is lost and re-done, the unrun energy refunded, the
    burned segment stays charged), marks the lost units dead so
    placement, idle-energy integration, and the Eq. (1) scorers all see
    the degraded capacity, and repairs them at recovery;
  * a per-(job, segment) exponential hazard pushes JOB_FAIL crashes;
  * every kill retries through RETRY events with capped exponential
    backoff (``max_retries``, then the job is *lost* — dropped with an
    ``on_lost`` notification rather than requeued forever).

NODE_FAIL/NODE_RECOVER regenerate forever (the timeline never ends), so
the batch ``run()`` stops when no *work* events or waiting jobs remain;
the heap keeps the timeline, which is exactly what the incremental
control-plane drivers need to resume.
"""
from __future__ import annotations

import heapq
import time as _time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.faults import FaultConfig, FaultInjector

# Event kinds.  ARRIVAL/COMPLETE keep the pre-refactor numeric order
# (arrivals sort before same-time completions); the elastic kinds follow,
# then the fault plane's.
EVT_ARRIVAL = 0
EVT_COMPLETE = 1
EVT_PREEMPT = 2
EVT_RESUME = 3
EVT_MIGRATE = 4
EVT_NODE_FAIL = 5
EVT_NODE_RECOVER = 6
EVT_JOB_FAIL = 7
EVT_RETRY = 8

EVENT_NAMES = {
    EVT_ARRIVAL: "ARRIVAL",
    EVT_COMPLETE: "COMPLETE",
    EVT_PREEMPT: "PREEMPT",
    EVT_RESUME: "RESUME",
    EVT_MIGRATE: "MIGRATE",
    EVT_NODE_FAIL: "NODE_FAIL",
    EVT_NODE_RECOVER: "NODE_RECOVER",
    EVT_JOB_FAIL: "JOB_FAIL",
    EVT_RETRY: "RETRY",
}

# the self-regenerating fault timeline: not "work", so an otherwise-idle
# batch run can stop while the heap still carries the next failure cycle
_TIMELINE_KINDS = frozenset((EVT_NODE_FAIL, EVT_NODE_RECOVER))


@dataclass(frozen=True)
class ElasticConfig:
    """Knobs for the beyond-static capabilities.  ``ElasticConfig()`` with
    every switch off is equivalent to ``elastic=None``.

    The checkpoint-cost model: a preemption holds the job's units for
    ``ckpt_time`` seconds at ``ckpt_power_scale`` × the job's busy power
    (charged to busy energy and tracked in ``ckpt_energy``); the next
    launch of that job pays ``restart_time`` seconds of re-execution
    overhead before its remaining work starts.
    """

    resize: bool = False  # EcoSched elastic resizing on COMPLETE events
    migrate: bool = False  # cluster-level waiting/preempted-job migration
    ckpt_time: float = 30.0  # checkpoint write (s); units held throughout
    restart_time: float = 15.0  # relaunch overhead (s) after a preemption
    ckpt_power_scale: float = 1.0  # power during the write, × busy power
    migration_delay: float = 10.0  # s a migrating job spends in transit
    min_gain_s: float = 60.0  # predicted saving must exceed this
    max_preempts: int = 2  # checkpoints per job (bounds churn)
    switch_cost: float = 0.05  # Eq. (1) bias on resize candidates != (g, f)
    # resize-order ablation (ISSUE 5 satellite): evaluate resizes *before*
    # the backfill scheduling pass on COMPLETE events, so a running job's
    # upsize gets first claim on freed units instead of backfill soaking
    # them (the PR 4 caveat: resizes fire mostly at drain tails).  Off by
    # default — the default path is byte-identical to PR 4.
    resize_before_backfill: bool = False

    @property
    def any_enabled(self) -> bool:
        return self.resize or self.migrate


class EventQueue:
    """The single heap.  Entries are ``(t, kind, seq, payload)`` — the
    exact tuple shape of the pre-refactor loops, so pop order (time, then
    kind, then push order) is unchanged.

    ``work`` counts the pending non-timeline events (everything except
    NODE_FAIL/NODE_RECOVER, which regenerate forever): the fault-aware
    batch loop stops on ``work == 0`` instead of an empty heap.
    """

    __slots__ = ("_heap", "_seq", "work")

    def __init__(self):
        self._heap: List[Tuple[float, int, int, object]] = []
        self._seq = 0
        self.work = 0

    def __len__(self) -> int:
        return len(self._heap)

    def push(self, t: float, kind: int, payload: object) -> None:
        heapq.heappush(self._heap, (t, kind, self._seq, payload))
        self._seq += 1
        if kind not in _TIMELINE_KINDS:
            self.work += 1

    def pop(self) -> Tuple[float, int, object]:
        t, kind, _, payload = heapq.heappop(self._heap)
        if kind not in _TIMELINE_KINDS:
            self.work -= 1
        return t, kind, payload

    def next_is(self, t: float, kind: int) -> bool:
        """True when the head event is exactly (t, kind) — the arrival
        batching test."""
        return bool(self._heap) and self._heap[0][0] == t and self._heap[0][1] == kind

    def peek_time(self) -> Optional[float]:
        """Head event time, or None when the heap is empty."""
        return self._heap[0][0] if self._heap else None


class EventLoop:
    """Shared driver: pops events, invokes per-node policies, applies the
    elastic hooks.  Owners provide:

      sims       — name -> NodeSim, in scheduling order (t=0 policy pass
                   runs over this order, like the pre-refactor loops),
      arrive     — (payload, t) -> node name: absorb one ARRIVAL payload
                   (single-node: enqueue locally; cluster: route + enqueue).
                   May return None to *drop* the arrival (a job cancelled
                   between submit and its ARRIVAL pop, control-plane path);
                   batch callers always return a name,
      max_events — deadlock-guard cap, counted per popped head event,
      cap_msg    — the RuntimeError message when the cap trips,
      elastic    — ``ElasticConfig`` or None (None = pre-refactor behavior),
      faults     — ``FaultConfig`` or None (None = pre-fault behavior);
                   ``fault_injector`` supplies the shared deterministic
                   draw streams (owners build one so NodeSim stragglers
                   and the loop's timelines share it),
      on_launch / on_complete / on_requeue / on_dequeue / on_retime —
                   optional array-state bookkeeping hooks (ClusterState),
      on_fail / on_retry / on_lost / on_capacity — optional fault hooks:
                   a job was killed (crash or node failure; receives the
                   pre-kill end time for array-state un-booking), a killed
                   job re-entered a waiting queue, a job exhausted its
                   retries, a node's alive capacity changed,
      migrate_candidate — optional (node, t) -> (donor, job) | None: pick a
                   waiting job to pull onto ``node`` (the cluster
                   dispatcher's migration hook),
      reroute_waiting — optional (node, t) hook: a node went fully dead —
                   move its waiting jobs somewhere alive (the cluster
                   implements this through the migration machinery),
      prepare_batch — optional (names, t) hook fired right before a
                   same-instant multi-node scheduling pass (the t=0 pass
                   and arrival batches): owners stage every pending score
                   reduction as one cross-node kernel launch (ISSUE 9);
                   pure staging, ``_schedule`` behaves identically
                   without it,
      prepare_complete — optional (pairs, t) hook fired once per
                   same-instant COMPLETE burst, at the first completion's
                   pop and *before* any of the burst is processed:
                   ``pairs`` is [(node, running_job)] with one entry per
                   distinct node (stale completions skipped).  Owners
                   stage the burst's backfill-launch and elastic-resize
                   reductions as one cross-node kernel launch (ISSUE 10).
                   Unlike arrivals, completions are never drained
                   together — each is still processed strictly in heap
                   order against the live state, and staged results are
                   signature-guarded predictions, so schedules are
                   bit-identical with the hook absent.
    """

    def __init__(
        self,
        sims: Dict[str, "NodeSim"],  # noqa: F821 (repro.core.simulator)
        *,
        arrive: Callable[[object, float], str],
        max_events: int,
        cap_msg: str,
        elastic: Optional[ElasticConfig] = None,
        faults: Optional[FaultConfig] = None,
        fault_injector: Optional[FaultInjector] = None,
        on_launch: Optional[Callable] = None,
        on_complete: Optional[Callable] = None,
        on_requeue: Optional[Callable] = None,
        on_dequeue: Optional[Callable] = None,
        on_retime: Optional[Callable] = None,
        on_fail: Optional[Callable] = None,
        on_retry: Optional[Callable] = None,
        on_lost: Optional[Callable] = None,
        on_capacity: Optional[Callable] = None,
        migrate_candidate: Optional[Callable] = None,
        reroute_waiting: Optional[Callable] = None,
        prepare_batch: Optional[Callable[[List[str], float], None]] = None,
        prepare_complete: Optional[Callable] = None,
    ):
        self.sims = sims
        self.queue = EventQueue()
        self.arrive = arrive
        self.max_events = max_events
        self.cap_msg = cap_msg
        self.elastic = elastic if (elastic and elastic.any_enabled) else None
        self.faults = faults if (faults and faults.enabled) else None
        if self.faults is not None and fault_injector is None:
            fault_injector = FaultInjector(self.faults)
        self.injector = fault_injector if self.faults is not None else None
        self.on_launch = on_launch
        self.on_complete = on_complete
        self.on_requeue = on_requeue
        self.on_dequeue = on_dequeue
        self.on_retime = on_retime
        self.on_fail = on_fail
        self.on_retry = on_retry
        self.on_lost = on_lost
        self.on_capacity = on_capacity
        self.migrate_candidate = migrate_candidate
        self.reroute_waiting = reroute_waiting
        # fleet-batched decision staging (ISSUE 9): invoked with the list
        # of touched node names right before a same-instant multi-node
        # scheduling pass, so an owner can run every pending score
        # reduction as one cross-node kernel launch.  Pure staging — the
        # per-node ``_schedule`` calls behave identically without it.
        self.prepare_batch = prepare_batch
        # COMPLETE-burst staging (ISSUE 10): fired once per same-instant
        # completion burst with the *predicted* (node, job) pairs, before
        # any of them is processed.  ``_staged_complete_t`` marks the
        # instant already staged so later pops of the same burst skip it.
        self.prepare_complete = prepare_complete
        self._staged_complete_t: Optional[float] = None
        # global per-job retry counts: a job killed on node A and rerouted
        # to node B keeps burning the same budget
        self._fault_retry: Dict[str, int] = {}
        # stepping state (control-plane incremental driving, ISSUE 6):
        # ``now`` advances to each popped head-event time, ``events`` is the
        # per-head-event cap counter, ``started`` guards the t=0 pass.
        self.now = 0.0
        self.events = 0
        self.started = False

    # -- scheduling ---------------------------------------------------------

    def _schedule(self, nm: str) -> None:
        """One policy invocation on node ``nm``; launched jobs get their
        COMPLETE events pushed (and, with faults, their crash draws)."""
        sim = self.sims[nm]
        if self.faults is not None and sim.placement.free_count() == 0:
            # a fully-dead (or fully-occupied) node has nothing to offer;
            # policies written against the pre-fault invariant
            # "idle => all units free" must not be consulted here
            return
        for rj in sim.invoke_policy():
            if self.on_launch is not None:
                self.on_launch(nm, rj)
            self.queue.push(rj.end, EVT_COMPLETE, (nm, rj))
            if self.faults is not None:
                t_c = rj.start + self.injector.crash_offset(
                    rj.job, rj.record.segment
                )
                if t_c < rj.end:
                    self.queue.push(t_c, EVT_JOB_FAIL, (nm, rj))

    # -- main loop ----------------------------------------------------------

    def start(self) -> None:
        """The t=0 scheduling pass (node order = spec order).  Idempotent,
        so incremental drivers can call it defensively before stepping."""
        if self.started:
            return
        self.started = True
        if self.prepare_batch is not None and len(self.sims) > 1:
            self.prepare_batch(list(self.sims), 0.0)
        for nm in self.sims:
            self._schedule(nm)
        if self.faults is not None and self.faults.node_mtbf_s > 0:
            for nm, sim in self.sims.items():
                up, down, k = self.injector.next_cycle(nm, sim.node.units)
                self.queue.push(up, EVT_NODE_FAIL, (nm, k, down))

    def step(self) -> bool:
        """Pop and process one head event (plus its same-instant arrival
        batch).  Returns False when the queue is empty.  Event counting and
        the cap check are per head event — exactly ``run()``'s accounting."""
        q = self.queue
        if not len(q):
            return False
        self.events += 1
        if self.events > self.max_events:
            raise RuntimeError(self.cap_msg)
        t, kind, payload = q.pop()
        self.now = t
        self._dispatch(t, kind, payload)
        return True

    def run_until(self, t_max: float) -> None:
        """Drain every event with time <= ``t_max`` (the control plane's
        ``advance`` verb).  ``now`` ends at the last processed event."""
        self.start()
        while True:
            head = self.queue.peek_time()
            if head is None or head > t_max:
                return
            self.step()

    def idle(self) -> bool:
        """True when only the self-regenerating fault timeline remains:
        no pending work events, no waiting jobs anywhere.  Without faults
        the heap simply drains, so this is never consulted."""
        if self.faults is None:
            return False
        return self.queue.work == 0 and not any(
            sim.waiting for sim in self.sims.values()
        )

    def run(self) -> None:
        self.start()
        while not self.idle() and self.step():
            pass

    def _dispatch(self, t: float, kind: int, payload: object) -> None:
        q = self.queue
        if kind == EVT_ARRIVAL:
            touched: List[Optional[str]] = [self.arrive(payload, t)]
            while q.next_is(t, EVT_ARRIVAL):
                nm = self.arrive(q.pop()[2], t)
                if nm not in touched:
                    touched.append(nm)
            if self.prepare_batch is not None and len(touched) > 1:
                self.prepare_batch([nm for nm in touched if nm is not None], t)
            for nm in touched:
                if nm is not None:  # None = arrival dropped (cancelled job)
                    self._schedule(nm)
        elif kind == EVT_COMPLETE:
            nm, rj = payload
            if rj.preempted or rj.failed:
                return  # superseded by a PREEMPT event / killed by a fault
            if (
                self.prepare_complete is not None
                and t != self._staged_complete_t
                and q.next_is(t, EVT_COMPLETE)
            ):
                # first pop of a same-instant COMPLETE burst: peek (never
                # pop) the rest of the burst and stage the cross-node
                # reductions once.  Only the first completion per node is
                # staged — later ones see a state this prediction cannot
                # cover and recompute solo via the signature guard.
                self._staged_complete_t = t
                pairs = [(nm, rj)]
                seen = {nm}
                for tt, kk, _, p in q._heap:
                    if tt != t or kk != EVT_COMPLETE:
                        continue
                    nm2, rj2 = p
                    if nm2 in seen or rj2.preempted or rj2.failed:
                        continue
                    seen.add(nm2)
                    pairs.append((nm2, rj2))
                if len(pairs) > 1:
                    self.prepare_complete(pairs, t)
            sim = self.sims[nm]
            sim.complete(rj)
            if self.on_complete is not None:
                self.on_complete(nm, rj)
            if self.elastic is None:
                if sim.waiting:
                    self._schedule(nm)
            else:
                self._post_complete(nm, t)
        elif kind == EVT_PREEMPT:
            nm, rj = payload
            if rj.failed:
                return  # the node died mid-checkpoint-write
            self.sims[nm].finish_preempt(rj, t)
            if self.on_complete is not None:
                self.on_complete(nm, rj)  # rj.end == t after retiming
            q.push(t, EVT_RESUME, (nm, rj.job))
        elif kind == EVT_RESUME:
            nm, job = payload
            self.sims[nm].requeue(job, t)
            if self.on_requeue is not None:
                self.on_requeue(nm, job)
            self._schedule(nm)
        elif kind == EVT_MIGRATE:
            to, job, state = payload
            self.sims[to].absorb(job, t, state)
            if self.on_requeue is not None:
                self.on_requeue(to, job)
            self._schedule(to)
        elif kind == EVT_JOB_FAIL:
            nm, rj = payload
            sim = self.sims[nm]
            if rj.preempted or rj.failed or rj not in sim.running:
                return  # stale draw: resized/checkpointed/done before it hit
            sim.job_crashes += 1
            self._kill(nm, rj, t)
            if sim.waiting and sim.placement.free_count() > 0:
                self._schedule(nm)  # the freed units can serve the queue
        elif kind == EVT_NODE_FAIL:
            nm, k, down = payload
            self._node_fail(nm, k, down, t)
        elif kind == EVT_NODE_RECOVER:
            nm, ids = payload
            self._node_recover(nm, ids, t)
        elif kind == EVT_RETRY:
            nm, job = payload
            sim = self.sims[nm]
            sim.requeue(job, t)
            if self.on_retry is not None:
                self.on_retry(nm, job)
            if (
                self.reroute_waiting is not None
                and sim.placement.dead_count() >= sim.node.units
            ):
                # retried onto a node that is still fully down: move it
                self.reroute_waiting(nm, t)
            if job in sim.waiting and sim.placement.free_count() > 0:
                self._schedule(nm)
        else:  # pragma: no cover - defensive
            raise RuntimeError(f"unknown event kind {kind}")

    # -- fault plane --------------------------------------------------------

    def _kill(self, nm: str, rj, t: float) -> None:
        """One job dies at ``t`` (crash or node failure): the node refunds
        the unrun energy and rolls the job back to its last checkpoint,
        then the job either retries (backoff) or is lost."""
        sim = self.sims[nm]
        old_end = rj.end
        sim.fail_running(rj, t)
        if self.on_fail is not None:
            self.on_fail(nm, rj, old_end)
        self._fault_requeue(nm, rj.job, t)

    def _fault_requeue(self, nm: str, job: str, t: float) -> None:
        cfg = self.faults
        count = self._fault_retry.get(job, 0)
        sim = self.sims[nm]
        if count >= cfg.max_retries:
            sim.drop_lost(job)
            if self.on_lost is not None:
                self.on_lost(nm, job)
            return
        self._fault_retry[job] = count + 1
        sim.fault_retries += 1
        self.queue.push(t + self.injector.retry_delay(count), EVT_RETRY, (nm, job))

    def _node_fail(self, nm: str, k: int, down: float, t: float) -> None:
        sim = self.sims[nm]
        sim.advance(t)
        sim.node_failures += 1
        alive = [u for u in range(sim.node.units) if not sim.placement.dead[u]]
        victims = set(alive[-k:]) if k < len(alive) else set(alive)
        for rj in [r for r in sim.running if set(r.units) & victims]:
            self._kill(nm, rj, t)
        sim.placement.mark_dead(sorted(victims))
        if self.on_capacity is not None:
            self.on_capacity(nm)
        if (
            self.reroute_waiting is not None
            and sim.placement.dead_count() >= sim.node.units
        ):
            self.reroute_waiting(nm, t)
        if sim.waiting and sim.placement.free_count() > 0:
            self._schedule(nm)  # partial failure: survivors may backfill
        self.queue.push(t + down, EVT_NODE_RECOVER, (nm, sorted(victims)))

    def _node_recover(self, nm: str, ids: List[int], t: float) -> None:
        sim = self.sims[nm]
        sim.advance(t)
        sim.placement.revive(ids)
        if self.on_capacity is not None:
            self.on_capacity(nm)
        if sim.waiting:
            self._schedule(nm)
        up, down, k = self.injector.next_cycle(nm, sim.node.units)
        self.queue.push(t + up, EVT_NODE_FAIL, (nm, k, down))

    # -- elastic hooks (resize + migration), bounded per COMPLETE event -----

    def _post_complete(self, nm: str, t: float) -> None:
        """Backfill + elastic actions after one COMPLETE.  The default
        order backfills waiting jobs before evaluating resizes (the PR 4
        contract); ``resize_before_backfill`` swaps the two so a resize
        gets first claim on the freed units (ablation, ISSUE 5)."""
        cfg = self.elastic
        sim = self.sims[nm]
        if cfg.resize and cfg.resize_before_backfill:
            t0 = _time.perf_counter()
            self._try_resize(nm, t)
            sim.resize_time += _time.perf_counter() - t0
        if sim.waiting:
            self._schedule(nm)
        if cfg.resize and not cfg.resize_before_backfill:
            t0 = _time.perf_counter()
            self._try_resize(nm, t)
            sim.resize_time += _time.perf_counter() - t0
        if cfg.migrate and self.migrate_candidate is not None:
            t0 = _time.perf_counter()
            self._try_migrate(nm, t)
            sim.migrate_time += _time.perf_counter() - t0

    def _try_resize(self, nm: str, t: float) -> None:
        sim = self.sims[nm]
        propose = getattr(sim.policy, "propose_resizes", None)
        if propose is None:
            return
        cfg = self.elastic
        for ln in propose(sim.node_view(), frac_of=sim.frac_of, cfg=cfg)[:1]:
            rj = next(
                (r for r in sim.running if r.job == ln.job and not r.preempted),
                None,
            )
            if rj is None:
                continue
            if sim.preempt_count.get(ln.job, 0) >= cfg.max_preempts:
                continue
            if rj.end - t <= cfg.ckpt_time + cfg.restart_time:
                continue  # finishing soon: a checkpoint can never pay off
            old_end = rj.end
            ck_end = sim.begin_preempt(rj, t, cfg)
            if self.on_retime is not None:
                self.on_retime(nm, rj, old_end)
            self.queue.push(ck_end, EVT_PREEMPT, (nm, rj))

    def _try_migrate(self, nm: str, t: float) -> None:
        cand = self.migrate_candidate(nm, t)
        if not cand:
            return
        donor, job = cand
        dsim = self.sims[donor]
        if job not in dsim.waiting:
            return
        state = dsim.evict(job)  # MigrantState: arrival/progress/counters
        if self.on_dequeue is not None:
            self.on_dequeue(donor, job)
        self.queue.push(
            t + self.elastic.migration_delay, EVT_MIGRATE, (nm, job, state)
        )

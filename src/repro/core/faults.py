"""Seeded, deterministic fault injection for the cluster simulator.

The fault plane (ISSUE 8) threads node failures, partial GPU
degradation, job crashes, and straggler slowdowns through the typed
event substrate (`core/events.py`).  This module holds the *model*:
``FaultConfig`` describes the fault process, ``FaultInjector`` draws
from it deterministically.

Determinism is the whole game — the daemon's crash-recovery contract
(replay the journal through a fresh backend, require bit-identical
transitions) only survives faults if every draw is a pure function of
``(seed, stream key)``, never of wall-clock, iteration order, or
Python's per-process hash randomization.  So every stream derives its
RNG from ``sha256(f"{seed}:{key}")``:

  * per-node uptime/downtime cycles keyed by node name,
  * per-(job, segment) crash offsets — an exponential time-to-crash
    hazard, so *exposure time* matters and checkpoints genuinely bound
    the loss (the draw is schedule-independent, which keeps seeded
    fault traces identical across the python/vector/Pallas engines),
  * per-(job, segment) straggler draws.

The idioms absorb the seed tree's ``distributed/fault.py``
(``FailureInjector``'s deterministic schedule, ``StragglerMonitor``'s
slowdown factors) into the scheduling core, where PR 4's
checkpoint/restart + migration machinery is the recovery primitive.
"""
from __future__ import annotations

import hashlib
import math
import random
from dataclasses import dataclass
from typing import Tuple

__all__ = ["FaultConfig", "FaultInjector"]


@dataclass(frozen=True)
class FaultConfig:
    """The fault process.  All rates default *off*: ``FaultConfig()``
    is inert, and ``faults=None`` everywhere rides the exact pre-fault
    code path (golden-locked bit-identical to PR 7).

    ``node_mtbf_s``     mean time between node failures (0 = never).
    ``node_mttr_s``     mean time to repair a failed node.
    ``degrade_frac``    probability a node failure is *partial*: the
                        node loses ``degrade_units`` GPUs instead of
                        all of them, and keeps scheduling on the rest.
    ``degrade_units``   GPUs lost in a partial failure.
    ``job_mtbf_s``      mean time to crash per running job (0 = never);
                        an exponential hazard over *execution* time, so
                        a job checkpointed often loses little per crash.
    ``straggler_prob``  per-(job, segment) probability of a straggler
                        slowdown (factor multiplied into the segment's
                        interference factor).
    ``straggler_factor`` the slowdown when it hits.
    ``max_retries``     crash/kill retries before a job is marked lost.
    ``retry_base_s``    first retry delay; doubles (``retry_mult``) per
                        retry, capped at ``retry_cap_s``.
    ``restart_time``    relaunch overhead charged when a killed job
                        restarts and no ``ElasticConfig`` supplies one.
    """

    seed: int = 0
    node_mtbf_s: float = 0.0
    node_mttr_s: float = 600.0
    degrade_frac: float = 0.0
    degrade_units: int = 1
    job_mtbf_s: float = 0.0
    straggler_prob: float = 0.0
    straggler_factor: float = 1.5
    max_retries: int = 3
    retry_base_s: float = 30.0
    retry_mult: float = 2.0
    retry_cap_s: float = 1800.0
    restart_time: float = 15.0

    @property
    def enabled(self) -> bool:
        return (
            self.node_mtbf_s > 0
            or self.job_mtbf_s > 0
            or self.straggler_prob > 0
        )

    def signature(self) -> str:
        """Compact deterministic identity for ``describe()`` — two
        backends with different fault processes must not share a
        journal."""
        return (
            f"s{self.seed}"
            f":n{self.node_mtbf_s:g}/{self.node_mttr_s:g}"
            f":d{self.degrade_frac:g}x{self.degrade_units}"
            f":j{self.job_mtbf_s:g}"
            f":g{self.straggler_prob:g}x{self.straggler_factor:g}"
            f":r{self.max_retries}"
        )


def _stream(seed: int, key: str) -> random.Random:
    """A named RNG stream: stable across processes and engine
    backends (sha256, *not* ``hash()`` which is salted per-process)."""
    digest = hashlib.sha256(f"{seed}:{key}".encode()).digest()
    return random.Random(int.from_bytes(digest[:8], "big"))


def _exp(rng: random.Random, mean: float) -> float:
    # inline expovariate on the u-draw so the stream stays stable even
    # if random.Random.expovariate's implementation shifts
    u = rng.random()
    while u <= 1e-12:  # pragma: no cover - astronomically unlikely
        u = rng.random()
    return -mean * math.log(u)


class FaultInjector:
    """Deterministic draws from a ``FaultConfig``.

    Node streams are stateful iterators (cycle after cycle); job
    streams are pure functions of ``(job, segment)`` so the same
    segment always gets the same crash offset regardless of when, or
    on which engine backend, it is scheduled.
    """

    def __init__(self, cfg: FaultConfig):
        self.cfg = cfg
        self._node_rng = {}

    # -- per-node failure timeline ------------------------------------
    def next_cycle(self, node: str, units: int) -> Tuple[float, float, int]:
        """``(up_dt, down_dt, k_lost)`` for the node's next failure:
        fail after ``up_dt`` healthy seconds, losing ``k_lost`` GPUs,
        repaired ``down_dt`` seconds later."""
        rng = self._node_rng.get(node)
        if rng is None:
            rng = self._node_rng[node] = _stream(self.cfg.seed, f"node:{node}")
        up = _exp(rng, self.cfg.node_mtbf_s)
        down = _exp(rng, self.cfg.node_mttr_s)
        if rng.random() < self.cfg.degrade_frac:
            k = min(self.cfg.degrade_units, units)
        else:
            k = units
        return up, down, k

    # -- per-(job, segment) crash hazard ------------------------------
    def crash_offset(self, job: str, segment: int) -> float:
        """Exponential time-to-crash for this execution segment,
        measured from its launch.  ``inf`` when the hazard is off."""
        if self.cfg.job_mtbf_s <= 0:
            return math.inf
        rng = _stream(self.cfg.seed, f"job:{job}:{segment}")
        return _exp(rng, self.cfg.job_mtbf_s)

    # -- per-(job, segment) straggler ----------------------------------
    def straggler(self, job: str, segment: int) -> float:
        """Slowdown factor for this segment (1.0 = healthy)."""
        if self.cfg.straggler_prob <= 0:
            return 1.0
        rng = _stream(self.cfg.seed, f"straggle:{job}:{segment}")
        if rng.random() < self.cfg.straggler_prob:
            return self.cfg.straggler_factor
        return 1.0

    # -- retry/backoff --------------------------------------------------
    def retry_delay(self, count: int) -> float:
        """Capped exponential backoff for a job's ``count``-th retry
        (0-based)."""
        return min(
            self.cfg.retry_base_s * self.cfg.retry_mult ** count,
            self.cfg.retry_cap_s,
        )

"""Phase II scoring — Eq. (1)–(2) of the paper, verbatim.

    S(a)        = R_energy(a) + λ·I(a)
    R_energy(a) = (1/|a|) Σ_{m∈a} (Ê_m^norm − 1)      (0 for the empty action)
    I(a)        = (G_free − G(a)) / M
    a*          = argmin_{a ∈ A_feas} S(a)

``Ê^norm`` is each mode's energy proxy normalized to the job's best mode
(=1 at the predicted-lowest-energy count).  The τ-filter (paper §III-C)
drops modes whose predicted slowdown exceeds (1+τ)·best before scoring.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

from repro.core.types import JobSpec, Launch, ModeEstimate


def tau_filter(spec: JobSpec, tau: float) -> JobSpec:
    if not spec.modes:  # nothing to filter; callers must skip modeless jobs
        return spec
    best = min(m.t_norm for m in spec.modes)
    keep = tuple(m for m in spec.modes if m.t_norm <= (1.0 + tau) * best)
    return JobSpec(name=spec.name, modes=keep)


def r_energy(modes: Sequence[ModeEstimate]) -> float:
    if not modes:
        return 0.0
    return sum(m.e_norm - 1.0 for m in modes) / len(modes)


def idle_term(total_g: int, g_free: int, M: int) -> float:
    return (g_free - total_g) / M


def score(
    modes: Sequence[ModeEstimate], *, g_free: int, M: int, lam: float
) -> float:
    total_g = sum(m.g for m in modes)
    return r_energy(modes) + lam * idle_term(total_g, g_free, M)

"""Phase II scoring — Eq. (1)–(2) of the paper, verbatim.

    S(a)        = R_energy(a) + λ·I(a)
    R_energy(a) = (1/|a|) Σ_{m∈a} (Ê_m^norm − 1)      (0 for the empty action)
    I(a)        = (G_free − G(a)) / M
    a*          = argmin_{a ∈ A_feas} S(a)

``Ê^norm`` is each mode's energy proxy normalized to the job's best mode
(=1 at the predicted-lowest-energy count).  The τ-filter (paper §III-C)
drops modes whose predicted slowdown exceeds (1+τ)·best before scoring.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

from repro.core.types import JobSpec, Launch, ModeEstimate


def tau_filter(spec: JobSpec, tau: float) -> JobSpec:
    if not spec.modes:  # nothing to filter; callers must skip modeless jobs
        return spec
    best = min(m.t_norm for m in spec.modes)
    keep = tuple(m for m in spec.modes if m.t_norm <= (1.0 + tau) * best)
    return JobSpec(name=spec.name, modes=keep)


def r_energy(modes: Sequence[ModeEstimate]) -> float:
    if not modes:
        return 0.0
    return sum(m.e_norm - 1.0 for m in modes) / len(modes)


def idle_term(total_g: int, g_free: int, M: int) -> float:
    return (g_free - total_g) / M


def freq_term(modes: Sequence[ModeEstimate]) -> float:
    """Mean frequency level of the action (0 for the empty action and for
    every base-clock action) — the DVFS conservatism axis."""
    if not modes:
        return 0.0
    return sum(m.f for m in modes) / len(modes)


def score(
    modes: Sequence[ModeEstimate],
    *,
    g_free: int,
    M: int,
    lam: float,
    lam_f: float = 0.0,
) -> float:
    """Eq. (1) score, generalized to (count × frequency) actions.

    ``lam_f`` penalizes (positive) or rewards (negative) downclocked modes
    by the action's mean frequency level; at the default 0.0 the joint
    argmin is decided purely by the energy/idle terms and every score is
    bit-identical to the count-only scorer (modes all carry ``f = 0``
    there, so the term vanishes either way).
    """
    total_g = sum(m.g for m in modes)
    s = r_energy(modes) + lam * idle_term(total_g, g_free, M)
    if lam_f:
        s += lam_f * freq_term(modes)
    return s

"""Durable scheduler control plane (ISSUE 6 tentpole).

Everything below ``SchedulerService`` is the batch machinery this repo
already had; this module turns it into a long-running *system*: a daemon
that accepts job submissions over a local API, tracks each job through a
strict lifecycle state machine, journals every input and every lifecycle
transition to an append-only JSONL file (``repro.core.journal``), and —
after a crash — rebuilds its exact state by replaying the journal through
the deterministic event substrate (``repro.core.events``).

Layers:

  * **state machine** — ``SUBMITTED → {ADMITTED, FAILED}``, ``ADMITTED →
    {QUEUED, CANCELLED}``, ``QUEUED → {RUNNING, MIGRATING, CANCELLED}``,
    ``RUNNING → {DONE, PREEMPTED, FAILED, FAILED_RETRYING}``,
    ``PREEMPTED/MIGRATING → QUEUED``, ``FAILED_RETRYING → {QUEUED,
    FAILED}`` (the fault plane's crash-retry leg, repro.core.faults);
    ``DONE``/``CANCELLED``/``FAILED`` are terminal.  Any other
    transition raises ``IllegalTransition`` — a lifecycle bug must never
    be absorbed silently.
  * **admission control** — ``AdmissionGate`` observes every submit
    instant through ``ArrivalRateEWMA`` (repro.core.arrivals) and rejects
    at the edge: a hard pending-queue cap, plus a burst gate that sheds
    load when the short-horizon arrival rate runs ahead of the baseline
    while the backlog is already deep — the same signal the forecast
    plane's hysteresis gates on, applied at the API boundary.
  * **backend protocol** — the service drives anything exposing
    ``submit/cancel/advance/now/result/set_transition_cb``;
    ``ClusterBackend`` adapts ``Cluster.open_run`` (repro.core.cluster),
    and a single node is just a one-node cluster (the substrate makes the
    two bit-identical, locked in tests/test_cluster.py).  A dry-run
    adapter over real nodes plugs in behind the same protocol.
  * **durability** — write-ahead journaling of inputs (submit / cancel /
    advance), write-behind journaling of lifecycle transitions.  The
    whole simulation stack is deterministic, so the input records are a
    redo log: ``recover`` replays them through a fresh backend, *verifies*
    the journaled transitions are a prefix of the regenerated stream
    (divergence raises ``RecoveryError`` — a wrong-config or tampered
    journal must not silently produce a different schedule), appends the
    transitions the crash lost, and resumes accepting requests.  The
    crash-parity property — SIGKILL at any journal offset, restart,
    replay, and the final schedule is bit-identical to the uninterrupted
    run — is property-tested in tests/test_service.py.

``serve`` runs the service over a unix-domain socket speaking JSON lines
(one request object per line, one response per line); ``repro.cli`` is
the matching command-line client and daemon launcher.  Requests are
handled strictly sequentially — concurrency would reorder journal inputs
and break replay determinism, and a scheduler tick is microseconds.
"""
from __future__ import annotations

import os
import socket
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.arrivals import ArrivalRateEWMA
from repro.core.cluster import Cluster, ClusterRun
from repro.core.events import ElasticConfig
from repro.core.faults import FaultConfig
from repro.core.forecast import ForecastConfig
from repro.core.journal import (
    JOURNAL_VERSION,
    Journal,
    JournalError,
    chain_hash,
)

# --------------------------------------------------------------------------
# Job lifecycle state machine
# --------------------------------------------------------------------------

SUBMITTED = "SUBMITTED"
ADMITTED = "ADMITTED"
QUEUED = "QUEUED"
RUNNING = "RUNNING"
PREEMPTED = "PREEMPTED"
MIGRATING = "MIGRATING"
FAILED_RETRYING = "FAILED_RETRYING"
DONE = "DONE"
CANCELLED = "CANCELLED"
FAILED = "FAILED"

JOB_STATES = (
    SUBMITTED, ADMITTED, QUEUED, RUNNING, PREEMPTED, MIGRATING,
    FAILED_RETRYING, DONE, CANCELLED, FAILED,
)

TRANSITIONS: Dict[str, frozenset] = {
    SUBMITTED: frozenset({ADMITTED, FAILED}),
    ADMITTED: frozenset({QUEUED, CANCELLED}),
    QUEUED: frozenset({RUNNING, MIGRATING, CANCELLED}),
    RUNNING: frozenset({DONE, PREEMPTED, FAILED, FAILED_RETRYING}),
    PREEMPTED: frozenset({QUEUED}),
    MIGRATING: frozenset({QUEUED}),
    FAILED_RETRYING: frozenset({QUEUED, FAILED}),
    DONE: frozenset(),
    CANCELLED: frozenset(),
    FAILED: frozenset(),
}

# which lifecycle event moves a job into which state (substrate feed)
_EVENT_STATE = {
    "queued": QUEUED,
    "launch": RUNNING,
    "done": DONE,
    "ckpt": PREEMPTED,
    "requeue": QUEUED,
    "migrate": MIGRATING,
    "fail": FAILED_RETRYING,
    "retry": QUEUED,
    "lost": FAILED,
}

# states that count against the pending-queue admission cap
_PENDING = frozenset({ADMITTED, QUEUED, PREEMPTED, MIGRATING, FAILED_RETRYING})


class IllegalTransition(ValueError):
    """A lifecycle transition outside ``TRANSITIONS``."""


@dataclass
class JobInfo:
    """One job's control-plane view: current state + full history."""

    name: str
    app: str
    state: str = SUBMITTED
    submit_t: float = 0.0
    node: str = ""  # last node the job was queued/launched on
    reason: str = ""  # FAILED detail (admission rejection, ...)
    launches: int = 0
    history: List[Tuple[float, str]] = field(default_factory=list)

    def advance(self, state: str, t: float) -> None:
        if state not in TRANSITIONS:
            raise IllegalTransition(f"{self.name}: unknown state {state!r}")
        if state not in TRANSITIONS[self.state]:
            raise IllegalTransition(
                f"{self.name}: illegal transition {self.state} -> {state}"
            )
        self.state = state
        self.history.append((t, state))

    def to_dict(self) -> Dict:
        return {
            "name": self.name,
            "app": self.app,
            "state": self.state,
            "submit_t": self.submit_t,
            "node": self.node,
            "reason": self.reason,
            "launches": self.launches,
            "history": [[t, s] for t, s in self.history],
        }


# --------------------------------------------------------------------------
# Admission control (API edge)
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class AdmissionConfig:
    """Edge admission knobs.

    ``max_pending`` is the hard backlog cap (ADMITTED/QUEUED/PREEMPTED/
    MIGRATING jobs); ``burst_limit`` sheds load earlier: once the backlog
    exceeds ``burst_pending``, a submit is rejected while the
    short-horizon arrival rate exceeds ``burst_limit`` × the baseline —
    the ``ArrivalRateEWMA`` burst signal applied at the API boundary, so
    a sweep submitted mid-burst queues up somewhere that is not the
    scheduler's own admission queue.  ``burst_limit=0`` disables the
    burst gate; ``max_pending=0`` disables the cap.
    """

    max_pending: int = 256
    burst_limit: float = 3.0
    burst_pending: int = 16
    ewma_horizon: int = 8
    baseline_horizon: int = 64

    def to_dict(self) -> Dict:
        return {
            "max_pending": self.max_pending,
            "burst_limit": self.burst_limit,
            "burst_pending": self.burst_pending,
            "ewma_horizon": self.ewma_horizon,
            "baseline_horizon": self.baseline_horizon,
        }


class AdmissionGate:
    """Stateful admission decision.  ``admit`` must be called for *every*
    submit attempt (accepted or not): the EWMA has to see the full
    arrival process, and replay calls it in the same order so the
    estimator state is reproduced exactly."""

    def __init__(self, cfg: AdmissionConfig):
        self.cfg = cfg
        self.rate = ArrivalRateEWMA(cfg.ewma_horizon, cfg.baseline_horizon)
        self.rejected = 0

    def admit(self, t: float, pending: int) -> Tuple[bool, str]:
        self.rate.observe(t)
        cfg = self.cfg
        if cfg.max_pending and pending >= cfg.max_pending:
            self.rejected += 1
            return False, f"queue full ({pending} pending)"
        if (
            cfg.burst_limit
            and pending >= cfg.burst_pending
            and self.rate.burst_factor() >= cfg.burst_limit
        ):
            self.rejected += 1
            return False, (
                f"burst shed (rate {self.rate.burst_factor():.2f}x baseline, "
                f"{pending} pending)"
            )
        return True, ""


# --------------------------------------------------------------------------
# Backend protocol + the simulator adapter
# --------------------------------------------------------------------------


class ClusterBackend:
    """Drop-in simulation backend: ``Cluster.open_run`` behind the
    service's backend protocol.  A single node is a one-node cluster.

    The backend owns one live ``ClusterRun``; the service drives it with
    ``submit``/``cancel``/``advance`` and receives lifecycle transitions
    through the callback installed with ``set_transition_cb``.
    """

    def __init__(
        self,
        cluster: Cluster,
        *,
        apps: Optional[Sequence[str]] = None,
        elastic: Optional[ElasticConfig] = None,
        forecast: Optional[ForecastConfig] = None,
        faults: Optional[FaultConfig] = None,
        fast_status: bool = True,
    ):
        if apps is None:
            apps = sorted(
                {app for s in cluster.specs for app in cluster.truth_for(s)}
            )
        self._cb: Optional[Callable] = None
        self.faults = faults if (faults is not None and faults.enabled) else None
        self.run: ClusterRun = cluster.open_run(
            apps=apps,
            elastic=elastic,
            forecast=forecast,
            faults=faults,
            fast_status=fast_status,
            on_transition=self._emit,
        )

    def _emit(
        self,
        event: str,
        t: float,
        job: str,
        node: str,
        g: int,
        end: float,
        f: int = 0,
    ) -> None:
        if self._cb is not None:
            self._cb(event, t, job, node, g, end, f)

    def set_transition_cb(self, cb: Optional[Callable]) -> None:
        self._cb = cb

    @property
    def now(self) -> float:
        return self.run.now

    def describe(self) -> str:
        nodes = ",".join(
            f"{s.name}:{s.units}u{s.domains}d" for s in self.run.specs
        )
        # DVFS-enabled systems journal a distinct identity: a journal
        # written with frequency ladders must not replay through a
        # base-clock-only backend (and vice versa)
        levels = max(
            (
                len(prof.freq_levels)
                for truth in self.run.app_truth.values()
                for prof in truth.values()
            ),
            default=1,
        )
        suffix = f"/f{levels}" if levels > 1 else ""
        # the fault timeline is part of the backend identity: a journal
        # written with failures injected must not replay fault-free
        fsuffix = (
            f"/faults:{self.faults.signature()}" if self.faults is not None else ""
        )
        return f"cluster[{nodes}]/{self.run.dispatcher.name()}{suffix}{fsuffix}"

    def can_run(self, app: str) -> bool:
        # admission consults *healthy* capacity: whether an app is
        # schedulable at all must not flap with transient node failures
        # (and replayed submit decisions must be time-independent)
        ai = self.run.state.app_index.get(app)
        return ai is not None and bool(self.run._fits_healthy[:, ai].any())

    def submit(self, name: str, app: str, t: float) -> None:
        self.run.submit(name, app, t)

    def cancel(self, name: str) -> bool:
        return self.run.cancel(name)

    def advance(self, until: Optional[float]) -> None:
        if until is None:
            self.run.run_to_completion()
        else:
            self.run.run_until(until)

    def result(self):
        return self.run.finalize()


# --------------------------------------------------------------------------
# The service
# --------------------------------------------------------------------------


class RecoveryError(JournalError):
    """Journal replay diverged from the journaled transitions (wrong
    backend/config for this journal, tampering, or lost determinism)."""


class SchedulerService:
    """The daemon core: state machine + admission + journal + recovery.

    ``make_backend`` must build a *fresh, deterministic* backend each
    call — recovery replays the journal through a new instance, so any
    state smuggled in from outside the journal breaks crash parity.

    ``compact_every_bytes`` / ``compact_max_age_s`` arm automatic journal
    compaction (ISSUE 9 satellite): after each mutating operation, if the
    journal has grown past the byte threshold, or the oldest un-compacted
    transition is older than the age threshold (wall-clock), the folded
    ``Journal.snapshot`` runs in place.  Compaction never changes what
    replay reconstructs, so the wall-clock trigger does not break
    determinism — it only bounds how much of the event tail a recovery
    has to re-verify record-by-record.  0 disables either trigger.
    """

    def __init__(
        self,
        make_backend: Callable[[], ClusterBackend],
        *,
        journal_path: Optional[str] = None,
        admission: Optional[AdmissionConfig] = None,
        fsync: bool = False,
        compact_every_bytes: int = 0,
        compact_max_age_s: float = 0.0,
    ):
        self.make_backend = make_backend
        self.compact_every_bytes = int(compact_every_bytes)
        self.compact_max_age_s = float(compact_max_age_s)
        self.auto_compactions = 0
        self._evts_since_snap = 0
        self._snap_age_t = time.monotonic()
        self.admission = admission or AdmissionConfig()
        self.gate = AdmissionGate(self.admission)
        self.jobs: Dict[str, JobInfo] = {}
        self.backend = make_backend()
        self.backend.set_transition_cb(self._on_transition)
        self._clock = 0.0  # monotone input-time watermark
        self._replaying = False
        self._regen: List[Dict] = []
        self.replay_divergences = 0
        self.journal: Optional[Journal] = None
        if journal_path is not None:
            records = (
                Journal.read(journal_path)
                if os.path.exists(journal_path)
                else []
            )
            if records:
                self._recover(records, journal_path)
            else:
                if os.path.exists(journal_path) and os.path.getsize(journal_path):
                    # the crash tore the header line itself: nothing is
                    # recoverable, start the journal over from scratch
                    os.truncate(journal_path, 0)
                self.journal = Journal(journal_path, fsync=fsync)
                self.journal.append(self._header())

    # -- journal plumbing ----------------------------------------------------

    def _header(self) -> Dict:
        return {
            "k": "hdr",
            "v": JOURNAL_VERSION,
            "backend": self.backend.describe(),
            "admission": self.admission.to_dict(),
        }

    def _append(self, rec: Dict) -> None:
        if self.journal is not None:
            self.journal.append(rec)
            if rec.get("k") == "evt":
                if self._evts_since_snap == 0:
                    self._snap_age_t = time.monotonic()  # oldest un-compacted
                self._evts_since_snap += 1

    def _maybe_compact(self) -> None:
        """Run the folded snapshot when either auto-compaction trigger is
        due.  Called after each mutating operation completes — never
        mid-operation, so the journal is quiescent (every write-ahead
        input has its write-behind transitions flushed behind it)."""
        if self.journal is None or self._evts_since_snap == 0:
            return
        due = bool(
            self.compact_every_bytes
            and self.journal.size() >= self.compact_every_bytes
        ) or bool(
            self.compact_max_age_s
            and time.monotonic() - self._snap_age_t >= self.compact_max_age_s
        )
        if due:
            self.journal.snapshot()
            self.auto_compactions += 1
            self._evts_since_snap = 0

    # -- lifecycle transitions (substrate feed) ------------------------------

    def _on_transition(
        self,
        event: str,
        t: float,
        job: str,
        node: str,
        g: int,
        end: float,
        f: int = 0,
    ) -> None:
        rec = {
            "k": "evt", "e": event, "t": t, "job": job,
            "node": node, "g": int(g), "end": end, "f": int(f),
        }
        if self._replaying:
            self._regen.append(rec)
        else:
            self._append(rec)
        info = self.jobs[job]
        info.advance(_EVENT_STATE[event], t)
        if node:
            info.node = node
        if event == "launch":
            info.launches += 1
        elif event == "lost":
            info.reason = "retries exhausted"

    # -- operations (each journals write-ahead, then applies) ----------------

    def _clamp(self, t: Optional[float]) -> float:
        t_eff = self._clock if t is None else max(float(t), self._clock)
        t_eff = max(t_eff, self.backend.now)
        self._clock = t_eff
        return t_eff

    def submit(
        self, name: str, app: str, t: Optional[float] = None
    ) -> Dict:
        if not name or not app:
            return {"ok": False, "error": "submit needs a name and an app"}
        if name in self.jobs:
            # idempotent: a client retrying after a daemon crash must not
            # double-submit; the journaled attempt already decided
            return {"ok": True, "dup": True, "job": self.jobs[name].to_dict()}
        t_eff = self._clamp(t)
        pending = sum(1 for j in self.jobs.values() if j.state in _PENDING)
        if not self.backend.can_run(app):
            ok, reason = False, f"no node can run app {app!r}"
            self.gate.admit(t_eff, pending)  # the EWMA still sees the attempt
        else:
            ok, reason = self.gate.admit(t_eff, pending)
        self._append(
            {
                "k": "sub", "t": t_eff, "name": name, "app": app,
                "ok": ok, "reason": reason,
            }
        )
        self._apply_submit(t_eff, name, app, ok, reason)
        self._maybe_compact()
        return {"ok": ok, "reason": reason, "job": self.jobs[name].to_dict()}

    def _apply_submit(
        self, t: float, name: str, app: str, ok: bool, reason: str
    ) -> None:
        info = JobInfo(name=name, app=app, submit_t=t)
        info.history.append((t, SUBMITTED))
        self.jobs[name] = info
        if ok:
            info.advance(ADMITTED, t)
            self.backend.submit(name, app, t)
        else:
            info.reason = reason
            info.advance(FAILED, t)

    def cancel(self, name: str) -> Dict:
        info = self.jobs.get(name)
        if info is None:
            return {"ok": False, "error": f"unknown job {name!r}"}
        # deterministic decision: only never-launched backlog is cancellable
        ok = info.state in (ADMITTED, QUEUED) and info.launches == 0
        self._append({"k": "cxl", "name": name, "ok": ok})
        applied = self._apply_cancel(name, ok)
        if ok and not applied:  # pragma: no cover - state-machine invariant
            raise RecoveryError(
                f"{name}: backend refused a cancel the state machine allowed"
            )
        self._maybe_compact()
        return {
            "ok": ok,
            "reason": "" if ok else f"not cancellable in state {info.state}",
            "job": info.to_dict(),
        }

    def _apply_cancel(self, name: str, ok: bool) -> bool:
        if not ok:
            return False
        applied = self.backend.cancel(name)
        if applied:
            self.jobs[name].advance(CANCELLED, max(self._clock, self.backend.now))
        return applied

    def advance(self, until: Optional[float] = None) -> Dict:
        until_eff = None if until is None else self._clamp(until)
        self._append({"k": "adv", "until": until_eff})
        self.backend.advance(until_eff)
        self._maybe_compact()
        return {"ok": True, "now": self.backend.now, "stats": self._counts()}

    # -- read-only operations ------------------------------------------------

    def status(self, name: str) -> Dict:
        info = self.jobs.get(name)
        if info is None:
            return {"ok": False, "error": f"unknown job {name!r}"}
        return {"ok": True, "job": info.to_dict()}

    def list_jobs(self) -> Dict:
        return {
            "ok": True,
            "jobs": [self.jobs[n].to_dict() for n in sorted(self.jobs)],
        }

    def _counts(self) -> Dict[str, int]:
        counts = {s: 0 for s in JOB_STATES}
        for j in self.jobs.values():
            counts[j.state] += 1
        return {s: c for s, c in counts.items() if c}

    def stats(self) -> Dict:
        return {
            "ok": True,
            "backend": self.backend.describe(),
            "now": self.backend.now,
            "clock": self._clock,
            "jobs": len(self.jobs),
            "counts": self._counts(),
            "admission": self.admission.to_dict(),
            "rejected": self.gate.rejected,
            "rate_short": self.gate.rate.rate(),
            "rate_baseline": self.gate.rate.baseline_rate(),
            "replay_divergences": self.replay_divergences,
            "journal": self.journal.path if self.journal else "",
            "journal_bytes": self.journal.size() if self.journal else 0,
            "auto_compactions": self.auto_compactions,
            "compact_every_bytes": self.compact_every_bytes,
            "compact_max_age_s": self.compact_max_age_s,
        }

    def compact(self) -> Dict:
        """Fold the journaled transition events into a snapshot record
        (``Journal.snapshot``): bounds journal growth for long-running
        daemons while keeping crash recovery bit-identical — replay still
        regenerates every folded event and verifies the snapshot's chained
        hash."""
        if self.journal is None:
            return {"ok": False, "error": "no journal configured"}
        folded = self.journal.snapshot()
        self._evts_since_snap = 0  # auto-compaction restarts from here
        return {"ok": True, "folded": folded, "journal": self.journal.path}

    def result(self) -> Dict:
        """Final schedule fingerprint; only meaningful after a full drain
        (``advance`` with no bound).  The keyed record list is the
        bit-identity object the crash-parity tests compare."""
        try:
            res = self.backend.result()
        except RuntimeError as exc:
            return {"ok": False, "error": str(exc)}
        return {
            "ok": True,
            "policy": res.policy,
            "makespan": res.makespan,
            "total_energy": res.total_energy,
            "edp": res.edp,
            "records": [
                [r.job, r.node, r.g, r.f, r.start, r.end] for r in res.records
            ],
        }

    # -- crash recovery ------------------------------------------------------

    def _recover(self, records: List[Dict], journal_path: str) -> None:
        """Replay the journaled inputs through the fresh backend, verify
        the journaled transitions prefix-match the regenerated stream,
        then append whatever transitions the crash lost."""
        hdr = records[0]
        if hdr.get("k") != "hdr":
            raise RecoveryError(f"{journal_path}: journal has no header")
        if hdr.get("v") != JOURNAL_VERSION:
            raise RecoveryError(
                f"{journal_path}: journal version {hdr.get('v')!r} != "
                f"{JOURNAL_VERSION}"
            )
        if hdr.get("backend") != self.backend.describe():
            raise RecoveryError(
                f"{journal_path}: journal was written by backend "
                f"{hdr.get('backend')!r}, this daemon runs "
                f"{self.backend.describe()!r}"
            )
        # a snap record (journal compaction) folds the first ``n``
        # transition events into a chained hash; replay regenerates them
        # and verifies the chain instead of comparing records
        snap_n, snap_sha = 0, ""
        if len(records) > 1 and records[1].get("k") == "snap":
            snap_n = int(records[1]["n"])
            snap_sha = str(records[1]["sha"])
        journaled = [r for r in records if r.get("k") == "evt"]
        self._replaying = True
        self._regen = []
        try:
            for rec in records[1:]:
                k = rec.get("k")
                if k in ("evt", "snap"):
                    continue
                elif k == "sub":
                    t = float(rec["t"])
                    self._clock = max(self._clock, t)
                    pending = sum(
                        1 for j in self.jobs.values() if j.state in _PENDING
                    )
                    # re-run the gate for its EWMA state; the *journaled*
                    # decision is the truth (a divergence means the gate
                    # config changed under the journal — count it)
                    ok_now, _ = self.gate.admit(t, pending)
                    if ok_now != rec["ok"]:
                        self.replay_divergences += 1
                    self._apply_submit(
                        t, rec["name"], rec["app"], rec["ok"],
                        rec.get("reason", ""),
                    )
                elif k == "cxl":
                    self._apply_cancel(rec["name"], rec["ok"])
                elif k == "adv":
                    until = rec["until"]
                    if until is not None:
                        self._clock = max(self._clock, float(until))
                    self.backend.advance(until)
                else:
                    raise RecoveryError(
                        f"{journal_path}: unknown record kind {k!r}"
                    )
        finally:
            self._replaying = False
        regen = self._regen
        self._regen = []
        seen = snap_n + len(journaled)
        if len(regen) < snap_n or chain_hash(regen[:snap_n]) != snap_sha:
            raise RecoveryError(
                f"{journal_path}: replay diverged from the snapshot chain "
                f"({snap_n} compacted transitions)"
            )
        if len(journaled) > len(regen) - snap_n or (
            regen[snap_n:seen] != journaled
        ):
            raise RecoveryError(
                f"{journal_path}: replay diverged from the journaled "
                f"transitions ({len(journaled)} journaled, "
                f"{len(regen) - snap_n} regenerated past the snapshot)"
            )
        # the journal verified: amputate any torn tail, reopen for append,
        # and complete the redo — transitions the crash lost are
        # regenerated deterministically
        Journal.repair(journal_path, records)
        self.journal = Journal(journal_path)
        for rec in regen[seen:]:
            self._append(rec)  # counts toward the auto-compaction triggers

    # -- request dispatch (the wire protocol) --------------------------------

    def handle(self, req: Dict) -> Dict:
        """One JSON request -> one JSON response (the socket protocol and
        the in-process test harness both call this)."""
        if not isinstance(req, dict):
            return {"ok": False, "error": "request must be a JSON object"}
        op = req.get("op")
        try:
            if op == "submit":
                return self.submit(
                    req.get("name", ""), req.get("app", ""), req.get("t")
                )
            if op == "cancel":
                return self.cancel(req.get("name", ""))
            if op == "status":
                return self.status(req.get("name", ""))
            if op == "jobs":
                return self.list_jobs()
            if op == "advance":
                return self.advance(req.get("until"))
            if op == "drain":
                return self.advance(None)
            if op == "stats":
                return self.stats()
            if op == "compact":
                return self.compact()
            if op == "result":
                return self.result()
            if op == "ping":
                return {"ok": True, "pong": True}
            if op == "shutdown":
                return {"ok": True, "shutdown": True}
            return {"ok": False, "error": f"unknown op {op!r}"}
        except (ValueError, RuntimeError) as exc:
            return {"ok": False, "error": f"{type(exc).__name__}: {exc}"}

    def close(self) -> None:
        if self.journal is not None:
            self.journal.close()


# --------------------------------------------------------------------------
# Unix-socket server (JSON lines)
# --------------------------------------------------------------------------


# longest request line the daemon will parse; anything beyond is
# answered with an error and drained, never buffered without bound
MAX_LINE = 1 << 20


def serve(
    service: SchedulerService, sock_path: str, *, read_timeout: float = 30.0
) -> None:
    """Serve ``service`` over a unix-domain socket until a ``shutdown``
    request (or KeyboardInterrupt).  One request line -> one response
    line; connections are handled strictly sequentially, which is what
    keeps the journal a total order of inputs.

    Hardened against misbehaving clients: malformed JSON and oversized
    lines (> ``MAX_LINE`` bytes) get an error response instead of killing
    the connection loop, and a client that connects but never sends a
    full line is dropped after ``read_timeout`` seconds — a stuck client
    must not wedge the (sequential) daemon forever."""
    import json

    if os.path.exists(sock_path):
        os.unlink(sock_path)  # stale socket from a killed daemon
    srv = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
    try:
        srv.bind(sock_path)
        srv.listen(8)
        stop = False
        while not stop:
            conn, _ = srv.accept()
            try:
                with conn:
                    conn.settimeout(read_timeout)
                    rfile = conn.makefile("r", encoding="utf-8")
                    while True:
                        line = rfile.readline(MAX_LINE + 1)
                        if not line:
                            break
                        if len(line) > MAX_LINE:
                            # drain the rest of the oversized line so the
                            # stream stays framed, then reject it
                            while line and not line.endswith("\n"):
                                line = rfile.readline(MAX_LINE + 1)
                            resp = {"ok": False, "error": "request too large"}
                            conn.sendall(
                                (json.dumps(resp, sort_keys=True) + "\n").encode()
                            )
                            continue
                        line = line.strip()
                        if not line:
                            continue
                        try:
                            req = json.loads(line)
                        except ValueError:
                            resp = {"ok": False, "error": "malformed JSON request"}
                        else:
                            resp = service.handle(req)
                        conn.sendall(
                            (json.dumps(resp, sort_keys=True) + "\n").encode()
                        )
                        if resp.get("shutdown"):
                            stop = True
                            break
            except OSError:
                # read timeout, reset, broken pipe: drop this client and
                # keep accepting — one bad connection must not take the
                # daemon down
                continue
    except KeyboardInterrupt:
        pass
    finally:
        srv.close()
        if os.path.exists(sock_path):
            os.unlink(sock_path)
        service.close()


def request(sock_path: str, req: Dict, *, timeout: float = 30.0) -> Dict:
    """One-shot client: connect, send one request line, read one response
    line.  Used by ``repro.cli`` and the smoke bench."""
    import json

    with socket.socket(socket.AF_UNIX, socket.SOCK_STREAM) as c:
        c.settimeout(timeout)
        c.connect(sock_path)
        c.sendall((json.dumps(req) + "\n").encode())
        buf = b""
        while not buf.endswith(b"\n"):
            chunk = c.recv(65536)
            if not chunk:
                break
            buf += chunk
    if not buf:
        raise ConnectionError(f"no response from daemon at {sock_path}")
    return json.loads(buf.decode())


def request_retry(
    sock_path: str,
    req: Dict,
    *,
    retries: int = 5,
    base: float = 0.1,
    timeout: float = 30.0,
) -> Dict:
    """``request`` with capped exponential backoff + jitter on the
    transient failure modes of a daemon that is starting up, recovering
    from a crash, or briefly wedged: connection refused, socket file not
    there yet, read timeout.  Application-level errors (an ``ok: False``
    response) are returned, not retried — the daemon answered.  The last
    attempt re-raises."""
    import random
    import time

    for attempt in range(retries + 1):
        try:
            return request(sock_path, req, timeout=timeout)
        except (ConnectionRefusedError, FileNotFoundError, TimeoutError):
            if attempt == retries:
                raise
            delay = base * (2.0 ** attempt)
            time.sleep(delay * (0.5 + random.random() / 2.0))
    raise AssertionError("unreachable")  # pragma: no cover

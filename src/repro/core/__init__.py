"""EcoSched core: the paper's contribution as a composable library.

Phase I:  perfmodel   (ProfiledPerfModel / RooflinePerfModel / Oracle)
Phase II: score (Eq.1) + actions + ecosched (the policy)
Substrate: placement (NUMA/ICI domains), simulator (event-driven energy
accounting), baselines, oracle (exact B&B), metrics.
"""
from repro.core.baselines import Marble, SequentialMax, SequentialOptimal
from repro.core.ecosched import EcoSched
from repro.core.metrics import (
    edp_saving,
    energy_saving,
    makespan_improvement,
    perf_loss,
    summarize,
)
from repro.core.oracle import OracleSolver
from repro.core.perfmodel import OraclePerfModel, ProfiledPerfModel, RooflinePerfModel
from repro.core.placement import PlacementState
from repro.core.simulator import Node, simulate
from repro.core.types import (
    JobProfile,
    JobSpec,
    Launch,
    ModeEstimate,
    NodeView,
    ScheduleResult,
)

__all__ = [
    "EcoSched",
    "JobProfile",
    "JobSpec",
    "Launch",
    "Marble",
    "ModeEstimate",
    "Node",
    "NodeView",
    "OraclePerfModel",
    "OracleSolver",
    "PlacementState",
    "ProfiledPerfModel",
    "RooflinePerfModel",
    "ScheduleResult",
    "SequentialMax",
    "SequentialOptimal",
    "edp_saving",
    "energy_saving",
    "makespan_improvement",
    "perf_loss",
    "simulate",
    "summarize",
]

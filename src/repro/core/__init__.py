"""EcoSched core: the paper's contribution as a composable library.

Phase I:  perfmodel   (ProfiledPerfModel / RooflinePerfModel / Oracle)
Phase II: score (Eq.1) + actions (pure-Python reference) + engine
          (vectorized batch scorer, parity-locked) + ecosched (the policy)
Substrate: placement (NUMA/ICI domains), simulator (event-driven energy
accounting), baselines, oracle (exact B&B), metrics.
"""
from repro.core.arrivals import (
    Arrival,
    ArrivalRateEWMA,
    bursty_stream,
    from_datacenter_csv,
    load_trace,
    poisson_stream,
    save_trace,
)
from repro.core.baselines import (
    Marble,
    NonElasticPolicy,
    SequentialMax,
    SequentialOptimal,
)
from repro.core.cluster import (
    Cluster,
    ClusterRun,
    ClusterState,
    EnergyAwareDispatcher,
    FleetIndex,
    HierarchicalDispatcher,
    LeastLoadedDispatcher,
    NodeSpec,
    PredictiveDispatcher,
    RoundRobinDispatcher,
)
from repro.core.ecosched import EcoSched
from repro.core.forecast import ForecastConfig, ForecastPlane, RefinedPerfModel
from repro.core.engine import (
    DecisionCache,
    PlacementOracle,
    ScoredBatch,
    enumerate_scored,
)
from repro.core.events import ElasticConfig, EventLoop, EventQueue
from repro.core.faults import FaultConfig, FaultInjector
from repro.core.metrics import (
    edp_saving,
    elastic_summary,
    energy_saving,
    makespan_improvement,
    perf_loss,
    summarize,
)
from repro.core.oracle import OracleSolver, cluster_oracle_bound
from repro.core.perfmodel import (
    DomainInterferenceModel,
    OraclePerfModel,
    ProfiledPerfModel,
    RooflinePerfModel,
)
from repro.core.journal import Journal, JournalError
from repro.core.placement import PlacementState, domains_of_units
from repro.core.service import (
    AdmissionConfig,
    AdmissionGate,
    ClusterBackend,
    IllegalTransition,
    JobInfo,
    RecoveryError,
    SchedulerService,
    serve,
)
from repro.core.simulator import Node, NodeSim, simulate
from repro.core.types import (
    ClusterResult,
    JobProfile,
    JobSpec,
    Launch,
    ModeEstimate,
    NodeView,
    ScheduleResult,
)

__all__ = [
    "AdmissionConfig",
    "AdmissionGate",
    "Arrival",
    "ArrivalRateEWMA",
    "Cluster",
    "ClusterBackend",
    "ClusterResult",
    "ClusterRun",
    "ClusterState",
    "DecisionCache",
    "DomainInterferenceModel",
    "EcoSched",
    "ElasticConfig",
    "EnergyAwareDispatcher",
    "FleetIndex",
    "HierarchicalDispatcher",
    "EventLoop",
    "EventQueue",
    "FaultConfig",
    "FaultInjector",
    "ForecastConfig",
    "ForecastPlane",
    "IllegalTransition",
    "JobInfo",
    "JobProfile",
    "JobSpec",
    "Journal",
    "JournalError",
    "Launch",
    "LeastLoadedDispatcher",
    "Marble",
    "ModeEstimate",
    "Node",
    "NodeSim",
    "NodeSpec",
    "NodeView",
    "NonElasticPolicy",
    "OraclePerfModel",
    "OracleSolver",
    "PlacementOracle",
    "PlacementState",
    "PredictiveDispatcher",
    "ProfiledPerfModel",
    "RecoveryError",
    "RefinedPerfModel",
    "ScoredBatch",
    "RooflinePerfModel",
    "RoundRobinDispatcher",
    "ScheduleResult",
    "SchedulerService",
    "SequentialMax",
    "SequentialOptimal",
    "serve",
    "bursty_stream",
    "cluster_oracle_bound",
    "domains_of_units",
    "edp_saving",
    "elastic_summary",
    "energy_saving",
    "enumerate_scored",
    "from_datacenter_csv",
    "load_trace",
    "makespan_improvement",
    "perf_loss",
    "poisson_stream",
    "save_trace",
    "simulate",
    "summarize",
]

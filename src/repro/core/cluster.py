"""Cluster-scale trace-driven simulation (heterogeneous nodes, online jobs).

Generalizes the single ``Node`` of ``simulator.py`` to a ``Cluster`` of
heterogeneous nodes, each typed by a ``ChipSpec`` (H100/A100/V100 power and
relative-runtime scaling — the paper's three evaluation systems as *one*
datacenter).  A job stream (``repro.core.arrivals``) flows through a
two-level policy:

  1. a cluster-level **dispatcher** routes each arriving job to a node,
  2. the node's own per-node policy (EcoSched or any baseline) decides
     when/at what GPU count to launch it — unchanged from the single-node
     reproduction.

Per-node accounting reuses ``NodeSim`` verbatim and the event loop itself
is the shared substrate (``repro.core.events``, ISSUE 4) — the same
``EventLoop`` that drives single-node ``simulate()`` — so a 1-node cluster
reproduces ``simulate()``'s energy and makespan exactly
(regression-locked in tests/test_cluster.py, and the substrate itself is
locked against pre-refactor golden schedules in tests/test_events.py).

Passing ``elastic=ElasticConfig(...)`` turns on the beyond-static
capabilities: per-node preemption/checkpoint-restart with EcoSched's
elastic GPU resizing, and cluster-level migration — after a completion
the drained node pulls a waiting (possibly checkpointed) job from the
most backlogged node whenever the predicted-wait gap beats the move cost.
A dispatcher can override the default greedy pull by implementing
``select_migration(nm, state, sims, now, cfg) -> (donor, job) | None``.

Passing ``forecast=ForecastConfig(...)`` additionally builds the
forecast-driven control plane (``repro.core.forecast``, ISSUE 5): per-node
queueing-aware wait forecasts feed the ``PredictiveDispatcher`` and the
migration gap test, a hysteretic burst-risk gate charges elastic actions
an extra margin while arrivals are bursting, and each node policy's
Phase-I estimates refine online toward observed segment runtimes.  With
``forecast=None`` no plane exists and schedules are bit-identical to the
forecast-free substrate.

Routing is array-backed (ISSUE 3): ``ClusterState`` holds preallocated
numpy columns — per-node outstanding-work sums updated in place on
launch/complete, and per-(node, app) feasibility/best-mode tables built
once per run — so dispatchers route through ``route_indexed`` without
materializing a per-arrival status list.  ``route_indexed(ai, state,
now) -> node index`` is the *only* dispatch protocol: the legacy
``route(arr, statuses)`` list protocol (deprecated since PR 4) has been
removed, and a dispatcher without ``route_indexed`` is rejected at run
construction with a ``TypeError``.  ``simulate(fast_status=False)``
keeps the PR-2 per-arrival Python scan as the *reference outstanding
computation* — the same ``route_indexed`` dispatch over a state view
whose drain proxy is recomputed by scanning every node (the benchmark
baseline in benchmarks/bench_cluster_throughput.py, parity-locked in
tests/test_decision_cache.py).
"""
from __future__ import annotations

import time as _time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.arrivals import Arrival
from repro.core.events import EVT_ARRIVAL, EVT_MIGRATE, ElasticConfig, EventLoop
from repro.core.faults import FaultConfig, FaultInjector
from repro.core.forecast import ForecastConfig, ForecastPlane
from repro.core.simulator import Node, NodeSim, _auto_max_events
from repro.core.types import ClusterResult, JobProfile, RunningJob
from repro.roofline.hw import ChipSpec


@dataclass(frozen=True)
class NodeSpec:
    """One schedulable node: allocation granularity + hardware type."""

    name: str
    chip: ChipSpec
    units: int = 4
    domains: int = 2

    @property
    def idle_power_per_unit(self) -> float:
        return self.chip.power_idle


class ClusterState:
    """Preallocated array view of the cluster for vectorized dispatch.

    Replaces the PR-2 per-arrival list-of-dataclass status scan: the
    drain proxy becomes three per-node accumulators updated in place —

        outstanding·units = max(Σ end·g − now·Σ g, 0) + Σ waiting min-work

    (every running job's ``end`` is in the future, so the running term
    equals Σ (end − now)·g) — and per-(node, app) feasibility and
    best-mode tables are built **once per run** instead of being rebuilt
    from ``JobProfile`` dicts in the routing hot path.
    """

    def __init__(
        self,
        specs: Sequence[NodeSpec],
        app_truth: Dict[str, Dict[str, JobProfile]],
        apps: Sequence[str],
    ):
        self.names = [s.name for s in specs]
        self.index = {n: i for i, n in enumerate(self.names)}
        self.app_index = {a: i for i, a in enumerate(apps)}
        N, A = len(specs), len(apps)
        self.units = np.array([float(s.units) for s in specs])
        # deterministic tie-break domain (ISSUE 9 satellite): dispatchers
        # resolve score ties by *name rank*, not construction index, so a
        # shuffled spec list yields the identical schedule.  order[r] is
        # the node index at rank r; rank[i] inverts it.
        self.order = np.array(
            sorted(range(N), key=self.names.__getitem__), dtype=np.int64
        )
        self.rank = np.empty(N, dtype=np.int64)
        self.rank[self.order] = np.arange(N, dtype=np.int64)
        self.fits = np.zeros((N, A), dtype=bool)
        self.min_unit_s = np.zeros((N, A))  # cheapest busy unit-seconds
        self.e_best = np.ones((N, A))  # min-energy mode: energy (J)
        self.t_best = np.ones((N, A))  # min-energy mode: runtime (s)
        # fragmentation gauge (ISSUE 9, à la Lettich et al.): per-node
        # free units, per-app largest-fitting-mode lookup over every
        # possible free level, and the running Σ_i unusable_i(a) column —
        # all updated incrementally so frag_now() is O(A) per event
        self._cap = max((s.units for s in specs), default=1)
        self.free = np.array([s.units for s in specs], dtype=np.int64)
        self.usable = np.zeros((N, self._cap + 1, A), dtype=np.int64)
        self.unusable = np.zeros(A)
        self.wait_by_app = np.zeros(A, dtype=np.int64)
        self.free_total = int(self.free.sum())
        self._fleet: Optional["FleetIndex"] = None
        # kept for the fault plane's capacity refits (set_alive_units)
        self._specs = list(specs)
        self._app_truth = app_truth
        for i, s in enumerate(specs):
            self._fill_node(i, app_truth[s.name], s.units)
        self.unusable[:] = (
            self.free[:, None] - self.usable[np.arange(N), self.free]
        ).sum(axis=0) if N else 0.0
        # in-place accumulators (launch/complete update these, not scans);
        # the counts let drained accumulators snap back to exactly 0.0 —
        # equal empty nodes must compare *equal*, not within float drift,
        # or dispatcher name-rank tie-breaks would depend on churn history
        self.sum_end_g = np.zeros(N)  # Σ end·g over running jobs
        self.sum_g = np.zeros(N)  # Σ g over running jobs
        self.wait_units_s = np.zeros(N)  # Σ min-work over waiting jobs
        self.n_running = np.zeros(N, dtype=np.int64)
        self.n_waiting = np.zeros(N, dtype=np.int64)

    def _fill_node(self, i: int, truth: Dict[str, JobProfile], limit: int) -> None:
        """(Re)build node ``i``'s feasibility/best-mode row for a unit
        budget of ``limit`` (its physical size at construction; its alive
        capacity after a fault-plane refit)."""
        for a, j in self.app_index.items():
            self.fits[i, j] = False
            self.min_unit_s[i, j] = 0.0
            self.e_best[i, j] = 1.0
            self.t_best[i, j] = 1.0
            self.usable[i, :, j] = 0
            prof = truth.get(a)
            if prof is None:
                continue
            counts = [g for g in prof.feasible_counts if g <= limit]
            if not counts:
                continue
            self.fits[i, j] = True
            # largest feasible mode ≤ f, for every free level f — the
            # fragmentation gauge's "usable GPUs" lookup (free − usable
            # is what this app's pending jobs cannot occupy)
            carr = np.asarray(sorted(counts))
            idx = np.searchsorted(carr, np.arange(self._cap + 1), side="right")
            self.usable[i, :, j] = np.where(idx > 0, carr[idx - 1], 0)
            # best modes over the joint (count, frequency) set; a
            # single-level profile reduces every *_at(g, 0) to the
            # count-only curves, so these cells are bit-identical to
            # the pre-DVFS tables there
            levels = prof.freq_levels
            self.min_unit_s[i, j] = min(
                prof.runtime_at(g, f) * g for g in counts for f in levels
            )
            e, t = min(
                (prof.energy_at(g, f), prof.runtime_at(g, f))
                for g in counts
                for f in levels
            )
            self.e_best[i, j], self.t_best[i, j] = e, t

    def set_alive_units(self, ni: int, alive: int) -> None:
        """Refit node ``ni`` to a degraded (or repaired) capacity: the
        feasibility/best-mode tables shrink to modes that fit the alive
        units, so dispatchers stop routing work a failed node can no
        longer host.  ``alive == spec.units`` restores the physical
        tables bit-identically (same deterministic rebuild)."""
        spec = self._specs[ni]
        # the usable table is about to be rebuilt under the new budget:
        # retract this node's stale unusable contribution first, re-add
        # it after (sync_free then corrects the free level itself once
        # the caller reads the placement)
        f = int(self.free[ni])
        self.unusable -= f - self.usable[ni, f]
        self._fill_node(ni, self._app_truth[spec.name], alive)
        self.unusable += f - self.usable[ni, f]
        # drain-proxy divisor: a degraded node spreads its backlog over
        # fewer units (max(1) keeps a fully-dead node's arithmetic finite
        # — its all-False fits row already blocks routing there)
        self.units[ni] = float(max(alive, 1))
        if self._fleet is not None:
            self._fleet.touch_caps(ni)

    def attach_fleet(self, fleet: "FleetIndex") -> None:
        """Hook a pod summary index into the bookkeeping updates: every
        per-node mutation marks its pod dirty for a lazy re-aggregate."""
        self._fleet = fleet

    def sync_free(self, ni: int, free: int) -> None:
        """Move node ``ni``'s free-unit level to ``free``, updating the
        per-app Σ unusable column with one O(A) row delta.  Clamped to
        [0, cap]: the gauge is observational, and synthetic drivers may
        push the accumulators past physical capacity."""
        f0 = int(self.free[ni])
        f1 = min(max(int(free), 0), self._cap)
        if f1 == f0:
            return
        self.unusable += (f1 - self.usable[ni, f1]) - (f0 - self.usable[ni, f0])
        self.free_total += f1 - f0
        self.free[ni] = f1

    def frag_now(self) -> float:
        """Unusable-GPU fraction given the pending mix (Lettich-style):
        over pending jobs, the mean fraction of the fleet's free GPUs no
        feasible mode of that job's app can occupy.  0.0 when nothing is
        pending or nothing is free; 1.0 when every free GPU is stranded."""
        wt = int(self.wait_by_app.sum())
        if wt == 0 or self.free_total <= 0:
            return 0.0
        return float(self.wait_by_app @ self.unusable) / (
            wt * self.free_total
        )

    def on_arrive(self, ni: int, ai: int) -> None:
        self.wait_units_s[ni] += self.min_unit_s[ni, ai]
        self.n_waiting[ni] += 1
        self.wait_by_app[ai] += 1
        if self._fleet is not None:
            self._fleet.touch(ni)

    def on_launch(self, ni: int, ai: int, end: float, g: int) -> None:
        self.wait_units_s[ni] -= self.min_unit_s[ni, ai]
        self.n_waiting[ni] -= 1
        if self.n_waiting[ni] == 0:
            self.wait_units_s[ni] = 0.0
        self.sum_end_g[ni] += end * g
        self.sum_g[ni] += g
        self.n_running[ni] += 1
        self.wait_by_app[ai] -= 1
        self.sync_free(ni, int(self.free[ni]) - g)
        if self._fleet is not None:
            self._fleet.touch(ni)

    def on_complete(self, ni: int, end: float, g: int) -> None:
        self.sum_end_g[ni] -= end * g
        self.sum_g[ni] -= g
        self.n_running[ni] -= 1
        if self.n_running[ni] == 0:
            self.sum_end_g[ni] = 0.0
            self.sum_g[ni] = 0.0
        self.sync_free(ni, int(self.free[ni]) + g)
        if self._fleet is not None:
            self._fleet.touch(ni)

    def on_retime(self, ni: int, old_end: float, new_end: float, g: int) -> None:
        """A preemption moved a running job's end (checkpoint supersedes the
        original completion); keep Σ end·g consistent with the new end."""
        self.sum_end_g[ni] += (new_end - old_end) * g
        if self._fleet is not None:
            self._fleet.touch(ni)

    def on_migrate_out(self, ni: int, ai: int) -> None:
        """A waiting job left this node's queue (migration); inverse of
        ``on_arrive``."""
        self.wait_units_s[ni] -= self.min_unit_s[ni, ai]
        self.n_waiting[ni] -= 1
        if self.n_waiting[ni] == 0:
            self.wait_units_s[ni] = 0.0
        self.wait_by_app[ai] -= 1
        if self._fleet is not None:
            self._fleet.touch(ni)

    def outstanding(self, now: float) -> np.ndarray:
        """Per-node committed busy unit-seconds / units (drain proxy)."""
        running = np.maximum(self.sum_end_g - now * self.sum_g, 0.0)
        return (running + self.wait_units_s) / self.units


# ---------------------------------------------------------------------------
# Dispatchers (cluster level — defer launch decisions to the node policy).
# ``route_indexed(ai, state, now) -> node index`` is the single dispatch
# protocol (returns -1 when no node fits).  The legacy ``route(arr,
# statuses)`` list protocol was removed after its PR-4 deprecation cycle.
#
# Score ties break by *name rank* (ISSUE 9 satellite), never construction
# index: two Cluster() calls over the same specs in different list orders
# produce the identical schedule (tests/test_fleet.py locks this for every
# built-in dispatcher).
# ---------------------------------------------------------------------------


def _node_order(state) -> np.ndarray:
    """Name-rank node ordering; identity for bare states without one."""
    order = getattr(state, "order", None)
    if order is None:
        order = np.arange(len(state.names))
    return order


def _rank_argmin(values: np.ndarray, state) -> int:
    """Argmin over per-node values with ties broken by name rank."""
    order = _node_order(state)
    return int(order[int(np.argmin(values[order]))])


class RoundRobinDispatcher:
    """FIFO routing: cycle over nodes in name order, skipping infeasible
    ones.  The pointer indexes *ranks*, so the cycle is independent of
    spec construction order."""

    def __init__(self):
        self._i = 0

    def name(self) -> str:
        return "rr"

    def reset(self) -> None:
        self._i = 0

    def route_indexed(self, ai: int, state: ClusterState, now: float) -> int:
        n = len(state.names)
        order = _node_order(state)
        seq = order[(self._i + np.arange(n)) % n]
        hits = np.flatnonzero(state.fits[seq, ai])
        if hits.size == 0:
            return -1
        k = int(hits[0])
        self._i = (self._i + k + 1) % n
        return int(seq[k])


class LeastLoadedDispatcher:
    """Route to the feasible node with the shallowest committed backlog."""

    def name(self) -> str:
        return "least-loaded"

    def route_indexed(self, ai: int, state: ClusterState, now: float) -> int:
        load = np.where(state.fits[:, ai], state.outstanding(now), np.inf)
        i = _rank_argmin(load, state)  # ties -> lowest name rank
        return i if state.fits[i, ai] else -1


class EnergyAwareDispatcher:
    """Route to the node minimizing congestion-inflated best-mode energy.

    For each feasible node, take the job's minimum-energy mode on that
    hardware (E*, t*) and score E* · (drain + t*) / t*: on an empty node
    this is the pure energy-optimal hardware choice; as a node's backlog
    grows its score inflates by the queueing slowdown, spilling work onto
    faster (or merely idler) hardware — the EDP tradeoff at cluster level.

    With a forecast plane attached (``forecast=...`` runs) the (E*, t*)
    cells come from ``plane.dispatch_tables()`` — the static priors with
    observed cells re-derived from each node's refined posterior — so
    dispatch and per-node placement score the *same* model (ISSUE 6
    satellite; before this, dispatchers routed on static tables while the
    node policies had already refined away from them).  Unattached,
    scoring reads ``ClusterState`` directly and is bit-identical to the
    pre-plane dispatcher.
    """

    def __init__(self):
        self._plane: Optional[ForecastPlane] = None

    def name(self) -> str:
        return "eco"

    def reset(self) -> None:
        self._plane = None  # re-attached per run by Cluster.simulate

    def attach_forecast(self, plane: ForecastPlane) -> None:
        self._plane = plane

    def _tables(self, state: ClusterState) -> Tuple[np.ndarray, np.ndarray]:
        if self._plane is None:
            return state.e_best, state.t_best
        return self._plane.dispatch_tables()

    def route_indexed(self, ai: int, state: ClusterState, now: float) -> int:
        out = state.outstanding(now)
        e_best, t_best = self._tables(state)
        t = t_best[:, ai]
        score = np.where(
            state.fits[:, ai], e_best[:, ai] * (out + t) / t, np.inf
        )
        i = _rank_argmin(score, state)  # ties -> lowest name rank
        return i if state.fits[i, ai] else -1


class PredictiveDispatcher(EnergyAwareDispatcher):
    """Queueing-aware routing (ISSUE 5): the EnergyAware score with the
    drain proxy replaced by the forecast plane's *predicted* wait —
    E* · (W_forecast + t*) / t* — where W_forecast inflates committed work
    by the M/G/c heavy-traffic factor from the arrival-rate EWMA.  A node
    that looks shallow right now but sits in a busy routing share gets
    charged the work that will land on it while it drains.

    ``Cluster.simulate`` attaches the plane when ``forecast`` is enabled;
    without one (or with ``queueing`` off, which makes the forecast
    degenerate to the proxy) routing is identical to
    ``EnergyAwareDispatcher`` — parity-locked in tests/test_forecast.py.
    """

    def name(self) -> str:
        return "predictive"

    def route_indexed(self, ai: int, state: ClusterState, now: float) -> int:
        if self._plane is None:
            return super().route_indexed(ai, state, now)
        wait = self._plane.wait_forecast(now)
        e_best, t_best = self._tables(state)
        t = t_best[:, ai]
        score = np.where(
            state.fits[:, ai], e_best[:, ai] * (wait + t) / t, np.inf
        )
        i = _rank_argmin(score, state)  # ties -> lowest name rank
        return i if state.fits[i, ai] else -1


# ---------------------------------------------------------------------------
# Fleet hierarchy (ISSUE 9): region → pod → node routing at 100–1000+ nodes
# ---------------------------------------------------------------------------


class FleetIndex:
    """Pod-level summary table over ``ClusterState`` (lazy, dirty-tracked).

    Nodes are ordered by name rank and cut into contiguous pods of
    ``pod_size``; pods group into regions of ``pods_per_region``.  Each
    pod keeps the aggregates a router needs to *lower-bound* every
    member's score without touching it:

      - load-skew drain pieces (ISSUE 10): the exact per-member
        ``outstanding`` minimum at the refresh instant, the fastest
        member drain rate (max Σg/units) and the waiting-work floor
        (min wait/units) — combined into a per-pod lower bound on
        ``outstanding(now)`` that is *tight* right after a refresh and
        decays admissibly between refreshes (a member's backlog can
        shrink no faster than its committed drain rate, and never below
        its waiting work);
      - per-app feasibility (any member fits);
      - per-app min best-mode energy E* and min E*/t* over fitting
        members, giving  score_i = E*_i + (E*_i/t*_i)·out_i
                                 ≥ Emin + EoTmin · out_lb.

    ``ClusterState`` hooks mark the index dirty; ``refresh``
    re-aggregates with a handful of vectorized ``reduceat`` passes over
    the rank-ordered arrays (one memory sweep, no per-pod Python loop).
    Load aggregates (outstanding, drain rate, waiting floor) move on
    every launch/complete and refresh often; the per-app capacity tables
    (fits, E*, E*/t*) only move on capacity events
    (``set_alive_units``) and refresh separately, so steady routing pays
    three reduceats, not six.
    """

    def __init__(self, state: ClusterState, pod_size: int = 16,
                 pods_per_region: int = 8):
        self.state = state
        self.pod_size = int(pod_size)
        N = len(state.names)
        A = len(state.app_index)
        P = max(1, -(-N // self.pod_size))
        self.n_pods = P
        self.pod_lo = np.arange(P, dtype=np.int64) * self.pod_size
        self.pod_hi = np.minimum(self.pod_lo + self.pod_size, N)
        self.pod_of = state.rank // self.pod_size  # node index -> pod
        self.region_lo = np.arange(0, P, int(pods_per_region), dtype=np.int64)
        self.outmin = np.zeros(P)  # min outstanding(t_load) over members
        self.rate_max = np.zeros(P)  # max Σg/units (fastest member drain)
        self.wmin_rate = np.zeros(P)  # min waiting-work/units (floor)
        self._t_load = 0.0  # instant the load aggregates were taken at
        self.pod_fits = np.zeros((P, A), dtype=bool)
        self.emin = np.full((P, A), np.inf)
        self.eot_min = np.full((P, A), np.inf)
        self._load_dirty = True
        self._caps_dirty = True

    def touch(self, ni: int) -> None:
        self._load_dirty = True

    def touch_caps(self, ni: int) -> None:
        """A capacity event (``set_alive_units``): fits/E*/units moved."""
        self._load_dirty = True
        self._caps_dirty = True

    def refresh(self, now: float = 0.0) -> None:
        st = self.state
        if len(st.order) == 0:
            return
        order, lo = st.order, self.pod_lo
        if self._caps_dirty:
            fit = st.fits[order]
            self.pod_fits = np.logical_or.reduceat(fit, lo, axis=0)
            self.emin = np.minimum.reduceat(
                np.where(fit, st.e_best[order], np.inf), lo, axis=0
            )
            self.eot_min = np.minimum.reduceat(
                np.where(fit, st.e_best[order] / st.t_best[order], np.inf),
                lo, axis=0,
            )
            self._caps_dirty = False
        if self._load_dirty:
            # exact per-member outstanding at the refresh instant, so the
            # pod bound is *tight* here (min over members, not a min of
            # sums) — on loaded fleets this is what lets pruning win
            # instead of every pod tying at a slack bound
            self.outmin = np.minimum.reduceat(st.outstanding(now)[order], lo)
            self.rate_max = np.maximum.reduceat(
                st.sum_g[order] / st.units[order], lo
            )
            self.wmin_rate = np.minimum.reduceat(
                st.wait_units_s[order] / st.units[order], lo
            )
            self._t_load = now
            self._load_dirty = False

    def out_lb(self, now: float) -> np.ndarray:
        """Per-pod lower bound on every member's ``outstanding(now)``.

        A member's backlog decays at most at its committed drain rate
        (Σg/units) and never below its waiting work, so
        ``outmin - dt·rate_max`` clipped to the waiting floor stays
        admissible for any ``now >= t_load`` (and for ``now < t_load``
        the dt clamp keeps the stale-but-valid refresh-time bound)."""
        dt = max(now - self._t_load, 0.0)
        return np.maximum(self.outmin - dt * self.rate_max, self.wmin_rate)


class HierarchicalDispatcher:
    """Two-level routing wrapper: region → pod → node, schedule-exact.

    Wraps a built-in dispatcher and reproduces its flat decision *bit for
    bit* — the pod summaries only prune: regions and pods whose score
    lower bound exceeds the best node found so far are skipped; surviving
    pods are scanned with the inner dispatcher's own formula on array
    slices (elementwise-identical IEEE ops), ties broken by name rank
    exactly like the flat path.  Pruning is strict (a pod with
    ``lb == best`` is still scanned), so equal-score ties can never be
    lost to the hierarchy — bench_fleet.py locks flat-vs-hierarchical
    schedule identity at 64/256/1024 nodes.

    Falls back to the inner dispatcher's flat scan when the state is not
    an array-backed ``ClusterState`` (the ``fast_status=False`` reference
    view) or a forecast plane is attached (posterior tables mutate per
    event; summaries would go stale).
    """

    def __init__(self, inner=None, *, pod_size: int = 16,
                 pods_per_region: int = 8, flat_fallback: int = 4):
        self.inner = inner if inner is not None else EnergyAwareDispatcher()
        self.pod_size = int(pod_size)
        self.pods_per_region = int(pods_per_region)
        # surviving-pod count above which the scored path hands the
        # arrival to the flat vectorized scan instead of per-pod Python
        # scans (result is identical either way; this only bounds cost
        # when the summaries fail to discriminate)
        self.flat_fallback = int(flat_fallback)

    def name(self) -> str:
        return f"hier-{self.inner.name()}"

    def reset(self) -> None:
        if hasattr(self.inner, "reset"):
            self.inner.reset()

    def attach_forecast(self, plane: ForecastPlane) -> None:
        if hasattr(self.inner, "attach_forecast"):
            self.inner.attach_forecast(plane)

    def _fleet(self, state: ClusterState) -> FleetIndex:
        fleet = state._fleet
        if (
            fleet is None
            or fleet.pod_size != self.pod_size
            or fleet.state is not state
        ):
            fleet = FleetIndex(state, self.pod_size, self.pods_per_region)
            state.attach_fleet(fleet)
        return fleet

    def route_indexed(self, ai: int, state, now: float) -> int:
        inner = self.inner
        if not isinstance(state, ClusterState) or (
            getattr(inner, "_plane", None) is not None
        ):
            return inner.route_indexed(ai, state, now)
        fleet = self._fleet(state)
        fleet.refresh(now)
        if isinstance(inner, RoundRobinDispatcher):
            return self._route_rr(ai, state, fleet)
        if isinstance(inner, (LeastLoadedDispatcher, EnergyAwareDispatcher)):
            eco = isinstance(inner, EnergyAwareDispatcher)
            return self._route_scored(ai, state, fleet, now, eco)
        return inner.route_indexed(ai, state, now)

    def _route_rr(self, ai: int, state: ClusterState, fleet: FleetIndex) -> int:
        inner = self.inner
        n = len(state.names)
        if n == 0:
            return -1
        start = inner._i % n
        P = fleet.n_pods
        p0 = start // fleet.pod_size
        # pods in cyclic order from the pointer's pod; the extra final
        # step re-visits p0 for the ranks before the pointer (wrap)
        for step in range(P + 1):
            p = (p0 + step) % P
            lo, hi = int(fleet.pod_lo[p]), int(fleet.pod_hi[p])
            if step == 0:
                lo = start
            elif step == P:
                hi = min(start, hi)
            if lo >= hi or not fleet.pod_fits[p, ai]:
                continue
            nodes = state.order[lo:hi]
            hits = np.flatnonzero(state.fits[nodes, ai])
            if hits.size:
                r = lo + int(hits[0])
                inner._i = (r + 1) % n
                return int(nodes[int(hits[0])])
        return -1

    def _route_scored(self, ai: int, state: ClusterState, fleet: FleetIndex,
                      now: float, eco: bool) -> int:
        out_lb = fleet.out_lb(now)
        ok = fleet.pod_fits[:, ai]
        lb = np.full(fleet.n_pods, np.inf)
        if eco:
            # inner._tables == state tables here (plane-attached runs
            # already fell back to the flat scan); masked assignment keeps
            # the no-fit pods' inf·0 bound from going NaN
            e_best, t_best = self.inner._tables(state)
            lb[ok] = (
                fleet.emin[ok, ai] + fleet.eot_min[ok, ai] * out_lb[ok]
            )
        else:
            lb[ok] = out_lb[ok]
        # one-sided float guard: the tight load-skew bound computes the
        # same quantity as a lone member's score through a *different*
        # rounding path (e + (e/t)·out vs e·(out+t)/t), so reassociation
        # can land lb a few ulps above a tying member — which would prune
        # its pod and break flat parity.  Shaving a relative 1e-12 (three
        # orders above the ~6·eps worst case) keeps the bound admissible
        # in floats too; the cost is only an occasional extra pod scan.
        lb[ok] *= 1.0 - 1e-12
        order = state.order
        sum_end_g, sum_g = state.sum_end_g, state.sum_g
        wait, units, fits = state.wait_units_s, state.units, state.fits
        best_val, best_rank, best_node = np.inf, -1, -1

        def scan(p: int) -> None:
            nonlocal best_val, best_rank, best_node
            lo = int(fleet.pod_lo[p])
            nodes = order[lo:int(fleet.pod_hi[p])]
            out = (
                np.maximum(sum_end_g[nodes] - now * sum_g[nodes], 0.0)
                + wait[nodes]
            ) / units[nodes]
            if eco:
                t = t_best[nodes, ai]
                vals = np.where(
                    fits[nodes, ai], e_best[nodes, ai] * (out + t) / t, np.inf
                )
            else:
                vals = np.where(fits[nodes, ai], out, np.inf)
            k = int(np.argmin(vals))
            v = vals[k]
            if np.isinf(v):
                return
            vr = lo + k  # nodes are rank-ordered: global rank of winner
            if v < best_val or (v == best_val and vr < best_rank):
                best_val, best_rank, best_node = float(v), vr, int(nodes[k])

        # seed with the globally tightest pod (usually the winner: one pod
        # scanned, everything else pruned), then sweep the survivors.  The
        # scan order never affects the result — (best_val, best_rank) is a
        # running min over every node visited, and only pods whose lower
        # bound strictly exceeds best_val are skipped, so equal-score ties
        # always get scanned and break on global name rank exactly like
        # the flat pass.
        p0 = int(np.argmin(lb))
        if np.isinf(lb[p0]):
            return -1
        if int(np.count_nonzero(lb <= lb[p0])) > self.flat_fallback:
            # already more pods tied at the minimum bound than the scan
            # budget: every one of them survives any best_val, so skip
            # straight to the flat pass
            return self.inner.route_indexed(ai, state, now)
        scan(p0)
        surv = lb <= best_val
        surv[p0] = False
        n_surv = int(np.count_nonzero(surv))
        if n_surv == 0:
            return best_node
        if n_surv > self.flat_fallback:
            # the bounds don't discriminate (typical of a homogeneous or
            # lightly loaded fleet, where every idle pod ties): per-pod
            # Python scans would cost more than one vectorized pass, so
            # delegate to the flat scan — bit-identical by the parity
            # construction, and never slower than the flat dispatcher
            return self.inner.route_indexed(ai, state, now)
        rlb = np.minimum.reduceat(lb, fleet.region_lo)
        n_regions = len(fleet.region_lo)
        for r in np.flatnonzero(rlb <= best_val):
            r = int(r)
            plo = int(fleet.region_lo[r])
            phi = (
                int(fleet.region_lo[r + 1])
                if r + 1 < n_regions else fleet.n_pods
            )
            for q in np.flatnonzero(lb[plo:phi] <= best_val):
                p = plo + int(q)
                if surv[p]:
                    scan(p)
        return best_node


class Cluster:
    """Heterogeneous cluster = node specs + per-node truth/policy factories.

    ``truth_for(spec)``  — app-keyed ``JobProfile`` table on that hardware
                           (runtime/power curves differ per ChipSpec).
    ``policy_for(spec, truth)`` — per-node policy over the *instance-keyed*
                           truth table built for one stream.
    ``slowdown_for(spec)`` — optional residual-interference model per node.
    """

    def __init__(
        self,
        specs: Sequence[NodeSpec],
        *,
        truth_for: Callable[[NodeSpec], Dict[str, JobProfile]],
        policy_for: Callable[[NodeSpec, Dict[str, JobProfile]], object],
        dispatcher,
        slowdown_for: Optional[Callable[[NodeSpec], object]] = None,
        label: str = "",
    ):
        if len({s.name for s in specs}) != len(specs):
            raise ValueError("node names must be unique")
        self.specs = list(specs)
        self.truth_for = truth_for
        self.policy_for = policy_for
        self.dispatcher = dispatcher
        self.slowdown_for = slowdown_for
        self.label = label

    def open_run(
        self,
        *,
        apps: Sequence[str],
        jobs: Sequence[Tuple[str, str]] = (),
        elastic: Optional[ElasticConfig] = None,
        forecast: Optional[ForecastConfig] = None,
        faults: Optional[FaultConfig] = None,
        max_events: Optional[int] = None,
        fast_status: bool = True,
        on_transition: Optional[Callable] = None,
    ) -> "ClusterRun":
        """Build an incrementally drivable run over a fixed app universe —
        the control-plane backend entry point (ISSUE 6).  ``jobs`` seeds
        (name, app) instances known up-front; a daemon adds more later via
        ``ClusterRun.submit``."""
        if hasattr(self.dispatcher, "reset"):
            self.dispatcher.reset()  # stateful dispatchers restart per run
        return ClusterRun(
            self,
            apps=apps,
            jobs=jobs,
            elastic=elastic,
            forecast=forecast,
            faults=faults,
            max_events=max_events,
            fast_status=fast_status,
            on_transition=on_transition,
        )

    def simulate(
        self,
        stream: Sequence[Arrival],
        *,
        charge_profiling: bool = False,
        max_events: Optional[int] = None,
        fast_status: bool = True,
        elastic: Optional[ElasticConfig] = None,
        forecast: Optional[ForecastConfig] = None,
        faults: Optional[FaultConfig] = None,
    ) -> ClusterResult:
        # stable on t only: same-instant arrivals keep submission order
        stream = sorted(stream, key=lambda a: a.t)
        if max_events is None:
            # same 50x-per-job bound as simulate(), cluster-sized floor
            max_events = _auto_max_events(len(stream), floor=1_000_000)
        if hasattr(self.dispatcher, "reset"):
            self.dispatcher.reset()  # stateful dispatchers restart per run
        if len({a.name for a in stream}) != len(stream):
            raise ValueError("arrival instance names must be unique")
        run = ClusterRun(
            self,
            apps=sorted({a.app for a in stream}),
            jobs=[(a.name, a.app) for a in stream],
            elastic=elastic,
            forecast=forecast,
            faults=faults,
            max_events=max_events,
            fast_status=fast_status,
        )
        for arr in stream:
            if arr.t <= 0.0:
                run.route(arr, 0.0)
            else:
                run.loop.queue.push(arr.t, EVT_ARRIVAL, arr)
        run.loop.run()
        return run.finalize(charge_profiling=charge_profiling)


class _ReferenceStateView:
    """``ClusterState`` proxy whose drain proxy is the PR-2 reference
    scan: ``outstanding(now)`` recomputes every node's committed busy
    unit-seconds by walking its running/waiting lists against the global
    clock instead of reading the in-place accumulators.  Dispatchers see
    the same ``route_indexed`` state interface either way — this is what
    ``simulate(fast_status=False)`` routes through (the benchmark
    baseline in benchmarks/bench_cluster_throughput.py, parity-locked in
    tests/test_decision_cache.py); every other attribute delegates to
    the real state."""

    def __init__(self, run: "ClusterRun"):
        self._run = run

    def __getattr__(self, name):
        return getattr(self._run.state, name)

    def outstanding(self, now: float) -> np.ndarray:
        run = self._run
        out = np.zeros(len(run.specs))
        for i, s in enumerate(run.specs):
            sim = run.sims[s.name]
            # PR-2 reference scan: remaining work vs the *global* clock —
            # a node's local sim.t lags until its next event, which
            # would inflate its load
            mins = run.min_unit_s[s.name]
            # .get(): a degraded node's refit may have dropped an app a
            # stranded waiter still belongs to — it contributes no
            # schedulable work until the repair restores the entry
            out[i] = (
                sum(max(r.end - now, 0.0) * r.g for r in sim.running)
                + sum(mins.get(run.app_of[j], 0.0) for j in sim.waiting)
            ) / run.state.units[i]
        return out


class _NodeTruth:
    """Instance-keyed truth view on one node's hardware.

    Resolves ``job -> JobProfile`` lazily through the run's shared
    ``app_of`` registry instead of materializing an entry per
    (node, instance) — registering a job is O(1) instead of O(nodes),
    which dominated ``ClusterRun`` construction at fleet scale.  Apps
    this hardware has no profile for are simply absent, exactly like the
    eager per-node dicts it replaces (the dispatcher's ``fits`` refuses
    to route them here).  Supports the mapping subset the simulator and
    perf models actually use: ``[]``, ``in``, ``get``, iteration.
    """

    __slots__ = ("_apps", "_app_of")

    def __init__(self, apps: Dict[str, JobProfile], app_of: Dict[str, str]):
        self._apps = apps      # app -> JobProfile on this hardware
        self._app_of = app_of  # shared instance -> app registry

    def __getitem__(self, job: str) -> JobProfile:
        return self._apps[self._app_of[job]]

    def __contains__(self, job: str) -> bool:
        app = self._app_of.get(job)
        return app is not None and app in self._apps

    def get(self, job: str, default=None):
        app = self._app_of.get(job)
        return self._apps.get(app, default) if app is not None else default

    def __iter__(self):
        return (j for j, a in self._app_of.items() if a in self._apps)

    def __len__(self) -> int:
        return sum(1 for _ in self)


class ClusterRun:
    """One live cluster simulation, exposed as a steppable backend.

    ``Cluster.simulate`` is a thin batch wrapper over this class (seed
    every arrival, ``loop.run()``, ``finalize()`` — bit-identical to the
    pre-refactor monolith); the scheduler daemon (``repro.core.service``)
    instead drives it incrementally: ``submit`` pushes arrivals into the
    live event heap, ``run_until``/``run_to_completion`` advance the
    clock, ``cancel`` drops never-launched jobs, and every lifecycle
    transition is reported through the optional ``on_transition`` callback
    — ``(event, t, job, node, g, end, f)`` with event in {queued, launch,
    done, ckpt, requeue, migrate} — which the daemon journals.

    The app universe (``apps``) is fixed at construction: the
    ``ClusterState`` routing tables are preallocated over it.  Job
    *instances* may keep arriving — per-node truth views and the
    instance->app map grow in place, which is safe because policies and
    perf models read their truth tables lazily per event.
    """

    def __init__(
        self,
        cluster: Cluster,
        *,
        apps: Sequence[str],
        jobs: Sequence[Tuple[str, str]] = (),
        elastic: Optional[ElasticConfig] = None,
        forecast: Optional[ForecastConfig] = None,
        faults: Optional[FaultConfig] = None,
        max_events: Optional[int] = None,
        fast_status: bool = True,
        on_transition: Optional[Callable] = None,
    ):
        self.cluster = cluster
        self.specs = cluster.specs
        self.dispatcher = cluster.dispatcher
        if not hasattr(self.dispatcher, "route_indexed"):
            raise TypeError(
                f"dispatcher {self.dispatcher.name()!r} must implement "
                "route_indexed(ai, state, now); the legacy route(arr, "
                "statuses) protocol (deprecated since PR 4) has been removed"
            )
        self.elastic = elastic
        self.faults = faults if (faults and faults.enabled) else None
        self.fault_injector = (
            FaultInjector(self.faults) if self.faults is not None else None
        )
        self.fast_status = fast_status
        self.on_transition = on_transition

        self.app_truth: Dict[str, Dict[str, JobProfile]] = {
            s.name: cluster.truth_for(s) for s in self.specs
        }
        self.spec_of = {s.name: s for s in self.specs}
        self.apps = list(apps)
        state = self.state = ClusterState(self.specs, self.app_truth, self.apps)
        # admission decisions must be time-independent: a job that fits a
        # *healthy* node is admittable even while that node is down
        self._fits_healthy = state.fits.copy()
        # per-node per-app minimum busy unit-seconds (legacy-scan form of
        # ClusterState.min_unit_s, for the PR-2 baseline status path)
        self.min_unit_s: Dict[str, Dict[str, float]] = {
            s.name: {
                app: state.min_unit_s[state.index[s.name], state.app_index[app]]
                for app in self.apps
                if state.fits[state.index[s.name], state.app_index[app]]
            }
            for s in self.specs
        }
        # forecast-driven control plane (ISSUE 5): never built on the
        # default path, so forecast=None is bit-identical to PR 4
        self.plane: Optional[ForecastPlane] = None
        if forecast is not None and forecast.enabled:
            self.plane = ForecastPlane(
                forecast,
                {s.name: s.units for s in self.specs},
                state=state,
                elastic=elastic,
            )
            if hasattr(self.dispatcher, "attach_forecast"):
                self.dispatcher.attach_forecast(self.plane)
            # posterior-refined dispatch tables (ISSUE 6 satellite)
            self.plane.bind_dispatch(self.app_truth)

        # instance-keyed state; grows in place as jobs are added.  Truth
        # views resolve instance -> app profile through the shared
        # ``app_of`` registry instead of copying one dict entry per
        # (node, instance): registration is O(1), not O(nodes) — at 256+
        # nodes the eager copies dominated ClusterRun construction.
        self.app_of: Dict[str, str] = {}
        self._truth_n: Dict[str, _NodeTruth] = {
            s.name: _NodeTruth(self.app_truth[s.name], self.app_of)
            for s in self.specs
        }
        for name, app in jobs:
            self._register(name, app)
        self.n_jobs = len(self.app_of)

        self.sims: Dict[str, NodeSim] = {}
        for s in self.specs:
            # instance-keyed view of the hardware truth for this stream;
            # apps this hardware has no profile for are simply absent (the
            # dispatcher's fits() already refuses to route them here)
            truth_n = self._truth_n[s.name]
            policy = cluster.policy_for(s, truth_n)
            if self.plane is not None and hasattr(policy, "attach_forecast"):
                policy.attach_forecast(self.plane, s.name)
            self.sims[s.name] = NodeSim(
                Node(s.units, s.domains, s.idle_power_per_unit),
                truth_n,
                policy,
                slowdown_model=(
                    cluster.slowdown_for(s) if cluster.slowdown_for else None
                ),
                name=s.name,
                elastic=elastic,
                faults=faults,
                fault_injector=self.fault_injector,
            )

        # fast_status=False swaps in the reference-scan drain proxy; the
        # dispatch protocol itself is route_indexed either way
        self._dispatch_state = (
            state if fast_status else _ReferenceStateView(self)
        )
        self._cancelled: set = set()  # cancelled before their ARRIVAL popped
        self._routed: set = set()  # instances that reached a node queue
        # fragmentation gauge rollup (ISSUE 9): time-weighted average of
        # ClusterState.frag_now(), sampled at every state transition
        self._frag_area = 0.0
        self._frag_t = 0.0
        self._frag_cur = 0.0
        self._frag_peak = 0.0
        # run-level decision-phase clocks (ISSUE 10): dispatch routing and
        # cross-node kernel staging are cluster work, not node work — the
        # per-node clocks (launch/resize/migrate) live on each NodeSim
        self._dispatch_time = 0.0
        self._stage_time = 0.0
        if max_events is None:
            max_events = _auto_max_events(self.n_jobs, floor=1_000_000)
        self.loop = EventLoop(
            self.sims,
            arrive=self.route,
            max_events=max_events,
            cap_msg="cluster event cap exceeded (policy deadlock?)",
            elastic=elastic,
            faults=faults,
            fault_injector=self.fault_injector,
            on_launch=self._on_launch,
            on_complete=self._on_complete,
            on_requeue=self._on_requeue,
            on_dequeue=self._on_dequeue,
            on_retime=self._on_retime,
            on_fail=self._on_fail,
            on_retry=self._on_retry,
            on_lost=self._on_lost,
            on_capacity=self._on_capacity,
            migrate_candidate=self._migrate_candidate,
            reroute_waiting=self._reroute_waiting,
            prepare_batch=self._prepare_batch,
            prepare_complete=self._prepare_complete_batch,
        )

    # -- job registry --------------------------------------------------------

    def _register(self, name: str, app: str) -> None:
        if name in self.app_of:
            raise ValueError(f"duplicate job instance {name!r}")
        # every node's _NodeTruth view sees the instance through app_of
        self.app_of[name] = app

    @property
    def now(self) -> float:
        return self.loop.now

    def add_job(self, name: str, app: str) -> None:
        """Register one new instance (daemon path).  Raises when the app
        is outside this run's universe or no node can fit it."""
        ai = self.state.app_index.get(app)
        if ai is None:
            raise ValueError(
                f"unknown application {app!r} (universe: {self.apps})"
            )
        if not bool(self._fits_healthy[:, ai].any()):
            raise ValueError(f"no node can fit any feasible mode of {app}")
        self._register(name, app)
        self.n_jobs += 1
        self.loop.max_events = max(
            self.loop.max_events, _auto_max_events(self.n_jobs, floor=1_000_000)
        )

    def submit(self, name: str, app: str, t: float) -> None:
        """Register + push the ARRIVAL event (daemon path).  ``t`` must not
        precede already-processed events; the service layer clamps."""
        self.add_job(name, app)
        self.loop.queue.push(t, EVT_ARRIVAL, Arrival(t=t, name=name, app=app))

    def cancel(self, name: str) -> bool:
        """Drop a job that has not launched yet.  True on success: either
        the ARRIVAL is still in flight (marked to be dropped at its pop) or
        the job is waiting, never-launched, on some node (dequeued in
        place).  False for anything already running, checkpointed, in
        migration transit, finished, or already cancelled."""
        if name not in self.app_of or name in self._cancelled:
            return False
        if name not in self._routed:
            self._cancelled.add(name)
            return True
        for nm, sim in self.sims.items():
            if name not in sim.waiting:
                continue
            if (
                name in sim.progress
                or name in sim.needs_restart
                or sim._segments.get(name, 0)
            ):
                return False  # has elastic state: not a pure queue entry
            sim.cancel_waiting(name)
            self.state.on_migrate_out(
                self.state.index[nm], self.state.app_index[self.app_of[name]]
            )
            self._cancelled.add(name)
            return True
        return False

    # -- driving -------------------------------------------------------------

    def run_until(self, t: float) -> None:
        self.loop.run_until(t)

    def run_to_completion(self) -> None:
        self.loop.run()

    # -- dispatch + substrate hooks ------------------------------------------

    def _emit(
        self,
        event: str,
        t: float,
        job: str,
        node: str,
        g: int,
        end: float,
        f: int = 0,
    ) -> None:
        if self.on_transition is not None:
            self.on_transition(event, t, job, node, g, end, f)

    def _frag_observe(self, t: float) -> None:
        """Close the previous fragmentation interval at ``t`` and sample
        the gauge after the state change that triggered this call."""
        if t > self._frag_t:
            self._frag_area += self._frag_cur * (t - self._frag_t)
            self._frag_t = t
        cur = self.state.frag_now()
        self._frag_cur = cur
        if cur > self._frag_peak:
            self._frag_peak = cur

    def _prepare_batch(self, names: Sequence[str], t: float) -> None:
        t0 = _time.perf_counter()
        try:
            self._stage_arrival_batch(names, t)
        finally:
            self._stage_time += _time.perf_counter() - t0

    def _prepare_complete_batch(self, pairs, t: float) -> None:
        t0 = _time.perf_counter()
        try:
            self._stage_complete_batch(pairs, t)
        finally:
            self._stage_time += _time.perf_counter() - t0

    def _stage_arrival_batch(self, names: Sequence[str], t: float) -> None:
        """Fleet-batched decision staging (ISSUE 9): when a same-instant
        event batch touches several nodes, run every pending Eq. (1)
        reduction as ONE cross-node kernel launch
        (``repro.kernels.score_reduce_batch``) and park each node's argmin
        on its policy; the per-node ``_schedule`` pass then consumes the
        staged result instead of launching its own kernel.  Pure staging:
        the batched kernel is bitwise-locked to the solo kernel
        (tests/test_score_reduce.py) and each policy re-checks its
        decision-state signature at consumption time, so any drift between
        staging and scheduling (e.g. a capacity change) falls back to the
        solo recomputation — schedules are bit-identical either way."""
        staged: List[Tuple[object, dict]] = []
        seen = set()
        for nm in names:
            if nm in seen:
                continue
            seen.add(nm)
            sim = self.sims[nm]
            pol = sim.policy
            if getattr(pol, "engine", None) != "jax":
                continue
            stage = getattr(pol, "stage_score", None)
            if stage is None:
                continue
            if self.faults is not None and sim.placement.free_count() == 0:
                continue  # _schedule skips fully-dead/occupied nodes
            req = stage(sim.node_view(), list(sim.waiting))
            if req is not None:
                staged.append((pol, req))
        if len(staged) < 2:
            for pol, _ in staged:
                pol.stage_drop()  # a lone decision gains nothing batched
            return
        from repro.kernels.score_reduce import score_reduce_batch

        out = score_reduce_batch([req for _, req in staged])
        second: List[Tuple[object, dict]] = []
        for (pol, _), (_, best) in zip(staged, out):
            req2 = pol.stage_round1(int(best))
            if req2 is not None:
                second.append((pol, req2))
        if second:  # idle-node deadlock guards, themselves batched
            out2 = score_reduce_batch([req for _, req in second])
            for (pol, _), (_, best) in zip(second, out2):
                pol.stage_round2(int(best))

    def _stage_complete_batch(self, pairs, t: float) -> None:
        """COMPLETE-burst decision staging (ISSUE 10 tentpole): when a
        same-instant COMPLETE burst spans several nodes, predict each
        node's post-completion view (the completing job's units freed,
        clock at the burst instant) and collect every Eq. (1) reduction
        that view implies — the backfill launch scoring and, where the
        elastic ordering allows, the whole resize candidate table — into
        ONE cross-node multi-window kernel launch
        (``repro.kernels.score_reduce_multi``).  Pure staging, exactly
        like the arrival path: the multi-window kernel is bitwise-locked
        to the solo kernel and every policy re-checks its decision-state
        signature at consumption time inside the strictly-ordered
        per-completion processing, so any prediction miss (a fault's
        capacity change, a migration, an earlier completion's backfill
        touching the node) falls back to the solo recomputation —
        schedules are bit-identical either way.

        Resize staging is attempted only when the resize phase will run
        against the post-completion view unchanged: either
        ``resize_before_backfill`` or an empty backfill queue.  In the
        other orderings the backfill launch would invalidate the
        signature anyway, so staging would be pure waste."""
        cfg = self.elastic
        launch_staged: List[Tuple[object, dict]] = []
        resize_staged: List[Tuple[object, List[dict]]] = []
        for nm, rj in pairs:
            sim = self.sims[nm]
            pol = sim.policy
            if getattr(pol, "engine", None) != "jax":
                continue
            if getattr(pol, "stage_score", None) is None or (
                getattr(pol, "_freed_view", None) is None
            ):
                continue
            view = pol._freed_view(sim.node_view(), rj, t=t, scratch=False)
            if sim.waiting:
                req = pol.stage_score(view, list(sim.waiting))
                if req is not None:
                    launch_staged.append((pol, req))
            if (
                cfg is not None
                and cfg.resize
                and (cfg.resize_before_backfill or not sim.waiting)
                and getattr(pol, "stage_resize", None) is not None
            ):
                reqs = pol.stage_resize(
                    view, frac_of=lambda r, _t=t: r.frac_at(_t), cfg=cfg
                )
                if reqs:
                    resize_staged.append((pol, reqs))
        if len(launch_staged) + len(resize_staged) < 2:
            # a lone node's decisions gain nothing from cross-node
            # batching (its resize table is already one multi-window
            # launch inside propose_resizes)
            for pol, _ in launch_staged:
                pol.stage_drop()
            for pol, _ in resize_staged:
                pol.stage_resize_drop()
            return
        from repro.kernels.score_reduce import score_reduce_multi

        reqs_all = [req for _, req in launch_staged]
        k_launch = len(reqs_all)
        for _, rl in resize_staged:
            reqs_all.extend(rl)
        bests = [b for _, b in score_reduce_multi(reqs_all)]
        second: List[Tuple[object, dict]] = []
        for (pol, _), best in zip(launch_staged, bests[:k_launch]):
            req2 = pol.stage_round1(int(best))
            if req2 is not None:
                second.append((pol, req2))
        if second:  # idle-node deadlock guards, themselves batched
            out2 = score_reduce_multi([req for _, req in second])
            for (pol, _), (_, best2) in zip(second, out2):
                pol.stage_round2(int(best2))
        i = k_launch
        for pol, rl in resize_staged:
            pol.stage_resize_results(bests[i:i + len(rl)])
            i += len(rl)

    def route(self, arr: Arrival, t: float) -> Optional[str]:
        if arr.name in self._cancelled:
            return None  # cancelled between submit and its ARRIVAL pop
        state = self.state
        ai = state.app_index[arr.app]
        t0 = _time.perf_counter()
        ni = self.dispatcher.route_indexed(ai, self._dispatch_state, t)
        self._dispatch_time += _time.perf_counter() - t0
        if ni < 0:
            if self.faults is not None and bool(self._fits_healthy[:, ai].any()):
                # every node that can host this app is currently failed or
                # degraded below its smallest mode: hold the job at the
                # cluster edge and retry after the backoff base — repairs
                # are always scheduled, so this terminates
                self.loop.queue.push(
                    t + self.faults.retry_base_s, EVT_ARRIVAL, arr
                )
                return None
            raise ValueError(
                f"no node can fit any feasible mode of {arr.app}"
            )
        nm = state.names[ni]
        # fits == profile present with a mode that fits the node
        if not state.fits[ni, ai]:
            raise ValueError(
                f"{self.dispatcher.name()} routed {arr.app} to {nm} "
                f"(units={self.spec_of[nm].units}) with no feasible mode"
            )
        self.sims[nm].arrive(arr.name, t)
        state.on_arrive(ni, ai)
        self._frag_observe(t)
        if self.plane is not None:
            self.plane.on_arrival(t, nm)
        self._routed.add(arr.name)
        self._emit("queued", t, arr.name, nm, 0, t)
        return nm

    # array-state bookkeeping hooks the substrate fires on transitions

    def _on_launch(self, nm: str, rj: RunningJob) -> None:
        state = self.state
        state.on_launch(
            state.index[nm], state.app_index[self.app_of[rj.job]], rj.end, rj.g
        )
        self._frag_observe(rj.start)
        if self.plane is not None:
            self.plane.on_launch(nm, rj)
        self._emit("launch", rj.start, rj.job, nm, rj.g, rj.end, rj.f)

    def _on_complete(self, nm: str, rj: RunningJob) -> None:
        self.state.on_complete(self.state.index[nm], rj.end, rj.g)
        self._frag_observe(rj.end)
        if self.plane is not None:
            self.plane.on_complete(nm, rj)
        self._emit(
            "ckpt" if rj.preempted else "done",
            rj.end,
            rj.job,
            nm,
            rj.g,
            rj.end,
            rj.f,
        )

    def _on_requeue(self, nm: str, job: str) -> None:
        state = self.state
        state.on_arrive(state.index[nm], state.app_index[self.app_of[job]])
        self._frag_observe(self.loop.now)
        self._emit("requeue", self.loop.now, job, nm, 0, self.loop.now)

    def _on_dequeue(self, nm: str, job: str) -> None:
        state = self.state
        state.on_migrate_out(state.index[nm], state.app_index[self.app_of[job]])
        self._frag_observe(self.loop.now)
        self._emit("migrate", self.loop.now, job, nm, 0, self.loop.now)

    def _on_retime(self, nm: str, rj: RunningJob, old_end: float) -> None:
        self.state.on_retime(self.state.index[nm], old_end, rj.end, rj.g)

    # fault-plane hooks (repro.core.faults; never fired with faults=None)

    def _on_fail(self, nm: str, rj: RunningJob, old_end: float) -> None:
        """A crash/node failure killed ``rj``: un-book its running term
        with the end the launch (or last retime) booked.  Deliberately NOT
        fed to the forecast plane — a crashed segment's duration says
        nothing about the app's runtime, and posteriors learning from it
        would corrupt every later estimate."""
        self.state.on_complete(self.state.index[nm], old_end, rj.g)
        self._frag_observe(rj.end)
        self._emit("fail", rj.end, rj.job, nm, rj.g, rj.end, rj.f)

    def _on_retry(self, nm: str, job: str) -> None:
        state = self.state
        state.on_arrive(state.index[nm], state.app_index[self.app_of[job]])
        self._frag_observe(self.loop.now)
        self._emit("retry", self.loop.now, job, nm, 0, self.loop.now)

    def _on_lost(self, nm: str, job: str) -> None:
        self._emit("lost", self.loop.now, job, nm, 0, self.loop.now)

    def _on_capacity(self, nm: str) -> None:
        """Node ``nm``'s alive capacity changed (failure or repair):
        refit the routing tables and recompute its waiting-work
        accumulator under the new per-app min-work costs."""
        state = self.state
        ni = state.index[nm]
        sim = self.sims[nm]
        state.set_alive_units(ni, sim.placement.alive_units())
        state.sync_free(ni, sim.placement.free_count())
        state.wait_units_s[ni] = sum(
            state.min_unit_s[ni, state.app_index[self.app_of[j]]]
            for j in sim.waiting
        )
        self._frag_observe(self.loop.now)
        # legacy-scan table (the fast_status=False reference path)
        self.min_unit_s[nm] = {
            app: state.min_unit_s[ni, state.app_index[app]]
            for app in self.apps
            if state.fits[ni, state.app_index[app]]
        }

    def _reroute_waiting(self, nm: str, t: float) -> None:
        """Node ``nm`` went fully dead: move its waiting jobs to live
        nodes through the migration machinery (transit delay charged).
        Without migration enabled the jobs wait out the repair."""
        if self.elastic is None or not self.elastic.migrate:
            return
        sim = self.sims[nm]
        state = self.state
        for job in list(sim.waiting):
            ai = state.app_index[self.app_of[job]]
            ni = self.dispatcher.route_indexed(ai, self._dispatch_state, t)
            if ni < 0 or state.names[ni] == nm:
                continue  # nowhere alive to go; wait for the repair
            dest = state.names[ni]
            mstate = sim.evict(job)
            self._on_dequeue(nm, job)
            self.loop.queue.push(
                t + self.elastic.migration_delay, EVT_MIGRATE, (dest, job, mstate)
            )

    def _migrate_candidate(self, nm: str, t: float):
        """Pull one waiting job from the most backlogged node onto the
        node that just completed, when the predicted-wait gap beats the
        move cost.  With a forecast plane the gap test runs on
        *forecasted* waits (queueing-inflated drain) and, while the
        burst gate is armed, demands an extra risk margin — the
        hysteresis that fixes the PR 4 eager-migration losing seeds.
        A dispatcher may override via
        ``select_migration(nm, state, sims, now, cfg)``."""
        hook = getattr(self.dispatcher, "select_migration", None)
        if hook is not None:
            return hook(nm, self.state, self.sims, t, self.elastic)
        state = self.state
        sims = self.sims
        plane = self.plane
        elastic = self.elastic
        ni = state.index[nm]
        if sims[nm].placement.free_count() <= 0:
            return None
        # One greedy proposer, two accept tests.  PR 4 path
        # (plane=None): raw drain-proxy gap, job-independent — a
        # checkpointed job pays its restart wherever it relaunches,
        # so only the transit delay counts against the move.
        # Forecast path: the same scan on *forecasted* waits, but a
        # fitting job is only pulled when the move's forecasted
        # cluster-level saving beats the burst-risk penalty —
        #   [(W_fc[donor] − own queued work + t_best[donor]) −
        #    (W_fc[recv] + delay + t_best[recv])]          (the moved job)
        #   + relief · (donor waiters left behind)          (their queue)
        #   > penalty
        # — the per-job term is what kills the PR 4 losing pulls (a job
        # whose best mode on the drained slower node runs thousands of
        # seconds longer never wins the gap test job-blindly won); the
        # relief term is the ISSUE 6 saturation fix: at high load the
        # donor's remaining waiters each stop waiting behind the moved
        # job's queued work, a cluster-throughput gain the myopic
        # single-job test left on the table.
        if plane is None:
            out = state.outstanding(t)
            penalty = None
        else:
            out = plane.wait_forecast(t)
            penalty = plane.migration_penalty_s(nm, t)
        threshold = out[ni] + elastic.migration_delay + elastic.min_gain_s
        for di in np.argsort(-out, kind="stable"):
            di = int(di)
            if di == ni or state.n_waiting[di] == 0:
                continue
            if out[di] <= threshold:
                break  # donors come in descending order: scan is done
            dsim = sims[state.names[di]]
            for job in dsim.waiting:
                ai2 = state.app_index[self.app_of[job]]
                if not state.fits[ni, ai2]:
                    continue
                if penalty is None:
                    return state.names[di], job
                # the donor backlog includes the candidate's own
                # queued min-work; staying means waiting behind the
                # *rest* of it.  The gap threshold above already
                # charged min_gain_s, so this veto only blocks moves
                # the forecast predicts to be harmful.
                own = state.min_unit_s[di, ai2] / state.units[di]
                gain = (out[di] - own + state.t_best[di, ai2]) - (
                    out[ni] + elastic.migration_delay + state.t_best[ni, ai2]
                )
                relief = (
                    plane.cfg.migration_relief_weight
                    * own
                    * max(int(state.n_waiting[di]) - 1, 0)
                )
                if gain + relief > penalty:
                    return state.names[di], job
                plane.migrations_vetoed += 1
        return None

    # -- results -------------------------------------------------------------

    def finalize(self, *, charge_profiling: bool = False) -> ClusterResult:
        stuck = {
            nm: sim.waiting for nm, sim in self.sims.items() if sim.waiting
        }
        if stuck:
            raise RuntimeError(
                f"cluster run finished with waiting jobs {stuck}"
            )
        per_node = {
            s.name: self.sims[s.name].result(charge_profiling=charge_profiling)
            for s in self.specs
        }
        makespan = max((r.makespan for r in per_node.values()), default=0.0)
        tail_idle = sum(
            (makespan - per_node[s.name].makespan)
            * s.units
            * s.idle_power_per_unit
            for s in self.specs
        )
        label = self.cluster.label or (
            f"{self.dispatcher.name()}:"
            f"{per_node[self.specs[0].name].policy if self.specs else ''}"
        )
        self._frag_observe(makespan)
        frag = {
            "time_avg": (
                self._frag_area / makespan if makespan > 0.0 else 0.0
            ),
            "peak": self._frag_peak,
            "final": self._frag_cur,
        }
        return ClusterResult(
            policy=label,
            per_node=per_node,
            makespan=makespan,
            tail_idle_energy=tail_idle,
            forecast=self.plane.summary() if self.plane is not None else {},
            fragmentation=frag,
            decision_phases={
                "dispatch": self._dispatch_time,
                "launch": sum(r.decision_time_s for r in per_node.values()),
                "resize": sum(r.resize_time_s for r in per_node.values()),
                "migrate": sum(r.migrate_time_s for r in per_node.values()),
                "stage": self._stage_time,
            },
        )

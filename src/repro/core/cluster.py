"""Cluster-scale trace-driven simulation (heterogeneous nodes, online jobs).

Generalizes the single ``Node`` of ``simulator.py`` to a ``Cluster`` of
heterogeneous nodes, each typed by a ``ChipSpec`` (H100/A100/V100 power and
relative-runtime scaling — the paper's three evaluation systems as *one*
datacenter).  A job stream (``repro.core.arrivals``) flows through a
two-level policy:

  1. a cluster-level **dispatcher** routes each arriving job to a node,
  2. the node's own per-node policy (EcoSched or any baseline) decides
     when/at what GPU count to launch it — unchanged from the single-node
     reproduction.

Per-node accounting reuses ``NodeSim`` verbatim, so a 1-node cluster
reproduces ``simulate()``'s energy and makespan exactly
(regression-locked in tests/test_cluster.py).
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.arrivals import Arrival
from repro.core.simulator import _ARRIVAL, _DONE, Node, NodeSim
from repro.core.types import ClusterResult, JobProfile, NodeView, RunningJob
from repro.roofline.hw import ChipSpec


@dataclass(frozen=True)
class NodeSpec:
    """One schedulable node: allocation granularity + hardware type."""

    name: str
    chip: ChipSpec
    units: int = 4
    domains: int = 2

    @property
    def idle_power_per_unit(self) -> float:
        return self.chip.power_idle


@dataclass
class NodeStatus:
    """Dispatcher-visible snapshot of one node at an arrival event."""

    spec: NodeSpec
    view: NodeView
    backlog: List[str]  # waiting instance names
    truth: Dict[str, JobProfile]  # app-keyed ground truth on this hardware
    outstanding_s: float  # committed busy unit-seconds / units (drain proxy)

    def fits(self, app: str) -> bool:
        prof = self.truth.get(app)
        return prof is not None and min(prof.feasible_counts) <= self.spec.units


# ---------------------------------------------------------------------------
# Dispatchers (cluster level — defer launch decisions to the node policy)
# ---------------------------------------------------------------------------


class RoundRobinDispatcher:
    """FIFO routing: cycle over nodes, skipping infeasible ones."""

    def __init__(self):
        self._i = 0

    def name(self) -> str:
        return "rr"

    def reset(self) -> None:
        self._i = 0

    def route(self, arr: Arrival, statuses: Sequence[NodeStatus]) -> str:
        n = len(statuses)
        for k in range(n):
            st = statuses[(self._i + k) % n]
            if st.fits(arr.app):
                self._i = (self._i + k + 1) % n
                return st.spec.name
        raise ValueError(f"no node can fit any feasible mode of {arr.app}")


class LeastLoadedDispatcher:
    """Route to the feasible node with the shallowest committed backlog."""

    def name(self) -> str:
        return "least-loaded"

    def route(self, arr: Arrival, statuses: Sequence[NodeStatus]) -> str:
        best = None
        for i, st in enumerate(statuses):
            if not st.fits(arr.app):
                continue
            key = (st.outstanding_s, i)
            if best is None or key < best[0]:
                best = (key, st.spec.name)
        if best is None:
            raise ValueError(f"no node can fit any feasible mode of {arr.app}")
        return best[1]


class EnergyAwareDispatcher:
    """Route to the node minimizing congestion-inflated best-mode energy.

    For each feasible node, take the job's minimum-energy mode on that
    hardware (E*, t*) and score E* · (drain + t*) / t*: on an empty node
    this is the pure energy-optimal hardware choice; as a node's backlog
    grows its score inflates by the queueing slowdown, spilling work onto
    faster (or merely idler) hardware — the EDP tradeoff at cluster level.
    """

    def name(self) -> str:
        return "eco"

    def route(self, arr: Arrival, statuses: Sequence[NodeStatus]) -> str:
        best = None
        for i, st in enumerate(statuses):
            if not st.fits(arr.app):
                continue
            prof = st.truth[arr.app]
            counts = [g for g in prof.feasible_counts if g <= st.spec.units]
            e_best, t_best = min(
                ((prof.energy(g), prof.runtime[g]) for g in counts)
            )
            score = e_best * (st.outstanding_s + t_best) / t_best
            key = (score, i)
            if best is None or key < best[0]:
                best = (key, st.spec.name)
        if best is None:
            raise ValueError(f"no node can fit any feasible mode of {arr.app}")
        return best[1]


# ---------------------------------------------------------------------------
# Cluster event loop — same heap protocol as simulator.simulate() (shared
# _ARRIVAL/_DONE ordering), with dispatch layered on top of NodeSim
# ---------------------------------------------------------------------------


class Cluster:
    """Heterogeneous cluster = node specs + per-node truth/policy factories.

    ``truth_for(spec)``  — app-keyed ``JobProfile`` table on that hardware
                           (runtime/power curves differ per ChipSpec).
    ``policy_for(spec, truth)`` — per-node policy over the *instance-keyed*
                           truth table built for one stream.
    ``slowdown_for(spec)`` — optional residual-interference model per node.
    """

    def __init__(
        self,
        specs: Sequence[NodeSpec],
        *,
        truth_for: Callable[[NodeSpec], Dict[str, JobProfile]],
        policy_for: Callable[[NodeSpec, Dict[str, JobProfile]], object],
        dispatcher,
        slowdown_for: Optional[Callable[[NodeSpec], object]] = None,
        label: str = "",
    ):
        if len({s.name for s in specs}) != len(specs):
            raise ValueError("node names must be unique")
        self.specs = list(specs)
        self.truth_for = truth_for
        self.policy_for = policy_for
        self.dispatcher = dispatcher
        self.slowdown_for = slowdown_for
        self.label = label

    def simulate(
        self,
        stream: Sequence[Arrival],
        *,
        charge_profiling: bool = False,
        max_events: int = 1_000_000,
    ) -> ClusterResult:
        # stable on t only: same-instant arrivals keep submission order
        stream = sorted(stream, key=lambda a: a.t)
        if hasattr(self.dispatcher, "reset"):
            self.dispatcher.reset()  # stateful dispatchers restart per run
        if len({a.name for a in stream}) != len(stream):
            raise ValueError("arrival instance names must be unique")

        app_truth: Dict[str, Dict[str, JobProfile]] = {
            s.name: self.truth_for(s) for s in self.specs
        }
        app_of = {a.name: a.app for a in stream}
        # per-node per-app minimum busy unit-seconds (drain proxy for the
        # dispatcher's outstanding-work estimate) — hoisted out of the
        # per-arrival statuses() hot path, which previously recomputed the
        # min over every waiting job's whole runtime table on every event
        min_unit_s: Dict[str, Dict[str, float]] = {}
        for s in self.specs:
            table: Dict[str, float] = {}
            for app, prof in app_truth[s.name].items():
                fits = [prof.runtime[g] * g for g in prof.runtime if g <= s.units]
                if fits:  # apps that don't fit are never routed here
                    table[app] = min(fits)
            min_unit_s[s.name] = table
        sims: Dict[str, NodeSim] = {}
        for s in self.specs:
            # instance-keyed view of the hardware truth for this stream;
            # apps this hardware has no profile for are simply absent (the
            # dispatcher's fits() already refuses to route them here)
            truth_n = {
                a.name: app_truth[s.name][a.app]
                for a in stream
                if a.app in app_truth[s.name]
            }
            sims[s.name] = NodeSim(
                Node(s.units, s.domains, s.idle_power_per_unit),
                truth_n,
                self.policy_for(s, truth_n),
                slowdown_model=self.slowdown_for(s) if self.slowdown_for else None,
                name=s.name,
            )

        def statuses(now: float) -> List[NodeStatus]:
            out = []
            for s in self.specs:
                sim = sims[s.name]
                # remaining work vs the *global* clock — a node's local sim.t
                # lags until its next event, which would inflate its load
                mins = min_unit_s[s.name]
                outstanding = sum(
                    max(r.end - now, 0.0) * r.g for r in sim.running
                ) + sum(mins[app_of[j]] for j in sim.waiting)
                out.append(
                    NodeStatus(
                        spec=s,
                        view=sim.node_view(),
                        backlog=list(sim.waiting),
                        truth=app_truth[s.name],
                        outstanding_s=outstanding / s.units,
                    )
                )
            return out

        def route(arr: Arrival, t: float) -> str:
            nm = self.dispatcher.route(arr, statuses(t))
            spec = next(s for s in self.specs if s.name == nm)
            prof = app_truth[nm].get(arr.app)
            if prof is None or min(prof.feasible_counts) > spec.units:
                raise ValueError(
                    f"{self.dispatcher.name()} routed {arr.app} to {nm} "
                    f"(units={spec.units}) with no feasible mode"
                )
            sims[nm].arrive(arr.name, t)
            return nm

        heap: List[Tuple[float, int, int, object]] = []
        seq = 0
        for arr in stream:
            if arr.t <= 0.0:
                route(arr, 0.0)
            else:
                heapq.heappush(heap, (arr.t, _ARRIVAL, seq, arr))
                seq += 1

        def push_launched(launched: List[RunningJob], node_name: str) -> None:
            nonlocal seq
            for rj in launched:
                heapq.heappush(heap, (rj.end, _DONE, seq, (node_name, rj)))
                seq += 1

        for s in self.specs:  # t=0 scheduling event on every node
            push_launched(sims[s.name].invoke_policy(), s.name)

        events = 0
        while heap:
            events += 1
            if events > max_events:
                raise RuntimeError("cluster event cap exceeded (policy deadlock?)")
            et, kind, _, payload = heapq.heappop(heap)
            if kind == _ARRIVAL:
                touched: List[str] = []
                nm = route(payload, et)
                touched.append(nm)
                while heap and heap[0][0] == et and heap[0][1] == _ARRIVAL:
                    _, _, _, arr = heapq.heappop(heap)
                    nm = route(arr, et)
                    if nm not in touched:
                        touched.append(nm)
                for nm in touched:
                    push_launched(sims[nm].invoke_policy(), nm)
            else:
                nm, rj = payload
                sims[nm].complete(rj)
                if sims[nm].waiting:
                    push_launched(sims[nm].invoke_policy(), nm)

        stuck = {nm: sim.waiting for nm, sim in sims.items() if sim.waiting}
        if stuck:
            raise RuntimeError(f"cluster run finished with waiting jobs {stuck}")

        per_node = {
            s.name: sims[s.name].result(charge_profiling=charge_profiling)
            for s in self.specs
        }
        makespan = max((r.makespan for r in per_node.values()), default=0.0)
        tail_idle = sum(
            (makespan - per_node[s.name].makespan)
            * s.units
            * s.idle_power_per_unit
            for s in self.specs
        )
        label = self.label or (
            f"{self.dispatcher.name()}:"
            f"{per_node[self.specs[0].name].policy if self.specs else ''}"
        )
        return ClusterResult(
            policy=label,
            per_node=per_node,
            makespan=makespan,
            tail_idle_energy=tail_idle,
        )

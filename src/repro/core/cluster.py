"""Cluster-scale trace-driven simulation (heterogeneous nodes, online jobs).

Generalizes the single ``Node`` of ``simulator.py`` to a ``Cluster`` of
heterogeneous nodes, each typed by a ``ChipSpec`` (H100/A100/V100 power and
relative-runtime scaling — the paper's three evaluation systems as *one*
datacenter).  A job stream (``repro.core.arrivals``) flows through a
two-level policy:

  1. a cluster-level **dispatcher** routes each arriving job to a node,
  2. the node's own per-node policy (EcoSched or any baseline) decides
     when/at what GPU count to launch it — unchanged from the single-node
     reproduction.

Per-node accounting reuses ``NodeSim`` verbatim and the event loop itself
is the shared substrate (``repro.core.events``, ISSUE 4) — the same
``EventLoop`` that drives single-node ``simulate()`` — so a 1-node cluster
reproduces ``simulate()``'s energy and makespan exactly
(regression-locked in tests/test_cluster.py, and the substrate itself is
locked against pre-refactor golden schedules in tests/test_events.py).

Passing ``elastic=ElasticConfig(...)`` turns on the beyond-static
capabilities: per-node preemption/checkpoint-restart with EcoSched's
elastic GPU resizing, and cluster-level migration — after a completion
the drained node pulls a waiting (possibly checkpointed) job from the
most backlogged node whenever the predicted-wait gap beats the move cost.
A dispatcher can override the default greedy pull by implementing
``select_migration(nm, state, sims, now, cfg) -> (donor, job) | None``.

Passing ``forecast=ForecastConfig(...)`` additionally builds the
forecast-driven control plane (``repro.core.forecast``, ISSUE 5): per-node
queueing-aware wait forecasts feed the ``PredictiveDispatcher`` and the
migration gap test, a hysteretic burst-risk gate charges elastic actions
an extra margin while arrivals are bursting, and each node policy's
Phase-I estimates refine online toward observed segment runtimes.  With
``forecast=None`` no plane exists and schedules are bit-identical to the
forecast-free substrate.

Routing is array-backed (ISSUE 3): ``ClusterState`` holds preallocated
numpy columns — per-node outstanding-work sums updated in place on
launch/complete, and per-(node, app) feasibility/best-mode tables built
once per run — so dispatchers route through ``route_indexed`` without
materializing a per-arrival status list.  ``route_indexed(ai, state,
now) -> node index`` is the *only* dispatch protocol: the legacy
``route(arr, statuses)`` list protocol (deprecated since PR 4) has been
removed, and a dispatcher without ``route_indexed`` is rejected at run
construction with a ``TypeError``.  ``simulate(fast_status=False)``
keeps the PR-2 per-arrival Python scan as the *reference outstanding
computation* — the same ``route_indexed`` dispatch over a state view
whose drain proxy is recomputed by scanning every node (the benchmark
baseline in benchmarks/bench_cluster_throughput.py, parity-locked in
tests/test_decision_cache.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core.arrivals import Arrival
from repro.core.events import EVT_ARRIVAL, EVT_MIGRATE, ElasticConfig, EventLoop
from repro.core.faults import FaultConfig, FaultInjector
from repro.core.forecast import ForecastConfig, ForecastPlane
from repro.core.simulator import Node, NodeSim, _auto_max_events
from repro.core.types import ClusterResult, JobProfile, RunningJob
from repro.roofline.hw import ChipSpec


@dataclass(frozen=True)
class NodeSpec:
    """One schedulable node: allocation granularity + hardware type."""

    name: str
    chip: ChipSpec
    units: int = 4
    domains: int = 2

    @property
    def idle_power_per_unit(self) -> float:
        return self.chip.power_idle


class ClusterState:
    """Preallocated array view of the cluster for vectorized dispatch.

    Replaces the PR-2 per-arrival list-of-dataclass status scan: the
    drain proxy becomes three per-node accumulators updated in place —

        outstanding·units = max(Σ end·g − now·Σ g, 0) + Σ waiting min-work

    (every running job's ``end`` is in the future, so the running term
    equals Σ (end − now)·g) — and per-(node, app) feasibility and
    best-mode tables are built **once per run** instead of being rebuilt
    from ``JobProfile`` dicts in the routing hot path.
    """

    def __init__(
        self,
        specs: Sequence[NodeSpec],
        app_truth: Dict[str, Dict[str, JobProfile]],
        apps: Sequence[str],
    ):
        self.names = [s.name for s in specs]
        self.index = {n: i for i, n in enumerate(self.names)}
        self.app_index = {a: i for i, a in enumerate(apps)}
        N, A = len(specs), len(apps)
        self.units = np.array([float(s.units) for s in specs])
        self.fits = np.zeros((N, A), dtype=bool)
        self.min_unit_s = np.zeros((N, A))  # cheapest busy unit-seconds
        self.e_best = np.ones((N, A))  # min-energy mode: energy (J)
        self.t_best = np.ones((N, A))  # min-energy mode: runtime (s)
        # kept for the fault plane's capacity refits (set_alive_units)
        self._specs = list(specs)
        self._app_truth = app_truth
        for i, s in enumerate(specs):
            self._fill_node(i, app_truth[s.name], s.units)
        # in-place accumulators (launch/complete update these, not scans);
        # the counts let drained accumulators snap back to exactly 0.0 —
        # equal empty nodes must compare *equal*, not within float drift,
        # or dispatcher index tie-breaks would depend on churn history
        self.sum_end_g = np.zeros(N)  # Σ end·g over running jobs
        self.sum_g = np.zeros(N)  # Σ g over running jobs
        self.wait_units_s = np.zeros(N)  # Σ min-work over waiting jobs
        self.n_running = np.zeros(N, dtype=np.int64)
        self.n_waiting = np.zeros(N, dtype=np.int64)

    def _fill_node(self, i: int, truth: Dict[str, JobProfile], limit: int) -> None:
        """(Re)build node ``i``'s feasibility/best-mode row for a unit
        budget of ``limit`` (its physical size at construction; its alive
        capacity after a fault-plane refit)."""
        for a, j in self.app_index.items():
            self.fits[i, j] = False
            self.min_unit_s[i, j] = 0.0
            self.e_best[i, j] = 1.0
            self.t_best[i, j] = 1.0
            prof = truth.get(a)
            if prof is None:
                continue
            counts = [g for g in prof.feasible_counts if g <= limit]
            if not counts:
                continue
            self.fits[i, j] = True
            # best modes over the joint (count, frequency) set; a
            # single-level profile reduces every *_at(g, 0) to the
            # count-only curves, so these cells are bit-identical to
            # the pre-DVFS tables there
            levels = prof.freq_levels
            self.min_unit_s[i, j] = min(
                prof.runtime_at(g, f) * g for g in counts for f in levels
            )
            e, t = min(
                (prof.energy_at(g, f), prof.runtime_at(g, f))
                for g in counts
                for f in levels
            )
            self.e_best[i, j], self.t_best[i, j] = e, t

    def set_alive_units(self, ni: int, alive: int) -> None:
        """Refit node ``ni`` to a degraded (or repaired) capacity: the
        feasibility/best-mode tables shrink to modes that fit the alive
        units, so dispatchers stop routing work a failed node can no
        longer host.  ``alive == spec.units`` restores the physical
        tables bit-identically (same deterministic rebuild)."""
        spec = self._specs[ni]
        self._fill_node(ni, self._app_truth[spec.name], alive)
        # drain-proxy divisor: a degraded node spreads its backlog over
        # fewer units (max(1) keeps a fully-dead node's arithmetic finite
        # — its all-False fits row already blocks routing there)
        self.units[ni] = float(max(alive, 1))

    def on_arrive(self, ni: int, ai: int) -> None:
        self.wait_units_s[ni] += self.min_unit_s[ni, ai]
        self.n_waiting[ni] += 1

    def on_launch(self, ni: int, ai: int, end: float, g: int) -> None:
        self.wait_units_s[ni] -= self.min_unit_s[ni, ai]
        self.n_waiting[ni] -= 1
        if self.n_waiting[ni] == 0:
            self.wait_units_s[ni] = 0.0
        self.sum_end_g[ni] += end * g
        self.sum_g[ni] += g
        self.n_running[ni] += 1

    def on_complete(self, ni: int, end: float, g: int) -> None:
        self.sum_end_g[ni] -= end * g
        self.sum_g[ni] -= g
        self.n_running[ni] -= 1
        if self.n_running[ni] == 0:
            self.sum_end_g[ni] = 0.0
            self.sum_g[ni] = 0.0

    def on_retime(self, ni: int, old_end: float, new_end: float, g: int) -> None:
        """A preemption moved a running job's end (checkpoint supersedes the
        original completion); keep Σ end·g consistent with the new end."""
        self.sum_end_g[ni] += (new_end - old_end) * g

    def on_migrate_out(self, ni: int, ai: int) -> None:
        """A waiting job left this node's queue (migration); inverse of
        ``on_arrive``."""
        self.wait_units_s[ni] -= self.min_unit_s[ni, ai]
        self.n_waiting[ni] -= 1
        if self.n_waiting[ni] == 0:
            self.wait_units_s[ni] = 0.0

    def outstanding(self, now: float) -> np.ndarray:
        """Per-node committed busy unit-seconds / units (drain proxy)."""
        running = np.maximum(self.sum_end_g - now * self.sum_g, 0.0)
        return (running + self.wait_units_s) / self.units


# ---------------------------------------------------------------------------
# Dispatchers (cluster level — defer launch decisions to the node policy).
# ``route_indexed(ai, state, now) -> node index`` is the single dispatch
# protocol (returns -1 when no node fits).  The legacy ``route(arr,
# statuses)`` list protocol was removed after its PR-4 deprecation cycle.
# ---------------------------------------------------------------------------


class RoundRobinDispatcher:
    """FIFO routing: cycle over nodes, skipping infeasible ones."""

    def __init__(self):
        self._i = 0

    def name(self) -> str:
        return "rr"

    def reset(self) -> None:
        self._i = 0

    def route_indexed(self, ai: int, state: ClusterState, now: float) -> int:
        n = len(state.names)
        order = (self._i + np.arange(n)) % n
        hits = np.flatnonzero(state.fits[order, ai])
        if hits.size == 0:
            return -1
        k = int(hits[0])
        self._i = (self._i + k + 1) % n
        return int(order[k])


class LeastLoadedDispatcher:
    """Route to the feasible node with the shallowest committed backlog."""

    def name(self) -> str:
        return "least-loaded"

    def route_indexed(self, ai: int, state: ClusterState, now: float) -> int:
        load = np.where(state.fits[:, ai], state.outstanding(now), np.inf)
        i = int(np.argmin(load))  # ties -> lowest index, like the list scan
        return i if state.fits[i, ai] else -1


class EnergyAwareDispatcher:
    """Route to the node minimizing congestion-inflated best-mode energy.

    For each feasible node, take the job's minimum-energy mode on that
    hardware (E*, t*) and score E* · (drain + t*) / t*: on an empty node
    this is the pure energy-optimal hardware choice; as a node's backlog
    grows its score inflates by the queueing slowdown, spilling work onto
    faster (or merely idler) hardware — the EDP tradeoff at cluster level.

    With a forecast plane attached (``forecast=...`` runs) the (E*, t*)
    cells come from ``plane.dispatch_tables()`` — the static priors with
    observed cells re-derived from each node's refined posterior — so
    dispatch and per-node placement score the *same* model (ISSUE 6
    satellite; before this, dispatchers routed on static tables while the
    node policies had already refined away from them).  Unattached,
    scoring reads ``ClusterState`` directly and is bit-identical to the
    pre-plane dispatcher.
    """

    def __init__(self):
        self._plane: Optional[ForecastPlane] = None

    def name(self) -> str:
        return "eco"

    def reset(self) -> None:
        self._plane = None  # re-attached per run by Cluster.simulate

    def attach_forecast(self, plane: ForecastPlane) -> None:
        self._plane = plane

    def _tables(self, state: ClusterState) -> Tuple[np.ndarray, np.ndarray]:
        if self._plane is None:
            return state.e_best, state.t_best
        return self._plane.dispatch_tables()

    def route_indexed(self, ai: int, state: ClusterState, now: float) -> int:
        out = state.outstanding(now)
        e_best, t_best = self._tables(state)
        t = t_best[:, ai]
        score = np.where(
            state.fits[:, ai], e_best[:, ai] * (out + t) / t, np.inf
        )
        i = int(np.argmin(score))  # ties -> lowest index, like the list scan
        return i if state.fits[i, ai] else -1


class PredictiveDispatcher(EnergyAwareDispatcher):
    """Queueing-aware routing (ISSUE 5): the EnergyAware score with the
    drain proxy replaced by the forecast plane's *predicted* wait —
    E* · (W_forecast + t*) / t* — where W_forecast inflates committed work
    by the M/G/c heavy-traffic factor from the arrival-rate EWMA.  A node
    that looks shallow right now but sits in a busy routing share gets
    charged the work that will land on it while it drains.

    ``Cluster.simulate`` attaches the plane when ``forecast`` is enabled;
    without one (or with ``queueing`` off, which makes the forecast
    degenerate to the proxy) routing is identical to
    ``EnergyAwareDispatcher`` — parity-locked in tests/test_forecast.py.
    """

    def name(self) -> str:
        return "predictive"

    def route_indexed(self, ai: int, state: ClusterState, now: float) -> int:
        if self._plane is None:
            return super().route_indexed(ai, state, now)
        wait = self._plane.wait_forecast(now)
        e_best, t_best = self._tables(state)
        t = t_best[:, ai]
        score = np.where(
            state.fits[:, ai], e_best[:, ai] * (wait + t) / t, np.inf
        )
        i = int(np.argmin(score))  # ties -> lowest index
        return i if state.fits[i, ai] else -1


# ---------------------------------------------------------------------------
# Cluster event loop — the shared substrate (repro.core.events) with
# dispatch, array-state bookkeeping and migration layered on top of NodeSim
# ---------------------------------------------------------------------------


class Cluster:
    """Heterogeneous cluster = node specs + per-node truth/policy factories.

    ``truth_for(spec)``  — app-keyed ``JobProfile`` table on that hardware
                           (runtime/power curves differ per ChipSpec).
    ``policy_for(spec, truth)`` — per-node policy over the *instance-keyed*
                           truth table built for one stream.
    ``slowdown_for(spec)`` — optional residual-interference model per node.
    """

    def __init__(
        self,
        specs: Sequence[NodeSpec],
        *,
        truth_for: Callable[[NodeSpec], Dict[str, JobProfile]],
        policy_for: Callable[[NodeSpec, Dict[str, JobProfile]], object],
        dispatcher,
        slowdown_for: Optional[Callable[[NodeSpec], object]] = None,
        label: str = "",
    ):
        if len({s.name for s in specs}) != len(specs):
            raise ValueError("node names must be unique")
        self.specs = list(specs)
        self.truth_for = truth_for
        self.policy_for = policy_for
        self.dispatcher = dispatcher
        self.slowdown_for = slowdown_for
        self.label = label

    def open_run(
        self,
        *,
        apps: Sequence[str],
        jobs: Sequence[Tuple[str, str]] = (),
        elastic: Optional[ElasticConfig] = None,
        forecast: Optional[ForecastConfig] = None,
        faults: Optional[FaultConfig] = None,
        max_events: Optional[int] = None,
        fast_status: bool = True,
        on_transition: Optional[Callable] = None,
    ) -> "ClusterRun":
        """Build an incrementally drivable run over a fixed app universe —
        the control-plane backend entry point (ISSUE 6).  ``jobs`` seeds
        (name, app) instances known up-front; a daemon adds more later via
        ``ClusterRun.submit``."""
        if hasattr(self.dispatcher, "reset"):
            self.dispatcher.reset()  # stateful dispatchers restart per run
        return ClusterRun(
            self,
            apps=apps,
            jobs=jobs,
            elastic=elastic,
            forecast=forecast,
            faults=faults,
            max_events=max_events,
            fast_status=fast_status,
            on_transition=on_transition,
        )

    def simulate(
        self,
        stream: Sequence[Arrival],
        *,
        charge_profiling: bool = False,
        max_events: Optional[int] = None,
        fast_status: bool = True,
        elastic: Optional[ElasticConfig] = None,
        forecast: Optional[ForecastConfig] = None,
        faults: Optional[FaultConfig] = None,
    ) -> ClusterResult:
        # stable on t only: same-instant arrivals keep submission order
        stream = sorted(stream, key=lambda a: a.t)
        if max_events is None:
            # same 50x-per-job bound as simulate(), cluster-sized floor
            max_events = _auto_max_events(len(stream), floor=1_000_000)
        if hasattr(self.dispatcher, "reset"):
            self.dispatcher.reset()  # stateful dispatchers restart per run
        if len({a.name for a in stream}) != len(stream):
            raise ValueError("arrival instance names must be unique")
        run = ClusterRun(
            self,
            apps=sorted({a.app for a in stream}),
            jobs=[(a.name, a.app) for a in stream],
            elastic=elastic,
            forecast=forecast,
            faults=faults,
            max_events=max_events,
            fast_status=fast_status,
        )
        for arr in stream:
            if arr.t <= 0.0:
                run.route(arr, 0.0)
            else:
                run.loop.queue.push(arr.t, EVT_ARRIVAL, arr)
        run.loop.run()
        return run.finalize(charge_profiling=charge_profiling)


class _ReferenceStateView:
    """``ClusterState`` proxy whose drain proxy is the PR-2 reference
    scan: ``outstanding(now)`` recomputes every node's committed busy
    unit-seconds by walking its running/waiting lists against the global
    clock instead of reading the in-place accumulators.  Dispatchers see
    the same ``route_indexed`` state interface either way — this is what
    ``simulate(fast_status=False)`` routes through (the benchmark
    baseline in benchmarks/bench_cluster_throughput.py, parity-locked in
    tests/test_decision_cache.py); every other attribute delegates to
    the real state."""

    def __init__(self, run: "ClusterRun"):
        self._run = run

    def __getattr__(self, name):
        return getattr(self._run.state, name)

    def outstanding(self, now: float) -> np.ndarray:
        run = self._run
        out = np.zeros(len(run.specs))
        for i, s in enumerate(run.specs):
            sim = run.sims[s.name]
            # PR-2 reference scan: remaining work vs the *global* clock —
            # a node's local sim.t lags until its next event, which
            # would inflate its load
            mins = run.min_unit_s[s.name]
            # .get(): a degraded node's refit may have dropped an app a
            # stranded waiter still belongs to — it contributes no
            # schedulable work until the repair restores the entry
            out[i] = (
                sum(max(r.end - now, 0.0) * r.g for r in sim.running)
                + sum(mins.get(run.app_of[j], 0.0) for j in sim.waiting)
            ) / run.state.units[i]
        return out


class ClusterRun:
    """One live cluster simulation, exposed as a steppable backend.

    ``Cluster.simulate`` is a thin batch wrapper over this class (seed
    every arrival, ``loop.run()``, ``finalize()`` — bit-identical to the
    pre-refactor monolith); the scheduler daemon (``repro.core.service``)
    instead drives it incrementally: ``submit`` pushes arrivals into the
    live event heap, ``run_until``/``run_to_completion`` advance the
    clock, ``cancel`` drops never-launched jobs, and every lifecycle
    transition is reported through the optional ``on_transition`` callback
    — ``(event, t, job, node, g, end, f)`` with event in {queued, launch,
    done, ckpt, requeue, migrate} — which the daemon journals.

    The app universe (``apps``) is fixed at construction: the
    ``ClusterState`` routing tables are preallocated over it.  Job
    *instances* may keep arriving — per-node truth views and the
    instance->app map grow in place, which is safe because policies and
    perf models read their truth tables lazily per event.
    """

    def __init__(
        self,
        cluster: Cluster,
        *,
        apps: Sequence[str],
        jobs: Sequence[Tuple[str, str]] = (),
        elastic: Optional[ElasticConfig] = None,
        forecast: Optional[ForecastConfig] = None,
        faults: Optional[FaultConfig] = None,
        max_events: Optional[int] = None,
        fast_status: bool = True,
        on_transition: Optional[Callable] = None,
    ):
        self.cluster = cluster
        self.specs = cluster.specs
        self.dispatcher = cluster.dispatcher
        if not hasattr(self.dispatcher, "route_indexed"):
            raise TypeError(
                f"dispatcher {self.dispatcher.name()!r} must implement "
                "route_indexed(ai, state, now); the legacy route(arr, "
                "statuses) protocol (deprecated since PR 4) has been removed"
            )
        self.elastic = elastic
        self.faults = faults if (faults and faults.enabled) else None
        self.fault_injector = (
            FaultInjector(self.faults) if self.faults is not None else None
        )
        self.fast_status = fast_status
        self.on_transition = on_transition

        self.app_truth: Dict[str, Dict[str, JobProfile]] = {
            s.name: cluster.truth_for(s) for s in self.specs
        }
        self.spec_of = {s.name: s for s in self.specs}
        self.apps = list(apps)
        state = self.state = ClusterState(self.specs, self.app_truth, self.apps)
        # admission decisions must be time-independent: a job that fits a
        # *healthy* node is admittable even while that node is down
        self._fits_healthy = state.fits.copy()
        # per-node per-app minimum busy unit-seconds (legacy-scan form of
        # ClusterState.min_unit_s, for the PR-2 baseline status path)
        self.min_unit_s: Dict[str, Dict[str, float]] = {
            s.name: {
                app: state.min_unit_s[state.index[s.name], state.app_index[app]]
                for app in self.apps
                if state.fits[state.index[s.name], state.app_index[app]]
            }
            for s in self.specs
        }
        # forecast-driven control plane (ISSUE 5): never built on the
        # default path, so forecast=None is bit-identical to PR 4
        self.plane: Optional[ForecastPlane] = None
        if forecast is not None and forecast.enabled:
            self.plane = ForecastPlane(
                forecast,
                {s.name: s.units for s in self.specs},
                state=state,
                elastic=elastic,
            )
            if hasattr(self.dispatcher, "attach_forecast"):
                self.dispatcher.attach_forecast(self.plane)
            # posterior-refined dispatch tables (ISSUE 6 satellite)
            self.plane.bind_dispatch(self.app_truth)

        # instance-keyed state; grows in place as jobs are added
        self.app_of: Dict[str, str] = {}
        self._truth_n: Dict[str, Dict[str, JobProfile]] = {
            s.name: {} for s in self.specs
        }
        for name, app in jobs:
            self._register(name, app)
        self.n_jobs = len(self.app_of)

        self.sims: Dict[str, NodeSim] = {}
        for s in self.specs:
            # instance-keyed view of the hardware truth for this stream;
            # apps this hardware has no profile for are simply absent (the
            # dispatcher's fits() already refuses to route them here)
            truth_n = self._truth_n[s.name]
            policy = cluster.policy_for(s, truth_n)
            if self.plane is not None and hasattr(policy, "attach_forecast"):
                policy.attach_forecast(self.plane, s.name)
            self.sims[s.name] = NodeSim(
                Node(s.units, s.domains, s.idle_power_per_unit),
                truth_n,
                policy,
                slowdown_model=(
                    cluster.slowdown_for(s) if cluster.slowdown_for else None
                ),
                name=s.name,
                elastic=elastic,
                faults=faults,
                fault_injector=self.fault_injector,
            )

        # fast_status=False swaps in the reference-scan drain proxy; the
        # dispatch protocol itself is route_indexed either way
        self._dispatch_state = (
            state if fast_status else _ReferenceStateView(self)
        )
        self._cancelled: set = set()  # cancelled before their ARRIVAL popped
        self._routed: set = set()  # instances that reached a node queue
        if max_events is None:
            max_events = _auto_max_events(self.n_jobs, floor=1_000_000)
        self.loop = EventLoop(
            self.sims,
            arrive=self.route,
            max_events=max_events,
            cap_msg="cluster event cap exceeded (policy deadlock?)",
            elastic=elastic,
            faults=faults,
            fault_injector=self.fault_injector,
            on_launch=self._on_launch,
            on_complete=self._on_complete,
            on_requeue=self._on_requeue,
            on_dequeue=self._on_dequeue,
            on_retime=self._on_retime,
            on_fail=self._on_fail,
            on_retry=self._on_retry,
            on_lost=self._on_lost,
            on_capacity=self._on_capacity,
            migrate_candidate=self._migrate_candidate,
            reroute_waiting=self._reroute_waiting,
        )

    # -- job registry --------------------------------------------------------

    def _register(self, name: str, app: str) -> None:
        if name in self.app_of:
            raise ValueError(f"duplicate job instance {name!r}")
        self.app_of[name] = app
        for s in self.specs:
            truth = self.app_truth[s.name]
            if app in truth:
                self._truth_n[s.name][name] = truth[app]

    @property
    def now(self) -> float:
        return self.loop.now

    def add_job(self, name: str, app: str) -> None:
        """Register one new instance (daemon path).  Raises when the app
        is outside this run's universe or no node can fit it."""
        ai = self.state.app_index.get(app)
        if ai is None:
            raise ValueError(
                f"unknown application {app!r} (universe: {self.apps})"
            )
        if not bool(self._fits_healthy[:, ai].any()):
            raise ValueError(f"no node can fit any feasible mode of {app}")
        self._register(name, app)
        self.n_jobs += 1
        self.loop.max_events = max(
            self.loop.max_events, _auto_max_events(self.n_jobs, floor=1_000_000)
        )

    def submit(self, name: str, app: str, t: float) -> None:
        """Register + push the ARRIVAL event (daemon path).  ``t`` must not
        precede already-processed events; the service layer clamps."""
        self.add_job(name, app)
        self.loop.queue.push(t, EVT_ARRIVAL, Arrival(t=t, name=name, app=app))

    def cancel(self, name: str) -> bool:
        """Drop a job that has not launched yet.  True on success: either
        the ARRIVAL is still in flight (marked to be dropped at its pop) or
        the job is waiting, never-launched, on some node (dequeued in
        place).  False for anything already running, checkpointed, in
        migration transit, finished, or already cancelled."""
        if name not in self.app_of or name in self._cancelled:
            return False
        if name not in self._routed:
            self._cancelled.add(name)
            return True
        for nm, sim in self.sims.items():
            if name not in sim.waiting:
                continue
            if (
                name in sim.progress
                or name in sim.needs_restart
                or sim._segments.get(name, 0)
            ):
                return False  # has elastic state: not a pure queue entry
            sim.cancel_waiting(name)
            self.state.on_migrate_out(
                self.state.index[nm], self.state.app_index[self.app_of[name]]
            )
            self._cancelled.add(name)
            return True
        return False

    # -- driving -------------------------------------------------------------

    def run_until(self, t: float) -> None:
        self.loop.run_until(t)

    def run_to_completion(self) -> None:
        self.loop.run()

    # -- dispatch + substrate hooks ------------------------------------------

    def _emit(
        self,
        event: str,
        t: float,
        job: str,
        node: str,
        g: int,
        end: float,
        f: int = 0,
    ) -> None:
        if self.on_transition is not None:
            self.on_transition(event, t, job, node, g, end, f)

    def route(self, arr: Arrival, t: float) -> Optional[str]:
        if arr.name in self._cancelled:
            return None  # cancelled between submit and its ARRIVAL pop
        state = self.state
        ai = state.app_index[arr.app]
        ni = self.dispatcher.route_indexed(ai, self._dispatch_state, t)
        if ni < 0:
            if self.faults is not None and bool(self._fits_healthy[:, ai].any()):
                # every node that can host this app is currently failed or
                # degraded below its smallest mode: hold the job at the
                # cluster edge and retry after the backoff base — repairs
                # are always scheduled, so this terminates
                self.loop.queue.push(
                    t + self.faults.retry_base_s, EVT_ARRIVAL, arr
                )
                return None
            raise ValueError(
                f"no node can fit any feasible mode of {arr.app}"
            )
        nm = state.names[ni]
        # fits == profile present with a mode that fits the node
        if not state.fits[ni, ai]:
            raise ValueError(
                f"{self.dispatcher.name()} routed {arr.app} to {nm} "
                f"(units={self.spec_of[nm].units}) with no feasible mode"
            )
        self.sims[nm].arrive(arr.name, t)
        state.on_arrive(ni, ai)
        if self.plane is not None:
            self.plane.on_arrival(t, nm)
        self._routed.add(arr.name)
        self._emit("queued", t, arr.name, nm, 0, t)
        return nm

    # array-state bookkeeping hooks the substrate fires on transitions

    def _on_launch(self, nm: str, rj: RunningJob) -> None:
        state = self.state
        state.on_launch(
            state.index[nm], state.app_index[self.app_of[rj.job]], rj.end, rj.g
        )
        if self.plane is not None:
            self.plane.on_launch(nm, rj)
        self._emit("launch", rj.start, rj.job, nm, rj.g, rj.end, rj.f)

    def _on_complete(self, nm: str, rj: RunningJob) -> None:
        self.state.on_complete(self.state.index[nm], rj.end, rj.g)
        if self.plane is not None:
            self.plane.on_complete(nm, rj)
        self._emit(
            "ckpt" if rj.preempted else "done",
            rj.end,
            rj.job,
            nm,
            rj.g,
            rj.end,
            rj.f,
        )

    def _on_requeue(self, nm: str, job: str) -> None:
        state = self.state
        state.on_arrive(state.index[nm], state.app_index[self.app_of[job]])
        self._emit("requeue", self.loop.now, job, nm, 0, self.loop.now)

    def _on_dequeue(self, nm: str, job: str) -> None:
        state = self.state
        state.on_migrate_out(state.index[nm], state.app_index[self.app_of[job]])
        self._emit("migrate", self.loop.now, job, nm, 0, self.loop.now)

    def _on_retime(self, nm: str, rj: RunningJob, old_end: float) -> None:
        self.state.on_retime(self.state.index[nm], old_end, rj.end, rj.g)

    # fault-plane hooks (repro.core.faults; never fired with faults=None)

    def _on_fail(self, nm: str, rj: RunningJob, old_end: float) -> None:
        """A crash/node failure killed ``rj``: un-book its running term
        with the end the launch (or last retime) booked.  Deliberately NOT
        fed to the forecast plane — a crashed segment's duration says
        nothing about the app's runtime, and posteriors learning from it
        would corrupt every later estimate."""
        self.state.on_complete(self.state.index[nm], old_end, rj.g)
        self._emit("fail", rj.end, rj.job, nm, rj.g, rj.end, rj.f)

    def _on_retry(self, nm: str, job: str) -> None:
        state = self.state
        state.on_arrive(state.index[nm], state.app_index[self.app_of[job]])
        self._emit("retry", self.loop.now, job, nm, 0, self.loop.now)

    def _on_lost(self, nm: str, job: str) -> None:
        self._emit("lost", self.loop.now, job, nm, 0, self.loop.now)

    def _on_capacity(self, nm: str) -> None:
        """Node ``nm``'s alive capacity changed (failure or repair):
        refit the routing tables and recompute its waiting-work
        accumulator under the new per-app min-work costs."""
        state = self.state
        ni = state.index[nm]
        sim = self.sims[nm]
        state.set_alive_units(ni, sim.placement.alive_units())
        state.wait_units_s[ni] = sum(
            state.min_unit_s[ni, state.app_index[self.app_of[j]]]
            for j in sim.waiting
        )
        # legacy-scan table (the fast_status=False reference path)
        self.min_unit_s[nm] = {
            app: state.min_unit_s[ni, state.app_index[app]]
            for app in self.apps
            if state.fits[ni, state.app_index[app]]
        }

    def _reroute_waiting(self, nm: str, t: float) -> None:
        """Node ``nm`` went fully dead: move its waiting jobs to live
        nodes through the migration machinery (transit delay charged).
        Without migration enabled the jobs wait out the repair."""
        if self.elastic is None or not self.elastic.migrate:
            return
        sim = self.sims[nm]
        state = self.state
        for job in list(sim.waiting):
            ai = state.app_index[self.app_of[job]]
            ni = self.dispatcher.route_indexed(ai, self._dispatch_state, t)
            if ni < 0 or state.names[ni] == nm:
                continue  # nowhere alive to go; wait for the repair
            dest = state.names[ni]
            mstate = sim.evict(job)
            self._on_dequeue(nm, job)
            self.loop.queue.push(
                t + self.elastic.migration_delay, EVT_MIGRATE, (dest, job, mstate)
            )

    def _migrate_candidate(self, nm: str, t: float):
        """Pull one waiting job from the most backlogged node onto the
        node that just completed, when the predicted-wait gap beats the
        move cost.  With a forecast plane the gap test runs on
        *forecasted* waits (queueing-inflated drain) and, while the
        burst gate is armed, demands an extra risk margin — the
        hysteresis that fixes the PR 4 eager-migration losing seeds.
        A dispatcher may override via
        ``select_migration(nm, state, sims, now, cfg)``."""
        hook = getattr(self.dispatcher, "select_migration", None)
        if hook is not None:
            return hook(nm, self.state, self.sims, t, self.elastic)
        state = self.state
        sims = self.sims
        plane = self.plane
        elastic = self.elastic
        ni = state.index[nm]
        if sims[nm].placement.free_count() <= 0:
            return None
        # One greedy proposer, two accept tests.  PR 4 path
        # (plane=None): raw drain-proxy gap, job-independent — a
        # checkpointed job pays its restart wherever it relaunches,
        # so only the transit delay counts against the move.
        # Forecast path: the same scan on *forecasted* waits, but a
        # fitting job is only pulled when the move's forecasted
        # cluster-level saving beats the burst-risk penalty —
        #   [(W_fc[donor] − own queued work + t_best[donor]) −
        #    (W_fc[recv] + delay + t_best[recv])]          (the moved job)
        #   + relief · (donor waiters left behind)          (their queue)
        #   > penalty
        # — the per-job term is what kills the PR 4 losing pulls (a job
        # whose best mode on the drained slower node runs thousands of
        # seconds longer never wins the gap test job-blindly won); the
        # relief term is the ISSUE 6 saturation fix: at high load the
        # donor's remaining waiters each stop waiting behind the moved
        # job's queued work, a cluster-throughput gain the myopic
        # single-job test left on the table.
        if plane is None:
            out = state.outstanding(t)
            penalty = None
        else:
            out = plane.wait_forecast(t)
            penalty = plane.migration_penalty_s(nm, t)
        threshold = out[ni] + elastic.migration_delay + elastic.min_gain_s
        for di in np.argsort(-out, kind="stable"):
            di = int(di)
            if di == ni or state.n_waiting[di] == 0:
                continue
            if out[di] <= threshold:
                break  # donors come in descending order: scan is done
            dsim = sims[state.names[di]]
            for job in dsim.waiting:
                ai2 = state.app_index[self.app_of[job]]
                if not state.fits[ni, ai2]:
                    continue
                if penalty is None:
                    return state.names[di], job
                # the donor backlog includes the candidate's own
                # queued min-work; staying means waiting behind the
                # *rest* of it.  The gap threshold above already
                # charged min_gain_s, so this veto only blocks moves
                # the forecast predicts to be harmful.
                own = state.min_unit_s[di, ai2] / state.units[di]
                gain = (out[di] - own + state.t_best[di, ai2]) - (
                    out[ni] + elastic.migration_delay + state.t_best[ni, ai2]
                )
                relief = (
                    plane.cfg.migration_relief_weight
                    * own
                    * max(int(state.n_waiting[di]) - 1, 0)
                )
                if gain + relief > penalty:
                    return state.names[di], job
                plane.migrations_vetoed += 1
        return None

    # -- results -------------------------------------------------------------

    def finalize(self, *, charge_profiling: bool = False) -> ClusterResult:
        stuck = {
            nm: sim.waiting for nm, sim in self.sims.items() if sim.waiting
        }
        if stuck:
            raise RuntimeError(
                f"cluster run finished with waiting jobs {stuck}"
            )
        per_node = {
            s.name: self.sims[s.name].result(charge_profiling=charge_profiling)
            for s in self.specs
        }
        makespan = max((r.makespan for r in per_node.values()), default=0.0)
        tail_idle = sum(
            (makespan - per_node[s.name].makespan)
            * s.units
            * s.idle_power_per_unit
            for s in self.specs
        )
        label = self.cluster.label or (
            f"{self.dispatcher.name()}:"
            f"{per_node[self.specs[0].name].policy if self.specs else ''}"
        )
        return ClusterResult(
            policy=label,
            per_node=per_node,
            makespan=makespan,
            tail_idle_energy=tail_idle,
            forecast=self.plane.summary() if self.plane is not None else {},
        )

"""Vectorized pod-scale scoring engine (ROADMAP: Perf).

``repro.core.actions.enumerate_actions`` is the pure-Python reference for
the paper's Phase-II decision (§III-C): enumerate feasible joint actions,
score each with Eq. (1), pick the argmin.  At the paper's node scale
(M=4, K=2) it is cheap; at pod scale (M=16, K=4, 17-job windows) its
per-candidate ``score()`` call and first-fit replay dominate decision
time.  This module reimplements both the exact and the beam path as
batched numpy computation:

  * a scheduling window becomes a ``_SpecTable`` of per-(job, mode)
    columns (unit counts, ``e_norm`` deviations, ``t_norm·g`` loads),
  * Eq. (1) scores for whole candidate batches are one vector expression,
  * placement feasibility replays the simulator's domain-spreading
    first-fit on an *integer bitmask* of the free map (shift/AND finds
    every contiguous run), memoized per count-multiset — thousands of
    candidates share a handful of multisets,
  * beam rounds become batched extend → dedupe → score → stable top-k.

The engine is parity-locked against the reference: identical candidate
order, identical argmin action, scores within 1e-9 (tests/test_engine.py
property-checks this over seeded random node states).  ``EcoSched``
consumes it through ``enumerate_scored`` + ``ScoredBatch.best_index`` so
the argmin never materializes Python tuples for the full action space.

At cluster scale the same decision recurs across events; ``DecisionCache``
memoizes spec tables, placement oracles and whole scored batches on
name-free structural keys so repeated decisions cost a dict lookup
(ISSUE 3).  ``ScoredBatch.padded_cols`` exposes the candidate matrices the
``kernels/score_reduce`` JAX/Pallas backend reduces on device.
"""
from __future__ import annotations

import copy
import itertools
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.actions import _space_estimate
from repro.core.score import score
from repro.core.types import JobSpec, ModeEstimate, NodeView

# Cap on elements per vectorized exact-path chunk; bounds peak memory when
# padded mode grids are much larger than the true action space.
_CHUNK_ELEMS = 2_000_000


def _mask_of(free_map: Sequence[bool]) -> int:
    """Free map as one integer (bit u set = unit u free)."""
    mask = 0
    for u, f in enumerate(free_map):
        if f:
            mask |= 1 << u
    return mask


# Window-shape-independent enumeration skeletons, shared across all spec
# tables: job combinations per (J, s) and padded mode grids per (mm, s).
_COMBO_MEMO: Dict[Tuple[int, int], np.ndarray] = {}
_GRID_MEMO: Dict[Tuple[int, int], np.ndarray] = {}


def _combos_of(J: int, s: int) -> np.ndarray:
    key = (J, s)
    hit = _COMBO_MEMO.get(key)
    if hit is None:
        if len(_COMBO_MEMO) > 256:
            _COMBO_MEMO.clear()
        hit = _COMBO_MEMO[key] = np.array(
            list(itertools.combinations(range(J), s)), dtype=np.int64
        )
    return hit


def _grid_of(mm: int, s: int) -> np.ndarray:
    key = (mm, s)
    hit = _GRID_MEMO.get(key)
    if hit is None:
        if len(_GRID_MEMO) > 256:
            _GRID_MEMO.clear()
        hit = _GRID_MEMO[key] = np.indices((mm,) * s).reshape(s, -1).T
    return hit


class PlacementOracle:
    """Memoized bitmask replay of ``PlacementState.allocate``.

    The free map is one integer (bit u set = unit u free); the feasible
    starts for a g-unit job are the set bits of ``m = mask & mask>>1 &
    ... & mask>>(g-1)``.  Start selection replicates the simulator's
    domain-spreading first-fit exactly: among feasible starts, minimize
    (home-domain occupancy, start) where the home domain is the
    least-occupied domain the range overlaps.  Feasibility of an action
    depends only on its count multiset, so verdicts are memoized per
    descending count tuple.
    """

    def __init__(
        self,
        free_map: Sequence[bool],
        domains: int,
        domain_jobs: Optional[Sequence[int]] = None,
    ):
        self._setup(_mask_of(free_map), len(free_map), domains, domain_jobs)

    @classmethod
    def from_mask(
        cls,
        mask: int,
        units: int,
        domains: int,
        domain_jobs: Optional[Sequence[int]] = None,
    ) -> "PlacementOracle":
        """Construct from an already-computed free-map bitmask (the
        ``DecisionCache`` key form, so cached oracles skip the bit loop)."""
        o = cls.__new__(cls)
        o._setup(mask, units, domains, domain_jobs)
        return o

    def _setup(self, mask, units, domains, domain_jobs):
        self.units = units
        self.domains = domains
        self.mask0 = mask
        self.occ0 = tuple(domain_jobs) if domain_jobs else (0,) * domains
        self._dom = [u * domains // units for u in range(units)]
        self._memo: Dict[Tuple[int, ...], bool] = {}

    def placeable(self, counts_desc: Tuple[int, ...]) -> bool:
        hit = self._memo.get(counts_desc)
        if hit is not None:
            return hit
        mask = self.mask0
        occ = list(self.occ0)
        ok = True
        for g in counts_desc:
            mask = self._alloc(mask, occ, g)
            if mask is None:
                ok = False
                break
        self._memo[counts_desc] = ok
        return ok

    def _alloc(self, mask: int, occ: List[int], g: int) -> Optional[int]:
        m = mask
        for i in range(1, g):
            m &= mask >> i
        if not m:
            return None
        best = None  # ((home occupancy, start), start, home)
        while m:
            s = (m & -m).bit_length() - 1
            d_lo = self._dom[s]
            d_hi = self._dom[s + g - 1]
            home = min(range(d_lo, d_hi + 1), key=lambda d: (occ[d], d))
            key = (occ[home], s)
            if best is None or key < best[0]:
                best = (key, s, home)
            if occ[home] == 0:
                break  # starts ascend: (0, s) is unbeatable
            m &= m - 1
        _, s, home = best
        occ[home] += 1
        return mask & ~(((1 << g) - 1) << s)


class _SpecTable:
    """Column-oriented view of one scheduling window's τ-filtered specs.

    Everything that depends only on the window *structure* — not on the
    node's placement state — lives here, including the exact path's full
    mode-valid candidate enumeration (``candidates``).  The table is what
    ``DecisionCache`` shares across events, so all of it is computed once
    per distinct window structure, not once per event.
    """

    def __init__(self, specs: Sequence[JobSpec]):
        self.specs = list(specs)
        J = len(self.specs)
        n_modes = [len(s.modes) for s in self.specs]
        self.mode_count = np.asarray(n_modes, dtype=np.int64)
        mm = max(n_modes) if J else 0
        self.max_modes = mm
        self.mode_g = np.zeros((J, mm), dtype=np.int64)
        self.mode_f = np.zeros((J, mm), dtype=np.int64)  # DVFS level per mode
        self.mode_dev = np.zeros((J, mm))  # e_norm - 1
        self.mode_load = np.zeros((J, mm))  # t_norm * g (lookahead proxy)
        for j, s in enumerate(self.specs):
            for k, m in enumerate(s.modes):
                self.mode_g[j, k] = m.g
                self.mode_f[j, k] = m.f
                self.mode_dev[j, k] = m.e_norm - 1.0
                self.mode_load[j, k] = m.t_norm * m.g
        # flattened (job, mode) pairs, job-major/mode-minor — the reference
        # path's iteration order
        self.pair_job = np.repeat(np.arange(J, dtype=np.int64), n_modes)
        self.pair_mode = (
            np.concatenate([np.arange(n, dtype=np.int64) for n in n_modes])
            if J
            else np.zeros(0, dtype=np.int64)
        )
        self.pair_g = self.mode_g[self.pair_job, self.pair_mode]
        self.pair_f = self.mode_f[self.pair_job, self.pair_mode]
        self.pair_dev = self.mode_dev[self.pair_job, self.pair_mode]
        self.pair_load = self.mode_load[self.pair_job, self.pair_mode]
        self._cand: Dict[int, Tuple[np.ndarray, ...]] = {}
        self._cap: "OrderedDict[Tuple[int, int], Optional[Tuple]]" = OrderedDict()
        self._est: Dict[Tuple[int, int], int] = {}

    def space_estimate(self, k_avail: int, exact_limit: int) -> int:
        """``actions._space_estimate`` memoized — it walks every job-count
        combination, which is itself non-trivial per event at pod scale."""
        key = (k_avail, exact_limit)
        hit = self._est.get(key)
        if hit is None:
            hit = self._est[key] = _space_estimate(
                [len(s.modes) for s in self.specs], k_avail, exact_limit
            )
        return hit

    def candidates(self, s: int) -> Tuple[np.ndarray, ...]:
        """All mode-valid size-``s`` candidates in reference order, with
        their per-candidate reductions precomputed (memoized per size):

            (job_mat (C, s), mode_mat (C, s), counts (C, s), tot (C,),
             dev_sum (C,), load_max (C,), load_min (C,))

        Only the exact path calls this, so C is bounded by ``exact_limit``
        (``_space_estimate`` counts exactly these rows).  The caller applies
        the state-dependent filters (``tot <= g_free``, placement) — both
        preserve this row order, which is the reference iteration order.
        """
        hit = self._cand.get(s)
        if hit is not None:
            return hit
        J = len(self.specs)
        mm = self.max_modes
        combos = _combos_of(J, s)  # (C, s) in reference order
        # (P, s) padded mode-index grid, last index fastest = product order
        grid = _grid_of(mm, s)
        P = len(grid)
        chunk = max(1, _CHUNK_ELEMS // max(P * s, 1))
        parts: List[Tuple[np.ndarray, np.ndarray]] = []
        for c0 in range(0, len(combos), chunk):
            cs = combos[c0 : c0 + chunk]
            jm = cs[:, None, :]  # (c, 1, s)
            gb = grid[None, :, :]  # (1, P, s)
            valid = (gb < self.mode_count[jm]).all(axis=2)  # (c, P)
            ci, pi = np.nonzero(valid)  # combo-major, product-minor
            if ci.size:
                parts.append((cs[ci], grid[pi]))
        if parts:
            job_mat = np.concatenate([p[0] for p in parts])
            mode_mat = np.concatenate([p[1] for p in parts])
        else:
            job_mat = np.zeros((0, s), dtype=np.int64)
            mode_mat = np.zeros((0, s), dtype=np.int64)
        counts = self.mode_g[job_mat, mode_mat]
        loads = self.mode_load[job_mat, mode_mat]
        out = (
            job_mat,
            mode_mat,
            counts,
            counts.sum(axis=1),
            self.mode_dev[job_mat, mode_mat].sum(axis=1),
            loads.max(axis=1, initial=-np.inf),
            loads.min(axis=1, initial=np.inf),
        )
        self._cand[s] = out
        return out

    def capacity(self, s: int, g_free: int) -> Optional[Tuple]:
        """``candidates(s)`` filtered to ``tot <= g_free``, with the count
        multisets pre-extracted for the placement oracle (memoized per
        (s, g_free) — g_free only takes node-fill values, so the layer is
        small).  Returns None when nothing fits, else

            (job_mat, mode_mat, counts, tot, dev_sum, load_max, load_min,
             multisets, inverse)

        where ``multisets[k]`` is the k-th distinct descending count tuple
        and ``inverse`` maps rows to multisets — a decision needs only one
        (memoized) oracle verdict per multiset, not per row.
        """
        key = (s, g_free)
        if key in self._cap:
            self._cap.move_to_end(key)
            return self._cap[key]
        job_mat, mode_mat, counts, tot, dev_sum, lmax, lmin = self.candidates(s)
        fit = tot <= g_free
        if not fit.any():
            entry = None
        else:
            job_mat, mode_mat, counts = job_mat[fit], mode_mat[fit], counts[fit]
            counts_desc = -np.sort(-counts, axis=1)
            # injective multiset code: base just above the largest count
            base = int(self.pair_g.max()) + 1 if len(self.pair_g) else 1
            weights = base ** np.arange(counts_desc.shape[1], dtype=np.int64)
            codes = counts_desc @ weights
            _, first, inv = np.unique(codes, return_index=True, return_inverse=True)
            multisets = [
                tuple(int(x) for x in counts_desc[i]) for i in first
            ]
            entry = (
                job_mat, mode_mat, counts, tot[fit], dev_sum[fit],
                lmax[fit], lmin[fit], multisets, inv,
            )
        self._cap[key] = entry
        if len(self._cap) > 64:
            self._cap.popitem(last=False)
        return entry


class DecisionCache:
    """Cross-event reuse for the repeated-decision hot path.

    Cluster-scale sweeps make the *same* decision over and over: consecutive
    scheduling events share windows, free maps recur as jobs cycle, and
    instances of one application carry identical Phase-I mode structures.
    Three LRU layers exploit that, all keyed on **structural** identity (job
    names stripped — the scored action space depends on names only through
    window position):

      * ``table``    — window structure -> ``_SpecTable``,
      * ``oracle``   — (units, domains, free-mask, occupancy) ->
                       ``PlacementOracle``; its count-multiset memo persists
                       across events instead of being rebuilt per invocation,
      * ``decision`` — (order-canonical window structure, free-mask,
                       occupancy, scoring params) -> (``ScoredBatch``,
                       producer permutation); a hit skips enumeration,
                       placement replay and scoring outright and rebinds
                       the batch to the current specs — the keys sort the
                       window's tokens (stably), so permuted waiting
                       windows share one entry (ISSUE 4 satellite).  A
                       permuted hit re-orders the stored rows into the
                       consumer window's reference order first (row order
                       carries the tie-break; see ``_reorder_hit``).

    Caching is *pure*: a hit returns arrays bit-identical to a rebuild
    (locked in tests/test_decision_cache.py), so schedules and energies are
    unchanged.  Every key is name-free, so one instance may be shared by
    many policies on identically-shaped nodes (ISSUE 10): fleet peers then
    serve each other's first-sight enumerations — at fleet scale a private
    cache never warms, because each node only ever sees a handful of jobs.
    Sharing changes hit rates, never schedules.
    """

    def __init__(
        self,
        max_tables: int = 512,
        max_oracles: int = 4096,
        max_decisions: int = 8192,
        max_structs: int = 100_000,
        max_launches: int = 65_536,
        max_frontiers: int = 16_384,
    ):
        self.max_tables = max_tables
        self.max_oracles = max_oracles
        self.max_decisions = max_decisions
        self.max_structs = max_structs
        self.max_launches = max_launches
        self.max_frontiers = max_frontiers
        # bumped whenever the token tables reset; anything keyed on tokens
        # (here and in EcoSched's launch memo) must be dropped with them
        self.epoch = 0
        self._tables: "OrderedDict[Tuple, _SpecTable]" = OrderedDict()
        self._oracles: "OrderedDict[Tuple, PlacementOracle]" = OrderedDict()
        self._decisions: "OrderedDict[Tuple, ScoredBatch]" = OrderedDict()
        # launch-level layers (EcoSched's memo, relocated here so fleet
        # peers sharing one cache serve each other's *decisions*, not just
        # each other's enumerations — a single node rarely repeats a
        # decision state, but 256 identically-shaped nodes repeat each
        # other's constantly):
        #   * _launches  — raw (order-sensitive) decision state -> final
        #     ((window position, g, f), ...) launch pairs; exact replay.
        #   * _frontiers — canonical (token-sorted) decision state -> the
        #     full argmin tie frontier in canonical-slot form; a permuted
        #     consumer re-breaks the tie in its own enumeration order
        #     (see ecosched._replay_frontier), which is exactly what its
        #     cold argmin would do.
        self._launches: "OrderedDict[Tuple, Tuple]" = OrderedDict()
        self._frontiers: "OrderedDict[Tuple, Tuple]" = OrderedDict()
        # structure interning: each distinct per-job mode structure gets a
        # small int token, so window keys are tuples of ints (fast to hash
        # in the per-event hot path) instead of nested float tuples.  The
        # token table pins its specs so id() stays unique while cached.
        self._spec_tokens: Dict[int, Tuple[JobSpec, int]] = {}
        self._struct_ids: Dict[Tuple, int] = {}
        self.table_hits = self.table_misses = 0
        self.oracle_hits = self.oracle_misses = 0
        self.decision_hits = self.decision_misses = 0

    @staticmethod
    def structure_of(spec: JobSpec) -> Tuple:
        """Name-free mode structure: the (g, f, t_norm, e_norm) tuples —
        everything Eq. (1) scoring and placement can observe of a job.
        ``f`` distinguishes same-count modes at different DVFS levels; it
        is constant 0 on single-frequency specs, so interning behavior
        there is unchanged."""
        return tuple((m.g, m.f, m.t_norm, m.e_norm) for m in spec.modes)

    def spec_token(self, spec: JobSpec) -> int:
        entry = self._spec_tokens.get(id(spec))
        if entry is not None and entry[0] is spec:
            return entry[1]
        if len(self._spec_tokens) >= self.max_structs:
            self._reset_structures()  # bounds noisy-model per-instance growth
        struct = self.structure_of(spec)
        tok = self._struct_ids.setdefault(struct, len(self._struct_ids))
        self._spec_tokens[id(spec)] = (spec, tok)
        return tok

    def _reset_structures(self) -> None:
        """Drop the token tables and every token-keyed store.  Tokens are
        only unique within one epoch, so reusing a stale token-keyed entry
        after a reset could alias two different windows."""
        self._spec_tokens.clear()
        self._struct_ids.clear()
        self._tables.clear()
        self._decisions.clear()
        self._launches.clear()
        self._frontiers.clear()
        self.epoch += 1

    def window_key(self, specs: Sequence[JobSpec]) -> Tuple:
        """Name-free window structure as a tuple of interned tokens."""
        return tuple(self.spec_token(s) for s in specs)

    @staticmethod
    def canonical_order(wkey: Tuple) -> Optional[Tuple[int, ...]]:
        """Stable permutation sorting the window's tokens, or ``None`` when
        the window is already canonical (the overwhelmingly common case —
        repeats of the same window).  Keying decisions on the *sorted*
        tokens lets permuted waiting windows (same jobs, different queue
        order) hit the same cache entry.  A same-order hit shares the
        stored arrays outright; a *permuted* hit re-orders the stored rows
        into the current window's reference enumeration order and re-runs
        the (cheap, vectorized) row reductions in that order — row order
        is load-bearing, because exact score ties break to the earliest
        row, and normalized best modes tie by construction.  Replaying the
        producer's row order verbatim diverged from a cold enumeration on
        exactly those ties.  Stability matters: equal tokens keep their
        relative window order on both sides, so the position bijection
        between producer and consumer windows is well-defined."""
        if all(wkey[i] <= wkey[i + 1] for i in range(len(wkey) - 1)):
            return None
        return tuple(sorted(range(len(wkey)), key=wkey.__getitem__))

    def _get(self, store: OrderedDict, key):
        hit = store.get(key)
        if hit is not None:
            store.move_to_end(key)
        return hit

    def _put(self, store: OrderedDict, key, value, cap: int) -> None:
        store[key] = value
        if len(store) > cap:
            store.popitem(last=False)

    def table(self, key: Tuple, specs: Sequence[JobSpec]) -> Tuple["_SpecTable", bool]:
        """Returns (table, warm): ``warm`` is False on first sight of this
        window structure — callers then prefer the streaming enumeration,
        so one-shot structures never pay for reusable materialization."""
        t = self._get(self._tables, key)
        if t is None:
            self.table_misses += 1
            t = _SpecTable(specs)
            self._put(self._tables, key, t, self.max_tables)
            return t, False
        self.table_hits += 1
        return t, True

    def oracle(
        self, mask: int, units: int, domains: int, occ: Tuple[int, ...]
    ) -> PlacementOracle:
        key = (units, domains, mask, occ)
        o = self._get(self._oracles, key)
        if o is None:
            self.oracle_misses += 1
            o = PlacementOracle.from_mask(mask, units, domains, occ)
            self._put(self._oracles, key, o, self.max_oracles)
        else:
            self.oracle_hits += 1
        return o

    def decision(
        self, key: Tuple
    ) -> Optional[Tuple["ScoredBatch", Optional[Tuple[int, ...]]]]:
        """Stored entries are ``(batch, producer_order)`` pairs — the
        canonical-key permutation the batch was built under (``None`` for
        an already-canonical window); ``enumerate_scored`` needs it to map
        stored row positions onto a permuted hit's window."""
        b = self._get(self._decisions, key)
        if b is None:
            self.decision_misses += 1
        else:
            self.decision_hits += 1
        return b

    def store_decision(
        self,
        key: Tuple,
        entry: Tuple["ScoredBatch", Optional[Tuple[int, ...]]],
    ) -> None:
        self._put(self._decisions, key, entry, self.max_decisions)

    def launch(self, key: Tuple) -> Optional[Tuple]:
        """Raw-key launch replay: the final pair tuple for an exact repeat
        of a decision state (token order included), or None."""
        return self._get(self._launches, key)

    def store_launch(self, key: Tuple, pairs: Tuple) -> None:
        self._put(self._launches, key, pairs, self.max_launches)

    def frontier(self, key: Tuple) -> Optional[Tuple]:
        """Canonical-key tie frontier for a permuted repeat, or None."""
        return self._get(self._frontiers, key)

    def store_frontier(self, key: Tuple, cands: Tuple) -> None:
        self._put(self._frontiers, key, cands, self.max_frontiers)

    def stats(self) -> Dict[str, float]:
        def rate(h, m):
            return h / (h + m) if h + m else 0.0

        return {
            "table_hits": self.table_hits,
            "table_misses": self.table_misses,
            "table_hit_rate": rate(self.table_hits, self.table_misses),
            "oracle_hits": self.oracle_hits,
            "oracle_misses": self.oracle_misses,
            "oracle_hit_rate": rate(self.oracle_hits, self.oracle_misses),
            "decision_hits": self.decision_hits,
            "decision_misses": self.decision_misses,
            "decision_hit_rate": rate(self.decision_hits, self.decision_misses),
            "tables": len(self._tables),
            "oracles": len(self._oracles),
            "decisions": len(self._decisions),
            "launches": len(self._launches),
            "frontiers": len(self._frontiers),
        }


# One enumeration block: actions of a single size s as column arrays.
# (scores, total_g, spread, job_mat (B, s), mode_mat (B, s))
_Block = Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, np.ndarray]


class ScoredBatch:
    """Array-backed scored action set; rows follow the reference order."""

    def __init__(
        self,
        specs: Sequence[JobSpec],
        blocks: List[_Block],
        table: Optional[_SpecTable] = None,
    ):
        self.specs = list(specs)
        self._blocks = blocks
        self._table = table
        # exact-path batches carry the reference row order and can be
        # re-ordered onto a permuted window; beam batches cannot (beam
        # pruning is itself window-order dependent)
        self.exact = True
        self._padded: Optional[Tuple[np.ndarray, ...]] = None
        self._padded_f: Optional[np.ndarray] = None
        self._best_memo: Dict[Tuple[float, bool], Optional[int]] = {}
        self._spread: Optional[np.ndarray] = None
        self._n_jobs: Optional[np.ndarray] = None
        self.scores = np.concatenate([b[0] for b in blocks])
        self.total_g = np.concatenate([b[1] for b in blocks])
        self._starts = np.cumsum([0] + [len(b[0]) for b in blocks])

    def __len__(self) -> int:
        return len(self.scores)

    @property
    def spread(self) -> np.ndarray:
        """Per-candidate load spread (lookahead penalty term); lazy — only
        lookahead-enabled policies ever touch it."""
        if self._spread is None:
            self._spread = np.concatenate([b[2] for b in self._blocks])
        return self._spread

    @property
    def n_jobs(self) -> np.ndarray:
        """Per-candidate action size; lazy — the common path only checks
        row 0 (the empty action is always the first row)."""
        if self._n_jobs is None:
            self._n_jobs = np.concatenate(
                [
                    np.full(len(b[0]), b[3].shape[1], dtype=np.int64)
                    for b in self._blocks
                ]
            )
        return self._n_jobs

    def rebind(self, specs: Sequence[JobSpec]) -> "ScoredBatch":
        """Shallow copy bound to a new window with the identical per-job mode
        structure (names may differ) — a ``DecisionCache`` hit reuses every
        array, only ``action()`` reconstruction sees the new names."""
        clone = copy.copy(self)
        clone.specs = list(specs)
        return clone

    def padded_cols(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Per-candidate slot columns ``(dev, g, n)`` for the jax/Pallas
        score-reduce backend: ``dev``/``g`` are (B, S) float32 padded with
        zeros past each action's size, ``n`` is the action size.  Memoized —
        decision-cache hits reuse the padded arrays too (``rebind`` shares
        them)."""
        if self._padded is None:
            B = len(self.scores)
            S = max((b[3].shape[1] for b in self._blocks), default=0) or 1
            dev = np.zeros((B, S), dtype=np.float32)
            g = np.zeros((B, S), dtype=np.float32)
            for start, blk in zip(self._starts, self._blocks):
                _, _, _, job_mat, mode_mat = blk
                s = job_mat.shape[1]
                if s == 0:
                    continue
                rows = slice(start, start + len(blk[0]))
                dev[rows, :s] = self._table.mode_dev[job_mat, mode_mat]
                g[rows, :s] = self._table.mode_g[job_mat, mode_mat]
            self._padded = (dev, g, self.n_jobs.astype(np.float32))
        return self._padded

    def padded_f(self) -> np.ndarray:
        """Per-candidate slot frequency levels, (B, S) float32 zero-padded —
        the kernel backend's frequency axis.  Kept separate from
        ``padded_cols`` (same memoize-through-``rebind`` behavior) so the
        single-frequency fast path never materializes an all-zero plane
        twice."""
        if self._padded_f is None:
            B = len(self.scores)
            S = max((b[3].shape[1] for b in self._blocks), default=0) or 1
            fcol = np.zeros((B, S), dtype=np.float32)
            for start, blk in zip(self._starts, self._blocks):
                _, _, _, job_mat, mode_mat = blk
                s = job_mat.shape[1]
                if s == 0:
                    continue
                rows = slice(start, start + len(blk[0]))
                fcol[rows, :s] = self._table.mode_f[job_mat, mode_mat]
            self._padded_f = fcol
        return self._padded_f

    def action(self, i: int) -> Tuple[Tuple[JobSpec, ModeEstimate], ...]:
        b = int(np.searchsorted(self._starts, i, side="right")) - 1
        row = i - self._starts[b]
        _, _, _, job_mat, mode_mat = self._blocks[b]
        return tuple(
            (self.specs[j], self.specs[j].modes[k])
            for j, k in zip(job_mat[row], mode_mat[row])
        )

    def row_pairs(self, i: int) -> Tuple[Tuple[int, int], ...]:
        """Name-free form of ``action(i)``: (window position, mode index)
        pairs — what the launch-memo layers store and replay."""
        b = int(np.searchsorted(self._starts, i, side="right")) - 1
        row = i - self._starts[b]
        _, _, _, job_mat, mode_mat = self._blocks[b]
        return tuple(
            (int(j), int(k)) for j, k in zip(job_mat[row], mode_mat[row])
        )

    def to_list(self):
        """Reference-format [(score, action), ...] — for parity tests."""
        return [(float(self.scores[i]), self.action(i)) for i in range(len(self))]

    def best_index(
        self, scores: Optional[np.ndarray] = None, *, nonempty: bool = False
    ) -> Optional[int]:
        """Argmin under the policy's tie-break: lowest score, then largest
        total unit count, then earliest generation order — exactly what a
        stable sort by (score, -total_g) over the reference list picks."""
        sc = self.scores if scores is None else scores
        idxs = np.flatnonzero(self.n_jobs > 0) if nonempty else np.arange(len(sc))
        if idxs.size == 0:
            return None
        sub = sc[idxs]
        tie = idxs[sub == sub.min()]
        return int(tie[np.argmax(self.total_g[tie])])

    def best_cached(
        self, lookahead: float = 0.0, *, nonempty: bool = False
    ) -> Optional[int]:
        """``best_index`` memoized per (lookahead, nonempty): the winner is a
        pure function of the batch arrays, so decision-cache hits (which
        share the memo through ``rebind``) skip the argmin too."""
        key = (lookahead, nonempty)
        if key not in self._best_memo:
            sc = (
                self.scores + lookahead * self.spread
                if lookahead
                else None
            )
            self._best_memo[key] = self.best_index(sc, nonempty=nonempty)
        return self._best_memo[key]


def enumerate_scored(
    specs: Sequence[JobSpec],
    view: NodeView,
    free_map: List[bool],
    *,
    lam: float,
    lam_f: float = 0.0,
    exact_limit: int = 50_000,
    beam: int = 64,
    cache: Optional[DecisionCache] = None,
) -> ScoredBatch:
    """Vectorized twin of ``actions.enumerate_actions`` (same feasible set,
    same scores, same row order).  With ``cache``, repeated decisions —
    same window structure on the same placement state — return the cached
    ``ScoredBatch`` without enumerating anything."""
    specs = list(specs)
    k_avail = view.domains - view.occupied_domains
    g_free = view.free_units
    # degraded nodes (fault plane) score over alive capacity; M is part of
    # the decision key below, so healthy and degraded states never collide
    M = view.alive_units
    if k_avail <= 0 or not specs:
        return ScoredBatch(
            specs,
            [_empty_block(score((), g_free=g_free, M=M, lam=lam, lam_f=lam_f))],
        )
    dkey = None
    order = None
    warm = False
    if cache is not None:
        wkey = cache.window_key(specs)
        mask = _mask_of(free_map)
        occ = tuple(view.domain_jobs) if view.domain_jobs else (0,) * view.domains
        # order-canonical decision key: permuted windows share one entry
        order = cache.canonical_order(wkey)
        ckey = wkey if order is None else tuple(wkey[i] for i in order)
        dkey = (ckey, mask, occ, g_free, M, lam, lam_f, exact_limit, beam)
        hit = cache.decision(dkey)
        if hit is not None:
            batch, st_order = hit
            if st_order == order:
                return batch.rebind(specs)
            reordered = _reorder_hit(
                batch, specs, st_order, order, cache, wkey,
                g_free=g_free, M=M, lam=lam, lam_f=lam_f,
            )
            if reordered is not None:
                return reordered
            # beam batch on a permuted window: fall through to a fresh
            # enumeration (beam row order is window-order dependent)
        table, warm = cache.table(wkey, specs)
        oracle = cache.oracle(mask, len(free_map), view.domains, occ)
    else:
        table = _SpecTable(specs)
        oracle = PlacementOracle(free_map, view.domains, view.domain_jobs)
    empty = _empty_block(score((), g_free=g_free, M=M, lam=lam, lam_f=lam_f))
    est = table.space_estimate(k_avail, exact_limit)
    if est <= exact_limit:
        blocks = _exact_blocks(
            table, oracle, k_avail, g_free, M, lam, lam_f=lam_f, reuse=warm
        )
    else:
        blocks = _beam_blocks(
            table, oracle, k_avail, g_free, M, lam, beam, lam_f=lam_f
        )
    batch = ScoredBatch(specs, [empty] + blocks, table=table)
    batch.exact = est <= exact_limit
    if dkey is not None:
        cache.store_decision(dkey, (batch, order))
    return batch


def _reorder_hit(
    batch: "ScoredBatch",
    specs: Sequence[JobSpec],
    st_order: Optional[Tuple[int, ...]],
    order: Optional[Tuple[int, ...]],
    cache: DecisionCache,
    wkey: Tuple,
    *,
    g_free: int,
    M: int,
    lam: float,
    lam_f: float,
) -> Optional["ScoredBatch"]:
    """Bind a cached batch built from a *permutation* of this window:
    remap its rows into this window's reference enumeration order and
    recompute the row reductions in that order.

    Row order is semantic — exact score ties break to the earliest row,
    and the reference order is a pure function of window order (size-s
    rows sort lexicographically by (ascending position tuple, mode
    tuple)).  Replaying the producer's rows verbatim resolved ties in the
    *producer's* window order, which diverged from a cold enumeration
    whenever two structures tied exactly (normalized best modes all score
    dev=0, so cross-app ties are structural, not accidental).  The
    reductions are also re-run here so float sums accumulate in this
    window's slot order — everything downstream is bit-identical to a
    fresh enumeration, at the cost of one gather per block.

    Canonical slot ``c`` holds the stored window's position
    ``st_order[c]`` and this window's position ``order[c]`` — both carry
    the same token, so the position bijection is pure.  Returns None for
    beam batches, whose row set itself depends on window order."""
    if not batch.exact:
        return None
    J = len(specs)
    cur = order if order is not None else tuple(range(J))
    st = st_order if st_order is not None else tuple(range(J))
    pi = np.empty(J, dtype=np.int64)
    for c in range(J):
        pi[st[c]] = cur[c]
    table, _ = cache.table(wkey, specs)
    blocks: List[_Block] = []
    for blk in batch._blocks:
        scores, tot, spread, job_mat, mode_mat = blk
        s = job_mat.shape[1]
        if s == 0:
            blocks.append(blk)  # the empty action: state-only, order-free
            continue
        cpos = pi[job_mat]
        within = np.argsort(cpos, axis=1, kind="stable")
        cpos = np.take_along_axis(cpos, within, axis=1)
        cmode = np.take_along_axis(mode_mat, within, axis=1)
        # reference order = lex by (position tuple, mode tuple), most
        # significant first; np.lexsort takes least-significant first
        keys = tuple(cmode[:, k] for k in range(s - 1, -1, -1)) + tuple(
            cpos[:, k] for k in range(s - 1, -1, -1)
        )
        perm = np.lexsort(keys)
        job_mat = cpos[perm]
        mode_mat = cmode[perm]
        dev = table.mode_dev[job_mat, mode_mat]
        tot2 = table.mode_g[job_mat, mode_mat].sum(axis=1)
        sc = dev.sum(axis=1) / s + lam * ((g_free - tot2) / M)
        if lam_f:
            sc = sc + lam_f * (
                table.mode_f[job_mat, mode_mat].sum(axis=1) / s
            )
        loads = table.mode_load[job_mat, mode_mat]
        spread2 = _spread(loads.max(axis=1), loads.min(axis=1), s)
        blocks.append((sc, tot2, spread2, job_mat, mode_mat))
    return ScoredBatch(specs, blocks, table=table)


def _empty_block(empty_score: float) -> _Block:
    return (
        np.array([empty_score]),
        np.zeros(1, dtype=np.int64),
        np.zeros(1),
        np.zeros((1, 0), dtype=np.int64),
        np.zeros((1, 0), dtype=np.int64),
    )


def _placeable_rows(oracle: PlacementOracle, counts: np.ndarray) -> np.ndarray:
    """Feasibility mask for a (B, s) count matrix.

    Feasibility depends only on the count *multiset*, so rows are encoded
    as one base-(units+1) integer each and the oracle runs once per
    distinct code — thousands of candidates share a handful of multisets.
    """
    counts_desc = -np.sort(-counts, axis=1)
    base = oracle.units + 1
    weights = base ** np.arange(counts_desc.shape[1], dtype=np.int64)
    codes = counts_desc @ weights
    uniq, first, inv = np.unique(codes, return_index=True, return_inverse=True)
    uok = np.fromiter(
        (
            oracle.placeable(tuple(int(g) for g in counts_desc[i]))
            for i in first
        ),
        dtype=bool,
        count=len(first),
    )
    return uok[inv]


def _spread(lmax: np.ndarray, lmin: np.ndarray, size: int) -> np.ndarray:
    """Completion-alignment proxy (EcoSched lookahead): load spread."""
    if size < 2:
        return np.zeros(len(lmax))
    return (lmax - lmin) / np.maximum(lmax, 1e-9)


def _exact_blocks(
    table: _SpecTable,
    oracle: PlacementOracle,
    k_avail: int,
    g_free: int,
    M: int,
    lam: float,
    *,
    lam_f: float = 0.0,
    reuse: bool = False,
) -> List[_Block]:
    """Exact path.  ``reuse=False`` (one-shot tables) streams the candidate
    grid chunk-by-chunk with the capacity filter applied inline — nothing
    larger than a chunk materializes.  ``reuse=True`` (cached tables)
    slices the table's memoized full enumeration instead: on a table-cache
    hit the combinatorial construction is gone and per event only the
    capacity mask, the (memoized) placement verdicts and two vector
    expressions remain.  Both produce the identical block row order."""
    if reuse:
        return _exact_blocks_cached(
            table, oracle, k_avail, g_free, M, lam, lam_f=lam_f
        )
    J = len(table.specs)
    mm = table.max_modes
    out: List[_Block] = []
    for s in range(1, min(k_avail, J) + 1):
        combos = np.array(
            list(itertools.combinations(range(J), s)), dtype=np.int64
        )  # (C, s) in reference order
        # (P, s) padded mode-index grid, last index fastest = product order
        grid = np.indices((mm,) * s).reshape(s, -1).T
        P = len(grid)
        chunk = max(1, _CHUNK_ELEMS // max(P * s, 1))
        parts: List[Tuple[np.ndarray, ...]] = []
        for c0 in range(0, len(combos), chunk):
            cs = combos[c0 : c0 + chunk]
            jm = cs[:, None, :]  # (c, 1, s)
            gb = grid[None, :, :]  # (1, P, s)
            valid = (gb < table.mode_count[jm]).all(axis=2)  # (c, P)
            g = table.mode_g[jm, gb]  # (c, P, s)
            tot = g.sum(axis=2)
            ok = valid & (tot <= g_free)
            ci, pi = np.nonzero(ok)  # row-major == combo-major, product-minor
            if ci.size == 0:
                continue
            parts.append((cs[ci], grid[pi], g[ci, pi]))
        if not parts:
            continue
        job_mat = np.concatenate([p[0] for p in parts])
        mode_mat = np.concatenate([p[1] for p in parts])
        counts = np.concatenate([p[2] for p in parts])
        keep = _placeable_rows(oracle, counts)
        if not keep.any():
            continue
        job_mat, mode_mat, counts = job_mat[keep], mode_mat[keep], counts[keep]
        dev = table.mode_dev[job_mat, mode_mat]
        loads = table.mode_load[job_mat, mode_mat]
        tot = counts.sum(axis=1)
        scores = dev.sum(axis=1) / s + lam * ((g_free - tot) / M)
        if lam_f:
            scores = scores + lam_f * (
                table.mode_f[job_mat, mode_mat].sum(axis=1) / s
            )
        spread = _spread(loads.max(axis=1), loads.min(axis=1), s)
        out.append((scores, tot, spread, job_mat, mode_mat))
    return out


def _exact_blocks_cached(
    table: _SpecTable,
    oracle: PlacementOracle,
    k_avail: int,
    g_free: int,
    M: int,
    lam: float,
    *,
    lam_f: float = 0.0,
) -> List[_Block]:
    J = len(table.specs)
    out: List[_Block] = []
    for s in range(1, min(k_avail, J) + 1):
        cap = table.capacity(s, g_free)
        if cap is None:
            continue
        job_mat, mode_mat, counts, tot, dev_sum, lmax, lmin, multisets, inv = cap
        uok = np.fromiter(
            (oracle.placeable(ms) for ms in multisets),
            dtype=bool,
            count=len(multisets),
        )
        keep = uok[inv]
        if not keep.any():
            continue
        job_mat, mode_mat = job_mat[keep], mode_mat[keep]
        tot_k = tot[keep]
        scores = dev_sum[keep] / s + lam * ((g_free - tot_k) / M)
        if lam_f:
            scores = scores + lam_f * (
                table.mode_f[job_mat, mode_mat].sum(axis=1) / s
            )
        spread = _spread(lmax[keep], lmin[keep], s)
        out.append((scores, tot_k, spread, job_mat, mode_mat))
    return out


def _beam_blocks(
    table: _SpecTable,
    oracle: PlacementOracle,
    k_avail: int,
    g_free: int,
    M: int,
    lam: float,
    beam: int,
    *,
    lam_f: float = 0.0,
) -> List[_Block]:
    J = len(table.specs)
    out: List[_Block] = []
    # A partial action's identity is its {(job, g, f)} set.  Encode each
    # member as (job·(maxg+1)+g)·(maxf+1)+f and the whole set as a base-B
    # little-endian integer over members in ascending order — order-free
    # and injective, so set equality becomes int64 equality and the dedupe
    # vectorizes.  Single-frequency windows have maxf = 0, collapsing the
    # member code and base to the historical job·(maxg+1)+g encoding.
    maxg = int(table.pair_g.max()) if len(table.pair_g) else 0
    maxf = int(table.pair_f.max()) if len(table.pair_f) else 0
    B = J * (maxg + 1) * (maxf + 1) + 1
    if float(B) ** k_avail >= 2**62:  # never at pod scale (17·17 base, K=4)
        raise OverflowError(
            f"action-set key space {B}^{k_avail} overflows int64; "
            "use the pure-Python reference path for windows this large"
        )
    pair_code = (
        table.pair_job * (maxg + 1) + table.pair_g
    ) * (maxf + 1) + table.pair_f
    # frontier = the single empty partial
    f_jobs = np.zeros((1, 0), dtype=np.int64)
    f_modes = np.zeros((1, 0), dtype=np.int64)
    f_counts = np.zeros((1, 0), dtype=np.int64)  # rows sorted descending
    f_codes = np.zeros((1, 0), dtype=np.int64)  # member codes, ascending
    f_dev = np.zeros(1)  # running Σ(e_norm-1) in extension order
    f_g = np.zeros(1, dtype=np.int64)
    f_fs = np.zeros(1, dtype=np.int64)  # running Σ frequency level
    f_lmax = np.full(1, -np.inf)
    f_lmin = np.full(1, np.inf)
    f_used = np.zeros((1, J), dtype=bool)
    for size in range(1, k_avail + 1):
        used = f_used[:, table.pair_job]  # (F, P)
        new_g = f_g[:, None] + table.pair_g[None, :]
        ok = ~used & (new_g <= g_free)
        fi, pi = np.nonzero(ok)  # frontier-major == reference iteration order
        if fi.size == 0:
            break
        # dedupe by {(job, g)} set, keep-first in iteration order: the same
        # action reached through different extension orders must occupy one
        # beam slot, not many.  Key = parent digits with the new member
        # code inserted at its sorted position.
        codes = f_codes[fi]  # (N, size-1), ascending member codes
        add = pair_code[pi]
        w = B ** np.arange(size - 1, dtype=np.int64)
        less = codes < add[:, None]
        low = (codes * w * less).sum(axis=1)
        high = (codes * w * ~less).sum(axis=1) * B
        keys = low + add * B ** less.sum(axis=1) + high
        _, first = np.unique(keys, return_index=True)
        kept = np.sort(first)  # back to generation order
        fi, pi = fi[kept], pi[kept]
        counts = np.concatenate([f_counts[fi], table.pair_g[pi][:, None]], axis=1)
        keep = _placeable_rows(oracle, counts)
        if not keep.any():
            break
        fi, pi, counts = fi[keep], pi[keep], counts[keep]
        pj, pg = table.pair_job, table.pair_g
        scores = (f_dev[fi] + table.pair_dev[pi]) / size + lam * (
            (g_free - (f_g[fi] + pg[pi])) / M
        )
        if lam_f:
            scores = scores + lam_f * ((f_fs[fi] + table.pair_f[pi]) / size)
        # stable top-k by score: ties keep generation order, like the
        # reference's stable list sort
        sel = np.argsort(scores, kind="stable")[:beam]
        fsel, psel = fi[sel], pi[sel]
        f_jobs = np.concatenate([f_jobs[fsel], pj[psel][:, None]], axis=1)
        f_modes = np.concatenate(
            [f_modes[fsel], table.pair_mode[psel][:, None]], axis=1
        )
        f_counts = -np.sort(-counts[sel], axis=1)
        f_codes = np.sort(
            np.concatenate([f_codes[fsel], pair_code[psel][:, None]], axis=1),
            axis=1,
        )
        f_dev = f_dev[fsel] + table.pair_dev[psel]
        f_g = f_g[fsel] + pg[psel]
        f_fs = f_fs[fsel] + table.pair_f[psel]
        f_lmax = np.maximum(f_lmax[fsel], table.pair_load[psel])
        f_lmin = np.minimum(f_lmin[fsel], table.pair_load[psel])
        f_used = f_used[fsel].copy()
        f_used[np.arange(len(fsel)), pj[psel]] = True
        out.append(
            (
                scores[sel],
                f_g.copy(),
                _spread(f_lmax, f_lmin, size),
                f_jobs.copy(),
                f_modes.copy(),
            )
        )
    return out

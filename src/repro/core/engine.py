"""Vectorized pod-scale scoring engine (ROADMAP: Perf).

``repro.core.actions.enumerate_actions`` is the pure-Python reference for
the paper's Phase-II decision (§III-C): enumerate feasible joint actions,
score each with Eq. (1), pick the argmin.  At the paper's node scale
(M=4, K=2) it is cheap; at pod scale (M=16, K=4, 17-job windows) its
per-candidate ``score()`` call and first-fit replay dominate decision
time.  This module reimplements both the exact and the beam path as
batched numpy computation:

  * a scheduling window becomes a ``_SpecTable`` of per-(job, mode)
    columns (unit counts, ``e_norm`` deviations, ``t_norm·g`` loads),
  * Eq. (1) scores for whole candidate batches are one vector expression,
  * placement feasibility replays the simulator's domain-spreading
    first-fit on an *integer bitmask* of the free map (shift/AND finds
    every contiguous run), memoized per count-multiset — thousands of
    candidates share a handful of multisets,
  * beam rounds become batched extend → dedupe → score → stable top-k.

The engine is parity-locked against the reference: identical candidate
order, identical argmin action, scores within 1e-9 (tests/test_engine.py
property-checks this over seeded random node states).  ``EcoSched``
consumes it through ``enumerate_scored`` + ``ScoredBatch.best_index`` so
the argmin never materializes Python tuples for the full action space.
"""
from __future__ import annotations

import itertools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.actions import _space_estimate
from repro.core.score import score
from repro.core.types import JobSpec, ModeEstimate, NodeView

# Cap on elements per vectorized exact-path chunk; bounds peak memory when
# padded mode grids are much larger than the true action space.
_CHUNK_ELEMS = 2_000_000


class PlacementOracle:
    """Memoized bitmask replay of ``PlacementState.allocate``.

    The free map is one integer (bit u set = unit u free); the feasible
    starts for a g-unit job are the set bits of ``m = mask & mask>>1 &
    ... & mask>>(g-1)``.  Start selection replicates the simulator's
    domain-spreading first-fit exactly: among feasible starts, minimize
    (home-domain occupancy, start) where the home domain is the
    least-occupied domain the range overlaps.  Feasibility of an action
    depends only on its count multiset, so verdicts are memoized per
    descending count tuple.
    """

    def __init__(
        self,
        free_map: Sequence[bool],
        domains: int,
        domain_jobs: Optional[Sequence[int]] = None,
    ):
        self.units = len(free_map)
        self.domains = domains
        self.mask0 = 0
        for u, f in enumerate(free_map):
            if f:
                self.mask0 |= 1 << u
        self.occ0 = tuple(domain_jobs) if domain_jobs else (0,) * domains
        self._dom = [u * domains // self.units for u in range(self.units)]
        self._memo: Dict[Tuple[int, ...], bool] = {}

    def placeable(self, counts_desc: Tuple[int, ...]) -> bool:
        hit = self._memo.get(counts_desc)
        if hit is not None:
            return hit
        mask = self.mask0
        occ = list(self.occ0)
        ok = True
        for g in counts_desc:
            mask = self._alloc(mask, occ, g)
            if mask is None:
                ok = False
                break
        self._memo[counts_desc] = ok
        return ok

    def _alloc(self, mask: int, occ: List[int], g: int) -> Optional[int]:
        m = mask
        for i in range(1, g):
            m &= mask >> i
        if not m:
            return None
        best = None  # ((home occupancy, start), start, home)
        while m:
            s = (m & -m).bit_length() - 1
            d_lo = self._dom[s]
            d_hi = self._dom[s + g - 1]
            home = min(range(d_lo, d_hi + 1), key=lambda d: (occ[d], d))
            key = (occ[home], s)
            if best is None or key < best[0]:
                best = (key, s, home)
            if occ[home] == 0:
                break  # starts ascend: (0, s) is unbeatable
            m &= m - 1
        _, s, home = best
        occ[home] += 1
        return mask & ~(((1 << g) - 1) << s)


class _SpecTable:
    """Column-oriented view of one scheduling window's τ-filtered specs."""

    def __init__(self, specs: Sequence[JobSpec]):
        self.specs = list(specs)
        J = len(self.specs)
        n_modes = [len(s.modes) for s in self.specs]
        self.mode_count = np.asarray(n_modes, dtype=np.int64)
        mm = max(n_modes) if J else 0
        self.max_modes = mm
        self.mode_g = np.zeros((J, mm), dtype=np.int64)
        self.mode_dev = np.zeros((J, mm))  # e_norm - 1
        self.mode_load = np.zeros((J, mm))  # t_norm * g (lookahead proxy)
        for j, s in enumerate(self.specs):
            for k, m in enumerate(s.modes):
                self.mode_g[j, k] = m.g
                self.mode_dev[j, k] = m.e_norm - 1.0
                self.mode_load[j, k] = m.t_norm * m.g
        # flattened (job, mode) pairs, job-major/mode-minor — the reference
        # path's iteration order
        self.pair_job = np.repeat(np.arange(J, dtype=np.int64), n_modes)
        self.pair_mode = (
            np.concatenate([np.arange(n, dtype=np.int64) for n in n_modes])
            if J
            else np.zeros(0, dtype=np.int64)
        )
        self.pair_g = self.mode_g[self.pair_job, self.pair_mode]
        self.pair_dev = self.mode_dev[self.pair_job, self.pair_mode]
        self.pair_load = self.mode_load[self.pair_job, self.pair_mode]


# One enumeration block: actions of a single size s as column arrays.
# (scores, total_g, spread, job_mat (B, s), mode_mat (B, s))
_Block = Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, np.ndarray]


class ScoredBatch:
    """Array-backed scored action set; rows follow the reference order."""

    def __init__(self, specs: Sequence[JobSpec], blocks: List[_Block]):
        self.specs = list(specs)
        self._blocks = blocks
        self.scores = np.concatenate([b[0] for b in blocks])
        self.total_g = np.concatenate([b[1] for b in blocks])
        self.spread = np.concatenate([b[2] for b in blocks])
        self.n_jobs = np.concatenate(
            [np.full(len(b[0]), b[3].shape[1], dtype=np.int64) for b in blocks]
        )
        self._starts = np.cumsum([0] + [len(b[0]) for b in blocks])

    def __len__(self) -> int:
        return len(self.scores)

    def action(self, i: int) -> Tuple[Tuple[JobSpec, ModeEstimate], ...]:
        b = int(np.searchsorted(self._starts, i, side="right")) - 1
        row = i - self._starts[b]
        _, _, _, job_mat, mode_mat = self._blocks[b]
        return tuple(
            (self.specs[j], self.specs[j].modes[k])
            for j, k in zip(job_mat[row], mode_mat[row])
        )

    def to_list(self):
        """Reference-format [(score, action), ...] — for parity tests."""
        return [(float(self.scores[i]), self.action(i)) for i in range(len(self))]

    def best_index(
        self, scores: Optional[np.ndarray] = None, *, nonempty: bool = False
    ) -> Optional[int]:
        """Argmin under the policy's tie-break: lowest score, then largest
        total unit count, then earliest generation order — exactly what a
        stable sort by (score, -total_g) over the reference list picks."""
        sc = self.scores if scores is None else scores
        idxs = np.flatnonzero(self.n_jobs > 0) if nonempty else np.arange(len(sc))
        if idxs.size == 0:
            return None
        sub = sc[idxs]
        tie = idxs[sub == sub.min()]
        return int(tie[np.argmax(self.total_g[tie])])


def enumerate_scored(
    specs: Sequence[JobSpec],
    view: NodeView,
    free_map: List[bool],
    *,
    lam: float,
    exact_limit: int = 50_000,
    beam: int = 64,
) -> ScoredBatch:
    """Vectorized twin of ``actions.enumerate_actions`` (same feasible set,
    same scores, same row order)."""
    specs = list(specs)
    k_avail = view.domains - view.occupied_domains
    g_free = view.free_units
    M = view.total_units
    empty = _empty_block(score((), g_free=g_free, M=M, lam=lam))
    if k_avail <= 0 or not specs:
        return ScoredBatch(specs, [empty])
    table = _SpecTable(specs)
    oracle = PlacementOracle(free_map, view.domains, view.domain_jobs)
    est = _space_estimate([len(s.modes) for s in specs], k_avail, exact_limit)
    if est <= exact_limit:
        blocks = _exact_blocks(table, oracle, k_avail, g_free, M, lam)
    else:
        blocks = _beam_blocks(table, oracle, k_avail, g_free, M, lam, beam)
    return ScoredBatch(specs, [empty] + blocks)


def _empty_block(empty_score: float) -> _Block:
    return (
        np.array([empty_score]),
        np.zeros(1, dtype=np.int64),
        np.zeros(1),
        np.zeros((1, 0), dtype=np.int64),
        np.zeros((1, 0), dtype=np.int64),
    )


def _placeable_rows(oracle: PlacementOracle, counts: np.ndarray) -> np.ndarray:
    """Feasibility mask for a (B, s) count matrix.

    Feasibility depends only on the count *multiset*, so rows are encoded
    as one base-(units+1) integer each and the oracle runs once per
    distinct code — thousands of candidates share a handful of multisets.
    """
    counts_desc = -np.sort(-counts, axis=1)
    base = oracle.units + 1
    weights = base ** np.arange(counts_desc.shape[1], dtype=np.int64)
    codes = counts_desc @ weights
    uniq, first, inv = np.unique(codes, return_index=True, return_inverse=True)
    uok = np.fromiter(
        (
            oracle.placeable(tuple(int(g) for g in counts_desc[i]))
            for i in first
        ),
        dtype=bool,
        count=len(first),
    )
    return uok[inv]


def _spread(lmax: np.ndarray, lmin: np.ndarray, size: int) -> np.ndarray:
    """Completion-alignment proxy (EcoSched lookahead): load spread."""
    if size < 2:
        return np.zeros(len(lmax))
    return (lmax - lmin) / np.maximum(lmax, 1e-9)


def _exact_blocks(
    table: _SpecTable,
    oracle: PlacementOracle,
    k_avail: int,
    g_free: int,
    M: int,
    lam: float,
) -> List[_Block]:
    J = len(table.specs)
    mm = table.max_modes
    out: List[_Block] = []
    for s in range(1, min(k_avail, J) + 1):
        combos = np.array(
            list(itertools.combinations(range(J), s)), dtype=np.int64
        )  # (C, s) in reference order
        # (P, s) padded mode-index grid, last index fastest = product order
        grid = np.indices((mm,) * s).reshape(s, -1).T
        P = len(grid)
        chunk = max(1, _CHUNK_ELEMS // max(P * s, 1))
        parts: List[Tuple[np.ndarray, ...]] = []
        for c0 in range(0, len(combos), chunk):
            cs = combos[c0 : c0 + chunk]
            jm = cs[:, None, :]  # (c, 1, s)
            gb = grid[None, :, :]  # (1, P, s)
            valid = (gb < table.mode_count[jm]).all(axis=2)  # (c, P)
            g = table.mode_g[jm, gb]  # (c, P, s)
            tot = g.sum(axis=2)
            ok = valid & (tot <= g_free)
            ci, pi = np.nonzero(ok)  # row-major == combo-major, product-minor
            if ci.size == 0:
                continue
            parts.append((cs[ci], grid[pi], g[ci, pi]))
        if not parts:
            continue
        job_mat = np.concatenate([p[0] for p in parts])
        mode_mat = np.concatenate([p[1] for p in parts])
        counts = np.concatenate([p[2] for p in parts])
        keep = _placeable_rows(oracle, counts)
        if not keep.any():
            continue
        job_mat, mode_mat, counts = job_mat[keep], mode_mat[keep], counts[keep]
        dev = table.mode_dev[job_mat, mode_mat]
        loads = table.mode_load[job_mat, mode_mat]
        tot = counts.sum(axis=1)
        scores = dev.sum(axis=1) / s + lam * ((g_free - tot) / M)
        spread = _spread(loads.max(axis=1), loads.min(axis=1), s)
        out.append((scores, tot, spread, job_mat, mode_mat))
    return out


def _beam_blocks(
    table: _SpecTable,
    oracle: PlacementOracle,
    k_avail: int,
    g_free: int,
    M: int,
    lam: float,
    beam: int,
) -> List[_Block]:
    J = len(table.specs)
    out: List[_Block] = []
    # A partial action's identity is its {(job, g)} set.  Encode each
    # member as job·(maxg+1)+g and the whole set as a base-B little-endian
    # integer over members in ascending order — order-free and injective,
    # so set equality becomes int64 equality and the dedupe vectorizes.
    maxg = int(table.pair_g.max()) if len(table.pair_g) else 0
    B = J * (maxg + 1) + 1
    if float(B) ** k_avail >= 2**62:  # never at pod scale (17·17 base, K=4)
        raise OverflowError(
            f"action-set key space {B}^{k_avail} overflows int64; "
            "use the pure-Python reference path for windows this large"
        )
    pair_code = table.pair_job * (maxg + 1) + table.pair_g
    # frontier = the single empty partial
    f_jobs = np.zeros((1, 0), dtype=np.int64)
    f_modes = np.zeros((1, 0), dtype=np.int64)
    f_counts = np.zeros((1, 0), dtype=np.int64)  # rows sorted descending
    f_codes = np.zeros((1, 0), dtype=np.int64)  # member codes, ascending
    f_dev = np.zeros(1)  # running Σ(e_norm-1) in extension order
    f_g = np.zeros(1, dtype=np.int64)
    f_lmax = np.full(1, -np.inf)
    f_lmin = np.full(1, np.inf)
    f_used = np.zeros((1, J), dtype=bool)
    for size in range(1, k_avail + 1):
        used = f_used[:, table.pair_job]  # (F, P)
        new_g = f_g[:, None] + table.pair_g[None, :]
        ok = ~used & (new_g <= g_free)
        fi, pi = np.nonzero(ok)  # frontier-major == reference iteration order
        if fi.size == 0:
            break
        # dedupe by {(job, g)} set, keep-first in iteration order: the same
        # action reached through different extension orders must occupy one
        # beam slot, not many.  Key = parent digits with the new member
        # code inserted at its sorted position.
        codes = f_codes[fi]  # (N, size-1), ascending member codes
        add = pair_code[pi]
        w = B ** np.arange(size - 1, dtype=np.int64)
        less = codes < add[:, None]
        low = (codes * w * less).sum(axis=1)
        high = (codes * w * ~less).sum(axis=1) * B
        keys = low + add * B ** less.sum(axis=1) + high
        _, first = np.unique(keys, return_index=True)
        kept = np.sort(first)  # back to generation order
        fi, pi = fi[kept], pi[kept]
        counts = np.concatenate([f_counts[fi], table.pair_g[pi][:, None]], axis=1)
        keep = _placeable_rows(oracle, counts)
        if not keep.any():
            break
        fi, pi, counts = fi[keep], pi[keep], counts[keep]
        pj, pg = table.pair_job, table.pair_g
        scores = (f_dev[fi] + table.pair_dev[pi]) / size + lam * (
            (g_free - (f_g[fi] + pg[pi])) / M
        )
        # stable top-k by score: ties keep generation order, like the
        # reference's stable list sort
        sel = np.argsort(scores, kind="stable")[:beam]
        fsel, psel = fi[sel], pi[sel]
        f_jobs = np.concatenate([f_jobs[fsel], pj[psel][:, None]], axis=1)
        f_modes = np.concatenate(
            [f_modes[fsel], table.pair_mode[psel][:, None]], axis=1
        )
        f_counts = -np.sort(-counts[sel], axis=1)
        f_codes = np.sort(
            np.concatenate([f_codes[fsel], pair_code[psel][:, None]], axis=1),
            axis=1,
        )
        f_dev = f_dev[fsel] + table.pair_dev[psel]
        f_g = f_g[fsel] + pg[psel]
        f_lmax = np.maximum(f_lmax[fsel], table.pair_load[psel])
        f_lmin = np.minimum(f_lmin[fsel], table.pair_load[psel])
        f_used = f_used[fsel].copy()
        f_used[np.arange(len(fsel)), pj[psel]] = True
        out.append(
            (
                scores[sel],
                f_g.copy(),
                _spread(f_lmax, f_lmin, size),
                f_jobs.copy(),
                f_modes.copy(),
            )
        )
    return out

"""Baseline policies (paper §IV).

* ``sequential_max_gpu``      — each job runs alone with all M units.
* ``sequential_optimal_gpu``  — each job runs alone at its
  performance-optimal count (known offline, as in the paper's setup).
* ``marble``                  — Marble-style co-scheduling [9]: offline
  profiles, every job pinned at its performance-optimal GPU count, FCFS
  first-fit packing under the same domain cap; no energy-aware
  downsizing, no τ-filter.  This reproduces the paper's characterization
  ("assumes performance-oriented GPU counts").

All baselines clamp mode choices to the node's unit count, so they run
unchanged on heterogeneous cluster nodes (``repro.core.cluster``) whose
sizes may not cover every profiled mode.

Baselines run on the same event-queue substrate as EcoSched
(``repro.core.events``) but are deliberately **non-elastic**: they never
propose GPU resizing (``propose_resizes`` returns nothing), exactly as the
papers they reproduce commit a count at launch.  Cluster-level migration
still applies to them — it is a dispatcher capability, not a policy one.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

from repro.core.types import JobProfile, Launch, NodeView


class NonElasticPolicy:
    """Explicit opt-out of the substrate's resize hook: fixed-count
    policies keep their launch-time GPU counts for the job's lifetime."""

    def propose_resizes(self, view: NodeView, *, frac_of, cfg) -> List[Launch]:
        return []


class SequentialMax(NonElasticPolicy):
    def __init__(self, truth: Dict[str, JobProfile]):
        self.truth = truth

    def name(self) -> str:
        return "sequential_max_gpu"

    def on_event(self, view: NodeView, waiting: Sequence[str]) -> List[Launch]:
        if view.running or not waiting:
            return []
        job = waiting[0]
        fits = [g for g in self.truth[job].feasible_counts if g <= view.alive_units]
        if not fits:
            if view.dead_units:
                return []  # degraded node: wait for repair
            raise ValueError(f"{job}: no feasible mode fits {view.total_units} units")
        return [Launch(job=job, g=max(fits))]


class SequentialOptimal(NonElasticPolicy):
    def __init__(self, truth: Dict[str, JobProfile]):
        self.truth = truth

    def name(self) -> str:
        return "sequential_optimal_gpu"

    def on_event(self, view: NodeView, waiting: Sequence[str]) -> List[Launch]:
        if view.running or not waiting:
            return []
        job = waiting[0]
        if view.dead_units and not any(
            g <= view.alive_units for g in self.truth[job].feasible_counts
        ):
            return []  # degraded node: wait for repair
        return [Launch(job=job, g=self.truth[job].optimal_count(view.alive_units))]


class Marble(NonElasticPolicy):
    def __init__(self, truth: Dict[str, JobProfile]):
        self.truth = truth

    def name(self) -> str:
        return "marble"

    def on_event(self, view: NodeView, waiting: Sequence[str]) -> List[Launch]:
        out: List[Launch] = []
        free = view.free_units
        slots = view.free_domains
        # FCFS first-fit at performance-optimal counts; replay on the real
        # domain state so launches land exactly where the simulator's
        # domain-spreading allocator will place them
        from repro.core.placement import PlacementState

        st = PlacementState(view.total_units, view.domains)
        st.free = list(view.free_map)
        if view.domain_jobs:
            st.domain_jobs = list(view.domain_jobs)
        for job in waiting:
            if slots - len(out) <= 0:
                break
            if not any(
                g <= view.alive_units for g in self.truth[job].feasible_counts
            ):
                continue  # no mode fits the (possibly degraded) capacity
            g = self.truth[job].optimal_count(view.alive_units)
            if g <= free and st.can_allocate(g):
                st.allocate(g)
                out.append(Launch(job=job, g=g))
                free -= g
        return out

"""Offline Oracle: exact branch-and-bound energy minimization (paper §IV).

The paper builds the oracle with CP-SAT over discretized time; OR-Tools is
not available offline, so we solve the same offline problem — each job
picks one ⟨count, placement⟩ mode; minimize active + idle-GPU energy to
completion under capacity/domain/contiguity constraints, with perfect
runtime/power knowledge — by depth-first branch-and-bound over
*non-delay* event-driven schedules:

  state   = (waiting multiset, running set with end times, free map, t,
             accumulated busy/idle energy)
  branch  = every feasible launch-set at the event (incl. "wait" when
            something is running)
  bound   = busy-so-far + idle-so-far + Σ_waiting min-mode busy energy
            (admissible: remaining idle ≥ 0, busy ≥ per-job minimum)

Exact for the window sizes the paper evaluates on a 4-unit node; a time
budget makes it anytime for bigger instances (best incumbent returned,
``exact`` flag in the result notes whether the search completed).
Restricting to non-delay schedules is the one approximation vs. a full
time-indexed CP model; with idle power > 0 delaying is never beneficial
unless it enables a denser future packing, which the λ-style branching
below still explores through "wait" branches.
"""
from __future__ import annotations

import heapq
import itertools
import math
import time as _time
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.placement import PlacementState
from repro.core.types import JobProfile, JobRecord, Launch, NodeView, ScheduleResult


def cluster_oracle_bound(specs, truth_for, stream) -> Dict[str, float]:
    """Greedy perfect-knowledge lower bounds for one cluster run (ISSUE 4).

    The single-node branch-and-bound above cannot scale to trace-driven
    clusters, so the cluster bound relaxes instead of searching: every job
    greedily takes its best ⟨node type, count⟩ with zero waiting and the
    cluster is treated as one pooled capacity.

      * ``energy_lb``   — Σ_j min over feasible (node, g) of busy energy;
        idle energy ≥ 0, so this bounds total energy below.
      * ``makespan_lb`` — max over arrivals i of
        t_i + (Σ_{j: t_j ≥ t_i} min-work_j) / Σ_n units_n   (work submitted
        at or after t_i cannot start earlier and must fit the pooled
        capacity), and t_i + fastest-runtime_i (a job cannot beat its own
        best solo time on the best hardware).
      * ``edp_lb``      — their product (both factors are lower bounds).

    Valid for any dispatcher/per-node policy, elastic or not: preemption
    and migration only ever *add* work (checkpoint + restart overheads).
    Reported alongside the elastic sweep in ``benchmarks/bench_elastic.py``.

    ``specs``: ``NodeSpec``-like objects (``name``/``units``);
    ``truth_for(spec)``: app-keyed ``JobProfile`` table on that hardware;
    ``stream``: ``Arrival``s.
    """
    specs = list(specs)
    app_truth = {s.name: truth_for(s) for s in specs}
    total_units = float(sum(s.units for s in specs))
    best: Dict[str, Tuple[float, float, float]] = {}  # app -> (e, work, t)
    rows: List[Tuple[float, float, float, float]] = []
    for a in sorted(stream, key=lambda a: a.t):
        hit = best.get(a.app)
        if hit is None:
            e_b = w_b = t_b = math.inf
            for s in specs:
                prof = app_truth[s.name].get(a.app)
                if prof is None:
                    continue
                for g in prof.feasible_counts:
                    if g > s.units:
                        continue
                    e_b = min(e_b, prof.energy(g))
                    w_b = min(w_b, prof.runtime[g] * g)
                    t_b = min(t_b, prof.runtime[g])
            if not math.isfinite(e_b):
                raise ValueError(f"no node can fit any feasible mode of {a.app}")
            hit = best[a.app] = (e_b, w_b, t_b)
        rows.append((a.t, *hit))
    energy_lb = sum(e for _, e, _, _ in rows)
    makespan_lb = 0.0
    suffix_work = 0.0
    for t, _, work, t_solo in reversed(rows):
        suffix_work += work
        makespan_lb = max(
            makespan_lb, t + suffix_work / total_units, t + t_solo
        )
    return {
        "energy_lb": energy_lb,
        "makespan_lb": makespan_lb,
        "edp_lb": energy_lb * makespan_lb,
    }


class OracleSolver:
    def __init__(
        self,
        node,
        truth: Dict[str, JobProfile],
        *,
        time_budget_s: float = 20.0,
        max_branch: int = 256,
    ):
        self.node = node
        self.truth = truth
        self.time_budget_s = time_budget_s
        self.max_branch = max_branch

    # ------------------------------------------------------------------
    def solve(self, queue: Sequence[str]) -> Tuple[ScheduleResult, bool]:
        t_start = _time.perf_counter()
        truth = self.truth
        node = self.node
        min_busy = {j: min(truth[j].energy(g) for g in truth[j].runtime) for j in queue}

        best = {"total": float("inf"), "plan": None}
        # Seed the incumbent with a perfect-knowledge EcoSched schedule so
        # the anytime result is never worse than the best known policy.
        try:
            from repro.core.ecosched import EcoSched
            from repro.core.perfmodel import OraclePerfModel
            from repro.core.simulator import simulate

            for lam in (0.25, 0.5, 1.0):
                seed = simulate(
                    EcoSched(OraclePerfModel(truth), lam=lam, tau=1.0),
                    node, truth, queue=list(queue),
                )
                total = seed.busy_energy + seed.idle_energy
                if total < best["total"]:
                    best["total"] = total
                    best["plan"] = tuple(
                        (r.job, r.g, r.start, r.end) for r in seed.records
                    )
        except Exception:
            pass
        deadline = t_start + self.time_budget_s
        exact = [True]

        def lb(waiting, busy, idle):
            return busy + idle + sum(min_busy[j] for j in waiting)

        def occupancy(running) -> List[int]:
            occ = [0] * node.domains
            for _, _, _, _, dom in running:
                occ[dom] += 1
            return occ

        def recurse(waiting: Tuple[str, ...],
                    running: Tuple[Tuple[float, str, int, Tuple[int, ...], int], ...],
                    free: Tuple[bool, ...], t: float, busy: float, idle: float,
                    plan: Tuple):
            if _time.perf_counter() > deadline:
                exact[0] = False
                return
            if not waiting and not running:
                total = busy + idle
                if total < best["total"]:
                    best["total"] = total
                    best["plan"] = plan
                return
            if lb(waiting, busy, idle) >= best["total"]:
                return

            # enumerate feasible launch sets at this event under the same
            # placement model the simulator enforces (domain-spreading
            # first-fit, co-run cap on *occupied* domains) — anything less
            # and the "oracle" would search a smaller space than the
            # online policies it is supposed to lower-bound
            occ = occupancy(running)
            free_count = sum(free)
            k_avail = node.domains - sum(1 for c in occ if c)
            choices: List[Tuple[Launch, ...]] = []
            if k_avail > 0 and waiting:
                jobs = list(dict.fromkeys(waiting))
                per_job_modes = {j: truth[j].feasible_counts for j in jobs}
                for size in range(1, min(k_avail, len(jobs)) + 1):
                    for combo in itertools.combinations(jobs, size):
                        for modes in itertools.product(*[per_job_modes[j] for j in combo]):
                            if sum(modes) > free_count:
                                continue
                            st2 = PlacementState(node.units, node.domains)
                            st2.free = list(free)
                            st2.domain_jobs = list(occ)
                            ok = True
                            try:
                                for g in modes:  # launch order, as applied
                                    st2.allocate(g)
                            except ValueError:
                                ok = False
                            if ok:
                                choices.append(
                                    tuple(Launch(job=j, g=g) for j, g in zip(combo, modes))
                                )
            if running:
                choices.append(())  # wait for a completion
            if not choices:
                return  # dead end (shouldn't happen: running or launchable)
            if len(choices) > self.max_branch:
                exact[0] = False
                # keep densest + most energy-efficient branches
                def key(ch):
                    if not ch:
                        return (1, 0.0)
                    e = sum(truth[l.job].energy(l.g) for l in ch)
                    return (0, e - 0.1 * sum(l.g for l in ch))
                choices = sorted(choices, key=key)[: self.max_branch]

            # order: denser, lower-energy first for good incumbents
            def order_key(ch):
                if not ch:
                    return (1, 0.0)
                return (0, sum(truth[l.job].energy(l.g) for l in ch)
                        - 1e-3 * sum(l.g for l in ch))

            for ch in sorted(choices, key=order_key):
                new_running = list(running)
                st3 = PlacementState(node.units, node.domains)
                st3.free = list(free)
                st3.domain_jobs = list(occ)
                nbusy = busy
                nplan = plan
                ok = True
                for l in ch:
                    try:
                        ids, dom = st3.allocate(l.g)
                    except ValueError:
                        ok = False
                        break
                    dur = truth[l.job].runtime[l.g]
                    nbusy += truth[l.job].energy(l.g)
                    new_running.append((t + dur, l.job, l.g, ids, dom))
                    nplan = nplan + ((l.job, l.g, t, t + dur),)
                if not ok or not new_running:
                    continue
                new_running.sort()
                end_t, jdone, gdone, ids_done, _ = new_running[0]
                free_now = st3.free_count()
                nidle = idle + free_now * (end_t - t) * node.idle_power_per_unit
                for u in ids_done:
                    st3.free[u] = True
                nwaiting = tuple(j for j in waiting if all(l.job != j for l in ch))
                recurse(
                    nwaiting,
                    tuple(new_running[1:]),
                    tuple(st3.free),
                    end_t,
                    nbusy,
                    nidle,
                    nplan,
                )

        recurse(tuple(queue), (), tuple([True] * node.units), 0.0, 0.0, 0.0, ())

        plan = best["plan"] or ()
        records = [
            JobRecord(job=j, g=g, start=s, end=e,
                      busy_energy=self.truth[j].energy(g))
            for (j, g, s, e) in plan
        ]
        makespan = max((e for (_, _, _, e) in plan), default=0.0)
        busy = sum(r.busy_energy for r in records)
        idle = best["total"] - busy if best["plan"] else 0.0
        result = ScheduleResult(
            policy="oracle",
            makespan=makespan,
            busy_energy=busy,
            idle_energy=idle,
            profiling_energy=0.0,
            records=records,
        )
        return result, exact[0]

"""Append-only JSONL journal for the scheduler control plane (ISSUE 6).

One record per line, appended and flushed before the action it describes
is applied (write-ahead for *inputs*: submissions, cancellations, advance
requests) or immediately after the substrate reports it (write-behind for
*transition events*).  Because the whole simulation stack is
deterministic, this split is exactly a redo log: replaying the input
records through a fresh backend regenerates every transition event, and
the journaled transitions double as a checksum of the replay
(``SchedulerService.recover`` verifies the journaled events are a prefix
of the regenerated stream before trusting the rebuilt state).

Durability model: every ``append`` flushes to the OS, so a SIGKILL of the
daemon loses at most the record being written — ``read`` tolerates ONE
trailing partial line (a torn final write) and drops it.  A malformed
record anywhere *before* the tail means real corruption and raises
``JournalError``.  ``fsync=True`` additionally fsyncs per record for
whole-machine-crash durability, at a large cost per append.

Record kinds (the ``"k"`` field):

  hdr — journal header: format version, backend label, admission config.
  snap — compaction marker (immediately after ``hdr``): ``n`` transition
        events have been folded away; ``sha`` is the chained hash over
        them (see ``chain_hash``).  Replay regenerates those events from
        the inputs and verifies the chain instead of comparing records.
  sub — a submit attempt: ``t, name, app, ok, reason`` (write-ahead).
  cxl — a cancel attempt: ``name, ok`` (write-ahead).
  adv — an advance request: ``until`` (float, or None = drain) (write-ahead).
  evt — one lifecycle transition from the event substrate:
        ``e`` in {queued, launch, done, ckpt, requeue, migrate, fail,
        retry, lost}, plus ``t, job, node, g, end, f`` (write-behind).

Version history: v1 journaled transitions without the DVFS frequency
level; v2 adds the ``f`` field to ``evt`` records so crash recovery
replays chosen (count, frequency) actions bit-identically; v3 adds the
fault-plane transition kinds (``fail``/``retry``/``lost``) and ``snap``
compaction records.
"""
from __future__ import annotations

import hashlib
import json
import os
from typing import Dict, List, Optional

JOURNAL_VERSION = 3


def _canon(rec: Dict) -> str:
    """The canonical serialization every journal byte goes through."""
    return json.dumps(rec, separators=(",", ":"), sort_keys=True)


def chain_hash(records: List[Dict], prev: str = "") -> str:
    """Chained sha256 over canonical record serializations:
    ``h_i = sha256(h_{i-1} + canon(rec_i))``, seeded by ``prev`` (empty
    for a chain starting at the journal's origin).  Sequential chaining
    makes compaction associative: a second snapshot continues the first
    snapshot's chain over the events journaled since, and the result is
    identical to one chain over the full event stream."""
    h = prev
    for rec in records:
        h = hashlib.sha256((h + _canon(rec)).encode()).hexdigest()
    return h


class JournalError(RuntimeError):
    """The journal is corrupt (malformed record before the tail) or
    inconsistent with the backend that is replaying it."""


class Journal:
    """Append-only JSONL writer.  One instance owns the file handle for
    the daemon's lifetime; ``read`` is a static method so recovery can
    inspect a journal before deciding to open it for append."""

    def __init__(self, path: str, *, fsync: bool = False):
        self.path = path
        self.fsync = fsync
        self._f = open(path, "a", encoding="utf-8")

    def append(self, rec: Dict) -> None:
        self._f.write(_canon(rec))
        self._f.write("\n")
        self._f.flush()
        if self.fsync:
            os.fsync(self._f.fileno())

    def snapshot(self) -> int:
        """Compact the journal in place: fold every ``evt`` record into a
        ``snap`` marker (count + chained hash), keeping the header and all
        input records verbatim.  Replay still regenerates the folded
        events deterministically from the inputs; the chain lets recovery
        verify them without storing them.  Crash-safe: the compacted file
        is written beside the journal, fsynced, and atomically renamed
        over it — a kill at any point leaves either the old or the new
        journal, never a mix.  Returns the number of events folded."""
        self.close()
        records = Journal.read(self.path)
        if not records or records[0].get("k") != "hdr":
            raise JournalError(f"{self.path}: cannot snapshot without a header")
        hdr, body = records[0], records[1:]
        prev_n, prev_sha = 0, ""
        if body and body[0].get("k") == "snap":
            prev_n = int(body[0]["n"])
            prev_sha = str(body[0]["sha"])
            body = body[1:]
        evts = [r for r in body if r.get("k") == "evt"]
        keep = [r for r in body if r.get("k") != "evt"]
        snap = {
            "k": "snap",
            "n": prev_n + len(evts),
            "sha": chain_hash(evts, prev_sha),
        }
        tmp = self.path + ".tmp"
        with open(tmp, "w", encoding="utf-8") as f:
            for rec in [hdr, snap] + keep:
                f.write(_canon(rec))
                f.write("\n")
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self.path)
        self._f = open(self.path, "a", encoding="utf-8")
        return len(evts)

    def size(self) -> int:
        """Current journal size in bytes.  ``append`` flushes every
        record, so the on-disk size is exact — this is what the service's
        size-triggered auto-compaction polls."""
        return os.path.getsize(self.path)

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()

    def __enter__(self) -> "Journal":
        return self

    def __exit__(self, *exc) -> Optional[bool]:
        self.close()
        return None

    @staticmethod
    def repair(path: str, records: List[Dict]) -> None:
        """Make the file end exactly after the last complete record in
        ``records`` (as returned by ``read``).  ``append`` serialization
        is canonical (sorted keys, fixed separators), so the byte length
        of the valid prefix is recomputable; a torn tail is truncated
        away and a lost final newline is restored — without this,
        reopening for append would write into the middle of the partial
        line and corrupt the journal."""
        want = sum(
            len(json.dumps(r, separators=(",", ":"), sort_keys=True).encode())
            + 1
            for r in records
        )
        size = os.path.getsize(path)
        if size > want:
            os.truncate(path, want)
        elif size == want - 1:  # the final newline itself was torn off
            with open(path, "a", encoding="utf-8") as f:
                f.write("\n")

    @staticmethod
    def read(path: str) -> List[Dict]:
        """Parse every complete record.  A torn *final* line (no trailing
        newline, or trailing garbage that fails to parse) is dropped —
        that is the expected signature of a crash mid-append.  Anything
        malformed earlier raises ``JournalError``."""
        with open(path, "r", encoding="utf-8") as f:
            raw = f.read()
        out: List[Dict] = []
        lines = raw.split("\n")
        # a well-formed journal ends with "\n", so the final split element
        # is ""; anything else is a torn tail and may only be dropped if
        # it is genuinely the last line
        complete, tail = lines[:-1], lines[-1]
        for i, line in enumerate(complete):
            if not line.strip():
                continue
            try:
                rec = json.loads(line)
            except ValueError as exc:
                if i == len(complete) - 1 and not tail:
                    break  # torn write that still got its newline out
                raise JournalError(
                    f"{path}: malformed record on line {i + 1}: {line[:80]!r}"
                ) from exc
            if not isinstance(rec, dict) or "k" not in rec:
                raise JournalError(
                    f"{path}: record on line {i + 1} is not a journal record"
                )
            out.append(rec)
        if tail:
            try:
                rec = json.loads(tail)
                if isinstance(rec, dict) and "k" in rec:
                    out.append(rec)  # complete record, newline lost
            except ValueError:
                pass  # torn tail: drop it
        return out

"""Core scheduler datatypes.

The scheduler sees *estimates* (``ModeEstimate`` from Phase I); the
simulator and the Oracle see *ground truth* (``JobProfile``).  Keeping the
two separated is what makes the online-vs-oracle comparison honest.

Units ("GPUs" in the paper) are the node's allocation granularity: one GPU
on a 4-GPU node, one 16-chip slice row on a 256-chip v5e pod
(DESIGN.md §2).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple


@dataclass(frozen=True)
class JobProfile:
    """Ground truth for one application (simulator/oracle only).

    ``freq_time``/``freq_power`` are per-frequency-level multipliers on the
    count-indexed runtime/power curves (DVFS third axis): level 0 is the
    base clock and both multipliers are 1.0 there.  Empty dicts mean the
    profile has a single frequency level — every ``*_at(g, f=0)`` helper
    collapses to the count-only curves, which keeps pre-DVFS behavior
    bit-identical.
    """

    name: str
    runtime: Dict[int, float]  # unit-count g -> solo execution seconds
    busy_power: Dict[int, float]  # g -> total active power (W) of the job
    dram_util: Dict[int, float] = field(default_factory=dict)  # profiling signal
    profiling_energy: float = 0.0  # one-time Phase-I cost (J)
    profiling_time: float = 0.0  # s of debug-node time (amortization analysis)
    freq_time: Dict[int, float] = field(default_factory=dict)  # f -> t multiplier
    freq_power: Dict[int, float] = field(default_factory=dict)  # f -> P multiplier

    @property
    def feasible_counts(self) -> Tuple[int, ...]:
        return tuple(sorted(self.runtime))

    @property
    def freq_levels(self) -> Tuple[int, ...]:
        return tuple(sorted(self.freq_time)) if self.freq_time else (0,)

    def optimal_count(self, limit: Optional[int] = None) -> int:
        """Performance-optimal count, optionally capped at ``limit`` units
        (heterogeneous cluster nodes may be smaller than every mode)."""
        counts = [g for g in self.runtime if limit is None or g <= limit]
        if not counts:
            raise ValueError(f"{self.name}: no feasible mode fits {limit} units")
        return min(counts, key=lambda g: (self.runtime[g], g))

    def energy(self, g: int) -> float:
        return self.runtime[g] * self.busy_power[g]

    def runtime_at(self, g: int, f: int = 0) -> float:
        """Solo runtime at count ``g``, frequency level ``f``."""
        t = self.runtime[g]
        return t if not self.freq_time else t * self.freq_time[f]

    def power_at(self, g: int, f: int = 0) -> float:
        """Busy power at count ``g``, frequency level ``f``."""
        p = self.busy_power[g]
        return p if not self.freq_power else p * self.freq_power[f]

    def energy_at(self, g: int, f: int = 0) -> float:
        return self.runtime_at(g, f) * self.power_at(g, f)


@dataclass(frozen=True)
class ModeEstimate:
    """Phase-I output for one (job, unit-count, frequency-level) mode.

    ``f`` is the DVFS frequency level (0 = base clock); profiles with a
    single level only ever produce ``f=0`` modes, which is the pre-DVFS
    mode set exactly.
    """

    g: int
    t_norm: float  # predicted runtime / predicted best runtime (>= 1)
    p_bar: float  # measured average busy power (W)
    e_norm: float  # normalized energy proxy Ẽ = P̄ · T̂norm, min-normalized
    f: int = 0  # DVFS frequency level (0 = base clock)


@dataclass(frozen=True)
class JobSpec:
    """What the scheduler knows about a waiting job."""

    name: str
    modes: Tuple[ModeEstimate, ...]  # τ-filtered happens in the policy

    def __post_init__(self):
        # precomputed (g, f) -> mode map: mode() sits on the resize hot
        # path and the joint DVFS mode set is 4-8x the count-only one
        object.__setattr__(
            self, "_by_gf", {(m.g, m.f): m for m in self.modes}
        )

    def mode(self, g: int, f: int = 0) -> ModeEstimate:
        m = self._by_gf.get((g, f))
        if m is None:
            raise KeyError((self.name, g, f))
        return m


@dataclass(frozen=True)
class Launch:
    """One scheduling decision element: run ``job`` on ``g`` units at
    frequency level ``f``."""

    job: str
    g: int
    f: int = 0


@dataclass
class RunningJob:
    job: str
    g: int
    units: Tuple[int, ...]
    domain: int
    start: float
    end: float
    power: float
    f: int = 0  # DVFS frequency level the segment runs at
    factor: float = 1.0  # interference slowdown applied to this segment
    # elastic substrate state (repro.core.events); inert for static runs
    frac0: float = 0.0  # work fraction completed before this segment
    restart: float = 0.0  # restart overhead charged at this segment's start
    preempted: bool = False  # a PREEMPT event supersedes this job's COMPLETE
    failed: bool = False  # killed by a fault; COMPLETE/PREEMPT become stale
    frac_ckpt: float = 0.0  # work fraction frozen at the checkpoint decision
    record: Optional["JobRecord"] = field(default=None, compare=False, repr=False)

    def frac_at(self, t: float) -> float:
        """Completed-work fraction at time ``t`` (useful work excludes the
        restart overhead at the segment head)."""
        useful = self.end - self.start - self.restart
        if useful <= 0.0:
            return 1.0
        elapsed = min(max(t - self.start - self.restart, 0.0), useful)
        return self.frac0 + (1.0 - self.frac0) * elapsed / useful


@dataclass
class NodeView:
    """Scheduler-visible node state at a scheduling event."""

    t: float
    total_units: int  # M
    domains: int  # K
    free_units: int
    running: List[RunningJob]
    free_map: List[bool] = field(default_factory=list)  # per-unit freedom
    domain_jobs: List[int] = field(default_factory=list)  # per-domain occupancy
    dead_units: int = 0  # units lost to a node failure (fault plane)

    @property
    def alive_units(self) -> int:
        """Schedulable capacity: Eq. (1)'s M on a degraded node."""
        return self.total_units - self.dead_units

    @property
    def occupied_domains(self) -> int:
        """Isolation domains hosting at least one job.  Falls back to the
        running-job count when the view carries no occupancy map (older
        callers); with correct labeling the two coincide."""
        if self.domain_jobs:
            return sum(1 for c in self.domain_jobs if c)
        return len(self.running)

    @property
    def free_domains(self) -> int:
        return self.domains - self.occupied_domains


@dataclass
class JobRecord:
    job: str
    g: int
    start: float
    end: float
    busy_energy: float
    arrival: float = 0.0  # when the job entered the system (0 = static queue)
    node: str = ""  # cluster node id; "" for single-node simulate()
    domain: int = -1  # isolation domain the job was homed in (-1 = unknown)
    segment: int = 0  # run segment index (a preempted job has several)
    kind: str = "run"  # "run" = completed, "ckpt" = checkpointed, "fail" = killed
    ckpt_energy: float = 0.0  # checkpoint-write energy inside busy_energy
    queued: float = 0.0  # when this segment entered a waiting queue
    f: int = 0  # DVFS frequency level the segment ran at

    @property
    def wait(self) -> float:
        """Genuine queueing time before this segment started.  For the
        first segment ``queued`` equals ``arrival``; a resumed/migrated
        segment measures from its re-enqueue instant, so preempted jobs do
        not count their own running time as waiting."""
        return self.start - max(self.queued, self.arrival)


@dataclass
class ScheduleResult:
    policy: str
    makespan: float
    busy_energy: float
    idle_energy: float
    profiling_energy: float
    records: List[JobRecord]
    decision_time_s: float = 0.0  # total wall-clock spent inside the policy
    decision_events: int = 0
    resize_time_s: float = 0.0  # wall-clock inside the elastic resize phase
    migrate_time_s: float = 0.0  # wall-clock inside the migration phase
    # elastic substrate accounting (all zero/empty for static runs)
    preemptions: int = 0  # checkpoints taken on this node
    migrations_in: int = 0  # jobs that arrived via MIGRATE events
    migrations_out: int = 0  # jobs this node handed to another node
    ckpt_energy: float = 0.0  # checkpoint-write energy (inside busy_energy)
    resize_history: Dict[str, List[Tuple[float, int, int]]] = field(
        default_factory=dict
    )  # job -> [(relaunch t, g_old, g_new)]
    freq_history: Dict[str, List[Tuple[float, int, int]]] = field(
        default_factory=dict
    )  # job -> [(relaunch t, f_old, f_new)] — DVFS retunes across segments
    # forecast-plane observability (repro.core.forecast; empty when the
    # run had no plane): final rate estimates, burst-gate state/flips,
    # migrations vetoed by the risk penalty, posterior feed counts
    forecast: Dict[str, float] = field(default_factory=dict)
    # fault-plane accounting (repro.core.faults; all zero without faults)
    job_crashes: int = 0  # JOB_FAIL kills on this node
    node_failures: int = 0  # NODE_FAIL events this node suffered
    fault_kills: int = 0  # jobs killed mid-flight (crashes + node failures)
    fault_retries: int = 0  # backoff retries queued from this node
    lost_jobs: List[str] = field(default_factory=list)  # retries exhausted

    @property
    def total_energy(self) -> float:
        return self.busy_energy + self.idle_energy + self.profiling_energy

    @property
    def resizes(self) -> int:
        return sum(len(v) for v in self.resize_history.values())

    @property
    def retunes(self) -> int:
        """Pure frequency retunes (relaunches that changed f, not g)."""
        return sum(len(v) for v in self.freq_history.values())

    @property
    def edp(self) -> float:
        return self.total_energy * self.makespan


@dataclass
class ClusterResult:
    """Rollup of per-node ``ScheduleResult``s for one cluster run.

    Each node integrates its own idle energy up to its *local* makespan
    (last completion on that node); ``tail_idle_energy`` is the extra idle
    drawn by nodes that drain early, up to the cluster makespan — so
    Σ busy + Σ idle + tail covers exactly Σ_n M_n · makespan unit-seconds.
    """

    policy: str
    per_node: Dict[str, ScheduleResult]
    makespan: float
    tail_idle_energy: float = 0.0
    # forecast-plane observability (repro.core.forecast); empty without one
    forecast: Dict[str, float] = field(default_factory=dict)
    # fleet fragmentation gauge (ISSUE 9): time_avg / peak / final
    # unusable-GPU fraction given the pending mix, à la Lettich et al.
    fragmentation: Dict[str, float] = field(default_factory=dict)
    # per-phase decision wall-clock breakdown (ISSUE 10): "dispatch"
    # (routing), "launch" (launch scoring inside on_event), "resize"
    # (elastic resize phase), "migrate" (migration phase), "stage"
    # (cross-node batched kernel staging)
    decision_phases: Dict[str, float] = field(default_factory=dict)

    @property
    def busy_energy(self) -> float:
        return sum(r.busy_energy for r in self.per_node.values())

    @property
    def idle_energy(self) -> float:
        return (
            sum(r.idle_energy for r in self.per_node.values())
            + self.tail_idle_energy
        )

    @property
    def profiling_energy(self) -> float:
        return sum(r.profiling_energy for r in self.per_node.values())

    @property
    def total_energy(self) -> float:
        return self.busy_energy + self.idle_energy + self.profiling_energy

    @property
    def edp(self) -> float:
        return self.total_energy * self.makespan

    @property
    def decision_time_s(self) -> float:
        return sum(r.decision_time_s for r in self.per_node.values())

    @property
    def decision_events(self) -> int:
        return sum(r.decision_events for r in self.per_node.values())

    @property
    def preemptions(self) -> int:
        return sum(r.preemptions for r in self.per_node.values())

    @property
    def migrations(self) -> int:
        """Completed migrations (arrivals on the receiving node)."""
        return sum(r.migrations_in for r in self.per_node.values())

    @property
    def resizes(self) -> int:
        return sum(r.resizes for r in self.per_node.values())

    @property
    def retunes(self) -> int:
        return sum(r.retunes for r in self.per_node.values())

    @property
    def ckpt_energy(self) -> float:
        return sum(r.ckpt_energy for r in self.per_node.values())

    @property
    def job_crashes(self) -> int:
        return sum(r.job_crashes for r in self.per_node.values())

    @property
    def node_failures(self) -> int:
        return sum(r.node_failures for r in self.per_node.values())

    @property
    def fault_kills(self) -> int:
        return sum(r.fault_kills for r in self.per_node.values())

    @property
    def fault_retries(self) -> int:
        return sum(r.fault_retries for r in self.per_node.values())

    @property
    def lost_jobs(self) -> List[str]:
        return sorted(
            j for r in self.per_node.values() for j in r.lost_jobs
        )

    @property
    def records(self) -> List[JobRecord]:
        out = [rec for r in self.per_node.values() for rec in r.records]
        out.sort(key=lambda rec: (rec.start, rec.job))
        return out

    @property
    def mean_wait(self) -> float:
        recs = self.records
        return sum(r.wait for r in recs) / len(recs) if recs else 0.0

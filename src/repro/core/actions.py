"""Feasible-action enumeration (paper §III-C) — pure-Python reference.

An action is a set of ⟨job, unit-count, frequency-level⟩ modes
satisfying, under the *current* node state:
  * total units ≤ free units, placeable as contiguous ranges (checked by
    replaying the simulator's domain-spreading first-fit on a copy of the
    node's placement state — counts in descending order, exactly the order
    EcoSched hands launches to the simulator),
  * co-running cap: occupied domains + |a| ≤ K,
  * one mode per job; jobs from the scheduling window only.

For the paper's node (M=4, K=2) exhaustive enumeration is tiny.  For pod
scale (M=16, K=4, 17-job windows) the exact space can exceed 10^5, so
beyond ``exact_limit`` we fall back to beam construction: extend the
current beam of partial actions by every (job, mode), dedupe partials
that reach the same {job → (g, f)} set through different extension orders
(otherwise one good set occupies several beam slots and beam width buys
no diversity), keep the best ``beam`` by score, and collect every partial
generated — greedy-complete in the same spirit as the paper's greedy
local decision strategy.

This module is the *reference oracle*: ``repro.core.engine`` reimplements
both paths with vectorized numpy batches and is parity-locked against it
(identical argmin action, scores within 1e-9) in tests/test_engine.py.
"""
from __future__ import annotations

import itertools
from typing import Iterable, List, Optional, Sequence, Tuple

from repro.core.placement import PlacementState
from repro.core.score import score
from repro.core.types import JobSpec, Launch, ModeEstimate, NodeView


def _placeable(
    free_map: List[bool],
    counts: Sequence[int],
    domains: int = 1,
    domain_jobs: Optional[Sequence[int]] = None,
) -> bool:
    """Replay the simulator's allocation for ``counts`` (descending) on a
    copy of the node's placement state."""
    st = PlacementState(len(free_map), domains)
    st.free = list(free_map)
    if domain_jobs:
        st.domain_jobs = list(domain_jobs)
    try:
        for g in sorted(counts, reverse=True):
            st.allocate(g)
    except ValueError:
        return False
    return True


def _space_estimate(per_job: Sequence[int], k_avail: int, exact_limit: int) -> int:
    """Size of the exact action space (capped just above ``exact_limit``)."""
    est = 1
    for size in range(1, min(k_avail, len(per_job)) + 1):
        for combo in itertools.combinations(per_job, size):
            est_c = 1
            for c in combo:
                est_c *= c
            est += est_c
            if est > exact_limit:
                return est
    return est


def enumerate_actions(
    specs: Sequence[JobSpec],
    view: NodeView,
    free_map: List[bool],
    *,
    lam: float,
    lam_f: float = 0.0,
    exact_limit: int = 50_000,
    beam: int = 64,
) -> List[Tuple[float, Tuple[Tuple[JobSpec, ModeEstimate], ...]]]:
    """Returns scored actions [(S(a), ((spec, mode), ...)), ...] incl. empty."""
    k_avail = view.domains - view.occupied_domains
    g_free = view.free_units
    M = view.alive_units  # degraded nodes score over their alive capacity
    domain_jobs = list(view.domain_jobs) or [0] * view.domains
    if k_avail <= 0 or not specs:
        return [(score((), g_free=g_free, M=M, lam=lam, lam_f=lam_f), ())]

    est = _space_estimate([len(s.modes) for s in specs], k_avail, exact_limit)

    def mode_list(a):
        return [m for _, m in a]

    results: List[Tuple[float, Tuple[Tuple[JobSpec, ModeEstimate], ...]]] = []

    def add(action):
        counts = [m.g for _, m in action]
        if sum(counts) > g_free:
            return False
        if action and not _placeable(free_map, counts, view.domains, domain_jobs):
            return False
        s = score(mode_list(action), g_free=g_free, M=M, lam=lam, lam_f=lam_f)
        results.append((s, tuple(action)))
        return True

    add(())

    if est <= exact_limit:
        for size in range(1, min(k_avail, len(specs)) + 1):
            for jobs in itertools.combinations(specs, size):
                for modes in itertools.product(*[j.modes for j in jobs]):
                    add(tuple(zip(jobs, modes)))
        return results

    # --- beam construction -------------------------------------------------
    frontier: List[Tuple[float, Tuple[Tuple[JobSpec, ModeEstimate], ...]]] = [
        (score((), g_free=g_free, M=M, lam=lam, lam_f=lam_f), ())
    ]
    for _ in range(k_avail):
        # dedupe by the {(job, g, f)} set: the same action reached through
        # different extension orders must occupy one beam slot, not many.
        # (g, f) is the joint mode identity; with a single frequency level
        # every f is 0 and the key collapses to the historical (job, g) set.
        seen = {}
        for _, partial in frontier:
            used = {sp.name for sp, _ in partial}
            used_g = sum(m.g for _, m in partial)
            base_key = frozenset((sp.name, m.g, m.f) for sp, m in partial)
            for sp in specs:
                if sp.name in used:
                    continue
                for m in sp.modes:
                    if used_g + m.g > g_free:
                        continue
                    key = base_key | {(sp.name, m.g, m.f)}
                    if key in seen:
                        continue
                    na = partial + ((sp, m),)
                    if not _placeable(
                        free_map, [mm.g for _, mm in na], view.domains, domain_jobs
                    ):
                        continue
                    seen[key] = (
                        score(
                            mode_list(na), g_free=g_free, M=M, lam=lam, lam_f=lam_f
                        ),
                        na,
                    )
        if not seen:
            break
        candidates = list(seen.values())
        candidates.sort(key=lambda kv: kv[0])  # stable: ties keep generation order
        frontier = candidates[:beam]
        results.extend(frontier)
    return results

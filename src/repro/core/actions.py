"""Feasible-action enumeration (paper §III-C).

An action is a set of ⟨job, unit-count⟩ modes satisfying, under the
*current* node state:
  * total units ≤ free units, placeable as contiguous ranges (checked by
    replaying first-fit on a copy of the free map),
  * co-running cap: |running| + |a| ≤ K,
  * one mode per job; jobs from the scheduling window only.

For the paper's node (M=4, K=2) exhaustive enumeration is tiny.  For pod
scale (M=16, K=4, 17-job windows) the exact space can exceed 10^5, so
beyond ``exact_limit`` we fall back to beam construction: extend the
current beam of partial actions by every (job, mode), keep the best
``beam`` by score, and collect every partial generated — greedy-complete
in the same spirit as the paper's greedy local decision strategy.
"""
from __future__ import annotations

import itertools
from typing import Iterable, List, Optional, Sequence, Tuple

from repro.core.placement import PlacementState
from repro.core.score import score
from repro.core.types import JobSpec, Launch, ModeEstimate, NodeView


def _placeable(free_map: List[bool], counts: Sequence[int]) -> bool:
    st = PlacementState(len(free_map), 1)
    st.free = list(free_map)
    try:
        for g in sorted(counts, reverse=True):
            st.allocate(g)
    except ValueError:
        return False
    return True


def enumerate_actions(
    specs: Sequence[JobSpec],
    view: NodeView,
    free_map: List[bool],
    *,
    lam: float,
    exact_limit: int = 50_000,
    beam: int = 64,
) -> List[Tuple[float, Tuple[Tuple[JobSpec, ModeEstimate], ...]]]:
    """Returns scored actions [(S(a), ((spec, mode), ...)), ...] incl. empty."""
    k_avail = view.domains - len(view.running)
    g_free = view.free_units
    M = view.total_units
    if k_avail <= 0 or not specs:
        return [(score((), g_free=g_free, M=M, lam=lam), ())]

    # estimate exact-space size
    per_job = [len(s.modes) for s in specs]
    est = 1
    for size in range(1, min(k_avail, len(specs)) + 1):
        for combo in itertools.combinations(per_job, size):
            est_c = 1
            for c in combo:
                est_c *= c
            est += est_c
            if est > exact_limit:
                break
        if est > exact_limit:
            break

    def mode_list(a):
        return [m for _, m in a]

    results: List[Tuple[float, Tuple[Tuple[JobSpec, ModeEstimate], ...]]] = []

    def add(action):
        counts = [m.g for _, m in action]
        if sum(counts) > g_free:
            return False
        if action and not _placeable(free_map, counts):
            return False
        s = score(mode_list(action), g_free=g_free, M=M, lam=lam)
        results.append((s, tuple(action)))
        return True

    add(())

    if est <= exact_limit:
        for size in range(1, min(k_avail, len(specs)) + 1):
            for jobs in itertools.combinations(specs, size):
                for modes in itertools.product(*[j.modes for j in jobs]):
                    add(tuple(zip(jobs, modes)))
        return results

    # --- beam construction -------------------------------------------------
    frontier: List[Tuple[float, Tuple[Tuple[JobSpec, ModeEstimate], ...]]] = [
        (score((), g_free=g_free, M=M, lam=lam), ())
    ]
    for _ in range(k_avail):
        candidates = []
        for _, partial in frontier:
            used = {sp.name for sp, _ in partial}
            used_g = sum(m.g for _, m in partial)
            for sp in specs:
                if sp.name in used:
                    continue
                for m in sp.modes:
                    if used_g + m.g > g_free:
                        continue
                    na = partial + ((sp, m),)
                    if not _placeable(free_map, [mm.g for _, mm in na]):
                        continue
                    s = score(mode_list(na), g_free=g_free, M=M, lam=lam)
                    candidates.append((s, na))
        if not candidates:
            break
        candidates.sort(key=lambda kv: kv[0])
        frontier = candidates[:beam]
        results.extend(frontier)
    return results

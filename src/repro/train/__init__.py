from repro.train.step import init_state, make_decode_step, make_prefill, make_train_step

__all__ = ["init_state", "make_decode_step", "make_prefill", "make_train_step"]

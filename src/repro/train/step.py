"""train_step / serve_step builders.

``train_step`` is a pure function (state, batch) → (state, metrics) suitable
for ``jax.jit`` with donated state; ``decode_step``/``prefill`` wrap the
model's serving entry points.  State is a plain dict pytree so the
checkpoint layer and the sharding-spec layer need no special casing.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.optim import AdamW, compress_grads, init_residuals


def init_state(model: Model, optimizer: AdamW, rng, *, compress: bool = False) -> dict:
    params = model.init(rng)
    state = {
        "params": params,
        "opt": optimizer.init(params),
        "step": jnp.zeros((), jnp.int32),
    }
    if compress:
        state["residuals"] = init_residuals(params)
    return state


def make_train_step(
    model: Model,
    optimizer: AdamW,
    schedule: Callable,
    *,
    compress: bool = False,
    grad_accum: int = 1,
    grad_shardings=None,
) -> Callable:
    """``grad_shardings``: optional NamedSharding tree (ZeRO layout).  When
    set, every (micro)batch's gradients are constrained to it immediately —
    XLA lowers the DP reduction to a reduce-scatter and the fp32 grad
    accumulator lives at 1/dp_size memory (ZeRO-2)."""

    def constrain_grads(g):
        if grad_shardings is None:
            return g
        return jax.tree_util.tree_map(
            jax.lax.with_sharding_constraint, g, grad_shardings
        )

    def train_step(state: dict, batch: dict) -> Tuple[dict, Dict[str, jax.Array]]:
        def loss_fn(p):
            return model.loss(p, batch)

        if grad_accum > 1:
            # Microbatch over the leading batch dim.  Python-unrolled (not
            # lax.scan) so XLA cost analysis counts every microbatch —
            # the dry-run's roofline extrapolation depends on it
            # (DESIGN.md §4).  Accumulation happens in fp32 at the ZeRO
            # sharding (tiny per-chip buffer).
            def micro(i, params):
                mb = jax.tree_util.tree_map(
                    lambda x: x.reshape(grad_accum, -1, *x.shape[1:])[i], batch
                )
                # barrier: stops XLA from rewriting gather(slice(tokens)) →
                # slice(gather(tokens)) and CSE-ing a full-batch embedding
                # lookup across microbatches (verified by HLO inspection)
                mb = jax.lax.optimization_barrier(mb)
                (l, mt), g = jax.value_and_grad(
                    lambda p: model.loss(p, mb), has_aux=True
                )(params)
                g = constrain_grads(
                    jax.tree_util.tree_map(lambda x: x.astype(jnp.float32), g)
                )
                return (l, mt), g

            params = state["params"]
            (loss, metrics), grads = micro(0, params)
            for i in range(1, grad_accum):
                # optimization_barrier ties microbatch i's forward to
                # microbatch i-1's accumulated grads: XLA cannot interleave
                # the unrolled microbatches, so only one microbatch's
                # activations are ever live (true sequential accumulation).
                grads, params = jax.lax.optimization_barrier((grads, params))
                (l2, m2), g2 = micro(i, params)
                loss = loss + l2
                grads = jax.tree_util.tree_map(jnp.add, grads, g2)
            loss = loss / grad_accum
            grads = jax.tree_util.tree_map(lambda g: g / grad_accum, grads)
            metrics = {k: v / grad_accum for k, v in metrics.items()}
        else:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                state["params"]
            )
            grads = constrain_grads(grads)

        new_state = dict(state)
        if compress:
            grads, new_state["residuals"] = compress_grads(grads, state["residuals"])
        lr = schedule(state["step"])
        new_params, new_opt, om = optimizer.update(grads, state["opt"], state["params"], lr)
        new_state.update(params=new_params, opt=new_opt, step=state["step"] + 1)
        out_metrics = dict(metrics)
        out_metrics.update(loss=loss, lr=lr, **om)
        return new_state, out_metrics

    return train_step


def make_decode_step(model: Model) -> Callable:
    def serve_step(params, cache, token, pos):
        return model.decode_step(params, cache, token, pos)

    return serve_step


def make_prefill(model: Model) -> Callable:
    def prefill(params, batch):
        return model.prefill(params, batch)

    return prefill

"""Training loop: checkpoint/restart, failure recovery, straggler watch.

The Trainer owns: the (possibly sub-)mesh, sharded state, the jitted step,
a CheckpointManager, a FailureInjector hook (tests/chaos), and the
StragglerMonitor.  On ``DeviceFailure`` it rebuilds a smaller mesh from
the surviving devices, restores the latest checkpoint with the new
shardings (elastic restore), re-jits, and continues — the documented
recovery path for node loss at pod scale (DESIGN.md §7).
"""
from __future__ import annotations

import logging
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import CheckpointManager
from repro.configs.base import ModelConfig
from repro.data import SyntheticLM
from repro.distributed import sharding as shd
from repro.distributed.ctx import sharding_rules
from repro.distributed.fault import DeviceFailure, FailureInjector, StragglerMonitor
from repro.distributed.meshes import make_mesh
from repro.models import Model, Runtime
from repro.optim import AdamW
from repro.train.step import init_state, make_train_step

log = logging.getLogger("repro.train")


@dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_keep: int = 3
    log_every: int = 10
    seed: int = 0
    zero: bool = True
    grad_accum: int = 1
    compress: bool = False


class Trainer:
    def __init__(
        self,
        cfg: ModelConfig,
        model: Model,
        optimizer: AdamW,
        schedule: Callable,
        dataset: SyntheticLM,
        tcfg: TrainerConfig,
        *,
        devices: Optional[List] = None,
        model_par: int = 1,
        failure_injector: Optional[FailureInjector] = None,
    ):
        self.cfg = cfg
        self.model = model
        self.optimizer = optimizer
        self.schedule = schedule
        self.dataset = dataset
        self.tcfg = tcfg
        self.devices = list(devices if devices is not None else jax.devices())
        self.model_par = model_par
        self.failure_injector = failure_injector
        self.straggler = StragglerMonitor()
        self.ckpt = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.ckpt_keep)
        self.metrics_history: List[Dict[str, float]] = []
        self.recoveries = 0
        self._build(self.devices)

    # ------------------------------------------------------------------
    def _build(self, devices: List):
        """(Re)build mesh, shardings and the jitted step on ``devices``."""
        n = len(devices)
        mp = self.model_par if n % self.model_par == 0 else 1
        self.mesh = make_mesh((n // mp, mp), ("data", "model"), devices=devices)
        self.active_devices = devices

        state_shape = jax.eval_shape(
            lambda: init_state(self.model, self.optimizer, jax.random.key(self.tcfg.seed),
                               compress=self.tcfg.compress)
        )
        pspecs = shd.param_specs(self.cfg, self.mesh, state_shape["params"])
        ospecs = shd.opt_state_specs(self.cfg, self.mesh, state_shape["opt"], zero=self.tcfg.zero)
        self.state_specs = {"params": pspecs, "opt": ospecs, "step": P()}
        if self.tcfg.compress:
            self.state_specs["residuals"] = pspecs
        self.state_shardings = jax.tree_util.tree_map(
            lambda s: NamedSharding(self.mesh, s), self.state_specs,
            is_leaf=lambda x: isinstance(x, P),
        )
        gshards = None
        if self.tcfg.zero:
            gshards = jax.tree_util.tree_map(
                lambda sp, leaf: NamedSharding(
                    self.mesh, shd.zero_extend(sp, tuple(leaf.shape), self.mesh)
                ),
                pspecs, state_shape["params"],
                is_leaf=lambda x: isinstance(x, P),
            )
        step_fn = make_train_step(
            self.model, self.optimizer, self.schedule,
            compress=self.tcfg.compress, grad_accum=self.tcfg.grad_accum,
            grad_shardings=gshards,
        )
        self._jit_step = jax.jit(step_fn, donate_argnums=(0,))
        self._rules = shd.activation_rules(self.cfg, self.mesh, self.dataset.batch)

    def _init_or_restore(self):
        state_shape = jax.eval_shape(
            lambda: init_state(self.model, self.optimizer, jax.random.key(self.tcfg.seed),
                               compress=self.tcfg.compress)
        )
        restored, meta = self.ckpt.restore_latest(state_shape, shardings=self.state_shardings)
        if restored is not None:
            log.info("restored checkpoint at step %s", meta["step"])
            return restored, int(meta["step"])
        with self.mesh:
            state = jax.jit(
                lambda: init_state(self.model, self.optimizer, jax.random.key(self.tcfg.seed),
                                   compress=self.tcfg.compress),
                out_shardings=self.state_shardings,
            )()
        return state, 0

    def _place_batch(self, batch: Dict[str, np.ndarray]):
        specs = shd.batch_specs(self.cfg, self.mesh, {k: v.shape for k, v in batch.items()})
        return {
            k: jax.device_put(v, NamedSharding(self.mesh, specs[k]))
            for k, v in batch.items()
        }

    # ------------------------------------------------------------------
    def run(self) -> Dict[str, Any]:
        state, start = self._init_or_restore()
        step = start
        while step < self.tcfg.total_steps:
            try:
                t0 = time.perf_counter()
                if self.failure_injector is not None:
                    self.failure_injector.check(step)
                batch = self._place_batch(self.dataset.global_batch(step))
                with self.mesh, sharding_rules(self._rules):
                    state, metrics = self._jit_step(state, batch)
                loss = float(metrics["loss"])
                dt = time.perf_counter() - t0
                self.straggler.observe(step, dt)
                self.metrics_history.append({"step": step, "loss": loss, "dt": dt})
                if step % self.tcfg.log_every == 0:
                    log.info("step %d loss %.4f (%.2fs)", step, loss, dt)
                step += 1
                if step % self.tcfg.ckpt_every == 0:
                    self.ckpt.save(step, state)
            except DeviceFailure as e:
                log.warning("device failure: %s — recovering", e)
                self.recoveries += 1
                survivors = [
                    d for i, d in enumerate(self.active_devices)
                    if i not in set(e.failed_devices)
                ]
                if not survivors:
                    raise
                self.ckpt.wait()
                self._build(survivors)
                state, step = self._init_or_restore()
        self.ckpt.save(step, state)
        self.ckpt.wait()
        return {
            "final_step": step,
            "final_loss": self.metrics_history[-1]["loss"] if self.metrics_history else None,
            "history": self.metrics_history,
            "recoveries": self.recoveries,
            "straggler_events": list(self.straggler.events),
        }

    # ------------------------------------------------------------------
    # EcoSched-Elastic hook: rescale this job onto a new device set at a
    # checkpoint boundary (beyond-paper extension; launch/coschedule.py).
    # ------------------------------------------------------------------
    def rescale(self, devices: List):
        self.ckpt.wait()
        self._build(devices)

"""AdamW with selectable moment precision: fp32 / bf16 / int8-blockwise.

The int8 path stores both Adam moments as symmetric per-block int8 with
fp32 scales (block = 256 contiguous elements of the flattened tensor).
For a 480B-param MoE this takes optimizer state from 8 bytes/param to
~2.06 bytes/param — the difference between fitting and not fitting a v5e's
16 GB HBM at 256-way sharding (DESIGN.md §7, EXPERIMENTS.md §Perf).
Quantization error is re-absorbed every step because moments are
dequantized, updated with the fresh gradient, and re-quantized — the same
structure as 8-bit Adam (Dettmers et al.) minus the dynamic-tree format.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


# ---------------------------------------------------------------------------
# Blockwise int8 quantization
# ---------------------------------------------------------------------------


def _q8(x: jax.Array) -> Dict[str, jax.Array]:
    """Blockwise int8 along the LAST axis only.

    Blocking the last axis (instead of flattening the whole tensor) keeps
    every leading dimension's sharding intact — a full flatten is not
    representable under SPMD and forced XLA to all-gather entire fp32
    moment tensors (8 TB/chip/step on arctic-480b; §Perf iteration A2).
    """
    last = x.shape[-1] if x.ndim else 1
    xb = x.reshape(*x.shape[:-1], last) if x.ndim else x.reshape(1)
    pad = (-last) % BLOCK
    if pad:
        xb = jnp.pad(xb, [(0, 0)] * (xb.ndim - 1) + [(0, pad)])
    nb = xb.shape[-1] // BLOCK
    blocks = xb.reshape(*xb.shape[:-1], nb, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
    return {"q": q, "scale": scale.astype(jnp.float32)}


def _dq8(qs: Dict[str, jax.Array], shape) -> jax.Array:
    blocks = qs["q"].astype(jnp.float32) * qs["scale"]
    padded = blocks.shape[-2] * blocks.shape[-1]  # no -1: zero-size safe
    flat_last = blocks.reshape(*blocks.shape[:-2], padded)
    last = shape[-1] if shape else 1
    return flat_last[..., :last].reshape(shape)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    state_dtype: str = "float32"  # float32 | bfloat16 | int8
    clip_norm: float = 1.0
    # ZeRO-3-style master weights: fp32 copies live in the optimizer state
    # (sharded over data by opt_state_specs); the bf16 params are re-formed
    # by an all-gather of the updated master each step.  Keeps the whole
    # optimizer stage at 1/dp_size memory and turns the DP grad all-reduce
    # into a reduce-scatter when the train step constrains grads.
    master_weights: bool = False


class AdamW:
    def __init__(self, cfg: AdamWConfig = AdamWConfig()):
        self.cfg = cfg

    # -- state ----------------------------------------------------------
    def _encode(self, x: jax.Array):
        sd = self.cfg.state_dtype
        if sd == "int8":
            return _q8(x)
        return x.astype(jnp.bfloat16 if sd == "bfloat16" else jnp.float32)

    def _decode(self, enc, shape) -> jax.Array:
        if isinstance(enc, dict) and "q" in enc:
            return _dq8(enc, shape)
        return enc.astype(jnp.float32)

    def init(self, params) -> dict:
        state = {
            "m": jax.tree_util.tree_map(
                lambda p: self._encode(jnp.zeros(p.shape, jnp.float32)), params
            ),
            "v": jax.tree_util.tree_map(
                lambda p: self._encode(jnp.zeros(p.shape, jnp.float32)), params
            ),
            "count": jnp.zeros((), jnp.int32),
        }
        if self.cfg.master_weights:
            state["master"] = jax.tree_util.tree_map(
                lambda p: p.astype(jnp.float32), params
            )
        return state

    # -- update ----------------------------------------------------------
    def update(
        self, grads, state: dict, params, lr: jax.Array
    ) -> Tuple[dict, dict, Dict[str, jax.Array]]:
        """Returns (new_params, new_state, metrics)."""
        cfg = self.cfg
        count = state["count"] + 1
        sq = jax.tree_util.tree_reduce(
            lambda acc, g: acc + jnp.sum(jnp.square(g.astype(jnp.float32))),
            grads,
            jnp.zeros((), jnp.float32),
        )
        gnorm = jnp.sqrt(sq)
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9)) if cfg.clip_norm else 1.0

        b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
        b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)
        use_master = cfg.master_weights and "master" in state
        masters = state.get("master", params)

        def upd(p, g, m_enc, v_enc, master):
            g = g.astype(jnp.float32) * scale
            m = self._decode(m_enc, p.shape)
            v = self._decode(v_enc, p.shape)
            m = cfg.b1 * m + (1 - cfg.b1) * g
            v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
            mh = m / b1c
            vh = v / b2c
            step = mh / (jnp.sqrt(vh) + cfg.eps)
            p32 = master.astype(jnp.float32) if use_master else p.astype(jnp.float32)
            if cfg.weight_decay and p.ndim >= 2:  # decay matrices, not norms/bias
                step = step + cfg.weight_decay * p32
            new_master = p32 - lr * step
            new_p = new_master.astype(p.dtype)
            return new_p, self._encode(m), self._encode(v), new_master

        out = jax.tree_util.tree_map(
            upd, params, grads, state["m"], state["v"], masters,
            is_leaf=lambda x: isinstance(x, jax.Array),
        )
        pick = lambda i: jax.tree_util.tree_map(
            lambda t: t[i], out, is_leaf=lambda x: isinstance(x, tuple)
        )
        new_state = {"m": pick(1), "v": pick(2), "count": count}
        if use_master:
            new_state["master"] = pick(3)
        return pick(0), new_state, {"grad_norm": gnorm}

    def state_bytes_per_param(self) -> float:
        return {"float32": 8.0, "bfloat16": 4.0, "int8": 2.0 + 8.0 / BLOCK}[
            self.cfg.state_dtype
        ]

"""Learning-rate schedules (pure functions of the step counter)."""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax.numpy as jnp


@dataclass(frozen=True)
class WarmupCosine:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    final_frac: float = 0.1

    def __call__(self, step):
        step = jnp.asarray(step, jnp.float32)
        warm = self.peak_lr * step / max(self.warmup_steps, 1)
        prog = jnp.clip(
            (step - self.warmup_steps) / max(self.decay_steps - self.warmup_steps, 1),
            0.0,
            1.0,
        )
        cos = self.final_frac + (1 - self.final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < self.warmup_steps, warm, self.peak_lr * cos)


@dataclass(frozen=True)
class Constant:
    lr: float = 1e-4

    def __call__(self, step):
        return jnp.full((), self.lr, jnp.float32)

from repro.optim.adamw import AdamW, AdamWConfig
from repro.optim.compress import compress_grads, init_residuals
from repro.optim.schedule import Constant, WarmupCosine

__all__ = [
    "AdamW",
    "AdamWConfig",
    "Constant",
    "WarmupCosine",
    "compress_grads",
    "init_residuals",
]

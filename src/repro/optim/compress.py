"""Gradient compression with error feedback (DP all-reduce width reduction).

``compress_grads`` quantizes each gradient tensor to blockwise-int8 before
the data-parallel reduction and carries the quantization residual into the
next step (error feedback), so the compression error is unbiased over
time.  On hardware this runs the DP reduce-scatter at 1/4 the bytes of
bf16; the dry-run roofline credits the collective term accordingly when
``--compress-grads`` is set (launch/train.py).

This transform is orthogonal to the optimizer: the train step applies
    g_q, residual' = compress(g + residual)
and feeds ``g_q`` to AdamW.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def _quantize(x: jax.Array) -> jax.Array:
    """Blockwise symmetric int8 round-trip (simulates the wire format).

    Blocks run along the last axis so the tensor's sharding is preserved
    (a full flatten is unshardable — §Perf iteration A2)."""
    shape = x.shape
    last = shape[-1] if x.ndim else 1
    pad = (-last) % BLOCK
    xb = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)]) if pad else x
    blocks = xb.reshape(*xb.shape[:-1], -1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale, 1e-12))
    q = jnp.clip(q, -127, 127)
    deq = (q * scale).reshape(*xb.shape[:-1], -1)[..., :last].reshape(shape)
    return deq


def init_residuals(params) -> dict:
    return jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_grads(grads, residuals) -> Tuple[dict, dict]:
    """Returns (quantized grads, new residuals)."""

    def one(g, r):
        g = g.astype(jnp.float32) + r
        q = _quantize(g)
        return q, g - q

    out = jax.tree_util.tree_map(one, grads, residuals)
    qs = jax.tree_util.tree_map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    rs = jax.tree_util.tree_map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return qs, rs

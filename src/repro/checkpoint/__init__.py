from repro.checkpoint.ckpt import CheckpointManager, load_arrays, restore, save

__all__ = ["CheckpointManager", "load_arrays", "restore", "save"]

"""Checkpointing: atomic, restart-safe, mesh-elastic.

Format: one ``.npz`` blob of flattened leaves + a msgpack sidecar with the
treedef paths, step, and user metadata.  Writes go to a temp dir followed
by ``os.replace`` (atomic on POSIX), so a crash mid-save never corrupts
the latest checkpoint — the restore path simply sees the previous one.

Elastic restore: leaves are loaded host-side as numpy and re-placed with
``jax.device_put(x, sharding)`` against whatever mesh the *restoring* job
carved — checkpoints are mesh-shape-agnostic, which is what lets a job
resume on fewer (or more) chips after a failure or an EcoSched rescale.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _set_by_path(tree, path_str: str, value):
    parts = path_str.split("/")
    node = tree
    for p in parts[:-1]:
        node = node[int(p) if isinstance(node, (list, tuple)) else p]
    last = parts[-1]
    node[int(last) if isinstance(node, (list, tuple)) else last] = value


def save(path: str, tree, *, step: int = 0, metadata: Optional[dict] = None) -> None:
    """Atomic checkpoint write of an arbitrary pytree of arrays."""
    flat = _flatten_with_paths(tree)
    # npz has no bf16: store as uint16 bits + dtype sidecar
    dtype_map = {}
    import ml_dtypes

    for k, v in list(flat.items()):
        if v.dtype == ml_dtypes.bfloat16:
            flat[k] = v.view(np.uint16)
            dtype_map[k] = "bfloat16"
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = tempfile.mkdtemp(dir=os.path.dirname(path) or ".")
    try:
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        meta = {
            "step": int(step), "metadata": metadata or {}, "keys": sorted(flat),
            "dtype_map": dtype_map,
        }
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        if os.path.isdir(path):
            shutil.rmtree(path)
        os.replace(tmp, path)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise


def load_arrays(path: str) -> Tuple[Dict[str, np.ndarray], dict]:
    import ml_dtypes

    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    with np.load(os.path.join(path, "arrays.npz")) as z:
        arrays = {k: z[k] for k in z.files}
    for k, dt in meta.get("dtype_map", {}).items():
        if dt == "bfloat16":
            arrays[k] = arrays[k].view(ml_dtypes.bfloat16)
    return arrays, meta


def restore(path: str, like, *, shardings=None) -> Tuple[Any, dict]:
    """Restore into the structure of ``like`` (a template pytree).

    ``shardings``: optional pytree (same structure) of ``NamedSharding`` to
    re-place leaves onto a (possibly different) mesh — the elastic path.
    """
    arrays, meta = load_arrays(path)
    paths = jax.tree_util.tree_flatten_with_path(like)[0]
    shard_leaves = None
    if shardings is not None:
        shard_leaves = jax.tree_util.tree_flatten(shardings)[0]
    leaves = []
    for i, (path_parts, leaf) in enumerate(paths):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path_parts)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = arrays[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: ckpt {arr.shape} vs template {leaf.shape}")
        if shard_leaves is not None:
            leaves.append(jax.device_put(arr, shard_leaves[i]))
        else:
            leaves.append(jax.device_put(arr.astype(leaf.dtype)))
    treedef = jax.tree_util.tree_structure(like)
    return jax.tree_util.tree_unflatten(treedef, leaves), meta


class CheckpointManager:
    """Rotation + async save + latest-checkpoint discovery."""

    def __init__(self, directory: str, *, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:010d}")

    def all_steps(self):
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and os.path.isdir(os.path.join(self.directory, name)):
                if os.path.exists(os.path.join(self.directory, name, "meta.json")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree, metadata: Optional[dict] = None):
        self.wait()
        # snapshot to host memory synchronously; write asynchronously
        host_tree = jax.tree_util.tree_map(lambda x: np.asarray(x), tree)

        def _write():
            save(self._step_dir(step), host_tree, step=step, metadata=metadata)
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def restore_latest(self, like, *, shardings=None):
        self.wait()
        step = self.latest_step()
        if step is None:
            return None, None
        tree, meta = restore(self._step_dir(step), like, shardings=shardings)
        return tree, meta

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

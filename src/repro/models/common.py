"""Shared primitives: norms, rotary embeddings, SwiGLU, init helpers.

Everything is a pure function over explicit parameter pytrees — no module
framework.  Parameters live in nested dicts; layer stacks carry a leading
``L`` axis so the model can scan over layers (MaxText-style).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32, "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# Init helpers
# ---------------------------------------------------------------------------


def dense_init(key, in_dim: int, out_dim: int, dtype) -> jax.Array:
    scale = 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def head_rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """qk-norm: RMS over the head_dim of (..., H, hd) tensors."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    if theta <= 0:
        return x
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(hd, theta))  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    angles = angles[..., None, :]  # (..., S, 1, hd/2) broadcast over heads
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(num_pos: int, dim: int) -> np.ndarray:
    """Whisper-style fixed sinusoidal embedding table (num_pos, dim)."""
    log_timescale = math.log(10_000) / (dim // 2 - 1)
    inv = np.exp(-log_timescale * np.arange(dim // 2, dtype=np.float32))
    scaled = np.arange(num_pos, dtype=np.float32)[:, None] * inv[None, :]
    return np.concatenate([np.sin(scaled), np.cos(scaled)], axis=1)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------


def swiglu_init(key, d_model: int, d_ff: int, dtype) -> dict:
    kg, ku, kd = jax.random.split(key, 3)
    return {
        "gate": dense_init(kg, d_model, d_ff, dtype),
        "up": dense_init(ku, d_model, d_ff, dtype),
        "down": dense_init(kd, d_ff, d_model, dtype),
    }


def swiglu_apply(p: dict, x: jax.Array) -> jax.Array:
    g = jax.nn.silu(x @ p["gate"])
    return (g * (x @ p["up"])) @ p["down"]


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------


def softmax_cross_entropy(logits: jax.Array, labels: jax.Array, z_loss: float = 0.0):
    """logits (..., V) fp32-accumulated CE with optional z-loss; labels int."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    target = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = lse - target
    if z_loss:
        loss = loss + z_loss * jnp.square(lse)
    return loss

"""Mamba2 / SSD (state-space duality) layer.

Chunked dual form (arXiv:2405.21060): the sequence is split into chunks of
``Q`` tokens; within a chunk the output is a (masked, decay-weighted)
attention-like quadratic form, and states propagate across chunks through a
scalar-decay linear recurrence.  The cross-chunk recurrence is evaluated
with ``jax.lax.associative_scan`` — log-depth combine, **no while loop** —
so XLA cost analysis counts its FLOPs correctly (DESIGN.md §4) and the
whole layer stays MXU-friendly.

Projections are stored **split** (z, x, B/C, Δ) rather than as one fused
in_proj: z/x/conv_x are head-aligned and tensor-parallel over the model
axis, while B/C/Δ are shared across heads and stay replicated — a fused
matrix could not express that partitioning (DESIGN.md §3).

Decode is the O(1) recurrent step:  h ← e^{AΔ}·h + Δ·B⊗x,  y = C·h + D·x,
with a small causal-conv ring buffer.

The per-chunk quadratic inner core is also available as a Pallas TPU
kernel (kernels/ssd_scan.py); this module is the XLA reference path.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, rms_norm


def ssd_init(key, cfg, dtype) -> dict:
    d = cfg.d_model
    di = cfg.d_inner
    N = cfg.ssm_state
    nh = cfg.ssm_heads
    w = cfg.ssm_conv
    kz, kx, kbc, kdt, kcx, kcbc, kout = jax.random.split(key, 7)
    a_init = jnp.log(jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32))
    dt_bias = jnp.log(jnp.expm1(jnp.linspace(1e-3, 1e-1, nh, dtype=jnp.float32)))
    return {
        "wz": dense_init(kz, d, di, dtype),
        "wx": dense_init(kx, d, di, dtype),
        "wbc": dense_init(kbc, d, 2 * N, dtype),
        "wdt": dense_init(kdt, d, nh, dtype),
        "conv_x": (jax.random.normal(kcx, (w, di), jnp.float32) / math.sqrt(w)).astype(dtype),
        "conv_bc": (jax.random.normal(kcbc, (w, 2 * N), jnp.float32) / math.sqrt(w)).astype(dtype),
        "conv_bx": jnp.zeros((di,), dtype),
        "conv_bbc": jnp.zeros((2 * N,), dtype),
        "A_log": a_init,
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": dt_bias,
        "norm": jnp.zeros((di,), dtype),
        "out_proj": dense_init(kout, di, d, dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over (B, S, ch) with kernel (w, ch) + silu."""
    W = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros(x.shape, jnp.float32)
    for i in range(W):  # tiny static loop (W == 4)
        out = out + pad[:, i : i + x.shape[1]].astype(jnp.float32) * w[i].astype(jnp.float32)
    return jax.nn.silu(out + b.astype(jnp.float32)).astype(x.dtype)


def ssd_chunked(
    xh: jax.Array,  # (B, S, nh, hp) inputs per head
    dt: jax.Array,  # (B, S, nh) positive step sizes
    A: jax.Array,  # (nh,) negative decay rates
    Bm: jax.Array,  # (B, S, N)
    Cm: jax.Array,  # (B, S, N)
    *,
    chunk: int,
    h0: Optional[jax.Array] = None,  # (B, nh, hp, N) initial state
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,nh,hp) fp32, final_state (B,nh,hp,N) fp32)."""
    B, S, nh, hp = xh.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    S_orig = S
    if S % Q:  # pad tail: dt=0 ⇒ decay=1 and zero deposit ⇒ exact
        pad = Q - S % Q
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nc = S // Q

    xc = xh.reshape(B, nc, Q, nh, hp)
    dtc = dt.reshape(B, nc, Q, nh).astype(jnp.float32)
    Bc = Bm.reshape(B, nc, Q, N)
    Cc = Cm.reshape(B, nc, Q, N)

    a = dtc * A  # (B,nc,Q,nh) negative log-decay per step
    La = jnp.cumsum(a, axis=2)  # inclusive within-chunk cumulative
    Ltot = La[:, :, -1]  # (B,nc,nh)

    # ---- intra-chunk (quadratic dual form) --------------------------------
    cb = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc, preferred_element_type=jnp.float32)
    decay = jnp.exp(La[:, :, :, None, :] - La[:, :, None, :, :])  # (B,nc,Q,Q,nh)
    idx = jnp.arange(Q)
    causal = (idx[:, None] >= idx[None, :])[None, None, :, :, None]
    scores = cb[..., None] * jnp.where(causal, decay, 0.0) * dtc[:, :, None, :, :]
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", scores, xc.astype(jnp.float32))

    # ---- chunk states -------------------------------------------------------
    w_state = jnp.exp(Ltot[:, :, None, :] - La) * dtc  # (B,nc,Q,nh)
    S_chunk = jnp.einsum("bcqn,bcqh,bcqhp->bchpn", Bc, w_state, xc.astype(jnp.float32))

    # ---- cross-chunk recurrence (associative scan, log-depth) --------------
    chunk_decay = jnp.exp(Ltot)  # (B,nc,nh)

    def combine(left, right):
        a_l, s_l = left
        a_r, s_r = right
        return a_l * a_r, s_l * a_r[..., None, None] + s_r

    if h0 is not None:
        chunk_decay = jnp.concatenate(
            [jnp.ones((B, 1, nh), chunk_decay.dtype), chunk_decay], axis=1
        )
        S_chunk = jnp.concatenate([h0.astype(jnp.float32)[:, None], S_chunk], axis=1)
        H_inc = jax.lax.associative_scan(combine, (chunk_decay, S_chunk), axis=1)[1]
        H_prev = H_inc[:, :-1]
        final = H_inc[:, -1]
    else:
        H_inc = jax.lax.associative_scan(combine, (chunk_decay, S_chunk), axis=1)[1]
        H_prev = jnp.concatenate([jnp.zeros_like(H_inc[:, :1]), H_inc[:, :-1]], axis=1)
        final = H_inc[:, -1]

    # ---- inter-chunk contribution ------------------------------------------
    y_inter = jnp.einsum("bcqn,bchpn->bcqhp", Cc, H_prev) * jnp.exp(La)[..., None]

    y = (y_intra + y_inter).reshape(B, S, nh, hp)[:, :S_orig]
    return y, final  # final: (B, nh, hp, N)


def ssd_forward(
    p: dict, x: jax.Array, cfg, *, h0: Optional[jax.Array] = None, use_pallas: bool = False
):
    """Full Mamba2 block over (B, S, d).

    Returns (out (B,S,d), final_state (B,nh,hp,N), conv_tail (B,w-1,di+2N)).
    """
    B, S, d = x.shape
    di, N, nh, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z = x @ p["wz"]
    xr = x @ p["wx"]
    bc = x @ p["wbc"]
    dt = x @ p["wdt"]
    xr = _causal_conv(xr, p["conv_x"], p["conv_bx"])
    bc = _causal_conv(bc, p["conv_bc"], p["conv_bbc"])
    xs = xr.reshape(B, S, nh, hp)
    Bm = bc[..., :N]
    Cm = bc[..., N:]
    dtp = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    if use_pallas:
        from repro.kernels import ops as kops

        y, state = kops.ssd_scan(xs, dtp, A, Bm, Cm, chunk=cfg.ssm_chunk)
    else:
        y, state = ssd_chunked(xs, dtp, A, Bm, Cm, chunk=cfg.ssm_chunk, h0=h0)
    y = y + xs.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(B, S, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    w = cfg.ssm_conv
    # conv tails store the *pre-conv* inputs needed to resume decoding
    tail_x = (x @ p["wx"])[:, max(S - (w - 1), 0) :, :]
    tail_bc = (x @ p["wbc"])[:, max(S - (w - 1), 0) :, :]
    conv_tail = jnp.concatenate([tail_x, tail_bc], axis=-1)
    return y @ p["out_proj"], state, conv_tail


def ssd_apply(p: dict, x: jax.Array, cfg) -> jax.Array:
    return ssd_forward(p, x, cfg)[0]


# ---------------------------------------------------------------------------
# Decode (recurrent) path
# ---------------------------------------------------------------------------


def ssd_decode_step(p: dict, state: dict, x: jax.Array, cfg):
    """x: (B, 1, d) single token.  Returns (out (B,1,d), new_state).

    state = {"conv": (B, w-1, di+2N) pre-conv inputs, "h": (B,nh,hp,N)}.
    """
    B = x.shape[0]
    di, N, nh, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    x0 = x[:, 0]
    z = x0 @ p["wz"]
    xr = x0 @ p["wx"]
    bc = x0 @ p["wbc"]
    dt = x0 @ p["wdt"]

    cur = jnp.concatenate([xr, bc], axis=-1)  # (B, di+2N)
    win = jnp.concatenate([state["conv"], cur[:, None, :]], axis=1)  # (B, w, ch)
    kern = jnp.concatenate([p["conv_x"], p["conv_bc"]], axis=-1)  # (w, ch)
    bias = jnp.concatenate([p["conv_bx"], p["conv_bbc"]], axis=-1)
    conv_out = jnp.einsum("bwc,wc->bc", win.astype(jnp.float32), kern.astype(jnp.float32))
    act = jax.nn.silu(conv_out + bias.astype(jnp.float32)).astype(x.dtype)
    new_conv = win[:, 1:]

    xs = act[..., :di].reshape(B, nh, hp)
    Bm = act[..., di : di + N]
    Cm = act[..., di + N :]
    dtp = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,nh)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dtp * A)  # (B,nh)

    h = state["h"] * dA[..., None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dtp, xs.astype(jnp.float32), Bm.astype(jnp.float32)
    )
    y = jnp.einsum("bn,bhpn->bhp", Cm.astype(jnp.float32), h)
    y = y + xs.astype(jnp.float32) * p["D"][None, :, None]
    y = y.reshape(B, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = (y @ p["out_proj"])[:, None, :]
    return out, {"conv": new_conv, "h": h}

"""Attention: GQA with causal / sliding-window / cross variants.

Two XLA paths plus the Pallas TPU kernel:

* ``dense``   — materializes the full score tensor.  Used for short
  sequences and for decode (Sq == 1).
* ``blocked`` — flash-style running-softmax over (q_chunk × kv_chunk)
  blocks.  The block loops are **python-unrolled** on purpose: the dry-run
  derives roofline terms from XLA cost analysis, which counts a `lax.scan`
  body only once (DESIGN.md §4).  Fully-masked blocks are skipped at trace
  time, so sliding-window layers get near-linear compute.
* ``pallas``  — kernels/flash_attention.py (TPU target; validated in
  interpret mode).  Selected via ``impl='pallas'``.

Shapes: q (B, Sq, H, hd); k, v (B, Skv, KVH, hd) with H % KVH == 0.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _softcap(scores: jax.Array, cap: float) -> jax.Array:
    if cap <= 0:
        return scores
    return cap * jnp.tanh(scores / cap)


def _block_mask(
    q_pos: jax.Array,  # (Sq,) absolute positions of queries
    kv_pos: jax.Array,  # (Skv,) absolute positions of keys
    *,
    causal: bool,
    window: int,
    kv_valid_len: Optional[jax.Array],
) -> jax.Array:  # noqa: D401
    """Boolean (Sq, Skv) mask: True = attend."""
    mask = jnp.ones((q_pos.shape[0], kv_pos.shape[0]), dtype=bool)
    if causal:
        mask &= kv_pos[None, :] <= q_pos[:, None]
    if window > 0:
        mask &= kv_pos[None, :] > (q_pos[:, None] - window)
    if kv_valid_len is not None:
        mask &= kv_pos[None, :] < kv_valid_len
    return mask


def _scores(q: jax.Array, k: jax.Array, scale: float, softcap: float) -> jax.Array:
    """q (B,Sq,KVH,G,hd) × k (B,Skv,KVH,hd) -> (B,KVH,G,Sq,Skv) fp32."""
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q, k, preferred_element_type=jnp.float32)
    return _softcap(s * scale, softcap)


def dense_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int | jax.Array = 0,
    kv_offset: int | jax.Array = 0,
    kv_valid_len: Optional[jax.Array] = None,
    softcap: float = 0.0,
    scale: Optional[float] = None,
) -> jax.Array:
    B, Sq, H, hd = q.shape
    _, Skv, KVH, _ = k.shape
    G = H // KVH
    scale = scale or (1.0 / math.sqrt(hd))
    qg = q.reshape(B, Sq, KVH, G, hd)
    s = _scores(qg, k, scale, softcap)  # (B,KVH,G,Sq,Skv)
    q_pos = jnp.arange(Sq) + q_offset
    kv_pos = jnp.arange(Skv) + kv_offset
    mask = _block_mask(q_pos, kv_pos, causal=causal, window=window, kv_valid_len=kv_valid_len)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)
    return o.reshape(B, Sq, H, hd)


def blocked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    q_chunk: int = 1024,
    kv_chunk: int = 2048,
    scale: Optional[float] = None,
) -> jax.Array:
    """Flash-style blocked attention, python-unrolled blocks, fp32 softmax.

    Assumes self-attention over a full sequence (q_offset == 0,
    kv_valid_len == Skv); decode uses ``dense_attention``.
    """
    B, Sq, H, hd = q.shape
    _, Skv, KVH, _ = k.shape
    G = H // KVH
    scale = scale or (1.0 / math.sqrt(hd))
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    assert Sq % q_chunk == 0 and Skv % kv_chunk == 0, (Sq, q_chunk, Skv, kv_chunk)

    out_chunks = []
    for qi in range(Sq // q_chunk):
        q_lo, q_hi = qi * q_chunk, (qi + 1) * q_chunk
        qg = q[:, q_lo:q_hi].reshape(B, q_chunk, KVH, G, hd)
        m = jnp.full((B, KVH, G, q_chunk), NEG_INF, jnp.float32)
        l = jnp.zeros((B, KVH, G, q_chunk), jnp.float32)
        o = jnp.zeros((B, KVH, G, q_chunk, hd), jnp.float32)
        for kj in range(Skv // kv_chunk):
            k_lo, k_hi = kj * kv_chunk, (kj + 1) * kv_chunk
            # trace-time block skipping
            if causal and k_lo > q_hi - 1:
                continue
            if window > 0 and k_hi - 1 <= q_lo - window:
                continue
            s = _scores(qg, k[:, k_lo:k_hi], scale, softcap)  # (B,KVH,G,qc,kc)
            needs_mask = (causal and k_hi > q_lo) or (window > 0 and k_lo <= q_hi - window)
            if needs_mask:
                mask = _block_mask(
                    jnp.arange(q_lo, q_hi),
                    jnp.arange(k_lo, k_hi),
                    causal=causal,
                    window=window,
                    kv_valid_len=None,
                )
                s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l = l * alpha + p.sum(axis=-1)
            pv = jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(v.dtype), v[:, k_lo:k_hi],
                preferred_element_type=jnp.float32,
            )
            o = o * alpha[..., None] + pv
            m = m_new
        o = o / jnp.maximum(l[..., None], 1e-37)
        out_chunks.append(
            o.transpose(0, 3, 1, 2, 4).reshape(B, q_chunk, H, hd).astype(q.dtype)
        )
    return jnp.concatenate(out_chunks, axis=1)


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int | jax.Array = 0,
    kv_valid_len: Optional[jax.Array] = None,
    kv_offset: int | jax.Array = 0,
    softcap: float = 0.0,
    impl: str = "auto",
    q_chunk: int = 1024,
    kv_chunk: int = 2048,
) -> jax.Array:
    """Dispatching entry point used by the model zoo."""
    Sq, Skv = q.shape[1], k.shape[1]
    if impl == "pallas":
        from repro.kernels import ops as kops

        if Sq == Skv and kv_valid_len is None:
            return kops.flash_attention(
                q, k, v, causal=causal, window=window, softcap=softcap
            )
        impl = "auto"  # decode / ragged falls back
    if impl == "auto":
        impl = "dense" if (Sq == 1 or Skv <= max(kv_chunk, 2048)) else "blocked"
    if impl == "dense":
        return dense_attention(
            q, k, v,
            causal=causal, window=window, q_offset=q_offset,
            kv_offset=kv_offset, kv_valid_len=kv_valid_len, softcap=softcap,
        )
    if impl == "blocked":
        assert kv_valid_len is None and (isinstance(q_offset, int) and q_offset == 0)
        return blocked_attention(
            q, k, v,
            causal=causal, window=window, softcap=softcap,
            q_chunk=q_chunk, kv_chunk=kv_chunk,
        )
    raise ValueError(f"unknown attention impl {impl!r}")

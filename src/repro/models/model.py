"""Unified model zoo: one ``Model`` class driving all 10 assigned archs.

Families: dense / moe / ssm / hybrid / vlm / audio (enc-dec).  One stacked
parameter tree (leading ``L`` axis) scanned over layers.  Local:global
attention patterns (gemma3 5:1, hymba 7:1) are handled by scanning over
*periods* — the period body is python-unrolled so every layer's window flag
is trace-time static (required for block-skipping in blocked attention).

API (pure functions over explicit param pytrees):
    init(rng)                        -> params
    forward(params, batch)           -> logits            (teacher forcing)
    loss(params, batch)              -> (loss, metrics)
    prefill(params, batch)           -> (last_logits, cache)
    init_cache(batch, cache_len)     -> zeroed cache pytree
    decode_step(params, cache, token, pos) -> (logits, cache)

Modality frontends are stubs per the assignment: batches carry precomputed
patch/frame embeddings (``patch_embeds`` / ``src_embeds``).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.ctx import constrain
from repro.models import ssd as ssd_mod
from repro.models.attention import attention
from repro.models.common import (
    apply_rope,
    dense_init,
    dtype_of,
    embed_init,
    head_rms_norm,
    rms_norm,
    sinusoidal_positions,
    softmax_cross_entropy,
    swiglu_apply,
    swiglu_init,
)
from repro.models.moe import moe_apply, moe_aux_loss, moe_init


@dataclass(frozen=True)
class Runtime:
    """Implementation knobs orthogonal to the architecture."""

    attn_impl: str = "auto"  # auto | dense | blocked | pallas
    remat: str = "full"  # none | full | dots
    capacity_factor: float = 1.25
    moe_aux_coef: float = 0.01
    # §Perf G1: decode on sliding-window layers slices the last ``window``
    # cache entries instead of masking the full sequence — O(window) HBM
    # reads per local layer instead of O(S).
    decode_window_slice: bool = False
    # §Perf A1: "ep" routes MoE through the expert-parallel shard_map path
    # (requires a mesh_context); "auto" uses it whenever a mesh is active
    # and E divides the model axis; "dense" keeps the scatter path.
    moe_impl: str = "dense"


def _remat(fn, mode: str):
    if mode == "none":
        return fn
    if mode == "dots":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)


def _tmap(fn, *trees):
    return jax.tree_util.tree_map(fn, *trees)


def _sinusoid_at(pos, dim: int):
    """Sinusoidal embedding for scalar position(s) without a full table."""
    half = dim // 2
    log_timescale = math.log(10_000) / (half - 1)
    inv = jnp.exp(-log_timescale * jnp.arange(half, dtype=jnp.float32))
    scaled = jnp.asarray(pos, jnp.float32)[..., None] * inv
    return jnp.concatenate([jnp.sin(scaled), jnp.cos(scaled)], axis=-1)


class Model:
    def __init__(self, cfg: ModelConfig, rt: Runtime = Runtime()):
        self.cfg = cfg
        self.rt = rt
        self.dtype = dtype_of(cfg.dtype)
        self.period = (
            cfg.local_global_ratio + 1
            if cfg.attention_pattern == "local_global"
            else 1
        )
        self.n_scan = cfg.num_layers // self.period
        self.n_tail = cfg.num_layers - self.n_scan * self.period
        self._enc_out = None  # set during enc-dec traces

    # ==================================================================
    # Init
    # ==================================================================
    def _init_block(self, key) -> dict:
        cfg, dt = self.cfg, self.dtype
        d = cfg.d_model
        keys = jax.random.split(key, 10)
        block: Dict[str, Any] = {}
        if cfg.uses_attention:
            attn = {
                "ln": jnp.zeros((d,), dt),
                "wq": dense_init(keys[0], d, cfg.q_dim, dt),
                "wk": dense_init(keys[1], d, cfg.kv_dim, dt),
                "wv": dense_init(keys[2], d, cfg.kv_dim, dt),
                "wo": dense_init(keys[3], cfg.q_dim, d, dt),
            }
            if cfg.qk_norm:
                attn["q_norm"] = jnp.zeros((cfg.resolved_head_dim,), dt)
                attn["k_norm"] = jnp.zeros((cfg.resolved_head_dim,), dt)
            block["attn"] = attn
        if cfg.uses_ssm:
            block["ssm"] = ssd_mod.ssd_init(keys[4], cfg, dt)
            if not cfg.uses_attention:
                block["ssm_ln"] = jnp.zeros((d,), dt)
        if cfg.cross_attention:
            block["cross"] = {
                "ln": jnp.zeros((d,), dt),
                "wq": dense_init(keys[5], d, cfg.q_dim, dt),
                "wk": dense_init(keys[6], d, cfg.kv_dim, dt),
                "wv": dense_init(keys[7], d, cfg.kv_dim, dt),
                "wo": dense_init(keys[8], cfg.q_dim, d, dt),
            }
        if cfg.uses_moe:
            block["moe_ln"] = jnp.zeros((d,), dt)
            block["moe"] = moe_init(keys[9], cfg, dt)
        elif cfg.d_ff:
            block["mlp_ln"] = jnp.zeros((d,), dt)
            block["mlp"] = swiglu_init(keys[9], d, cfg.d_ff, dt)
        return block

    def _init_enc_block(self, key) -> dict:
        cfg, dt = self.cfg, self.dtype
        d = cfg.d_model
        keys = jax.random.split(key, 5)
        return {
            "attn": {
                "ln": jnp.zeros((d,), dt),
                "wq": dense_init(keys[0], d, cfg.q_dim, dt),
                "wk": dense_init(keys[1], d, cfg.kv_dim, dt),
                "wv": dense_init(keys[2], d, cfg.kv_dim, dt),
                "wo": dense_init(keys[3], cfg.q_dim, d, dt),
            },
            "mlp_ln": jnp.zeros((d,), dt),
            "mlp": swiglu_init(keys[4], d, cfg.d_ff, dt),
        }

    def init(self, rng) -> dict:
        cfg, dt = self.cfg, self.dtype
        k_embed, k_blocks, k_head, k_enc = jax.random.split(rng, 4)
        params: Dict[str, Any] = {
            "embed": embed_init(k_embed, cfg.vocab_size, cfg.d_model, dt),
            "blocks": jax.vmap(self._init_block)(
                jax.random.split(k_blocks, cfg.num_layers)
            ),
            "final_norm": jnp.zeros((cfg.d_model,), dt),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = dense_init(k_head, cfg.d_model, cfg.vocab_size, dt)
        if cfg.is_encoder_decoder:
            params["enc_blocks"] = jax.vmap(self._init_enc_block)(
                jax.random.split(k_enc, cfg.num_encoder_layers)
            )
            params["enc_norm"] = jnp.zeros((cfg.d_model,), dt)
        return params

    # ==================================================================
    # Sublayers
    # ==================================================================
    def _qkv(self, attn_bp: dict, h: jax.Array, positions):
        cfg = self.cfg
        hd = cfg.resolved_head_dim
        B, S, _ = h.shape
        x = rms_norm(h, attn_bp["ln"], cfg.norm_eps)
        q = (x @ attn_bp["wq"]).reshape(B, S, cfg.num_heads, hd)
        k = (x @ attn_bp["wk"]).reshape(B, S, cfg.num_kv_heads, hd)
        v = (x @ attn_bp["wv"]).reshape(B, S, cfg.num_kv_heads, hd)
        if cfg.qk_norm:
            q = head_rms_norm(q, attn_bp["q_norm"], cfg.norm_eps)
            k = head_rms_norm(k, attn_bp["k_norm"], cfg.norm_eps)
        if cfg.rope_theta > 0:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
        return q, k, v

    def _attn_sublayer(self, attn_bp, h, *, is_global: bool, positions) -> jax.Array:
        cfg, rt = self.cfg, self.rt
        window = 0 if is_global else cfg.sliding_window
        q, k, v = self._qkv(attn_bp, h, positions)
        o = attention(
            q, k, v,
            causal=True,
            window=window,
            softcap=cfg.attn_logit_softcap,
            impl=rt.attn_impl,
            q_chunk=cfg.attn_q_chunk,
            kv_chunk=cfg.attn_kv_chunk,
        )
        o = constrain(o.reshape(*h.shape[:2], cfg.q_dim), "attn_out")
        return o @ attn_bp["wo"]

    def _mlp_sublayer(self, bp, h) -> jax.Array:
        cfg = self.cfg
        if cfg.uses_moe:
            x = rms_norm(h, bp["moe_ln"], cfg.norm_eps)
            if self.rt.moe_impl in ("ep", "auto"):
                from repro.distributed.ctx import current_mesh
                from repro.models.moe import moe_apply_ep

                mesh = current_mesh()
                if mesh is not None and cfg.num_experts % mesh.shape.get("model", 1) == 0:
                    return moe_apply_ep(
                        bp["moe"], x, cfg, mesh,
                        capacity_factor=self.rt.capacity_factor,
                    )
                if self.rt.moe_impl == "ep":
                    raise RuntimeError("moe_impl='ep' requires an active mesh_context")
            return moe_apply(bp["moe"], x, cfg, capacity_factor=self.rt.capacity_factor)
        x = rms_norm(h, bp["mlp_ln"], cfg.norm_eps)
        return swiglu_apply(bp["mlp"], x)

    def _ssm_prenorm(self, bp, h) -> jax.Array:
        cfg = self.cfg
        ln = bp["ssm_ln"] if "ssm_ln" in bp else bp["attn"]["ln"]
        return rms_norm(h, ln, cfg.norm_eps)

    def _cross_sublayer(self, cp, h, enc_out) -> jax.Array:
        cfg = self.cfg
        hd = cfg.resolved_head_dim
        B, S, _ = h.shape
        Se = enc_out.shape[1]
        x = rms_norm(h, cp["ln"], cfg.norm_eps)
        q = (x @ cp["wq"]).reshape(B, S, cfg.num_heads, hd)
        k = (enc_out @ cp["wk"]).reshape(B, Se, cfg.num_kv_heads, hd)
        v = (enc_out @ cp["wv"]).reshape(B, Se, cfg.num_kv_heads, hd)
        o = attention(q, k, v, causal=False, impl="dense")
        return o.reshape(B, S, cfg.q_dim) @ cp["wo"]

    # ==================================================================
    # One layer: train-forward / prefill / decode
    # ==================================================================
    def _block_fwd(self, bp, h, *, is_global: bool, positions) -> jax.Array:
        cfg = self.cfg
        if cfg.family == "ssm":
            return h + ssd_mod.ssd_apply(bp["ssm"], self._ssm_prenorm(bp, h), cfg)
        if cfg.parallel_ssm:
            a = self._attn_sublayer(bp["attn"], h, is_global=is_global, positions=positions)
            s = ssd_mod.ssd_apply(bp["ssm"], self._ssm_prenorm(bp, h), cfg)
            h = h + a + s
        else:
            h = h + self._attn_sublayer(bp["attn"], h, is_global=is_global, positions=positions)
        if "cross" in bp:
            h = h + self._cross_sublayer(bp["cross"], h, self._enc_out)
        h = h + self._mlp_sublayer(bp, h)
        return constrain(h, "residual")

    def _block_prefill(self, bp, h, *, is_global: bool, positions):
        """Like _block_fwd but also returns this layer's cache entries."""
        cfg = self.cfg
        B, S, _ = h.shape
        lc: Dict[str, Any] = {}
        parts = []
        if cfg.uses_attention:
            window = 0 if is_global else cfg.sliding_window
            q, k, v = self._qkv(bp["attn"], h, positions)
            o = attention(
                q, k, v, causal=True, window=window,
                softcap=cfg.attn_logit_softcap, impl=self.rt.attn_impl,
                q_chunk=cfg.attn_q_chunk, kv_chunk=cfg.attn_kv_chunk,
            )
            parts.append(o.reshape(B, S, cfg.q_dim) @ bp["attn"]["wo"])
            lc["k"], lc["v"] = k, v
        if cfg.uses_ssm:
            x = self._ssm_prenorm(bp, h)
            out, state, conv_tail = self._ssd_with_state(bp["ssm"], x)
            parts.append(out)
            lc["h"] = state
            lc["conv"] = conv_tail
        h = h + sum(parts)
        if "cross" in bp:
            hd = cfg.resolved_head_dim
            Se = self._enc_out.shape[1]
            lc["cross_k"] = (self._enc_out @ bp["cross"]["wk"]).reshape(
                B, Se, cfg.num_kv_heads, hd
            )
            lc["cross_v"] = (self._enc_out @ bp["cross"]["wv"]).reshape(
                B, Se, cfg.num_kv_heads, hd
            )
            h = h + self._cross_sublayer(bp["cross"], h, self._enc_out)
        if cfg.uses_moe or cfg.d_ff:
            h = h + self._mlp_sublayer(bp, h)
        return h, lc

    def _ssd_with_state(self, sp, x):
        """SSD over a full sequence, returning output + decode-ready state."""
        return ssd_mod.ssd_forward(sp, x, self.cfg)

    def _striped_attention(self, q, k6, v6, pos, *, window: int, is_global: bool):
        """Attention over a striped (B, nblk, w, KVH, hd) cache.

        Local layers read only the ≤2 blocks covering [pos-w+1, pos];
        global layers read all blocks.  Scores keep the (block, offset)
        axes so the sharded offset dim never reshapes across shards.
        """
        cfg = self.cfg
        B, _, H, hd = q.shape
        KVH = k6.shape[-2]
        G = H // KVH
        w = k6.shape[2]
        scale = 1.0 / math.sqrt(hd)
        qg = q.reshape(B, 1, KVH, G, hd)
        if is_global:
            k_att, v_att, blk0 = k6, v6, 0
        else:
            nblk = k6.shape[1]
            blk = pos // w
            blk0 = jnp.clip(blk - 1, 0, nblk - 2)
            k_att = jax.lax.dynamic_slice_in_dim(k6, blk0, 2, 1)
            v_att = jax.lax.dynamic_slice_in_dim(v6, blk0, 2, 1)
        s = jnp.einsum(
            "bqhgd,bBwhd->bhgqBw", qg, k_att, preferred_element_type=jnp.float32
        ) * scale
        if cfg.attn_logit_softcap > 0:
            s = cfg.attn_logit_softcap * jnp.tanh(s / cfg.attn_logit_softcap)
        nB, nw = k_att.shape[1], k_att.shape[2]
        pos_abs = (blk0 + jax.lax.broadcasted_iota(jnp.int32, (nB, nw), 0)) * w \
            + jax.lax.broadcasted_iota(jnp.int32, (nB, nw), 1)
        mask = pos_abs <= pos
        if not is_global:
            mask &= pos_abs > pos - window
        s = jnp.where(mask[None, None, None, None], s, -1e30)
        # softmax jointly over (block, offset) WITHOUT flattening — a
        # reshape across the sharded offset dim forced a scores all-gather
        # (§Perf iteration G3); axis reductions shard cleanly instead.
        m = jnp.max(s, axis=(-2, -1), keepdims=True)
        p = jnp.exp(s - m)
        l = jnp.sum(p, axis=(-2, -1), keepdims=True)
        p = p / jnp.maximum(l, 1e-37)
        o = jnp.einsum("bhgqBw,bBwhd->bqhgd", p.astype(v_att.dtype), v_att)
        return o.reshape(B, 1, H, hd)

    def _block_decode(self, bp, lc, h, pos, *, is_global: bool):
        """One layer of single-token decode.  h (B, 1, d)."""
        cfg = self.cfg
        nc = dict(lc)
        positions = jnp.full((h.shape[0], 1), pos)
        parts = []
        if cfg.uses_attention and lc.get("k") is not None and lc["k"].ndim == 5:
            # striped cache layout (B, nblk, w, KVH, hd)
            q, k_new, v_new = self._qkv(bp["attn"], h, positions)
            w = lc["k"].shape[2]
            blk, off = pos // w, pos % w
            k_cache = jax.lax.dynamic_update_slice(
                lc["k"], k_new[:, None], (0, blk, off, 0, 0)
            )
            v_cache = jax.lax.dynamic_update_slice(
                lc["v"], v_new[:, None], (0, blk, off, 0, 0)
            )
            window = 0 if is_global else cfg.sliding_window
            o = self._striped_attention(
                q, k_cache, v_cache, pos, window=window, is_global=is_global
            )
            parts.append(o.reshape(*h.shape[:2], cfg.q_dim) @ bp["attn"]["wo"])
            nc["k"], nc["v"] = k_cache, v_cache
        elif cfg.uses_attention:
            q, k_new, v_new = self._qkv(bp["attn"], h, positions)
            k_cache = jax.lax.dynamic_update_slice(lc["k"], k_new, (0, pos, 0, 0))
            v_cache = jax.lax.dynamic_update_slice(lc["v"], v_new, (0, pos, 0, 0))
            window = 0 if is_global else cfg.sliding_window
            S_cap = lc["k"].shape[1]
            if self.rt.decode_window_slice and window and window < S_cap:
                # §Perf G1: touch only the window, not the whole cache
                start = jnp.clip(pos - window + 1, 0, S_cap - window)
                k_att = jax.lax.dynamic_slice_in_dim(k_cache, start, window, 1)
                v_att = jax.lax.dynamic_slice_in_dim(v_cache, start, window, 1)
                kv_off = start
            else:
                k_att, v_att, kv_off = k_cache, v_cache, 0
            o = attention(
                q, k_att, v_att,
                causal=False,  # masking via kv_valid_len / window
                window=window,
                q_offset=pos,
                kv_offset=kv_off,
                kv_valid_len=pos + 1,
                softcap=cfg.attn_logit_softcap,
                impl="dense",
            )
            parts.append(o.reshape(*h.shape[:2], cfg.q_dim) @ bp["attn"]["wo"])
            nc["k"], nc["v"] = k_cache, v_cache
        if cfg.uses_ssm:
            x = self._ssm_prenorm(bp, h)
            s_out, s_state = ssd_mod.ssd_decode_step(
                bp["ssm"], {"conv": lc["conv"], "h": lc["h"]}, x, cfg
            )
            parts.append(s_out)
            nc["conv"], nc["h"] = s_state["conv"], s_state["h"]
        h = h + sum(parts)
        if "cross" in bp:
            h = h + self._cross_decode(bp["cross"], h, lc)
        if cfg.uses_moe or cfg.d_ff:
            h = h + self._mlp_sublayer(bp, h)
        return h, nc

    def _cross_decode(self, cp, h, lc) -> jax.Array:
        cfg = self.cfg
        hd = cfg.resolved_head_dim
        B, S, _ = h.shape
        x = rms_norm(h, cp["ln"], cfg.norm_eps)
        q = (x @ cp["wq"]).reshape(B, S, cfg.num_heads, hd)
        o = attention(q, lc["cross_k"], lc["cross_v"], causal=False, impl="dense")
        return o.reshape(B, S, cfg.q_dim) @ cp["wo"]

    # ==================================================================
    # Layer-stack traversal: scan over periods, unrolled tail.
    # ``layer_fn(bp, carry, layer_idx_in_period, li) -> (carry, ys|None)``
    # ==================================================================
    def _traverse(self, blocks, carry, layer_fn, extra_xs: Optional[dict] = None):
        cfg, period = self.cfg, self.period
        n_scan, n_tail = self.n_scan, self.n_tail
        ys_all = None
        if n_scan == 0 and n_tail == 0:
            return carry, extra_xs

        if n_scan:
            scanned_bp = _tmap(
                lambda x: x[: n_scan * period].reshape(n_scan, period, *x.shape[1:]),
                blocks,
            )
            scanned_xs = None
            if extra_xs is not None:
                scanned_xs = _tmap(
                    lambda x: x[: n_scan * period].reshape(n_scan, period, *x.shape[1:]),
                    extra_xs,
                )

            def period_fn(c, xs):
                bp_p, xs_p = xs
                ys_layers = []
                for j in range(period):
                    bp = _tmap(lambda x: x[j], bp_p)
                    x_j = None if xs_p is None else _tmap(lambda x: x[j], xs_p)
                    c, ys = layer_fn(bp, c, j, x_j)
                    ys_layers.append(ys)
                if ys_layers[0] is None:
                    return c, None
                return c, _tmap(lambda *a: jnp.stack(a), *ys_layers)

            carry, ys_all = jax.lax.scan(
                _remat(period_fn, self.rt.remat), carry, (scanned_bp, scanned_xs)
            )
            if ys_all is not None:
                ys_all = _tmap(
                    lambda x: x.reshape(n_scan * period, *x.shape[2:]), ys_all
                )

        tail_ys = []
        for i in range(n_tail):
            li = n_scan * period + i
            bp = _tmap(lambda x: x[li], blocks)
            x_i = None if extra_xs is None else _tmap(lambda x: x[li], extra_xs)
            carry, ys = layer_fn(bp, carry, li % period if period else 0, x_i)
            tail_ys.append(ys)
        if tail_ys and tail_ys[0] is not None:
            stacked = _tmap(lambda *a: jnp.stack(a), *tail_ys)
            if ys_all is None:
                ys_all = stacked
            else:
                ys_all = _tmap(
                    lambda a, b: jnp.concatenate([a, b], axis=0), ys_all, stacked
                )
        return carry, ys_all

    # ==================================================================
    # Embedding / head / encoder
    # ==================================================================
    def _embed(self, params, batch) -> jax.Array:
        cfg = self.cfg
        tokens = batch["tokens"]
        h = params["embed"][tokens]
        if cfg.frontend == "patch_stub" and "patch_embeds" in batch:
            n = cfg.num_frontend_tokens
            pe = batch["patch_embeds"].astype(h.dtype)
            h = jnp.concatenate([pe, h[:, n:]], axis=1)
        if cfg.rope_theta <= 0:
            S = h.shape[1]
            pos_tab = jnp.asarray(sinusoidal_positions(S, cfg.d_model))
            h = h + pos_tab[None].astype(h.dtype)
        return constrain(h, "embed")

    def _head(self, params, h) -> jax.Array:
        cfg = self.cfg
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        return h @ w

    def _encode(self, params, src_embeds) -> jax.Array:
        cfg = self.cfg
        B, S, d = src_embeds.shape
        pos_tab = jnp.asarray(sinusoidal_positions(S, d))
        h = src_embeds.astype(self.dtype) + pos_tab[None].astype(self.dtype)

        def enc_block(h, bp):
            hd = cfg.resolved_head_dim
            x = rms_norm(h, bp["attn"]["ln"], cfg.norm_eps)
            q = (x @ bp["attn"]["wq"]).reshape(B, S, cfg.num_heads, hd)
            k = (x @ bp["attn"]["wk"]).reshape(B, S, cfg.num_kv_heads, hd)
            v = (x @ bp["attn"]["wv"]).reshape(B, S, cfg.num_kv_heads, hd)
            o = attention(
                q, k, v, causal=False, impl=self.rt.attn_impl,
                q_chunk=cfg.attn_q_chunk, kv_chunk=cfg.attn_kv_chunk,
            )
            h = h + o.reshape(B, S, cfg.q_dim) @ bp["attn"]["wo"]
            h = h + swiglu_apply(bp["mlp"], rms_norm(h, bp["mlp_ln"], cfg.norm_eps))
            return h, None

        h, _ = jax.lax.scan(_remat(enc_block, self.rt.remat), h, params["enc_blocks"])
        return rms_norm(h, params["enc_norm"], cfg.norm_eps)

    # ==================================================================
    # Public API
    # ==================================================================
    def forward(self, params, batch) -> jax.Array:
        cfg = self.cfg
        self._enc_out = (
            self._encode(params, batch["src_embeds"]) if cfg.is_encoder_decoder else None
        )
        h = self._embed(params, batch)
        S = batch["tokens"].shape[1]
        positions = jnp.arange(S)[None, :]

        def layer_fn(bp, c, j, _):
            return self._block_fwd(bp, c, is_global=cfg.layer_is_global(j), positions=positions), None

        h, _ = self._traverse(params["blocks"], h, layer_fn)
        logits = self._head(params, h)
        self._enc_out = None
        return logits

    def loss(self, params, batch) -> Tuple[jax.Array, dict]:
        cfg = self.cfg
        logits = self.forward(params, batch)
        tokens = batch["tokens"]
        targets = batch.get("targets")
        if targets is None:
            targets = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
        mask = jnp.ones(targets.shape, jnp.float32)
        mask = mask.at[:, -1].set(0.0)
        if cfg.frontend == "patch_stub":
            mask = mask.at[:, : cfg.num_frontend_tokens].set(0.0)
        ce = softmax_cross_entropy(logits, targets)
        loss = (ce * mask).sum() / jnp.maximum(mask.sum(), 1.0)
        metrics = {"ce": loss}
        if cfg.uses_moe and cfg.num_layers > 0:
            aux = self._moe_aux(params, batch)
            metrics["moe_aux"] = aux
            loss = loss + self.rt.moe_aux_coef * aux
        return loss, metrics

    def _moe_aux(self, params, batch) -> jax.Array:
        cfg = self.cfg
        h = self._embed(params, batch)
        bp0 = _tmap(lambda x: x[0], params["blocks"])
        return moe_aux_loss(bp0["moe"], rms_norm(h, bp0["moe_ln"], cfg.norm_eps), cfg)

    # ------------------------------------------------------------------
    def _striped(self, cache_len: int) -> bool:
        """§Perf G2: cyclic (block, offset) cache layout for windowed archs —
        the attention window spans ≤2 blocks and the *offset* dim shards
        evenly across the model axis, so window reads stay local+balanced
        (a seq-blocked layout forced XLA to all-gather the whole cache)."""
        w = self.cfg.sliding_window
        return (
            self.rt.decode_window_slice
            and self.cfg.uses_attention
            and w > 0
            and cache_len % w == 0
            and cache_len // w >= 2
        )

    def init_cache(self, batch: int, cache_len: int) -> dict:
        cfg, dt = self.cfg, self.dtype
        L = cfg.num_layers
        cache: Dict[str, Any] = {}
        if cfg.uses_attention and self._striped(cache_len):
            w = cfg.sliding_window
            kv = (L, batch, cache_len // w, w, cfg.num_kv_heads, cfg.resolved_head_dim)
            cache["k"] = jnp.zeros(kv, dt)
            cache["v"] = jnp.zeros(kv, dt)
        elif cfg.uses_attention:
            kv = (L, batch, cache_len, cfg.num_kv_heads, cfg.resolved_head_dim)
            cache["k"] = jnp.zeros(kv, dt)
            cache["v"] = jnp.zeros(kv, dt)
        if cfg.uses_ssm:
            conv_ch = cfg.d_inner + 2 * cfg.ssm_state
            cache["conv"] = jnp.zeros((L, batch, cfg.ssm_conv - 1, conv_ch), dt)
            cache["h"] = jnp.zeros(
                (L, batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32
            )
        if cfg.is_encoder_decoder:
            xs = (L, batch, cfg.max_source_positions, cfg.num_kv_heads, cfg.resolved_head_dim)
            cache["cross_k"] = jnp.zeros(xs, dt)
            cache["cross_v"] = jnp.zeros(xs, dt)
        return cache

    def prefill(self, params, batch):
        """Run the full prompt; return (last-position logits, filled cache)."""
        cfg = self.cfg
        self._enc_out = (
            self._encode(params, batch["src_embeds"]) if cfg.is_encoder_decoder else None
        )
        h = self._embed(params, batch)
        S = batch["tokens"].shape[1]
        positions = jnp.arange(S)[None, :]

        def layer_fn(bp, c, j, _):
            return self._block_prefill(bp, c, is_global=cfg.layer_is_global(j), positions=positions)

        h, cache = self._traverse(params["blocks"], h, layer_fn)
        logits = self._head(params, h[:, -1:, :])
        self._enc_out = None
        return logits, cache

    def decode_step(self, params, cache, token, pos):
        """token (B, 1) int32; pos scalar int32 (write index).  Returns
        (logits (B,1,V), updated cache)."""
        cfg = self.cfg
        h = params["embed"][token]
        if cfg.rope_theta <= 0:
            h = h + _sinusoid_at(pos, cfg.d_model)[None, None].astype(h.dtype)

        def layer_fn(bp, c, j, lc):
            c, nc = self._block_decode(bp, lc, c, pos, is_global=cfg.layer_is_global(j))
            return c, nc

        h, new_cache = self._traverse(params["blocks"], h, layer_fn, extra_xs=cache)
        logits = self._head(params, h)
        return logits, new_cache


def build_model(cfg: ModelConfig, rt: Runtime = Runtime()) -> Model:
    return Model(cfg, rt)

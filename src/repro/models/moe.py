"""Mixture-of-Experts layer: top-k routing, capacity-based dispatch.

Design (shardable under pjit auto-SPMD):

* routing + position-in-expert are computed **per batch row**, so the
  dispatch never serializes across the data axis;
* tokens are scattered into an ``(E, B, C, d)`` buffer (experts sharded on
  the ``model`` axis ⇒ expert parallelism; batch on ``data``) — the
  token→expert redistribution lowers to all-to-all-style collectives;
* expert FFNs run as one grouped einsum over the stacked (E, d, ff)
  weights — MXU-shaped, no ragged shapes;
* tokens over capacity ``C = ceil(cf · S · k / E)`` are dropped (standard
  Switch-style capacity dropping, cf = 1.25).

Supports qwen2-moe (shared experts + routed) and arctic (dense-residual
FFN in parallel with the routed experts).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, swiglu_apply, swiglu_init


def moe_init(key, cfg, dtype) -> dict:
    d = cfg.d_model
    e_ff = cfg.moe_d_ff or cfg.d_ff
    E = cfg.num_experts
    kr, kg, ku, kd, ks, kdr = jax.random.split(key, 6)
    scale = 1.0 / math.sqrt(d)
    p = {
        "router": dense_init(kr, d, E, jnp.float32),
        "experts": {
            "gate": (jax.random.normal(kg, (E, d, e_ff), jnp.float32) * scale).astype(dtype),
            "up": (jax.random.normal(ku, (E, d, e_ff), jnp.float32) * scale).astype(dtype),
            "down": (jax.random.normal(kd, (E, e_ff, d), jnp.float32) / math.sqrt(e_ff)).astype(dtype),
        },
    }
    if cfg.num_shared_experts:
        p["shared"] = swiglu_init(ks, d, cfg.num_shared_experts * e_ff, dtype)
        p["shared_gate"] = dense_init(kdr, d, 1, jnp.float32)
    if cfg.dense_residual:
        p["dense_ffn"] = swiglu_init(kdr, d, cfg.d_ff, dtype)
    return p


def moe_apply(p: dict, x: jax.Array, cfg, *, capacity_factor: float = 1.25) -> jax.Array:
    """x: (B, S, d) -> (B, S, d)."""
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    C = max(1, math.ceil(capacity_factor * S * k / E))

    logits = (x.astype(jnp.float32) @ p["router"]).astype(jnp.float32)  # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)  # (B,S,k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # --- per-row position-in-expert (B, S*k) ------------------------------
    flat_e = top_e.reshape(B, S * k)
    oh = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # (B, S*k, E)
    pos = jnp.cumsum(oh, axis=1) - 1  # position among same-expert slots
    pos_of = jnp.take_along_axis(pos, flat_e[..., None], axis=2)[..., 0]  # (B,S*k)
    keep = pos_of < C
    pos_clip = jnp.where(keep, pos_of, C)  # dropped slots land in a scratch slot

    # --- scatter tokens into (E, B, C+1, d) expert buffers ------------------
    tok = jnp.repeat(x, k, axis=1)  # (B, S*k, d) token replicated per slot
    buf = jnp.zeros((E, B, C + 1, d), x.dtype)
    b_idx = jnp.broadcast_to(jnp.arange(B)[:, None], (B, S * k))
    buf = buf.at[flat_e, b_idx, pos_clip].add(tok, mode="drop")
    buf = buf[:, :, :C]  # drop scratch slot

    # --- grouped expert FFN -------------------------------------------------
    w = p["experts"]
    g = jax.nn.silu(jnp.einsum("ebcd,edf->ebcf", buf, w["gate"]))
    u = jnp.einsum("ebcd,edf->ebcf", buf, w["up"])
    eo = jnp.einsum("ebcf,efd->ebcd", g * u, w["down"])  # (E,B,C,d)

    # --- gather back + combine ----------------------------------------------
    eo = jnp.concatenate([eo, jnp.zeros((E, B, 1, d), eo.dtype)], axis=2)
    back = eo[flat_e, b_idx, pos_clip]  # (B, S*k, d)
    back = back * (keep[..., None] * top_w.reshape(B, S * k)[..., None]).astype(back.dtype)
    out = back.reshape(B, S, k, d).sum(axis=2)

    # --- shared experts / dense residual ------------------------------------
    if "shared" in p:
        sh = swiglu_apply(p["shared"], x)
        gate = jax.nn.sigmoid((x.astype(jnp.float32) @ p["shared_gate"])).astype(x.dtype)
        out = out + sh * gate
    if "dense_ffn" in p:
        out = out + swiglu_apply(p["dense_ffn"], x)
    return out


def moe_aux_loss(p: dict, x: jax.Array, cfg) -> jax.Array:
    """Load-balancing auxiliary loss (Switch-style f·P)."""
    logits = (x.astype(jnp.float32) @ p["router"])
    probs = jax.nn.softmax(logits, axis=-1)  # (B,S,E)
    top_e = jax.lax.top_k(probs, cfg.num_experts_per_tok)[1]
    E = cfg.num_experts
    frac = jax.nn.one_hot(top_e, E).mean(axis=(0, 1, 2))  # fraction routed
    imp = probs.mean(axis=(0, 1))  # mean router prob
    return E * jnp.sum(frac * imp)


# ---------------------------------------------------------------------------
# Expert-parallel shard_map path (§Perf iteration A1).
#
# The auto-SPMD scatter dispatch above forces XLA to all-gather expert
# weights (8 TB/chip/step on arctic train_4k).  Here experts stay
# stationary: the residual stream is replicated across the ``model`` axis
# (Megatron invariant), so every model column already holds every token —
# each column simply *filters* the (token, slot) pairs routed to its local
# E/mp experts, computes them, and the per-column partial outputs combine
# with one psum over ``model``.  Collective cost per layer: one
# activation-sized all-reduce — the same class as a dense FFN, with zero
# token or weight movement.
# ---------------------------------------------------------------------------


def _local_expert_compute(x, logits, w_gate, w_up, w_down, *, e_base, E, k, C):
    """One (data, model) shard: route all local tokens to local experts.

    x (T, d); logits (T, E) fp32; local experts are [e_base, e_base+E_loc).
    Returns the partial combined output (T, d).
    """
    E_loc = w_gate.shape[0]
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)  # (T, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    flat_e = top_e.reshape(-1)  # (T*k,) global expert ids
    flat_w = top_w.reshape(-1)
    local = (flat_e >= e_base) & (flat_e < e_base + E_loc)
    loc_e = jnp.where(local, flat_e - e_base, E_loc)  # E_loc = drop bucket

    oh = jax.nn.one_hot(loc_e, E_loc + 1, dtype=jnp.int32)
    pos = jnp.cumsum(oh, axis=0) - 1
    pos_of = jnp.take_along_axis(pos, loc_e[:, None], axis=1)[:, 0]
    keep = local & (pos_of < C)
    pos_clip = jnp.where(keep, pos_of, C)
    loc_e_c = jnp.where(keep, loc_e, E_loc)

    T = x.shape[0]
    tok_idx = jnp.repeat(jnp.arange(T), k)
    buf = jnp.zeros((E_loc + 1, C + 1, x.shape[1]), x.dtype)
    buf = buf.at[loc_e_c, pos_clip].add(x[tok_idx], mode="drop")
    buf = buf[:E_loc, :C]

    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w_gate))
    u = jnp.einsum("ecd,edf->ecf", buf, w_up)
    eo = jnp.einsum("ecf,efd->ecd", g * u, w_down)  # (E_loc, C, d)

    eo = jnp.pad(eo, ((0, 1), (0, 1), (0, 0)))
    back = eo[loc_e_c, pos_clip]  # (T*k, d)
    back = back * (keep * flat_w)[:, None].astype(back.dtype)
    return jnp.zeros_like(x).at[tok_idx].add(back)


def moe_apply_ep(
    p: dict, x: jax.Array, cfg, mesh, *, capacity_factor: float = 1.25
) -> jax.Array:
    """Expert-parallel MoE over ``mesh`` (model axis = EP)."""
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map

    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    mp = mesh.shape.get("model", 1)
    assert E % mp == 0, (E, mp)
    E_loc = E // mp
    dp_axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dp = 1
    for a in dp_axes:
        dp *= mesh.shape[a]
    b_spec = dp_axes if len(dp_axes) > 1 else (dp_axes[0] if dp_axes else None)
    b_ok = B % max(dp, 1) == 0 and dp > 1
    x_spec = P(b_spec if b_ok else None, None, None)

    def local_fn(xl, router, w_gate, w_up, w_down):
        # xl (B_loc, S, d) — identical across model columns
        j = jax.lax.axis_index("model")
        T = xl.shape[0] * xl.shape[1]
        x2 = xl.reshape(T, d)
        logits = x2.astype(jnp.float32) @ router
        C = max(1, math.ceil(capacity_factor * T * k / E))
        out = _local_expert_compute(
            x2, logits, w_gate, w_up, w_down,
            e_base=j * E_loc, E=E, k=k, C=C,
        )
        out = jax.lax.psum(out, "model")
        return out.reshape(xl.shape)

    w = p["experts"]
    out = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(
            x_spec, P(None, None),
            P("model", None, None), P("model", None, None), P("model", None, None),
        ),
        out_specs=x_spec,
        check_vma=False,
    )(x, p["router"], w["gate"], w["up"], w["down"])

    if "shared" in p:
        sh = swiglu_apply(p["shared"], x)
        gate = jax.nn.sigmoid((x.astype(jnp.float32) @ p["shared_gate"])).astype(x.dtype)
        out = out + sh * gate
    if "dense_ffn" in p:
        out = out + swiglu_apply(p["dense_ffn"], x)
    return out

"""Command-line front end for the scheduler daemon (ISSUE 6).

``python -m repro.cli daemon`` boots a ``SchedulerService`` over a unix
socket on a calibrated simulation backend; every other subcommand is a
thin JSON-lines client against a running daemon:

    python -m repro.cli daemon --socket /tmp/eco.sock --journal /tmp/eco.jnl &
    python -m repro.cli submit --socket /tmp/eco.sock --name j0 --app resnet
    python -m repro.cli advance --socket /tmp/eco.sock --until 3600
    python -m repro.cli jobs --socket /tmp/eco.sock
    python -m repro.cli drain --socket /tmp/eco.sock
    python -m repro.cli result --socket /tmp/eco.sock
    python -m repro.cli shutdown --socket /tmp/eco.sock

Kill the daemon (even with SIGKILL) and boot it again with the same
``--journal`` and preset: it replays the journal through a fresh backend
and resumes exactly where it was — the recovery contract documented in
docs/control_plane.md and property-tested in tests/test_service.py.

Presets build the same calibrated systems the benchmarks use (the
paper's H100/A100/V100 platforms, EcoSched per node):

  * ``single-h100`` — one 4-GPU H100 node,
  * ``hetero``      — one node each of H100/A100/V100 behind the
                      energy-aware dispatcher.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.core import calibration as C
from repro.core.cluster import (
    Cluster,
    EnergyAwareDispatcher,
    LeastLoadedDispatcher,
    NodeSpec,
    PredictiveDispatcher,
    RoundRobinDispatcher,
)
from repro.core.ecosched import EcoSched
from repro.core.events import ElasticConfig
from repro.core.faults import FaultConfig
from repro.core.forecast import ForecastConfig
from repro.core.perfmodel import ProfiledPerfModel
from repro.core.service import (
    AdmissionConfig,
    ClusterBackend,
    SchedulerService,
    request,
    request_retry,
    serve,
)
from repro.roofline.hw import CHIPS

# reproduction-locked policy hyperparameters (EXPERIMENTS.md)
LAM, TAU, NOISE, SEED = 0.35, 0.45, 0.02, 1

PRESETS = {
    "single-h100": ("h100",),
    "hetero": ("h100", "a100", "v100"),
}

DISPATCHERS = {
    "eco": EnergyAwareDispatcher,
    "predictive": PredictiveDispatcher,
    "rr": RoundRobinDispatcher,
    "least-loaded": LeastLoadedDispatcher,
}


def make_backend_factory(
    preset: str,
    *,
    dispatcher: str = "eco",
    elastic: bool = False,
    forecast: bool = False,
    freq_levels: int = 1,
    faults: "FaultConfig | None" = None,
):
    """A fresh-backend factory for ``SchedulerService``: every call
    rebuilds the calibrated cluster from scratch (deterministically),
    which is exactly what journal replay needs.  ``freq_levels > 1``
    enables DVFS: each node's truth tables carry per-frequency
    runtime/power curves, the per-node policies pick joint (count,
    frequency) actions, and the chosen level is journaled per transition
    so crash recovery replays it bit-identically."""
    systems = PRESETS[preset]

    def make() -> ClusterBackend:
        seen = {}
        specs = []
        for s in systems:
            idx = seen.get(s, 0)
            seen[s] = idx + 1
            specs.append(NodeSpec(name=f"{s}-{idx}", chip=CHIPS[s]))
        cluster = Cluster(
            specs,
            truth_for=lambda spec: C.build_system(
                spec.chip.name, freq_levels=freq_levels
            ),
            policy_for=lambda spec, truth: EcoSched(
                ProfiledPerfModel(truth, noise=NOISE, seed=SEED),
                lam=LAM,
                tau=TAU,
            ),
            dispatcher=DISPATCHERS[dispatcher](),
            slowdown_for=lambda spec: C.cross_numa_slowdown,
            label=f"{preset}:{dispatcher}",
        )
        return ClusterBackend(
            cluster,
            elastic=(
                ElasticConfig(resize=True, migrate=len(systems) > 1)
                if elastic
                else None
            ),
            forecast=ForecastConfig() if forecast else None,
            faults=faults,
        )

    return make


def _client(args: argparse.Namespace, req: dict) -> int:
    # transient connect failures (daemon still booting / recovering) are
    # retried with exponential backoff unless --no-retry asks for the
    # old fail-fast behavior
    if getattr(args, "no_retry", False):
        resp = request(args.socket, req)
    else:
        resp = request_retry(args.socket, req)
    print(json.dumps(resp, sort_keys=True, indent=2))
    return 0 if resp.get("ok") else 1


def main(argv=None) -> int:
    p = argparse.ArgumentParser(prog="repro.cli", description=__doc__)
    sub = p.add_subparsers(dest="cmd", required=True)

    def add(name, **kw):
        sp = sub.add_parser(name, **kw)
        sp.add_argument("--socket", required=True, help="unix socket path")
        sp.add_argument(
            "--no-retry",
            action="store_true",
            help="fail fast instead of retrying transient connect errors",
        )
        return sp

    d = add("daemon", help="boot the scheduler daemon")
    d.add_argument("--journal", default=None, help="append-only journal path")
    d.add_argument("--preset", default="hetero", choices=sorted(PRESETS))
    d.add_argument(
        "--dispatcher", default="eco", choices=sorted(DISPATCHERS)
    )
    d.add_argument("--elastic", action="store_true")
    d.add_argument("--forecast", action="store_true")
    d.add_argument(
        "--freq-levels",
        type=int,
        default=1,
        help="DVFS levels per chip (1 = base clock only)",
    )
    d.add_argument("--fsync", action="store_true")
    d.add_argument("--max-pending", type=int, default=256)
    d.add_argument("--burst-limit", type=float, default=3.0)
    d.add_argument("--burst-pending", type=int, default=16)
    d.add_argument(
        "--fault-seed", type=int, default=0, help="fault-injection RNG seed"
    )
    d.add_argument(
        "--node-mtbf",
        type=float,
        default=0.0,
        help="mean seconds between node failures (0 = no node faults)",
    )
    d.add_argument(
        "--node-mttr", type=float, default=600.0, help="mean repair seconds"
    )
    d.add_argument(
        "--degrade-frac",
        type=float,
        default=0.0,
        help="probability a node failure is partial (loses --degrade-units)",
    )
    d.add_argument("--degrade-units", type=int, default=1)
    d.add_argument(
        "--job-mtbf",
        type=float,
        default=0.0,
        help="mean running seconds between job crashes (0 = no job faults)",
    )
    d.add_argument("--max-retries", type=int, default=3)

    s = add("submit", help="submit one job")
    s.add_argument("--name", required=True)
    s.add_argument("--app", required=True)
    s.add_argument("--t", type=float, default=None)

    c = add("cancel", help="cancel a not-yet-running job")
    c.add_argument("--name", required=True)

    st = add("status", help="one job's lifecycle state")
    st.add_argument("--name", required=True)

    add("jobs", help="list all jobs")
    a = add("advance", help="advance simulated time")
    a.add_argument("--until", type=float, default=None)
    add("drain", help="run until every queued job has finished")
    add("stats", help="daemon statistics")
    add("compact", help="fold journaled transitions into a snapshot")
    add("result", help="final schedule fingerprint (after drain)")
    add("ping", help="liveness check")
    add("shutdown", help="stop the daemon cleanly")

    args = p.parse_args(argv)

    if args.cmd == "daemon":
        faults = FaultConfig(
            seed=args.fault_seed,
            node_mtbf_s=args.node_mtbf,
            node_mttr_s=args.node_mttr,
            degrade_frac=args.degrade_frac,
            degrade_units=args.degrade_units,
            job_mtbf_s=args.job_mtbf,
            max_retries=args.max_retries,
        )
        service = SchedulerService(
            make_backend_factory(
                args.preset,
                dispatcher=args.dispatcher,
                elastic=args.elastic,
                forecast=args.forecast,
                freq_levels=args.freq_levels,
                faults=faults if faults.enabled else None,
            ),
            journal_path=args.journal,
            admission=AdmissionConfig(
                max_pending=args.max_pending,
                burst_limit=args.burst_limit,
                burst_pending=args.burst_pending,
            ),
            fsync=args.fsync,
        )
        print(f"daemon: {service.backend.describe()} on {args.socket}", flush=True)
        serve(service, args.socket)
        return 0
    if args.cmd == "submit":
        req = {"op": "submit", "name": args.name, "app": args.app}
        if args.t is not None:
            req["t"] = args.t
        return _client(args, req)
    if args.cmd == "cancel":
        return _client(args, {"op": "cancel", "name": args.name})
    if args.cmd == "status":
        return _client(args, {"op": "status", "name": args.name})
    if args.cmd == "advance":
        req = {"op": "advance"}
        if args.until is not None:
            req["until"] = args.until
        return _client(args, req)
    return _client(args, {"op": args.cmd})


if __name__ == "__main__":
    sys.exit(main())

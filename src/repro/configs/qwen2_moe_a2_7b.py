"""qwen2-moe-a2.7b — MoE, 4 shared + 60 routed top-4.

[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]
24L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=151936, MoE 60e top-4.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2_048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1_408,
    vocab_size=151_936,
    num_experts=60,
    num_experts_per_tok=4,
    num_shared_experts=4,
    moe_d_ff=1_408,
)

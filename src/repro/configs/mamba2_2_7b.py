"""mamba2-2.7b — SSM, SSD (state-space duality).  [arXiv:2405.21060; unverified]

64L d_model=2560 (attn-free) vocab=50280, ssm_state=128, expand=2,
head_dim=64 (80 SSD heads).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2_560,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50_280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_conv=4,
    tie_embeddings=True,
)

"""Config dataclasses shared by every architecture.

A ``ModelConfig`` fully determines a model: family dispatch, layer geometry,
attention flavour, MoE/SSM/frontend extras.  A ``ShapeCell`` is one
(input-shape × step-kind) evaluation point from the assignment grid.  The
product (arch × cell) is what the dry-run, the roofline table and the
scheduler's workload pool all iterate over.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | vlm | hybrid | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # --- attention flavour ---------------------------------------------
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    attention_pattern: str = "global"  # "global" | "local_global"
    local_global_ratio: int = 0  # N local layers per 1 global (gemma3: 5)
    sliding_window: int = 0  # window size for local layers
    attn_logit_softcap: float = 0.0

    # --- MoE -------------------------------------------------------------
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0
    dense_residual: bool = False  # arctic: dense FFN in parallel with MoE

    # --- SSM (Mamba2 / SSD) ----------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256

    # --- hybrid (hymba) ----------------------------------------------------
    parallel_ssm: bool = False  # attention and SSM heads run in parallel

    # --- encoder-decoder (whisper) -----------------------------------------
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    cross_attention: bool = False
    max_source_positions: int = 1500  # whisper cross-cache length

    # --- modality frontend (stubbed per assignment) -------------------------
    frontend: str = "none"  # none | patch_stub | audio_stub
    num_frontend_tokens: int = 0  # e.g. 576 CLIP patches for phi-3-vision

    # --- TP-divisibility padding (set by distributed.sharding.shardable) ----
    d_inner_override: int = 0  # padded SSM inner width (nh padded to mesh)
    vocab_size_real: int = 0  # original vocab before padding (0 = unpadded)

    # --- numerics / impl -----------------------------------------------------
    dtype: str = "bfloat16"
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    # q/kv chunk sizes for the chunked (flash-style) attention path.  These
    # are python-unrolled in the dry-run path so XLA cost analysis counts
    # every block (see DESIGN.md §4).
    attn_q_chunk: int = 1024
    attn_kv_chunk: int = 2048

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.resolved_head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.resolved_head_dim

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.d_inner_override or (self.ssm_expand * self.d_model)

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_state else 0

    @property
    def uses_attention(self) -> bool:
        return self.family != "ssm"

    @property
    def uses_ssm(self) -> bool:
        return self.family == "ssm" or self.parallel_ssm

    @property
    def uses_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def full_attention_only(self) -> bool:
        """True when every token attends to the full (quadratic) context.

        Used by the shape grid: ``long_500k`` is skipped for these archs.
        """
        if self.family in ("ssm", "hybrid"):
            return False
        if self.attention_pattern in ("local_global", "local"):
            return False
        return True

    # ------------------------------------------------------------------
    def layer_is_global(self, layer_idx: int) -> bool:
        """gemma3-style interleaving: ratio local layers then one global."""
        if self.attention_pattern == "local":
            return False
        if self.attention_pattern != "local_global":
            return True
        period = self.local_global_ratio + 1
        return (layer_idx % period) == self.local_global_ratio

    # ------------------------------------------------------------------
    # Parameter counting (used for MODEL_FLOPS = 6·N·D and memory napkins)
    # ------------------------------------------------------------------
    def _per_layer_params(self) -> dict:
        d, hd = self.d_model, self.resolved_head_dim
        out: dict = {}
        if self.uses_attention:
            out["attn_qkvo"] = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
            if self.qk_norm:
                out["qk_norm"] = 2 * hd
        if self.family == "ssm" or self.parallel_ssm:
            di = self.d_inner
            # in_proj: x->(z, x, B, C, dt heads); conv; out_proj; per-head A/D
            nh = self.ssm_heads
            proj_in = d * (2 * di + 2 * self.ssm_state * 1 + nh)
            conv = self.ssm_conv * (di + 2 * self.ssm_state)
            out["ssm"] = proj_in + conv + di * d + 2 * nh + di
        if self.uses_moe:
            e_ff = self.moe_d_ff or self.d_ff
            out["router"] = d * self.num_experts
            out["experts"] = self.num_experts * 3 * d * e_ff
            if self.num_shared_experts:
                out["shared"] = self.num_shared_experts * 3 * d * e_ff + d
            if self.dense_residual:
                out["dense_ffn"] = 3 * d * self.d_ff
        elif self.d_ff:
            out["ffn"] = 3 * d * self.d_ff  # SwiGLU gate/up/down
        out["norms"] = 2 * d
        return out

    def param_count(self) -> int:
        per_layer = sum(self._per_layer_params().values())
        n = self.num_layers * per_layer
        if self.is_encoder_decoder:
            # encoder layers: self-attn + ffn; decoder adds cross-attn.
            d = self.d_model
            enc_layer = (
                d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
                + 3 * d * self.d_ff + 2 * d
            )
            cross = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d + d
            n += self.num_encoder_layers * enc_layer + self.num_layers * cross
        n += self.vocab_size * self.d_model  # embedding
        if not self.tie_embeddings:
            n += self.vocab_size * self.d_model  # lm head
        n += self.d_model  # final norm
        return int(n)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed-in experts)."""
        if not self.uses_moe:
            return self.param_count()
        per_layer = dict(self._per_layer_params())
        e_ff = self.moe_d_ff or self.d_ff
        per_layer["experts"] = self.num_experts_per_tok * 3 * self.d_model * e_ff
        n = self.num_layers * sum(per_layer.values())
        n += self.vocab_size * self.d_model * (1 if self.tie_embeddings else 2)
        n += self.d_model
        return int(n)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Shape grid
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str  # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int

    @property
    def tokens_per_step(self) -> int:
        if self.kind == "decode":
            return self.global_batch  # one new token per sequence
        return self.global_batch * self.seq_len


SHAPE_CELLS: Tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", "train", 4_096, 256),
    ShapeCell("prefill_32k", "prefill", 32_768, 32),
    ShapeCell("decode_32k", "decode", 32_768, 128),
    ShapeCell("long_500k", "decode", 524_288, 1),
)

SHAPES = {c.name: c for c in SHAPE_CELLS}


def cell_applicable(cfg: ModelConfig, cell: ShapeCell) -> Tuple[bool, str]:
    """(runs?, reason).  Mirrors the assignment's skip rules (DESIGN.md §5)."""
    if cell.name == "long_500k" and cfg.full_attention_only:
        return False, "pure full-attention arch: long_500k needs sub-quadratic attention"
    if cell.name == "long_500k" and cfg.is_encoder_decoder:
        return False, "enc-dec full attention: no sub-quadratic path"
    return True, ""


# ---------------------------------------------------------------------------
# Reduced (smoke) configs — same family wiring, tiny dims.
# ---------------------------------------------------------------------------


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Shrink a config for CPU smoke tests while preserving its structure."""
    heads = max(2, min(4, cfg.num_heads))
    kv = max(1, min(heads, max(1, cfg.num_kv_heads * heads // max(cfg.num_heads, 1))))
    if heads % kv:
        kv = 1
    layers = 2
    if cfg.attention_pattern == "local_global":
        layers = cfg.local_global_ratio + 1  # one full local:global period
    kw = dict(
        num_layers=layers,
        d_model=64,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        sliding_window=min(cfg.sliding_window, 32) if cfg.sliding_window else 0,
        attn_q_chunk=16,
        attn_kv_chunk=16,
        ssm_chunk=16,
        max_source_positions=24,
    )
    if cfg.uses_moe:
        kw.update(
            num_experts=4,
            num_experts_per_tok=min(2, cfg.num_experts_per_tok),
            num_shared_experts=min(1, cfg.num_shared_experts),
            moe_d_ff=32 if cfg.moe_d_ff else 0,
        )
    if cfg.ssm_state:
        kw.update(ssm_state=8, ssm_head_dim=16, ssm_expand=2)
    if cfg.is_encoder_decoder:
        kw.update(num_encoder_layers=2)
    if cfg.frontend != "none":
        kw.update(num_frontend_tokens=4)
    return cfg.replace(name=cfg.name + "-smoke", **kw)

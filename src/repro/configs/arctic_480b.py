"""arctic-480b — MoE, 128 experts top-2 + dense residual.

[hf:Snowflake/snowflake-arctic-base; hf]
35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000, MoE 128e top-2.
Arctic runs a small dense FFN residually in parallel with the routed MoE.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7_168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=4_864,
    vocab_size=32_000,
    num_experts=128,
    num_experts_per_tok=2,
    moe_d_ff=4_864,
    dense_residual=True,
)

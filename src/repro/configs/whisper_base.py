"""whisper-base — enc-dec audio, conv frontend (stub).  [arXiv:2212.04356; unverified]

6L (enc) + 6L (dec) d_model=512 8H d_ff=2048 vocab=51865.  The conv audio
frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed frame embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    num_layers=6,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    head_dim=64,
    d_ff=2_048,
    vocab_size=51_865,
    is_encoder_decoder=True,
    num_encoder_layers=6,
    cross_attention=True,
    frontend="audio_stub",
    max_source_positions=1_500,
    rope_theta=0.0,  # whisper uses learned positions; we use sinusoidal stub
    tie_embeddings=True,
)

"""Architecture registry: the 10 assigned archs × their shape cells.

``get_config(name)`` returns the exact published config; ``reduced`` makes
the CPU-smoke variant.  ``grid()`` yields every (arch × shape) cell with its
applicability verdict — the dry-run, roofline table and scheduler workload
pool all iterate this one grid.
"""
from __future__ import annotations

from typing import Dict, Iterator, Tuple

from repro.configs.base import (
    SHAPE_CELLS,
    SHAPES,
    ModelConfig,
    ShapeCell,
    cell_applicable,
    reduced,
)

from repro.configs.qwen3_32b import CONFIG as _qwen3
from repro.configs.granite_8b import CONFIG as _granite
from repro.configs.phi4_mini_3_8b import CONFIG as _phi4
from repro.configs.gemma3_4b import CONFIG as _gemma3
from repro.configs.arctic_480b import CONFIG as _arctic
from repro.configs.qwen2_moe_a2_7b import CONFIG as _qwen2moe
from repro.configs.mamba2_2_7b import CONFIG as _mamba2
from repro.configs.phi3_vision_4_2b import CONFIG as _phi3v
from repro.configs.hymba_1_5b import CONFIG as _hymba
from repro.configs.whisper_base import CONFIG as _whisper

ARCHS: Dict[str, ModelConfig] = {
    c.name: c
    for c in (
        _qwen3,
        _granite,
        _phi4,
        _gemma3,
        _arctic,
        _qwen2moe,
        _mamba2,
        _phi3v,
        _hymba,
        _whisper,
    )
}


def get_config(name: str) -> ModelConfig:
    try:
        return ARCHS[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}") from None


def list_archs() -> list:
    return sorted(ARCHS)


def grid() -> Iterator[Tuple[ModelConfig, ShapeCell, bool, str]]:
    """Yield (config, cell, applicable, reason) over all 40 cells."""
    for name in sorted(ARCHS):
        cfg = ARCHS[name]
        for cell in SHAPE_CELLS:
            ok, why = cell_applicable(cfg, cell)
            yield cfg, cell, ok, why


__all__ = [
    "ARCHS",
    "SHAPES",
    "SHAPE_CELLS",
    "ModelConfig",
    "ShapeCell",
    "cell_applicable",
    "get_config",
    "grid",
    "list_archs",
    "reduced",
]

"""hymba-1.5b — hybrid: parallel attention + mamba heads.  [arXiv:2411.13676; hf]

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Every layer runs attention heads and SSM heads in parallel on the same
input and sums their outputs (Hymba's parallel-head design).  Attention
uses a sliding window on most layers (sub-quadratic ⇒ long_500k runs).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1_600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5_504,
    vocab_size=32_001,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=64,
    parallel_ssm=True,
    # Hymba's 3 global-attention layers are approximated as windowed so the
    # layer stack stays scan-uniform (period 1) — the hybrid parallel-head
    # structure is the systems-relevant property (DESIGN.md §5).
    attention_pattern="local",
    sliding_window=1_024,
    attn_q_chunk=2_048,
    attn_kv_chunk=4_096,
)

"""phi-3-vision-4.2b — VLM: phi3-mini backbone + CLIP frontend (stubbed).

[hf:microsoft/Phi-3-vision-128k-instruct; hf]
32L d_model=3072 32H (GQA kv=32) d_ff=8192 vocab=32064.  The CLIP ViT
frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed patch embeddings (576 CLIP ViT-L/14@336 patches).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    num_layers=32,
    d_model=3_072,
    num_heads=32,
    num_kv_heads=32,
    head_dim=96,
    d_ff=8_192,
    vocab_size=32_064,
    frontend="patch_stub",
    num_frontend_tokens=576,
)

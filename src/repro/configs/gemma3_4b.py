"""gemma3-4b — dense, 5:1 local:global attention, 128k context.

[hf:google/gemma-3-1b-pt; unverified]
34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144, head_dim=256,
sliding window 1024 on local layers, qk_norm (gemma3 uses RMS qk-norm).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b",
    family="dense",
    num_layers=34,
    d_model=2_560,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=10_240,
    vocab_size=262_144,
    qk_norm=True,
    attention_pattern="local_global",
    local_global_ratio=5,
    sliding_window=1_024,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)

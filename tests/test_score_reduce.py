"""JAX/Pallas score-reduce kernel (kernels/score_reduce.py): parity of the
pallas-interpret and pure-jnp ref paths against the numpy engine over seeded
random windows, edge cases (empty window, all-infeasible candidates), and the
EcoSched engine="jax" end-to-end wiring."""
import numpy as np
import pytest

from repro.core import EcoSched, JobProfile, Node, ProfiledPerfModel, simulate
from repro.core.engine import enumerate_scored
from repro.core.perfmodel import _mk_spec
from repro.core.types import NodeView
from repro.kernels.score_reduce import (
    score_reduce,
    score_reduce_batch,
    score_reduce_multi,
)

LAM = 0.35
TOL = 1e-6  # float32 kernel vs float64 numpy engine (ISSUE 3 acceptance)


def rand_window(seed):
    """Seeded random (specs, view): like tests/test_engine.rand_state but
    with honest fragmented free maps driven through PlacementState."""
    from repro.core import PlacementState

    rng = np.random.default_rng(seed)
    M = int(rng.choice([4, 8, 16]))
    K = int(rng.choice([2, 4]))
    W = int(rng.integers(1, 8))
    counts = [g for g in (1, 2, 3, 4, 8, 16) if g <= M]
    specs = []
    for i in range(W):
        sub = sorted(
            rng.choice(counts, size=int(rng.integers(1, len(counts) + 1)), replace=False)
        )
        t_hat = {int(g): float(100.0 / g ** rng.uniform(0.3, 1.0)) for g in sub}
        p_hat = {int(g): float(300.0 * g ** rng.uniform(0.6, 0.95)) for g in sub}
        specs.append(_mk_spec(f"j{i}", t_hat, p_hat))
    st = PlacementState(M, K)
    running = []
    for _ in range(int(rng.integers(0, K))):
        g = int(rng.integers(1, max(2, M // 2)))
        if st.can_allocate(g) and st.occupied_domains() < K:
            st.allocate(g)
            running.append(object())
    view = NodeView(
        t=0.0, total_units=M, domains=K, free_units=st.free_count(),
        running=running, free_map=list(st.free), domain_jobs=list(st.domain_jobs),
    )
    return specs, view


def reduce_case(seed, mode):
    specs, view = rand_window(seed)
    batch = enumerate_scored(specs, view, list(view.free_map), lam=LAM)
    dev, g, n = batch.padded_cols()
    scores, best = score_reduce(
        dev, g, n, lam=LAM, g_free=view.free_units, M=view.total_units, mode=mode
    )
    return batch, scores, best


@pytest.mark.parametrize("mode,seeds", [("ref", range(60)), ("interpret", range(10))])
def test_kernel_parity_vs_numpy_engine(mode, seeds):
    for seed in seeds:
        batch, scores, best = reduce_case(seed, mode)
        assert scores.shape == batch.scores.shape
        assert np.max(np.abs(scores - batch.scores)) <= TOL, seed
        # the kernel's tie-broken winner scores exactly like the engine's
        ref = batch.best_index()
        assert best >= 0
        assert abs(float(scores[best]) - float(batch.scores[ref])) <= TOL, seed
        assert batch.total_g[best] == batch.total_g[ref], seed


def test_interpret_matches_ref_bitwise():
    """Both non-TPU paths compute the identical float32 reduction."""
    for seed in range(10):
        _, s_ref, b_ref = reduce_case(seed, "ref")
        _, s_int, b_int = reduce_case(seed, "interpret")
        assert np.array_equal(s_ref, s_int), seed
        assert b_ref == b_int, seed


@pytest.mark.parametrize("mode", ["ref", "interpret"])
def test_empty_window(mode):
    view = NodeView(t=0.0, total_units=8, domains=2, free_units=8,
                    running=[], free_map=[True] * 8, domain_jobs=[0, 0])
    batch = enumerate_scored([], view, list(view.free_map), lam=LAM)
    dev, g, n = batch.padded_cols()
    scores, best = score_reduce(dev, g, n, lam=LAM, g_free=8, M=8, mode=mode)
    assert best == 0  # only the empty action exists
    assert scores[0] == pytest.approx(batch.scores[0], abs=TOL)


@pytest.mark.parametrize("mode", ["ref", "interpret"])
def test_all_infeasible_returns_sentinel(mode):
    batch, _, _ = reduce_case(3, "ref")
    dev, g, n = batch.padded_cols()
    scores, best = score_reduce(
        dev, g, n, lam=LAM, g_free=8, M=8,
        mask=np.zeros(len(batch), dtype=bool), mode=mode,
    )
    assert best == -1
    assert np.all(np.isinf(scores))


def test_mask_restricts_argmin():
    specs, view = rand_window(5)
    batch = enumerate_scored(specs, view, list(view.free_map), lam=LAM)
    dev, g, n = batch.padded_cols()
    _, best = score_reduce(dev, g, n, lam=LAM, g_free=view.free_units,
                           M=view.total_units, mode="ref")
    mask = np.ones(len(batch), dtype=bool)
    mask[best] = False
    s2, b2 = score_reduce(dev, g, n, lam=LAM, g_free=view.free_units,
                          M=view.total_units, mask=mask, mode="ref")
    assert b2 != best
    assert np.isinf(s2[best])


def test_bias_shifts_scores():
    """The bias column (EcoSched's lookahead penalty) adds elementwise."""
    specs, view = rand_window(7)
    batch = enumerate_scored(specs, view, list(view.free_map), lam=LAM)
    dev, g, n = batch.padded_cols()
    bias = np.linspace(0.0, 0.5, len(batch))
    s0, _ = score_reduce(dev, g, n, lam=LAM, g_free=view.free_units,
                         M=view.total_units, mode="ref")
    s1, _ = score_reduce(dev, g, n, lam=LAM, g_free=view.free_units,
                         M=view.total_units, bias=bias, mode="ref")
    assert np.max(np.abs((s1 - s0) - bias.astype(np.float32))) <= TOL


# ---------------------------------------------------------------------------
# Cross-node batched reduction (ISSUE 9): one launch, many nodes
# ---------------------------------------------------------------------------


def batch_cases(seeds):
    """Per-node requests + the solo-path reference results."""
    reqs, refs = [], []
    for seed in seeds:
        specs, view = rand_window(seed)
        batch = enumerate_scored(specs, view, list(view.free_map), lam=LAM)
        dev, g, n = batch.padded_cols()
        reqs.append(dict(dev=dev, g=g, n=n, lam=LAM,
                         g_free=view.free_units, M=view.total_units))
        refs.append((dev, g, n, view))
    return reqs, refs


@pytest.mark.parametrize("mode", ["ref", "interpret"])
def test_batch_matches_per_node_kernel(mode):
    """The batched kernel reproduces the solo path per node, bitwise —
    common (b_pad, s_pad) zero-padding adds exactly +0.0 per combine."""
    reqs, refs = batch_cases(range(9))
    out = score_reduce_batch(reqs, mode=mode)
    assert len(out) == len(reqs)
    for (scores, best), (dev, g, n, view) in zip(out, refs):
        s_solo, b_solo = score_reduce(
            dev, g, n, lam=LAM, g_free=view.free_units,
            M=view.total_units, mode=mode,
        )
        assert best == b_solo
        finite = np.isfinite(s_solo)
        assert np.array_equal(scores[finite], s_solo[finite])
        assert np.all(np.isinf(scores[~finite]))


@pytest.mark.parametrize("mode", ["ref", "interpret"])
def test_batch_mixed_edges(mode):
    """All-infeasible and empty-window nodes ride in the same launch as
    healthy ones without perturbing them."""
    reqs, refs = batch_cases(range(3))
    dead_mask = np.zeros(len(reqs[1]["dev"]), dtype=bool)
    reqs.insert(1, dict(reqs[1], mask=dead_mask))  # all-infeasible clone
    view = NodeView(t=0.0, total_units=8, domains=2, free_units=8,
                    running=[], free_map=[True] * 8, domain_jobs=[0, 0])
    empty = enumerate_scored([], view, list(view.free_map), lam=LAM)
    dev_e, g_e, n_e = empty.padded_cols()
    reqs.append(dict(dev=dev_e, g=g_e, n=n_e, lam=LAM, g_free=8, M=8))
    out = score_reduce_batch(reqs, mode=mode)
    assert out[1][1] == -1 and np.all(np.isinf(out[1][0]))
    assert out[-1][1] == 0  # only the empty action exists
    assert out[-1][0][0] == pytest.approx(empty.scores[0], abs=TOL)
    for (scores, best), (dev, g, n, v) in zip(
        [out[0]] + list(out[2:-1]), refs
    ):
        s_solo, b_solo = score_reduce(
            dev, g, n, lam=LAM, g_free=v.free_units, M=v.total_units,
            mode=mode,
        )
        assert best == b_solo
        finite = np.isfinite(s_solo)
        assert np.array_equal(scores[finite], s_solo[finite])


def test_batch_empty_request_list():
    assert score_reduce_batch([]) == []


@pytest.mark.parametrize("mode", ["ref", "interpret"])
def test_multi_matches_solo_per_window(mode):
    """The row-packed multi-window plane (the COMPLETE path's kernel)
    reproduces a solo ``score_reduce`` per window bitwise, including
    heterogeneous per-window f planes, biases, and λ_f."""
    reqs, _ = batch_cases(range(9))
    rng = np.random.default_rng(0)
    for k, r in enumerate(reqs):  # spice up params per window
        r["lam"] = float(0.1 + 0.1 * k)
        if k % 2 == 0:
            r["f"] = np.ones_like(r["dev"])
            r["lam_f"] = 0.25
        if k % 3 == 0:
            r["bias"] = rng.uniform(0.0, 0.5, size=len(r["dev"])).astype(
                np.float32
            )
    out = score_reduce_multi(reqs, mode=mode)
    assert len(out) == len(reqs)
    for (scores, best), r in zip(out, reqs):
        s_solo, b_solo = score_reduce(
            r["dev"], r["g"], r["n"], f=r.get("f"), lam=r["lam"],
            g_free=r["g_free"], M=r["M"], lam_f=r.get("lam_f", 0.0),
            bias=r.get("bias"), mode=mode,
        )
        assert best == b_solo
        finite = np.isfinite(s_solo)
        assert np.array_equal(scores[finite], s_solo[finite])
        assert np.all(np.isinf(scores[~finite]))


@pytest.mark.parametrize("mode", ["ref", "interpret"])
def test_multi_mixed_edges(mode):
    """Zero-row, all-masked, and healthy windows share one launch: the
    degenerate windows return -1 without perturbing their neighbours."""
    reqs, refs = batch_cases(range(3))
    dead_mask = np.zeros(len(reqs[1]["dev"]), dtype=bool)
    reqs.insert(1, dict(reqs[1], mask=dead_mask))  # all-infeasible clone
    s = reqs[0]["dev"].shape[1]
    reqs.append(  # a truly empty window: zero candidate rows
        dict(dev=np.zeros((0, s), dtype=np.float32),
             g=np.zeros((0, s), dtype=np.float32),
             n=np.zeros((0,), dtype=np.float32), lam=LAM, g_free=8, M=8)
    )
    out = score_reduce_multi(reqs, mode=mode)
    assert out[1][1] == -1 and np.all(np.isinf(out[1][0]))
    assert out[-1][1] == -1 and out[-1][0].size == 0
    for (scores, best), (dev, g, n, v) in zip(
        [out[0]] + list(out[2:-1]), refs
    ):
        s_solo, b_solo = score_reduce(
            dev, g, n, lam=LAM, g_free=v.free_units, M=v.total_units,
            mode=mode,
        )
        assert best == b_solo
        finite = np.isfinite(s_solo)
        assert np.array_equal(scores[finite], s_solo[finite])


def test_multi_empty_request_list():
    assert score_reduce_multi([]) == []


def test_batch_per_node_params_ride_in_smem():
    """Heterogeneous λ/G_free/M/λ_f rows per node in one launch: each
    node's result matches a solo call with its own scalars."""
    specs, view = rand_window(11)
    batch = enumerate_scored(specs, view, list(view.free_map), lam=LAM)
    dev, g, n = batch.padded_cols()
    f = np.ones_like(dev)
    cfgs = [
        dict(lam=0.1, g_free=2, M=4, lam_f=0.0),
        dict(lam=0.9, g_free=16, M=16, lam_f=0.25),
        dict(lam=0.35, g_free=8, M=8, lam_f=0.5),
    ]
    reqs = [dict(dev=dev, g=g, n=n, f=f, **c) for c in cfgs]
    out = score_reduce_batch(reqs, mode="ref")
    for (scores, best), c in zip(out, cfgs):
        s_solo, b_solo = score_reduce(dev, g, n, f=f, mode="ref", **c)
        assert best == b_solo
        assert np.array_equal(scores, s_solo)


def test_engine_jax_end_to_end_matches_vector():
    """EcoSched(engine="jax") reproduces the vector backend's schedule."""
    truth = {
        name: JobProfile(
            name=name,
            runtime={1: t, 2: t / 1.8, 3: t / 2.4, 4: t / 2.8},
            busy_power={1: p, 2: 1.9 * p, 3: 2.7 * p, 4: 3.4 * p},
        )
        for name, t, p in [
            ("a", 100.0, 100.0), ("b", 200.0, 120.0), ("c", 50.0, 90.0),
            ("d", 140.0, 105.0), ("e", 90.0, 115.0),
        ]
    }
    node = Node(units=4, domains=2, idle_power_per_unit=10.0)
    kw = dict(lam=0.4, tau=0.5)
    r_jax = simulate(
        EcoSched(ProfiledPerfModel(truth, noise=0.02, seed=3), engine="jax", **kw),
        node, truth, queue=list(truth),
    )
    r_vec = simulate(
        EcoSched(ProfiledPerfModel(truth, noise=0.02, seed=3), engine="vector", **kw),
        node, truth, queue=list(truth),
    )
    assert [(r.job, r.g, r.start, r.domain) for r in r_jax.records] == [
        (r.job, r.g, r.start, r.domain) for r in r_vec.records
    ]
    assert r_jax.total_energy == r_vec.total_energy

"""Paper-reproduction validation: Table II, headline bands, §V-B/§V-C anchors.

Tolerance bands are generous where the paper leaves freedom (absolute
runtimes are reconstructed — DESIGN.md §6) and tight where it gives
numbers (power model, amortization).
"""
import pytest

from repro.core import (
    EcoSched, Marble, Node, ProfiledPerfModel, SequentialOptimal,
    perf_loss, simulate, summarize,
)
from repro.core import calibration as C

LAM, TAU, NOISE, SEED = 0.35, 0.45, 0.02, 1


def run(system):
    truth = C.build_system(system)
    node = Node(units=4, domains=2, idle_power_per_unit=C.idle_power(system))
    pm = ProfiledPerfModel(truth, noise=NOISE, seed=SEED)
    res = {}
    for pol in [SequentialOptimal(truth), Marble(truth), EcoSched(pm, lam=LAM, tau=TAU)]:
        r = simulate(
            pol, node, truth, queue=list(C.APP_ORDER),
            charge_profiling=pol.name() == "ecosched",
            slowdown_model=C.cross_numa_slowdown
            if pol.name() in ("ecosched", "marble") else None,
        )
        res[r.policy] = r
    return res, truth


@pytest.fixture(scope="module")
def all_systems():
    return {s: run(s) for s in ("h100", "a100", "v100")}


def test_table2_choices_match(all_systems):
    total = 0
    for system, (res, _) in all_systems.items():
        chosen = {rec.job: rec.g for rec in res["ecosched"].records}
        total += sum(1 for a, t in C.TABLE_II.items() if chosen.get(a) == t[system])
    assert total >= 48, f"Table II match {total}/51"


def test_h100_headline_band(all_systems):
    res, _ = all_systems["h100"]
    s = summarize(res["sequential_optimal_gpu"], res["ecosched"])
    # paper: 14.8% / 30.1% / 40.4%
    assert 0.10 <= s["energy_saving"] <= 0.19, s
    assert 0.25 <= s["makespan_improvement"] <= 0.38, s
    assert 0.34 <= s["edp_saving"] <= 0.48, s


def test_v100_headline_band(all_systems):
    res, _ = all_systems["v100"]
    s = summarize(res["sequential_optimal_gpu"], res["ecosched"])
    # paper: 4.4% / 14.1% / 17.9% — V100 has least slack
    assert 0.01 <= s["energy_saving"] <= 0.09, s
    assert 0.05 <= s["makespan_improvement"] <= 0.18, s
    h = summarize(
        all_systems["h100"][0]["sequential_optimal_gpu"], all_systems["h100"][0]["ecosched"]
    )
    assert h["edp_saving"] > s["edp_saving"]  # gains larger on H100 (§V-A)


def test_ecosched_beats_marble_everywhere(all_systems):
    for system, (res, _) in all_systems.items():
        base = res["sequential_optimal_gpu"]
        e = summarize(base, res["ecosched"])
        m = summarize(base, res["marble"])
        assert e["energy_saving"] > m["energy_saving"], system
        assert e["edp_saving"] > m["edp_saving"], system


def test_gpt2_power_anchor():
    truth = C.build_system("h100")
    gpt2 = truth["gpt2"]
    assert gpt2.busy_power[3] == pytest.approx(1287, rel=0.02)  # §V-C
    assert gpt2.busy_power[2] == pytest.approx(946, rel=0.02)
    assert gpt2.profiling_energy == pytest.approx(64e3)


def test_vb_case_study_downsizing(all_systems):
    res, truth = all_systems["h100"]
    chosen = {rec.job: rec.g for rec in res["ecosched"].records}
    assert chosen["pot3d"] == 2 and chosen["resnet50"] == 3 and chosen["gpt2"] == 2
    # §V-B anchors are the pure downsizing slowdowns (no interference):
    pot3d, r50 = truth["pot3d"], truth["resnet50"]
    assert pot3d.runtime[2] / pot3d.runtime[4] - 1 == pytest.approx(0.10, abs=0.01)
    assert r50.runtime[3] / r50.runtime[4] - 1 == pytest.approx(0.05, abs=0.01)
    # schedule-level losses add the residual cross-NUMA factor (Fig. 9)
    losses = perf_loss(res["ecosched"], truth)
    assert losses["pot3d"] < 0.16 and losses["resnet50"] < 0.12


def test_miniweather_v100_anchor(all_systems):
    res, truth = all_systems["v100"]
    chosen = {rec.job: rec.g for rec in res["ecosched"].records}
    assert chosen["miniweather"] == 1
    losses = perf_loss(res["ecosched"], truth)
    assert losses["miniweather"] == pytest.approx(0.40, abs=0.06)  # §V-C: 40%
    mw = truth["miniweather"]
    saving = 1 - mw.energy(1) / mw.energy(4)
    assert saving == pytest.approx(0.20, abs=0.05)  # §V-C: ~20%


def test_decision_latency_small(all_systems):
    res, _ = all_systems["h100"]
    eco = res["ecosched"]
    per_event = eco.decision_time_s / max(eco.decision_events, 1)
    assert per_event < 0.05  # 50 ms in pure Python (paper: <0.5 ms in C)

"""End-to-end behaviour tests for the paper's system (scaffold contract).

The heavyweight end-to-end paths live in dedicated modules:
  * paper reproduction bands  — test_calibration.py
  * training + restart        — test_train_integration.py
  * multi-device + elastic    — test_multidevice.py
This module asserts the top-level wiring: public imports, the benchmark
harness contract, and the dry-run driver's single-cell path (reduced
size) in a subprocess.
"""
import os
import subprocess
import sys

import pytest


def test_public_imports():
    import repro.core as core
    from repro.configs import get_config, grid
    from repro.models import build_model

    assert hasattr(core, "EcoSched") and hasattr(core, "OracleSolver")
    assert len(list(grid())) == 40


def test_benchmark_modules_import():
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    import benchmarks.run  # noqa: F401
    from benchmarks import common  # noqa: F401


@pytest.mark.slow
def test_dryrun_single_cell_subprocess():
    """The dry-run driver lowers+compiles a full cell on the 512-device
    production mesh (whisper-base: the cheapest full config)."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out_dir = "/tmp/repro_test_dryrun"
    proc = subprocess.run(
        [
            sys.executable, "-m", "repro.launch.dryrun",
            "--arch", "whisper-base", "--shape", "decode_32k",
            "--out", out_dir, "--skip-variants",
        ],
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        env={**env, "PYTHONPATH": "src"},
        capture_output=True, text=True, timeout=900,
    )
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    assert "dry-run complete" in proc.stdout

"""Multi-device behaviour (sub-mesh carving, sharded ZeRO training, elastic
failover).  Runs in a subprocess with 8 emulated host devices — the main
test process must keep the default single device (dry-run isolation rule).
"""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

assert len(jax.devices()) == 8

# --- sub-mesh carving: two disjoint 4-device meshes -----------------------
from repro.distributed.meshes import carve_submesh
m1 = carve_submesh(jax.devices(), 0, 4, model_axis=2)
m2 = carve_submesh(jax.devices(), 4, 4, model_axis=2)
assert set(m1.devices.flat).isdisjoint(set(m2.devices.flat))

import jax.numpy as jnp
x1 = jax.device_put(np.ones((8, 16), np.float32), NamedSharding(m1, P("data", "model")))
x2 = jax.device_put(np.ones((8, 16), np.float32) * 2, NamedSharding(m2, P("data", "model")))
y1 = jax.jit(lambda a: (a * 3).sum())(x1)
y2 = jax.jit(lambda a: (a * 3).sum())(x2)
assert float(y1) == 384.0 and float(y2) == 768.0
print("submesh OK")

# --- sharded ZeRO training on a 4x2 mesh ------------------------------------
from repro.configs import get_config, reduced
from repro.data import SyntheticLM
from repro.models import Runtime, build_model
from repro.optim import AdamW, AdamWConfig, WarmupCosine
from repro.train.loop import Trainer, TrainerConfig
from repro.distributed.fault import FailureInjector
import shutil

ckpt = "/tmp/repro_test_md"
shutil.rmtree(ckpt, ignore_errors=True)
cfg = reduced(get_config("qwen3-32b")).replace(vocab_size=512)
model = build_model(cfg, Runtime(remat="none"))
data = SyntheticLM(cfg, batch=8, seq_len=32)
trainer = Trainer(
    cfg, model, AdamW(AdamWConfig(master_weights=True)),
    WarmupCosine(peak_lr=2e-3, warmup_steps=3, decay_steps=30),
    data,
    TrainerConfig(total_steps=30, ckpt_every=8, ckpt_dir=ckpt, log_every=1000),
    model_par=2,
    failure_injector=FailureInjector(schedule={18: 2}),
)
out = trainer.run()
assert out["final_step"] == 30, out["final_step"]
assert out["recoveries"] == 1, out["recoveries"]
losses = [h["loss"] for h in out["history"]]
assert losses[-1] < losses[0], (losses[0], losses[-1])
print("elastic ZeRO training OK", losses[0], "->", losses[-1])

# --- elastic rescale at a checkpoint boundary (EcoSched-Elastic hook) -------
trainer.rescale(jax.devices()[:4])
state, step = trainer._init_or_restore()
assert step == 30
print("rescale OK")
print("ALL OK")
"""


@pytest.mark.slow
def test_multidevice_subprocess():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        env=env, capture_output=True, text=True, timeout=900,
    )
    assert proc.returncode == 0, proc.stdout[-3000:] + proc.stderr[-3000:]
    assert "ALL OK" in proc.stdout

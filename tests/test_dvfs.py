"""DVFS third axis (ISSUE 7): joint (count × frequency) decision stack.

Locks the tentpole invariants: the analytic sweet-spot frequency model
(hw.py / calibration.py), single-frequency collapse (``freq_levels=1``
systems are bit-identical to the count-only stack on every scoring
engine), joint argmin == brute-force scan over the (g, f) candidate
space, and the Pallas score-reduce kernel's frequency axis vs numpy.
"""
import hashlib

import numpy as np
import pytest

from repro.core import (
    EcoSched,
    Node,
    ProfiledPerfModel,
    simulate,
)
from repro.core import calibration as C
from repro.core.actions import enumerate_actions
from repro.core.engine import enumerate_scored
from repro.core.events import ElasticConfig
from repro.core.score import score
from repro.core.types import JobSpec, ModeEstimate, NodeView
from repro.kernels.score_reduce import score_reduce
from repro.roofline.hw import A100, CHIPS, H100, V100

LAM, TAU, NOISE, SEED = 0.35, 0.45, 0.02, 1


def fp_records(records):
    s = ";".join(
        f"{r.job}|{r.g}|{r.start!r}|{r.end!r}|{r.node}|{r.domain}"
        for r in records
    )
    return hashlib.md5(s.encode()).hexdigest()


# ---------------------------------------------------------------------------
# Sweet-spot frequency model (roofline/hw.py + calibration.py)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("chip", [H100, A100, V100], ids=lambda c: c.name)
def test_chip_frequency_ladder_sane(chip):
    ratios = chip.freq_ratios
    assert ratios[0] == 1.0
    assert all(b < a for a, b in zip(ratios, ratios[1:]))  # monotone down
    assert 0.0 < chip.power_floor < 1.0
    # level 0 is the base clock exactly: both multipliers collapse to 1
    assert chip.freq_time_multiplier(0, mu=0.5) == 1.0
    assert chip.freq_power_multiplier(0) == 1.0
    for f in range(1, len(ratios)):
        # downclocking always costs time and saves power
        assert chip.freq_time_multiplier(f, mu=0.5) > chip.freq_time_multiplier(f - 1, mu=0.5)
        assert chip.freq_power_multiplier(f) < chip.freq_power_multiplier(f - 1)
        # memory-bound work stretches less than compute-bound work
        assert chip.freq_time_multiplier(f, mu=0.8) < chip.freq_time_multiplier(f, mu=0.1)


def test_sweet_spot_edp_separates_memory_and_compute_bound():
    """The model's point: a deep downclock EDP-wins for memory-bound work
    and EDP-loses for compute-bound work (EDP multiplier = T²·P)."""
    f = len(H100.freq_ratios) - 1

    def edp_mult(mu):
        t = H100.freq_time_multiplier(f, mu)
        return t * t * H100.freq_power_multiplier(f)

    assert edp_mult(0.75) < 1.0  # lbm-like: wins
    assert edp_mult(0.10) > 1.0  # MonteCarlo-like: loses


def test_freq_curves_clamped_to_ladder():
    ft, fp = C.freq_curves("v100", "bert", levels=99)
    assert sorted(ft) == sorted(fp) == list(range(len(V100.freq_ratios)))
    assert ft[0] == fp[0] == 1.0


def test_build_system_single_level_is_the_count_only_table():
    base = C.build_system("h100")
    one = C.build_system("h100", freq_levels=1)
    for app in C.APP_ORDER:
        b, o = base[app], one[app]
        assert not b.freq_time and not o.freq_time
        assert b.runtime == o.runtime and b.busy_power == o.busy_power
        assert o.freq_levels == (0,)
        for g in o.feasible_counts:
            # the *_at(g, 0) helpers collapse exactly to the count curves
            assert o.runtime_at(g, 0) == o.runtime[g]
            assert o.power_at(g, 0) == o.busy_power[g]
            assert o.energy_at(g, 0) == o.energy(g)


def test_build_system_levels_attach_joint_curves():
    sys3 = C.build_system("a100", freq_levels=3)
    for app in C.APP_ORDER:
        prof = sys3[app]
        assert prof.freq_levels == (0, 1, 2)
        mu = C.MEMORY_BOUND_MU[app]
        for g in prof.feasible_counts:
            assert prof.runtime_at(g, 0) == prof.runtime[g]
            for f in (1, 2):
                assert prof.runtime_at(g, f) == prof.runtime[g] * A100.freq_time_multiplier(f, mu)
                assert prof.power_at(g, f) == prof.busy_power[g] * A100.freq_power_multiplier(f)
                assert prof.runtime_at(g, f) > prof.runtime_at(g, f - 1)
                assert prof.power_at(g, f) < prof.power_at(g, f - 1)


# ---------------------------------------------------------------------------
# Joint argmin == brute-force scan over (g, f)
# ---------------------------------------------------------------------------


def _random_specs(rng, n_jobs, n_levels):
    specs = []
    for j in range(n_jobs):
        modes = []
        for g in sorted(rng.choice([1, 2, 3, 4], size=rng.integers(1, 4), replace=False)):
            t0 = float(rng.uniform(0.8, 2.0))
            for f in range(n_levels):
                modes.append(
                    ModeEstimate(
                        g=int(g),
                        t_norm=t0 * (1.0 + 0.15 * f),
                        p_bar=float(rng.uniform(80.0, 400.0)),
                        e_norm=float(rng.uniform(0.9, 1.4)) * (1.0 - 0.08 * f),
                        f=f,
                    )
                )
        specs.append(JobSpec(f"j{j}", tuple(modes)))
    return specs


@pytest.mark.parametrize("lam_f", [0.0, 0.25])
def test_joint_argmin_matches_brute_force_scan(lam_f):
    """The engine's tie-broken argmin over the joint candidate space
    equals an independent brute-force rescore-and-scan of the reference
    action list (min score, then max Σg, then generation order)."""
    rng = np.random.default_rng(7)
    for trial in range(8):
        n_levels = int(rng.integers(1, 4))
        specs = _random_specs(rng, int(rng.integers(1, 4)), n_levels)
        free = int(rng.integers(1, 5))
        free_map = [True] * free + [False] * (4 - free)
        view = NodeView(
            t=0.0, total_units=4, domains=2, free_units=free,
            running=[], free_map=free_map,
        )
        ref = enumerate_actions(specs, view, free_map, lam=LAM, lam_f=lam_f)
        # brute force: rescore every action from its modes with Eq. (1)
        rescored = [
            score(tuple(m for _, m in a), g_free=free, M=4, lam=LAM, lam_f=lam_f)
            for _, a in ref
        ]
        assert rescored == pytest.approx([s for s, _ in ref])
        best_bf = min(
            range(len(ref)),
            key=lambda i: (rescored[i], -sum(m.g for _, m in ref[i][1]), i),
        )
        batch = enumerate_scored(specs, view, free_map, lam=LAM, lam_f=lam_f)
        bi = batch.best_index()
        key = lambda a: sorted((sp.name, m.g, m.f) for sp, m in a)
        assert key(batch.action(bi)) == key(ref[best_bf][1])
        assert batch.scores[bi] == pytest.approx(rescored[best_bf])


def test_frequency_axis_multiplies_candidate_space():
    """3 levels must enumerate strictly more candidates than 1, and
    collapsing the frequency axis recovers the count-only set exactly."""
    rng = np.random.default_rng(3)
    specs3 = _random_specs(rng, 2, 3)
    specs1 = [
        JobSpec(s.name, tuple(m for m in s.modes if m.f == 0)) for s in specs3
    ]
    view = NodeView(
        t=0.0, total_units=4, domains=2, free_units=4,
        running=[], free_map=[True] * 4,
    )
    a3 = enumerate_actions(specs3, view, [True] * 4, lam=LAM)
    a1 = enumerate_actions(specs1, view, [True] * 4, lam=LAM)
    assert len(a3) > len(a1)
    collapsed = {
        tuple(sorted((sp.name, m.g) for sp, m in a)) for _, a in a3
    }
    assert {
        tuple(sorted((sp.name, m.g) for sp, m in a)) for _, a in a1
    } <= collapsed


# ---------------------------------------------------------------------------
# Kernel parity with the frequency axis live
# ---------------------------------------------------------------------------


def _np_reference(dev, g, f, n, bias, mask, lam, g_free, M, lam_f):
    n_eff = np.maximum(n, 1.0)
    s = (
        dev.sum(axis=1) / n_eff
        + lam * (g_free - g.sum(axis=1)) / M
        + lam_f * f.sum(axis=1) / n_eff
        + bias
    )
    return np.where(mask > 0, s, np.inf)


@pytest.mark.parametrize("mode", ["ref", "interpret"])
def test_kernel_frequency_axis_matches_numpy(mode):
    rng = np.random.default_rng(11)
    B, S = 37, 5
    dev = rng.uniform(-0.5, 0.5, size=(B, S)).astype(np.float32)
    g = rng.integers(0, 5, size=(B, S)).astype(np.float32)
    f = rng.integers(0, 4, size=(B, S)).astype(np.float32)
    n = rng.integers(1, S + 1, size=B).astype(np.float32)
    bias = rng.uniform(0.0, 0.1, size=B).astype(np.float32)
    mask = (rng.random(B) > 0.2).astype(np.float32)
    kw = dict(lam=0.35, g_free=4, M=16, lam_f=0.4)
    want = _np_reference(dev, g, f, n, bias, mask, **kw)
    got, best = score_reduce(dev, g, n, f=f, bias=bias, mask=mask, mode=mode, **kw)
    feas = mask > 0
    assert np.allclose(got[feas], want[feas], atol=1e-6)
    tot = g.sum(axis=1)
    m = want.min()
    tie = np.flatnonzero((want == m) & feas)
    t_best = tot[tie].max()
    assert best == int(tie[tot[tie] == t_best].min())


@pytest.mark.parametrize("mode", ["ref", "interpret"])
def test_kernel_no_f_plane_equals_zero_levels(mode):
    """``f=None`` must score bit-identically to an all-zero plane even at
    ``lam_f > 0`` — the single-frequency collapse inside the kernel."""
    rng = np.random.default_rng(13)
    B, S = 16, 3
    dev = rng.uniform(-0.5, 0.5, size=(B, S)).astype(np.float32)
    g = rng.integers(0, 5, size=(B, S)).astype(np.float32)
    n = rng.integers(1, S + 1, size=B).astype(np.float32)
    kw = dict(lam=0.35, g_free=4, M=16, lam_f=0.7, mode=mode)
    s0, b0 = score_reduce(dev, g, n, f=None, **kw)
    sz, bz = score_reduce(dev, g, n, f=np.zeros_like(dev), **kw)
    assert np.array_equal(s0, sz) and b0 == bz


def test_kernel_all_infeasible_returns_minus_one():
    dev = np.zeros((4, 2), dtype=np.float32)
    g = np.ones((4, 2), dtype=np.float32)
    f = np.ones((4, 2), dtype=np.float32)
    n = np.full(4, 2.0, dtype=np.float32)
    _, best = score_reduce(
        dev, g, n, f=f, lam=0.5, g_free=4, M=4, lam_f=0.3,
        mask=np.zeros(4, dtype=np.float32), mode="ref",
    )
    assert best == -1


# ---------------------------------------------------------------------------
# End-to-end: engines agree on DVFS schedules; frequency-off collapses
# ---------------------------------------------------------------------------


def _run(truth, engine):
    node = Node(4, 2, C.idle_power("h100"))
    pol = EcoSched(
        ProfiledPerfModel(truth, noise=NOISE, seed=SEED),
        lam=LAM, tau=TAU, engine=engine,
    )
    return simulate(
        pol, node, truth,
        arrivals=[(120.0 * i, a) for i, a in enumerate(C.APP_ORDER)],
        slowdown_model=C.cross_numa_slowdown,
    )


def test_three_engines_agree_on_dvfs_schedule():
    truth = C.build_system("h100", freq_levels=3)
    runs = {eng: _run(truth, eng) for eng in ("python", "vector", "jax")}
    keys = {
        eng: [(r.job, r.g, r.f, r.start, r.end) for r in res.records]
        for eng, res in runs.items()
    }
    assert keys["python"] == keys["vector"] == keys["jax"]
    assert runs["python"].total_energy == runs["vector"].total_energy
    # the third axis is actually exercised (not a degenerate collapse)
    assert any(r.f > 0 for r in runs["vector"].records)
    levels = {a: truth[a].freq_levels for a in truth}
    assert all(r.f in levels[r.job] for r in runs["vector"].records)


@pytest.mark.parametrize("engine", ["python", "vector", "jax"])
def test_single_frequency_bit_identical_to_count_only(engine):
    """freq_levels=1 systems reproduce the count-only schedule (the PR 6
    golden fingerprint) bit-identically on every engine, with f ≡ 0."""
    base = _run(C.build_system("h100"), engine)
    one = _run(C.build_system("h100", freq_levels=1), engine)
    assert fp_records(one.records) == fp_records(base.records)
    assert one.total_energy == base.total_energy
    assert one.makespan == base.makespan
    assert all(r.f == 0 for r in one.records)
    # and the count-only schedule is still the PR 6 golden lock
    assert fp_records(base.records) == "4e5acdeeb3914722311e6f77658684e6"


def test_dvfs_elastic_run_retunes_frequency():
    """Elastic DVFS: frequency retunes ride checkpoint/relaunch, land in
    ``freq_history`` (not ``resize_history``), and the run still drains."""
    truth = C.build_system("h100", freq_levels=3)
    node = Node(4, 2, C.idle_power("h100"))
    pol = EcoSched(
        ProfiledPerfModel(truth, noise=NOISE, seed=SEED), lam=LAM, tau=TAU
    )
    res = simulate(
        pol, node, truth,
        arrivals=[(120.0 * i, a) for i, a in enumerate(C.APP_ORDER)],
        slowdown_model=C.cross_numa_slowdown,
        elastic=ElasticConfig(resize=True),
    )
    assert sorted({r.job for r in res.records}) == sorted(C.APP_ORDER)
    assert res.retunes >= 0
    for job, moves in res.freq_history.items():
        for _, f_old, f_new in moves:
            assert f_old != f_new
            assert f_new in truth[job].freq_levels

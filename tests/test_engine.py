"""Vectorized scoring engine (core/engine.py): parity locks against the
pure-Python reference, beam-dedup regression, NUMA-domain occupancy
invariants, adversarial trace round-trip, benchmark smoke."""
import numpy as np
import pytest

from repro.core import (
    EcoSched,
    JobProfile,
    Node,
    OraclePerfModel,
    PlacementState,
    ProfiledPerfModel,
    simulate,
)
from repro.core.actions import enumerate_actions
from repro.core.arrivals import Arrival, dumps_trace, loads_trace
from repro.core.engine import PlacementOracle, enumerate_scored
from repro.core.perfmodel import _mk_spec
from repro.core.score import tau_filter
from repro.core.types import JobSpec, Launch, ModeEstimate, NodeView


# ---------------------------------------------------------------------------
# Seeded random node states
# ---------------------------------------------------------------------------


def rand_state(seed):
    """Random (specs, view): node size/domains, fragmented free map with
    honest per-domain occupancy, jobs with random feasible mode subsets."""
    rng = np.random.default_rng(seed)
    M = int(rng.choice([4, 8, 16]))
    K = int(rng.choice([2, 4]))
    W = int(rng.integers(1, 8))
    counts = [g for g in (1, 2, 3, 4, 8, 16) if g <= M]
    specs = []
    for i in range(W):
        sub = sorted(
            rng.choice(counts, size=int(rng.integers(1, len(counts) + 1)), replace=False)
        )
        t_hat = {int(g): float(100.0 / g ** rng.uniform(0.3, 1.0)) for g in sub}
        p_hat = {int(g): float(300.0 * g ** rng.uniform(0.6, 0.95)) for g in sub}
        specs.append(_mk_spec(f"j{i}", t_hat, p_hat))
    st = PlacementState(M, K)
    running = []
    for _ in range(int(rng.integers(0, K))):
        g = int(rng.integers(1, max(2, M // 2)))
        if st.can_allocate(g) and st.occupied_domains() < K:
            st.allocate(g)
            running.append(object())  # only len()/fallback is ever used
    view = NodeView(
        t=0.0, total_units=M, domains=K, free_units=st.free_count(),
        running=running, free_map=list(st.free), domain_jobs=list(st.domain_jobs),
    )
    return specs, view


def names_g(action):
    return [(sp.name, m.g) for sp, m in action]


def pick(scored):
    """EcoSched's selection rule over a reference-format scored list."""
    scored = sorted(scored, key=lambda kv: (kv[0], -sum(m.g for _, m in kv[1])))
    return scored[0]


# ---------------------------------------------------------------------------
# Parity locks (ISSUE 2 acceptance: argmin identical, scores within 1e-9)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("exact_limit,beam", [(50_000, 16), (1, 8)], ids=["exact", "beam"])
def test_engine_parity_property(exact_limit, beam):
    for seed in range(120):
        specs, view = rand_state(seed)
        ref = enumerate_actions(
            specs, view, list(view.free_map), lam=0.5, exact_limit=exact_limit, beam=beam
        )
        batch = enumerate_scored(
            specs, view, list(view.free_map), lam=0.5, exact_limit=exact_limit, beam=beam
        )
        vec = batch.to_list()
        assert len(ref) == len(vec)
        for (rs, ra), (vs, va) in zip(ref, vec):
            assert abs(rs - vs) <= 1e-9
            assert names_g(ra) == names_g(va)
        rs, ra = pick(ref)
        i = batch.best_index()
        assert abs(rs - float(batch.scores[i])) <= 1e-9
        assert names_g(ra) == names_g(batch.action(i))


def test_engine_policy_parity_end_to_end():
    """Vector and python EcoSched backends produce the identical schedule."""
    truth = {
        name: JobProfile(
            name=name,
            runtime={1: t, 2: t / 1.8, 3: t / 2.4, 4: t / 2.8},
            busy_power={1: p, 2: 1.9 * p, 3: 2.7 * p, 4: 3.4 * p},
        )
        for name, t, p in [
            ("a", 100.0, 100.0), ("b", 200.0, 120.0), ("c", 50.0, 90.0),
            ("d", 140.0, 105.0), ("e", 90.0, 115.0),
        ]
    }
    node = Node(units=4, domains=2, idle_power_per_unit=10.0)
    pm = ProfiledPerfModel(truth, noise=0.02, seed=3)
    kw = dict(lam=0.4, tau=0.5)
    r_vec = simulate(EcoSched(pm, engine="vector", **kw), node, truth, queue=list(truth))
    r_py = simulate(EcoSched(pm, engine="python", **kw), node, truth, queue=list(truth))
    assert [(r.job, r.g, r.start, r.domain) for r in r_vec.records] == [
        (r.job, r.g, r.start, r.domain) for r in r_py.records
    ]
    assert r_vec.total_energy == r_py.total_energy


def test_placement_oracle_matches_state_replay():
    """Bitmask replay == PlacementState replay for random count multisets."""
    for seed in range(200):
        rng = np.random.default_rng(seed)
        _, view = rand_state(seed)
        oracle = PlacementOracle(view.free_map, view.domains, view.domain_jobs)
        n = int(rng.integers(1, view.domains + 1))
        counts = tuple(
            sorted((int(rng.integers(1, view.total_units + 1)) for _ in range(n)),
                   reverse=True)
        )
        st = PlacementState(view.total_units, view.domains)
        st.free = list(view.free_map)
        st.domain_jobs = list(view.domain_jobs)
        try:
            for g in counts:
                st.allocate(g)
            expect = True
        except ValueError:
            expect = False
        assert oracle.placeable(counts) == expect


# ---------------------------------------------------------------------------
# Beam dedupe (satellite): duplicates must not crowd out the argmin
# ---------------------------------------------------------------------------


def crowding_window(seed=5, W=6):
    """Seeded window where the pre-fix beam (no dedupe) lost the exact
    argmin to duplicate partials at beam=2 (found by replaying the PR-1
    beam against exhaustive enumeration)."""
    rng = np.random.default_rng(seed)
    specs = []
    for i in range(W):
        sub = sorted(rng.choice([1, 2, 4, 8], size=int(rng.integers(2, 5)), replace=False))
        t_hat = {int(g): float(100.0 / g ** rng.uniform(0.3, 1.0)) for g in sub}
        p_hat = {int(g): float(300.0 * g ** rng.uniform(0.6, 0.95)) for g in sub}
        specs.append(_mk_spec(f"j{i}", t_hat, p_hat))
    view = NodeView(
        t=0.0, total_units=16, domains=4, free_units=16,
        running=[], free_map=[True] * 16, domain_jobs=[0] * 4,
    )
    return specs, view


def test_beam_dedup_finds_exact_argmin():
    specs, view = crowding_window()
    exact = pick(enumerate_actions(specs, view, list(view.free_map),
                                   lam=0.35, exact_limit=10**9))
    beam = pick(enumerate_actions(specs, view, list(view.free_map),
                                  lam=0.35, exact_limit=1, beam=2))
    assert set(names_g(beam[1])) == set(names_g(exact[1]))
    assert beam[0] == pytest.approx(exact[0], abs=1e-12)


def test_beam_results_have_no_duplicate_actions():
    for seed in (5, 23, 30):
        specs, view = crowding_window(seed)
        for enum in (enumerate_actions, lambda *a, **k: enumerate_scored(*a, **k).to_list()):
            res = enum(specs, view, list(view.free_map), lam=0.35, exact_limit=1, beam=4)
            keys = [frozenset(names_g(a)) for _, a in res]
            assert len(keys) == len(set(keys))


# ---------------------------------------------------------------------------
# NUMA-domain occupancy (satellite)
# ---------------------------------------------------------------------------


def test_placement_spreads_across_domains():
    # pre-fix: unit 1 on a 4-unit/2-domain node was labeled domain 0
    # (1*2//4), stacking two jobs in domain 0 while domain 1 sat empty
    st = PlacementState(4, 2)
    _, d1 = st.allocate(1)
    _, d2 = st.allocate(1)
    assert {d1, d2} == {0, 1}
    assert st.occupied_domains() == 2


def test_placement_occupancy_released():
    st = PlacementState(4, 2)
    ids1, d1 = st.allocate(2)
    ids2, d2 = st.allocate(2)
    assert {d1, d2} == {0, 1}
    st.release(ids1, d1)
    assert st.occupied_domains() == 1
    ids3, d3 = st.allocate(1)
    assert d3 == d1  # the freed domain is reused, not the occupied one


def test_domain_occupancy_invariant_under_random_churn():
    """Whenever an empty domain exists, a new job must be homed in one —
    co-running jobs never share a domain while another sits empty."""
    for seed in range(50):
        rng = np.random.default_rng(seed)
        M = int(rng.choice([4, 8, 16]))
        K = int(rng.choice([2, 4]))
        st = PlacementState(M, K)
        live = []
        for _ in range(60):
            if live and rng.random() < 0.4:
                ids, dom = live.pop(int(rng.integers(len(live))))
                st.release(ids, dom)
                continue
            if st.occupied_domains() >= K:
                continue
            g = int(rng.integers(1, M + 1))
            if not st.can_allocate(g):
                continue
            had_empty = st.occupied_domains() < K
            before = list(st.domain_jobs)
            ids, dom = st.allocate(g)
            if had_empty and 0 in [
                before[d]
                for d in range(st.domain_of_unit(ids[0]), st.domain_of_unit(ids[-1]) + 1)
            ]:
                assert before[dom] == 0, (seed, before, ids, dom)
            live.append((ids, dom))
        assert sum(st.domain_jobs) == len(live)


def test_marble_replay_matches_spreading_allocator():
    """Marble's feasibility replay must use the real domain state: with
    1-domain plain first-fit it predicted placements the domain-spreading
    allocator doesn't make, and the simulator crashed on M=16/K=4 with
    optimal counts [1, 1, 12]."""
    from repro.core import Marble

    truth = {
        "a": JobProfile(name="a", runtime={1: 100.0}, busy_power={1: 100.0}),
        "b": JobProfile(name="b", runtime={1: 100.0}, busy_power={1: 100.0}),
        "c": JobProfile(name="c", runtime={12: 50.0}, busy_power={12: 900.0}),
    }
    node = Node(units=16, domains=4, idle_power_per_unit=10.0)
    r = simulate(Marble(truth), node, truth, queue=["a", "b", "c"])
    assert sorted(rec.job for rec in r.records) == ["a", "b", "c"]


def test_simulated_corunners_get_distinct_domains():
    truth = {
        name: JobProfile(
            name=name,
            runtime={1: 100.0, 2: 60.0},
            busy_power={1: 100.0, 2: 180.0},
        )
        for name in ("a", "b", "c", "d")
    }
    node = Node(units=4, domains=2, idle_power_per_unit=10.0)
    r = simulate(EcoSched(OraclePerfModel(truth), lam=0.2, tau=1.0),
                 node, truth, queue=list(truth))
    assert all(rec.domain >= 0 for rec in r.records)
    for i, a in enumerate(r.records):
        for b in r.records[i + 1:]:
            if a.start < b.end - 1e-9 and b.start < a.end - 1e-9:  # overlap
                assert a.domain != b.domain, (a, b)


def test_engine_overflow_falls_back_to_reference():
    """Windows too wide for int64 action-set keys: enumerate_scored raises
    a clear error and EcoSched transparently uses the reference path."""
    specs = [
        JobSpec(f"j{i}", tuple(
            ModeEstimate(g=g, t_norm=1.0 + 0.01 * g, p_bar=100.0, e_norm=1.0 + 0.02 * g)
            for g in (1, 2, 16)
        ))
        for i in range(13)
    ]
    view = NodeView(t=0.0, total_units=64, domains=8, free_units=64,
                    running=[], free_map=[True] * 64, domain_jobs=[0] * 8)
    with pytest.raises(OverflowError):
        enumerate_scored(specs, view, list(view.free_map), lam=0.3, exact_limit=1, beam=4)

    class Model:
        def spec(self, job):
            return specs[int(job[1:])]

    pol = EcoSched(Model(), lam=0.3, tau=1.0, exact_limit=1, beam=4, engine="vector")
    ref = EcoSched(Model(), lam=0.3, tau=1.0, exact_limit=1, beam=4, engine="python")
    jobs = [s.name for s in specs]
    assert pol.on_event(view, jobs) == ref.on_event(view, jobs)


# ---------------------------------------------------------------------------
# τ-filter guard (satellite)
# ---------------------------------------------------------------------------


def test_tau_filter_empty_modes_no_crash():
    spec = JobSpec("x", ())
    out = tau_filter(spec, 0.3)
    assert out.modes == ()


def test_ecosched_skips_modeless_jobs():
    class HoleyModel:
        def spec(self, job):
            if job == "bad":
                return JobSpec("bad", ())
            return JobSpec(job, (ModeEstimate(g=1, t_norm=1.0, p_bar=100.0, e_norm=1.0),))

    view = NodeView(t=0.0, total_units=4, domains=2, free_units=4,
                    running=[], free_map=[True] * 4, domain_jobs=[0, 0])
    for engine in ("vector", "python"):
        pol = EcoSched(HoleyModel(), lam=0.2, tau=0.3, engine=engine)
        launches = pol.on_event(view, ["bad", "ok"])
        assert [ln.job for ln in launches] == ["ok"]
        assert pol.on_event(view, ["bad"]) == []


# ---------------------------------------------------------------------------
# Trace parsing (satellite)
# ---------------------------------------------------------------------------


def test_trace_roundtrip_adversarial_names():
    stream = [
        Arrival(t=0.5, name="sweep,lr=0.1#0", app="sweep,lr=0.1"),
        Arrival(t=1.25, name='he said "go"#1', app='he said "go"'),
        Arrival(t=2.0, name="plain#2", app="plain"),
        Arrival(t=3.0, name="multi\nline#3", app="multi\nline"),
    ]
    assert loads_trace(dumps_trace(stream)) == stream


def test_trace_plain_names_keep_legacy_bytes():
    stream = [Arrival(t=1.5, name="gpt2#0", app="gpt2")]
    assert dumps_trace(stream) == "t,name,app\n1.5,gpt2#0,gpt2\n"
    legacy = "t,name,app\n1.5,gpt2#0,gpt2\n"
    assert loads_trace(legacy) == stream


def test_trace_rejects_empty_fields_and_garbage():
    with pytest.raises(ValueError):
        dumps_trace([Arrival(t=0.0, name="", app="x")])
    with pytest.raises(ValueError):
        loads_trace("nope\n1,2\n")
    with pytest.raises(ValueError):
        loads_trace("t,name,app\n1.0,only-two\n")


# ---------------------------------------------------------------------------
# Benchmark smoke (satellite): the decision-overhead tripwire must run
# ---------------------------------------------------------------------------


def test_bench_decision_overhead_smoke():
    from benchmarks.bench_decision_overhead import run
    from benchmarks.common import Csv

    res = run(Csv(), verbose=False, smoke=True)  # parity-gates internally
    assert res and all(r["vector_ms"] > 0 for r in res.values())

"""Integration: training loop end-to-end — loss decreases, checkpoint
resume is bit-reproducible, stragglers are detected."""
import shutil

import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.data import DataConfig, SyntheticLM
from repro.distributed.fault import StragglerMonitor
from repro.models import Runtime, build_model
from repro.optim import AdamW, AdamWConfig, WarmupCosine
from repro.train.loop import Trainer, TrainerConfig


def make_trainer(ckpt_dir, steps, seed=0, horizon=20):
    cfg = reduced(get_config("granite-8b")).replace(vocab_size=512)
    model = build_model(cfg, Runtime(remat="none"))
    data = SyntheticLM(cfg, batch=4, seq_len=64, dcfg=DataConfig(seed=1))
    return Trainer(
        cfg, model, AdamW(AdamWConfig()),
        WarmupCosine(peak_lr=3e-3, warmup_steps=5, decay_steps=horizon),
        data,
        TrainerConfig(total_steps=steps, ckpt_every=10, ckpt_dir=ckpt_dir,
                      log_every=1000, seed=seed),
    )


def test_loss_decreases(tmp_path):
    out = make_trainer(str(tmp_path / "a"), 30, horizon=30).run()
    losses = [h["loss"] for h in out["history"]]
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3


def test_checkpoint_resume_reproducible(tmp_path):
    full = make_trainer(str(tmp_path / "full"), 20).run()
    # interrupted run: stop at 10 (ckpt), then resume to 20 in a new Trainer
    make_trainer(str(tmp_path / "resume"), 10).run()
    resumed = make_trainer(str(tmp_path / "resume"), 20).run()
    assert resumed["final_step"] == 20
    np.testing.assert_allclose(
        resumed["final_loss"], full["final_loss"], rtol=1e-5
    )


def test_straggler_monitor_fires():
    mon = StragglerMonitor(alpha=0.2, threshold=1.5, patience=2)
    fired = []
    mon.on_straggle = lambda step, ratio: fired.append((step, ratio))
    for i in range(10):
        mon.observe(i, 1.0)
    for i in range(10, 13):
        mon.observe(i, 3.0)
    assert fired and fired[0][0] >= 10
    assert mon.events


def test_straggler_monitor_ignores_single_spike():
    mon = StragglerMonitor(threshold=1.5, patience=3)
    for i in range(5):
        mon.observe(i, 1.0)
    assert not mon.observe(5, 5.0)  # one spike: no event
    assert not mon.events

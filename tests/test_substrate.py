"""Substrate tests: data pipeline, optimizer, compression, checkpointing."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, restore, save
from repro.configs import get_config, reduced
from repro.data import DataConfig, SyntheticLM
from repro.optim import AdamW, AdamWConfig, WarmupCosine, compress_grads, init_residuals


# ---------------------------------------------------------------------------
# Data
# ---------------------------------------------------------------------------


def test_data_deterministic_and_shardable():
    cfg = reduced(get_config("granite-8b"))
    ds = SyntheticLM(cfg, batch=8, seq_len=32, dcfg=DataConfig(seed=3))
    b1 = ds.global_batch(5)
    b2 = ds.global_batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(b1["tokens"], ds.global_batch(6)["tokens"])
    # host slices partition the global batch
    parts = [ds.host_slice(5, i, 4)["tokens"] for i in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts, 0), b1["tokens"])


def test_data_has_learnable_structure():
    cfg = reduced(get_config("granite-8b")).replace(vocab_size=64)
    ds = SyntheticLM(cfg, batch=4, seq_len=256, dcfg=DataConfig(seed=0, noise_p=0.2))
    t = ds.global_batch(0)["tokens"]
    nxt = (t[:, :-1] * 3 + 7) % 64
    frac_chain = (t[:, 1:] == nxt).mean()
    assert frac_chain > 0.6  # ~80% of transitions follow the chain


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------


def quad_params():
    return {"w": jnp.asarray([3.0, -2.0, 1.5]), "b": jnp.asarray([[1.0, -1.0]] * 2)}


@pytest.mark.parametrize("state_dtype", ["float32", "bfloat16", "int8"])
def test_adamw_descends(state_dtype):
    opt = AdamW(AdamWConfig(state_dtype=state_dtype, weight_decay=0.0))
    params = quad_params()
    state = opt.init(params)

    def loss(p):
        return sum(jnp.sum(jnp.square(x)) for x in jax.tree_util.tree_leaves(p))

    l0 = float(loss(params))
    for _ in range(50):
        grads = jax.grad(loss)(params)
        params, state, _ = opt.update(grads, state, params, jnp.float32(0.05))
    assert float(loss(params)) < 0.2 * l0


def test_adamw_master_weights_bf16_params():
    opt = AdamW(AdamWConfig(master_weights=True, weight_decay=0.0))
    params = {"w": jnp.ones((64,), jnp.bfloat16)}
    state = opt.init(params)
    assert state["master"]["w"].dtype == jnp.float32

    def loss(p):
        return jnp.sum(jnp.square(p["w"].astype(jnp.float32)))

    for _ in range(30):
        g = jax.grad(loss)(params)
        params, state, _ = opt.update(g, state, params, jnp.float32(0.03))
    # master accumulates sub-bf16 updates
    assert float(loss(params)) < 10.0


def test_int8_state_bytes():
    assert AdamW(AdamWConfig(state_dtype="int8")).state_bytes_per_param() < 2.2
    assert AdamW(AdamWConfig(state_dtype="float32")).state_bytes_per_param() == 8.0


def test_compression_error_feedback_identity():
    """quantized + residual == accumulated true gradients (EF exactness)."""
    rng = np.random.default_rng(0)
    grads = {"w": jnp.asarray(rng.normal(size=(300,)), jnp.float32)}
    res = init_residuals(grads)
    q, res = compress_grads(grads, res)
    np.testing.assert_allclose(
        np.asarray(q["w"] + res["w"]), np.asarray(grads["w"]), atol=1e-6
    )
    # int8 error is bounded by scale step
    err = np.abs(np.asarray(res["w"]))
    blocks = np.abs(np.asarray(grads["w"]))
    assert err.max() <= blocks.max() / 127.0 + 1e-6


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------


def test_ckpt_roundtrip_bf16(tmp_path):
    tree = {
        "a": jnp.asarray(np.arange(12).reshape(3, 4), jnp.bfloat16),
        "nested": {"b": jnp.ones((2,), jnp.int32), "c": jnp.zeros((), jnp.float32)},
    }
    p = str(tmp_path / "ck")
    save(p, tree, step=7, metadata={"note": "x"})
    out, meta = restore(p, jax.eval_shape(lambda: tree))
    assert meta["step"] == 7
    np.testing.assert_array_equal(
        np.asarray(out["a"], np.float32), np.asarray(tree["a"], np.float32)
    )
    assert out["a"].dtype == jnp.bfloat16


def test_ckpt_manager_rotation_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    tree = {"x": jnp.arange(4)}
    for s in (10, 20, 30):
        mgr.save(s, tree)
    assert mgr.all_steps() == [20, 30]
    out, meta = mgr.restore_latest(jax.eval_shape(lambda: tree))
    assert meta["step"] == 30


def test_ckpt_shape_mismatch_raises(tmp_path):
    p = str(tmp_path / "ck")
    save(p, {"x": jnp.ones((4,))})
    with pytest.raises(ValueError):
        restore(p, jax.eval_shape(lambda: {"x": jnp.ones((5,))}))


def test_schedule_shapes():
    sch = WarmupCosine(peak_lr=1e-3, warmup_steps=10, decay_steps=100)
    assert float(sch(0)) == 0.0
    assert float(sch(10)) == pytest.approx(1e-3, rel=1e-5)
    assert float(sch(100)) == pytest.approx(1e-4, rel=1e-2)

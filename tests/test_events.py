"""Event-queue substrate (ISSUE 4): pre-refactor bit-identity locks,
preemption/checkpoint-restart mechanics, elastic resizing, migration,
legacy dispatcher parity + deprecation."""
import hashlib
import warnings

import numpy as np
import pytest

from repro.core import (
    Arrival,
    Cluster,
    EcoSched,
    ElasticConfig,
    EnergyAwareDispatcher,
    EventQueue,
    JobProfile,
    LeastLoadedDispatcher,
    Marble,
    Node,
    NodeSim,
    NodeSpec,
    ProfiledPerfModel,
    RoundRobinDispatcher,
    SequentialMax,
    bursty_stream,
    elastic_summary,
    poisson_stream,
    simulate,
)
from repro.core import calibration as C
from repro.core.events import (
    EVT_ARRIVAL,
    EVT_COMPLETE,
    EVT_MIGRATE,
    EVT_PREEMPT,
    EVT_RESUME,
)
from repro.core.types import RunningJob
from repro.roofline.hw import A100, H100, V100


def fp_records(records):
    s = ";".join(
        f"{r.job}|{r.g}|{r.start!r}|{r.end!r}|{r.node}|{r.domain}"
        for r in records
    )
    return hashlib.md5(s.encode()).hexdigest()


def prof(name, times, pows):
    util = {g: 1.0 / (times[g] * g) for g in times}
    return JobProfile(name=name, runtime=times, busy_power=pows, dram_util=util)


# ---------------------------------------------------------------------------
# Regression lock: the substrate reproduces the PRE-refactor loops bit-exactly
# (fingerprints captured from the original simulate()/Cluster.simulate()
# heaps at commit 07ec742, immediately before the events.py refactor)
# ---------------------------------------------------------------------------

GOLDEN = {
    "single_eco": ("4e5acdeeb3914722311e6f77658684e6",
                   28776.922695292677, 37833975.82206808),
    "single_marble": ("ae237255c84080ef71dd1656b25dd6fc",
                      37049.71767090324, 42220817.23598296),
    # rr/ll fingerprints re-captured for ISSUE 9: dispatcher ordering and
    # score ties now follow *name rank* instead of spec construction order
    # (the hetero fixture constructs h100-0 before a100-0, so the rr cycle
    # and the empty-cluster ll ties shifted; eco scores have no ties here
    # and its rows are the original pre-refactor captures)
    "cluster_rr_poisson": ("6d4e0947e2cc1abf9fbbca4344388686",
                           29071.552330516854, 52281764.54420596),
    "cluster_rr_bursty": ("026e027ccb63f638f098a003d07e20d6",
                          30795.74235233504, 56501997.61546908),
    "cluster_ll_poisson": ("89870d98998f9d73dc8e9029ada743a2",
                           23660.99784615058, 50152980.42951542),
    "cluster_ll_bursty": ("5d0ba4e4314ceb89afd624e415a405e8",
                          23587.94143314568, 51811670.13997635),
    "cluster_eco_poisson": ("121a072270dd10043f630b6817baa3a8",
                            22616.542502162163, 48650401.147005975),
    "cluster_eco_bursty": ("221212a44202a789b7345968ae61b2f4",
                           24528.02720558229, 52370378.05932653),
    "cluster_fifo_bursty": ("e66e494286395166d4d76d421082bd10",
                            53076.10181267525, 67945350.48415726),
}


def _hetero(dispatcher):
    return Cluster(
        [NodeSpec("h100-0", H100), NodeSpec("a100-0", A100),
         NodeSpec("v100-0", V100)],
        truth_for=lambda s: C.build_system(s.chip.name),
        policy_for=lambda s, t: EcoSched(
            ProfiledPerfModel(t, noise=0.02, seed=1), lam=0.35, tau=0.45
        ),
        dispatcher=dispatcher,
        slowdown_for=lambda s: C.cross_numa_slowdown,
    )


def _golden_streams():
    return {
        "poisson": poisson_stream(C.APP_ORDER, rate=1 / 700, n=20, seed=11),
        "bursty": bursty_stream(C.APP_ORDER, rate=1 / 500, n=22, burst=4, seed=5),
    }


def test_single_node_matches_pre_refactor_golden():
    truth = C.build_system("h100")
    node = Node(4, 2, C.idle_power("h100"))
    pol = EcoSched(ProfiledPerfModel(truth, noise=0.02, seed=1),
                   lam=0.35, tau=0.45)
    r = simulate(
        pol, node, truth,
        arrivals=[(120.0 * i, a) for i, a in enumerate(C.APP_ORDER)],
        slowdown_model=C.cross_numa_slowdown,
    )
    fp, makespan, energy = GOLDEN["single_eco"]
    assert fp_records(r.records) == fp
    assert r.makespan == makespan and r.total_energy == energy

    r2 = simulate(Marble(truth), node, truth, queue=list(C.APP_ORDER))
    fp, makespan, energy = GOLDEN["single_marble"]
    assert fp_records(r2.records) == fp
    assert r2.makespan == makespan and r2.total_energy == energy


@pytest.mark.parametrize("dn,disp", [
    ("rr", RoundRobinDispatcher), ("ll", LeastLoadedDispatcher),
    ("eco", EnergyAwareDispatcher),
])
def test_cluster_matches_pre_refactor_golden(dn, disp):
    for sn, stream in _golden_streams().items():
        res = _hetero(disp()).simulate(stream)
        fp, makespan, energy = GOLDEN[f"cluster_{dn}_{sn}"]
        assert fp_records(res.records) == fp
        assert res.makespan == makespan and res.total_energy == energy


def test_baseline_cluster_matches_pre_refactor_golden():
    res = Cluster(
        [NodeSpec("h100-0", H100), NodeSpec("v100-0", V100)],
        truth_for=lambda s: C.build_system(s.chip.name),
        policy_for=lambda s, t: SequentialMax(t),
        dispatcher=RoundRobinDispatcher(),
    ).simulate(_golden_streams()["bursty"])
    fp, makespan, energy = GOLDEN["cluster_fifo_bursty"]
    assert fp_records(res.records) == fp
    assert res.makespan == makespan and res.total_energy == energy


def test_all_off_elastic_config_is_bit_identical():
    """``ElasticConfig()`` with every switch off must ride the exact static
    path — single-node and cluster."""
    truth = C.build_system("v100")
    node = Node(4, 2, C.idle_power("v100"))

    def pol():
        return EcoSched(ProfiledPerfModel(truth, noise=0.02, seed=1),
                        lam=0.35, tau=0.45)

    a = simulate(pol(), node, truth, queue=list(C.APP_ORDER))
    b = simulate(pol(), node, truth, queue=list(C.APP_ORDER),
                 elastic=ElasticConfig())
    assert fp_records(a.records) == fp_records(b.records)
    assert a.total_energy == b.total_energy and a.makespan == b.makespan

    stream = _golden_streams()["poisson"]
    ca = _hetero(EnergyAwareDispatcher()).simulate(stream)
    cb = _hetero(EnergyAwareDispatcher()).simulate(
        stream, elastic=ElasticConfig()
    )
    assert fp_records(ca.records) == fp_records(cb.records)
    assert ca.total_energy == cb.total_energy


# ---------------------------------------------------------------------------
# Event queue ordering
# ---------------------------------------------------------------------------


def test_event_kind_ordering_at_one_instant():
    q = EventQueue()
    q.push(5.0, EVT_MIGRATE, "m")
    q.push(5.0, EVT_COMPLETE, "c")
    q.push(5.0, EVT_ARRIVAL, "a")
    q.push(5.0, EVT_RESUME, "r")
    q.push(5.0, EVT_PREEMPT, "p")
    q.push(1.0, EVT_COMPLETE, "early")
    order = [q.pop()[2] for _ in range(len(q))]
    assert order == ["early", "a", "c", "p", "r", "m"]


def test_same_kind_ties_keep_push_order():
    q = EventQueue()
    for i in range(5):
        q.push(2.0, EVT_COMPLETE, i)
    assert [q.pop()[2] for _ in range(len(q))] == [0, 1, 2, 3, 4]
    assert q.next_is(1.0, EVT_ARRIVAL) is False


# ---------------------------------------------------------------------------
# Preemption / checkpoint-restart mechanics
# ---------------------------------------------------------------------------

AB_TRUTH = {
    # A: moderate scaler whose τ-kept modes span {2, 3, 4}, with g=4 cheap
    # enough that upsizing beats the switch cost once the node drains
    "A": prof("A", {1: 3500, 2: 2000, 3: 1600, 4: 1450},
              {1: 140, 2: 250, 3: 330, 4: 380}),
    "B": prof("B", {1: 1050, 2: 600, 3: 480, 4: 435},
              {1: 140, 2: 250, 3: 330, 4: 380}),
}


def _eco_ab():
    return EcoSched(ProfiledPerfModel(AB_TRUTH, noise=0.0, seed=0),
                    lam=0.35, tau=0.45)


def test_resize_preempts_and_relaunches_at_better_count():
    """Co-scheduled pair at g=2 each; when B completes, A is checkpointed
    and relaunched on all 4 units — time and EDP improve, every joule is
    accounted."""
    node = Node(4, 2, 10.0)
    cfg = ElasticConfig(resize=True, ckpt_time=30.0, restart_time=15.0,
                        min_gain_s=60.0)
    static = simulate(_eco_ab(), node, AB_TRUTH, queue=["A", "B"])
    el = simulate(_eco_ab(), node, AB_TRUTH, queue=["A", "B"], elastic=cfg)

    assert static.preemptions == 0 and static.resizes == 0
    assert el.preemptions == 1
    assert el.resize_history == {"A": [(630.0, 2, 4)]}
    assert el.makespan < static.makespan
    assert el.edp < static.edp

    segs = [(r.job, r.g, r.segment, r.kind, r.start, r.end) for r in el.records]
    assert segs == [
        ("A", 2, 0, "ckpt", 0.0, 630.0),  # 600 useful + 30 ckpt write
        ("B", 2, 0, "run", 0.0, 600.0),
        ("A", 4, 1, "run", 630.0, 1660.0),  # 15 restart + 70% of 1450
    ]
    # exact energy: A seg0 = 600s@250W + 30s ckpt@250W; relaunch 1030s@380W
    assert el.records[0].busy_energy == 250.0 * 600 + 250.0 * 30
    assert el.records[0].ckpt_energy == 250.0 * 30
    assert el.records[2].busy_energy == pytest.approx(380.0 * 1030, rel=1e-12)
    assert el.ckpt_energy == 250.0 * 30
    assert el.busy_energy == pytest.approx(
        sum(r.busy_energy for r in el.records), rel=1e-12
    )
    assert elastic_summary(el) == {
        "preemptions": 1, "migrations": 0, "resizes": 1,
        "ckpt_energy": 250.0 * 30,
    }


def test_preemption_conserves_gpu_seconds():
    node = Node(4, 2, 10.0)
    cfg = ElasticConfig(resize=True, ckpt_time=30.0, restart_time=15.0,
                        min_gain_s=60.0)
    r = simulate(_eco_ab(), node, AB_TRUTH, queue=["A", "B"], elastic=cfg)
    busy_us = sum((rec.end - rec.start) * rec.g for rec in r.records)
    idle_us = r.idle_energy / node.idle_power_per_unit
    assert busy_us + idle_us == pytest.approx(node.units * r.makespan, rel=1e-9)


def test_max_preempts_bounds_churn():
    node = Node(4, 2, 10.0)
    cfg = ElasticConfig(resize=True, ckpt_time=1.0, restart_time=1.0,
                        min_gain_s=0.0, max_preempts=0)
    r = simulate(_eco_ab(), node, AB_TRUTH, queue=["A", "B"], elastic=cfg)
    assert r.preemptions == 0  # budget 0: the proposal is always refused


def test_frac_at_tracks_useful_work():
    rj = RunningJob(job="x", g=2, units=(0, 1), domain=0, start=100.0,
                    end=100.0 + 15.0 + 700.0, power=200.0,
                    frac0=0.3, restart=15.0)
    assert rj.frac_at(100.0) == pytest.approx(0.3)
    assert rj.frac_at(115.0) == pytest.approx(0.3)  # restart = no progress
    assert rj.frac_at(115.0 + 350.0) == pytest.approx(0.3 + 0.7 / 2)
    assert rj.frac_at(815.0) == pytest.approx(1.0)
    assert rj.frac_at(9999.0) == 1.0


def test_resize_identical_across_scoring_backends():
    """The switch-cost-biased resize scoring runs through whichever backend
    the policy uses — vector argmin, pure-Python reference, or the Pallas
    score-reduce kernel (interpret fallback on CPU) — with one decision."""
    import os

    os.environ.setdefault("REPRO_KERNELS", "interpret")
    node = Node(4, 2, 10.0)
    cfg = ElasticConfig(resize=True, ckpt_time=30.0, restart_time=15.0,
                        min_gain_s=60.0)
    out = {}
    for eng in ("vector", "python", "jax"):
        pol = EcoSched(ProfiledPerfModel(AB_TRUTH, noise=0.0, seed=0),
                       lam=0.35, tau=0.45, engine=eng)
        r = simulate(pol, node, AB_TRUTH, queue=["A", "B"], elastic=cfg)
        out[eng] = (r.makespan, r.total_energy, r.preemptions,
                    dict(r.resize_history))
    assert out["vector"] == out["python"] == out["jax"]
    assert out["vector"][3] == {"A": [(630.0, 2, 4)]}


def test_nonelastic_baselines_never_resize():
    node = Node(4, 2, 10.0)
    cfg = ElasticConfig(resize=True, ckpt_time=1.0, restart_time=1.0,
                        min_gain_s=0.0)
    r = simulate(SequentialMax(AB_TRUTH), node, AB_TRUTH,
                 queue=["A", "B"], elastic=cfg)
    assert r.preemptions == 0 and r.resizes == 0


# ---------------------------------------------------------------------------
# Migration
# ---------------------------------------------------------------------------

MIG_TRUTH = {
    "L": JobProfile(name="L", runtime={4: 4000.0}, busy_power={4: 400.0}),
    "S": JobProfile(name="S", runtime={4: 400.0}, busy_power={4: 400.0}),
}


def _mig_cluster():
    return Cluster(
        [NodeSpec("n0", H100), NodeSpec("n1", H100)],
        truth_for=lambda s: MIG_TRUTH,
        policy_for=lambda s, t: SequentialMax(t),
        dispatcher=RoundRobinDispatcher(),
    )


MIG_STREAM = [
    Arrival(0.0, "L#0", "L"), Arrival(0.0, "S#1", "S"), Arrival(0.0, "L#2", "L"),
]


def test_migration_pulls_waiting_job_to_drained_node():
    cfg = ElasticConfig(migrate=True, migration_delay=10.0, min_gain_s=60.0)
    static = _mig_cluster().simulate(MIG_STREAM)
    el = _mig_cluster().simulate(MIG_STREAM, elastic=cfg)
    assert static.migrations == 0
    assert el.migrations == 1
    assert el.makespan < static.makespan
    moved = next(r for r in el.records if r.job == "L#2")
    assert moved.node == "n1"  # pulled onto the drained node
    assert moved.start == pytest.approx(400.0 + 10.0)  # after the delay
    assert moved.arrival == 0.0  # waiting time counts from submission
    # donor queueing + transit is all genuine waiting for a job that
    # never ran: wait spans submission -> launch on the receiving node
    assert moved.wait == pytest.approx(410.0)
    assert el.per_node["n0"].migrations_out == 1
    assert el.per_node["n1"].migrations_in == 1
    # conservation per node still holds with the cross-node move
    for nm, nr in el.per_node.items():
        busy_us = sum((rec.end - rec.start) * rec.g for rec in nr.records)
        idle_us = nr.idle_energy / H100.power_idle
        assert busy_us + idle_us == pytest.approx(4 * nr.makespan, rel=1e-9)


def test_migration_declines_when_gain_too_small():
    cfg = ElasticConfig(migrate=True, migration_delay=10.0, min_gain_s=1e9)
    el = _mig_cluster().simulate(MIG_STREAM, elastic=cfg)
    assert el.migrations == 0


def test_preempted_job_state_travels_on_migration():
    """evict/absorb carry progress + the restart obligation across nodes;
    the relaunch runs only the remaining work plus the restart overhead."""
    cfg = ElasticConfig(resize=True, ckpt_time=30.0, restart_time=15.0,
                        min_gain_s=60.0)
    node = Node(4, 2, 10.0)
    donor = NodeSim(node, AB_TRUTH, _eco_ab(), name="donor", elastic=cfg)
    target = NodeSim(node, AB_TRUTH, SequentialMax(AB_TRUTH), name="target",
                     elastic=cfg)
    donor.arrive("A", 0.0)
    (rj,) = donor.invoke_policy()
    frac = 1000.0 / AB_TRUTH["A"].runtime[rj.g]
    ck_end = donor.begin_preempt(rj, 1000.0, cfg)
    assert ck_end == 1030.0
    donor.finish_preempt(rj, ck_end)
    donor.requeue("A", ck_end)  # the RESUME event the substrate would fire
    assert donor.progress["A"] == pytest.approx(frac)
    st = donor.evict("A")
    assert st.arrival == 0.0 and st.progress == pytest.approx(frac)
    assert st.restart is True and st.segment == 1
    assert st.preempts == 1 and st.last_g == rj.g  # budget + history travel
    assert st.queued_at == ck_end  # donor's requeue instant travels too
    assert donor.migrations_out == 1 and "A" not in donor.progress
    assert "A" not in donor.preempt_count

    target.absorb("A", 1040.0, st)
    assert target.migrations_in == 1
    assert target.preempt_count["A"] == 1  # max_preempts stays global
    (rj2,) = target.invoke_policy()
    assert rj2.frac0 == pytest.approx(frac) and rj2.restart == 15.0
    # SequentialMax launches at g=4: restart + the remaining fraction
    assert rj2.end - rj2.start == pytest.approx(15.0 + (1 - frac) * 1450.0)
    rec = target.records[-1]
    assert rec.arrival == 0.0 and rec.segment == 1
    # wait counts from the donor's requeue (1030) through the transit to
    # the launch at 1040 — queueing + transit, but not the running time
    assert rec.queued == ck_end and rec.wait == pytest.approx(10.0)
    if rj2.g != rj.g:  # cross-node resize lands in the history
        assert target.resize_history["A"] == [(1040.0, rj.g, rj2.g)]


def test_resumed_segment_wait_counts_requeue_time_only():
    """A preempted job's resume record must not count its own running time
    as waiting (mean_wait would otherwise penalize elastic runs)."""
    node = Node(4, 2, 10.0)
    cfg = ElasticConfig(resize=True, ckpt_time=30.0, restart_time=15.0,
                        min_gain_s=60.0)
    el = simulate(_eco_ab(), node, AB_TRUTH, queue=["A", "B"], elastic=cfg)
    resumed = next(r for r in el.records if r.segment == 1)
    # requeued at the checkpoint end (630) and relaunched immediately
    assert resumed.queued == 630.0
    assert resumed.wait == pytest.approx(0.0)
    assert resumed.arrival == 0.0  # submission time still preserved


# ---------------------------------------------------------------------------
# Legacy route(arr, statuses) protocol: graduated to a hard error (satellite)
# ---------------------------------------------------------------------------


class LegacyLeastLoaded:
    """route()-only dispatcher — the pre-PR-4 protocol, now rejected."""

    def name(self):
        return "legacy-ll"

    def route(self, arr, statuses):
        raise AssertionError("the legacy protocol must never be invoked")


def test_legacy_route_only_dispatcher_is_rejected():
    """A dispatcher without route_indexed fails fast at run construction
    (the DeprecationWarning period ended; the list protocol is gone)."""
    stream = [Arrival(0.0, "L#0", "L")]
    cl = Cluster(
        [NodeSpec("n0", H100)],
        truth_for=lambda s: MIG_TRUTH,
        policy_for=lambda s, t: SequentialMax(t),
        dispatcher=LegacyLeastLoaded(),
    )
    with pytest.raises(TypeError, match="route_indexed"):
        cl.simulate(stream)


def test_route_indexed_dispatcher_does_not_warn():
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        _mig_cluster().simulate(MIG_STREAM)  # must not raise


# ---------------------------------------------------------------------------
# Cancel races against in-flight elastic transitions (ISSUE 8)
# ---------------------------------------------------------------------------


def test_cancel_during_migration_transit_is_refused():
    """Between the donor's evict and the receiver's absorb the job exists
    only as an in-flight MIGRATE event; a cancel there must be refused
    and the migration must land untouched."""
    cfg = ElasticConfig(migrate=True, migration_delay=10.0, min_gain_s=60.0)

    def drive(cancel_at=None):
        run = _mig_cluster().open_run(apps=["L", "S"], elastic=cfg)
        for a in MIG_STREAM:
            run.submit(a.name, a.app, a.t)
        if cancel_at is not None:
            run.run_until(cancel_at)
            assert run.cancel("L#2") is False  # mid-transit: refused
        run.run_to_completion()
        return run.finalize()

    res = drive(cancel_at=405.0)  # n1 drains at 400, L#2 lands at 410
    ctrl = drive()
    assert res.migrations == 1
    moved = next(r for r in res.records if r.job == "L#2")
    assert moved.node == "n1" and moved.start == pytest.approx(410.0)
    assert [(r.job, r.node, r.start, r.end) for r in res.records] == [
        (r.job, r.node, r.start, r.end) for r in ctrl.records
    ]


def test_cancel_during_checkpoint_write_is_refused():
    """While a resize checkpoint is being written the job is neither
    waiting nor done; cancel must refuse, and the relaunch must proceed
    exactly as if nobody had asked."""
    cfg = ElasticConfig(resize=True, ckpt_time=30.0, restart_time=15.0,
                        min_gain_s=60.0)

    def cluster():
        return Cluster(
            [NodeSpec("n0", H100)],
            truth_for=lambda s: AB_TRUTH,
            policy_for=lambda s, t: _eco_ab(),
            dispatcher=RoundRobinDispatcher(),
        )

    def drive(cancel_at=None):
        run = cluster().open_run(apps=["A", "B"], elastic=cfg)
        run.submit("A", "A", 0.0)
        run.submit("B", "B", 0.0)
        if cancel_at is not None:
            run.run_until(cancel_at)
            assert run.cancel("A") is False  # mid-ckpt-write: refused
        run.run_to_completion()
        return run.finalize()

    res = drive(cancel_at=615.0)  # ckpt write spans 600 -> 630
    ctrl = drive()
    segs = [(r.job, r.g, r.kind, r.start, r.end) for r in res.records]
    assert ("A", 2, "ckpt", 0.0, 630.0) in segs
    assert segs == [(r.job, r.g, r.kind, r.start, r.end) for r in ctrl.records]

"""Fleet scale-out (ISSUE 9): hierarchical dispatch parity with the flat
reference, dispatcher permutation invariance (construction order must not
leak into schedules), cross-node batched jax decisions (staging is pure:
schedules bit-identical to the solo kernel path), capacity-degradation
staleness (satellite 4), and the fragmentation gauge."""
import numpy as np
import pytest

from repro.core import (
    Cluster,
    ClusterState,
    EcoSched,
    EnergyAwareDispatcher,
    FaultConfig,
    FleetIndex,
    HierarchicalDispatcher,
    JobProfile,
    LeastLoadedDispatcher,
    NodeSpec,
    PredictiveDispatcher,
    ProfiledPerfModel,
    RoundRobinDispatcher,
    bursty_stream,
)
from repro.core import calibration as C
from repro.core.events import EVT_ARRIVAL
from repro.core.types import NodeView
from repro.kernels.score_reduce import score_reduce
from repro.roofline.hw import A100, H100, V100

CHIP_CYCLE = [H100, A100, V100]


def eco_policy(spec, truth):
    return EcoSched(
        ProfiledPerfModel(truth, noise=0.02, seed=1), lam=0.35, tau=0.45
    )


def fleet_cluster(dispatcher, *, n=12, order=None, policies=None):
    """Hetero fleet with zero-padded names (name order == index order when
    ``order`` is None); ``order`` permutes the *construction* order only —
    the same named nodes exist either way."""
    idx = list(range(n)) if order is None else list(order)

    def policy_for(spec, truth):
        pol = eco_policy(spec, truth)
        if policies is not None:
            policies.append(pol)
        return pol

    return Cluster(
        [
            NodeSpec(f"n{i:03d}", CHIP_CYCLE[i % 3], units=4, domains=2)
            for i in idx
        ],
        truth_for=lambda s: C.build_system(s.chip.name),
        policy_for=policy_for,
        dispatcher=dispatcher,
        slowdown_for=lambda s: C.cross_numa_slowdown,
    )


def fleet_stream(n=60, seed=11):
    return bursty_stream(list(C.APP_ORDER), rate=0.25, n=n, seed=seed, burst=6)


def schedule_of(res):
    return [(r.job, r.node, r.g, r.start, r.end) for r in res.records]


# ---------------------------------------------------------------------------
# Hierarchical dispatch: schedule parity with the flat reference
# ---------------------------------------------------------------------------


DISPATCHERS = {
    "rr": RoundRobinDispatcher,
    "ll": LeastLoadedDispatcher,
    "eco": EnergyAwareDispatcher,
}


@pytest.mark.parametrize("disp", list(DISPATCHERS), ids=list(DISPATCHERS))
def test_hierarchical_matches_flat(disp):
    """Two-level (region -> pod -> node) routing with summary-table
    pruning picks the same node as the flat scan, every arrival."""
    mk = DISPATCHERS[disp]
    stream = fleet_stream()
    flat = fleet_cluster(mk()).simulate(stream)
    hier = fleet_cluster(
        HierarchicalDispatcher(mk(), pod_size=4, pods_per_region=2)
    ).simulate(stream)
    assert schedule_of(hier) == schedule_of(flat)
    assert hier.total_energy == flat.total_energy


def test_hierarchical_name():
    h = HierarchicalDispatcher(EnergyAwareDispatcher())
    assert h.name() == "hier-eco"


def test_hierarchical_ragged_pod_geometry():
    """Node counts that don't divide evenly into pods/regions still route
    identically (last pod and last region are short)."""
    stream = fleet_stream(n=40, seed=5)
    flat = fleet_cluster(EnergyAwareDispatcher(), n=11).simulate(stream)
    hier = fleet_cluster(
        HierarchicalDispatcher(EnergyAwareDispatcher(), pod_size=3,
                               pods_per_region=2),
        n=11,
    ).simulate(stream)
    assert schedule_of(hier) == schedule_of(flat)


# ---------------------------------------------------------------------------
# Permutation invariance (satellite 3): construction order must not leak
# ---------------------------------------------------------------------------


PERM_DISPATCHERS = {
    "rr": lambda: RoundRobinDispatcher(),
    "ll": lambda: LeastLoadedDispatcher(),
    "eco": lambda: EnergyAwareDispatcher(),
    "predictive": lambda: PredictiveDispatcher(),
    "hier-eco": lambda: HierarchicalDispatcher(
        EnergyAwareDispatcher(), pod_size=4, pods_per_region=2
    ),
    "hier-rr": lambda: HierarchicalDispatcher(
        RoundRobinDispatcher(), pod_size=4, pods_per_region=2
    ),
}


@pytest.mark.parametrize("disp", list(PERM_DISPATCHERS), ids=list(PERM_DISPATCHERS))
def test_dispatcher_permutation_invariance(disp):
    """The same named fleet built in a permuted order produces the exact
    same schedule: every tie breaks on name rank, never on spec index."""
    stream = fleet_stream(n=48, seed=13)
    base = fleet_cluster(PERM_DISPATCHERS[disp]()).simulate(stream)
    rng = np.random.default_rng(99)
    for _ in range(2):
        order = rng.permutation(12).tolist()
        perm = fleet_cluster(PERM_DISPATCHERS[disp](), order=order).simulate(stream)
        assert schedule_of(perm) == schedule_of(base), order
        # per-node results are bitwise equal; the cluster total is summed
        # in construction order, so only ulp-level drift is tolerated
        assert sorted(
            (nm, r.total_energy) for nm, r in perm.per_node.items()
        ) == sorted((nm, r.total_energy) for nm, r in base.per_node.items())
        assert perm.total_energy == pytest.approx(base.total_energy, rel=1e-12)


# ---------------------------------------------------------------------------
# Cross-node batched jax decisions: staging is pure
# ---------------------------------------------------------------------------


def jax_fleet(policies=None, dispatcher=None):
    apps = C.build_system("h100")

    def policy_for(spec, truth):
        pol = EcoSched(
            ProfiledPerfModel(truth, noise=0.0, seed=1),
            lam=0.35, tau=0.45, engine="jax",
        )
        if policies is not None:
            policies.append(pol)
        return pol

    return Cluster(
        [NodeSpec(f"n{i:03d}", H100, units=8, domains=2) for i in range(4)],
        truth_for=lambda s: apps,
        policy_for=policy_for,
        dispatcher=dispatcher or RoundRobinDispatcher(),
    )


def run_without_batching(cl, stream, **kw):
    """Cluster.simulate with the fleet staging hook disabled — the solo
    per-node kernel path."""
    stream = sorted(stream, key=lambda a: a.t)
    run = cl.open_run(
        apps=sorted({a.app for a in stream}),
        jobs=[(a.name, a.app) for a in stream],
        **kw,
    )
    run.loop.prepare_batch = None
    for a in stream:
        if a.t <= 0.0:
            run.route(a, 0.0)
        else:
            run.loop.queue.push(a.t, EVT_ARRIVAL, a)
    run.loop.run()
    return run.finalize()


def test_batched_jax_matches_solo_bitwise():
    """Same-instant multi-node bursts are scored in one cross-node kernel
    launch; the schedule is bit-identical to per-node solo launches."""
    stream = fleet_stream(n=48, seed=21)
    pols = []
    batched = jax_fleet(policies=pols).simulate(stream)
    assert sum(p.stage_served for p in pols) > 0  # the batch path ran
    solo = run_without_batching(jax_fleet(), stream)
    assert schedule_of(batched) == schedule_of(solo)
    assert batched.total_energy == solo.total_energy


def test_batched_jax_under_faults_matches_solo():
    """set_alive_units x batched path (satellite 4, end-to-end): capacity
    events interleave with staged bursts; every decision still lands
    exactly where the solo path puts it."""
    cfg = FaultConfig(
        seed=4, node_mtbf_s=4000.0, node_mttr_s=600.0,
        degrade_frac=0.5, degrade_units=4, job_mtbf_s=9000.0,
    )
    stream = fleet_stream(n=40, seed=23)
    pols = []
    batched = jax_fleet(policies=pols).simulate(stream, faults=cfg)
    solo = run_without_batching(jax_fleet(), stream, faults=cfg)
    assert schedule_of(batched) == schedule_of(solo)
    assert batched.total_energy == solo.total_energy


def test_stale_staging_refits_on_capacity_change():
    """Satellite 4, mechanism level: a staged result whose node degraded
    between staging and consumption is discarded (signature mismatch) and
    the decision recomputes against the degraded view."""
    truth = C.build_system("h100")
    jobs = list(C.APP_ORDER)[:4]

    def fresh():
        return EcoSched(
            ProfiledPerfModel(truth, noise=0.0, seed=1),
            lam=0.35, tau=0.45, engine="jax",
        )

    view = NodeView(t=0.0, total_units=8, domains=2, free_units=8,
                    running=[], free_map=[True] * 8, domain_jobs=[0, 0])

    # coordinator round trip against the healthy view
    pol = fresh()
    req = pol.stage_score(view, jobs)
    assert req is not None
    _, best = score_reduce(**req)
    req2 = pol.stage_round1(int(best))
    if req2 is not None:
        _, best2 = score_reduce(**req2)
        pol.stage_round2(int(best2))

    # the node loses half its units before _schedule consumes the staging
    degraded = NodeView(
        t=0.0, total_units=8, domains=2, free_units=4, running=[],
        free_map=[True] * 4 + [False] * 4, domain_jobs=[0, 0], dead_units=4,
    )
    out = pol.on_event(degraded, jobs)
    assert pol.stage_served == 0  # stale staging was NOT consumed
    assert out == fresh().on_event(degraded, jobs)
    for ln in out:  # and the re-fit respects the degraded capacity
        assert ln.g <= 4

    # control: an unchanged view does consume the staging
    pol2 = fresh()
    req = pol2.stage_score(view, jobs)
    _, best = score_reduce(**req)
    r2 = pol2.stage_round1(int(best))
    if r2 is not None:
        _, b2 = score_reduce(**r2)
        pol2.stage_round2(int(b2))
    out2 = pol2.on_event(view, jobs)
    assert pol2.stage_served == 1
    assert out2 == fresh().on_event(view, jobs)


def test_stage_score_declines_when_no_kernel_would_run():
    truth = C.build_system("h100")
    view = NodeView(t=0.0, total_units=8, domains=2, free_units=8,
                    running=[], free_map=[True] * 8, domain_jobs=[0, 0])
    vec = EcoSched(ProfiledPerfModel(truth, noise=0.0, seed=1), engine="vector")
    assert vec.stage_score(view, list(C.APP_ORDER)[:2]) is None
    jax_pol = EcoSched(
        ProfiledPerfModel(truth, noise=0.0, seed=1), engine="jax"
    )
    assert jax_pol.stage_score(view, []) is None  # empty window
    # a launch-memo hit needs no kernel: prime the memo, then re-stage
    jobs = list(C.APP_ORDER)[:2]
    jax_pol.on_event(view, jobs)
    assert jax_pol.stage_score(view, jobs) is None


# ---------------------------------------------------------------------------
# Fragmentation gauge (Lettich-style unusable-GPU fraction)
# ---------------------------------------------------------------------------


def rigid_cluster(n_nodes=2, dispatcher=None):
    """Nodes with 6 units but a single rigid 4-GPU mode: whenever a job
    runs, the 2 leftover units are unusable for the pending mix."""
    apps = {
        "rigid": JobProfile(
            name="rigid", runtime={4: 120.0}, busy_power={4: 400.0}
        )
    }
    return Cluster(
        [NodeSpec(f"n{i:03d}", H100, units=6, domains=2) for i in range(n_nodes)],
        truth_for=lambda s: apps,
        policy_for=eco_policy,
        dispatcher=dispatcher or LeastLoadedDispatcher(),
    )


def test_frag_now_arithmetic():
    apps = {
        "rigid": JobProfile(
            name="rigid", runtime={4: 120.0}, busy_power={4: 400.0}
        )
    }
    spec = NodeSpec("n000", H100, units=6, domains=2)
    st = ClusterState([spec], {"n000": apps}, ["rigid"])
    assert st.frag_now() == 0.0  # nothing waiting
    st.on_arrive(0, 0)
    # free=6, best fit for the 4-GPU mode leaves 2 unusable: 2/6
    assert st.frag_now() == pytest.approx(2.0 / 6.0)
    st.on_launch(0, 0, end=120.0, g=4)
    assert st.frag_now() == 0.0  # queue drained
    st.on_arrive(0, 0)
    # free=2 < smallest mode: the whole remainder is unusable
    assert st.frag_now() == pytest.approx(1.0)
    st.on_complete(0, end=120.0, g=4)
    assert st.frag_now() == pytest.approx(2.0 / 6.0)


def test_cluster_result_reports_fragmentation():
    stream = bursty_stream(["rigid"], rate=0.2, n=24, seed=3, burst=6)
    res = rigid_cluster().simulate(stream)
    frag = res.fragmentation
    assert set(frag) == {"time_avg", "peak", "final"}
    assert 0.0 < frag["time_avg"] <= 1.0  # rigid mix under load fragments
    assert frag["peak"] >= frag["time_avg"]
    assert frag["final"] == 0.0  # everything drained at makespan


def test_fragmentation_zero_when_mix_fits():
    """A mode list that always packs the node exactly never strands
    capacity: the gauge stays at zero end to end."""
    apps = {
        "elastic": JobProfile(
            name="elastic",
            runtime={1: 100.0, 2: 60.0, 4: 40.0},
            busy_power={1: 300.0, 2: 550.0, 4: 1000.0},
        )
    }
    cl = Cluster(
        [NodeSpec("n000", H100, units=4, domains=2)],
        truth_for=lambda s: apps,
        policy_for=eco_policy,
        dispatcher=LeastLoadedDispatcher(),
    )
    res = cl.simulate(bursty_stream(["elastic"], rate=0.2, n=12, seed=3))
    assert res.fragmentation["peak"] == 0.0


# ---------------------------------------------------------------------------
# FleetIndex summaries: admissible bounds, lazy refresh
# ---------------------------------------------------------------------------


def test_fleet_index_bounds_are_admissible():
    """pod-level out_lb never exceeds the true per-node drain proxy of any
    node in the pod — the precondition for pruning being lossless."""
    stream = fleet_stream(n=30, seed=7)
    cl = fleet_cluster(
        HierarchicalDispatcher(EnergyAwareDispatcher(), pod_size=4,
                               pods_per_region=2)
    )
    run = cl.open_run(
        apps=sorted({a.app for a in stream}),
        jobs=[(a.name, a.app) for a in stream],
    )
    for a in sorted(stream, key=lambda a: a.t):
        run.loop.queue.push(a.t, EVT_ARRIVAL, a) if a.t > 0 else run.route(a, 0.0)
    run.loop.run()
    state = run.state
    fleet = state._fleet
    assert isinstance(fleet, FleetIndex)
    fleet.refresh()
    now = run.loop.now
    out = state.outstanding(now)
    lb = fleet.out_lb(now)
    for p in range(fleet.n_pods):
        nodes = state.order[fleet.pod_lo[p]: fleet.pod_hi[p]]
        assert lb[p] <= out[nodes].min() + 1e-9


def test_fleet_index_load_skew_bound_is_tight_then_decays_admissibly():
    """ISSUE 10 load-skew pieces: right after a refresh the pod bound
    equals the exact per-member outstanding minimum (tight, not a min of
    sums), and between refreshes it decays at the fastest member drain
    rate — staying below every member's true backlog at any later t."""
    stream = fleet_stream(n=40, seed=13)
    cl = fleet_cluster(
        HierarchicalDispatcher(EnergyAwareDispatcher(), pod_size=4,
                               pods_per_region=2)
    )
    run = cl.open_run(
        apps=sorted({a.app for a in stream}),
        jobs=[(a.name, a.app) for a in stream],
    )
    for a in sorted(stream, key=lambda a: a.t):
        run.loop.queue.push(a.t, EVT_ARRIVAL, a) if a.t > 0 else run.route(a, 0.0)
    # drain only part of the event queue so real work is still in flight
    run.loop.run_until(sorted(a.t for a in stream)[len(stream) // 2])
    state, fleet = run.state, run.state._fleet
    now = run.loop.now
    fleet.refresh(now)
    out = state.outstanding(now)
    lb = fleet.out_lb(now)
    for p in range(fleet.n_pods):
        nodes = state.order[fleet.pod_lo[p]: fleet.pod_hi[p]]
        assert lb[p] == pytest.approx(out[nodes].min())  # tight at refresh
    assert out.max() > out.min()  # the stream actually skews the load
    # admissible decay: without any new event, the bound at a later
    # instant still lower-bounds each member's true outstanding there
    for dt in (10.0, 300.0, 5000.0):
        later = now + dt
        out_t = state.outstanding(later)
        lb_t = fleet.out_lb(later)
        for p in range(fleet.n_pods):
            nodes = state.order[fleet.pod_lo[p]: fleet.pod_hi[p]]
            assert lb_t[p] <= out_t[nodes].min() + 1e-9, (p, dt)
